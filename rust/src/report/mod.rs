//! Figure/table harness: regenerates every figure of the paper's
//! evaluation (Figs. 1, 4, 5, 6, 7, 8, 9, 10) and the headline geomean
//! claims, as CSV + markdown. Cluster-plane tables (fleet scaling and
//! router-policy comparisons) live in [`cluster`]; DSE-plane tables
//! (Pareto frontiers, the §V-B 3-point search) live in [`dse`];
//! power-plane tables (energy per token, power over time, TDP
//! throttling) live in [`power`].

pub mod cluster;
pub mod critpath;
pub mod dse;
pub mod obs;
pub mod power;

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::arch::cim::CimEngine;
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig, Phase};
use crate::sim::roofline::{roofline_points, Roofline};
use crate::sim::{simulate_e2e, simulate_phase, Scenario};
use crate::util::geomean;

/// A generated table (one per figure panel).
#[derive(Debug, Clone)]
pub struct Table {
    pub name: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: &str, title: &str, headers: &[&str]) -> Self {
        Table {
            name: name.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity in {}", self.name);
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let hdrs: Vec<&str> = self.headers.iter().map(|s| s.as_str()).collect();
        format!("### {}\n\n{}", self.title, crate::util::markdown_table(&hdrs, &self.rows))
    }

    pub fn write_csv(&self, dir: &Path) -> std::io::Result<()> {
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{}.csv", self.name)), self.to_csv())
    }

    /// Numeric column accessor (for tests/benches).
    pub fn col_f64(&self, header: &str) -> Vec<f64> {
        let idx = self.headers.iter().position(|h| h == header).expect("header");
        self.rows.iter().filter_map(|r| r[idx].parse().ok()).collect()
    }
}

pub(crate) fn f(v: f64) -> String {
    format!("{v:.6e}")
}

/// The (L_in, L_out) grid of Figs. 7/8/10 (paper: 128 up to 10K tokens).
pub fn context_grid() -> Vec<(usize, usize)> {
    let mut g = Vec::new();
    for l_in in [128usize, 512, 2048, 4096, 8192] {
        for l_out in [128usize, 512, 2048] {
            g.push((l_in, l_out));
        }
    }
    g
}

/// The L_in sweep of Figs. 5/6.
pub fn lin_sweep() -> Vec<usize> {
    vec![128, 512, 1024, 2048, 4096, 8192]
}

// ---------------------------------------------------------------- figures

/// Fig. 1: roofline of the CiM accelerator, prefill (BS=1, L=512) vs
/// decode (BS=1 and BS=16) GEMMs of LLaMA-2 7B.
pub fn fig1_roofline(hw: &HwConfig) -> Table {
    fig1_roofline_at(hw, 512, 16)
}

/// Fig. 1 roofline at a custom scenario point: prefill (`l_in`, BS=1) vs
/// decode at context `l_in` for BS=1 and BS=`batch` (the CLI's
/// `roofline --lin/--batch` entry).
pub fn fig1_roofline_at(hw: &HwConfig, l_in: usize, batch: usize) -> Table {
    let m = LlmConfig::llama2_7b();
    let rf = Roofline::of(&CimEngine::new(hw));
    let mut t = Table::new(
        "fig1_roofline",
        &format!("Fig.1 — CiM roofline: LLaMA-2 7B GEMMs, prefill (L_in={l_in}) vs decode"),
        &[
            "phase",
            "batch",
            "op",
            "M",
            "K",
            "N",
            "intensity_flop_per_byte",
            "attainable_flops",
            "compute_bound",
            "ridge",
            "peak_flops",
        ],
    );
    let mut push = |phase: &str, batch: usize, graph| {
        for p in roofline_points(&graph, &rf, 1) {
            t.row(vec![
                phase.into(),
                batch.to_string(),
                p.kind.into(),
                p.m.to_string(),
                p.k.to_string(),
                p.n.to_string(),
                f(p.intensity),
                f(p.attainable_flops),
                p.compute_bound.to_string(),
                f(rf.ridge()),
                f(rf.peak_flops),
            ]);
        }
    };
    push("prefill", 1, build_prefill_graph(&m, l_in, 1));
    push("decode", 1, build_decode_graph(&m, l_in, 1));
    if batch != 1 {
        push("decode", batch, build_decode_graph(&m, l_in, batch));
    }
    t
}

/// Fig. 4: execution-time breakdown by operation class on the CiM
/// accelerator (L_in=2048, L_out=128, BS=1).
pub fn fig4_breakdown(hw: &HwConfig) -> Table {
    let m = LlmConfig::llama2_7b();
    let mut t = Table::new(
        "fig4_breakdown",
        "Fig.4 — execution-time breakdown on the CiM accelerator (LLaMA-2 7B, L_in=2048, L_out=128)",
        &["phase", "op", "latency_s", "share", "t_compute", "t_memory", "t_write"],
    );
    for (phase, seq) in [(Phase::Prefill, 2048usize), (Phase::Decode, 2048 + 64)] {
        let r = simulate_phase(&m, hw, MappingKind::FullCim, phase, seq, 1);
        for (kind, c) in &r.by_kind {
            t.row(vec![
                phase.name().into(),
                (*kind).into(),
                f(c.latency),
                f(c.latency / r.latency),
                f(c.t_compute),
                f(c.t_memory),
                f(c.t_write),
            ]);
        }
    }
    t
}

/// Figs. 5 & 6: fully-CiD vs fully-CiM, TTFT/TPOT and phase energies.
pub fn fig56_cid_vs_cim(hw: &HwConfig) -> Table {
    let m = LlmConfig::llama2_7b();
    let mut t = Table::new(
        "fig56_cid_vs_cim",
        "Fig.5/6 — fully-CiD vs fully-CiM: TTFT, prefill energy, TPOT, decode energy/token (LLaMA-2 7B)",
        &[
            "l_in",
            "ttft_cid_s",
            "ttft_cim_s",
            "prefill_e_cid_j",
            "prefill_e_cim_j",
            "tpot_cid_s",
            "tpot_cim_s",
            "decode_e_cid_j",
            "decode_e_cim_j",
        ],
    );
    for l_in in lin_sweep() {
        let pre_cid = simulate_phase(&m, hw, MappingKind::FullCid, Phase::Prefill, l_in, 1);
        let pre_cim = simulate_phase(&m, hw, MappingKind::FullCim, Phase::Prefill, l_in, 1);
        let ctx = l_in + 64;
        let dec_cid = simulate_phase(&m, hw, MappingKind::FullCid, Phase::Decode, ctx, 1);
        let dec_cim = simulate_phase(&m, hw, MappingKind::FullCim, Phase::Decode, ctx, 1);
        t.row(vec![
            l_in.to_string(),
            f(pre_cid.latency),
            f(pre_cim.latency),
            f(pre_cid.energy),
            f(pre_cim.energy),
            f(dec_cid.latency),
            f(dec_cim.latency),
            f(dec_cid.energy),
            f(dec_cim.energy),
        ]);
    }
    t
}

/// Figs. 7 (time) and 8 (energy): all Table II mappings over the context
/// grid, both models, normalized per config to the slowest baseline.
pub fn fig78_e2e(hw: &HwConfig, energy: bool) -> Table {
    let (name, title) = if energy {
        ("fig8_e2e_energy", "Fig.8 — e2e energy distribution and totals (normalized per config)")
    } else {
        ("fig7_e2e_time", "Fig.7 — e2e time distribution and totals (normalized per config)")
    };
    let mut t = Table::new(
        name,
        title,
        &["model", "l_in", "l_out", "mapping", "prefill", "decode", "total", "normalized"],
    );
    for m in [LlmConfig::llama2_7b(), LlmConfig::qwen3_8b()] {
        for (l_in, l_out) in context_grid() {
            let sc = Scenario { l_in, l_out, batch: 1 };
            let runs: Vec<_> = MappingKind::table2()
                .iter()
                .map(|mk| (*mk, simulate_e2e(&m, hw, *mk, &sc)))
                .collect();
            let value = |r: &crate::sim::RunResult| -> (f64, f64) {
                if energy {
                    (r.prefill.energy, r.decode_energy())
                } else {
                    (r.ttft(), r.decode_latency())
                }
            };
            let worst = runs
                .iter()
                .map(|(_, r)| {
                    let (p, d) = value(r);
                    p + d
                })
                .fold(0.0f64, f64::max);
            for (mk, r) in &runs {
                let (p, d) = value(r);
                t.row(vec![
                    m.name.into(),
                    l_in.to_string(),
                    l_out.to_string(),
                    mk.name().into(),
                    f(p),
                    f(d),
                    f(p + d),
                    f((p + d) / worst),
                ]);
            }
        }
    }
    t
}

/// Fig. 9: batch-size sweep at L_in=128, L_out=2048 (LLaMA-2 7B).
pub fn fig9_batch_sweep(hw: &HwConfig) -> Table {
    let m = LlmConfig::llama2_7b();
    let mut t = Table::new(
        "fig9_batch_sweep",
        "Fig.9 — e2e time vs batch size (LLaMA-2 7B, L_in=128, L_out=2048)",
        &["batch", "mapping", "e2e_s", "ttft_s", "tpot_s"],
    );
    for b in [1usize, 2, 4, 8, 16, 32, 64] {
        for mk in [
            MappingKind::Halo1,
            MappingKind::Halo2,
            MappingKind::Cent,
            MappingKind::AttAcc1,
            MappingKind::AttAcc2,
        ] {
            let r = simulate_e2e(&m, hw, mk, &Scenario { l_in: 128, l_out: 2048, batch: b });
            t.row(vec![
                b.to_string(),
                mk.name().into(),
                f(r.e2e_latency()),
                f(r.ttft()),
                f(r.tpot()),
            ]);
        }
    }
    t
}

/// Fig. 10: HALO with analog CiM (wl 128/64) vs iso-area systolic arrays.
pub fn fig10_cim_vs_sa(hw: &HwConfig) -> Table {
    let m = LlmConfig::llama2_7b();
    let mut t = Table::new(
        "fig10_cim_vs_sa",
        "Fig.10 — HALO-CiM1/2 vs HALO-SA, normalized e2e time (LLaMA-2 7B)",
        &["l_in", "l_out", "mapping", "e2e_s", "normalized_to_sa"],
    );
    for (l_in, l_out) in context_grid() {
        let sc = Scenario { l_in, l_out, batch: 1 };
        let sa = simulate_e2e(&m, hw, MappingKind::HaloSa, &sc).e2e_latency();
        for mk in [MappingKind::Halo1, MappingKind::Halo2, MappingKind::HaloSa] {
            let e = simulate_e2e(&m, hw, mk, &sc).e2e_latency();
            let label = match mk {
                MappingKind::Halo1 => "HALO-CiM1",
                MappingKind::Halo2 => "HALO-CiM2",
                _ => "HALO-SA",
            };
            t.row(vec![l_in.to_string(), l_out.to_string(), label.into(), f(e), f(e / sa)]);
        }
    }
    t
}

/// Headline geomean claims (paper abstract + §V-B/C/D), paper value vs ours.
pub fn headline_summary(hw: &HwConfig) -> Table {
    let m = LlmConfig::llama2_7b();
    let q = LlmConfig::qwen3_8b();
    let mut t = Table::new(
        "headline",
        "Headline geomean ratios: paper vs this reproduction",
        &["claim", "paper", "ours"],
    );

    // Fig.5/6 geomeans
    let mut ttft_r = Vec::new();
    let mut pre_e_r = Vec::new();
    let mut tpot_r = Vec::new();
    let mut dec_e_r = Vec::new();
    for l_in in lin_sweep() {
        let pc = simulate_phase(&m, hw, MappingKind::FullCid, Phase::Prefill, l_in, 1);
        let pm = simulate_phase(&m, hw, MappingKind::FullCim, Phase::Prefill, l_in, 1);
        ttft_r.push(pc.latency / pm.latency);
        pre_e_r.push(pc.energy / pm.energy);
        let dc = simulate_phase(&m, hw, MappingKind::FullCid, Phase::Decode, l_in + 64, 1);
        let dm = simulate_phase(&m, hw, MappingKind::FullCim, Phase::Decode, l_in + 64, 1);
        tpot_r.push(dm.latency / dc.latency);
        dec_e_r.push(dm.energy / dc.energy);
    }
    t.row(vec![
        "TTFT: fully-CiM over fully-CiD".into(),
        "6x".into(),
        format!("{:.2}x", geomean(&ttft_r)),
    ]);
    t.row(vec![
        "Prefill energy: CiM under CiD".into(),
        "2.6x".into(),
        format!("{:.2}x", geomean(&pre_e_r)),
    ]);
    t.row(vec![
        "TPOT: fully-CiD over fully-CiM".into(),
        "39x".into(),
        format!("{:.2}x", geomean(&tpot_r)),
    ]);
    t.row(vec![
        "Decode energy: CiD under CiM".into(),
        "3.9x".into(),
        format!("{:.2}x", geomean(&dec_e_r)),
    ]);

    // e2e & phase geomeans over both models and the grid
    let mut e2e_vs_att = Vec::new();
    let mut e2e_vs_cent = Vec::new();
    let mut pre_vs_cent = Vec::new();
    let mut dec_vs_att = Vec::new();
    let mut e_vs_att = Vec::new();
    let mut e_vs_cent = Vec::new();
    let mut h2_slow = Vec::new();
    for model in [&m, &q] {
        for (l_in, l_out) in context_grid() {
            let sc = Scenario { l_in, l_out, batch: 1 };
            let halo = simulate_e2e(model, hw, MappingKind::Halo1, &sc);
            let halo2 = simulate_e2e(model, hw, MappingKind::Halo2, &sc);
            let cent = simulate_e2e(model, hw, MappingKind::Cent, &sc);
            let att = simulate_e2e(model, hw, MappingKind::AttAcc1, &sc);
            e2e_vs_att.push(att.e2e_latency() / halo.e2e_latency());
            e2e_vs_cent.push(cent.e2e_latency() / halo.e2e_latency());
            pre_vs_cent.push(cent.ttft() / halo.ttft());
            dec_vs_att.push(att.tpot() / halo.tpot());
            e_vs_att.push(att.e2e_energy() / halo.e2e_energy());
            e_vs_cent.push(cent.e2e_energy() / halo.e2e_energy());
            h2_slow.push(halo2.e2e_latency() / halo.e2e_latency());
        }
    }
    t.row(vec![
        "E2E speedup vs AttAcc1".into(),
        "18x".into(),
        format!("{:.2}x", geomean(&e2e_vs_att)),
    ]);
    t.row(vec![
        "E2E speedup vs CENT".into(),
        "2.4x".into(),
        format!("{:.2}x", geomean(&e2e_vs_cent)),
    ]);
    t.row(vec![
        "Prefill speedup vs CENT".into(),
        "6.54x".into(),
        format!("{:.2}x", geomean(&pre_vs_cent)),
    ]);
    t.row(vec![
        "Decode speedup vs AttAcc1".into(),
        "34x".into(),
        format!("{:.2}x", geomean(&dec_vs_att)),
    ]);
    t.row(vec![
        "Energy vs AttAcc1".into(),
        "2x".into(),
        format!("{:.2}x", geomean(&e_vs_att)),
    ]);
    t.row(vec![
        "Energy vs CENT".into(),
        "1.8x".into(),
        format!("{:.2}x", geomean(&e_vs_cent)),
    ]);
    t.row(vec![
        "HALO2 slowdown vs HALO1".into(),
        "1.1x".into(),
        format!("{:.2}x", geomean(&h2_slow)),
    ]);

    // Fig.10 geomean
    let mut cim1_vs_sa = Vec::new();
    let mut cim2_vs_sa = Vec::new();
    for (l_in, l_out) in context_grid() {
        let sc = Scenario { l_in, l_out, batch: 1 };
        let sa = simulate_e2e(&m, hw, MappingKind::HaloSa, &sc).e2e_latency();
        cim1_vs_sa.push(sa / simulate_e2e(&m, hw, MappingKind::Halo1, &sc).e2e_latency());
        cim2_vs_sa.push(sa / simulate_e2e(&m, hw, MappingKind::Halo2, &sc).e2e_latency());
    }
    t.row(vec![
        "HALO-CiM1 speedup vs HALO-SA".into(),
        "1.3x".into(),
        format!("{:.2}x", geomean(&cim1_vs_sa)),
    ]);
    t.row(vec![
        "HALO-CiM2 speedup vs HALO-SA".into(),
        "1.2x".into(),
        format!("{:.2}x", geomean(&cim2_vs_sa)),
    ]);
    t
}

/// Generate every figure table.
pub fn all_figures(hw: &HwConfig) -> Vec<Table> {
    vec![
        fig1_roofline(hw),
        fig4_breakdown(hw),
        fig56_cid_vs_cim(hw),
        fig78_e2e(hw, false),
        fig78_e2e(hw, true),
        fig9_batch_sweep(hw),
        fig10_cim_vs_sa(hw),
        headline_summary(hw),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    #[test]
    fn fig1_has_all_series() {
        let t = fig1_roofline(&hw());
        assert!(t.rows.iter().any(|r| r[0] == "prefill"));
        assert!(t.rows.iter().any(|r| r[0] == "decode" && r[1] == "16"));
        assert!(t.rows.len() > 15);
    }

    #[test]
    fn fig56_ratios_consistent() {
        let t = fig56_cid_vs_cim(&hw());
        let cid = t.col_f64("ttft_cid_s");
        let cim = t.col_f64("ttft_cim_s");
        assert!(cid.iter().zip(&cim).all(|(a, b)| a > b), "CiM wins prefill everywhere");
        let tc = t.col_f64("tpot_cid_s");
        let tm = t.col_f64("tpot_cim_s");
        assert!(tc.iter().zip(&tm).all(|(a, b)| a < b), "CiD wins decode everywhere");
    }

    #[test]
    fn fig7_normalization_bounded() {
        let t = fig78_e2e(&hw(), false);
        let norm = t.col_f64("normalized");
        assert!(norm.iter().all(|v| *v > 0.0 && *v <= 1.0 + 1e-9));
        // 2 models x 15 grid points x 5 mappings
        assert_eq!(t.rows.len(), 2 * 15 * 5);
        // every config has exactly one mapping at 1.0 (the slowest)
        let ones = norm.iter().filter(|v| (**v - 1.0).abs() < 1e-9).count();
        assert_eq!(ones, 2 * 15);
    }

    #[test]
    fn fig9_has_expected_batches() {
        let t = fig9_batch_sweep(&hw());
        assert_eq!(t.rows.len(), 7 * 5);
    }

    #[test]
    fn headline_table_covers_all_claims() {
        let t = headline_summary(&hw());
        assert_eq!(t.rows.len(), 13);
        // every 'ours' cell parses as a positive ratio
        for r in &t.rows {
            let v: f64 = r[2].trim_end_matches('x').parse().unwrap();
            assert!(v > 0.0, "{r:?}");
        }
    }

    #[test]
    fn csv_and_markdown_render() {
        let t = fig9_batch_sweep(&hw());
        let csv = t.to_csv();
        assert!(csv.lines().count() == t.rows.len() + 1);
        let md = t.to_markdown();
        assert!(md.contains("| batch |") || md.contains("| batch|") || md.contains("batch"));
    }
}

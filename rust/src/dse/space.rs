//! The searchable configuration space: axes, candidates, and presets.
//!
//! A [`SearchSpace`] is a cross product of small per-axis value lists
//! covering every plane the simulator exposes: router policy and fleet
//! composition (cluster), device count and pool split, scheduler knobs
//! (chunk size, admission, KV budget), hardware knobs (CiM tile mesh,
//! interposer bandwidth — the CiM *wordline* knob rides on the mapping
//! choice, HALO1 vs HALO2, because the engine set pins wordlines per
//! Table II), a per-package TDP cap (0 = uncapped) that engages the
//! power plane's thermal throttle, and per-phase DVFS operating points
//! (prefill/decode ladder indices) so energy-per-token/EDP searches can
//! trade frequency against TTFT SLOs. A point in the space is an
//! [`Index`] (one position per axis); [`SearchSpace::decode`] turns it
//! into a concrete [`Candidate`] that knows how to build its own
//! [`HwConfig`] and fleet.

use crate::cluster::{Fleet, FleetBuilder, Interconnect, Policy, Router, SchedConfig};
use crate::config::{HwConfig, PowerConfig};
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::power::{DvfsConfig, ThermalConfig};
use crate::sim::device::AdmissionPolicy;
use crate::util::Rng;

/// Number of axes in the space (fixed; see [`SearchSpace`] fields).
pub const AXES: usize = 11;

/// One point of the space: a per-axis position vector.
pub type Index = [usize; AXES];

/// How a *unified* fleet's devices are mapped. Disaggregated topologies
/// ignore this — their pools are Fully-CiM / Fully-CiD by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Composition {
    /// Every device runs the same mapping.
    Uniform(MappingKind),
    /// Alternate HALO1 / HALO2 devices (latency/accuracy tiering).
    MixedHalo,
    /// Alternate HALO1 / HALO-SA devices (analog + digital fallback).
    MixedHaloSa,
}

impl Composition {
    pub fn name(&self) -> String {
        match self {
            Composition::Uniform(m) => m.name().to_string(),
            Composition::MixedHalo => "H1+H2".to_string(),
            Composition::MixedHaloSa => "H1+SA".to_string(),
        }
    }

    /// Per-device mappings for a unified fleet of `devices`.
    pub fn mappings(&self, devices: usize) -> Vec<MappingKind> {
        (0..devices)
            .map(|i| match self {
                Composition::Uniform(m) => *m,
                Composition::MixedHalo => {
                    if i % 2 == 0 {
                        MappingKind::Halo1
                    } else {
                        MappingKind::Halo2
                    }
                }
                Composition::MixedHaloSa => {
                    if i % 2 == 0 {
                        MappingKind::Halo1
                    } else {
                        MappingKind::HaloSa
                    }
                }
            })
            .collect()
    }
}

/// A fully resolved configuration point.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub policy: Policy,
    pub composition: Composition,
    pub devices: usize,
    /// Prefill chunk size in tokens (0 = serialized monolithic prefill).
    pub chunk: usize,
    pub admission: AdmissionPolicy,
    /// Per-device resident-KV budget in GB (0 = unlimited).
    pub kv_cap_gb: f64,
    /// Prefill-pool fraction (disaggregated topologies only).
    pub prefill_frac: f64,
    /// CiM tile-mesh width multiplier (1 = Table I's 4x4 mesh).
    pub tile_scale: usize,
    /// Interposer / global-buffer bandwidth multiplier.
    pub interposer_scale: f64,
    /// Per-package TDP cap in W (0 = uncapped, no thermal throttle).
    pub tdp_w: f64,
    /// Per-phase DVFS ladder indices `(prefill, decode)` into
    /// [`PowerConfig::dvfs_points`] ((0, 0) = nominal).
    pub dvfs: (usize, usize),
}

impl Candidate {
    /// Structurally impossible combinations (skipped without evaluation).
    pub fn valid(&self) -> bool {
        !(self.policy.is_disaggregated() && self.devices < 2)
    }

    /// The candidate's hardware point, derived from `base`.
    pub fn hw(&self, base: &HwConfig) -> HwConfig {
        let mut hw = base.clone();
        let mesh = (hw.cim.tile_mesh.0 * self.tile_scale, hw.cim.tile_mesh.1);
        hw.cim = hw.cim.with_tile_mesh(mesh);
        hw.interposer = hw.interposer.clone().scaled(self.interposer_scale);
        // the global buffer is sized to the link (Table I ties them)
        hw.cim.gb_bw *= self.interposer_scale;
        hw
    }

    /// The candidate's per-device scheduler.
    pub fn sched(&self) -> SchedConfig {
        SchedConfig {
            chunk: (self.chunk > 0).then_some(self.chunk),
            admission: self.admission,
            kv_capacity: (self.kv_cap_gb > 0.0).then_some((self.kv_cap_gb * 1e9) as u64),
        }
    }

    /// The candidate's thermal configuration, if a TDP cap is set.
    pub fn thermal(&self) -> Option<ThermalConfig> {
        (self.tdp_w > 0.0).then(|| ThermalConfig::paper(self.tdp_w))
    }

    /// Build the (fleet, router) pair this candidate describes. Power
    /// tracking is always attached (so every evaluation carries energy
    /// metrics); the thermal throttle engages only under a TDP cap, so
    /// uncapped candidates keep bit-identical latency results.
    pub fn build_fleet(
        &self,
        llm: &LlmConfig,
        hw: &HwConfig,
        slots: usize,
        link: Interconnect,
    ) -> (Fleet, Box<dyn Router>) {
        let builder = FleetBuilder::new(llm, hw)
            .slots(slots)
            .interconnect(link)
            .sched(self.sched())
            .power(self.thermal())
            .dvfs(DvfsConfig::with_indices(&hw.power, self.dvfs.0, self.dvfs.1));
        let fleet = if self.policy.is_disaggregated() {
            builder.devices(self.devices).disaggregated(self.prefill_frac).build()
        } else {
            builder.heterogeneous(&self.composition.mappings(self.devices)).build()
        };
        (fleet, self.policy.router())
    }

    /// Compact one-line description for tables and logs.
    pub fn label(&self) -> String {
        let fleet = if self.policy.is_disaggregated() {
            format!("cim->cid x{} pf={:.2}", self.devices, self.prefill_frac)
        } else {
            format!("{} x{}", self.composition.name(), self.devices)
        };
        let kv = if self.kv_cap_gb > 0.0 {
            format!("{:.0}GB", self.kv_cap_gb)
        } else {
            "inf".to_string()
        };
        let tdp = if self.tdp_w > 0.0 {
            format!("{:.0}W", self.tdp_w)
        } else {
            "inf".to_string()
        };
        // names come from the paper ladder (a Candidate is hw-agnostic);
        // indices beyond a custom ladder's names print as `pN`
        let ladder = PowerConfig::paper().dvfs_points;
        let point = |i: usize| {
            ladder.get(i).map(|p| p.name.to_string()).unwrap_or_else(|| format!("p{i}"))
        };
        let dvfs = if self.dvfs.0 == self.dvfs.1 {
            point(self.dvfs.0)
        } else {
            format!("{}/{}", point(self.dvfs.0), point(self.dvfs.1))
        };
        format!(
            "{} {} chunk={} {} kv={} tiles=x{} bw=x{:.2} tdp={} dvfs={}",
            self.policy.name(),
            fleet,
            self.chunk,
            self.admission.name(),
            kv,
            self.tile_scale,
            self.interposer_scale,
            tdp,
            dvfs
        )
    }
}

/// The cross product of per-axis value lists. Build with the `with_*`
/// methods from a preset or from [`SearchSpace::paper_point`].
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub policies: Vec<Policy>,
    pub compositions: Vec<Composition>,
    pub devices: Vec<usize>,
    pub chunks: Vec<usize>,
    pub admissions: Vec<AdmissionPolicy>,
    pub kv_caps_gb: Vec<f64>,
    pub prefill_fracs: Vec<f64>,
    pub tile_scales: Vec<usize>,
    pub interposer_scales: Vec<f64>,
    /// Per-package TDP caps in W (0 = uncapped).
    pub tdp_caps_w: Vec<f64>,
    /// Per-phase DVFS points as `(prefill, decode)` ladder indices into
    /// [`PowerConfig::dvfs_points`] ((0, 0) = nominal).
    pub dvfs: Vec<(usize, usize)>,
}

impl SearchSpace {
    /// The single-point space at the paper's configuration: one HALO1
    /// device fleet of 4 behind least-loaded routing, default scheduler.
    pub fn paper_point() -> Self {
        SearchSpace {
            policies: vec![Policy::LeastLoaded],
            compositions: vec![Composition::Uniform(MappingKind::Halo1)],
            devices: vec![4],
            chunks: vec![0],
            admissions: vec![AdmissionPolicy::Fifo],
            kv_caps_gb: vec![0.0],
            prefill_fracs: vec![0.5],
            tile_scales: vec![1],
            interposer_scales: vec![1.0],
            tdp_caps_w: vec![0.0],
            dvfs: vec![(0, 0)],
        }
    }

    pub fn with_policies(mut self, v: Vec<Policy>) -> Self {
        assert!(!v.is_empty());
        self.policies = v;
        self
    }

    pub fn with_compositions(mut self, v: Vec<Composition>) -> Self {
        assert!(!v.is_empty());
        self.compositions = v;
        self
    }

    pub fn with_devices(mut self, v: Vec<usize>) -> Self {
        assert!(!v.is_empty() && v.iter().all(|&d| d > 0));
        self.devices = v;
        self
    }

    pub fn with_chunks(mut self, v: Vec<usize>) -> Self {
        assert!(!v.is_empty());
        self.chunks = v;
        self
    }

    pub fn with_admissions(mut self, v: Vec<AdmissionPolicy>) -> Self {
        assert!(!v.is_empty());
        self.admissions = v;
        self
    }

    pub fn with_kv_caps_gb(mut self, v: Vec<f64>) -> Self {
        assert!(!v.is_empty() && v.iter().all(|&g| g >= 0.0));
        self.kv_caps_gb = v;
        self
    }

    pub fn with_prefill_fracs(mut self, v: Vec<f64>) -> Self {
        assert!(!v.is_empty() && v.iter().all(|&f| f > 0.0 && f < 1.0));
        self.prefill_fracs = v;
        self
    }

    pub fn with_tile_scales(mut self, v: Vec<usize>) -> Self {
        assert!(!v.is_empty() && v.iter().all(|&s| s > 0));
        self.tile_scales = v;
        self
    }

    pub fn with_interposer_scales(mut self, v: Vec<f64>) -> Self {
        assert!(!v.is_empty() && v.iter().all(|&s| s > 0.0));
        self.interposer_scales = v;
        self
    }

    pub fn with_tdp_caps_w(mut self, v: Vec<f64>) -> Self {
        assert!(!v.is_empty() && v.iter().all(|&w| w >= 0.0));
        self.tdp_caps_w = v;
        self
    }

    pub fn with_dvfs(mut self, v: Vec<(usize, usize)>) -> Self {
        assert!(!v.is_empty());
        self.dvfs = v;
        self
    }

    /// Per-axis cardinalities, in [`Index`] order.
    pub fn dims(&self) -> Index {
        [
            self.policies.len(),
            self.compositions.len(),
            self.devices.len(),
            self.chunks.len(),
            self.admissions.len(),
            self.kv_caps_gb.len(),
            self.prefill_fracs.len(),
            self.tile_scales.len(),
            self.interposer_scales.len(),
            self.tdp_caps_w.len(),
            self.dvfs.len(),
        ]
    }

    /// Total number of points (valid or not).
    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The all-zeros index (every axis at its first value).
    pub fn first_index(&self) -> Index {
        [0; AXES]
    }

    /// Mixed-radix decode of a flat enumeration position.
    pub fn flat(&self, mut i: usize) -> Index {
        let dims = self.dims();
        let mut idx = [0usize; AXES];
        for axis in (0..AXES).rev() {
            idx[axis] = i % dims[axis];
            i /= dims[axis];
        }
        idx
    }

    /// Uniformly random point (for random search and climb restarts).
    pub fn sample(&self, rng: &mut Rng) -> Index {
        let dims = self.dims();
        let mut idx = [0usize; AXES];
        for axis in 0..AXES {
            idx[axis] = rng.below(dims[axis] as u64) as usize;
        }
        idx
    }

    /// Canonical form of an index: axes the point's topology ignores are
    /// pinned to 0 so physically identical configurations share one memo
    /// entry (and one frontier row). Disaggregated topologies ignore the
    /// composition axis (their pools are Fully-CiM/Fully-CiD by
    /// construction); unified topologies ignore the prefill-fraction
    /// axis (there are no split pools).
    pub fn canonical(&self, idx: &Index) -> Index {
        let mut out = *idx;
        if self.policies[out[0]].is_disaggregated() {
            out[1] = 0; // composition
        } else {
            out[6] = 0; // prefill_frac
        }
        out
    }

    /// Neighbor of `idx` one step along `axis` (`dir` = -1 or +1), or
    /// `None` at the axis boundary.
    pub fn step(&self, idx: &Index, axis: usize, dir: i64) -> Option<Index> {
        let dims = self.dims();
        let cur = idx[axis] as i64 + dir;
        if cur < 0 || cur >= dims[axis] as i64 {
            return None;
        }
        let mut out = *idx;
        out[axis] = cur as usize;
        Some(out)
    }

    /// Resolve an index to its concrete candidate.
    pub fn decode(&self, idx: &Index) -> Candidate {
        Candidate {
            policy: self.policies[idx[0]],
            composition: self.compositions[idx[1]],
            devices: self.devices[idx[2]],
            chunk: self.chunks[idx[3]],
            admission: self.admissions[idx[4]],
            kv_cap_gb: self.kv_caps_gb[idx[5]],
            prefill_frac: self.prefill_fracs[idx[6]],
            tile_scale: self.tile_scales[idx[7]],
            interposer_scale: self.interposer_scales[idx[8]],
            tdp_w: self.tdp_caps_w[idx[9]],
            dvfs: self.dvfs[idx[10]],
        }
    }

    // ------------------------------------------------------------ presets

    /// Tiny grid for CI smoke runs: unified vs KV-aware disaggregated,
    /// serialized vs chunked prefill, capped vs uncapped KV (8 points).
    pub fn smoke() -> Self {
        Self::paper_point()
            .with_policies(vec![Policy::LeastLoaded, Policy::KvAware])
            .with_devices(vec![2])
            .with_chunks(vec![0, 512])
            .with_kv_caps_gb(vec![0.0, 8.0])
    }

    /// Scheduler-knob space on one device: chunk sweep x admission
    /// policies (the chunk-size auto-tuning space of the ROADMAP).
    pub fn sched() -> Self {
        Self::paper_point()
            .with_devices(vec![1])
            .with_chunks(vec![0, 256, 512, 1024, 2048])
            .with_admissions(AdmissionPolicy::all().to_vec())
    }

    /// Fleet-level space: routing policy x fleet size x chunking x KV
    /// budget (36 points; the pool-sizing/routing tradeoff).
    pub fn fleet() -> Self {
        Self::paper_point()
            .with_policies(vec![
                Policy::LeastLoaded,
                Policy::PhaseDisaggregated,
                Policy::KvAware,
            ])
            .with_devices(vec![2, 4, 8])
            .with_chunks(vec![0, 512])
            .with_kv_caps_gb(vec![0.0, 8.0])
    }

    /// Hardware space: mapping composition x CiM tile mesh x interposer
    /// bandwidth on small unified fleets. Fleets of at least 2 keep the
    /// mixed compositions distinct from their uniform degenerations.
    pub fn hardware() -> Self {
        Self::paper_point()
            .with_devices(vec![2, 4])
            .with_compositions(vec![
                Composition::Uniform(MappingKind::Halo1),
                Composition::Uniform(MappingKind::Halo2),
                Composition::MixedHalo,
                Composition::MixedHaloSa,
            ])
            .with_tile_scales(vec![1, 2])
            .with_interposer_scales(vec![0.5, 1.0, 2.0])
    }

    /// The §V-B extremes as a degenerate 3-point search: Fully-CiD vs
    /// Fully-CiM vs phase-aware HALO1 on a single device.
    pub fn mapping_extremes() -> Self {
        Self::paper_point().with_devices(vec![1]).with_compositions(vec![
            Composition::Uniform(MappingKind::FullCid),
            Composition::Uniform(MappingKind::FullCim),
            Composition::Uniform(MappingKind::Halo1),
        ])
    }

    /// Energy/TDP/DVFS space: the architectural extremes and phase-aware
    /// points under tightening package power caps and down the DVFS
    /// ladder (uniform points plus a decode-only eco split) on small
    /// unified fleets — the `energy-per-token` / `edp` search territory.
    pub fn power() -> Self {
        Self::paper_point()
            .with_devices(vec![1, 2])
            .with_compositions(vec![
                Composition::Uniform(MappingKind::FullCid),
                Composition::Uniform(MappingKind::FullCim),
                Composition::Uniform(MappingKind::Halo1),
                Composition::Uniform(MappingKind::Halo2),
            ])
            .with_tdp_caps_w(vec![0.0, 120.0, 60.0])
            .with_dvfs(vec![(0, 0), (1, 1), (0, 2), (2, 2)])
    }

    /// Everything at once (~20k points) — random/hill-climb territory.
    pub fn full() -> Self {
        let comps: Vec<Composition> = MappingKind::dse_unified()
            .iter()
            .map(|&m| Composition::Uniform(m))
            .chain([Composition::MixedHalo, Composition::MixedHaloSa])
            .collect();
        Self::paper_point()
            .with_policies(Policy::all().to_vec())
            .with_compositions(comps)
            .with_devices(vec![1, 2, 4, 8])
            .with_chunks(vec![0, 512, 2048])
            .with_admissions(AdmissionPolicy::all().to_vec())
            .with_kv_caps_gb(vec![0.0, 8.0])
            .with_prefill_fracs(vec![0.25, 0.5])
            .with_tile_scales(vec![1, 2])
            .with_interposer_scales(vec![0.5, 1.0, 2.0])
            .with_tdp_caps_w(vec![0.0, 120.0])
            .with_dvfs(vec![(0, 0), (2, 2)])
    }

    pub fn preset(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "smoke" => Some(Self::smoke()),
            "sched" | "scheduler" => Some(Self::sched()),
            "fleet" | "cluster" => Some(Self::fleet()),
            "hw" | "hardware" => Some(Self::hardware()),
            "mapping" | "extremes" | "vb" => Some(Self::mapping_extremes()),
            "power" | "energy" | "tdp" => Some(Self::power()),
            "full" | "all" => Some(Self::full()),
            _ => None,
        }
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["smoke", "sched", "fleet", "hw", "mapping", "power", "full"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_decode_roundtrips_the_grid() {
        let s = SearchSpace::fleet();
        assert_eq!(s.len(), 3 * 3 * 2 * 2);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..s.len() {
            let idx = s.flat(i);
            let dims = s.dims();
            assert!(idx.iter().zip(dims.iter()).all(|(&x, &d)| x < d));
            seen.insert(idx);
        }
        assert_eq!(seen.len(), s.len(), "flat enumeration covers every point once");
    }

    #[test]
    fn validity_rejects_single_device_disaggregation() {
        let s = SearchSpace::paper_point()
            .with_policies(vec![Policy::KvAware])
            .with_devices(vec![1]);
        assert!(!s.decode(&s.first_index()).valid());
        let ok = SearchSpace::paper_point();
        assert!(ok.decode(&ok.first_index()).valid());
    }

    #[test]
    fn candidate_hw_applies_knobs() {
        let mut s = SearchSpace::paper_point()
            .with_tile_scales(vec![2])
            .with_interposer_scales(vec![4.0]);
        s.chunks = vec![768];
        s.kv_caps_gb = vec![2.0];
        let c = s.decode(&s.first_index());
        let base = HwConfig::paper();
        let hw = c.hw(&base);
        assert_eq!(hw.cim.tile_mesh, (8, 4));
        assert_eq!(hw.interposer.bw, 4.0 * base.interposer.bw);
        assert_eq!(hw.cim.gb_bw, 4.0 * base.cim.gb_bw);
        let sched = c.sched();
        assert_eq!(sched.chunk, Some(768));
        assert_eq!(sched.kv_capacity, Some(2_000_000_000));
    }

    #[test]
    fn compositions_tile_the_fleet() {
        let mix = Composition::MixedHalo.mappings(5);
        assert_eq!(
            mix,
            vec![
                MappingKind::Halo1,
                MappingKind::Halo2,
                MappingKind::Halo1,
                MappingKind::Halo2,
                MappingKind::Halo1
            ]
        );
        assert!(Composition::Uniform(MappingKind::FullCim)
            .mappings(3)
            .iter()
            .all(|&m| m == MappingKind::FullCim));
    }

    #[test]
    fn canonical_pins_ignored_axes() {
        let s = SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded, Policy::KvAware])
            .with_devices(vec![2])
            .with_compositions(vec![
                Composition::Uniform(MappingKind::Halo1),
                Composition::Uniform(MappingKind::Halo2),
            ])
            .with_prefill_fracs(vec![0.25, 0.5]);
        // unified (policy 0): prefill_frac is pinned, composition kept
        let mut unified = s.first_index();
        unified[1] = 1;
        unified[6] = 1;
        let c = s.canonical(&unified);
        assert_eq!(c[6], 0, "unified ignores prefill_frac");
        assert_eq!(c[1], 1, "unified keeps composition");
        // disaggregated (policy 1): composition pinned, prefill_frac kept
        let mut disagg = unified;
        disagg[0] = 1;
        let c = s.canonical(&disagg);
        assert_eq!(c[1], 0, "disaggregated ignores composition");
        assert_eq!(c[6], 1, "disaggregated keeps prefill_frac");
    }

    #[test]
    fn step_respects_bounds() {
        let s = SearchSpace::sched();
        let first = s.first_index();
        assert!(s.step(&first, 3, -1).is_none());
        let up = s.step(&first, 3, 1).unwrap();
        assert_eq!(up[3], 1);
        let dims = s.dims();
        let mut last = first;
        last[3] = dims[3] - 1;
        assert!(s.step(&last, 3, 1).is_none());
    }

    #[test]
    fn presets_resolve_and_are_nonempty() {
        for name in SearchSpace::preset_names() {
            let s = SearchSpace::preset(name).unwrap();
            assert!(!s.is_empty(), "{name}");
            // every preset contains at least one valid candidate
            assert!((0..s.len()).any(|i| s.decode(&s.flat(i)).valid()), "{name}");
        }
        assert!(SearchSpace::preset("galaxy").is_none());
    }

    #[test]
    fn tdp_axis_decodes_into_a_thermal_config() {
        let s = SearchSpace::paper_point().with_tdp_caps_w(vec![0.0, 90.0]);
        let uncapped = s.decode(&s.first_index());
        assert_eq!(uncapped.tdp_w, 0.0);
        assert!(uncapped.thermal().is_none());
        let mut idx = s.first_index();
        idx[9] = 1;
        let capped = s.decode(&idx);
        assert_eq!(capped.tdp_w, 90.0);
        let th = capped.thermal().expect("capped candidate carries a thermal config");
        assert_eq!(th.tdp_w, 90.0);
        assert!(capped.label().contains("tdp=90W"), "{}", capped.label());
        assert!(uncapped.label().contains("tdp=inf"), "{}", uncapped.label());
        // the power preset spans mappings x caps
        let p = SearchSpace::power();
        assert!(p.len() >= 12);
        assert_eq!(SearchSpace::preset("power").unwrap().len(), p.len());
    }

    #[test]
    fn dvfs_axis_decodes_and_spans_the_ladder_in_the_power_preset() {
        let s = SearchSpace::paper_point().with_dvfs(vec![(0, 0), (0, 2), (2, 2)]);
        assert_eq!(s.len(), 3);
        let mut idx = s.first_index();
        assert_eq!(s.decode(&idx).dvfs, (0, 0));
        idx[10] = 1;
        let split = s.decode(&idx);
        assert_eq!(split.dvfs, (0, 2));
        assert!(split.label().contains("dvfs=nominal/eco"), "{}", split.label());
        idx[10] = 2;
        assert!(s.decode(&idx).label().contains("dvfs=eco"));
        // acceptance: the power preset searches at least 3 DVFS points
        let p = SearchSpace::power();
        assert!(p.dvfs.len() >= 3, "power preset must span the DVFS ladder");
        let distinct: std::collections::BTreeSet<(usize, usize)> =
            p.dvfs.iter().copied().collect();
        assert!(distinct.len() >= 3);
        assert!(p.dvfs.contains(&(0, 0)), "nominal must stay searchable");
    }

    #[test]
    fn labels_identify_the_knobs() {
        let s = SearchSpace::smoke();
        let labels: std::collections::BTreeSet<String> =
            (0..s.len()).map(|i| s.decode(&s.flat(i)).label()).collect();
        assert_eq!(labels.len(), s.len(), "labels are unique per candidate");
        assert!(labels.iter().any(|l| l.contains("chunk=512")));
        assert!(labels.iter().any(|l| l.contains("kvaware")));
    }
}

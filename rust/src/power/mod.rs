//! Power plane: per-event energy accounting, TDP/thermal feedback,
//! per-phase DVFS, and windowed power traces for the event-driven
//! simulator.
//!
//! The joint latency/energy curves themselves live in
//! [`sim::cost`](crate::sim::cost) — one memoized `simulate_graph` walk
//! per distinct point feeds both the device clock and the energy meter,
//! so the planes agree by construction and power tracking adds no walks.
//! What stays here is everything a graph walk cannot see:
//!
//! * [`model`] — [`EnergyModel`], the thin energy view over the joint
//!   oracle plus the static floor (HBM refresh + leakage) integrated
//!   over wall-clock time;
//! * [`thermal`] — a per-package RC thermal model with a TDP cap whose
//!   throttle *feeds back into service time*, and a 2.5D coupling term
//!   that pushes CiM-die heat into the HBM stacks, doubling refresh
//!   power in the JEDEC hot band;
//! * [`dvfs`] — the voltage-frequency operating-point ladder
//!   ([`crate::config::PowerConfig::dvfs_points`]), selectable per phase
//!   (prefill vs decode) as a static knob (`halo cluster --dvfs`), or
//!   driven by the thermal model as a *stepped governor* that replaces
//!   the scalar throttle factor under a TDP cap;
//! * [`trace`] — windowed average/peak power timelines from the
//!   per-event logs.
//!
//! A [`DevicePower`] instance attaches to one `sim::device::Device`
//! (`Device::enable_power`) and meters every busy event; with tracking
//! disabled, or tracking on without a TDP cap at nominal DVFS, the
//! device's latency math is bit-identical to the untracked device
//! (pinned by `tests/power_plane.rs`). The cluster plane aggregates
//! per-device energy into fleet stats, and the `dse` plane scores
//! `energy-per-token` / `edp` / `peak-power` objectives over TDP and
//! DVFS axes. Surfaces: `halo power`, `halo cluster --power/--tdp/--dvfs`,
//! `halo report --fig power`.

pub mod dvfs;
pub mod model;
pub mod thermal;
pub mod trace;

pub use crate::config::DvfsPoint;
pub use dvfs::DvfsConfig;
pub use model::{EnergyBreakdown, EnergyModel};
pub use thermal::{ThermalConfig, ThermalModel};
pub use trace::{power_trace, PowerEvent, PowerTrace};

use crate::config::HwConfig;
use crate::model::Phase;
use crate::sim::cost::PhaseCost;

/// Per-device power state: the static floor, optional thermal/TDP state,
/// the accumulated energy breakdown, the per-event log, and the DVFS
/// governor position. Dynamic energy arrives with each event as the
/// energy half of the same [`PhaseCost`] that advances the device clock.
pub struct DevicePower {
    /// Static floor at normal / hot-refresh DRAM temperature, W.
    static_cold_w: f64,
    static_hot_w: f64,
    pub thermal: Option<ThermalModel>,
    /// Accumulated energy of every busy event (dynamic + busy-time
    /// static). Idle-time static is added at collection, where the
    /// observer knows the replay makespan.
    pub energy: EnergyBreakdown,
    /// Busy-event log for windowed power traces.
    pub events: Vec<PowerEvent>,
    /// Highest mean event power seen, W.
    pub peak_w: f64,
    /// Extra service time added by thermal throttling (scalar or
    /// governor) beyond the configured DVFS point, s.
    pub throttled_s: f64,
    /// Current rung of the stepped DVFS governor (0 unless governing).
    gov_idx: usize,
    /// Deepest governor rung engaged during the replay.
    pub max_gov_idx: usize,
}

impl DevicePower {
    pub fn new(hw: &HwConfig, thermal: Option<ThermalModel>) -> Self {
        DevicePower {
            static_cold_w: hw.power.static_w(hw.hbm.stacks, false),
            static_hot_w: hw.power.static_w(hw.hbm.stacks, true),
            thermal,
            energy: EnergyBreakdown::default(),
            events: Vec::new(),
            peak_w: 0.0,
            throttled_s: 0.0,
            gov_idx: 0,
            max_gov_idx: 0,
        }
    }

    /// Current rung of the stepped DVFS governor (0 unless governing) —
    /// read by the observability plane to annotate throttle events.
    pub fn governor_rung(&self) -> usize {
        self.gov_idx
    }

    /// Background power floor, W (`hot_refresh` doubles the DRAM refresh
    /// share — the 2.5D coupling penalty when the stacks run hot).
    pub fn static_power(&self, hot_refresh: bool) -> f64 {
        if hot_refresh {
            self.static_hot_w
        } else {
            self.static_cold_w
        }
    }

    /// Account one busy event of `phase` starting at `start` whose
    /// *nominal* joint cost is `nominal`. Applies the phase's static
    /// DVFS point (latency times `1/f`, dynamic energy times `V^2`),
    /// then the thermal response — the scalar throttle, or one step of
    /// the DVFS governor when armed (with the scalar throttle as a
    /// backstop once the ladder is exhausted) — charges busy-time static
    /// power
    /// (doubled refresh when the HBM stacks are hot), heats the package,
    /// and returns the actual duration the device clock must advance by.
    /// Without a thermal model the configured-point duration is returned
    /// untouched (at nominal DVFS: bit-identical to the raw latency).
    pub fn busy_event(
        &mut self,
        start: f64,
        nominal: PhaseCost,
        dvfs: &DvfsConfig,
        phase: Phase,
    ) -> f64 {
        let idle_w = self.static_power(false);
        let cfg_idx = dvfs.index(phase);
        let cfg_dt = nominal.latency * dvfs.ladder()[cfg_idx].time_scale();
        let (eff_idx, dt, hot) = match &mut self.thermal {
            None => (cfg_idx, cfg_dt, false),
            Some(th) => {
                th.advance_idle(start, idle_w);
                if dvfs.governor {
                    self.gov_idx = dvfs.step_governor(self.gov_idx, th);
                    let eff = dvfs.effective_index(phase, self.gov_idx);
                    let mut gdt = nominal.latency * dvfs.ladder()[eff].time_scale();
                    // ladder exhausted but the junction still over the
                    // ceiling: the scalar throttle takes over as a
                    // backstop (factor is 1.0 at or below the ceiling),
                    // so arbitrarily tight caps still converge onto TDP
                    if eff + 1 == dvfs.ladder().len() {
                        gdt /= th.throttle_factor();
                    }
                    (eff, gdt, th.hbm_hot())
                } else {
                    (cfg_idx, cfg_dt / th.throttle_factor(), th.hbm_hot())
                }
            }
        };
        self.max_gov_idx = self.max_gov_idx.max(self.gov_idx);
        let mut e = nominal.energy.scaled_dynamic(dvfs.ladder()[eff_idx].energy_scale());
        e.e_static += self.static_power(hot) * dt;
        let total = e.total();
        let watts = total / dt.max(1e-30);
        if let Some(th) = &mut self.thermal {
            th.heat(dt, watts);
        }
        self.energy.add(&e);
        self.peak_w = self.peak_w.max(watts);
        self.throttled_s += dt - cfg_dt;
        self.events.push(PowerEvent { start, end: start + dt, joules: total });
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::mapping::MappingKind;
    use crate::model::LlmConfig;
    use crate::sim::cost::CostModel;

    fn meter(thermal: Option<ThermalConfig>) -> DevicePower {
        DevicePower::new(&HwConfig::paper(), thermal.map(ThermalModel::new))
    }

    fn oracle() -> CostModel {
        CostModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), MappingKind::Halo1)
    }

    #[test]
    fn untracked_thermal_keeps_duration_exact() {
        let mut pw = meter(None);
        let mut cm = oracle();
        let raw = 0.0123456789f64;
        let c = PhaseCost { latency: raw, energy: cm.prefill(256).energy };
        let dt = pw.busy_event(1.0, c, &DvfsConfig::default(), Phase::Prefill);
        assert_eq!(dt.to_bits(), raw.to_bits(), "no thermal model, no stretching");
        assert_eq!(pw.throttled_s, 0.0);
        assert_eq!(pw.events.len(), 1);
        // event energy = dynamic + static floor over the event
        let want = c.energy.dynamic() + pw.static_power(false) * raw;
        assert!((pw.events[0].joules - want).abs() < 1e-12 * want);
        assert!(pw.peak_w > 0.0);
    }

    #[test]
    fn hot_package_stretches_events_and_logs_throttle_time() {
        // pre-heat far above a tiny TDP ceiling, then run an event
        let mut pw = meter(Some(ThermalConfig::paper(20.0)));
        pw.thermal.as_mut().unwrap().heat(100.0, 200.0);
        let mut cm = oracle();
        let raw = 1e-3;
        let c = PhaseCost { latency: raw, energy: cm.decode_step(4, 1024).energy };
        let dt = pw.busy_event(100.0, c, &DvfsConfig::default(), Phase::Decode);
        assert!(dt > raw * 2.0, "expected a strong throttle, got {}x", dt / raw);
        assert!((pw.throttled_s - (dt - raw)).abs() < 1e-15);
        let ev = pw.events[0];
        // end - start loses a few ulps of `start`'s magnitude
        assert!((ev.duration() - dt).abs() < 1e-12);
    }

    #[test]
    fn static_dvfs_point_scales_time_and_dynamic_energy() {
        let hw = HwConfig::paper();
        let mut pw = meter(None);
        let mut cm = oracle();
        let c = cm.decode_step(2, 512);
        let eco = hw.power.dvfs_points.len() - 1;
        let dvfs = DvfsConfig::with_indices(&hw.power, eco, eco);
        let p = dvfs.point(Phase::Decode);
        let dt = pw.busy_event(0.0, c, &dvfs, Phase::Decode);
        assert!((dt - c.latency * p.time_scale()).abs() < 1e-15 * dt);
        // logged joules = V^2-scaled dynamic + static over the longer event
        let want = c.energy.dynamic() * p.energy_scale() + pw.static_power(false) * dt;
        assert!((pw.events[0].joules - want).abs() < 1e-12 * want);
        // a configured point books no throttling
        assert_eq!(pw.throttled_s, 0.0);
        // peak power strictly below the nominal event's power
        let nominal_w = (c.energy.dynamic() + pw.static_power(false) * c.latency) / c.latency;
        assert!(pw.peak_w < nominal_w);
    }

    #[test]
    fn governor_walks_the_ladder_under_heat_and_books_throttle_time() {
        let hw = HwConfig::paper();
        let mut pw = meter(Some(ThermalConfig::paper(30.0)));
        // pre-heat over the 30 W ceiling so the governor must step down
        pw.thermal.as_mut().unwrap().heat(100.0, 200.0);
        let mut cm = oracle();
        let c = cm.decode_step(4, 1024);
        let dvfs = DvfsConfig::governed(&hw.power);
        let d1 = pw.busy_event(100.0, c, &dvfs, Phase::Decode);
        assert!((d1 - c.latency * dvfs.ladder()[1].time_scale()).abs() < 1e-15 * d1);
        assert_eq!(pw.max_gov_idx, 1);
        // still hot (tiny events barely cool it): next event steps to the
        // ladder bottom, where the scalar backstop stretches it further
        // (the junction is still far over the 30 W ceiling)
        let d2 = pw.busy_event(100.0 + d1, c, &dvfs, Phase::Decode);
        assert!(d2 > c.latency * dvfs.ladder()[2].time_scale(), "backstop must engage");
        assert_eq!(pw.max_gov_idx, 2);
        assert!(d2 > d1);
        assert!(pw.throttled_s > 0.0);
        // governed events scale dynamic energy by the rung's V^2
        let e1 = pw.events[0].joules - pw.static_power(false) * d1;
        let want1 = c.energy.dynamic() * dvfs.ladder()[1].energy_scale();
        assert!((e1 - want1).abs() < 1e-9 * want1, "{e1} vs {want1}");
        let e2 = pw.events[1].joules - pw.static_power(false) * d2;
        let want2 = c.energy.dynamic() * dvfs.ladder()[2].energy_scale();
        assert!((e2 - want2).abs() < 1e-9 * want2, "{e2} vs {want2}");
    }

    #[test]
    fn accumulated_energy_matches_event_log() {
        let mut pw = meter(None);
        let mut cm = oracle();
        let mut t = 0.0;
        for l in [128usize, 256, 512] {
            let c = PhaseCost { latency: 0.01, energy: cm.prefill(l).energy };
            let dt = pw.busy_event(t, c, &DvfsConfig::default(), Phase::Prefill);
            t += dt;
        }
        let logged: f64 = pw.events.iter().map(|e| e.joules).sum();
        assert!((pw.energy.total() - logged).abs() < 1e-9 * logged);
    }
}

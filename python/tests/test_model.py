"""L2 model tests: structure, prefill/decode consistency, CiM-noise impact."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.TinyLlamaConfig(n_layers=2, max_seq=64)  # small: tests stay fast
CFG_F32 = M.reference_config(CFG)
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def toks(b, l):
    return jnp.asarray(RNG.integers(0, CFG.vocab, (b, l), dtype=np.int32))


# ------------------------------------------------------------------ shapes


def test_param_specs_order_and_count():
    specs = M.param_specs(CFG)
    names = [n for n, _ in specs]
    assert names[0] == "embed" and names[-1] == "w_lm" and names[-2] == "g_final"
    assert len(names) == 1 + 9 * CFG.n_layers + 2
    # per-layer block layout is stable (the Rust weights.bin contract)
    assert names[1:10] == [
        "l0.wq", "l0.wk", "l0.wv", "l0.wo",
        "l0.w_gate", "l0.w_up", "l0.w_down", "l0.g_attn", "l0.g_ffn",
    ]


def test_prefill_shapes(params):
    logits, kc, vc = M.prefill(params, toks(1, 8), CFG_F32)
    assert logits.shape == (1, 8, CFG.vocab)
    assert kc.shape == (CFG.n_layers, 1, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim)
    assert vc.shape == kc.shape
    # cache is zero beyond the prompt
    assert float(jnp.abs(kc[:, :, 8:]).max()) == 0.0


def test_decode_shapes(params):
    b = 3
    kc = jnp.zeros((CFG.n_layers, b, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    lg, kc2, vc2 = M.decode_step(
        params, toks(b, 1)[:, 0], jnp.zeros((b,), jnp.int32), kc, vc, CFG_F32
    )
    assert lg.shape == (b, CFG.vocab)
    assert kc2.shape == kc.shape


# ------------------------------------------------- prefill/decode agreement


def test_decode_matches_prefill_f32(params):
    """Token-by-token decode reproduces the prefill logits and KV cache."""
    t = toks(1, 12)
    lf, kf, vf = M.prefill(params, t, CFG_F32)
    kc = jnp.zeros_like(kf)
    vc = jnp.zeros_like(vf)
    logits = []
    for i in range(12):
        lg, kc, vc = M.decode_step(
            params, t[:, i], jnp.asarray([i], jnp.int32), kc, vc, CFG_F32
        )
        logits.append(lg)
    dec = jnp.stack(logits, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(lf), atol=2e-4)
    np.testing.assert_allclose(np.asarray(kc), np.asarray(kf), atol=2e-4)


def test_decode_slots_are_independent(params):
    """Batched decode == each slot decoded alone (continuous batching
    correctness; slots must not leak into each other)."""
    b = 3
    kc = jnp.asarray(RNG.normal(size=(CFG.n_layers, b, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim)).astype(np.float32))
    vc = jnp.asarray(RNG.normal(size=kc.shape).astype(np.float32))
    tok = toks(b, 1)[:, 0]
    pos = jnp.asarray([5, 9, 2], jnp.int32)
    lg, kc2, vc2 = M.decode_step(params, tok, pos, kc, vc, CFG_F32)
    for s in range(b):
        lg1, kc1, vc1 = M.decode_step(
            params, tok[s : s + 1], pos[s : s + 1],
            kc[:, s : s + 1], vc[:, s : s + 1], CFG_F32,
        )
        np.testing.assert_allclose(np.asarray(lg[s]), np.asarray(lg1[0]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(kc2[:, s]), np.asarray(kc1[:, 0]), atol=1e-5)


def test_decode_writes_kv_at_pos(params):
    b = 2
    kc = jnp.zeros((CFG.n_layers, b, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    pos = jnp.asarray([4, 7], jnp.int32)
    _, kc2, _ = M.decode_step(params, toks(b, 1)[:, 0], pos, kc, vc, CFG_F32)
    for s, p in enumerate([4, 7]):
        assert float(jnp.abs(kc2[:, s, p]).max()) > 0
        mask = jnp.ones(CFG.max_seq, bool).at[p].set(False)
        assert float(jnp.abs(kc2[:, s, mask]).max()) == 0.0


# ------------------------------------------------------------ numeric units


def test_rms_norm_unit_variance():
    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32)) * 13.0
    y = M.rms_norm(x, jnp.ones((64,)), 1e-6)
    np.testing.assert_allclose(np.asarray(jnp.mean(y * y, -1)), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_zero_pos_identity():
    cfg = CFG
    x = jnp.asarray(RNG.normal(size=(2, 5, cfg.n_heads, cfg.head_dim)).astype(np.float32))
    cos, sin = M.rope_angles(cfg, jnp.arange(5))
    y = M.apply_rope(x, cos[None, :, None, :], sin[None, :, None, :])
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    cos0, sin0 = M.rope_angles(cfg, jnp.zeros((1,), jnp.int32))
    y0 = M.apply_rope(x[:, :1], cos0[None, :, None, :], sin0[None, :, None, :])
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x[:, :1]), atol=1e-6)


def test_rope_relative_shift_property():
    """RoPE dot products depend only on relative position."""
    cfg = CFG
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, cfg.head_dim)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, cfg.head_dim)).astype(np.float32))

    def dot_at(pq, pk):
        cq, sq = M.rope_angles(cfg, jnp.asarray([pq]))
        ck, sk = M.rope_angles(cfg, jnp.asarray([pk]))
        qq = M.apply_rope(q, cq[None, :, None, :], sq[None, :, None, :])
        kk = M.apply_rope(k, ck[None, :, None, :], sk[None, :, None, :])
        return float(jnp.sum(qq * kk))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)


# ----------------------------------------------------------- CiM-noise path


def test_cim_prefill_close_to_f32(params):
    """The analog-CiM prefill path (calibrated ADC) tracks the f32 model:
    hidden-state cosine stays high and top-1 next-token mostly agrees."""
    t = toks(1, 8)
    lc, _, _ = M.prefill(params, t, CFG)  # CiM path
    lf, _, _ = M.prefill(params, t, CFG_F32)
    a = np.asarray(lc[0, -1]).ravel()
    b = np.asarray(lf[0, -1]).ravel()
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9))
    assert cos > 0.8, f"CiM prefill diverged from f32: cos={cos}"


def test_decode_cid_close_to_f32(params):
    """The decode path is digital (CiD, exact int8): much tighter match."""
    b = 1
    kc = jnp.asarray(RNG.normal(size=(CFG.n_layers, b, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim)).astype(np.float32)) * 0.1
    vc = jnp.asarray(RNG.normal(size=kc.shape).astype(np.float32)) * 0.1
    tok = toks(b, 1)[:, 0]
    pos = jnp.asarray([3], jnp.int32)
    lg_cid, _, _ = M.decode_step(params, tok, pos, kc, vc, CFG)
    lg_f32, _, _ = M.decode_step(params, tok, pos, kc, vc, CFG_F32)
    a, c = np.asarray(lg_cid).ravel(), np.asarray(lg_f32).ravel()
    cos = float(a @ c / (np.linalg.norm(a) * np.linalg.norm(c) + 1e-9))
    assert cos > 0.99


def test_generate_runs_and_is_deterministic(params):
    t = toks(1, 4)
    g1 = np.asarray(M.generate(params, t, CFG_F32, 3))
    g2 = np.asarray(M.generate(params, t, CFG_F32, 3))
    assert g1.shape == (1, 3)
    np.testing.assert_array_equal(g1, g2)
    assert g1.min() >= 0 and g1.max() < CFG.vocab

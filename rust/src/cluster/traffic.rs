//! Streaming traffic engine: seeded, deterministic workload *sources*.
//!
//! Everything upstream of the fleet used to be a fully materialized
//! `Vec<TraceRequest>` ([`Mix::trace`](super::workload::Mix)); that caps
//! studies at whatever fits in RSS and cannot express the traffic the
//! paper's low-batch interactive regime actually faces: bursts, diurnal
//! load curves, heavy-tailed lengths, and multi-turn sessions. This
//! module replaces the materialized trace with a *pull* abstraction:
//!
//! * [`WorkloadSource`] — `fn next(&mut self) -> Option<TraceRequest>`
//!   with nondecreasing arrivals; the streaming analogue of a trace
//!   slice. [`SliceSource`] adapts any existing trace, so the legacy
//!   [`Fleet::replay`](super::fleet::Fleet::replay) path is a thin
//!   wrapper over the streaming loop.
//! * [`ArrivalProcess`] — seeded arrival-time generators:
//!   [`Poisson`] (homogeneous), [`Mmpp`] (2-state Markov-modulated
//!   Poisson: calm/burst phases with exponential sojourns — bursty
//!   traffic with a controlled long-run mean rate), and [`Diurnal`]
//!   (sinusoidal rate curve thinned Lewis–Shedler style — a day of
//!   traffic with peak and trough). [`ArrivalKind`] names them for the
//!   CLI (`halo cluster --arrivals poisson|mmpp|diurnal`).
//! * [`LengthSampler`] — heavy-tailed length law: log-uniform within a
//!   band (the law every `Mix` preset uses) plus a Pareto tail beyond
//!   the band with probability `tail_p`, capturing the rare very long
//!   prompt/output that dominates tail latency at consumer scale.
//! * [`SessionConfig`] / sessions — multi-turn conversations: a fresh
//!   arrival opens a session that *re-arrives* after a think time with
//!   its context grown by the previous turn's output plus a follow-up
//!   (so successive turns share a strictly growing prefix). Session
//!   identity travels on [`TraceRequest::session`] for downstream
//!   prefix-cache studies.
//! * [`TrafficGen`] — the composition: one seeded RNG drives an arrival
//!   process, the length samplers, tenant assignment, and the session
//!   re-arrival queue, merged into a single strictly-increasing arrival
//!   stream. Bounded memory: state is the active-session set (bounded
//!   by rate x session lifetime), never the emitted request count.
//!
//! Determinism: every sampler draws from one `util::Rng`, so a
//! [`TrafficConfig`] is a complete, replayable description of a
//! workload — the same seed yields the same stream whether it is
//! consumed request-by-request by [`Fleet::serve`](super::fleet::Fleet::serve)
//! or materialized by [`collect_trace`] first (pinned by test).

use crate::sim::queueing::{log_uniform, TraceRequest};
use crate::util::Rng;

use super::workload::Mix;

/// A stream of requests with nondecreasing arrival times — the pull-side
/// seam between workload generation and [`Fleet::serve`](super::fleet::Fleet::serve).
/// Implementations must yield arrivals that never go backwards; the
/// fleet's event loop relies on this to pull one lookahead request at a
/// time instead of scanning a slice.
pub trait WorkloadSource {
    /// The next request, or `None` when the stream is exhausted.
    fn next(&mut self) -> Option<TraceRequest>;
}

/// Adapts a materialized trace slice to [`WorkloadSource`].
pub struct SliceSource<'a> {
    trace: &'a [TraceRequest],
    pos: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(trace: &'a [TraceRequest]) -> Self {
        SliceSource { trace, pos: 0 }
    }
}

impl WorkloadSource for SliceSource<'_> {
    fn next(&mut self) -> Option<TraceRequest> {
        let r = self.trace.get(self.pos).cloned();
        self.pos += usize::from(r.is_some());
        r
    }
}

/// Drain a source into a materialized trace (the bridge back to every
/// slice-based API: `per_tenant_stats`, figure tables, DSE calibration).
pub fn collect_trace(source: &mut dyn WorkloadSource) -> Vec<TraceRequest> {
    let mut out = Vec::new();
    while let Some(r) = source.next() {
        out.push(r);
    }
    out
}

/// A seeded point process generating absolute arrival times.
pub trait ArrivalProcess {
    /// Advance to and return the next arrival time (strictly after the
    /// previous one).
    fn next_arrival(&mut self, rng: &mut Rng) -> f64;
}

/// Homogeneous Poisson arrivals at `rate` requests/s.
pub struct Poisson {
    rate: f64,
    t: f64,
}

impl Poisson {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0, "arrival rate must be positive");
        Poisson { rate, t: 0.0 }
    }
}

impl ArrivalProcess for Poisson {
    fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        self.t += rng.exp(self.rate);
        self.t
    }
}

/// 2-state Markov-modulated Poisson process: exponential sojourns in a
/// *calm* phase and a *burst* phase, each an independent Poisson stream.
/// Burstiness shows up as an inter-arrival squared coefficient of
/// variation above 1 (Poisson is exactly 1) while the long-run mean rate
/// stays at the configured target.
pub struct Mmpp {
    calm_rate: f64,
    burst_rate: f64,
    mean_calm_s: f64,
    mean_burst_s: f64,
    t: f64,
    burst: bool,
    phase_ends: f64,
}

impl Mmpp {
    /// An MMPP whose long-run mean is `rate`: bursts run at 4x the calm
    /// rate, mean sojourns 10 s calm / 2 s burst, so 1/6 of the time is
    /// spent bursting and `mean = (5/6 + 4/6) * calm = rate`.
    pub fn balanced(rate: f64) -> Self {
        assert!(rate > 0.0, "arrival rate must be positive");
        let calm = rate / 1.5;
        Mmpp {
            calm_rate: calm,
            burst_rate: 4.0 * calm,
            mean_calm_s: 10.0,
            mean_burst_s: 2.0,
            t: 0.0,
            // start "in" a zero-length burst so the first step draws a
            // calm sojourn; phase flips are memoryless, so discarding
            // the gap drawn past a boundary is distribution-correct
            burst: true,
            phase_ends: 0.0,
        }
    }
}

impl ArrivalProcess for Mmpp {
    fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        loop {
            let rate = if self.burst { self.burst_rate } else { self.calm_rate };
            let gap = rng.exp(rate);
            if self.t + gap <= self.phase_ends {
                self.t += gap;
                return self.t;
            }
            self.t = self.phase_ends;
            self.burst = !self.burst;
            let mean = if self.burst { self.mean_burst_s } else { self.mean_calm_s };
            self.phase_ends = self.t + rng.exp(1.0 / mean);
        }
    }
}

/// Nonhomogeneous Poisson with a sinusoidal rate curve
/// `rate(t) = base * (1 + amplitude * sin(2 pi t / period))` — one
/// "day" of traffic per period, mean rate `base` over whole periods.
/// Sampled by Lewis–Shedler thinning against the peak rate.
pub struct Diurnal {
    base_rate: f64,
    amplitude: f64,
    period_s: f64,
    t: f64,
}

impl Diurnal {
    pub fn new(base_rate: f64, amplitude: f64, period_s: f64) -> Self {
        assert!(base_rate > 0.0, "arrival rate must be positive");
        assert!((0.0..1.0).contains(&amplitude), "amplitude must be in [0, 1)");
        assert!(period_s > 0.0, "period must be positive");
        Diurnal { base_rate, amplitude, period_s, t: 0.0 }
    }

    fn rate_at(&self, t: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t / self.period_s;
        self.base_rate * (1.0 + self.amplitude * phase.sin())
    }
}

impl ArrivalProcess for Diurnal {
    fn next_arrival(&mut self, rng: &mut Rng) -> f64 {
        let peak = self.base_rate * (1.0 + self.amplitude);
        loop {
            self.t += rng.exp(peak);
            if rng.f64() * peak <= self.rate_at(self.t) {
                return self.t;
            }
        }
    }
}

/// Named arrival process for the CLI (`--arrivals`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    Mmpp,
    Diurnal,
}

impl ArrivalKind {
    pub fn all() -> [ArrivalKind; 3] {
        [ArrivalKind::Poisson, ArrivalKind::Mmpp, ArrivalKind::Diurnal]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Mmpp => "mmpp",
            ArrivalKind::Diurnal => "diurnal",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" => Some(ArrivalKind::Poisson),
            "mmpp" | "burst" | "bursty" => Some(ArrivalKind::Mmpp),
            "diurnal" | "day" => Some(ArrivalKind::Diurnal),
            _ => None,
        }
    }

    /// Instantiate the process at a mean `rate`; `period_s` shapes the
    /// diurnal curve (one full day-cycle per period) and is ignored by
    /// the stationary processes.
    pub fn process(&self, rate: f64, period_s: f64) -> Box<dyn ArrivalProcess> {
        match self {
            ArrivalKind::Poisson => Box::new(Poisson::new(rate)),
            ArrivalKind::Mmpp => Box::new(Mmpp::balanced(rate)),
            ArrivalKind::Diurnal => Box::new(Diurnal::new(rate, 0.6, period_s.max(1.0))),
        }
    }
}

/// Heavy-tailed token-length law: log-uniform in `[lo, hi]` with
/// probability `1 - tail_p`, otherwise a Pareto tail
/// `hi * U^(-1/alpha)` capped at `cap` — the occasional very long
/// prompt/output that a bounded band cannot express.
#[derive(Debug, Clone, Copy)]
pub struct LengthSampler {
    pub lo: usize,
    pub hi: usize,
    /// Probability a draw comes from the Pareto tail (0 disables it).
    pub tail_p: f64,
    /// Pareto shape; smaller = heavier tail.
    pub tail_alpha: f64,
    /// Hard cap on tail draws (keeps KV budgets finite).
    pub cap: usize,
}

impl LengthSampler {
    /// Log-uniform band with a default 5% / alpha=1.5 Pareto tail capped
    /// at 16x the band ceiling.
    pub fn band(lo: usize, hi: usize) -> Self {
        assert!(lo >= 1 && hi >= lo, "bad length band [{lo}, {hi}]");
        LengthSampler { lo, hi, tail_p: 0.05, tail_alpha: 1.5, cap: hi.saturating_mul(16) }
    }

    /// The band without the tail — bit-compatible with the `Mix` law.
    pub fn body_only(lo: usize, hi: usize) -> Self {
        LengthSampler { tail_p: 0.0, ..LengthSampler::band(lo, hi) }
    }

    /// (prompt, output) samplers matching a [`Mix`] preset's bands, with
    /// the heavy tail on. `Interactive` — a blend in the trace API —
    /// maps to log-uniform over the blend's full span.
    pub fn for_mix(mix: Mix) -> (LengthSampler, LengthSampler) {
        match mix {
            Mix::Chat => (LengthSampler::band(64, 512), LengthSampler::band(64, 256)),
            Mix::Summarization => (LengthSampler::band(2048, 8192), LengthSampler::band(32, 128)),
            Mix::Generation => (LengthSampler::band(64, 256), LengthSampler::band(512, 2048)),
            Mix::Interactive => (LengthSampler::band(64, 8192), LengthSampler::band(32, 2048)),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        if self.tail_p > 0.0 && rng.f64() < self.tail_p {
            let u = rng.f64().max(1e-12);
            let x = self.hi as f64 * u.powf(-1.0 / self.tail_alpha);
            (x.round() as usize).clamp(self.hi, self.cap.max(self.hi)).max(1)
        } else {
            log_uniform(rng, self.lo, self.hi)
        }
    }
}

/// Multi-turn session behaviour.
#[derive(Debug, Clone, Copy)]
pub struct SessionConfig {
    /// Mean think time between a turn's (estimated) completion and the
    /// next turn's arrival, exponentially distributed.
    pub think_time_s: f64,
    /// Turns per session are drawn uniformly in `[1, max_turns]`.
    pub max_turns: usize,
    /// Fresh tokens appended by each follow-up turn on top of the
    /// previous turn's full context (prompt + generated output).
    pub follow_up: LengthSampler,
    /// Crude service-time allowance (s/token) used to estimate when a
    /// turn completes before scheduling the next think time; the
    /// generator is upstream of the fleet, so it cannot observe real
    /// completions.
    pub service_s_per_token: f64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            think_time_s: 5.0,
            max_turns: 6,
            follow_up: LengthSampler::band(16, 128),
            service_s_per_token: 2e-3,
        }
    }
}

/// One live conversation awaiting its next turn.
struct Session {
    id: u64,
    tenant: usize,
    /// Context of the next turn: everything said so far plus the fresh
    /// follow-up tokens (the shared, strictly growing prefix).
    next_l_in: usize,
    turns_left: usize,
    next_arrival: f64,
}

/// Complete, replayable description of a generated workload.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    pub seed: u64,
    /// Mean offered rate, requests/s (fresh arrivals; session re-arrivals
    /// add turns on top).
    pub rate: f64,
    /// Fresh arrivals stop after this horizon; in-flight sessions whose
    /// next turn would land beyond it are retired.
    pub duration_s: f64,
    pub kind: ArrivalKind,
    pub prompt: LengthSampler,
    pub output: LengthSampler,
    /// Tenants are drawn uniformly per request (per session when
    /// sessions are on); `<= 1` tags everything tenant 0.
    pub tenants: usize,
    /// `Some` turns every fresh arrival into a session opener.
    pub sessions: Option<SessionConfig>,
    /// Hard cap on emitted requests (0 = unlimited) — lets benches pin
    /// an exact request count independent of the rate/duration product.
    pub max_requests: usize,
}

impl TrafficConfig {
    /// Poisson arrivals, mix-shaped lengths, no sessions, no cap.
    pub fn new(seed: u64, rate: f64, duration_s: f64, mix: Mix) -> Self {
        let (prompt, output) = LengthSampler::for_mix(mix);
        TrafficConfig {
            seed,
            rate,
            duration_s,
            kind: ArrivalKind::Poisson,
            prompt,
            output,
            tenants: 1,
            sessions: None,
            max_requests: 0,
        }
    }

    pub fn with_kind(mut self, kind: ArrivalKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn with_sessions(mut self, sessions: SessionConfig) -> Self {
        self.sessions = Some(sessions);
        self
    }

    pub fn with_tenants(mut self, tenants: usize) -> Self {
        self.tenants = tenants.max(1);
        self
    }

    pub fn with_max_requests(mut self, max_requests: usize) -> Self {
        self.max_requests = max_requests;
        self
    }

    pub fn build(&self) -> TrafficGen {
        TrafficGen::new(self.clone())
    }
}

/// The streaming generator: merges fresh arrivals from the configured
/// [`ArrivalProcess`] with session re-arrivals into one strictly
/// increasing [`WorkloadSource`]. Memory is O(active sessions), never
/// O(emitted requests).
pub struct TrafficGen {
    cfg: TrafficConfig,
    rng: Rng,
    process: Box<dyn ArrivalProcess>,
    /// Pre-drawn next fresh arrival (None once the horizon is passed).
    next_fresh: Option<f64>,
    fresh_done: bool,
    sessions: Vec<Session>,
    next_session_id: u64,
    emitted: usize,
    last_arrival: f64,
}

impl TrafficGen {
    pub fn new(cfg: TrafficConfig) -> Self {
        let process = cfg.kind.process(cfg.rate, cfg.duration_s);
        TrafficGen {
            rng: Rng::new(cfg.seed),
            process,
            cfg,
            next_fresh: None,
            fresh_done: false,
            sessions: Vec::new(),
            next_session_id: 1,
            emitted: 0,
            last_arrival: 0.0,
        }
    }

    /// Live sessions awaiting their next turn (test/diagnostic surface).
    pub fn active_sessions(&self) -> usize {
        self.sessions.len()
    }

    fn draw_fresh(&mut self) {
        if self.fresh_done || self.next_fresh.is_some() {
            return;
        }
        let t = self.process.next_arrival(&mut self.rng);
        if t <= self.cfg.duration_s {
            self.next_fresh = Some(t);
        } else {
            self.fresh_done = true;
        }
    }

    /// Index of the session with the earliest next turn (ties broken by
    /// session id for determinism).
    fn earliest_session(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, s) in self.sessions.iter().enumerate() {
            let better = match best {
                None => true,
                Some(j) => {
                    let b = &self.sessions[j];
                    s.next_arrival < b.next_arrival
                        || (s.next_arrival == b.next_arrival && s.id < b.id)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }

    fn emit(
        &mut self,
        arrival: f64,
        l_in: usize,
        l_out: usize,
        tenant: usize,
        session: u64,
    ) -> TraceRequest {
        // strictly increasing arrivals: legacy joins key on arrival bits
        let arrival = if arrival > self.last_arrival {
            arrival
        } else {
            self.last_arrival + 1e-9
        };
        self.last_arrival = arrival;
        self.emitted += 1;
        TraceRequest { arrival, l_in, l_out, tenant, session }
    }
}

impl WorkloadSource for TrafficGen {
    fn next(&mut self) -> Option<TraceRequest> {
        if self.cfg.max_requests > 0 && self.emitted >= self.cfg.max_requests {
            return None;
        }
        self.draw_fresh();
        loop {
            let sess_idx = self.earliest_session();
            let sess_at = sess_idx.map(|i| self.sessions[i].next_arrival);
            match (self.next_fresh, sess_at) {
                (None, None) => return None,
                // session turn is due first
                (fresh, Some(at)) if fresh.is_none_or(|f| at <= f) => {
                    let i = sess_idx.unwrap();
                    if at > self.cfg.duration_s {
                        // horizon passed mid-think: retire quietly
                        self.sessions.swap_remove(i);
                        continue;
                    }
                    let l_in = self.sessions[i].next_l_in;
                    let (id, tenant) = (self.sessions[i].id, self.sessions[i].tenant);
                    let l_out = self.cfg.output.sample(&mut self.rng);
                    let req = self.emit(at, l_in, l_out, tenant, id);
                    let sc = self.cfg.sessions.unwrap_or_default();
                    let s = &mut self.sessions[i];
                    s.turns_left -= 1;
                    if s.turns_left == 0 {
                        self.sessions.swap_remove(i);
                    } else {
                        let follow = sc.follow_up.sample(&mut self.rng);
                        // grown context: prior turn's full exchange is the
                        // shared prefix of the next turn
                        s.next_l_in = l_in + l_out + follow;
                        let depart =
                            req.arrival + sc.service_s_per_token * (l_in + l_out) as f64;
                        s.next_arrival = depart + self.rng.exp(1.0 / sc.think_time_s.max(1e-9));
                    }
                    return Some(req);
                }
                // fresh arrival is due first
                (Some(at), _) => {
                    self.next_fresh = None;
                    let l_in = self.cfg.prompt.sample(&mut self.rng);
                    let l_out = self.cfg.output.sample(&mut self.rng);
                    let tenant = if self.cfg.tenants > 1 {
                        self.rng.below(self.cfg.tenants as u64) as usize
                    } else {
                        0
                    };
                    let (session, req);
                    if let Some(sc) = self.cfg.sessions {
                        let turns = 1 + self.rng.below(sc.max_turns.max(1) as u64) as usize;
                        session = self.next_session_id;
                        self.next_session_id += 1;
                        req = self.emit(at, l_in, l_out, tenant, session);
                        if turns > 1 {
                            let follow = sc.follow_up.sample(&mut self.rng);
                            let depart =
                                req.arrival + sc.service_s_per_token * (l_in + l_out) as f64;
                            self.sessions.push(Session {
                                id: session,
                                tenant,
                                next_l_in: l_in + l_out + follow,
                                turns_left: turns - 1,
                                next_arrival: depart
                                    + self.rng.exp(1.0 / sc.think_time_s.max(1e-9)),
                            });
                        }
                    } else {
                        req = self.emit(at, l_in, l_out, tenant, 0);
                    }
                    return Some(req);
                }
                (None, Some(_)) => unreachable!("session arm above covers fresh=None"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(cfg: &TrafficConfig) -> Vec<TraceRequest> {
        collect_trace(&mut cfg.build())
    }

    #[test]
    fn slice_source_replays_trace_verbatim() {
        let trace = Mix::Chat.trace(1, 50, 10.0);
        let mut src = SliceSource::new(&trace);
        let copy = collect_trace(&mut src);
        assert_eq!(copy.len(), trace.len());
        for (a, b) in trace.iter().zip(&copy) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(
                (a.l_in, a.l_out, a.tenant, a.session),
                (b.l_in, b.l_out, b.tenant, b.session)
            );
        }
        assert!(src.next().is_none());
    }

    #[test]
    fn poisson_rate_and_monotonicity() {
        for kind in ArrivalKind::all() {
            let cfg = TrafficConfig::new(11, 50.0, 200.0, Mix::Chat).with_kind(kind);
            let tr = drain(&cfg);
            assert!(
                tr.windows(2).all(|w| w[0].arrival < w[1].arrival),
                "{} arrivals must strictly increase",
                kind.name()
            );
            // ~50 rps * 200 s = ~10k requests; generous band for the
            // modulated processes
            let n = tr.len() as f64;
            assert!(
                (n - 10_000.0).abs() < 2_000.0,
                "{}: {} requests for a 10k-expectation run",
                kind.name(),
                tr.len()
            );
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        let scv = |kind: ArrivalKind| {
            let cfg = TrafficConfig::new(5, 20.0, 500.0, Mix::Chat).with_kind(kind);
            let tr = drain(&cfg);
            let gaps: Vec<f64> =
                tr.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let poisson = scv(ArrivalKind::Poisson);
        let mmpp = scv(ArrivalKind::Mmpp);
        assert!((0.7..1.4).contains(&poisson), "poisson scv {poisson}");
        assert!(mmpp > poisson + 0.3, "mmpp scv {mmpp} vs poisson {poisson}");
    }

    #[test]
    fn diurnal_rate_tracks_the_curve() {
        // amplitude 0.6, one period over the horizon: the first half-day
        // runs above base rate, the second below
        let cfg = TrafficConfig::new(3, 40.0, 400.0, Mix::Chat).with_kind(ArrivalKind::Diurnal);
        let tr = drain(&cfg);
        let first = tr.iter().filter(|r| r.arrival < 200.0).count();
        let second = tr.len() - first;
        assert!(
            first as f64 > 1.5 * second as f64,
            "diurnal peak half {first} vs trough half {second}"
        );
    }

    #[test]
    fn length_sampler_band_and_tail() {
        let s = LengthSampler::band(64, 512);
        let mut rng = Rng::new(9);
        let draws: Vec<usize> = (0..4000).map(|_| s.sample(&mut rng)).collect();
        assert!(draws.iter().all(|&x| x >= 64 && x <= s.cap));
        let tail = draws.iter().filter(|&&x| x > 512).count();
        // ~5% of 4000 = 200
        assert!((100..=350).contains(&tail), "tail draws {tail}");
        let body = LengthSampler::body_only(64, 512);
        let mut rng = Rng::new(9);
        assert!((0..4000).all(|_| body.sample(&mut rng) <= 512));
    }

    #[test]
    fn sessions_grow_context_monotonically() {
        use std::collections::HashMap;
        let cfg = TrafficConfig::new(21, 5.0, 120.0, Mix::Chat)
            .with_sessions(SessionConfig::default())
            .with_tenants(3);
        let tr = drain(&cfg);
        assert!(tr.iter().all(|r| r.session > 0), "every request belongs to a session");
        let mut turns: HashMap<u64, Vec<&TraceRequest>> = HashMap::new();
        for r in &tr {
            turns.entry(r.session).or_default().push(r);
        }
        let multi = turns.values().filter(|v| v.len() > 1).count();
        assert!(multi > 10, "expected many multi-turn sessions, got {multi}");
        for reqs in turns.values() {
            for w in reqs.windows(2) {
                // next turn's prompt strictly contains the previous
                // turn's full exchange
                assert!(w[1].l_in > w[0].l_in + w[0].l_out - 1);
                assert!(w[1].arrival > w[0].arrival);
                assert_eq!(w[1].tenant, w[0].tenant);
            }
        }
    }

    #[test]
    fn deterministic_per_seed_and_bounded_state() {
        let cfg = TrafficConfig::new(7, 30.0, 60.0, Mix::Interactive)
            .with_kind(ArrivalKind::Mmpp)
            .with_sessions(SessionConfig::default());
        let a = drain(&cfg);
        let b = drain(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(
                (x.l_in, x.l_out, x.tenant, x.session),
                (y.l_in, y.l_out, y.tenant, y.session)
            );
        }
        let c = drain(&TrafficConfig::new(8, 30.0, 60.0, Mix::Interactive));
        assert!(a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn max_requests_caps_the_stream() {
        let cfg = TrafficConfig::new(2, 100.0, 1e9, Mix::Chat).with_max_requests(1234);
        assert_eq!(drain(&cfg).len(), 1234);
    }
}

//! Power-plane integration tests: cross-plane energy agreement (the
//! event-driven replay charges exactly the joules the joint cost oracle
//! computes — bit-for-bit, since both halves come from one
//! `simulate_graph` walk), the one-walk-per-point guarantee (power
//! tracking adds no graph walks), monotonicity of energy in workload
//! size, the zero-overhead guarantee (power tracking off or uncapped
//! changes no latency bit), interconnect KV-transfer energy accounting,
//! the live TDP throttling feedback (tighter caps cost real throughput),
//! and the per-phase DVFS plane (ladder monotonicity, stepped governor
//! convergence).

use halo::cluster::{Fleet, FleetBuilder, Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::power::{DvfsConfig, ThermalConfig};
use halo::sim::cost::CostModel;
use halo::sim::queueing::TraceRequest;
use halo::sim::{simulate_e2e, Scenario};

fn hw() -> HwConfig {
    HwConfig::paper()
}

fn llm() -> LlmConfig {
    LlmConfig::llama2_7b()
}

/// A plain unified fleet on the board link, 8 slots/device.
fn unified_fleet(devices: usize) -> Fleet {
    FleetBuilder::new(&llm(), &hw())
        .devices(devices)
        .slots(8)
        .interconnect(Interconnect::board())
        .build()
}

/// One power-tracked HALO1 device serving `trace`.
fn powered_replay(
    trace: &[TraceRequest],
    thermal: Option<ThermalConfig>,
) -> halo::cluster::FleetResult {
    let mut fleet = FleetBuilder::new(&llm(), &hw())
        .devices(1)
        .slots(8)
        .interconnect(Interconnect::board())
        .power(thermal)
        .build();
    let mut router = Policy::LeastLoaded.router();
    fleet.replay(trace, router.as_mut())
}

fn single_request(l_in: usize, l_out: usize) -> Vec<TraceRequest> {
    vec![TraceRequest { arrival: 0.0, l_in, l_out, tenant: 0, session: 0 }]
}

#[test]
fn replay_energy_equals_the_joint_oracle_bit_for_bit() {
    // acceptance: both planes share one walk, so the agreement is exact
    // equality, not a 5% band. A single-request replay runs one prefill
    // at l_in and l_out decode steps at contexts l_in .. l_in+l_out-1
    // (batch 1); accumulating the same curves in the same order must
    // reproduce the replay's dynamic energy to the last bit.
    for (l_in, l_out) in [(512usize, 16usize), (1024, 8)] {
        let r = powered_replay(&single_request(l_in, l_out), None);
        let mut cm = CostModel::new(&llm(), &hw(), MappingKind::Halo1);
        let mut want = cm.prefill(l_in).energy;
        for k in 0..l_out {
            want.add(&cm.decode_step(1, l_in + k).energy);
        }
        assert_eq!(r.energy.e_dram.to_bits(), want.e_dram.to_bits(), "({l_in},{l_out})");
        assert_eq!(r.energy.e_compute.to_bits(), want.e_compute.to_bits());
        assert_eq!(r.energy.e_buffer.to_bits(), want.e_buffer.to_bits());
        assert_eq!(r.energy.e_write.to_bits(), want.e_write.to_bits());
        assert_eq!(r.energy.dynamic().to_bits(), want.dynamic().to_bits());
    }
}

#[test]
fn power_tracking_performs_no_extra_graph_walks() {
    // acceptance: with power tracking enabled, each distinct
    // (prefill-length / decode-batch / chunk) point walks simulate_graph
    // exactly once — a power-tracked replay performs no more walks than
    // the latency-only replay of the same trace
    let trace = Mix::Interactive.trace(41, 48, 12.0);
    let walks = |power: bool| {
        let mut fleet = unified_fleet(2);
        if power {
            fleet.enable_power(&hw(), None);
        }
        let mut router = Policy::LeastLoaded.router();
        fleet.replay(&trace, router.as_mut());
        fleet.cost_walks()
    };
    let plain = walks(false);
    let tracked = walks(true);
    assert!(plain > 0);
    assert!(tracked <= plain, "power tracking added walks: {tracked} vs {plain}");
    assert_eq!(tracked, plain, "same trace, same distinct points, same walks");
    // and the process-wide counter on simulate_graph moves when a walk runs
    let before = halo::sim::graph_walks();
    let mut cm = CostModel::new(&llm(), &hw(), MappingKind::Halo1);
    cm.prefill(333);
    assert!(halo::sim::graph_walks() >= before + 1);
}

#[test]
fn single_request_energy_matches_the_analytical_plane() {
    // acceptance: a one-request replay's accumulated dynamic energy must
    // agree with arch's e2e energy. The replay runs l_out - 1 discrete
    // decode steps at exact contexts while the analytical plane charges
    // l_out steps at the mid-generation context (affine costs), so the
    // two differ by about one step in l_out — well inside 5%.
    for (l_in, l_out) in [(512usize, 64usize), (2048, 128), (1024, 32)] {
        let r = powered_replay(&single_request(l_in, l_out), None);
        assert!(r.power_tracked);
        let replay_dynamic = r.energy.dynamic();
        let arch = simulate_e2e(
            &llm(),
            &hw(),
            MappingKind::Halo1,
            &Scenario { l_in, l_out, batch: 1 },
        )
        .e2e_energy();
        let rel = (replay_dynamic - arch).abs() / arch;
        assert!(
            rel < 0.05,
            "({l_in},{l_out}): replay {replay_dynamic} vs arch {arch} (rel {rel:.4})"
        );
        // static energy is accounted on top of (never inside) dynamic
        assert!(r.energy.e_static > 0.0);
        assert!(r.energy_j() > replay_dynamic);
    }
}

#[test]
fn replay_energy_is_monotone_in_tokens_and_sequence_length() {
    let dynamic = |l_in: usize, l_out: usize| {
        powered_replay(&single_request(l_in, l_out), None).energy.dynamic()
    };
    // non-decreasing in generated tokens
    let e16 = dynamic(512, 16);
    let e64 = dynamic(512, 64);
    let e256 = dynamic(512, 256);
    assert!(e16 < e64 && e64 < e256, "{e16} {e64} {e256}");
    // non-decreasing in prompt length
    let p256 = dynamic(256, 32);
    let p1024 = dynamic(1024, 32);
    let p4096 = dynamic(4096, 32);
    assert!(p256 < p1024 && p1024 < p4096, "{p256} {p1024} {p4096}");
}

#[test]
fn power_tracking_off_or_uncapped_is_bit_identical() {
    // acceptance: with tracking disabled the replay is the legacy one;
    // with tracking on but no TDP cap, latency results are still
    // bit-identical — attribution is an observer, not a participant
    let trace = Mix::Interactive.trace(31, 60, 10.0);
    let run = |power: Option<Option<ThermalConfig>>| {
        let mut fleet = unified_fleet(2);
        if let Some(thermal) = power {
            fleet.enable_power(&hw(), thermal);
        }
        let mut router = Policy::LeastLoaded.router();
        fleet.replay(&trace, router.as_mut())
    };
    let plain = run(None);
    let tracked = run(Some(None));
    assert_eq!(plain.makespan.to_bits(), tracked.makespan.to_bits());
    assert_eq!(plain.decode_steps, tracked.decode_steps);
    assert_eq!(plain.served.len(), tracked.served.len());
    for (a, b) in plain.served.iter().zip(&tracked.served) {
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
        assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
    }
    // the observer still observed
    assert!(!plain.power_tracked && tracked.power_tracked);
    assert_eq!(plain.energy_j(), 0.0);
    assert!(tracked.energy_j() > 0.0);
    assert_eq!(tracked.throttled_s, 0.0);
}

#[test]
fn throughput_degrades_monotonically_as_tdp_tightens() {
    // acceptance: throttling feedback is live. Saturating burst on one
    // device: served rate == capacity, so any throttling shows directly.
    let trace = Mix::Generation.trace(33, 48, 1.0e6);
    let caps: [Option<f64>; 4] = [None, Some(150.0), Some(100.0), Some(60.0)];
    let mut rps = Vec::new();
    let mut throttled = Vec::new();
    for cap in caps {
        let r = powered_replay(&trace, cap.map(ThermalConfig::paper));
        assert_eq!(r.served.len(), 48);
        rps.push(r.throughput_rps());
        throttled.push(r.throttled_s);
    }
    for w in rps.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-9), "tighter cap raised throughput: {rps:?}");
    }
    assert!(rps[3] < rps[0] * 0.95, "the tightest cap must cost real throughput: {rps:?}");
    assert_eq!(throttled[0], 0.0);
    assert!(throttled[3] > throttled[1], "{throttled:?}");
}

#[test]
fn kv_transfers_cost_joules_proportional_to_bytes() {
    let trace = Mix::Chat.trace(35, 40, 50.0);
    let run = |link: Interconnect| {
        let (mut fleet, mut router) =
            Policy::PhaseDisaggregated.build(&llm(), &hw(), 4, 8, 0.5, link);
        fleet.replay(&trace, router.as_mut())
    };
    let board = run(Interconnect::board());
    let eth = run(Interconnect::ethernet());
    assert_eq!(board.transfers, 40);
    assert_eq!(board.kv_bytes, eth.kv_bytes, "same trace, same KV volume");
    let want_board = Interconnect::board().transfer_energy(board.kv_bytes);
    assert!((board.kv_transfer_energy_j - want_board).abs() < 1e-9 * want_board);
    // a higher-energy link class costs proportionally more joules
    let ratio = eth.kv_transfer_energy_j / board.kv_transfer_energy_j;
    let want_ratio = Interconnect::ethernet().e_per_byte / Interconnect::board().e_per_byte;
    assert!((ratio - want_ratio).abs() < 1e-9, "{ratio} vs {want_ratio}");
}

#[test]
fn dvfs_ladder_monotone_on_compute_bound_prefill() {
    // satellite acceptance: on compute-bound prefill, lower frequency
    // points never reduce energy per token (the static-time penalty
    // outweighs the shallow CV^2 saving) while strictly reducing peak
    // power — and they strictly stretch the replay.
    let trace: Vec<TraceRequest> = (0..12)
        .map(|i| TraceRequest {
            arrival: i as f64 * 1e-3,
            l_in: 2048,
            l_out: 1,
            tenant: 0,
            session: 0,
        })
        .collect();
    let ladder_len = hw().power.dvfs_points.len();
    assert!(ladder_len >= 3);
    let run = |idx: usize| {
        let mut fleet = FleetBuilder::new(&llm(), &hw())
            .devices(1)
            .slots(8)
            .interconnect(Interconnect::board())
            .power(None)
            .dvfs(DvfsConfig::with_indices(&hw().power, idx, idx))
            .build();
        let mut router = Policy::LeastLoaded.router();
        fleet.replay(&trace, router.as_mut())
    };
    let runs: Vec<_> = (0..ladder_len).map(run).collect();
    for w in runs.windows(2) {
        assert!(
            w[1].energy_j() >= w[0].energy_j() * (1.0 - 1e-9),
            "a lower point reduced prefill energy: {} vs {}",
            w[1].energy_j(),
            w[0].energy_j()
        );
        assert!(
            w[1].peak_power_w < w[0].peak_power_w,
            "peak power must fall down the ladder: {} vs {}",
            w[1].peak_power_w,
            w[0].peak_power_w
        );
        assert!(w[1].makespan > w[0].makespan, "lower points must be slower");
    }
    // configured slowdowns book no throttle time
    assert!(runs.iter().all(|r| r.throttled_s == 0.0));
}

#[test]
fn dvfs_governor_converges_under_a_tdp_cap_like_the_scalar_throttle() {
    // the stepped governor replaces the scalar throttle factor: under a
    // TDP cap it must trade real throughput for power (monotonically in
    // the cap) by walking the ladder, and do nothing uncapped
    let trace = Mix::Generation.trace(39, 40, 1.0e6);
    let run = |cap: Option<f64>| {
        let mut fleet = FleetBuilder::new(&llm(), &hw())
            .devices(1)
            .slots(8)
            .interconnect(Interconnect::board())
            .power(cap.map(|w| {
                // short replay: shrink the thermal time constant so the
                // package reaches its band within the test's busy time
                let mut c = ThermalConfig::paper(w);
                c.tau_s = 0.05;
                c
            }))
            .dvfs(DvfsConfig::governed(&hw().power))
            .build();
        let mut router = Policy::LeastLoaded.router();
        let r = fleet.replay(&trace, router.as_mut());
        let max_gov = fleet.devices[0].power().unwrap().max_gov_idx;
        (r, max_gov)
    };
    let (free, free_gov) = run(None);
    let (mid, mid_gov) = run(Some(120.0));
    let (tight, tight_gov) = run(Some(60.0));
    // no cap, no thermal model: the governor never engages
    assert_eq!(free_gov, 0);
    assert_eq!(free.throttled_s, 0.0);
    // capped runs walk the ladder and pay real wall-clock time
    assert!(tight_gov >= 1, "tight cap must step the governor down");
    assert!(tight.throttled_s > 0.0);
    assert!(
        tight.makespan > free.makespan * 1.05,
        "a 60 W cap must visibly stretch the replay: {} vs {}",
        tight.makespan,
        free.makespan
    );
    // tighter caps never run faster (small slack for rung hysteresis)
    assert!(mid.makespan >= free.makespan * (1.0 - 1e-9));
    assert!(tight.makespan >= mid.makespan * 0.999, "{} vs {}", tight.makespan, mid.makespan);
    assert!(tight_gov >= mid_gov);
}

#[test]
fn per_device_energy_and_utilization_surface_in_fleet_stats() {
    let trace = Mix::Interactive.trace(37, 60, 30.0);
    let mut fleet = unified_fleet(3);
    fleet.enable_power(&hw(), None);
    let mut router = Policy::LeastLoaded.router();
    let r = fleet.replay(&trace, router.as_mut());
    let device_sum: f64 = r.per_device.iter().map(|d| d.energy.total()).sum();
    assert!((r.energy_j() - device_sum).abs() < 1e-9 * device_sum);
    for d in &r.per_device {
        let util = d.utilization(r.makespan);
        assert!((0.0..=1.0 + 1e-9).contains(&util), "device {} util {util}", d.id);
        // every serving device draws at least the static floor on average
        let floor = hw().power.static_w(hw().hbm.stacks, false);
        assert!(d.avg_power_w(r.makespan) >= floor * 0.99, "device {}", d.id);
        assert!(d.peak_power_w >= floor || d.served == 0);
    }
}

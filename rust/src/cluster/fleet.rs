//! A fleet of HALO devices advanced in global event order.
//!
//! Each device is an independent [`Device`] state machine with its own
//! clock; the fleet interleaves three event sources — workload arrivals,
//! KV-handoff deliveries, and device scheduling cycles — always taking
//! the earliest. Requests routed with distinct prefill/decode devices
//! incur a KV-cache transfer over the [`Interconnect`] between the
//! prefill's completion and the decode admission.
//!
//! Two entry points share the same event loop:
//!
//! - [`Fleet::serve`] pulls arrivals one at a time from any
//!   [`WorkloadSource`] and folds completions into an online
//!   [`ServeSink`]-backed result — bounded memory in the request count,
//!   with streaming [`LogHistogram`] percentiles once the configurable
//!   retention cap ([`ServeOptions`]) is exceeded.
//! - [`Fleet::replay`] is a thin wrapper: a slice-backed source with an
//!   unbounded retention cap, bit-identical to the historical
//!   materialized-trace replay (pinned by fingerprint tests below).
//!
//! Fleets are built with [`FleetBuilder`]; the historical constructors
//! (`unified`, `disaggregated_with`, ...) remain as deprecated shims.

use super::interconnect::{kv_transfer_bytes, Interconnect};
use super::router::Router;
use super::traffic::{SliceSource, WorkloadSource};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::obs::{self, GaugeSample, LogHistogram, Recorder, Span, SpanKind, Track, WindowSeries};
use crate::power::{DvfsConfig, EnergyBreakdown, ThermalConfig};
use crate::sim::device::{Device, DeviceJob, ReqTag, SchedConfig};
use crate::sim::queueing::{served_rate, ServedRequest, TraceRequest};
use crate::util::json::Json;
use crate::util::percentile_sorted;

/// A KV cache in flight between a prefill device and a decode device.
#[derive(Debug, Clone)]
struct InFlight {
    ready: f64,
    dev: usize,
    arrival: f64,
    first_token_at: f64,
    ctx: usize,
    remaining: usize,
    tag: ReqTag,
}

/// How [`Fleet::serve`] retains completed requests.
///
/// Counters, histograms, and the makespan are always exact; the cap
/// only bounds how many raw [`ServedRequest`] records survive into
/// [`FleetResult::served`]. Under the cap the result is `complete` and
/// percentiles come from the exact sorted views (bit-compatible with
/// the legacy clone-and-sort helpers); over it they fall back to the
/// ~±3% log-bucketed histograms and RSS stays flat in request count.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Maximum number of raw served records to retain.
    pub retain_cap: usize,
}

impl ServeOptions {
    /// Retain every served record (the replay-compatible default).
    pub fn exact() -> Self {
        ServeOptions { retain_cap: usize::MAX }
    }

    /// Retain at most `retain_cap` records; statistics go streaming.
    pub fn streaming(retain_cap: usize) -> Self {
        ServeOptions { retain_cap }
    }
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self::exact()
    }
}

/// Online accumulator for completed requests: exact counters + streaming
/// histograms always, raw records only up to the retention cap. Each
/// retained record is keyed `(device, per-device completion seq)` so the
/// collect step can reconstruct the legacy device-major `served` order
/// no matter how device completions interleaved in global time.
struct ServeSink {
    retain_cap: usize,
    retained: Vec<(usize, u64, ServedRequest)>,
    /// Per-device completion count; doubles as the next seq key.
    dev_seq: Vec<u64>,
    ttft_hist: LogHistogram,
    e2e_hist: LogHistogram,
    requests: usize,
    tokens: u64,
}

impl ServeSink {
    fn new(retain_cap: usize, devices: usize) -> Self {
        ServeSink {
            retain_cap,
            retained: Vec::new(),
            dev_seq: vec![0; devices],
            ttft_hist: LogHistogram::new(),
            e2e_hist: LogHistogram::new(),
            requests: 0,
            tokens: 0,
        }
    }

    fn fold(&mut self, dev: usize, r: ServedRequest) {
        self.ttft_hist.record(r.ttft);
        self.e2e_hist.record(r.e2e);
        self.requests += 1;
        self.tokens += r.tokens;
        let seq = self.dev_seq[dev];
        self.dev_seq[dev] += 1;
        if self.retained.len() < self.retain_cap {
            self.retained.push((dev, seq, r));
        }
    }
}

/// Topology selected on a [`FleetBuilder`].
#[derive(Debug, Clone)]
enum Topology {
    Unified,
    Disaggregated { prefill_frac: f64 },
    Heterogeneous { mappings: Vec<MappingKind> },
}

/// Fluent construction for [`Fleet`]: one builder replacing the five
/// historical constructors plus the mutate-after-build sprawl
/// (`enable_power` / `enable_obs` / `set_dvfs` / `set_kv_capacity`).
///
/// ```ignore
/// let mut fleet = FleetBuilder::new(&llm, &hw)
///     .devices(8)
///     .slots(4)
///     .disaggregated(0.5)
///     .interconnect(Interconnect::board())
///     .power(None)
///     .build();
/// ```
///
/// Defaults: one unified HALO1 device, 4 slots, board-level link,
/// default scheduler, no power/obs/DVFS.
#[derive(Debug, Clone)]
pub struct FleetBuilder {
    llm: LlmConfig,
    hw: HwConfig,
    topology: Topology,
    devices: usize,
    slots: usize,
    interconnect: Interconnect,
    sched: SchedConfig,
    kv_caps: Vec<(usize, Option<u64>)>,
    power_enabled: bool,
    thermal: Option<ThermalConfig>,
    dvfs: Option<DvfsConfig>,
    obs: bool,
}

impl FleetBuilder {
    pub fn new(llm: &LlmConfig, hw: &HwConfig) -> Self {
        FleetBuilder {
            llm: llm.clone(),
            hw: hw.clone(),
            topology: Topology::Unified,
            devices: 1,
            slots: 4,
            interconnect: Interconnect::board(),
            sched: SchedConfig::default(),
            kv_caps: Vec::new(),
            power_enabled: false,
            thermal: None,
            dvfs: None,
            obs: false,
        }
    }

    /// Number of devices (ignored by [`FleetBuilder::heterogeneous`],
    /// which sizes the fleet from its mapping list).
    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n;
        self
    }

    /// Concurrent decode slots per device.
    pub fn slots(mut self, n: usize) -> Self {
        self.slots = n;
        self
    }

    pub fn interconnect(mut self, link: Interconnect) -> Self {
        self.interconnect = link;
        self
    }

    /// Per-device scheduling configuration (chunked prefill, admission
    /// policy, KV capacity).
    pub fn sched(mut self, sched: SchedConfig) -> Self {
        self.sched = sched;
        self
    }

    /// Homogeneous HALO1 fleet; every device prefills and decodes (the
    /// default topology).
    pub fn unified(mut self) -> Self {
        self.topology = Topology::Unified;
        self
    }

    /// Phase-disaggregated fleet: `prefill_frac` of the devices (at
    /// least one, at most n-1) form a Fully-CiM prefill pool feeding a
    /// Fully-CiD decode pool.
    pub fn disaggregated(mut self, prefill_frac: f64) -> Self {
        self.topology = Topology::Disaggregated { prefill_frac };
        self
    }

    /// Unified fleet with an explicit per-device mapping (HALO1 beside
    /// HALO2 / HALO-SA devices); fleet size follows the list.
    pub fn heterogeneous(mut self, mappings: &[MappingKind]) -> Self {
        self.devices = mappings.len();
        self.topology = Topology::Heterogeneous { mappings: mappings.to_vec() };
        self
    }

    /// Override one device's resident-KV budget after construction.
    pub fn kv_capacity(mut self, dev: usize, cap: Option<u64>) -> Self {
        self.kv_caps.push((dev, cap));
        self
    }

    /// Attach per-event energy attribution (and, with a
    /// [`ThermalConfig`], a live TDP throttle) to every device.
    pub fn power(mut self, thermal: Option<ThermalConfig>) -> Self {
        self.power_enabled = true;
        self.thermal = thermal;
        self
    }

    /// Pin every device to a per-phase DVFS configuration.
    pub fn dvfs(mut self, dvfs: DvfsConfig) -> Self {
        self.dvfs = Some(dvfs);
        self
    }

    /// Attach request-lifecycle span recorders for Chrome-trace export.
    pub fn obs(mut self) -> Self {
        self.obs = true;
        self
    }

    pub fn build(self) -> Fleet {
        let (devs, prefill_pool, decode_pool): (Vec<Device>, Vec<usize>, Vec<usize>) =
            match &self.topology {
                Topology::Unified => {
                    assert!(self.devices > 0);
                    let devs = (0..self.devices)
                        .map(|i| {
                            Device::with_sched(
                                &self.llm,
                                &self.hw,
                                MappingKind::Halo1,
                                self.slots,
                                i,
                                self.sched.clone(),
                            )
                        })
                        .collect();
                    (devs, (0..self.devices).collect(), (0..self.devices).collect())
                }
                Topology::Disaggregated { prefill_frac } => {
                    let devices = self.devices;
                    assert!(devices >= 2, "disaggregation needs at least 2 devices");
                    assert!(*prefill_frac > 0.0 && *prefill_frac < 1.0);
                    let n_pre = ((devices as f64 * prefill_frac).round() as usize)
                        .clamp(1, devices - 1);
                    let devs = (0..devices)
                        .map(|i| {
                            let mapping = if i < n_pre {
                                MappingKind::FullCim
                            } else {
                                MappingKind::FullCid
                            };
                            Device::with_sched(
                                &self.llm,
                                &self.hw,
                                mapping,
                                self.slots,
                                i,
                                self.sched.clone(),
                            )
                        })
                        .collect();
                    (devs, (0..n_pre).collect(), (n_pre..devices).collect())
                }
                Topology::Heterogeneous { mappings } => {
                    assert!(!mappings.is_empty(), "heterogeneous fleet needs at least 1 device");
                    let devs = mappings
                        .iter()
                        .enumerate()
                        .map(|(i, &m)| {
                            Device::with_sched(
                                &self.llm,
                                &self.hw,
                                m,
                                self.slots,
                                i,
                                self.sched.clone(),
                            )
                        })
                        .collect();
                    (devs, (0..mappings.len()).collect(), (0..mappings.len()).collect())
                }
            };
        let devices = devs.len();
        let mut fleet = Fleet {
            llm: self.llm,
            devices: devs,
            interconnect: self.interconnect,
            prefill_pool,
            decode_pool,
            kv_bytes: 0,
            transfers: 0,
            kv_energy_j: 0.0,
            pending_decode: vec![0; devices],
            pending_kv: vec![0; devices],
            obs_kv: None,
            obs_kv_cap: usize::MAX,
        };
        for (dev, cap) in self.kv_caps {
            fleet.set_kv_capacity(dev, cap);
        }
        if self.power_enabled {
            fleet.enable_power(&self.hw, self.thermal);
        }
        if let Some(dvfs) = self.dvfs {
            fleet.set_dvfs(dvfs);
        }
        if self.obs {
            fleet.enable_obs();
        }
        fleet
    }
}

/// N devices, their routing pools, and the link between them.
pub struct Fleet {
    pub llm: LlmConfig,
    pub devices: Vec<Device>,
    pub interconnect: Interconnect,
    /// Devices eligible to run prefills (all of them for unified fleets).
    pub prefill_pool: Vec<usize>,
    /// Devices eligible to run decode (all of them for unified fleets).
    pub decode_pool: Vec<usize>,
    /// KV bytes moved across the interconnect so far.
    pub kv_bytes: u64,
    pub transfers: u64,
    /// Joules spent moving KV caches across the interconnect.
    pub kv_energy_j: f64,
    /// Decode work committed by routing but not yet delivered (request
    /// still in prefill or KV transfer), per device. Without it, burst
    /// routing would herd every request onto one decode device, since
    /// `Device::load` only rises once the handoff lands.
    pending_decode: Vec<usize>,
    /// Estimated KV bytes of those undelivered decode assignments
    /// (`(l_in + l_out) x bytes/token`), per device — what a
    /// capacity-aware router must subtract from the device headroom.
    pending_kv: Vec<u64>,
    /// KV-handoff transfer spans for the trace's interconnect track
    /// (`Some` once [`Fleet::enable_obs`] is called).
    obs_kv: Option<Vec<Span>>,
    /// Retention cap on `obs_kv` (mirroring the device recorders'):
    /// `usize::MAX` for `enable_obs`, finite for `enable_obs_capped`.
    obs_kv_cap: usize,
}

impl Fleet {
    /// A homogeneous fleet: every device runs the HALO1 phase-aware
    /// mapping end-to-end (the monolithic baseline).
    #[deprecated(since = "0.7.0", note = "use FleetBuilder::new(llm, hw).devices(n)…build()")]
    pub fn unified(
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        interconnect: Interconnect,
    ) -> Self {
        FleetBuilder::new(llm, hw)
            .devices(devices)
            .slots(slots)
            .interconnect(interconnect)
            .build()
    }

    /// [`Fleet::unified`] under an explicit per-device scheduling
    /// configuration (chunked prefill, admission policy, KV capacity).
    #[deprecated(since = "0.7.0", note = "use FleetBuilder::new(llm, hw).sched(…)…build()")]
    pub fn unified_with(
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        interconnect: Interconnect,
        sched: SchedConfig,
    ) -> Self {
        FleetBuilder::new(llm, hw)
            .devices(devices)
            .slots(slots)
            .interconnect(interconnect)
            .sched(sched)
            .build()
    }

    /// A unified fleet with an explicit per-device mapping — heterogeneous
    /// compositions such as HALO1 devices serving alongside HALO2
    /// (accuracy-tiered) or HALO-SA (digital-fallback) devices. Every
    /// device prefills and decodes; routing decides who gets what.
    #[deprecated(since = "0.7.0", note = "use FleetBuilder with .heterogeneous(mappings)")]
    pub fn heterogeneous_with(
        llm: &LlmConfig,
        hw: &HwConfig,
        mappings: &[MappingKind],
        slots: usize,
        interconnect: Interconnect,
        sched: SchedConfig,
    ) -> Self {
        FleetBuilder::new(llm, hw)
            .heterogeneous(mappings)
            .slots(slots)
            .interconnect(interconnect)
            .sched(sched)
            .build()
    }

    /// A phase-disaggregated fleet: a Fully-CiM prefill pool feeding a
    /// Fully-CiD decode pool (Table II taken to cluster scale).
    /// `prefill_frac` of the devices (at least one, at most n-1) prefill.
    #[deprecated(since = "0.7.0", note = "use FleetBuilder with .disaggregated(prefill_frac)")]
    pub fn disaggregated(
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        prefill_frac: f64,
        interconnect: Interconnect,
    ) -> Self {
        FleetBuilder::new(llm, hw)
            .devices(devices)
            .slots(slots)
            .disaggregated(prefill_frac)
            .interconnect(interconnect)
            .build()
    }

    /// [`Fleet::disaggregated`] under an explicit per-device scheduling
    /// configuration. The KV capacity applies to every device; use
    /// [`Fleet::set_kv_capacity`] afterwards for heterogeneous budgets.
    #[deprecated(since = "0.7.0", note = "use FleetBuilder with .disaggregated(prefill_frac)")]
    pub fn disaggregated_with(
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        prefill_frac: f64,
        interconnect: Interconnect,
        sched: SchedConfig,
    ) -> Self {
        FleetBuilder::new(llm, hw)
            .devices(devices)
            .slots(slots)
            .disaggregated(prefill_frac)
            .interconnect(interconnect)
            .sched(sched)
            .build()
    }

    /// Override one device's resident-KV budget (heterogeneous fleets:
    /// e.g. a decode pool mixing large- and small-memory devices).
    pub fn set_kv_capacity(&mut self, dev: usize, cap: Option<u64>) {
        self.devices[dev].set_kv_capacity(cap);
    }

    /// Attach per-event energy attribution to every device — and, with a
    /// [`ThermalConfig`], a live per-package TDP throttle. Call before
    /// [`Fleet::replay`]. Without a thermal cap the replay's latency
    /// results stay bit-identical to the untracked fleet: the energy
    /// charged per event is the energy half of the same joint
    /// [`PhaseCost`](crate::sim::device::PhaseCost) that advances the
    /// clock, so tracking adds no `simulate_graph` walks.
    pub fn enable_power(&mut self, hw: &HwConfig, thermal: Option<ThermalConfig>) {
        for d in &mut self.devices {
            d.enable_power(hw, thermal.clone());
        }
    }

    /// Attach a request-lifecycle span recorder ([`crate::obs`]) to every
    /// device and start collecting KV-transfer spans for the trace's
    /// interconnect track. Pure observation: recording copies the same
    /// `f64`s that advance the clocks, so an instrumented replay is
    /// bit-identical to an untracked one. Call before [`Fleet::replay`];
    /// export with [`Fleet::chrome_trace`] afterwards.
    pub fn enable_obs(&mut self) {
        for d in &mut self.devices {
            d.enable_obs();
        }
        self.obs_kv = Some(Vec::new());
        self.obs_kv_cap = usize::MAX;
    }

    /// [`enable_obs`](Self::enable_obs) with a retention cap per
    /// recorder (and on the KV-span log), mirroring
    /// [`ServeOptions::streaming`]: a monitored million-request stream
    /// keeps flat memory while busy totals stay exact.
    pub fn enable_obs_capped(&mut self, cap: usize) {
        for d in &mut self.devices {
            d.enable_obs_capped(cap);
        }
        self.obs_kv = Some(Vec::new());
        self.obs_kv_cap = cap;
    }

    /// Pin every device to the same per-phase DVFS configuration (static
    /// operating points, optionally the thermal stepped governor — the
    /// governor engages only on power-tracked devices with a TDP cap).
    pub fn set_dvfs(&mut self, dvfs: DvfsConfig) {
        for d in &mut self.devices {
            d.set_dvfs(dvfs.clone());
        }
    }

    /// Total `simulate_graph` walks performed by the fleet's cost
    /// oracles (the one-walk-per-point guarantee's observable).
    pub fn cost_walks(&self) -> u64 {
        self.devices.iter().map(|d| d.cost_walks()).sum()
    }

    /// Total cost-oracle lookups served from memo tables without a walk
    /// (the other half of the one-walk-per-point guarantee).
    pub fn cost_memo_hits(&self) -> u64 {
        self.devices.iter().map(|d| d.cost_memo_hits()).sum()
    }

    /// Decode-side load of a device as a router should see it: queued +
    /// active work plus decode assignments still in prefill or transfer.
    pub fn decode_load(&self, dev: usize) -> usize {
        self.devices[dev].load() + self.pending_decode[dev]
    }

    /// Decode-side KV headroom of a device as a router should see it:
    /// the device's uncommitted budget minus the estimated KV of
    /// assignments still in prefill or transfer (`u64::MAX`-ish when the
    /// budget is unlimited).
    pub fn decode_kv_headroom(&self, dev: usize) -> u64 {
        self.devices[dev].kv_headroom().saturating_sub(self.pending_kv[dev])
    }

    /// Outbound KV parked on a prefill device (queued + streaming handoff
    /// prefills): work that will land in the decode pool once it
    /// completes. A capacity-aware router reads this before adding to a
    /// device's handoff backlog while the decode pool is under pressure.
    pub fn prefill_handoff_backlog(&self, dev: usize) -> u64 {
        self.devices[dev].handoff_backlog_bytes()
    }

    /// Estimated lifetime KV bytes of a request once fully decoded. The
    /// `max(1)` mirrors the decode continuation's final context
    /// (`ctx + remaining + 1`, with `remaining = l_out - 1` floored at
    /// zero), keeping the routing-time credit and the delivery-time debit
    /// of `pending_kv` exactly symmetric even for `l_out == 0` requests.
    pub fn kv_estimate(&self, req: &TraceRequest) -> u64 {
        (req.l_in + req.l_out.max(1)) as u64 * self.llm.kv_bytes_per_token()
    }

    /// Serve a materialized trace through the fleet under `router`.
    /// Consumes the fleet's working state; call once per constructed
    /// fleet. A thin wrapper over [`Fleet::serve`] with a slice-backed
    /// source and unbounded retention — bit-identical to the historical
    /// replay loop (fingerprint-pinned in tests).
    pub fn replay(&mut self, trace: &[TraceRequest], router: &mut dyn Router) -> FleetResult {
        let mut source = SliceSource::new(trace);
        let r = self.serve(&mut source, router, ServeOptions::exact());
        debug_assert_eq!(r.requests, trace.len(), "requests conserved");
        r
    }

    /// Serve a streaming workload through the fleet under `router`:
    /// arrivals are pulled from `source` one at a time (never
    /// materialized), and completions fold into online statistics as
    /// devices finish them, so memory stays flat in the request count
    /// when `opts` caps retention. Event order — and therefore every
    /// timing result — is identical to the historical slice replay:
    /// ties resolve arrival first, then KV handoff, then the earliest
    /// device cycle.
    pub fn serve(
        &mut self,
        source: &mut dyn WorkloadSource,
        router: &mut dyn Router,
        opts: ServeOptions,
    ) -> FleetResult {
        self.serve_inner(source, router, opts, None)
    }

    /// [`serve`](Self::serve) with windowed telemetry: `series` is fed
    /// arrivals, completions, and gauge samples at window boundaries as
    /// the stream plays out, then finalized at the makespan. Monitoring
    /// is pure observation — it copies the same `f64`s that advance the
    /// clocks — so the returned result is bit-identical to an
    /// unmonitored [`serve`](Self::serve) (fingerprint-pinned in
    /// `rust/tests/monitor_plane.rs`).
    pub fn serve_monitored(
        &mut self,
        source: &mut dyn WorkloadSource,
        router: &mut dyn Router,
        opts: ServeOptions,
        series: &mut WindowSeries,
    ) -> FleetResult {
        self.serve_inner(source, router, opts, Some(series))
    }

    /// [`replay`](Self::replay) with windowed telemetry (exact
    /// retention) — the `halo trace --timeseries` path.
    pub fn replay_monitored(
        &mut self,
        trace: &[TraceRequest],
        router: &mut dyn Router,
        series: &mut WindowSeries,
    ) -> FleetResult {
        let mut source = SliceSource::new(trace);
        let r = self.serve_inner(&mut source, router, ServeOptions::exact(), Some(series));
        debug_assert_eq!(r.requests, trace.len(), "requests conserved");
        r
    }

    /// Fleet-wide gauge snapshot at the current simulated instant.
    fn gauge_sample(&self) -> GaugeSample {
        GaugeSample::from_devices(self.devices.iter().map(Device::telemetry))
    }

    fn serve_inner(
        &mut self,
        source: &mut dyn WorkloadSource,
        router: &mut dyn Router,
        opts: ServeOptions,
        mut series: Option<&mut WindowSeries>,
    ) -> FleetResult {
        let mut sink = ServeSink::new(opts.retain_cap, self.devices.len());
        let mut next_req = source.next();
        let mut inflight: Vec<InFlight> = Vec::new();
        // dirty-min caches over the per-event scans: each event touches
        // exactly one device, so only that device's next-action time is
        // recomputed, and the in-flight min-ready folds incrementally on
        // push (a delivery rebuilds it). Bit-identical to the full
        // rescans — pinned by the reference-loop replay test on every
        // `Mix` preset.
        let mut dev_next: Vec<Option<f64>> =
            self.devices.iter().map(Device::next_action_time).collect();
        let mut hand_min = f64::INFINITY;
        loop {
            // earliest actionable device
            let mut best: Option<(f64, usize)> = None;
            for (d, t) in self.devices.iter().zip(dev_next.iter()) {
                if let Some(t) = *t {
                    if best.is_none_or(|(bt, _)| t < bt) {
                        best = Some((t, d.id));
                    }
                }
            }
            let t_dev = best.map_or(f64::INFINITY, |(t, _)| t);
            let t_arr = next_req.as_ref().map_or(f64::INFINITY, |r| r.arrival);
            let t_hand = hand_min;
            debug_assert_eq!(
                t_hand.to_bits(),
                inflight.iter().map(|h| h.ready).fold(f64::INFINITY, f64::min).to_bits(),
                "stale in-flight min-ready cache"
            );

            // window roll before dispatch: when the next event crosses a
            // window boundary, close windows with gauges read *before*
            // the event executes (pure reads — nothing feeds back)
            if let Some(s) = series.as_deref_mut() {
                let t_next = t_arr.min(t_hand).min(t_dev);
                if t_next.is_finite() && s.needs_roll(t_next) {
                    let sample = self.gauge_sample();
                    s.roll(t_next, &sample);
                }
            }

            if t_arr.is_finite() && t_arr <= t_dev && t_arr <= t_hand {
                // route the next arrival (ties resolve arrival-first, the
                // single-device replay's "pull arrivals up to now" rule)
                let req = next_req.take().unwrap();
                if let Some(s) = series.as_deref_mut() {
                    s.observe_arrival(req.arrival);
                }
                let route = router.route(self, &req);
                let tag = ReqTag::of(&req);
                if route.prefill == route.decode {
                    self.devices[route.prefill].push_tagged(DeviceJob::full(&req), tag);
                } else {
                    let est = self.kv_estimate(&req);
                    self.pending_decode[route.decode] += 1;
                    self.pending_kv[route.decode] += est;
                    self.devices[route.prefill].push_tagged(
                        DeviceJob::PrefillOnly {
                            arrival: req.arrival,
                            ready: req.arrival,
                            l_in: req.l_in,
                            l_out: req.l_out,
                            decode_dev: route.decode,
                        },
                        tag,
                    );
                }
                dev_next[route.prefill] = self.devices[route.prefill].next_action_time();
                next_req = source.next();
            } else if t_hand.is_finite() && t_hand <= t_dev {
                // deliver the earliest completed KV transfer
                let i = inflight
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.ready.partial_cmp(&b.1.ready).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                let h = inflight.swap_remove(i);
                hand_min = inflight.iter().map(|h| h.ready).fold(f64::INFINITY, f64::min);
                self.pending_decode[h.dev] -= 1;
                // exact reverse of kv_estimate:
                // l_in + max(l_out, 1) == ctx + remaining + 1
                let est = (h.ctx + h.remaining + 1) as u64 * self.llm.kv_bytes_per_token();
                self.pending_kv[h.dev] = self.pending_kv[h.dev].saturating_sub(est);
                self.devices[h.dev].push_tagged(
                    DeviceJob::DecodeOnly {
                        arrival: h.arrival,
                        ready: h.ready,
                        first_token_at: h.first_token_at,
                        ctx: h.ctx,
                        remaining: h.remaining,
                    },
                    h.tag,
                );
                dev_next[h.dev] = self.devices[h.dev].next_action_time();
            } else if let Some((_, id)) = best {
                for done in self.devices[id].step_cycle() {
                    let bytes = kv_transfer_bytes(&self.llm, done.l_in);
                    self.kv_bytes += bytes;
                    self.transfers += 1;
                    self.kv_energy_j += self.interconnect.transfer_energy(bytes);
                    let t_xfer = self.interconnect.transfer_time(bytes);
                    if let Some(kv) = &mut self.obs_kv {
                        if kv.len() < self.obs_kv_cap {
                            kv.push(Span {
                                kind: SpanKind::KvTransfer,
                                start: done.done_at,
                                dur: t_xfer,
                                arrival: done.arrival,
                                batch: 1,
                            });
                        }
                    }
                    inflight.push(InFlight {
                        ready: done.done_at + t_xfer,
                        dev: done.decode_dev,
                        arrival: done.arrival,
                        first_token_at: done.done_at,
                        ctx: done.l_in,
                        remaining: done.l_out.saturating_sub(1),
                        tag: done.tag,
                    });
                    hand_min = hand_min.min(done.done_at + t_xfer);
                }
                dev_next[id] = self.devices[id].next_action_time();
                // fold completions as they happen so the retained window
                // and the histograms stay current without re-scanning
                if !self.devices[id].served.is_empty() {
                    for r in std::mem::take(&mut self.devices[id].served) {
                        if let Some(s) = series.as_deref_mut() {
                            s.observe_completion(r.arrival + r.e2e, r.ttft, r.e2e, r.tokens);
                        }
                        sink.fold(id, r);
                    }
                }
            } else {
                break;
            }
        }
        if let Some(s) = series.as_deref_mut() {
            // drain any completions still parked on devices (device
            // order — collect_streamed's own fold order) so the series
            // sees the full population, then close it at the makespan
            for i in 0..self.devices.len() {
                if !self.devices[i].served.is_empty() {
                    for r in std::mem::take(&mut self.devices[i].served) {
                        s.observe_completion(r.arrival + r.e2e, r.ttft, r.e2e, r.tokens);
                        sink.fold(i, r);
                    }
                }
            }
            let makespan = self.devices.iter().map(|d| d.now()).fold(0.0, f64::max);
            let sample = self.gauge_sample();
            s.finalize(makespan, &sample);
        }
        self.collect_streamed(sink)
    }

    fn collect_streamed(&mut self, mut sink: ServeSink) -> FleetResult {
        // fold any completions still parked on devices (none after
        // `serve`, everything after a raw device-driven loop) in device
        // order — the legacy `served` ordering
        for (i, d) in self.devices.iter_mut().enumerate() {
            if !d.served.is_empty() {
                for r in std::mem::take(&mut d.served) {
                    sink.fold(i, r);
                }
            }
        }
        let makespan = self.devices.iter().map(|d| d.now()).fold(0.0, f64::max);
        let mut per_device = Vec::new();
        let mut fleet_energy = EnergyBreakdown::default();
        let mut power_tracked = false;
        let mut peak_power_w = 0.0f64;
        let mut throttled_s = 0.0;
        for d in &self.devices {
            // per-device energy: every busy event's dynamic + static
            // share, plus the cold static floor over the idle remainder
            // of the fleet makespan
            let (energy, peak_w, dev_throttled) = match d.power() {
                Some(pw) => {
                    power_tracked = true;
                    let mut e = pw.energy;
                    e.e_static += pw.static_power(false) * (makespan - d.busy).max(0.0);
                    (e, pw.peak_w, pw.throttled_s)
                }
                None => (EnergyBreakdown::default(), 0.0, 0.0),
            };
            fleet_energy.add(&energy);
            peak_power_w = peak_power_w.max(peak_w);
            throttled_s += dev_throttled;
            per_device.push(DeviceSummary {
                id: d.id,
                mapping: d.mapping,
                role: role_of(d.id, &self.prefill_pool, &self.decode_pool),
                prefills: d.prefills,
                decode_steps: d.decode_steps,
                served: sink.dev_seq[d.id] as usize,
                busy: d.busy,
                // when this device last executed work — not its clock,
                // which idle-jumps can push past the final activity
                last_active: d.last_active,
                evictions: d.evictions,
                recompute_tokens: d.recompute_tokens,
                kv_peak: d.kv_peak,
                energy,
                peak_power_w: peak_w,
                throttled_s: dev_throttled,
            });
        }
        fleet_energy.e_link += self.kv_energy_j;
        let ServeSink { mut retained, ttft_hist, e2e_hist, requests, tokens, .. } = sink;
        // (device, per-device seq) order == the legacy device-major
        // append order, regardless of global completion interleaving
        retained.sort_by_key(|&(dev, seq, _)| (dev, seq));
        let complete = retained.len() == requests;
        let served: Vec<ServedRequest> = retained.into_iter().map(|(_, _, r)| r).collect();
        // sorted once here, with util::percentile's exact comparator, so
        // the percentile accessors stay bit-compatible with the legacy
        // clone-and-sort helpers without re-sorting per call; skipped
        // when retention was capped (the histograms answer instead)
        let (ttft_sorted, e2e_sorted) = if complete {
            let mut t: Vec<f64> = served.iter().map(|s| s.ttft).collect();
            t.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut e: Vec<f64> = served.iter().map(|s| s.e2e).collect();
            e.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (t, e)
        } else {
            (Vec::new(), Vec::new())
        };
        FleetResult {
            served,
            ttft_sorted,
            e2e_sorted,
            requests,
            tokens,
            ttft_hist,
            e2e_hist,
            complete,
            makespan,
            decode_steps: per_device.iter().map(|s| s.decode_steps).sum(),
            prefills: per_device.iter().map(|s| s.prefills).sum(),
            kv_bytes: self.kv_bytes,
            transfers: self.transfers,
            kv_transfer_energy_j: self.kv_energy_j,
            evictions: per_device.iter().map(|s| s.evictions).sum(),
            recompute_tokens: per_device.iter().map(|s| s.recompute_tokens).sum(),
            power_tracked,
            energy: fleet_energy,
            peak_power_w,
            throttled_s,
            per_device,
        }
    }

    /// Export the recorded replay as a Chrome-trace/Perfetto JSON
    /// document: one track per device plus an interconnect track for KV
    /// handoffs. `None` unless [`Fleet::enable_obs`] was called before
    /// the replay. Event order is deterministic, so the same seed always
    /// produces a byte-identical trace.
    pub fn chrome_trace(&self) -> Option<Json> {
        let mut tracks = Vec::with_capacity(self.devices.len());
        for d in &self.devices {
            tracks.push(Track {
                tid: d.id,
                label: format!(
                    "dev{} {} ({})",
                    d.id,
                    d.mapping.name(),
                    role_of(d.id, &self.prefill_pool, &self.decode_pool)
                ),
                rec: d.obs()?,
            });
        }
        let kv = self.obs_kv.as_deref().unwrap_or(&[]);
        Some(obs::chrome_trace(&tracks, kv, "interconnect"))
    }

    /// Recorded KV-transfer spans (`None` unless obs is enabled).
    pub fn kv_spans(&self) -> Option<&[Span]> {
        self.obs_kv.as_deref()
    }

    /// Per-device span recorders (`None` unless obs is enabled) — the
    /// latency-attribution plane's input alongside [`Fleet::kv_spans`].
    pub fn recorders(&self) -> Option<Vec<&Recorder>> {
        self.devices.iter().map(Device::obs).collect()
    }

    /// Fleet-wide observability drop counters `(spans, events,
    /// decode-batch records)` summed across device recorders, or `None`
    /// unless obs is enabled. `(0, 0, 0)` means the recorded trace is
    /// lossless; anything else marks downstream span-derived analyses
    /// (attribution, critical paths) as working from partial evidence.
    pub fn obs_dropped(&self) -> Option<(u64, u64, u64)> {
        let recs = self.recorders()?;
        let mut total = (0u64, 0u64, 0u64);
        for r in recs {
            let (s, e) = r.dropped();
            total.0 += s;
            total.1 += e;
            total.2 += r.dropped_batches();
        }
        Some(total)
    }
}

fn role_of(id: usize, prefill: &[usize], decode: &[usize]) -> &'static str {
    match (prefill.contains(&id), decode.contains(&id)) {
        (true, true) => "unified",
        (true, false) => "prefill",
        (false, true) => "decode",
        (false, false) => "idle",
    }
}

/// Per-device share of a fleet replay.
#[derive(Debug, Clone)]
pub struct DeviceSummary {
    pub id: usize,
    pub mapping: MappingKind,
    pub role: &'static str,
    pub prefills: u64,
    pub decode_steps: u64,
    pub served: usize,
    pub busy: f64,
    /// Clock value at this device's last executed work (`<= makespan`).
    pub last_active: f64,
    /// Sequences evicted here under KV pressure.
    pub evictions: u64,
    /// Cached tokens re-prefilled here because of evictions.
    pub recompute_tokens: u64,
    /// High-water mark of resident KV bytes on this device.
    pub kv_peak: u64,
    /// Attributed energy over the whole makespan (zero when power
    /// tracking is off).
    pub energy: EnergyBreakdown,
    /// Highest mean event power on this device, W.
    pub peak_power_w: f64,
    /// Extra service time added here by thermal throttling, s.
    pub throttled_s: f64,
}

impl DeviceSummary {
    /// Busy fraction of the fleet makespan (per-device utilization).
    pub fn utilization(&self, makespan: f64) -> f64 {
        self.busy / makespan.max(1e-12)
    }

    /// Mean power over the makespan, W (zero when untracked).
    pub fn avg_power_w(&self, makespan: f64) -> f64 {
        self.energy.total() / makespan.max(1e-12)
    }
}

/// Aggregate results of a fleet replay or streamed serve.
#[derive(Debug, Clone)]
pub struct FleetResult {
    /// Completed requests in the legacy device-major order. The full
    /// population when `complete`; otherwise a retention-capped sample
    /// (see [`ServeOptions`]) — use `requests` for the true count.
    pub served: Vec<ServedRequest>,
    /// TTFTs of `served`, ascending — built once at collection so the
    /// percentile accessors are cheap reads instead of a clone-and-sort
    /// per call (DSE reads several per objective evaluation). Empty when
    /// retention was capped; the histograms answer instead.
    pub ttft_sorted: Vec<f64>,
    /// End-to-end latencies of `served`, ascending (see `ttft_sorted`).
    pub e2e_sorted: Vec<f64>,
    /// Exact number of requests served, independent of retention.
    pub requests: usize,
    /// Exact output tokens generated, independent of retention.
    pub tokens: u64,
    /// Streaming TTFT population (exact count/min/max/mean, ~±3%
    /// interior percentiles) — always recorded, capped or not.
    pub ttft_hist: LogHistogram,
    /// Streaming end-to-end latency population (see `ttft_hist`).
    pub e2e_hist: LogHistogram,
    /// Whether `served` holds every completed request (retention cap
    /// never hit) — when true the percentile accessors are exact.
    pub complete: bool,
    pub makespan: f64,
    pub decode_steps: u64,
    pub prefills: u64,
    pub kv_bytes: u64,
    pub transfers: u64,
    /// Joules spent moving KV caches across the interconnect (always
    /// counted; independent of device power tracking).
    pub kv_transfer_energy_j: f64,
    /// Fleet-wide sequences evicted under KV pressure.
    pub evictions: u64,
    /// Fleet-wide cached tokens re-prefilled because of evictions.
    pub recompute_tokens: u64,
    /// Whether any device attributed energy (see [`Fleet::enable_power`]).
    pub power_tracked: bool,
    /// Fleet-wide energy: per-device dynamic + static (busy and idle)
    /// plus interconnect KV-transfer energy in `e_link`.
    pub energy: EnergyBreakdown,
    /// Highest mean event power across the fleet's devices, W.
    pub peak_power_w: f64,
    /// Total extra service time added by thermal throttling, s.
    pub throttled_s: f64,
    pub per_device: Vec<DeviceSummary>,
}

impl FleetResult {
    /// TTFT at percentile `p`: off the cached sorted view when the
    /// result is `complete` (bit-compatible with
    /// `ttft_percentile(&self.served, p)`), off the streaming histogram
    /// when retention was capped. 0.0 when nothing was served.
    pub fn ttft_pct(&self, p: f64) -> f64 {
        if self.requests == 0 {
            0.0
        } else if self.complete {
            percentile_sorted(&self.ttft_sorted, p)
        } else {
            self.ttft_hist.percentile(p)
        }
    }
    /// End-to-end latency at percentile `p` (see [`FleetResult::ttft_pct`]).
    pub fn e2e_pct(&self, p: f64) -> f64 {
        if self.requests == 0 {
            0.0
        } else if self.complete {
            percentile_sorted(&self.e2e_sorted, p)
        } else {
            self.e2e_hist.percentile(p)
        }
    }
    pub fn ttft_p50(&self) -> f64 {
        self.ttft_pct(50.0)
    }
    pub fn ttft_p99(&self) -> f64 {
        self.ttft_pct(99.0)
    }
    pub fn e2e_p50(&self) -> f64 {
        self.e2e_pct(50.0)
    }
    pub fn e2e_p99(&self) -> f64 {
        self.e2e_pct(99.0)
    }
    pub fn throughput_rps(&self) -> f64 {
        served_rate(self.requests, self.makespan)
    }
    /// Mean device busy fraction over the fleet makespan.
    pub fn utilization(&self) -> f64 {
        let busy: f64 = self.per_device.iter().map(|d| d.busy).sum();
        busy / (self.per_device.len() as f64 * self.makespan.max(1e-12))
    }
    /// Total fleet energy over the makespan, J (0 when untracked).
    pub fn energy_j(&self) -> f64 {
        self.energy.total()
    }
    /// Fleet energy per generated token, J (`tokens` = the workload's
    /// total output tokens; [`FleetResult::tokens`] carries the exact
    /// count for streamed runs). 0.0 on a zero-token run — an empty or
    /// fully rejected replay must not push inf/NaN into DSE rankings or
    /// report tables.
    pub fn energy_per_token(&self, tokens: u64) -> f64 {
        if tokens == 0 {
            0.0
        } else {
            self.energy_j() / tokens as f64
        }
    }
    /// Mean fleet power over the makespan, W.
    pub fn avg_power_w(&self) -> f64 {
        self.energy_j() / self.makespan.max(1e-12)
    }

    /// Order-sensitive FNV-1a digest over every replay-deterministic
    /// field: counters, the makespan bits, and each retained record's
    /// timing + identity bits. Two results fingerprint equal iff the
    /// simulations were bit-identical — the pin used by the
    /// replay-vs-reference and shim-vs-builder equivalence tests.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: &mut u64, x: u64) {
            *h ^= x;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        mix(&mut h, self.requests as u64);
        mix(&mut h, self.tokens);
        mix(&mut h, self.decode_steps);
        mix(&mut h, self.prefills);
        mix(&mut h, self.kv_bytes);
        mix(&mut h, self.transfers);
        mix(&mut h, self.evictions);
        mix(&mut h, self.recompute_tokens);
        mix(&mut h, self.makespan.to_bits());
        for s in &self.served {
            mix(&mut h, s.arrival.to_bits());
            mix(&mut h, s.ttft.to_bits());
            mix(&mut h, s.e2e.to_bits());
            mix(&mut h, s.tenant as u64);
            mix(&mut h, s.session);
            mix(&mut h, s.tokens);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::{LeastLoaded, PhaseDisaggregated, RoundRobin};
    use crate::cluster::workload::Mix;
    use crate::sim::queueing::{poisson_trace, replay_trace};

    fn llm() -> LlmConfig {
        LlmConfig::llama2_7b()
    }

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    fn unified(devices: usize) -> Fleet {
        FleetBuilder::new(&llm(), &hw()).devices(devices).slots(4).build()
    }

    fn disaggregated(devices: usize, frac: f64) -> Fleet {
        FleetBuilder::new(&llm(), &hw()).devices(devices).slots(4).disaggregated(frac).build()
    }

    /// The pre-refactor replay loop, verbatim: peeks a materialized
    /// slice, leaves completions parked on the devices, and collects at
    /// the end. `Fleet::serve` must stay bit-identical to this.
    fn reference_replay(
        fleet: &mut Fleet,
        trace: &[TraceRequest],
        router: &mut dyn Router,
    ) -> FleetResult {
        let mut pending = trace.iter().peekable();
        let mut inflight: Vec<InFlight> = Vec::new();
        loop {
            let mut best: Option<(f64, usize)> = None;
            for d in &fleet.devices {
                if let Some(t) = d.next_action_time() {
                    if best.is_none_or(|(bt, _)| t < bt) {
                        best = Some((t, d.id));
                    }
                }
            }
            let t_dev = best.map_or(f64::INFINITY, |(t, _)| t);
            let t_arr = pending.peek().map_or(f64::INFINITY, |r| r.arrival);
            let t_hand = inflight.iter().map(|h| h.ready).fold(f64::INFINITY, f64::min);

            if t_arr.is_finite() && t_arr <= t_dev && t_arr <= t_hand {
                let req = pending.next().unwrap();
                let route = router.route(fleet, req);
                if route.prefill == route.decode {
                    fleet.devices[route.prefill].push_tagged(DeviceJob::full(req), ReqTag::of(req));
                } else {
                    let est = fleet.kv_estimate(req);
                    fleet.pending_decode[route.decode] += 1;
                    fleet.pending_kv[route.decode] += est;
                    fleet.devices[route.prefill].push_tagged(
                        DeviceJob::PrefillOnly {
                            arrival: req.arrival,
                            ready: req.arrival,
                            l_in: req.l_in,
                            l_out: req.l_out,
                            decode_dev: route.decode,
                        },
                        ReqTag::of(req),
                    );
                }
            } else if t_hand.is_finite() && t_hand <= t_dev {
                let i = inflight
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.ready.partial_cmp(&b.1.ready).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                let h = inflight.swap_remove(i);
                fleet.pending_decode[h.dev] -= 1;
                let est = (h.ctx + h.remaining + 1) as u64 * fleet.llm.kv_bytes_per_token();
                fleet.pending_kv[h.dev] = fleet.pending_kv[h.dev].saturating_sub(est);
                fleet.devices[h.dev].push_tagged(
                    DeviceJob::DecodeOnly {
                        arrival: h.arrival,
                        ready: h.ready,
                        first_token_at: h.first_token_at,
                        ctx: h.ctx,
                        remaining: h.remaining,
                    },
                    h.tag,
                );
            } else if let Some((_, id)) = best {
                for done in fleet.devices[id].step_cycle() {
                    let bytes = kv_transfer_bytes(&fleet.llm, done.l_in);
                    fleet.kv_bytes += bytes;
                    fleet.transfers += 1;
                    fleet.kv_energy_j += fleet.interconnect.transfer_energy(bytes);
                    let t_xfer = fleet.interconnect.transfer_time(bytes);
                    inflight.push(InFlight {
                        ready: done.done_at + t_xfer,
                        dev: done.decode_dev,
                        arrival: done.arrival,
                        first_token_at: done.done_at,
                        ctx: done.l_in,
                        remaining: done.l_out.saturating_sub(1),
                        tag: done.tag,
                    });
                }
            } else {
                break;
            }
        }
        let sink = ServeSink::new(usize::MAX, fleet.devices.len());
        fleet.collect_streamed(sink)
    }

    #[test]
    fn single_device_fleet_reproduces_replay_trace() {
        let tr = poisson_trace(21, 40, 4.0, (64, 1024), 32);
        let single = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        let mut fleet = unified(1);
        let r = fleet.replay(&tr, &mut RoundRobin::default());
        assert_eq!(r.served.len(), single.served.len());
        assert_eq!(r.decode_steps, single.decode_steps);
        assert!(
            (r.makespan - single.makespan).abs() < 1e-12,
            "{} vs {}",
            r.makespan,
            single.makespan
        );
        for (a, b) in r.served.iter().zip(&single.served) {
            assert_eq!(a.arrival, b.arrival);
            assert!((a.ttft - b.ttft).abs() < 1e-12);
            assert!((a.e2e - b.e2e).abs() < 1e-12);
        }
    }

    #[test]
    fn unified_fleet_conserves_requests_without_transfers() {
        let tr = poisson_trace(22, 60, 20.0, (64, 512), 16);
        let mut fleet = unified(4);
        let r = fleet.replay(&tr, &mut LeastLoaded);
        assert_eq!(r.served.len(), 60);
        assert_eq!(r.requests, 60);
        assert!(r.complete);
        assert_eq!(r.transfers, 0);
        assert_eq!(r.kv_bytes, 0);
        // least-loaded spreads work across every device
        assert!(r.per_device.iter().all(|d| d.served > 0), "{:?}", r.per_device);
        // the per-device served counts re-add to the fleet total
        let dev_sum: usize = r.per_device.iter().map(|d| d.served).sum();
        assert_eq!(dev_sum, r.requests);
    }

    #[test]
    fn disaggregated_fleet_transfers_every_kv_cache() {
        let tr = poisson_trace(23, 30, 10.0, (128, 512), 8);
        let mut fleet = disaggregated(4, 0.5);
        let r = fleet.replay(&tr, &mut PhaseDisaggregated);
        assert_eq!(r.served.len(), 30);
        assert_eq!(r.transfers, 30);
        let expect: u64 = tr.iter().map(|q| kv_transfer_bytes(&llm(), q.l_in)).sum();
        assert_eq!(r.kv_bytes, expect);
        // prefill devices never decode; decode devices never prefill
        for d in &r.per_device {
            match d.role {
                "prefill" => assert!(d.decode_steps == 0 && d.prefills > 0 && d.served == 0),
                "decode" => assert!(d.prefills == 0 && d.served > 0),
                other => panic!("unexpected role {other}"),
            }
        }
        for s in &r.served {
            assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
        }
    }

    #[test]
    fn heterogeneous_fleet_mixes_mappings_and_conserves() {
        let tr = poisson_trace(26, 40, 30.0, (64, 512), 16);
        let mappings = [MappingKind::Halo1, MappingKind::Halo2, MappingKind::Halo1];
        let mut fleet = FleetBuilder::new(&llm(), &hw()).heterogeneous(&mappings).slots(4).build();
        assert_eq!(fleet.devices[1].mapping, MappingKind::Halo2);
        let r = fleet.replay(&tr, &mut LeastLoaded);
        assert_eq!(r.served.len(), 40);
        assert_eq!(r.transfers, 0, "unified pools keep both phases local");
        assert!(r.per_device.iter().all(|d| d.role == "unified"));
        // the mapping column survives into the per-device summary
        let summary: Vec<MappingKind> = r.per_device.iter().map(|d| d.mapping).collect();
        assert_eq!(summary, mappings);
    }

    #[test]
    fn kv_transfer_energy_counted_per_byte() {
        let tr = poisson_trace(27, 20, 10.0, (128, 512), 8);
        let link = Interconnect::board();
        let mut fleet = FleetBuilder::new(&llm(), &hw())
            .devices(4)
            .slots(4)
            .disaggregated(0.5)
            .interconnect(link.clone())
            .build();
        let r = fleet.replay(&tr, &mut PhaseDisaggregated);
        assert_eq!(r.transfers, 20);
        let want = link.transfer_energy(r.kv_bytes);
        assert!((r.kv_transfer_energy_j - want).abs() < 1e-9 * want.max(1.0));
        // counted even without device power tracking, and folded into the
        // fleet link-energy component
        assert!(!r.power_tracked);
        assert_eq!(r.energy.e_link, r.kv_transfer_energy_j);
        assert_eq!(r.energy.dynamic(), 0.0);
    }

    #[test]
    fn powered_fleet_attributes_energy_to_every_active_device() {
        let tr = poisson_trace(28, 40, 20.0, (64, 512), 16);
        let mut fleet =
            FleetBuilder::new(&llm(), &hw()).devices(2).slots(4).power(None).build();
        let r = fleet.replay(&tr, &mut LeastLoaded);
        assert!(r.power_tracked);
        assert!(r.energy_j() > 0.0);
        assert!(r.peak_power_w > 0.0);
        assert_eq!(r.throttled_s, 0.0, "no TDP cap, no throttling");
        let device_sum: f64 = r.per_device.iter().map(|d| d.energy.total()).sum();
        assert!((r.energy_j() - device_sum).abs() < 1e-9 * device_sum, "unified: no link energy");
        for d in &r.per_device {
            assert!(d.served == 0 || d.energy.dynamic() > 0.0, "device {}", d.id);
            // static idle floor covers the makespan remainder
            assert!(d.energy.e_static > 0.0);
            assert!(d.utilization(r.makespan) <= 1.0 + 1e-12);
            assert!(d.avg_power_w(r.makespan) > 0.0);
        }
        let tokens: u64 = tr.iter().map(|q| q.l_out as u64).sum();
        assert_eq!(r.tokens, tokens, "streamed token counter matches the trace");
        assert!(r.energy_per_token(tokens) > 0.0);
        assert!((r.avg_power_w() - r.energy_j() / r.makespan).abs() < 1e-9);
    }

    #[test]
    fn fleet_dvfs_slows_replay_and_stays_latency_identical_when_tracked() {
        // saturating burst: makespan is busy-time-driven, so the eco
        // point's 1/f stretch shows up whole
        let tr = poisson_trace(29, 30, 1.0e6, (64, 512), 16);
        let hw = hw();
        let eco = hw.power.dvfs_points.len() - 1;
        let run = |idx: usize, power: bool| {
            let mut b = FleetBuilder::new(&llm(), &hw).devices(2).slots(4);
            if power {
                b = b.power(None);
            }
            let mut fleet = b.dvfs(DvfsConfig::with_indices(&hw.power, idx, idx)).build();
            let r = fleet.replay(&tr, &mut LeastLoaded);
            (r, fleet.cost_walks())
        };
        let (nominal, _) = run(0, false);
        let (plain_eco, plain_walks) = run(eco, false);
        let (tracked_eco, tracked_walks) = run(eco, true);
        // a lower operating point costs real wall-clock time
        assert!(plain_eco.makespan > nominal.makespan * 1.05);
        // power tracking observes without perturbing, at any point
        assert_eq!(plain_eco.makespan.to_bits(), tracked_eco.makespan.to_bits());
        assert_eq!(plain_walks, tracked_walks, "tracking must not add graph walks");
        assert!(tracked_eco.power_tracked && tracked_eco.energy_j() > 0.0);
    }

    #[test]
    fn cached_percentiles_match_legacy_helpers_bitwise() {
        use crate::sim::queueing::{e2e_percentile, ttft_percentile};
        let tr = poisson_trace(31, 50, 15.0, (64, 768), 16);
        let mut fleet = unified(2);
        let r = fleet.replay(&tr, &mut LeastLoaded);
        for p in [0.0, 17.0, 50.0, 83.0, 99.0, 100.0] {
            assert_eq!(r.ttft_pct(p).to_bits(), ttft_percentile(&r.served, p).to_bits());
            assert_eq!(r.e2e_pct(p).to_bits(), e2e_percentile(&r.served, p).to_bits());
        }
    }

    #[test]
    fn slow_link_delays_e2e_not_ttft() {
        let tr = poisson_trace(24, 20, 5.0, (256, 1024), 8);
        let run = |link: Interconnect| {
            let mut fleet = FleetBuilder::new(&llm(), &hw())
                .devices(4)
                .slots(4)
                .disaggregated(0.5)
                .interconnect(link)
                .build();
            fleet.replay(&tr, &mut PhaseDisaggregated)
        };
        let fast = run(Interconnect::board());
        let slow = run(Interconnect::wan());
        // TTFT is earned at prefill completion; the link only delays decode
        assert!((fast.ttft_p50() - slow.ttft_p50()).abs() < 1e-9);
        assert!(slow.e2e_p50() > fast.e2e_p50() + 0.05, "{} vs {}", slow.e2e_p50(), fast.e2e_p50());
    }

    #[test]
    fn replay_is_bit_identical_to_the_reference_loop_on_all_mixes() {
        for (i, mix) in Mix::all().into_iter().enumerate() {
            let tr = mix.trace(40 + i as u64, 60, 12.0);
            // unified fleet under least-loaded routing
            let a = unified(3).replay(&tr, &mut LeastLoaded);
            let b = reference_replay(&mut unified(3), &tr, &mut LeastLoaded);
            assert_eq!(a.fingerprint(), b.fingerprint(), "unified, mix {}", mix.name());
            // disaggregated fleet with real KV handoffs in flight
            let c = disaggregated(4, 0.5).replay(&tr, &mut PhaseDisaggregated);
            let d = reference_replay(&mut disaggregated(4, 0.5), &tr, &mut PhaseDisaggregated);
            assert_eq!(c.fingerprint(), d.fingerprint(), "disaggregated, mix {}", mix.name());
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructor_shims_match_builder_bit_for_bit() {
        let tr = Mix::Chat.trace(51, 40, 10.0);
        let fp =
            |mut fleet: Fleet, router: &mut dyn Router| fleet.replay(&tr, router).fingerprint();
        let (l, h) = (llm(), hw());
        let link = Interconnect::board;

        let shim = fp(Fleet::unified(&l, &h, 2, 4, link()), &mut LeastLoaded);
        let built = fp(
            FleetBuilder::new(&l, &h).devices(2).slots(4).interconnect(link()).build(),
            &mut LeastLoaded,
        );
        assert_eq!(shim, built, "unified");

        let sched = SchedConfig::default();
        let shim = fp(Fleet::unified_with(&l, &h, 2, 4, link(), sched.clone()), &mut LeastLoaded);
        let built = fp(
            FleetBuilder::new(&l, &h)
                .devices(2)
                .slots(4)
                .interconnect(link())
                .sched(sched.clone())
                .build(),
            &mut LeastLoaded,
        );
        assert_eq!(shim, built, "unified_with");

        let shim = fp(Fleet::disaggregated(&l, &h, 4, 4, 0.5, link()), &mut PhaseDisaggregated);
        let built = fp(
            FleetBuilder::new(&l, &h)
                .devices(4)
                .slots(4)
                .disaggregated(0.5)
                .interconnect(link())
                .build(),
            &mut PhaseDisaggregated,
        );
        assert_eq!(shim, built, "disaggregated");

        let shim = fp(
            Fleet::disaggregated_with(&l, &h, 4, 4, 0.5, link(), sched.clone()),
            &mut PhaseDisaggregated,
        );
        let built = fp(
            FleetBuilder::new(&l, &h)
                .devices(4)
                .slots(4)
                .disaggregated(0.5)
                .interconnect(link())
                .sched(sched.clone())
                .build(),
            &mut PhaseDisaggregated,
        );
        assert_eq!(shim, built, "disaggregated_with");

        let mappings = [MappingKind::Halo1, MappingKind::Halo2];
        let shim = fp(
            Fleet::heterogeneous_with(&l, &h, &mappings, 4, link(), sched.clone()),
            &mut LeastLoaded,
        );
        let built = fp(
            FleetBuilder::new(&l, &h)
                .heterogeneous(&mappings)
                .slots(4)
                .interconnect(link())
                .sched(sched)
                .build(),
            &mut LeastLoaded,
        );
        assert_eq!(shim, built, "heterogeneous_with");
    }

    #[test]
    fn streaming_retention_cap_keeps_counters_exact() {
        let tr = Mix::Chat.trace(52, 80, 20.0);
        let exact = unified(2).replay(&tr, &mut LeastLoaded);
        let mut fleet = unified(2);
        let mut src = SliceSource::new(&tr);
        let capped = fleet.serve(&mut src, &mut LeastLoaded, ServeOptions::streaming(8));
        // counters, timing, and histograms are exact regardless of the cap
        assert_eq!(capped.requests, 80);
        assert_eq!(capped.served.len(), 8, "only the cap survives as raw records");
        assert!(!capped.complete && exact.complete);
        assert_eq!(capped.makespan.to_bits(), exact.makespan.to_bits());
        assert_eq!(capped.decode_steps, exact.decode_steps);
        assert_eq!(capped.tokens, exact.tokens);
        assert_eq!(capped.ttft_hist, exact.ttft_hist);
        assert_eq!(capped.e2e_hist, exact.e2e_hist);
        assert_eq!(capped.throughput_rps().to_bits(), exact.throughput_rps().to_bits());
        // histogram percentiles stay inside the exact envelope and near
        // the exact interior percentiles (log-bucket quantization only)
        for p in [50.0, 90.0, 99.0] {
            let v = capped.ttft_pct(p);
            assert!(
                v >= exact.ttft_pct(0.0) && v <= exact.ttft_pct(100.0),
                "p{p}: {v} outside the exact envelope"
            );
            let rel = (v - exact.ttft_pct(p)).abs() / exact.ttft_pct(p).max(1e-12);
            assert!(rel < 0.25, "p{p}: hist {v} vs exact {} (rel {rel})", exact.ttft_pct(p));
        }
    }

    #[test]
    fn empty_source_yields_finite_zero_result() {
        let mut fleet = unified(2);
        let r = fleet.serve(
            &mut SliceSource::new(&[]),
            &mut LeastLoaded,
            ServeOptions::default(),
        );
        assert_eq!(r.requests, 0);
        assert!(r.complete);
        assert_eq!(r.ttft_pct(50.0), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert!(r.makespan.is_finite());
    }
}

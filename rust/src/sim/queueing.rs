//! Discrete-event serving simulation on top of the analytical model.
//!
//! The paper motivates HALO with *latency-sensitive interactive
//! applications* (chatbots, assistants) but evaluates isolated requests.
//! This module closes that gap: it replays a Poisson arrival trace
//! against a device whose prefill/decode step times come from the
//! analytical simulator, with the same slot-based continuous batching
//! policy the functional coordinator implements — yielding TTFT/latency
//! distributions and saturation points per mapping.
//!
//! Model: a single HALO device with `slots` decode slots. Prefills are
//! serialized on the accelerator (prefill occupies the whole device —
//! both CiD and CiM mappings are throughput-limited by the same shared
//! substrate); decode steps process all active slots in one batched step
//! whose duration comes from `simulate_phase` at the batch's mean context.
//!
//! The device state machine itself lives in [`sim::device`](super::device)
//! so the `cluster` fleet simulator and this single-device replay share
//! one core; this module keeps the trace generators and the single-device
//! entry points. [`replay_trace`] runs the legacy configuration
//! (serialized prefill, FIFO, unlimited KV); [`replay_trace_with`] takes
//! an explicit [`SchedConfig`] for chunked prefill, priority admission,
//! and KV-capacity studies.

use super::device::{Device, DeviceJob, ReqTag, SchedConfig};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::util::{percentile, Rng};

/// One request in the trace. `tenant` tags the submitting tenant for
/// multi-tenant studies (0 for single-tenant traces) and `session` ties
/// the turns of a multi-turn conversation together (0 for standalone
/// requests; see [`cluster::traffic`](crate::cluster::traffic)). Both
/// identities also travel on the [`ServedRequest`], so streaming
/// consumers aggregate without retaining the trace; the legacy
/// join-by-arrival-time path still works because arrivals are strictly
/// increasing.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub arrival: f64,
    pub l_in: usize,
    pub l_out: usize,
    pub tenant: usize,
    pub session: u64,
}

/// Generate a Poisson-arrival trace whose per-request lengths come from
/// `sample` (drawing from the same RNG keeps traces reproducible).
pub fn trace_with(
    seed: u64,
    n: usize,
    rate_per_s: f64,
    sample: impl FnMut(&mut Rng) -> (usize, usize),
) -> Vec<TraceRequest> {
    trace_with_tenants(seed, n, rate_per_s, 1, sample)
}

/// [`trace_with`] tagging each request with a uniformly drawn tenant in
/// `[0, tenants)`. With `tenants <= 1` no tenant draw is made, so the
/// trace is bit-identical to the single-tenant generator's.
pub fn trace_with_tenants(
    seed: u64,
    n: usize,
    rate_per_s: f64,
    tenants: usize,
    mut sample: impl FnMut(&mut Rng) -> (usize, usize),
) -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exp(rate_per_s);
            let (l_in, l_out) = sample(&mut rng);
            let tenant = if tenants > 1 { rng.below(tenants as u64) as usize } else { 0 };
            TraceRequest { arrival: t, l_in, l_out, tenant, session: 0 }
        })
        .collect()
}

/// Log-uniform integer in `[lo, hi]` — the prompt-length law shared by
/// [`poisson_trace`] and the cluster workload mixes.
pub fn log_uniform(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    let u = rng.f64();
    let v = (lo as f64 * (hi as f64 / lo as f64).powf(u)).round() as usize;
    v.max(1)
}

/// Generate a Poisson-arrival trace with log-uniform prompt lengths.
pub fn poisson_trace(
    seed: u64,
    n: usize,
    rate_per_s: f64,
    l_in_range: (usize, usize),
    l_out: usize,
) -> Vec<TraceRequest> {
    let (lo, hi) = l_in_range;
    trace_with(seed, n, rate_per_s, |rng| (log_uniform(rng, lo, hi), l_out))
}

/// Completed-request record. Carries the request's identity (`tenant`,
/// `session`) and its generated token count so streaming consumers can
/// aggregate per tenant/session without joining back to a materialized
/// trace.
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub arrival: f64,
    pub ttft: f64,
    pub e2e: f64,
    pub tenant: usize,
    pub session: u64,
    /// Output tokens generated (the request's `l_out`).
    pub tokens: u64,
}

/// p-th TTFT percentile over a served set (shared by the single-device
/// [`QueueingResult`] and the fleet result type). 0.0 on an empty set —
/// an empty or fully rejected trace must yield finite zero metrics, not
/// a panic or NaN poisoning downstream `total_cmp` rankings.
pub fn ttft_percentile(served: &[ServedRequest], p: f64) -> f64 {
    if served.is_empty() {
        return 0.0;
    }
    percentile(&served.iter().map(|r| r.ttft).collect::<Vec<_>>(), p)
}

/// p-th end-to-end-latency percentile over a served set (0.0 on empty).
pub fn e2e_percentile(served: &[ServedRequest], p: f64) -> f64 {
    if served.is_empty() {
        return 0.0;
    }
    percentile(&served.iter().map(|r| r.e2e).collect::<Vec<_>>(), p)
}

/// Served requests per second over a makespan.
pub fn served_rate(n_served: usize, makespan: f64) -> f64 {
    n_served as f64 / makespan.max(1e-12)
}

/// Aggregate results of a trace replay.
#[derive(Debug, Clone)]
pub struct QueueingResult {
    pub served: Vec<ServedRequest>,
    pub makespan: f64,
    pub decode_steps: u64,
    /// Sequences evicted under KV pressure (0 with an unlimited budget).
    pub evictions: u64,
    /// Cached tokens re-prefilled because of evictions.
    pub recompute_tokens: u64,
}

impl QueueingResult {
    pub fn ttft_p50(&self) -> f64 {
        ttft_percentile(&self.served, 50.0)
    }
    pub fn ttft_p99(&self) -> f64 {
        ttft_percentile(&self.served, 99.0)
    }
    pub fn e2e_p50(&self) -> f64 {
        e2e_percentile(&self.served, 50.0)
    }
    pub fn e2e_p99(&self) -> f64 {
        e2e_percentile(&self.served, 99.0)
    }
    pub fn throughput_rps(&self) -> f64 {
        served_rate(self.served.len(), self.makespan)
    }
}

/// Replay a trace on one device under a mapping.
///
/// Scheduling policy (mirrors `coordinator::Server`): FIFO admission into
/// free slots; an admission runs the request's prefill exclusively; decode
/// proceeds in batched steps over the active slots. Decode-step latency is
/// interpolated from the analytical model at the current batch size and
/// mean context (costs are affine in context, so the mean is exact).
pub fn replay_trace(
    llm: &LlmConfig,
    hw: &HwConfig,
    mapping: MappingKind,
    slots: usize,
    trace: &[TraceRequest],
) -> QueueingResult {
    replay_trace_with(llm, hw, mapping, slots, SchedConfig::default(), trace)
}

/// [`replay_trace`] under an explicit device scheduling configuration
/// (chunked prefill, admission policy, KV capacity). The default
/// [`SchedConfig`] reproduces `replay_trace` bit-for-bit.
pub fn replay_trace_with(
    llm: &LlmConfig,
    hw: &HwConfig,
    mapping: MappingKind,
    slots: usize,
    sched: SchedConfig,
    trace: &[TraceRequest],
) -> QueueingResult {
    let mut dev = Device::with_sched(llm, hw, mapping, slots, 0, sched);
    let mut pending = trace.iter().peekable();
    loop {
        // pull arrivals up to the device clock
        while pending.peek().is_some_and(|r| r.arrival <= dev.now()) {
            let r = pending.next().unwrap();
            dev.push_tagged(DeviceJob::full(r), ReqTag::of(r));
        }
        if !dev.has_work() {
            match pending.peek() {
                Some(r) => {
                    let t = r.arrival;
                    dev.advance_to(t);
                    continue;
                }
                None => break,
            }
        }
        dev.step_cycle();
    }
    QueueingResult {
        served: std::mem::take(&mut dev.served),
        makespan: dev.now(),
        decode_steps: dev.decode_steps,
        evictions: dev.evictions,
        recompute_tokens: dev.recompute_tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_graph, EngineSet};
    use crate::model::build_decode_graph;

    fn llm() -> LlmConfig {
        LlmConfig::llama2_7b()
    }

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    #[test]
    fn poisson_trace_statistics() {
        let tr = poisson_trace(1, 2000, 10.0, (64, 1024), 128);
        assert_eq!(tr.len(), 2000);
        // arrivals are sorted and the mean inter-arrival ~ 1/rate
        assert!(tr.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let mean_gap = tr.last().unwrap().arrival / 2000.0;
        assert!((mean_gap - 0.1).abs() < 0.02, "{mean_gap}");
        assert!(tr.iter().all(|r| (64..=1024).contains(&r.l_in)));
    }

    #[test]
    fn tenant_tagging_preserves_single_tenant_stream() {
        let single = poisson_trace(6, 200, 10.0, (64, 1024), 64);
        // tenants = 1 must be bit-identical to the untagged generator
        let tagged =
            trace_with_tenants(6, 200, 10.0, 1, |rng| (log_uniform(rng, 64, 1024), 64));
        for (a, b) in single.iter().zip(&tagged) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!((a.l_in, a.l_out, a.tenant), (b.l_in, b.l_out, b.tenant));
        }
        // multi-tenant draws cover every tenant and stay deterministic
        let multi = trace_with_tenants(6, 600, 10.0, 3, |rng| (log_uniform(rng, 64, 1024), 64));
        for t in 0..3 {
            let n = multi.iter().filter(|r| r.tenant == t).count();
            assert!(n > 100, "tenant {t} got only {n} of 600 requests");
        }
        let again = trace_with_tenants(6, 600, 10.0, 3, |rng| (log_uniform(rng, 64, 1024), 64));
        assert!(multi.iter().zip(&again).all(|(a, b)| a.tenant == b.tenant));
    }

    #[test]
    fn all_requests_served_once() {
        let tr = poisson_trace(2, 50, 5.0, (64, 512), 32);
        let r = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        assert_eq!(r.served.len(), 50);
        assert!(r.decode_steps >= 31, "{}", r.decode_steps);
        for s in &r.served {
            assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
        }
    }

    #[test]
    fn latency_grows_with_load() {
        let slow = |rate: f64| {
            let tr = poisson_trace(3, 60, rate, (128, 2048), 64);
            replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr).ttft_p99()
        };
        let light = slow(0.5);
        let heavy = slow(50.0);
        assert!(heavy > light, "p99 ttft: light {light}, heavy {heavy}");
    }

    #[test]
    fn halo_sustains_more_load_than_attacc() {
        // at a load where HALO is comfortable, AttAcc's slow decode
        // steps blow up end-to-end latency
        let tr = poisson_trace(4, 40, 2.0, (128, 1024), 64);
        let halo = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        let att = replay_trace(&llm(), &hw(), MappingKind::AttAcc1, 4, &tr);
        assert!(att.e2e_p50() > 3.0 * halo.e2e_p50(), "{} vs {}", att.e2e_p50(), halo.e2e_p50());
        assert!(att.makespan > halo.makespan);
    }

    #[test]
    fn throughput_bounded_by_decode_rate() {
        // closed-form sanity: with saturating load, token throughput
        // can't exceed slots / tpot
        let tr = poisson_trace(5, 80, 1000.0, (128, 128), 64);
        let r = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        let tokens = 80.0 * 64.0;
        let tok_rate = tokens / r.makespan;
        let engines = EngineSet::new(&hw(), MappingKind::Halo1);
        let tpot4 =
            simulate_graph(&build_decode_graph(&llm(), 256, 4), &engines, MappingKind::Halo1)
                .latency;
        assert!(tok_rate <= 4.0 / tpot4 * 1.05, "{tok_rate} vs {}", 4.0 / tpot4);
    }
}

"""Analog non-ideality study (paper §V-A accuracy discussion, Table II).

Quantifies the CiM ADC noise the HALO1/HALO2 wordline knob controls, and
the layer-compounding behaviour that motivates routing only *prefill*
through the analog path while decode stays digital.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref
from compile.kernels.cim_matmul import cim_linear

RNG = np.random.default_rng(99)


def rel_err(a, b):
    return float(np.abs(a - b).mean() / (np.abs(b).mean() + 1e-9))


@pytest.fixture(scope="module")
def gauss_mats():
    x = RNG.normal(size=(32, 256)).astype(np.float32)
    w = RNG.normal(size=(256, 64)).astype(np.float32)
    return x, w, x @ w


def test_single_matmul_noise_band(gauss_mats):
    """Calibrated 128-wordline ADC noise sits in the ~8-20% band per
    matmul — large enough to matter, small enough that wordline
    throttling meaningfully helps (the paper's accuracy story)."""
    x, w, yt = gauss_mats
    y = np.asarray(cim_linear(jnp.asarray(x), jnp.asarray(w), ref.MODEL_SPEC))
    e = rel_err(y, yt)
    assert 0.05 < e < 0.25, e


def test_halo2_wordlines_reduce_model_noise(gauss_mats):
    """HALO2 (64 wordlines) must beat HALO1 (128) on accuracy in
    calibrated mode too, not just in the full-range mode."""
    x, w, yt = gauss_mats
    errs = {}
    for wl in (128, 64):
        spec = dataclasses.replace(ref.MODEL_SPEC, wordlines=wl)
        y = np.asarray(cim_linear(jnp.asarray(x), jnp.asarray(w), spec))
        errs[wl] = rel_err(y, yt)
    assert errs[64] < errs[128], errs


def test_noise_compounds_across_layers():
    """Per-layer noise compounds roughly multiplicatively through the
    network: the 2-layer model's logit error exceeds a single matmul's.
    This is why the functional serving path offers an ideal-ADC prefill
    (see EXPERIMENTS.md §Functional)."""
    cfg = M.TinyLlamaConfig(n_layers=2, max_seq=32)
    params = M.init_params(cfg, 3)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (1, 8), dtype=np.int32))
    lg_cim, _, _ = M.prefill(params, toks, cfg)
    lg_f32, _, _ = M.prefill(params, toks, M.reference_config(cfg))
    e_model = rel_err(np.asarray(lg_cim), np.asarray(lg_f32))
    assert e_model > 0.05, f"expected visible compounded noise, got {e_model}"
    # but the ideal-ADC path stays within int8-quantization error
    cfg_i = dataclasses.replace(cfg, cim_spec=M.IDEAL_SPEC)
    lg_ideal, _, _ = M.prefill(params, toks, cfg_i)
    e_ideal = rel_err(np.asarray(lg_ideal), np.asarray(lg_f32))
    assert e_ideal < 0.25 * e_model, (e_ideal, e_model)


def test_decode_path_immune_to_adc_noise():
    """Decode runs on CiD (digital): its only error source is int8
    fake-quantization, orders below the analog path."""
    cfg = M.TinyLlamaConfig(n_layers=2, max_seq=32)
    params = M.init_params(cfg, 3)
    kc = jnp.zeros((cfg.n_layers, 1, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim))
    vc = jnp.zeros_like(kc)
    tok = jnp.asarray([7], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    lg_cid, _, _ = M.decode_step(params, tok, pos, kc, vc, cfg)
    lg_f32, _, _ = M.decode_step(params, tok, pos, kc, vc, M.reference_config(cfg))
    assert rel_err(np.asarray(lg_cid), np.asarray(lg_f32)) < 0.05


def test_ideal_prefill_bit_stable_across_reruns():
    """The strict-validation artifact path: ideal-ADC prefill is exactly
    reproducible run-to-run (integer pipeline end to end)."""
    cfg = dataclasses.replace(M.TinyLlamaConfig(n_layers=2, max_seq=32), cim_spec=M.IDEAL_SPEC)
    params = M.init_params(cfg, 1)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (1, 6), dtype=np.int32))
    a = np.asarray(M.prefill(params, toks, cfg)[0])
    b = np.asarray(M.prefill(params, toks, cfg)[0])
    np.testing.assert_array_equal(a, b)

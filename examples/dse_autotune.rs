//! SLO auto-tuning with the DSE engine: search the scheduler space on
//! one device, print the Pareto frontier, then ask for the cheapest
//! configuration meeting a median-TTFT SLO — the ROADMAP's "chunk-size
//! auto-tuning against a TTFT SLO" follow-on, end to end.
//!
//!     cargo run --release --example dse_autotune

use halo::cluster::Mix;
use halo::dse::{explore, DseConfig, Exhaustive, SearchSpace, SloSpec};
use halo::model::LlmConfig;
use halo::report::dse::frontier_table;
use halo::util::fmt_seconds;

fn main() {
    let space = SearchSpace::sched();
    let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
    cfg.requests = 120;
    cfg.seed = 41;
    cfg.rate_scale = 1.25; // mild overload: scheduling, not idle luck

    println!("searching {} scheduler configurations...\n", space.len());
    let res = explore(&space, &mut Exhaustive, &cfg);
    let table = frontier_table(
        &res,
        "dse_sched_frontier",
        &format!("Scheduler-space Pareto frontier ({:.2} req/s offered)", res.rate),
    );
    println!("{}", table.to_markdown());

    // read the serialized-FIFO baseline's median TTFT off the search,
    // then demand 40% better and re-run in auto-tune mode
    let serialized = res
        .evaluated
        .iter()
        .find(|e| e.candidate.chunk == 0 && e.candidate.admission.name() == "fifo")
        .expect("baseline point");
    let target = 0.6 * serialized.metrics.slo_ttft;
    println!(
        "serialized FIFO median TTFT: {}  ->  asking for {}",
        fmt_seconds(serialized.metrics.slo_ttft),
        fmt_seconds(target)
    );
    cfg.slo = Some(SloSpec::median(target));
    let tuned = explore(&space, &mut Exhaustive, &cfg);
    match tuned.slo_choice {
        Some(i) => {
            let e = &tuned.evaluated[i];
            println!(
                "auto-tune pick: {}  (median TTFT {}, relative cost {:.2})",
                e.candidate.label(),
                fmt_seconds(e.metrics.slo_ttft),
                e.metrics.cost
            );
        }
        None => println!("no scheduler configuration meets that SLO at this load"),
    }
}

//! Regenerate every figure of the paper's evaluation section as CSV (under
//! out/figures) and print the headline geomean comparison.
//!
//!     cargo run --release --example paper_figures

use std::path::Path;

use halo::config::HwConfig;
use halo::report;

fn main() -> anyhow::Result<()> {
    let hw = HwConfig::paper();
    let out = Path::new("out/figures");
    for t in report::all_figures(&hw) {
        t.write_csv(out)?;
        println!("wrote {}/{}.csv  ({} rows) — {}", out.display(), t.name, t.rows.len(), t.title);
    }
    println!();
    println!("{}", report::headline_summary(&hw).to_markdown());
    Ok(())
}

//! Design-space exploration beyond the paper's figures: sweep the
//! calibrated hardware parameters and show how the headline claims move.
//! (The paper's "future work" knobs: wordlines, ADC count, interposer
//! bandwidth, crossbar write speed, CiD buffer size.)
//!
//!     cargo run --release --example design_space

use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::report::context_grid;
use halo::sim::{simulate_e2e, Scenario};
use halo::util::geomean;

fn geomean_speedup(hw: &HwConfig, baseline: MappingKind) -> f64 {
    let m = LlmConfig::llama2_7b();
    let mut r = Vec::new();
    for (l_in, l_out) in context_grid() {
        let sc = Scenario { l_in, l_out, batch: 1 };
        let halo = simulate_e2e(&m, hw, MappingKind::Halo1, &sc).e2e_latency();
        r.push(simulate_e2e(&m, hw, baseline, &sc).e2e_latency() / halo);
    }
    geomean(&r)
}

fn main() {
    let base = HwConfig::paper();
    println!("design-space sweeps: HALO1 geomean e2e speedup vs CENT / AttAcc1\n");

    println!("-- interposer / GB bandwidth (paper: 2 TB/s) --");
    for scale in [0.5, 1.0, 2.0, 4.0] {
        let mut hw = base.clone();
        hw.cim.gb_bw *= scale;
        hw.interposer.bw *= scale;
        println!(
            "  {:>4.1} TB/s: vs CENT {:.2}x, vs AttAcc1 {:.2}x",
            hw.cim.gb_bw / 1e12,
            geomean_speedup(&hw, MappingKind::Cent),
            geomean_speedup(&hw, MappingKind::AttAcc1)
        );
    }

    println!("\n-- crossbar row-write time (paper calibration: 20 ns) --");
    for t in [5e-9, 10e-9, 20e-9, 40e-9] {
        let mut hw = base.clone();
        hw.cim.t_write_row = t;
        println!(
            "  {:>4.0} ns: vs CENT {:.2}x, vs AttAcc1 {:.2}x",
            t * 1e9,
            geomean_speedup(&hw, MappingKind::Cent),
            geomean_speedup(&hw, MappingKind::AttAcc1)
        );
    }

    println!("\n-- ADC bit-phase time (CiM compute rate; calibration: 1.5 ns) --");
    for t in [0.75e-9, 1.5e-9, 3e-9, 6e-9] {
        let mut hw = base.clone();
        hw.cim.t_bit_phase = t;
        println!(
            "  {:>5.2} ns: vs CENT {:.2}x (prefill-bound claim)",
            t * 1e9,
            geomean_speedup(&hw, MappingKind::Cent)
        );
    }

    println!("\n-- CiD input buffer (paper: 4 KB, shared x2) --");
    for kb in [1usize, 4, 16, 64] {
        let mut hw = base.clone();
        hw.cid.input_buffer = kb * 1024;
        println!(
            "  {:>3} KB: vs CENT {:.2}x  (bigger buffer -> better CiD GEMM reuse -> smaller HALO edge)",
            kb,
            geomean_speedup(&hw, MappingKind::Cent)
        );
    }

    println!("\n-- wordline throttling (HALO1=128, HALO2=64, plus finer) --");
    let m = LlmConfig::llama2_7b();
    for wl in [128usize, 64, 32] {
        let mut hw = base.clone();
        hw.cim = hw.cim.clone().with_wordlines(wl);
        let sc = Scenario { l_in: 2048, l_out: 512, batch: 1 };
        // bypass the mapping's own wordline override by comparing FullCim
        let r = simulate_e2e(&m, &hw, MappingKind::FullCim, &sc);
        println!(
            "  {:>3} wordlines: prefill {:.1} ms (accuracy up, latency up)",
            wl,
            r.ttft() * 1e3
        );
    }
}

//! The observability plane end to end: replay a disaggregated fleet
//! with span recording on, verify the timeline reconciles bit-exactly
//! with the replay's busy accounting, export a Chrome-trace JSON file
//! (open it in <https://ui.perfetto.dev>), then build the streaming
//! metrics registry and a `halo.cluster.v1` snapshot from the same
//! replay.
//!
//!     cargo run --release --example observability

use halo::cluster::{Interconnect, Mix, Policy, SchedConfig};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::obs::{cluster_snapshot, fleet_registry, jobj, SelfProfile};
use halo::util::json::Json;

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();
    let trace = Mix::Chat.trace(71, 64, 16.0);

    let (mut fleet, mut router) = Policy::PhaseDisaggregated.build_with(
        &llm,
        &hw,
        4,
        8,
        0.5,
        Interconnect::board(),
        SchedConfig::chunked(256),
    );
    fleet.enable_obs();

    let mut prof = SelfProfile::new();
    let r = prof.time("fleet_replay", || fleet.replay(&trace, router.as_mut()));

    println!("== span timelines reconcile with busy accounting ==");
    for d in &r.per_device {
        let rec = fleet.devices[d.id].obs().unwrap();
        assert_eq!(rec.busy_total().to_bits(), d.busy.to_bits());
        println!(
            "  dev{} ({:<8}): {:>4} spans, {:>3} events, busy {:.3} s — bit-exact",
            d.id,
            d.role,
            rec.spans.len(),
            rec.events.len(),
            d.busy
        );
    }

    let doc = fleet.chrome_trace().unwrap();
    let n = doc.path(&["traceEvents"]).and_then(Json::as_arr).map_or(0, <[Json]>::len);
    std::fs::write("trace.json", doc.to_string()).unwrap();
    println!("\n== Chrome trace: {n} events -> trace.json (load in Perfetto) ==");

    println!("\n== streaming metrics registry ==");
    let reg = fleet_registry(&r, fleet.cost_walks(), fleet.cost_memo_hits());
    println!(
        "  served {} requests, ttft p99 {:.4} s (histogram: {:.4} s from {} buckets of memory)",
        reg.counter("requests_served"),
        r.ttft_p99(),
        reg.histogram("ttft_s").unwrap().percentile(99.0),
        halo::obs::hist::N_BUCKETS
    );
    println!(
        "  graph walks {}, oracle memo hits {} (replay {:.3} s wall)",
        reg.counter("graph_walks"),
        reg.counter("oracle_memo_hits"),
        prof.wall_s("fleet_replay")
    );

    let snap = cluster_snapshot(
        &r,
        fleet.cost_walks(),
        fleet.cost_memo_hits(),
        &prof,
        jobj(vec![("example", Json::Str("observability".to_string()))]),
    );
    println!(
        "\n== halo.cluster.v1 snapshot: {} bytes of JSON (same data as `halo cluster --json`) ==",
        snap.to_string().len()
    );
}

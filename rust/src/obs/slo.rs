//! Per-window SLO evaluation with multi-window burn-rate alerting.
//!
//! An SLO here is "fraction of requests with TTFT (or e2e) at or below
//! a target must be at least `objective`". The complement of the
//! objective is the *error budget*; the **burn rate** of a span of
//! windows is its bad-request fraction divided by that budget — burn
//! 1.0 spends the budget exactly, burn 10 spends it ten times too
//! fast. Following the SRE multi-window pattern, an alert needs *both*
//! a fast (recent, spiky) and a slow (sustained) trailing span over
//! the threshold, which filters one-window blips without missing real
//! regressions; alerts fire on the rising edge only, so a sustained
//! breach is one alert, not one per window.
//!
//! Empty windows (idle diurnal troughs) have a bad fraction of 0.0 —
//! no traffic burns no budget — so quiet periods can never alert
//! (satellite fix: these helpers return 0.0, never NaN, on empty
//! populations).
//!
//! Everything is computed from the deterministic [`WindowSeries`], so
//! the alert stream is bit-reproducible per seed — the consumable
//! signal a future autoscaler reacts to.

use super::hist::LogHistogram;
use super::jobj;
use super::timeseries::WindowSeries;
use crate::util::json::Json;

/// Latency service-level objective: attainment targets for TTFT and
/// end-to-end latency. (Distinct from [`crate::dse::SloSpec`], the
/// DSE auto-tune knob — qualify as `obs::SloSpec` where both are in
/// scope.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// TTFT target in simulated seconds.
    pub ttft_target_s: f64,
    /// End-to-end latency target in simulated seconds.
    pub e2e_target_s: f64,
    /// Required attainment in (0, 1), e.g. 0.99.
    pub objective: f64,
}

impl SloSpec {
    /// The interactive-serving default: 500 ms TTFT, 10 s e2e, 99%.
    pub fn interactive() -> Self {
        SloSpec { ttft_target_s: 0.5, e2e_target_s: 10.0, objective: 0.99 }
    }

    /// Tolerable bad-request fraction (`1 - objective`).
    pub fn error_budget(&self) -> f64 {
        1.0 - self.objective
    }
}

/// Fraction of recorded samples at or below `target` (bucket
/// resolution, ~2.2%). Returns 0.0 — not NaN — on an empty histogram.
pub fn attainment(h: &LogHistogram, target: f64) -> f64 {
    if h.count() == 0 {
        return 0.0;
    }
    h.count_at_or_below(target) as f64 / h.count() as f64
}

/// Fraction of recorded samples above `target`. Returns 0.0 on an
/// empty histogram: an idle window burns no error budget.
pub fn bad_fraction(h: &LogHistogram, target: f64) -> f64 {
    if h.count() == 0 {
        return 0.0;
    }
    (h.count() - h.count_at_or_below(target)) as f64 / h.count() as f64
}

/// Burn-rate alerting shape: trailing window counts for the fast and
/// slow spans, and the burn threshold both must exceed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurnRateConfig {
    pub fast_windows: usize,
    pub slow_windows: usize,
    pub threshold: f64,
}

impl Default for BurnRateConfig {
    fn default() -> Self {
        BurnRateConfig { fast_windows: 3, slow_windows: 12, threshold: 4.0 }
    }
}

/// One window's SLO readout.
#[derive(Debug, Clone, Copy)]
pub struct WindowSlo {
    pub start_s: f64,
    /// Completions in the window.
    pub total: u64,
    pub ttft_attainment: f64,
    pub e2e_attainment: f64,
    pub ttft_burn_fast: f64,
    pub ttft_burn_slow: f64,
    pub e2e_burn_fast: f64,
    pub e2e_burn_slow: f64,
}

/// A rising-edge burn-rate alert: at window `window` both the fast and
/// slow trailing burns for `metric` crossed the threshold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloAlert {
    /// `"ttft"` or `"e2e"`.
    pub metric: &'static str,
    /// Index into the series' windows.
    pub window: usize,
    /// Simulated time of the window's end.
    pub t_s: f64,
    pub burn_fast: f64,
    pub burn_slow: f64,
}

/// The full SLO evaluation of one serve.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub spec: SloSpec,
    pub burn: BurnRateConfig,
    /// Whole-run TTFT attainment (merged population).
    pub ttft_attainment: f64,
    /// Whole-run e2e attainment (merged population).
    pub e2e_attainment: f64,
    pub per_window: Vec<WindowSlo>,
    pub alerts: Vec<SloAlert>,
}

impl SloReport {
    pub fn to_json(&self) -> Json {
        let windows: Vec<Json> = self
            .per_window
            .iter()
            .map(|w| {
                jobj(vec![
                    ("start_s", Json::Num(w.start_s)),
                    ("total", Json::Num(w.total as f64)),
                    ("ttft_attainment", Json::Num(w.ttft_attainment)),
                    ("e2e_attainment", Json::Num(w.e2e_attainment)),
                    ("ttft_burn_fast", Json::Num(w.ttft_burn_fast)),
                    ("ttft_burn_slow", Json::Num(w.ttft_burn_slow)),
                    ("e2e_burn_fast", Json::Num(w.e2e_burn_fast)),
                    ("e2e_burn_slow", Json::Num(w.e2e_burn_slow)),
                ])
            })
            .collect();
        let alerts: Vec<Json> = self
            .alerts
            .iter()
            .map(|a| {
                jobj(vec![
                    ("metric", Json::Str(a.metric.to_string())),
                    ("window", Json::Num(a.window as f64)),
                    ("t_s", Json::Num(a.t_s)),
                    ("burn_fast", Json::Num(a.burn_fast)),
                    ("burn_slow", Json::Num(a.burn_slow)),
                ])
            })
            .collect();
        jobj(vec![
            (
                "spec",
                jobj(vec![
                    ("ttft_target_s", Json::Num(self.spec.ttft_target_s)),
                    ("e2e_target_s", Json::Num(self.spec.e2e_target_s)),
                    ("objective", Json::Num(self.spec.objective)),
                ]),
            ),
            (
                "burn",
                jobj(vec![
                    ("fast_windows", Json::Num(self.burn.fast_windows as f64)),
                    ("slow_windows", Json::Num(self.burn.slow_windows as f64)),
                    ("threshold", Json::Num(self.burn.threshold)),
                ]),
            ),
            ("ttft_attainment", Json::Num(self.ttft_attainment)),
            ("e2e_attainment", Json::Num(self.e2e_attainment)),
            ("windows", Json::Arr(windows)),
            ("alerts", Json::Arr(alerts)),
        ])
    }
}

/// Bad/total counts of the trailing `k` windows ending at `i`.
fn trailing(stats: &[(u64, u64)], i: usize, k: usize) -> (u64, u64) {
    let lo = (i + 1).saturating_sub(k.max(1));
    stats[lo..=i].iter().fold((0, 0), |acc, s| (acc.0 + s.0, acc.1 + s.1))
}

/// Burn rate of a (bad, total) span: bad fraction over the error
/// budget; 0.0 when the span saw no traffic.
fn burn_of(bad: u64, total: u64, budget: f64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    (bad as f64 / total as f64) / budget
}

/// Evaluate `spec` over every window of `series` with multi-window
/// burn-rate alerting. Deterministic: same series, same report.
pub fn evaluate(series: &WindowSeries, spec: &SloSpec, burn: &BurnRateConfig) -> SloReport {
    let budget = spec.error_budget().max(1e-12);
    // per-window (bad, total) for each metric
    let ttft_stats: Vec<(u64, u64)> = series
        .windows()
        .iter()
        .map(|w| (w.ttft.count() - w.ttft.count_at_or_below(spec.ttft_target_s), w.ttft.count()))
        .collect();
    let e2e_stats: Vec<(u64, u64)> = series
        .windows()
        .iter()
        .map(|w| (w.e2e.count() - w.e2e.count_at_or_below(spec.e2e_target_s), w.e2e.count()))
        .collect();
    let mut per_window = Vec::with_capacity(series.len());
    let mut alerts = Vec::new();
    let mut firing = [false; 2];
    for (i, w) in series.windows().iter().enumerate() {
        let (tf_bad, tf_tot) = trailing(&ttft_stats, i, burn.fast_windows);
        let (ts_bad, ts_tot) = trailing(&ttft_stats, i, burn.slow_windows);
        let (ef_bad, ef_tot) = trailing(&e2e_stats, i, burn.fast_windows);
        let (es_bad, es_tot) = trailing(&e2e_stats, i, burn.slow_windows);
        let row = WindowSlo {
            start_s: series.start_of(i),
            total: w.e2e.count(),
            ttft_attainment: attainment(&w.ttft, spec.ttft_target_s),
            e2e_attainment: attainment(&w.e2e, spec.e2e_target_s),
            ttft_burn_fast: burn_of(tf_bad, tf_tot, budget),
            ttft_burn_slow: burn_of(ts_bad, ts_tot, budget),
            e2e_burn_fast: burn_of(ef_bad, ef_tot, budget),
            e2e_burn_slow: burn_of(es_bad, es_tot, budget),
        };
        let conds = [
            ("ttft", row.ttft_burn_fast, row.ttft_burn_slow),
            ("e2e", row.e2e_burn_fast, row.e2e_burn_slow),
        ];
        for (m, (metric, fast, slow)) in conds.into_iter().enumerate() {
            let cond = fast >= burn.threshold && slow >= burn.threshold;
            if cond && !firing[m] {
                alerts.push(SloAlert {
                    metric,
                    window: i,
                    t_s: series.start_of(i) + series.width_s(),
                    burn_fast: fast,
                    burn_slow: slow,
                });
            }
            firing[m] = cond;
        }
        per_window.push(row);
    }
    SloReport {
        spec: *spec,
        burn: *burn,
        ttft_attainment: attainment(&series.merged_ttft(), spec.ttft_target_s),
        e2e_attainment: attainment(&series.merged_e2e(), spec.e2e_target_s),
        per_window,
        alerts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::timeseries::GaugeSample;

    #[test]
    fn empty_population_helpers_are_zero_not_nan() {
        let h = LogHistogram::new();
        assert_eq!(attainment(&h, 0.5), 0.0);
        assert_eq!(bad_fraction(&h, 0.5), 0.0);
        assert_eq!(burn_of(0, 0, 0.01), 0.0);
    }

    #[test]
    fn attainment_splits_population_at_target() {
        let mut h = LogHistogram::new();
        for _ in 0..90 {
            h.record(0.1);
        }
        for _ in 0..10 {
            h.record(2.0);
        }
        // target between the two modes: bucket error is irrelevant
        assert!((attainment(&h, 0.5) - 0.9).abs() < 1e-12);
        assert!((bad_fraction(&h, 0.5) - 0.1).abs() < 1e-12);
    }

    /// A series with `good` windows of in-SLO traffic, then `bad`
    /// windows of violations, then `good` again.
    fn staged_series(good: usize, bad: usize, tail: usize) -> WindowSeries {
        let mut s = WindowSeries::new(1.0, 64);
        let mut t = 0.5;
        for stage in [(good, 0.1), (bad, 5.0), (tail, 0.1)] {
            for _ in 0..stage.0 {
                if s.needs_roll(t) {
                    s.roll(t, &GaugeSample::default());
                }
                for _ in 0..10 {
                    s.observe_completion(t, stage.1, stage.1, 1);
                }
                t += 1.0;
            }
        }
        s.finalize(t, &GaugeSample::default());
        s
    }

    #[test]
    fn sustained_breach_is_one_rising_edge_alert_per_metric() {
        let spec = SloSpec { ttft_target_s: 0.5, e2e_target_s: 0.5, objective: 0.9 };
        let burn = BurnRateConfig { fast_windows: 2, slow_windows: 4, threshold: 2.0 };
        let s = staged_series(4, 6, 0);
        let rep = evaluate(&s, &spec, &burn);
        let ttft_alerts: Vec<_> = rep.alerts.iter().filter(|a| a.metric == "ttft").collect();
        assert_eq!(ttft_alerts.len(), 1, "sustained breach fires exactly once: {:?}", rep.alerts);
        // violations start at window 4; burn 10x crosses both spans there
        assert_eq!(ttft_alerts[0].window, 4);
        assert!(ttft_alerts[0].burn_fast >= 2.0 && ttft_alerts[0].burn_slow >= 2.0);
    }

    #[test]
    fn recovery_and_rebreach_fires_again_but_idle_never_does() {
        let spec = SloSpec { ttft_target_s: 0.5, e2e_target_s: 0.5, objective: 0.9 };
        let burn = BurnRateConfig { fast_windows: 1, slow_windows: 2, threshold: 2.0 };
        // good, breach, long recovery (clears the slow span), breach again
        let mut s = WindowSeries::new(1.0, 64);
        let mut t = 0.5;
        for stage in [(2usize, 0.1), (2, 5.0), (4, 0.1), (2, 5.0)] {
            for _ in 0..stage.0 {
                if s.needs_roll(t) {
                    s.roll(t, &GaugeSample::default());
                }
                for _ in 0..10 {
                    s.observe_completion(t, stage.1, stage.1, 1);
                }
                t += 1.0;
            }
        }
        // trailing idle windows: no traffic, must not alert
        s.finalize(t + 8.0, &GaugeSample::default());
        let rep = evaluate(&s, &spec, &burn);
        let e2e_alerts: Vec<_> = rep.alerts.iter().filter(|a| a.metric == "e2e").collect();
        assert_eq!(e2e_alerts.len(), 2, "re-breach after recovery re-alerts: {:?}", rep.alerts);
        let last_breach_end = 10;
        assert!(
            rep.alerts.iter().all(|a| a.window < last_breach_end),
            "idle trailing windows never alert: {:?}",
            rep.alerts
        );
        // whole-run attainments are finite and in [0, 1]
        assert!((0.0..=1.0).contains(&rep.ttft_attainment));
    }
}

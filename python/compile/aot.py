"""AOT compile path: lower the L2 model + L1 kernels to HLO text artifacts.

Python runs ONCE (``make artifacts``); the Rust coordinator then loads the
HLO text through the PJRT C API and Python never appears on the request
path.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):
  * ``<entry>.hlo.txt``     — one per entry point (prefill, decode steps,
    kernel microbenches)
  * ``weights.bin``         — flat little-endian f32 parameter image
  * ``manifest.json``       — parameter table (name/shape/offset), entry
    point signatures, model config, and test-vector index
  * ``testvec/*.bin``       — input/output vectors for Rust integration
    tests (computed with the same jitted functions that were lowered)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.cid_gemv import cid_gemv
from .kernels.cim_matmul import cim_matmul
from .kernels.ref import HALO1_SPEC

DTYPE_MAP = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32", np.dtype(np.int8): "i8"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(arrs) -> list[dict]:
    out = []
    for a in arrs:
        a = np.asarray(a)
        out.append({"shape": list(a.shape), "dtype": DTYPE_MAP[a.dtype]})
    return out


class ArtifactWriter:
    def __init__(self, outdir: pathlib.Path):
        self.outdir = outdir
        self.vec_dir = outdir / "testvec"
        self.outdir.mkdir(parents=True, exist_ok=True)
        self.vec_dir.mkdir(parents=True, exist_ok=True)
        self.entries: dict[str, dict] = {}

    def add_entry(self, name: str, fn, example_inputs, *, n_params: int = 0,
                  testvec: bool = True):
        """Lower ``fn`` at the example inputs, dump HLO text and vectors.

        ``n_params``: how many leading inputs are model parameters (not
        re-exported as test vectors; the Rust side feeds ``weights.bin``).
        """
        jitted = jax.jit(fn)
        lowered = jitted.lower(*example_inputs)
        text = to_hlo_text(lowered)
        hlo_path = self.outdir / f"{name}.hlo.txt"
        hlo_path.write_text(text)

        outputs = jitted(*example_inputs)
        if not isinstance(outputs, tuple):
            outputs = (outputs,)

        vec_inputs = example_inputs[n_params:]
        entry = {
            "hlo": hlo_path.name,
            "n_params": n_params,
            "inputs": _sig(example_inputs),
            "outputs": _sig(outputs),
        }
        if testvec:
            in_files, out_files = [], []
            for i, a in enumerate(vec_inputs):
                f = f"{name}.in{i}.bin"
                np.asarray(a).tofile(self.vec_dir / f)
                in_files.append(f)
            for i, a in enumerate(outputs):
                f = f"{name}.out{i}.bin"
                np.asarray(a).tofile(self.vec_dir / f)
                out_files.append(f)
            entry["testvec"] = {"inputs": in_files, "outputs": out_files}
        self.entries[name] = entry
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO, "
              f"{len(example_inputs)} inputs, {len(outputs)} outputs")
        return outputs


def export_weights(outdir: pathlib.Path, cfg, params) -> list[dict]:
    table, offset = [], 0
    with open(outdir / "weights.bin", "wb") as f:
        for (name, shape), arr in zip(M.param_specs(cfg), params):
            a = np.asarray(arr, dtype=np.float32)
            assert tuple(a.shape) == tuple(shape), (name, a.shape, shape)
            f.write(a.tobytes())
            table.append(
                {"name": name, "shape": list(shape), "offset": offset, "nelems": int(a.size)}
            )
            offset += a.size * 4
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-lens", type=int, nargs="+", default=[16, 64])
    ap.add_argument("--decode-batches", type=int, nargs="+", default=[1, 4])
    args = ap.parse_args()
    outdir = pathlib.Path(args.outdir)

    cfg = M.TinyLlamaConfig()
    params = M.init_params(cfg, args.seed)
    n_p = len(params)
    w = ArtifactWriter(outdir)
    rng = np.random.default_rng(args.seed)

    print(f"[aot] tiny-llama: {sum(int(np.prod(s)) for _, s in M.param_specs(cfg))} params")
    param_table = export_weights(outdir, cfg, params)

    # --- model entry points (phase-aware: prefill=CiM, decode=CiD) --------
    # Two prefill variants per length: the calibrated-ADC CiM path (the
    # realistic serving path; validated with a loose tolerance because ADC
    # code rounding amplifies cross-XLA-version reduction-order noise) and
    # an ideal-ADC path (integer-exact, byte-stable across XLA versions;
    # the strict Rust-side validation target).
    cfg_ideal = dataclasses.replace(cfg, cim_spec=M.IDEAL_SPEC)
    prefill_outs = {}
    for L in args.prefill_lens:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, L), dtype=np.int32))
        outs = w.add_entry(
            f"prefill_b1_s{L}",
            lambda *a: M.prefill(list(a[:n_p]), a[n_p], cfg),
            (*params, tokens),
            n_params=n_p,
        )
        w.add_entry(
            f"prefill_ideal_b1_s{L}",
            lambda *a: M.prefill(list(a[:n_p]), a[n_p], cfg_ideal),
            (*params, tokens),
            n_params=n_p,
        )
        prefill_outs[L] = (tokens, outs)

    for B in args.decode_batches:
        # seed the decode test vector from a real prefill state
        L0 = args.prefill_lens[0]
        tokens, (lg, kc1, vc1) = prefill_outs[L0]
        kc = jnp.broadcast_to(kc1, (cfg.n_layers, B, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim))
        vc = jnp.broadcast_to(vc1, kc.shape)
        token = jnp.asarray(rng.integers(0, cfg.vocab, (B,), dtype=np.int32))
        pos = jnp.full((B,), L0, jnp.int32)
        w.add_entry(
            f"decode_b{B}",
            lambda *a: M.decode_step(list(a[:n_p]), a[n_p], a[n_p + 1], a[n_p + 2], a[n_p + 3], cfg),
            (*params, token, pos, kc, vc),
            n_params=n_p,
        )

    # --- kernel microbench artifacts (for Rust runtime tests/benches) -----
    x8 = jnp.asarray(rng.integers(-128, 128, (64, 256), dtype=np.int8))
    w8 = jnp.asarray(rng.integers(-128, 128, (256, 128), dtype=np.int8))
    w.add_entry("cim_gemm_64x256x128", lambda x, ww: (cim_matmul(x, ww, HALO1_SPEC),), (x8, w8))

    xg = jnp.asarray(rng.integers(-128, 128, (4, 256), dtype=np.int8))
    wg = jnp.asarray(rng.integers(-128, 128, (256, 512), dtype=np.int8))
    w.add_entry("cid_gemv_4x256x512", lambda x, ww: (cid_gemv(x, ww),), (xg, wg))

    manifest = {
        "config": {
            k: (dataclasses.asdict(v) if dataclasses.is_dataclass(v) else v)
            for k, v in dataclasses.asdict(cfg).items()
        },
        "seed": args.seed,
        "params": param_table,
        "entries": w.entries,
    }
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] wrote {outdir}/manifest.json")


if __name__ == "__main__":
    main()

//! Integration tests over the analytical plane: cross-module trends that
//! the paper's figures depend on, plus property tests on simulator
//! invariants (monotonicity, normalization, conservation).

use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::{LlmConfig, Phase};
use halo::report;
use halo::sim::{simulate_e2e, simulate_phase, Scenario};
use halo::util::prop::{forall, OneOf, Triple, UsizeIn};
use halo::util::geomean;

fn hw() -> HwConfig {
    HwConfig::paper()
}

const ALL_MAPPINGS: [MappingKind; 8] = [
    MappingKind::Cent,
    MappingKind::AttAcc1,
    MappingKind::AttAcc2,
    MappingKind::Halo1,
    MappingKind::Halo2,
    MappingKind::FullCid,
    MappingKind::FullCim,
    MappingKind::HaloSa,
];

#[test]
fn e2e_latency_monotone_in_context_for_all_mappings() {
    let m = LlmConfig::llama2_7b();
    forall(
        3,
        50,
        Triple(UsizeIn(64, 4096), UsizeIn(64, 2048), OneOf(&ALL_MAPPINGS)),
        |(l_in, l_out, mk)| {
            let a =
                simulate_e2e(&m, &hw(), *mk, &Scenario { l_in: *l_in, l_out: *l_out, batch: 1 });
            let b = simulate_e2e(
                &m,
                &hw(),
                *mk,
                &Scenario { l_in: l_in + 64, l_out: *l_out, batch: 1 },
            );
            let c = simulate_e2e(
                &m,
                &hw(),
                *mk,
                &Scenario { l_in: *l_in, l_out: l_out + 64, batch: 1 },
            );
            a.e2e_latency() <= b.e2e_latency() + 1e-12
                && a.e2e_latency() <= c.e2e_latency() + 1e-12
                && a.e2e_energy() <= b.e2e_energy() + 1e-9
                && a.e2e_energy() <= c.e2e_energy() + 1e-9
        },
    );
}

#[test]
fn latency_and_energy_always_positive_and_finite() {
    let q = LlmConfig::qwen3_8b();
    forall(
        11,
        40,
        Triple(UsizeIn(1, 8192), UsizeIn(1, 4096), OneOf(&ALL_MAPPINGS)),
        |(l_in, l_out, mk)| {
            let r =
                simulate_e2e(&q, &hw(), *mk, &Scenario { l_in: *l_in, l_out: *l_out, batch: 1 });
            let vals = [r.ttft(), r.tpot(), r.e2e_latency(), r.e2e_energy()];
            vals.iter().all(|v| v.is_finite() && *v > 0.0)
        },
    );
}

#[test]
fn batch_increases_throughput_never_per_batch_latency_decrease() {
    // more sequences never finish faster in aggregate latency, but
    // per-sequence throughput improves (or stays flat) for every mapping
    let m = LlmConfig::llama2_7b();
    forall(7, 30, Triple(UsizeIn(1, 32), UsizeIn(64, 1024), OneOf(&ALL_MAPPINGS)), |(b, l, mk)| {
        let sc1 = Scenario { l_in: *l, l_out: 256, batch: *b };
        let sc2 = Scenario { l_in: *l, l_out: 256, batch: b * 2 };
        let r1 = simulate_e2e(&m, &hw(), *mk, &sc1);
        let r2 = simulate_e2e(&m, &hw(), *mk, &sc2);
        r2.e2e_latency() + 1e-12 >= r1.e2e_latency()
            && r2.e2e_latency() / (2.0 * b.max(&1) .clone() as f64)
                <= r1.e2e_latency() / *b as f64 + 1e-9
    });
}

#[test]
fn phase_aware_mapping_dominates_both_extremes() {
    // HALO1 should never lose to Fully-CiD or Fully-CiM on e2e latency
    let m = LlmConfig::llama2_7b();
    for (l_in, l_out) in report::context_grid() {
        let sc = Scenario { l_in, l_out, batch: 1 };
        let halo = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc).e2e_latency();
        let cid = simulate_e2e(&m, &hw(), MappingKind::FullCid, &sc).e2e_latency();
        let cim = simulate_e2e(&m, &hw(), MappingKind::FullCim, &sc).e2e_latency();
        assert!(halo <= cid * 1.0001 && halo <= cim * 1.0001, "({l_in},{l_out})");
    }
}

#[test]
fn fig7_headline_bands_hold_for_both_models() {
    // abstract claims: up to 18x vs AttAcc, 2.5x vs CENT; geomeans land in
    // the published bands for BOTH evaluated models
    for m in [LlmConfig::llama2_7b(), LlmConfig::qwen3_8b()] {
        let mut vs_att = Vec::new();
        let mut vs_cent = Vec::new();
        for (l_in, l_out) in report::context_grid() {
            let sc = Scenario { l_in, l_out, batch: 1 };
            let halo = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc).e2e_latency();
            vs_att.push(simulate_e2e(&m, &hw(), MappingKind::AttAcc1, &sc).e2e_latency() / halo);
            vs_cent.push(simulate_e2e(&m, &hw(), MappingKind::Cent, &sc).e2e_latency() / halo);
        }
        let ga = geomean(&vs_att);
        let gc = geomean(&vs_cent);
        assert!(ga > 10.0 && ga < 35.0, "{}: vs AttAcc1 {ga} (paper 18x)", m.name);
        assert!(gc > 1.5 && gc < 4.0, "{}: vs CENT {gc} (paper 2.4x)", m.name);
    }
}

#[test]
fn attacc_beats_halo_only_at_high_batch() {
    // Fig. 9 crossover: HALO1 wins up to batch 32, AttAcc1 by batch 64
    let m = LlmConfig::llama2_7b();
    let e2e = |mk: MappingKind, b: usize| {
        simulate_e2e(&m, &hw(), mk, &Scenario { l_in: 128, l_out: 2048, batch: b }).e2e_latency()
    };
    for b in [1usize, 2, 4, 8, 16, 32] {
        assert!(e2e(MappingKind::Halo1, b) < e2e(MappingKind::AttAcc1, b), "batch {b}");
    }
    assert!(e2e(MappingKind::AttAcc1, 64) < e2e(MappingKind::Halo1, 64));
}

#[test]
fn wordline_ablation_monotone() {
    // more aggressive wordline throttling monotonically slows prefill
    let m = LlmConfig::llama2_7b();
    let mut last = 0.0;
    for wl in [128usize, 64, 32, 16] {
        let mut hwc = hw();
        hwc.cim = hwc.cim.clone().with_wordlines(wl);
        let r = simulate_phase(&m, &hwc, MappingKind::FullCim, Phase::Prefill, 2048, 1);
        assert!(r.latency >= last, "wl {wl}");
        last = r.latency;
    }
}

#[test]
fn gb_bandwidth_ablation_decode_bound() {
    // fully-CiM decode is interposer/write bound: halving GB bandwidth
    // must hurt it, while CiD decode is unaffected
    let m = LlmConfig::llama2_7b();
    let mut slow = hw();
    // /8 pushes the per-round fill time past the crossbar-write bound
    slow.cim.gb_bw /= 8.0;
    slow.interposer.bw /= 8.0;
    let fast_cim = simulate_phase(&m, &hw(), MappingKind::FullCim, Phase::Decode, 1024, 1);
    let slow_cim = simulate_phase(&m, &slow, MappingKind::FullCim, Phase::Decode, 1024, 1);
    assert!(slow_cim.latency > 1.5 * fast_cim.latency);
    let fast_cid = simulate_phase(&m, &hw(), MappingKind::FullCid, Phase::Decode, 1024, 1);
    let slow_cid = simulate_phase(&m, &slow, MappingKind::FullCid, Phase::Decode, 1024, 1);
    assert!((slow_cid.latency / fast_cid.latency - 1.0).abs() < 1e-9);
}

#[test]
fn figure_tables_are_complete_and_consistent() {
    let tables = report::all_figures(&hw());
    assert_eq!(tables.len(), 8);
    for t in &tables {
        assert!(!t.rows.is_empty(), "{} empty", t.name);
        for r in &t.rows {
            assert_eq!(r.len(), t.headers.len(), "{} arity", t.name);
        }
    }
    // fig10: HALO-SA normalizes to itself
    let f10 = &tables[6];
    assert_eq!(f10.name, "fig10_cim_vs_sa");
    for row in f10.rows.iter().filter(|r| r[2] == "HALO-SA") {
        let norm: f64 = row[4].parse().unwrap();
        assert!((norm - 1.0).abs() < 1e-9);
    }
}

#[test]
fn kv_cache_pressure_shows_in_decode_latency() {
    // decode TPOT grows with context (attention streams the KV cache)
    let m = LlmConfig::llama2_7b();
    let t = |ctx: usize| {
        simulate_phase(&m, &hw(), MappingKind::Halo1, Phase::Decode, ctx, 1).latency
    };
    assert!(t(8192) > t(512) * 1.2);
    // and GQA (qwen) reduces the KV growth rate relative to MHA
    let q = LlmConfig::qwen3_8b();
    let tq = |ctx: usize| {
        simulate_phase(&q, &hw(), MappingKind::Halo1, Phase::Decode, ctx, 1).latency
    };
    let llama_growth = t(8192) - t(512);
    let qwen_growth = tq(8192) - tq(512);
    assert!(qwen_growth < llama_growth, "GQA must shrink KV traffic growth");
}

#[test]
fn energy_conservation_across_breakdowns() {
    let m = LlmConfig::qwen3_8b();
    let gen = Triple(UsizeIn(64, 4096), UsizeIn(64, 1024), OneOf(&ALL_MAPPINGS));
    forall(5, 20, gen, |(li, lo, mk)| {
        let r = simulate_e2e(&m, &hw(), *mk, &Scenario { l_in: *li, l_out: *lo, batch: 1 });
        let by_kind: f64 = r.prefill.by_kind.values().map(|c| c.energy).sum();
        let by_engine: f64 = r.prefill.by_engine.values().map(|c| c.energy).sum();
        (by_kind / r.prefill.energy - 1.0).abs() < 1e-9
            && (by_engine / r.prefill.energy - 1.0).abs() < 1e-9
    });
}

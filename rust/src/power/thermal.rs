//! Package thermal model: RC junction dynamics, a TDP throttle, and the
//! 2.5D co-packaging coupling into the HBM stacks.
//!
//! One HALO package is modeled as a single thermal RC node: junction
//! temperature relaxes toward `ambient + theta * power` with time
//! constant `tau`. The TDP cap maps to a temperature ceiling
//! (`ambient + theta * tdp`); while the junction sits above it, device
//! service is slowed by `ceiling_rise / actual_rise` — which makes the
//! *delivered* power converge onto exactly the TDP (energy per event is
//! fixed, so stretching an event by `1/f` scales its power by `f`). The
//! feedback is live: throttled events take longer on the simulated clock,
//! so throughput genuinely degrades as the cap tightens.
//!
//! 2.5D coupling: the CiM die and the HBM stacks share the interposer, so
//! a fraction of the junction rise appears on the DRAM. Above the JEDEC
//! hot threshold the refresh rate — and the refresh share of static power
//! — doubles, which feeds back into package power and hence temperature.

/// Thermal/TDP configuration of one package.
#[derive(Debug, Clone, PartialEq)]
pub struct ThermalConfig {
    /// Package TDP cap, W (the throttle target).
    pub tdp_w: f64,
    /// RC time constant of the package + heatsink, s.
    pub tau_s: f64,
    /// Junction-to-ambient thermal resistance, degC/W.
    pub theta_c_per_w: f64,
    pub ambient_c: f64,
    /// Floor on the service-rate factor (worst-case slowdown bound).
    pub min_throttle: f64,
    /// Fraction of the junction rise that appears on the co-packaged HBM
    /// stacks (2.5D coupling).
    pub hbm_coupling: f64,
    /// HBM temperature above which DRAM refresh doubles (JEDEC 2x band).
    pub hbm_refresh_temp_c: f64,
}

impl ThermalConfig {
    /// CALIBRATED package constants at a given TDP cap: 0.35 degC/W to
    /// ambient through a 2.5D package heatsink, a 2 s thermal time
    /// constant, 60% of the junction rise coupled into the stacks.
    pub fn paper(tdp_w: f64) -> Self {
        assert!(tdp_w > 0.0, "TDP cap must be positive");
        ThermalConfig {
            tdp_w,
            tau_s: 2.0,
            theta_c_per_w: 0.35,
            ambient_c: 25.0,
            min_throttle: 0.1,
            hbm_coupling: 0.6,
            hbm_refresh_temp_c: 85.0,
        }
    }
}

/// RC thermal state of one package, advanced event by event.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    pub cfg: ThermalConfig,
    temp_c: f64,
    /// Clock of the last update (device event time).
    clock: f64,
    /// High-water mark of the junction temperature.
    pub max_temp_c: f64,
}

impl ThermalModel {
    pub fn new(cfg: ThermalConfig) -> Self {
        let ambient = cfg.ambient_c;
        ThermalModel { cfg, temp_c: ambient, clock: 0.0, max_temp_c: ambient }
    }

    pub fn temp_c(&self) -> f64 {
        self.temp_c
    }

    /// HBM stack temperature: ambient plus the coupled junction rise.
    pub fn hbm_temp_c(&self) -> f64 {
        self.cfg.ambient_c + self.cfg.hbm_coupling * (self.temp_c - self.cfg.ambient_c)
    }

    /// Whether the stacks sit in the 2x-refresh band right now.
    pub fn hbm_hot(&self) -> bool {
        self.hbm_temp_c() >= self.cfg.hbm_refresh_temp_c
    }

    /// Service-rate factor in `(0, 1]`: 1 while the junction sits at or
    /// below the TDP temperature ceiling, `ceiling_rise / rise` above it
    /// (clamped at `min_throttle`).
    pub fn throttle_factor(&self) -> f64 {
        let rise = self.temp_c - self.cfg.ambient_c;
        let limit = self.cfg.theta_c_per_w * self.cfg.tdp_w;
        if rise <= limit {
            1.0
        } else {
            (limit / rise).max(self.cfg.min_throttle)
        }
    }

    /// Cool toward the idle steady state over any gap between the last
    /// event and `t` (idle power = the static floor).
    pub fn advance_idle(&mut self, t: f64, idle_w: f64) {
        if t > self.clock {
            let dt = t - self.clock;
            self.relax(dt, idle_w);
            self.clock = t;
        }
    }

    /// Heat over a busy event of duration `dt` at mean power `p_w`.
    pub fn heat(&mut self, dt: f64, p_w: f64) {
        self.relax(dt, p_w);
        self.clock += dt;
        self.max_temp_c = self.max_temp_c.max(self.temp_c);
    }

    fn relax(&mut self, dt: f64, p_w: f64) {
        let t_ss = self.cfg.ambient_c + self.cfg.theta_c_per_w * p_w;
        let a = (-dt / self.cfg.tau_s).exp();
        self.temp_c = t_ss + (self.temp_c - t_ss) * a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heats_toward_steady_state_and_cools_back() {
        let mut th = ThermalModel::new(ThermalConfig::paper(100.0));
        assert_eq!(th.throttle_factor(), 1.0);
        // long burn at 200 W -> essentially steady state
        th.heat(100.0, 200.0);
        let t_ss = 25.0 + 0.35 * 200.0;
        assert!((th.temp_c() - t_ss).abs() < 1e-6, "{}", th.temp_c());
        assert!(th.max_temp_c >= th.temp_c());
        // above the 100 W ceiling: throttle = tdp/power at steady state
        assert!((th.throttle_factor() - 0.5).abs() < 1e-6, "{}", th.throttle_factor());
        // long idle at a 16 W floor cools most of the way back
        th.advance_idle(th.clock + 100.0, 16.0);
        assert!(th.temp_c() < 25.0 + 0.35 * 16.0 + 1e-6);
        assert_eq!(th.throttle_factor(), 1.0);
    }

    #[test]
    fn rc_is_gradual_not_instant() {
        let mut th = ThermalModel::new(ThermalConfig::paper(100.0));
        th.heat(0.5, 200.0); // quarter of a time constant
        let t_ss = 25.0 + 0.35 * 200.0;
        assert!(th.temp_c() > 25.0 + 5.0 && th.temp_c() < t_ss - 5.0, "{}", th.temp_c());
    }

    #[test]
    fn tighter_tdp_throttles_harder_at_equal_temperature() {
        let mut hot = ThermalModel::new(ThermalConfig::paper(150.0));
        hot.heat(100.0, 200.0);
        let mut tight = ThermalModel::new(ThermalConfig::paper(75.0));
        tight.heat(100.0, 200.0);
        assert!((hot.temp_c() - tight.temp_c()).abs() < 1e-9);
        assert!(tight.throttle_factor() < hot.throttle_factor());
        assert!((tight.throttle_factor() / hot.throttle_factor() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn min_throttle_bounds_the_slowdown() {
        let mut th = ThermalModel::new(ThermalConfig::paper(1.0));
        th.heat(100.0, 500.0);
        assert_eq!(th.throttle_factor(), 0.1);
    }

    #[test]
    fn hbm_coupling_reaches_the_refresh_band_under_sustained_load() {
        // junction at 200 W steady state = 95 C -> HBM at 25 + 0.6*70 = 67:
        // below the default 85 C band...
        let mut th = ThermalModel::new(ThermalConfig::paper(300.0));
        th.heat(100.0, 200.0);
        assert!(!th.hbm_hot());
        // ...but a tighter refresh threshold (poorly cooled deployment)
        // lands in the 2x band at the same load
        let mut cfg = ThermalConfig::paper(300.0);
        cfg.hbm_refresh_temp_c = 60.0;
        let mut th = ThermalModel::new(cfg);
        th.heat(100.0, 200.0);
        assert!(th.hbm_hot());
        assert!((th.hbm_temp_c() - (25.0 + 0.6 * 70.0)).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn paper_config_rejects_nonpositive_tdp() {
        ThermalConfig::paper(0.0);
    }
}

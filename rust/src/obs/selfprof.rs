//! Simulator self-profiling: wall-clock time and work counters per
//! stage, so hot paths are measurable before they are optimized.
//!
//! Wall times are host measurements (`std::time::Instant`), never part
//! of any simulated quantity — they live in a side struct precisely so
//! determinism guarantees over simulation results are untouched.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Debug, Clone, Default)]
pub struct SelfProfile {
    wall: BTreeMap<&'static str, f64>,
    counts: BTreeMap<&'static str, u64>,
}

impl SelfProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, accumulating its wall time under `key` and bumping the
    /// same-named counter by one invocation.
    pub fn time<R>(&mut self, key: &'static str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        *self.wall.entry(key).or_insert(0.0) += t0.elapsed().as_secs_f64();
        *self.counts.entry(key).or_insert(0) += 1;
        r
    }

    pub fn add(&mut self, key: &'static str, n: u64) {
        *self.counts.entry(key).or_insert(0) += n;
    }

    pub fn wall_s(&self, key: &str) -> f64 {
        self.wall.get(key).copied().unwrap_or(0.0)
    }

    pub fn count(&self, key: &str) -> u64 {
        self.counts.get(key).copied().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let wall: BTreeMap<String, Json> =
            self.wall.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect();
        let counts: BTreeMap<String, Json> =
            self.counts.iter().map(|(k, v)| (k.to_string(), Json::Num(*v as f64))).collect();
        let mut m = BTreeMap::new();
        m.insert("wall_s".to_string(), Json::Obj(wall));
        m.insert("counts".to_string(), Json::Obj(counts));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates_and_counts() {
        let mut p = SelfProfile::new();
        let x = p.time("work", || 7);
        assert_eq!(x, 7);
        p.time("work", || ());
        p.add("walks", 3);
        assert_eq!(p.count("work"), 2);
        assert_eq!(p.count("walks"), 3);
        assert!(p.wall_s("work") >= 0.0);
        assert_eq!(p.wall_s("missing"), 0.0);
        let j = p.to_json();
        assert!(j.path(&["counts", "walks"]).is_some());
    }
}

"""Pure-jnp correctness oracles for the HALO functional kernels.

These implement the *hardware math spec* of the two compute substrates:

* ``cim_matmul_ref``  — the analog CiM crossbar: weights bit-sliced at
  2 bits/cell across crossbars, inputs bit-streamed 1 bit/cycle, partial
  sums digitized by a 7-bit SAR ADC per column group, shift-and-add
  recombination, and digital offset corrections (weights/inputs are mapped
  to the unsigned domain before slicing, as in typical CiM macros).
* ``cid_gemv_ref``    — the CiD bank-level unit: exact int8 multiplies with
  exact integer accumulation in the in-bank reduction tree.

The Pallas kernels in ``cim_matmul.py`` / ``cid_gemv.py`` must match these
*exactly* (integer code equality), because both follow the same spec; this
module is deliberately written in plain vectorized jnp, without Pallas, so
the two implementations are independent.

The fake-quantized float wrappers (``cim_linear_ref`` / ``cid_linear_ref``)
are what the L2 model uses conceptually: per-tensor symmetric int8
quantization around the integer kernels.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Crossbar geometry fixed by the paper (Table I): 128x128 arrays.
XBAR_ROWS = 128


@dataclasses.dataclass(frozen=True)
class CimSpec:
    """Configuration of the analog CiM functional model.

    Mirrors Table I / Section IV-A of the paper:
      * ``input_bits``  — bit-serial input stream length (8-bit activations).
      * ``slice_bits``  — weight bits stored per cell (2 b/cell => an 8-bit
        weight spans 4 crossbars).
      * ``weight_bits`` — total weight precision (8).
      * ``adc_bits``    — SAR ADC resolution (7).
      * ``wordlines``   — rows activated simultaneously: 128 for HALO1 /
        AttAcc1, 64 for HALO2 / AttAcc2 (less analog error, 2x ADC reads).
      * ``adc_mode``    — ``"full"``: ADC spans the worst-case partial-sum
        range [0, wordlines*slice_max] (classic ISAAC-style sizing);
        ``"calibrated"``: the adaptive-SNR scheme of the paper's CiM macro
        reference [1] (Ali et al., CICC'23) — per-column ADC range
        calibrated to the expected partial-sum distribution (mean
        rho*colsum(w_slice), +/-4 sigma with sigma from Bernoulli(rho)
        input-bit statistics), trading rare clips for a much finer grid.
      * ``ideal``       — bypass ADC quantization (infinite-precision ADC);
        used to isolate quantization error in tests.
    """

    input_bits: int = 8
    slice_bits: int = 2
    weight_bits: int = 8
    adc_bits: int = 7
    wordlines: int = 128
    adc_mode: str = "full"
    ideal: bool = False

    @property
    def num_slices(self) -> int:
        assert self.weight_bits % self.slice_bits == 0
        return self.weight_bits // self.slice_bits

    @property
    def slice_max(self) -> int:
        return (1 << self.slice_bits) - 1

    @property
    def adc_levels(self) -> int:
        return (1 << self.adc_bits) - 1

    @property
    def phases_per_block(self) -> int:
        """Wordline activation phases needed to cover one 128-row block."""
        assert XBAR_ROWS % self.wordlines == 0
        return XBAR_ROWS // self.wordlines

    @property
    def adc_delta(self) -> float:
        """ADC quantization step: full range of one analog accumulation
        (``wordlines`` rows, each contributing at most ``slice_max``) mapped
        onto ``adc_levels`` codes."""
        return (self.wordlines * self.slice_max) / self.adc_levels


HALO1_SPEC = CimSpec(wordlines=128)
HALO2_SPEC = CimSpec(wordlines=64)
# Adaptive-SNR configuration used for the functional L2 model: calibrated
# per-column ADC ranges as in the macro the paper builds on [1].
MODEL_SPEC = CimSpec(wordlines=128, adc_mode="calibrated")

# Input-bit density the calibrated ADC ranges are trimmed for.
_CAL_RHO = 0.5
# Calibrated range half-width in sigmas of the expected partial-sum
# distribution; +/-4 sigma keeps clipping rare for near-Bernoulli bits.
_CAL_NSIGMA = 4.0


def _to_unsigned(a_i8: jnp.ndarray) -> jnp.ndarray:
    """Map signed int8 values into the unsigned [0, 255] cell domain."""
    return a_i8.astype(jnp.int32) + 128


def adc_quantize(p: jnp.ndarray, spec: CimSpec) -> jnp.ndarray:
    """Digitize an analog partial sum ``p`` (in MAC units) to ADC codes.

    Returns integer codes in [0, adc_levels]; the caller scales by
    ``spec.adc_delta``. In ``ideal`` mode the 'code' is the exact partial
    sum (delta == 1 semantics handled by the caller).
    """
    if spec.ideal:
        return p.astype(jnp.int32)
    delta = spec.adc_delta
    q = jnp.round(p.astype(jnp.float32) / delta)
    return jnp.clip(q, 0, spec.adc_levels).astype(jnp.int32)


def cim_matmul_codes_ref(
    x_i8: jnp.ndarray, w_i8: jnp.ndarray, spec: CimSpec = HALO1_SPEC
) -> jnp.ndarray:
    """Unsigned-domain crossbar accumulation, returned as integer codes.

    x_i8: (M, K) int8, w_i8: (K, N) int8; K must be a multiple of 128
    (one crossbar row-block per 128 rows — callers pad).

    Returns int32 codes such that
      X_u @ W_u ~= codes * spec.adc_delta      (== codes exactly when ideal)
    where X_u = x+128, W_u = w+128.
    """
    m, k = x_i8.shape
    k2, n = w_i8.shape
    assert k == k2 and k % XBAR_ROWS == 0, (k, k2)
    assert spec.ideal or spec.adc_mode == "full", "codes are full-mode only"
    x_u = _to_unsigned(x_i8)  # (M, K) in [0, 255]
    w_u = _to_unsigned(w_i8)  # (K, N) in [0, 255]

    bits = jnp.arange(spec.input_bits, dtype=jnp.int32)
    slices = jnp.arange(spec.num_slices, dtype=jnp.int32)
    # (B, M, K) binary input planes and (S, K, N) weight slice planes.
    x_planes = (x_u[None, :, :] >> bits[:, None, None]) & 1
    w_planes = (w_u[None, :, :] >> (spec.slice_bits * slices[:, None, None])) & spec.slice_max

    # shift-and-add weights for recombining (bit, slice) partials
    weight = (1 << bits)[:, None, None, None] * (
        1 << (spec.slice_bits * slices)[None, :, None, None]
    )

    total = jnp.zeros((m, n), dtype=jnp.int32)
    n_blocks = k // XBAR_ROWS
    phase_rows = spec.wordlines
    for blk in range(n_blocks):
        lo = blk * XBAR_ROWS
        for ph in range(spec.phases_per_block):
            rlo = lo + ph * phase_rows
            xs = x_planes[:, :, rlo : rlo + phase_rows].astype(jnp.float32)
            ws = w_planes[:, rlo : rlo + phase_rows, :].astype(jnp.float32)
            # analog accumulation: one dot per (input bit, weight slice)
            p = jnp.einsum("bmk,skn->bsmn", xs, ws)
            codes = adc_quantize(p, spec)
            total = total + jnp.sum(codes * weight, axis=(0, 1), dtype=jnp.int32)
    return total


def cim_matmul_unsigned_ref(
    x_i8: jnp.ndarray, w_i8: jnp.ndarray, spec: CimSpec = HALO1_SPEC
) -> jnp.ndarray:
    """Float estimate of X_u @ W_u through the ADC pipeline (any mode)."""
    if spec.ideal or spec.adc_mode == "full":
        codes = cim_matmul_codes_ref(x_i8, w_i8, spec)
        delta = 1.0 if spec.ideal else spec.adc_delta
        return codes.astype(jnp.float32) * jnp.float32(delta)

    assert spec.adc_mode == "calibrated", spec.adc_mode
    m, k = x_i8.shape
    _, n = w_i8.shape
    assert k % XBAR_ROWS == 0
    x_u = _to_unsigned(x_i8)
    w_u = _to_unsigned(w_i8)
    bits = jnp.arange(spec.input_bits, dtype=jnp.int32)
    slices = jnp.arange(spec.num_slices, dtype=jnp.int32)
    x_planes = (x_u[None, :, :] >> bits[:, None, None]) & 1
    w_planes = (w_u[None, :, :] >> (spec.slice_bits * slices[:, None, None])) & spec.slice_max
    saa = (
        (1 << bits)[:, None, None, None]
        * (1 << (spec.slice_bits * slices))[None, :, None, None]
    ).astype(jnp.float32)
    half = 1 << (spec.adc_bits - 1)

    total = jnp.zeros((m, n), dtype=jnp.float32)
    for blk in range(k // XBAR_ROWS):
        lo = blk * XBAR_ROWS
        for ph in range(spec.phases_per_block):
            rlo = lo + ph * spec.wordlines
            xs = x_planes[:, :, rlo : rlo + spec.wordlines].astype(jnp.float32)
            ws = w_planes[:, rlo : rlo + spec.wordlines, :].astype(jnp.float32)
            p = jnp.einsum("bmk,skn->bsmn", xs, ws)
            # per-(slice, column) calibrated range: mean rho*colsum(w),
            # half-width NSIGMA * sqrt(rho(1-rho) * colsum(w^2))
            center = _CAL_RHO * jnp.sum(ws, axis=1)[:, None, :]  # (S,1,N)
            sigma = jnp.sqrt(_CAL_RHO * (1 - _CAL_RHO) * jnp.sum(ws * ws, axis=1))
            delta = jnp.maximum(2.0 * _CAL_NSIGMA * sigma / (2 * half), 1e-6)
            delta = delta[:, None, :]  # (S,1,N)
            q = jnp.clip(jnp.round((p - center[None]) / delta[None]), -half, half - 1)
            val = center[None] + q * delta[None]
            total = total + jnp.sum(val * saa, axis=(0, 1))
    return total


def cim_matmul_ref(
    x_i8: jnp.ndarray, w_i8: jnp.ndarray, spec: CimSpec = HALO1_SPEC
) -> jnp.ndarray:
    """Full signed CiM matmul (float result of the analog pipeline).

    Y = X @ W computed as the ADC estimate of X_u @ W_u minus exact digital
    offset corrections:
      X@W = X_u@W_u - 128*rowsum(X_u) - 128*colsum(W_u) + 128^2*K
    (rowsum/colsum corrections are exact digital ops in the hardware).
    """
    k = x_i8.shape[1]
    y_u = cim_matmul_unsigned_ref(x_i8, w_i8, spec)
    xu_rowsum = jnp.sum(_to_unsigned(x_i8), axis=1, keepdims=True)  # (M,1)
    wu_colsum = jnp.sum(_to_unsigned(w_i8), axis=0, keepdims=True)  # (1,N)
    return y_u - 128.0 * xu_rowsum - 128.0 * wu_colsum + 128.0 * 128.0 * k


def cid_gemv_ref(x_i8: jnp.ndarray, w_i8: jnp.ndarray) -> jnp.ndarray:
    """Exact int8 GEMV/GEMM of the CiD bank units (int32 accumulate)."""
    return jnp.matmul(
        x_i8.astype(jnp.int32),
        w_i8.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


# ---------------------------------------------------------------------------
# Fake-quantized float wrappers (what the L2 model math looks like).
# ---------------------------------------------------------------------------


def quantize_sym_i8(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(a)), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(a / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pad_k(x_i8: jnp.ndarray, w_i8: jnp.ndarray):
    """Pad the contraction dim to a multiple of the crossbar height.

    Padding uses value -128 (unsigned-domain 0), which contributes *zero*
    to every bit/slice plane — so it adds no ADC noise — and a known exact
    constant 128*128*n_pad to the signed product, subtracted by callers.
    """
    k = x_i8.shape[1]
    k_pad = (-k) % XBAR_ROWS
    if k_pad == 0:
        return x_i8, w_i8, 0
    xp = jnp.pad(x_i8, ((0, 0), (0, k_pad)), constant_values=-128)
    wp = jnp.pad(w_i8, ((0, k_pad), (0, 0)), constant_values=-128)
    return xp, wp, k_pad


def cim_linear_ref(
    x: jnp.ndarray, w: jnp.ndarray, spec: CimSpec = HALO1_SPEC
) -> jnp.ndarray:
    """Float x @ w through the analog CiM path (fake-quantized)."""
    qx, sx = quantize_sym_i8(x)
    qw, sw = quantize_sym_i8(w)
    qxp, qwp, k_pad = pad_k(qx, qw)
    y = cim_matmul_ref(qxp, qwp, spec)
    y = y - 128.0 * 128.0 * k_pad  # remove the exact padding constant
    return y * (sx * sw)


def cid_linear_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Float x @ w through the exact digital CiD int8 path."""
    qx, sx = quantize_sym_i8(x)
    qw, sw = quantize_sym_i8(w)
    y = cid_gemv_ref(qx, qw).astype(jnp.float32)
    return y * (sx * sw)

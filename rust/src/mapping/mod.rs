//! Mapping strategies (Table II): which substrate runs each operation in
//! each phase. This is the paper's system-level contribution — the
//! phase-aware mapping — plus every baseline it is compared against.

use crate::arch::EngineSel;
use crate::model::{Op, OpClass, Phase};

/// The mapping configurations of Table II plus the §V-B extremes and the
/// §V-D systolic ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingKind {
    /// Everything on the CiD accelerator, both phases (CENT [12]).
    Cent,
    /// Prefill on CiM (128 wordlines); decode: attention on CiD, all other
    /// ops on the accelerator die (AttAcc [21]).
    AttAcc1,
    /// AttAcc with 64 active wordlines.
    AttAcc2,
    /// Phase-aware (ours): prefill on CiM (128 wl), decode on CiD.
    Halo1,
    /// Phase-aware with 64 active wordlines.
    Halo2,
    /// §V-B extreme: everything on CiD (same routing as CENT; kept
    /// distinct for reporting).
    FullCid,
    /// §V-B extreme: everything on the analog CiM die.
    FullCim,
    /// §V-D ablation: HALO with the analog CiM replaced by iso-area
    /// digital systolic arrays (NeuPIM-style).
    HaloSa,
}

impl MappingKind {
    pub fn name(&self) -> &'static str {
        match self {
            MappingKind::Cent => "CENT",
            MappingKind::AttAcc1 => "AttAcc1",
            MappingKind::AttAcc2 => "AttAcc2",
            MappingKind::Halo1 => "HALO1",
            MappingKind::Halo2 => "HALO2",
            MappingKind::FullCid => "Fully-CiD",
            MappingKind::FullCim => "Fully-CiM",
            MappingKind::HaloSa => "HALO-SA",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        let norm: String = s.to_ascii_lowercase().chars().filter(|c| *c != '-').collect();
        match norm.as_str() {
            "cent" => Some(Self::Cent),
            "attacc1" => Some(Self::AttAcc1),
            "attacc2" => Some(Self::AttAcc2),
            "halo1" => Some(Self::Halo1),
            "halo2" => Some(Self::Halo2),
            "fullcid" | "fullycid" | "cid" => Some(Self::FullCid),
            "fullcim" | "fullycim" | "cim" => Some(Self::FullCim),
            "halosa" | "sa" => Some(Self::HaloSa),
            _ => None,
        }
    }

    /// Mappings worth searching over for *unified* fleet devices in the
    /// `dse` plane: the phase-aware points plus the §V-B extremes (the
    /// AttAcc baselines are strictly dominated on decode and only clutter
    /// a search).
    pub fn dse_unified() -> &'static [MappingKind] {
        &[
            MappingKind::Halo1,
            MappingKind::Halo2,
            MappingKind::HaloSa,
            MappingKind::FullCid,
            MappingKind::FullCim,
        ]
    }

    /// All Table II mappings compared in Figs. 7-8.
    pub fn table2() -> &'static [MappingKind] {
        &[
            MappingKind::AttAcc1,
            MappingKind::AttAcc2,
            MappingKind::Cent,
            MappingKind::Halo1,
            MappingKind::Halo2,
        ]
    }

    /// Active wordlines for the CiM config under this mapping.
    pub fn wordlines(&self) -> usize {
        match self {
            MappingKind::AttAcc2 | MappingKind::Halo2 => 64,
            _ => 128,
        }
    }

    /// Route one operation. Non-GEMM ops always go to the logic-die
    /// vector/exponent/scalar units (paper §IV-B).
    pub fn assign(&self, op: &Op, phase: Phase) -> EngineSel {
        if !op.is_matmul() {
            return EngineSel::LogicDie;
        }
        match self {
            MappingKind::Cent | MappingKind::FullCid => EngineSel::Cid,
            MappingKind::FullCim => EngineSel::Cim,
            MappingKind::Halo1 | MappingKind::Halo2 => match phase {
                Phase::Prefill => EngineSel::Cim,
                Phase::Decode => EngineSel::Cid,
            },
            MappingKind::HaloSa => match phase {
                Phase::Prefill => EngineSel::Systolic,
                Phase::Decode => EngineSel::Cid,
            },
            MappingKind::AttAcc1 | MappingKind::AttAcc2 => match phase {
                Phase::Prefill => EngineSel::Cim,
                Phase::Decode => {
                    if op.class == OpClass::Attention {
                        EngineSel::Cid
                    } else {
                        EngineSel::Cim
                    }
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig};

    fn weight_op() -> Op {
        use crate::model::{OpKind, Operand};
        Op::matmul(OpKind::FfnUp, OpClass::Gemv, Operand::StaticWeight, 1, 4096, 4096, 1)
    }

    fn attn_op() -> Op {
        use crate::model::{OpKind, Operand};
        Op::matmul(OpKind::AttnScore, OpClass::Attention, Operand::Dynamic, 1, 128, 2048, 32)
    }

    #[test]
    fn table2_routing_rules() {
        // CENT: everything CiD
        assert_eq!(MappingKind::Cent.assign(&weight_op(), Phase::Prefill), EngineSel::Cid);
        assert_eq!(MappingKind::Cent.assign(&attn_op(), Phase::Decode), EngineSel::Cid);
        // HALO: phase split
        assert_eq!(MappingKind::Halo1.assign(&weight_op(), Phase::Prefill), EngineSel::Cim);
        assert_eq!(MappingKind::Halo1.assign(&weight_op(), Phase::Decode), EngineSel::Cid);
        assert_eq!(MappingKind::Halo1.assign(&attn_op(), Phase::Decode), EngineSel::Cid);
        // AttAcc: decode attention only on CiD
        assert_eq!(MappingKind::AttAcc1.assign(&attn_op(), Phase::Decode), EngineSel::Cid);
        assert_eq!(MappingKind::AttAcc1.assign(&weight_op(), Phase::Decode), EngineSel::Cim);
        assert_eq!(MappingKind::AttAcc1.assign(&weight_op(), Phase::Prefill), EngineSel::Cim);
        // HALO-SA: systolic prefill
        assert_eq!(MappingKind::HaloSa.assign(&weight_op(), Phase::Prefill), EngineSel::Systolic);
        assert_eq!(MappingKind::HaloSa.assign(&weight_op(), Phase::Decode), EngineSel::Cid);
    }

    #[test]
    fn wordline_configs() {
        assert_eq!(MappingKind::Halo1.wordlines(), 128);
        assert_eq!(MappingKind::Halo2.wordlines(), 64);
        assert_eq!(MappingKind::AttAcc2.wordlines(), 64);
        assert_eq!(MappingKind::Cent.wordlines(), 128);
    }

    #[test]
    fn nongemm_always_logic_die() {
        let m = LlmConfig::llama2_7b();
        let graphs = [build_prefill_graph(&m, 128, 1), build_decode_graph(&m, 128, 1)];
        for mk in [
            MappingKind::Cent,
            MappingKind::Halo1,
            MappingKind::AttAcc1,
            MappingKind::FullCim,
            MappingKind::HaloSa,
        ] {
            for g in &graphs {
                for op in g.non_gemm_ops() {
                    assert_eq!(mk.assign(op, g.phase), EngineSel::LogicDie);
                }
            }
        }
    }

    #[test]
    fn every_op_gets_exactly_one_engine() {
        // total-coverage invariant: assign() is total over all graphs
        let m = LlmConfig::qwen3_8b();
        for g in [build_prefill_graph(&m, 512, 2), build_decode_graph(&m, 512, 2)] {
            for mk in [
                MappingKind::Cent,
                MappingKind::AttAcc1,
                MappingKind::AttAcc2,
                MappingKind::Halo1,
                MappingKind::Halo2,
                MappingKind::FullCid,
                MappingKind::FullCim,
                MappingKind::HaloSa,
            ] {
                for op in &g.ops {
                    let _ = mk.assign(op, g.phase); // must not panic
                }
            }
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for mk in [MappingKind::Cent, MappingKind::Halo1, MappingKind::HaloSa] {
            assert_eq!(MappingKind::by_name(mk.name()), Some(mk));
        }
        assert!(MappingKind::by_name("gpu").is_none());
    }
}

//! Power-plane tables: the Fully-CiD / Fully-CiM / HALO energy-per-token
//! comparison on a mixed workload, a power-over-time breakdown, and the
//! TDP throttling sweep (`halo report --fig power`).

use super::{f, Table};
use crate::cluster::{Fleet, FleetBuilder, Interconnect, Mix, Router};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::power::{power_trace, DvfsConfig, ThermalConfig};
use crate::sim::queueing::TraceRequest;

const SLOTS: usize = 8;
const N_REQ: usize = 96;

/// The three §V-B mapping points every power table compares.
pub fn extreme_mappings() -> [MappingKind; 3] {
    [MappingKind::FullCid, MappingKind::FullCim, MappingKind::Halo1]
}

/// Replay `trace` on one power-tracked device running `mapping`.
fn powered_replay(
    hw: &HwConfig,
    llm: &LlmConfig,
    mapping: MappingKind,
    thermal: Option<ThermalConfig>,
    trace: &[TraceRequest],
) -> (Fleet, crate::cluster::FleetResult) {
    let mut fleet = FleetBuilder::new(llm, hw)
        .heterogeneous(&[mapping])
        .slots(SLOTS)
        .interconnect(Interconnect::board())
        .power(thermal)
        .build();
    let mut router: Box<dyn Router> = crate::cluster::Policy::LeastLoaded.router();
    let r = fleet.replay(trace, router.as_mut());
    (fleet, r)
}

/// Energy-per-token on the mixed (interactive) workload: the paper's
/// §V-B energy argument at serving granularity. The phase-aware mapping
/// picks the cheaper engine per phase, so it must rank at or below both
/// architectural extremes — the `rank_by_ept` column pins that.
pub fn power_extremes(hw: &HwConfig) -> Table {
    let t1 = super::cluster::single_device_capacity(
        hw,
        &LlmConfig::llama2_7b(),
        Mix::Interactive,
        SLOTS,
    );
    power_extremes_at(hw, t1)
}

/// [`power_extremes`] with the single-device capacity `t1` already
/// measured (callers generating several power tables calibrate once).
pub fn power_extremes_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let rate = 1.25 * t1;
    let trace = mix.trace(51, N_REQ, rate);
    let tokens: u64 = trace.iter().map(|q| q.l_out as u64).sum();
    let mut t = Table::new(
        "power_extremes",
        &format!(
            "Energy per token — Fully-CiD vs Fully-CiM vs HALO1, single device, \
             {} mix, offered {rate:.2} req/s",
            mix.name()
        ),
        &[
            "mapping",
            "energy_per_token_j",
            "e_dram_j",
            "e_compute_j",
            "e_buffer_j",
            "e_write_j",
            "e_static_j",
            "avg_power_w",
            "peak_power_w",
            "served_rps",
            "rank_by_ept",
        ],
    );
    let runs: Vec<_> = extreme_mappings()
        .iter()
        .map(|&mk| {
            let (_, r) = powered_replay(hw, &llm, mk, None, &trace);
            (mk, r)
        })
        .collect();
    let mut order: Vec<usize> = (0..runs.len()).collect();
    order.sort_by(|&a, &b| {
        runs[a].1.energy_per_token(tokens).total_cmp(&runs[b].1.energy_per_token(tokens))
    });
    for (i, (mk, r)) in runs.iter().enumerate() {
        let rank = order.iter().position(|&j| j == i).unwrap() + 1;
        t.row(vec![
            mk.name().into(),
            f(r.energy_per_token(tokens)),
            f(r.energy.e_dram),
            f(r.energy.e_compute),
            f(r.energy.e_buffer),
            f(r.energy.e_write),
            f(r.energy.e_static),
            f(r.avg_power_w()),
            f(r.peak_power_w),
            f(r.throughput_rps()),
            rank.to_string(),
        ]);
    }
    t
}

/// Windowed power-over-time breakdown of the same three replays: each
/// mapping's makespan is split into 16 windows of average watts — the
/// "power over time" panel of the energy comparison.
pub fn power_timeline(hw: &HwConfig) -> Table {
    let t1 = super::cluster::single_device_capacity(
        hw,
        &LlmConfig::llama2_7b(),
        Mix::Interactive,
        SLOTS,
    );
    power_timeline_at(hw, t1)
}

/// [`power_timeline`] with the single-device capacity already measured.
pub fn power_timeline_at(hw: &HwConfig, t1: f64) -> Table {
    const WINDOWS: usize = 16;
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let rate = 1.25 * t1;
    let trace = mix.trace(51, N_REQ, rate);
    let mut t = Table::new(
        "power_timeline",
        &format!(
            "Power over time — {WINDOWS} windows per mapping, single device, {} mix",
            mix.name()
        ),
        &["mapping", "window", "t_start_s", "t_end_s", "avg_w"],
    );
    for mk in extreme_mappings() {
        let (fleet, r) = powered_replay(hw, &llm, mk, None, &trace);
        let pw = fleet.devices[0].power().expect("power tracking enabled");
        let trace_w = power_trace(&pw.events, pw.static_power(false), r.makespan, WINDOWS);
        for (w, &avg) in trace_w.avg_w.iter().enumerate() {
            t.row(vec![
                mk.name().into(),
                w.to_string(),
                f(w as f64 * trace_w.window_s),
                f((w + 1) as f64 * trace_w.window_s),
                f(avg),
            ]);
        }
    }
    t
}

/// Saturated throughput vs TDP cap on one HALO1 device (burst trace, so
/// served rate == capacity): the throttling feedback is live — tighter
/// caps must cost real throughput, not just report a flag.
pub fn tdp_throttling(hw: &HwConfig) -> Table {
    tdp_throttling_at(hw, &[0.0, 150.0, 100.0, 60.0])
}

/// [`tdp_throttling`] over an explicit cap sweep (0 = uncapped),
/// tightest last.
pub fn tdp_throttling_at(hw: &HwConfig, caps_w: &[f64]) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Generation; // decode-heavy: the high-power phase
    let trace = mix.trace(53, 64, 1.0e6);
    let mut t = Table::new(
        "power_tdp_throttling",
        "Saturated throughput vs package TDP cap — single HALO1 device, generation mix",
        &[
            "tdp_w",
            "served_rps",
            "makespan_s",
            "avg_power_w",
            "peak_power_w",
            "throttled_s",
            "max_temp_c",
        ],
    );
    for &cap in caps_w {
        let thermal = (cap > 0.0).then(|| ThermalConfig::paper(cap));
        let (fleet, r) = powered_replay(hw, &llm, MappingKind::Halo1, thermal, &trace);
        let max_temp = fleet.devices[0]
            .power()
            .and_then(|pw| pw.thermal.as_ref())
            .map_or(f64::NAN, |th| th.max_temp_c);
        t.row(vec![
            format!("{cap}"),
            f(r.throughput_rps()),
            f(r.makespan),
            f(r.avg_power_w()),
            f(r.peak_power_w),
            f(r.throttled_s),
            if max_temp.is_nan() { "-".into() } else { format!("{max_temp:.1}") },
        ]);
    }
    t
}

/// Replay a saturating burst on one power-tracked HALO1 device at the
/// given per-phase DVFS point.
fn dvfs_replay(
    hw: &HwConfig,
    trace: &[TraceRequest],
    prefill_idx: usize,
    decode_idx: usize,
) -> crate::cluster::FleetResult {
    let llm = LlmConfig::llama2_7b();
    let mut fleet = FleetBuilder::new(&llm, hw)
        .heterogeneous(&[MappingKind::Halo1])
        .slots(SLOTS)
        .interconnect(Interconnect::board())
        .power(None)
        .dvfs(DvfsConfig::with_indices(&hw.power, prefill_idx, decode_idx))
        .build();
    let mut router: Box<dyn Router> = crate::cluster::Policy::LeastLoaded.router();
    fleet.replay(trace, router.as_mut())
}

/// The DVFS ladder on the prefill-dominated summarization mix (both
/// phases pinned to the same point): stepping down strictly cuts peak
/// power but cannot cut energy per token — compute-bound prefill pays
/// the stretched static-time penalty for a modest CV^2 saving.
pub fn dvfs_ladder(hw: &HwConfig) -> Table {
    let trace = Mix::Summarization.trace(57, 24, 1.0e6);
    let tokens: u64 = trace.iter().map(|q| q.l_out as u64).sum();
    let mut t = Table::new(
        "power_dvfs_ladder",
        "DVFS ladder — single HALO1 device, summarization burst (prefill-dominated): \
         lower points cut peak power, never energy per token",
        &[
            "dvfs",
            "f_scale",
            "v_scale",
            "energy_per_token_j",
            "avg_power_w",
            "peak_power_w",
            "ttft_p50_s",
            "served_rps",
        ],
    );
    for (i, p) in hw.power.dvfs_points.iter().enumerate() {
        let r = dvfs_replay(hw, &trace, i, i);
        t.row(vec![
            p.name.into(),
            f(p.f_scale),
            f(p.v_scale),
            f(r.energy_per_token(tokens)),
            f(r.avg_power_w()),
            f(r.peak_power_w),
            f(r.ttft_p50()),
            f(r.throughput_rps()),
        ]);
    }
    t
}

/// Per-phase DVFS split on the decode-dominated generation mix: pinning
/// only decode to the eco point cuts energy per token below nominal
/// (CiD's streaming power dwarfs the static floor), the HALO asymmetry
/// exploited per phase rather than per device.
pub fn dvfs_phase_split(hw: &HwConfig) -> Table {
    let trace = Mix::Generation.trace(59, 32, 1.0e6);
    let tokens: u64 = trace.iter().map(|q| q.l_out as u64).sum();
    let eco = hw.power.dvfs_points.len() - 1;
    let mut t = Table::new(
        "power_dvfs_phase_split",
        "Per-phase DVFS — single HALO1 device, generation burst (decode-dominated): \
         eco decode beats nominal on energy per token",
        &[
            "dvfs",
            "energy_per_token_j",
            "avg_power_w",
            "peak_power_w",
            "tok_per_s",
            "makespan_s",
        ],
    );
    for (label, pre, dec) in [
        ("nominal", 0, 0),
        ("eco-decode", 0, eco),
        ("eco", eco, eco),
    ] {
        let r = dvfs_replay(hw, &trace, pre, dec);
        t.row(vec![
            label.into(),
            f(r.energy_per_token(tokens)),
            f(r.avg_power_w()),
            f(r.peak_power_w),
            f(tokens as f64 / r.makespan.max(1e-12)),
            f(r.makespan),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    #[test]
    fn halo_ranks_at_or_below_both_extremes_on_energy_per_token() {
        // acceptance: the phase-aware mapping wins the mixed-workload
        // energy comparison deterministically
        let t = power_extremes(&hw());
        assert_eq!(t.rows.len(), 3);
        let ept = t.col_f64("energy_per_token_j");
        assert!(ept.iter().all(|&e| e > 0.0));
        let halo = t.rows.iter().position(|r| r[0] == "HALO1").unwrap();
        for (i, r) in t.rows.iter().enumerate() {
            if i != halo {
                assert!(
                    ept[halo] <= ept[i],
                    "HALO1 ept {} above {} ({})",
                    ept[halo],
                    ept[i],
                    r[0]
                );
            }
        }
        let rank: usize = t.rows[halo][10].parse().unwrap();
        assert_eq!(rank, 1, "HALO1 must rank first by energy per token");
        // component columns sum to less than the total energy budget
        // implied by avg power (static included)
        let avg_w = t.col_f64("avg_power_w");
        assert!(avg_w.iter().all(|&w| w > 0.0));
    }

    #[test]
    fn timeline_has_positive_power_in_every_window() {
        let t = power_timeline(&hw());
        assert_eq!(t.rows.len(), 3 * 16);
        let avg = t.col_f64("avg_w");
        // every window carries at least the static floor
        let floor = hw().power.static_w(hw().hbm.stacks, false);
        assert!(avg.iter().all(|&w| w >= floor * 0.99), "window under the static floor");
        // the decode-heavy CiD rows must show real dynamic power somewhere
        assert!(avg.iter().any(|&w| w > 2.0 * floor));
    }

    #[test]
    fn dvfs_ladder_cuts_peak_power_never_prefill_energy_per_token() {
        // satellite acceptance: lower frequency points never reduce
        // energy per token on compute-bound prefill while strictly
        // reducing peak power
        let t = dvfs_ladder(&hw());
        assert_eq!(t.rows.len(), hw().power.dvfs_points.len());
        let ept = t.col_f64("energy_per_token_j");
        let peak = t.col_f64("peak_power_w");
        let ttft = t.col_f64("ttft_p50_s");
        for w in peak.windows(2) {
            assert!(w[1] < w[0], "peak power must fall down the ladder: {peak:?}");
        }
        for w in ept.windows(2) {
            assert!(
                w[1] >= w[0] * (1.0 - 1e-9),
                "a lower point reduced prefill energy per token: {ept:?}"
            );
        }
        for w in ttft.windows(2) {
            assert!(w[1] > w[0], "lower points must stretch TTFT: {ttft:?}");
        }
    }

    #[test]
    fn eco_decode_beats_nominal_energy_per_token_on_generation() {
        let t = dvfs_phase_split(&hw());
        assert_eq!(t.rows.len(), 3);
        let ept = t.col_f64("energy_per_token_j");
        let peak = t.col_f64("peak_power_w");
        // rows: nominal, eco-decode, eco
        assert!(ept[1] < ept[0], "eco decode must save joules per token: {ept:?}");
        assert!(peak[1] < peak[0], "{peak:?}");
        assert!(peak[2] <= peak[1] * (1.0 + 1e-9), "{peak:?}");
    }

    #[test]
    fn throughput_degrades_monotonically_as_tdp_tightens() {
        // acceptance: live throttling feedback, not a cosmetic flag
        let t = tdp_throttling(&hw());
        assert_eq!(t.rows.len(), 4);
        let rps = t.col_f64("served_rps");
        for w in rps.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "tighter cap raised throughput: {rps:?}");
        }
        assert!(
            rps[3] < rps[0] * 0.95,
            "the tightest cap must cost real throughput: {rps:?}"
        );
        let throttled = t.col_f64("throttled_s");
        assert_eq!(throttled[0], 0.0, "uncapped run never throttles");
        assert!(throttled[3] > throttled[1], "{throttled:?}");
    }
}

//! Microbenchmarks of the simulator hot path (the L3 perf-pass targets):
//! graph construction, per-op costing, and single-scenario e2e simulation.

use halo::arch::cim::CimEngine;
use halo::arch::{cid::CidEngine, MatmulEngine};
use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::{build_decode_graph, build_prefill_graph, LlmConfig};
use halo::sim::{simulate_e2e, simulate_graph, EngineSet, Scenario};
use halo::util::bench::{bb, BenchSuite};

fn main() {
    let hw = HwConfig::paper();
    let m = LlmConfig::llama2_7b();
    let q = LlmConfig::qwen3_8b();
    let mut s = BenchSuite::new("sim_hotpath");

    s.bench("build_prefill_graph_llama_2048", || {
        bb(build_prefill_graph(&m, 2048, 1));
    });
    s.bench("build_decode_graph_llama_2048", || {
        bb(build_decode_graph(&m, 2048, 1));
    });

    let cid = CidEngine::new(&hw);
    let cim = CimEngine::new(&hw);
    let g = build_prefill_graph(&m, 2048, 1);
    s.bench_throughput("cost_all_ops_cid", g.ops.len() as f64, || {
        for op in g.matmul_ops() {
            bb(cid.matmul_cost(op));
        }
    });
    s.bench_throughput("cost_all_ops_cim", g.ops.len() as f64, || {
        for op in g.matmul_ops() {
            bb(cim.matmul_cost(op));
        }
    });

    let engines = EngineSet::new(&hw, MappingKind::Halo1);
    s.bench("simulate_graph_prefill_halo1", || {
        bb(simulate_graph(&g, &engines, MappingKind::Halo1));
    });

    let sc = Scenario { l_in: 2048, l_out: 512, batch: 1 };
    s.bench("simulate_e2e_llama_halo1", || {
        bb(simulate_e2e(&m, &hw, MappingKind::Halo1, &sc));
    });
    s.bench("simulate_e2e_qwen_attacc1", || {
        bb(simulate_e2e(&q, &hw, MappingKind::AttAcc1, &sc));
    });
    // the whole Table-II comparison at one grid point
    s.bench_throughput("simulate_all_table2_mappings", 5.0, || {
        for mk in MappingKind::table2() {
            bb(simulate_e2e(&m, &hw, *mk, &sc));
        }
    });
    s.finish();
}

//! Windowed per-device power traces extracted from replay event logs.
//!
//! Every busy event a power-tracked device executes is recorded as a
//! `(start, end, joules)` triple; [`power_trace`] buckets that energy
//! uniformly over each event's span into fixed wall-clock windows and
//! adds the static floor over each window's idle remainder, yielding the
//! average-power timeline (and its peak) that `halo power` and
//! `report --fig power` print.

/// One busy event on a device: energy `joules` delivered over
/// `[start, end)` of the device clock (throttling already applied).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerEvent {
    pub start: f64,
    pub end: f64,
    pub joules: f64,
}

impl PowerEvent {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }

    /// Mean power over the event, W.
    pub fn watts(&self) -> f64 {
        self.joules / self.duration().max(1e-30)
    }
}

/// A fixed-window average-power timeline.
#[derive(Debug, Clone)]
pub struct PowerTrace {
    /// Window length, s.
    pub window_s: f64,
    /// Average power per window, W, covering `[0, windows * window_s)`.
    pub avg_w: Vec<f64>,
}

impl PowerTrace {
    pub fn peak_w(&self) -> f64 {
        self.avg_w.iter().fold(0.0f64, |a, &b| a.max(b))
    }

    pub fn mean_w(&self) -> f64 {
        if self.avg_w.is_empty() {
            0.0
        } else {
            self.avg_w.iter().sum::<f64>() / self.avg_w.len() as f64
        }
    }
}

/// Bucket `events` into `windows` equal windows over `[0, span_s)`.
/// Event energy spreads uniformly over the event's span; `idle_floor_w`
/// (the cold static floor) covers whatever part of each window no event
/// occupies, so a fully idle window still reads the refresh+leakage
/// floor. Events already include their own static share, so the floor is
/// only applied to the *uncovered* remainder — no double counting.
pub fn power_trace(
    events: &[PowerEvent],
    idle_floor_w: f64,
    span_s: f64,
    windows: usize,
) -> PowerTrace {
    if windows == 0 || span_s <= 0.0 {
        return PowerTrace { window_s: 0.0, avg_w: Vec::new() };
    }
    let window = span_s / windows as f64;
    let mut energy = vec![0.0f64; windows];
    let mut busy = vec![0.0f64; windows];
    for ev in events {
        let dur = ev.duration();
        if dur <= 0.0 {
            continue;
        }
        let first = ((ev.start / window).floor() as usize).min(windows - 1);
        let last = ((ev.end / window).ceil() as usize).clamp(first + 1, windows);
        for (w, (e, b)) in energy
            .iter_mut()
            .zip(busy.iter_mut())
            .enumerate()
            .take(last)
            .skip(first)
        {
            let lo = (w as f64 * window).max(ev.start);
            let hi = ((w + 1) as f64 * window).min(ev.end);
            let overlap = (hi - lo).max(0.0);
            *e += ev.joules * overlap / dur;
            *b += overlap;
        }
    }
    let avg_w = energy
        .iter()
        .zip(&busy)
        .map(|(&e, &b)| (e + idle_floor_w * (window - b).max(0.0)) / window)
        .collect();
    PowerTrace { window_s: window, avg_w }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_event_lands_in_its_windows() {
        // 10 J over [1, 3) of a 4 s span in 4 windows -> 5 W in w1 and w2
        let ev = [PowerEvent { start: 1.0, end: 3.0, joules: 10.0 }];
        let t = power_trace(&ev, 0.0, 4.0, 4);
        assert_eq!(t.avg_w.len(), 4);
        assert!((t.avg_w[0] - 0.0).abs() < 1e-12);
        assert!((t.avg_w[1] - 5.0).abs() < 1e-12);
        assert!((t.avg_w[2] - 5.0).abs() < 1e-12);
        assert!((t.avg_w[3] - 0.0).abs() < 1e-12);
        assert!((t.peak_w() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn idle_floor_covers_uncovered_time_only() {
        // event fills half of window 0; floor 2 W covers the other half
        let ev = [PowerEvent { start: 0.0, end: 0.5, joules: 4.0 }];
        let t = power_trace(&ev, 2.0, 2.0, 2);
        // w0: 4 J + 2 W * 0.5 s = 5 J over 1 s
        assert!((t.avg_w[0] - 5.0).abs() < 1e-12, "{:?}", t.avg_w);
        // w1: pure floor
        assert!((t.avg_w[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn energy_is_conserved_across_windows() {
        let evs = [
            PowerEvent { start: 0.2, end: 1.7, joules: 3.0 },
            PowerEvent { start: 2.1, end: 2.4, joules: 5.0 },
            PowerEvent { start: 3.9, end: 4.0, joules: 1.0 },
        ];
        let t = power_trace(&evs, 0.0, 4.0, 8);
        let total: f64 = t.avg_w.iter().map(|w| w * t.window_s).sum();
        assert!((total - 9.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn event_past_span_clamps_into_last_window() {
        let ev = [PowerEvent { start: 3.5, end: 4.5, joules: 2.0 }];
        let t = power_trace(&ev, 0.0, 4.0, 4);
        // half of the event overlaps the span; the rest is dropped
        assert!((t.avg_w[3] - 1.0).abs() < 1e-12, "{:?}", t.avg_w);
    }

    #[test]
    fn degenerate_inputs_yield_empty_trace() {
        assert!(power_trace(&[], 1.0, 0.0, 4).avg_w.is_empty());
        assert!(power_trace(&[], 1.0, 4.0, 0).avg_w.is_empty());
        let t = power_trace(&[], 3.0, 4.0, 2);
        assert_eq!(t.avg_w, vec![3.0, 3.0]);
        assert_eq!(t.mean_w(), 3.0);
    }

    #[test]
    fn event_watts_accessor() {
        let ev = PowerEvent { start: 1.0, end: 3.0, joules: 10.0 };
        assert!((ev.watts() - 5.0).abs() < 1e-12);
        assert!((ev.duration() - 2.0).abs() < 1e-12);
    }
}

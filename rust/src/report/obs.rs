//! Observability-plane tables: where the p99 comes from (per-request
//! latency attribution aggregated over the tail) and how SLO attainment
//! and burn rate evolve window over window on a bursty stream.
//!
//! Both tables run the same MMPP chat stream against a 4-device
//! phase-disaggregated fleet with chunked prefill — the configuration
//! where attribution is most interesting (queue wait, chunked prefill,
//! KV handoff and decode all contribute) — so the `halo report --fig
//! obs` artifact doubles as a worked example of the `halo monitor`
//! surface.

use super::Table;
use crate::cluster::{
    collect_trace, ArrivalKind, Interconnect, Mix, Policy, SchedConfig, TrafficConfig,
};
use crate::config::HwConfig;
use crate::model::LlmConfig;
use crate::obs::{self, attribute, tail_breakdown, WindowSeries};

use super::f;

/// Decode slots per device (matches the cluster-plane tables).
const SLOTS: usize = 8;

/// The shared workload: an MMPP chat stream, bursty enough that queue
/// wait dominates the tail inside bursts while the troughs stay quiet.
fn obs_trace(rate: f64) -> Vec<crate::sim::queueing::TraceRequest> {
    let cfg = TrafficConfig::new(4242, rate, 40.0, Mix::Chat)
        .with_kind(ArrivalKind::Mmpp)
        .with_max_requests(400);
    collect_trace(&mut cfg.build())
}

/// Latency attribution over the e2e tail: for each component, its mean
/// share of a request's end-to-end latency across the whole population
/// vs across the p99 tail — the "where does p99 come from" table.
pub fn attribution_breakdown(hw: &HwConfig) -> Table {
    let llm = LlmConfig::llama2_7b();
    let rate = 24.0;
    let trace = obs_trace(rate);
    let (mut fleet, mut router) = Policy::PhaseDisaggregated.build_with(
        &llm,
        hw,
        4,
        SLOTS,
        0.5,
        Interconnect::board(),
        SchedConfig::chunked(256),
    );
    fleet.enable_obs();
    let r = fleet.replay(&trace, router.as_mut());
    let recorders = fleet.recorders().expect("obs enabled");
    let kv = fleet.kv_spans().expect("obs enabled");
    let attrs = attribute(&r.served, &recorders, kv);
    debug_assert_eq!(obs::reconcile(&attrs), 0, "attribution must fold bit-exactly");
    let rows = tail_breakdown(&attrs, 99.0);
    let mut t = Table::new(
        "obs_attribution",
        &format!(
            "Latency attribution — mean component seconds, all requests vs p99 e2e tail \
             (LLaMA-2 7B, chat MMPP {:.1} req/s, 4-dev disaggregated, chunked prefill)",
            rate
        ),
        &["component", "mean_s_all", "mean_s_tail", "tail_share"],
    );
    for row in rows {
        t.row(vec![
            row.component.to_string(),
            f(row.mean_s_all),
            f(row.mean_s_tail),
            f(row.tail_share),
        ]);
    }
    t
}

/// Windowed SLO attainment and burn rate over the monitored stream: one
/// row per window of the same MMPP replay, showing attainment dip and
/// burn-rate spike inside bursts.
pub fn slo_burn_windows(hw: &HwConfig) -> Table {
    let llm = LlmConfig::llama2_7b();
    let rate = 24.0;
    let trace = obs_trace(rate);
    let (mut fleet, mut router) = Policy::PhaseDisaggregated.build_with(
        &llm,
        hw,
        4,
        SLOTS,
        0.5,
        Interconnect::board(),
        SchedConfig::chunked(256),
    );
    let mut series = WindowSeries::new(2.0, 64);
    let r = fleet.replay_monitored(&trace, router.as_mut(), &mut series);
    let spec = obs::SloSpec::interactive();
    let report = obs::slo::evaluate(&series, &spec, &obs::BurnRateConfig::default());
    let mut t = Table::new(
        "obs_slo_windows",
        &format!(
            "Windowed SLO — attainment and burn rate per {:.1}s window \
             (chat MMPP {:.1} req/s, {} served, TTFT<{:.2}s / e2e<{:.1}s @ {:.0}%)",
            series.width_s(),
            rate,
            r.requests,
            spec.ttft_target_s,
            spec.e2e_target_s,
            spec.objective * 100.0
        ),
        &[
            "window_start_s",
            "completions",
            "throughput_rps",
            "ttft_attainment",
            "e2e_attainment",
            "ttft_burn_fast",
            "e2e_burn_fast",
            "utilization",
        ],
    );
    let width = series.width_s();
    for (w, s) in series.windows().iter().zip(&report.per_window) {
        t.row(vec![
            f(s.start_s),
            w.completions.to_string(),
            f(w.throughput_rps(width)),
            f(s.ttft_attainment),
            f(s.e2e_attainment),
            f(s.ttft_burn_fast),
            f(s.e2e_burn_fast),
            f(w.utilization(width, 4)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribution_table_reconciles_and_covers_components() {
        let t = attribution_breakdown(&HwConfig::paper());
        // one row per e2e component plus the closing e2e row
        assert_eq!(t.rows.len(), 7);
        let shares = t.col_f64("tail_share");
        let last = *shares.last().unwrap();
        assert!((last - 1.0).abs() < 1e-12, "e2e row must carry share 1.0");
        // component shares (all but the e2e row) sum to ~1
        let sum: f64 = shares[..shares.len() - 1].iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "component shares sum to 1, got {sum}");
    }

    #[test]
    fn slo_window_table_is_finite_and_nonempty() {
        let t = slo_burn_windows(&HwConfig::paper());
        assert!(!t.rows.is_empty());
        for h in ["ttft_attainment", "e2e_attainment", "ttft_burn_fast", "utilization"] {
            for v in t.col_f64(h) {
                assert!(v.is_finite(), "{h} must stay finite on every window");
            }
        }
        let served: f64 = t.col_f64("completions").iter().sum();
        assert!(served > 0.0);
    }
}

//! Per-phase DVFS: a ladder of voltage-frequency operating points
//! ([`PowerConfig::dvfs_points`]) selectable independently for prefill
//! and decode, plus a thermal *stepped governor* that walks the ladder
//! under a TDP cap instead of the scalar throttle factor.
//!
//! Latency scales linearly in `1/f` and dynamic energy quadratically in
//! `V` (see [`DvfsPoint`]); the scaling applies to the memoized nominal
//! [`PhaseCost`](crate::sim::cost::PhaseCost) at charge time, so DVFS
//! adds no `simulate_graph` walks. Static point selection is a plain
//! performance knob and works with or without power tracking; the
//! governor reads the RC thermal state and therefore needs power
//! tracking with a TDP cap. The governor never boosts above the
//! configured static point — it only steps further down the ladder.

use super::thermal::ThermalModel;
use crate::config::{DvfsPoint, PowerConfig};
use crate::model::Phase;

/// Hysteresis band of the stepped governor: it steps back up only once
/// the junction rise falls below this fraction of the TDP temperature
/// ceiling (stepping down triggers at the ceiling itself).
pub const GOVERNOR_STEP_UP_FRACTION: f64 = 0.9;

/// Per-device DVFS selection: the ladder, one static operating point per
/// phase, and the optional thermal stepped governor.
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsConfig {
    ladder: Vec<DvfsPoint>,
    /// Static ladder index for prefill (and recompute) events.
    pub prefill_idx: usize,
    /// Static ladder index for batched decode steps.
    pub decode_idx: usize,
    /// Thermal stepped governor: under a TDP cap, walk the ladder one
    /// rung per busy event — down while the junction sits over the TDP
    /// temperature ceiling, up below the hysteresis band — instead of
    /// applying the scalar throttle factor. Once the ladder is exhausted
    /// and the junction still sits over the ceiling, the scalar throttle
    /// takes over as a backstop, so arbitrarily tight caps still
    /// converge onto their TDP.
    pub governor: bool,
}

impl Default for DvfsConfig {
    fn default() -> Self {
        DvfsConfig::nominal(&PowerConfig::paper())
    }
}

impl DvfsConfig {
    /// Both phases at the nominal point, no governor (the exact-identity
    /// default: every scale is 1.0).
    pub fn nominal(power: &PowerConfig) -> Self {
        Self::with_indices(power, 0, 0)
    }

    /// Explicit per-phase ladder indices (0 = nominal).
    pub fn with_indices(power: &PowerConfig, prefill_idx: usize, decode_idx: usize) -> Self {
        let ladder = power.dvfs_points.clone();
        assert!(!ladder.is_empty(), "empty DVFS ladder");
        assert!(ladder[0].is_nominal(), "ladder index 0 must be the nominal point");
        assert!(
            prefill_idx < ladder.len() && decode_idx < ladder.len(),
            "DVFS index out of range: ({prefill_idx}, {decode_idx}) on a {}-point ladder",
            ladder.len()
        );
        DvfsConfig { ladder, prefill_idx, decode_idx, governor: false }
    }

    /// Nominal static points with the thermal stepped governor armed.
    pub fn governed(power: &PowerConfig) -> Self {
        let mut d = Self::nominal(power);
        d.governor = true;
        d
    }

    /// Parse a CLI spec against a ladder: `NAME` pins both phases,
    /// `PRE,DEC` pins them separately, and the token `governor` (alone
    /// or as an extra comma term) arms the thermal stepped governor.
    pub fn parse(power: &PowerConfig, spec: &str) -> Result<Self, String> {
        let mut governor = false;
        let mut names: Vec<&str> = Vec::new();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            if tok.eq_ignore_ascii_case("governor") || tok.eq_ignore_ascii_case("gov") {
                governor = true;
            } else {
                names.push(tok);
            }
        }
        let known: Vec<&str> = power.dvfs_points.iter().map(|p| p.name).collect();
        let resolve = |n: &str| {
            power
                .dvfs_index(n)
                .ok_or_else(|| format!("unknown DVFS point `{n}` (one of {known:?}, governor)"))
        };
        let (prefill_idx, decode_idx) = match names.as_slice() {
            &[] => (0, 0),
            &[both] => {
                let i = resolve(both)?;
                (i, i)
            }
            &[pre, dec] => (resolve(pre)?, resolve(dec)?),
            _ => {
                return Err(format!(
                    "expected at most two DVFS points (prefill,decode), got {}",
                    names.len()
                ))
            }
        };
        let mut d = Self::with_indices(power, prefill_idx, decode_idx);
        d.governor = governor;
        Ok(d)
    }

    pub fn ladder(&self) -> &[DvfsPoint] {
        &self.ladder
    }

    /// The static ladder index configured for `phase`.
    pub fn index(&self, phase: Phase) -> usize {
        match phase {
            Phase::Prefill => self.prefill_idx,
            Phase::Decode => self.decode_idx,
        }
    }

    /// The static operating point configured for `phase`.
    pub fn point(&self, phase: Phase) -> &DvfsPoint {
        &self.ladder[self.index(phase)]
    }

    /// Ladder index of the effective point for `phase` when the governor
    /// currently sits at `gov_idx`: the deeper (slower) of the two rungs
    /// — the governor never boosts above the configured static point.
    /// Out-of-range governor positions clamp to the ladder bottom.
    pub fn effective_index(&self, phase: Phase, gov_idx: usize) -> usize {
        self.index(phase).max(gov_idx.min(self.ladder.len() - 1))
    }

    /// The effective operating point (see [`Self::effective_index`]).
    pub fn effective(&self, phase: Phase, gov_idx: usize) -> &DvfsPoint {
        &self.ladder[self.effective_index(phase, gov_idx)]
    }

    /// Whether every event runs at the exact-identity nominal point.
    pub fn is_nominal(&self) -> bool {
        self.prefill_idx == 0 && self.decode_idx == 0 && !self.governor
    }

    /// One governor step against the current thermal state: down a rung
    /// while the junction rise exceeds the TDP temperature ceiling, up a
    /// rung below the hysteresis band, unchanged in between.
    pub fn step_governor(&self, cur: usize, th: &ThermalModel) -> usize {
        let rise = th.temp_c() - th.cfg.ambient_c;
        let limit = th.cfg.theta_c_per_w * th.cfg.tdp_w;
        if rise > limit {
            (cur + 1).min(self.ladder.len() - 1)
        } else if rise < GOVERNOR_STEP_UP_FRACTION * limit {
            cur.saturating_sub(1)
        } else {
            cur
        }
    }

    /// Compact label for tables and CLI echoes, e.g. `nominal`,
    /// `nominal/eco`, `eco+gov`.
    pub fn label(&self) -> String {
        let base = if self.prefill_idx == self.decode_idx {
            self.ladder[self.prefill_idx].name.to_string()
        } else {
            format!(
                "{}/{}",
                self.ladder[self.prefill_idx].name, self.ladder[self.decode_idx].name
            )
        };
        if self.governor {
            format!("{base}+gov")
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::ThermalConfig;

    fn power() -> PowerConfig {
        PowerConfig::paper()
    }

    #[test]
    fn default_is_the_exact_identity() {
        let d = DvfsConfig::default();
        assert!(d.is_nominal());
        assert_eq!(d.point(Phase::Prefill).time_scale(), 1.0);
        assert_eq!(d.point(Phase::Decode).energy_scale(), 1.0);
        assert_eq!(d.label(), "nominal");
    }

    #[test]
    fn parse_accepts_single_pair_and_governor_forms() {
        let p = power();
        let eco = DvfsConfig::parse(&p, "eco").unwrap();
        assert_eq!((eco.prefill_idx, eco.decode_idx, eco.governor), (2, 2, false));
        let split = DvfsConfig::parse(&p, "nominal,eco").unwrap();
        assert_eq!((split.prefill_idx, split.decode_idx), (0, 2));
        assert_eq!(split.label(), "nominal/eco");
        let gov = DvfsConfig::parse(&p, "governor").unwrap();
        assert!(gov.governor && gov.prefill_idx == 0 && gov.decode_idx == 0);
        assert_eq!(gov.label(), "nominal+gov");
        let both = DvfsConfig::parse(&p, "balanced,governor").unwrap();
        assert!(both.governor);
        assert_eq!((both.prefill_idx, both.decode_idx), (1, 1));
        assert!(DvfsConfig::parse(&p, "warp").is_err());
        assert!(DvfsConfig::parse(&p, "eco,eco,eco").is_err());
    }

    #[test]
    fn effective_point_never_boosts_above_the_static_choice() {
        let p = power();
        let d = DvfsConfig::with_indices(&p, 2, 0);
        // governor at nominal: prefill stays pinned at its slow point
        assert_eq!(d.effective(Phase::Prefill, 0).name, "eco");
        assert_eq!(d.effective(Phase::Decode, 0).name, "nominal");
        // governor deep: both phases follow it down
        assert_eq!(d.effective(Phase::Decode, 1).name, "balanced");
        assert_eq!(d.effective(Phase::Prefill, 1).name, "eco");
        // out-of-range governor indices clamp to the ladder bottom
        assert_eq!(d.effective(Phase::Decode, 99).name, "eco");
    }

    #[test]
    fn governor_steps_down_over_the_ceiling_and_back_up_below_it() {
        let p = power();
        let d = DvfsConfig::governed(&p);
        let mut th = ThermalModel::new(ThermalConfig::paper(100.0));
        // cold package: stays at (or returns to) the top
        assert_eq!(d.step_governor(0, &th), 0);
        assert_eq!(d.step_governor(2, &th), 1);
        // burn far over the 100 W ceiling: steps down one rung at a time
        th.heat(100.0, 300.0);
        assert_eq!(d.step_governor(0, &th), 1);
        assert_eq!(d.step_governor(1, &th), 2);
        assert_eq!(d.step_governor(2, &th), 2, "clamped at the ladder bottom");
    }

    #[test]
    #[should_panic]
    fn out_of_range_static_index_panics() {
        DvfsConfig::with_indices(&power(), 0, 99);
    }
}

//! Streaming metrics registry: counters, gauges and mergeable
//! [`LogHistogram`]s behind one snapshot serializer.
//!
//! This is the single place every CLI surface (`--json` flags, trace
//! summaries, CI artifacts) gets its machine-readable numbers from, so
//! the schema stays consistent across subcommands.

use super::hist::LogHistogram;
use super::timeseries::WindowSeries;
use crate::cluster::fleet::FleetResult;
use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, LogHistogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Histogram under `name`, created on first touch.
    pub fn hist(&mut self, name: &str) -> &mut LogHistogram {
        self.hists.entry(name.to_string()).or_default()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.hists.get(name)
    }

    /// Fold another registry into this one: counters add, gauges take
    /// the other's value, histograms merge.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    /// OpenMetrics text exposition (the Prometheus scrape format): one
    /// `# TYPE` block per metric, counters suffixed `_total`, gauges
    /// verbatim, histograms as cumulative `_bucket{le=...}` series at
    /// fixed boundaries plus `_sum`/`_count`, closed by `# EOF`.
    ///
    /// Names are prefixed `halo_`; bucket counts are bucket-granular
    /// (a boundary includes its whole containing log bucket). BTreeMap
    /// iteration keeps the output byte-deterministic — pinned by the
    /// golden-file test in `rust/tests/critpath_plane.rs`.
    pub fn to_openmetrics(&self) -> String {
        const LE: [f64; 6] = [0.001, 0.01, 0.1, 1.0, 10.0, 100.0];
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("# TYPE halo_{k} counter\n"));
            out.push_str(&format!("halo_{k}_total {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("# TYPE halo_{k} gauge\n"));
            out.push_str(&format!("halo_{k} {}\n", om_num(*v)));
        }
        for (k, h) in &self.hists {
            out.push_str(&format!("# TYPE halo_{k} histogram\n"));
            for le in LE {
                out.push_str(&format!(
                    "halo_{k}_bucket{{le=\"{}\"}} {}\n",
                    om_num(le),
                    h.count_at_or_below(le)
                ));
            }
            out.push_str(&format!("halo_{k}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("halo_{k}_sum {}\n", om_num(h.sum())));
            out.push_str(&format!("halo_{k}_count {}\n", h.count()));
        }
        out.push_str("# EOF\n");
        out
    }

    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> =
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect();
        let gauges: BTreeMap<String, Json> =
            self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        let hists: BTreeMap<String, Json> =
            self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        let mut m = BTreeMap::new();
        m.insert("counters".to_string(), Json::Obj(counters));
        m.insert("gauges".to_string(), Json::Obj(gauges));
        m.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(m)
    }
}

/// OpenMetrics number formatting: Rust's shortest-roundtrip `Display`
/// (deterministic), with non-finite values spelled per the spec.
fn om_num(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{v}")
    }
}

/// The standard fleet-replay registry: every counter/gauge the cluster
/// and DSE surfaces report, built from one [`FleetResult`].
pub fn fleet_registry(r: &FleetResult, walks: u64, memo_hits: u64) -> Registry {
    let mut reg = Registry::new();
    reg.inc("requests_served", r.requests as u64);
    reg.inc("output_tokens", r.tokens);
    reg.inc("prefills", r.prefills);
    reg.inc("decode_steps", r.decode_steps);
    reg.inc("evictions", r.evictions);
    reg.inc("recompute_tokens", r.recompute_tokens);
    reg.inc("kv_transfers", r.transfers);
    reg.inc("kv_bytes_moved", r.kv_bytes);
    reg.inc("graph_walks", walks);
    reg.inc("oracle_memo_hits", memo_hits);
    reg.gauge("makespan_s", r.makespan);
    reg.gauge("throughput_rps", r.throughput_rps());
    reg.gauge("utilization", r.utilization());
    reg.gauge("ttft_p50_s", r.ttft_p50());
    reg.gauge("ttft_p99_s", r.ttft_p99());
    reg.gauge("e2e_p50_s", r.e2e_p50());
    reg.gauge("e2e_p99_s", r.e2e_p99());
    reg.gauge("energy_j", r.energy_j());
    reg.gauge("kv_transfer_energy_j", r.kv_transfer_energy_j);
    reg.gauge("avg_power_w", r.avg_power_w());
    reg.gauge("peak_power_w", r.peak_power_w);
    reg.gauge("throttled_s", r.throttled_s);
    // the replay already folded every completion into streaming
    // histograms (retention-cap independent); merge them instead of
    // re-recording off the possibly-sampled served vector
    reg.hist("ttft_s").merge(&r.ttft_hist);
    reg.hist("e2e_s").merge(&r.e2e_hist);
    reg
}

/// Fold a [`WindowSeries`] into registry vocabulary: series-level
/// counters/gauges plus a per-window completions histogram (how bursty
/// the stream was window over window). The merged latency populations
/// are *not* duplicated here — `fleet_registry` already carries them
/// and the series' merged histograms are bit-identical to those.
pub fn timeseries_registry(reg: &mut Registry, series: &WindowSeries) {
    reg.inc("timeseries_windows", series.len() as u64);
    reg.inc("timeseries_coarsenings", u64::from(series.coarsenings()));
    reg.inc("timeseries_arrivals", series.windows().iter().map(|w| w.arrivals).sum());
    reg.inc("timeseries_completions", series.windows().iter().map(|w| w.completions).sum());
    reg.inc("timeseries_tokens", series.windows().iter().map(|w| w.tokens).sum());
    reg.gauge("timeseries_window_s", series.width_s());
    let h = reg.hist("window_completions");
    for w in series.windows() {
        h.record(w.completions as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_hists_roundtrip() {
        let mut r = Registry::new();
        r.inc("walks", 2);
        r.inc("walks", 3);
        r.gauge("util", 0.5);
        r.hist("lat").record(0.25);
        assert_eq!(r.counter("walks"), 5);
        assert_eq!(r.gauge_value("util"), Some(0.5));
        assert_eq!(r.histogram("lat").unwrap().count(), 1);
        let j = r.to_json();
        assert_eq!(j.path(&["counters", "walks"]).and_then(Json::as_f64), Some(5.0));
        assert_eq!(j.path(&["histograms", "lat", "count"]).and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn openmetrics_exposition_is_well_formed_and_deterministic() {
        let mut r = Registry::new();
        r.inc("requests_served", 42);
        r.gauge("utilization", 0.5);
        r.hist("ttft_s").record(0.25);
        r.hist("ttft_s").record(7.0);
        let s = r.to_openmetrics();
        assert_eq!(s, r.to_openmetrics(), "byte-deterministic");
        assert!(s.ends_with("# EOF\n"));
        assert!(s.contains("# TYPE halo_requests_served counter\n"));
        assert!(s.contains("halo_requests_served_total 42\n"));
        assert!(s.contains("halo_utilization 0.5\n"));
        assert!(s.contains("halo_ttft_s_bucket{le=\"1\"} 1\n"));
        assert!(s.contains("halo_ttft_s_bucket{le=\"10\"} 2\n"));
        assert!(s.contains("halo_ttft_s_bucket{le=\"+Inf\"} 2\n"));
        assert!(s.contains("halo_ttft_s_sum 7.25\n"));
        assert!(s.contains("halo_ttft_s_count 2\n"));
    }

    #[test]
    fn merge_adds_counters_and_merges_hists() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        a.inc("n", 1);
        b.inc("n", 2);
        a.hist("lat").record(1.0);
        b.hist("lat").record(2.0);
        b.gauge("g", 9.0);
        a.merge(&b);
        assert_eq!(a.counter("n"), 3);
        assert_eq!(a.histogram("lat").unwrap().count(), 2);
        assert_eq!(a.gauge_value("g"), Some(9.0));
    }
}

//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs `[[bench]]` targets with `harness = false`; each
//! target builds a [`BenchSuite`], registers closures, and calls `run()`,
//! which warms up, samples wall-clock time, and prints mean / stddev /
//! p50 / p95 per benchmark plus an optional throughput line.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub throughput_items: Option<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.samples)
    }
    pub fn stddev(&self) -> f64 {
        crate::util::stddev(&self.samples)
    }
    pub fn p50(&self) -> f64 {
        crate::util::percentile(&self.samples, 50.0)
    }
    pub fn p95(&self) -> f64 {
        crate::util::percentile(&self.samples, 95.0)
    }
}

pub struct BenchSuite {
    title: String,
    min_samples: usize,
    max_samples: usize,
    target_time: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // honor `cargo bench -- <filter>`
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        BenchSuite {
            title: title.to_string(),
            min_samples: 10,
            max_samples: 200,
            target_time: Duration::from_secs(2),
            warmup: Duration::from_millis(300),
            results: Vec::new(),
            filter,
        }
    }

    pub fn with_target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Benchmark `f`; one sample per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        self.bench_items(name, None, &mut f)
    }

    /// Benchmark with a throughput denominator (items per iteration).
    pub fn bench_throughput(&mut self, name: &str, items: f64, mut f: impl FnMut()) {
        self.bench_items(name, Some(items), &mut f)
    }

    fn bench_items(&mut self, name: &str, items: Option<f64>, f: &mut dyn FnMut()) {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return;
            }
        }
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while samples.len() < self.min_samples
            || (t0.elapsed() < self.target_time && samples.len() < self.max_samples)
        {
            let s = Instant::now();
            black_box(f());
            samples.push(s.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), samples, throughput_items: items };
        print_result(&r);
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self) {
        println!(
            "\n[{}] {} benchmarks done",
            self.title,
            self.results.len()
        );
    }
}

fn print_result(r: &BenchResult) {
    let mut line = format!(
        "{:<44} {:>12}/iter  (sd {:>10}, p95 {:>10}, n={})",
        r.name,
        crate::util::fmt_seconds(r.mean()),
        crate::util::fmt_seconds(r.stddev()),
        crate::util::fmt_seconds(r.p95()),
        r.samples.len()
    );
    if let Some(items) = r.throughput_items {
        line.push_str(&format!("  [{:.1} items/s]", items / r.mean()));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut s = BenchSuite::new("t").with_target_time(Duration::from_millis(50));
        s.warmup = Duration::from_millis(5);
        let mut n = 0u64;
        s.bench("noop", || {
            n = n.wrapping_add(1);
        });
        assert!(!s.results().is_empty());
        assert!(s.results()[0].samples.len() >= 10);
        assert!(s.results()[0].mean() >= 0.0);
    }
}

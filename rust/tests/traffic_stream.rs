//! Streaming-serving integration: a generator-fed `Fleet::serve` must be
//! bit-identical to materializing the same stream and replaying it, the
//! slice-backed serve must equal the legacy replay on every mix preset,
//! request identity (tenant, session, tokens) must travel on the served
//! records themselves, and a bounded retention cap must bound the raw
//! records without perturbing any online statistic.

use halo::cluster::router::LeastLoaded;
use halo::cluster::{
    collect_trace, per_tenant_stats_served, ArrivalKind, Fleet, FleetBuilder, Interconnect, Mix,
    ServeOptions, SessionConfig, SliceSource, TrafficConfig,
};
use halo::config::HwConfig;
use halo::model::LlmConfig;

fn fleet(devices: usize) -> Fleet {
    FleetBuilder::new(&LlmConfig::llama2_7b(), &HwConfig::paper())
        .devices(devices)
        .slots(8)
        .interconnect(Interconnect::board())
        .build()
}

fn traffic() -> TrafficConfig {
    TrafficConfig::new(17, 30.0, 20.0, Mix::Chat).with_kind(ArrivalKind::Mmpp).with_tenants(3)
}

#[test]
fn generator_stream_and_materialized_replay_are_bit_identical() {
    // acceptance: same seed, two consumption styles — pulled one request
    // at a time through serve(), or drained up front and replayed as a
    // slice — must produce the same FleetResult to the bit
    let trace = collect_trace(&mut traffic().build());
    assert!(trace.len() > 100, "workload too small to be meaningful: {}", trace.len());
    let mut gen = traffic().build();
    let streamed = fleet(3).serve(&mut gen, &mut LeastLoaded, ServeOptions::exact());
    let replayed = fleet(3).replay(&trace, &mut LeastLoaded);
    assert_eq!(streamed.fingerprint(), replayed.fingerprint());
    assert_eq!(streamed.requests, trace.len());
    assert!(streamed.complete);
}

#[test]
fn slice_backed_serve_equals_legacy_replay_on_every_mix() {
    for (i, mix) in Mix::all().into_iter().enumerate() {
        let trace = mix.trace(70 + i as u64, 60, 12.0);
        let a = fleet(3).replay(&trace, &mut LeastLoaded);
        let b = fleet(3).serve(
            &mut SliceSource::new(&trace),
            &mut LeastLoaded,
            ServeOptions::exact(),
        );
        assert_eq!(a.fingerprint(), b.fingerprint(), "{}", mix.name());
        assert_eq!(a.requests, 60, "{}", mix.name());
    }
}

#[test]
fn tenant_and_session_identity_travels_on_served_requests() {
    // the bugfix pin: identity is carried by the simulation itself, not
    // recovered by a post-hoc arrival-time join against the trace
    let cfg = traffic().with_sessions(SessionConfig::default());
    let r = fleet(3).serve(&mut cfg.build(), &mut LeastLoaded, ServeOptions::exact());
    assert!(r.complete && r.requests > 0);
    assert!(r.served.iter().all(|s| s.tenant < 3), "tenant ids must survive serving");
    assert!(r.served.iter().all(|s| s.session > 0), "session ids must survive serving");
    assert_eq!(r.served.iter().map(|s| s.tokens).sum::<u64>(), r.tokens);
    let stats = per_tenant_stats_served(&r.served, r.makespan);
    assert!(!stats.is_empty() && stats.len() <= 3);
    assert_eq!(stats.iter().map(|t| t.requests).sum::<usize>(), r.requests);
    assert_eq!(stats.iter().map(|t| t.tokens).sum::<u64>(), r.tokens);
}

#[test]
fn retention_cap_bounds_records_not_statistics() {
    let trace = Mix::Chat.trace(19, 80, 20.0);
    let run = |opts: ServeOptions| {
        fleet(2).serve(&mut SliceSource::new(&trace), &mut LeastLoaded, opts)
    };
    let exact = run(ServeOptions::exact());
    let capped = run(ServeOptions::streaming(8));
    assert_eq!(capped.requests, 80);
    assert_eq!(capped.served.len(), 8, "only the cap survives as raw records");
    assert!(!capped.complete && exact.complete);
    // every online statistic is identical — the cap only sheds records
    assert_eq!(capped.makespan.to_bits(), exact.makespan.to_bits());
    assert_eq!(capped.tokens, exact.tokens);
    assert_eq!(capped.decode_steps, exact.decode_steps);
    assert_eq!(capped.ttft_hist, exact.ttft_hist);
    assert_eq!(capped.e2e_hist, exact.e2e_hist);
    assert_eq!(capped.ttft_hist.count(), 80);
    // capped percentiles come from the histogram: inside the exact
    // envelope and close to the exact-sorted values
    for p in [50.0, 90.0, 99.0] {
        let (a, b) = (capped.ttft_pct(p), exact.ttft_pct(p));
        assert!(a >= exact.ttft_hist.min() && a <= exact.ttft_hist.max());
        assert!((a - b).abs() <= 0.25 * b.abs().max(1e-12), "p{p}: hist {a} vs exact {b}");
    }
}

#[test]
fn session_turns_replay_with_grown_prefixes_under_serving() {
    // multi-turn sessions keep their grown context through the full
    // serving path: later turns of a session must carry strictly larger
    // prompts, visible in the served token accounting
    let cfg = TrafficConfig::new(23, 8.0, 60.0, Mix::Chat).with_sessions(SessionConfig::default());
    let trace = collect_trace(&mut cfg.build());
    let mut by_session: std::collections::HashMap<u64, Vec<&halo::sim::queueing::TraceRequest>> =
        std::collections::HashMap::new();
    for q in &trace {
        by_session.entry(q.session).or_default().push(q);
    }
    assert!(by_session.values().any(|v| v.len() > 1), "no multi-turn sessions generated");
    for turns in by_session.values() {
        for w in turns.windows(2) {
            assert!(w[1].l_in > w[0].l_in, "session prefix must grow turn over turn");
        }
    }
    // and the stream serves to completion with conserved counts
    let r = fleet(2).serve(&mut cfg.build(), &mut LeastLoaded, ServeOptions::exact());
    assert_eq!(r.requests, trace.len());
    assert!(r.complete);
}

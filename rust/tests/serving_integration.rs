//! Serving-stack integration tests over the real PJRT engine: slot
//! isolation, determinism, continuous batching, and phase-aware
//! correctness of the coordinator. Skipped when artifacts are absent.
//!
//! PJRT compiles are the slow part, so all cases share one engine through
//! a serial test (the engine is deliberately not Sync).

use std::path::{Path, PathBuf};

use halo::coordinator::{InferenceEngine, Request, Server};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn prompt(seed: u64, len: usize, vocab: usize) -> Vec<i32> {
    let mut rng = halo::util::Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

#[test]
fn serving_stack_end_to_end() {
    let dir = match artifacts() {
        Some(p) => p,
        None => {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
    };
    let engine = InferenceEngine::load(&dir, 4).expect("engine load");
    let vocab = engine.vocab;
    let mut server = Server::new(engine);

    // --- determinism: same prompt twice produces identical tokens -------
    let p1 = prompt(1, 9, vocab);
    server.submit(Request::new(100, p1.clone(), 6));
    let (r1, _) = server.run_to_completion().unwrap();
    server.submit(Request::new(101, p1.clone(), 6));
    let (r2, _) = server.run_to_completion().unwrap();
    assert_eq!(r1[0].tokens, r2[0].tokens, "greedy generation must be deterministic");
    assert_eq!(r1[0].tokens.len(), 6);
    assert!(r1[0].tokens.iter().all(|t| (0..vocab as i32).contains(t)));

    // --- slot isolation: result is batch-composition independent --------
    let p2 = prompt(2, 12, vocab);
    let p3 = prompt(3, 5, vocab);
    server.submit(Request::new(200, p2.clone(), 5));
    let (alone, _) = server.run_to_completion().unwrap();
    server.submit(Request::new(201, p2.clone(), 5));
    server.submit(Request::new(202, p3.clone(), 7));
    server.submit(Request::new(203, prompt(4, 7, vocab), 4));
    let (together, _) = server.run_to_completion().unwrap();
    let t201 = together.iter().find(|r| r.id == 201).unwrap();
    assert_eq!(
        alone[0].tokens, t201.tokens,
        "a sequence's output must not depend on its batch-mates"
    );

    // --- continuous batching: more requests than slots ------------------
    for id in 0..7u64 {
        server.submit(Request::new(300 + id, prompt(10 + id, 4 + id as usize, vocab), 3));
    }
    let (many, stats) = server.run_to_completion().unwrap();
    assert_eq!(many.len(), 7);
    let mut ids: Vec<u64> = many.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, (300..307).collect::<Vec<_>>());
    assert!(many.iter().all(|r| r.tokens.len() == 3));
    assert!(stats.requests == 7 && stats.generated_tokens == 21);
    assert!(stats.execute_fraction() > 0.5, "PJRT should dominate wall time");

    // --- prompt-length ladder: both prefill sizes exercised -------------
    let long = prompt(20, 40, vocab); // > 16 -> uses the s64 executable
    server.submit(Request::new(400, long, 2));
    let (r, _) = server.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 2);

    // --- max_new_tokens == 1: satisfied by prefill alone -----------------
    server.submit(Request::new(500, prompt(30, 6, vocab), 1));
    let (r, stats) = server.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 1);
    assert_eq!(stats.decode_steps, 0);

    // --- oversized prompt is rejected, not wedged ------------------------
    server.submit(Request::new(600, prompt(40, 200, vocab), 2));
    server.submit(Request::new(601, prompt(41, 6, vocab), 2));
    let (r, _) = server.run_to_completion().unwrap();
    assert_eq!(r.len(), 1, "only the well-sized request completes");
    assert_eq!(r[0].id, 601);
}

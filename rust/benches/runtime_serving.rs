//! Functional-plane benchmarks: PJRT execute latency for the prefill and
//! batched decode entries, and the whole serving loop. Skipped when
//! `make artifacts` has not been run.

use std::path::Path;
use std::time::Duration;

use halo::coordinator::{InferenceEngine, Request, Server};
use halo::util::bench::{bb, BenchSuite};
use halo::util::Rng;

fn main() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("runtime_serving: skipped (run `make artifacts` first)");
        return;
    }
    let mut s = BenchSuite::new("runtime_serving").with_target_time(Duration::from_secs(5));

    // decode-step latency == functional TPOT at batch 4
    let mut engine = InferenceEngine::load(&artifacts, 4).expect("engine");
    let vocab = engine.vocab;
    engine.prefill_into_slot(0, 1, &[5, 17, 99, 3], 1 << 20).unwrap();
    engine.prefill_into_slot(1, 2, &[1, 2, 3, 4, 5, 6], 1 << 20).unwrap();
    let mut cur = vec![7i32; 4];
    s.bench_throughput("decode_step_batch4_2active", 2.0, || {
        let next = engine.decode_step(&cur).unwrap();
        cur = next;
        // keep positions bounded: the slots were given a huge budget and
        // max_seq wraps long before the bench ends, so re-arm when needed
        if engine.kv.active_slots().len() < 2 {
            engine.kv.release(0);
            engine.kv.release(1);
            engine.prefill_into_slot(0, 1, &[5, 17, 99, 3], 1 << 20).unwrap();
            engine.prefill_into_slot(1, 2, &[1, 2, 3, 4, 5, 6], 1 << 20).unwrap();
        }
        bb(&cur);
    });

    // prefill latency == functional TTFT (s16 and s64 ladder rungs)
    let mut engine2 = InferenceEngine::load(&artifacts, 4).expect("engine");
    s.bench("prefill_s16_ttft", || {
        let out = engine2.prefill_into_slot(2, 9, &[1, 2, 3, 4, 5, 6, 7, 8], 4).unwrap();
        engine2.kv.release(2);
        bb(out);
    });
    let long: Vec<i32> = (0..40).collect();
    s.bench("prefill_s64_ttft", || {
        let out = engine2.prefill_into_slot(2, 9, &long, 4).unwrap();
        engine2.kv.release(2);
        bb(out);
    });

    // whole serving loop: 6 requests through 4 slots
    let mut rng = Rng::new(5);
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|_| (0..rng.range(4, 12)).map(|_| rng.below(vocab as u64) as i32).collect())
        .collect();
    let mut server = Server::new(InferenceEngine::load(&artifacts, 4).expect("engine"));
    s.bench_throughput("serve_6_requests_8_tokens", 48.0, || {
        for (i, p) in prompts.iter().enumerate() {
            server.submit(Request::new(i as u64, p.clone(), 8));
        }
        bb(server.run_to_completion().unwrap());
    });
    s.finish();
}

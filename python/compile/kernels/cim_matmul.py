"""Pallas kernel: functional model of the HALO analog CiM crossbar GEMM.

This is the L1 compute hot-spot. The kernel reproduces, inside one Pallas
block, exactly what the paper's 8T-SRAM analog macro does (Section II /
Fig. 3c):

  * the weight operand is stored bit-sliced: ``slice_bits`` (2) bits per
    cell, so an 8-bit weight spans ``num_slices`` (4) crossbars;
  * the input operand is bit-streamed: 1 bit per cycle over ``input_bits``
    (8) cycles, applied to the wordlines;
  * each (input-bit, weight-slice) pair produces an analog partial sum per
    bitline, digitized by a 7-bit SAR ADC — modeled as round-to-nearest
    quantization onto the ADC's code grid with saturation;
  * wordline throttling: HALO1 activates all 128 rows at once, HALO2 only
    64 at a time (two phases, double the ADC conversions, finer ADC grid —
    the accuracy/latency trade-off of Table II);
  * shift-and-add recombines (bit, slice, phase) codes into the result.

BlockSpec tiles the GEMM into crossbar-shaped 128-row blocks: the grid's
K dimension walks one 128-row crossbar load per step, mirroring the
GB -> (IB, WB) double-buffered fills of the COMET pipeline (HBM<->VMEM
schedule on a real TPU).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets a
2.5D ASIC, not a GPU — so there is no warp/shared-memory idiom to port.
What we keep is the *dataflow*: weight-stationary 128x128 tiles, bit-serial
activation streaming, and per-tile quantized accumulation.

Kernels run with ``interpret=True`` (CPU PJRT); see DESIGN.md for the
real-TPU perf estimate methodology.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import XBAR_ROWS, CimSpec, HALO1_SPEC, HALO2_SPEC, pad_k, quantize_sym_i8


def _cim_block_kernel(x_ref, w_ref, o_ref, *, spec: CimSpec):
    """One (TM, 128) x (128, TN) crossbar-load worth of bit-serial GEMM.

    Accumulates int32 shift-and-add codes into ``o_ref`` across the K grid
    dimension (the grid walks K innermost, so accumulation is sequential).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tm = x_ref.shape[0]
    tn = w_ref.shape[1]
    nbits = spec.input_bits
    nsl = spec.num_slices
    nph = spec.phases_per_block
    wl = spec.wordlines

    # Unsigned cell domain (0..255): offset corrections happen digitally in
    # the wrapper, exactly as the macro's peripheral logic does.
    x_u = x_ref[...].astype(jnp.int32) + 128  # (TM, 128)
    w_u = w_ref[...].astype(jnp.int32) + 128  # (128, TN)

    bits = jnp.arange(nbits, dtype=jnp.int32)
    sl = jnp.arange(nsl, dtype=jnp.int32)

    # Input bit-planes (nbits, TM, nph, wl) and weight slice-planes
    # (nsl, nph, wl, TN): the nph axis is the wordline-throttling phase.
    x_planes = ((x_u[None, :, :] >> bits[:, None, None]) & 1).astype(jnp.float32)
    x_planes = x_planes.reshape(nbits, tm, nph, wl)
    w_planes = (
        (w_u[None, :, :] >> (spec.slice_bits * sl)[:, None, None]) & spec.slice_max
    ).astype(jnp.float32)
    w_planes = w_planes.reshape(nsl, nph, wl, tn)

    # Analog accumulation: one 'crossbar read' per (bit, slice, phase).
    partial = jnp.einsum("bmpw,spwn->bspmn", x_planes, w_planes)

    # Shift-and-add recombination: weight 2^(input_bit + slice_bits*slice).
    saa = (1 << bits)[:, None, None, None, None] * (
        1 << (spec.slice_bits * sl)[None, :, None, None, None]
    )

    if spec.ideal:
        codes = partial.astype(jnp.int32)
        o_ref[...] += jnp.sum(codes * saa, axis=(0, 1, 2), dtype=jnp.int32)
    elif spec.adc_mode == "full":
        delta = jnp.float32(spec.adc_delta)
        q = jnp.round(partial / delta)
        codes = jnp.clip(q, 0, spec.adc_levels).astype(jnp.int32)
        o_ref[...] += jnp.sum(codes * saa, axis=(0, 1, 2), dtype=jnp.int32)
    else:
        # Adaptive-SNR calibrated ADC (macro ref [1]): per-(slice, phase,
        # column) range centered on the expected partial sum for
        # Bernoulli(rho) input bits, +/- NSIGMA sigma wide.
        assert spec.adc_mode == "calibrated", spec.adc_mode
        rho, nsigma = 0.5, 4.0
        half = 1 << (spec.adc_bits - 1)
        center = rho * jnp.sum(w_planes, axis=2)  # (S, P, TN)
        sigma = jnp.sqrt(rho * (1 - rho) * jnp.sum(w_planes * w_planes, axis=2))
        delta = jnp.maximum(2.0 * nsigma * sigma / (2 * half), 1e-6)
        c = center[None, :, :, None, :]  # (1,S,P,1,TN)
        d = delta[None, :, :, None, :]
        q = jnp.clip(jnp.round((partial - c) / d), -half, half - 1)
        val = c + q * d
        o_ref[...] += jnp.sum(val * saa.astype(jnp.float32), axis=(0, 1, 2))


def _block_dim(size: int, pref: int) -> int:
    return pref if size >= pref else size


def cim_matmul_codes(
    x_i8: jnp.ndarray,
    w_i8: jnp.ndarray,
    spec: CimSpec = HALO1_SPEC,
    *,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """Unsigned-domain crossbar codes via the Pallas kernel.

    x_i8 (M, K) int8, w_i8 (K, N) int8; K must already be a multiple of 128
    (use :func:`ref.pad_k`). M and N are padded here as needed. Matches
    :func:`ref.cim_matmul_codes_ref` bit-exactly.
    """
    m, k = x_i8.shape
    k2, n = w_i8.shape
    assert k == k2 and k % XBAR_ROWS == 0, (k, k2)

    tm = _block_dim(m, block_m)
    tn = _block_dim(n, block_n)
    m_pad = (-m) % tm
    n_pad = (-n) % tn
    # -128 pads are zero in the unsigned domain: they contribute nothing to
    # any bit/slice plane, so padded rows/cols carry no ADC noise either.
    if m_pad:
        x_i8 = jnp.pad(x_i8, ((0, m_pad), (0, 0)), constant_values=-128)
    if n_pad:
        w_i8 = jnp.pad(w_i8, ((0, 0), (0, n_pad)), constant_values=-128)
    mp, np_ = m + m_pad, n + n_pad

    grid = (mp // tm, np_ // tn, k // XBAR_ROWS)
    acc_dtype = jnp.float32 if (not spec.ideal and spec.adc_mode == "calibrated") else jnp.int32
    out = pl.pallas_call(
        functools.partial(_cim_block_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, XBAR_ROWS), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((XBAR_ROWS, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), acc_dtype),
        interpret=True,  # CPU PJRT; Mosaic lowering is TPU-only
    )(x_i8, w_i8)
    return out[:m, :n]


def cim_matmul(
    x_i8: jnp.ndarray, w_i8: jnp.ndarray, spec: CimSpec = HALO1_SPEC
) -> jnp.ndarray:
    """Signed CiM matmul X @ W (float result of the analog pipeline).

    Applies the digital offset corrections around the unsigned-domain
    Pallas kernel; pads K internally.
    """
    k_real = x_i8.shape[1]
    xq, wq, _ = pad_k(x_i8, w_i8)
    codes = cim_matmul_codes(xq, wq, spec)
    # "calibrated" accumulates real-valued ADC estimates; "full" integer
    # codes on a uniform grid of pitch adc_delta; "ideal" exact partials.
    if spec.ideal or spec.adc_mode == "calibrated":
        delta = 1.0
    else:
        delta = spec.adc_delta
    # Corrections use the *unpadded* operands: pad value -128 maps to 0 in
    # the unsigned domain, so padded rows/cols contribute nothing to the
    # kernel's codes, and the identity
    #   X@W = X_u@W_u - 128*rowsum(X_u) - 128*colsum(W_u) + 128^2*K
    # holds with K = the real contraction length.
    xu_rowsum = jnp.sum(x_i8.astype(jnp.int32) + 128, axis=1, keepdims=True)
    wu_colsum = jnp.sum(w_i8.astype(jnp.int32) + 128, axis=0, keepdims=True)
    y_u = codes.astype(jnp.float32) * jnp.float32(delta)
    return y_u - 128.0 * xu_rowsum - 128.0 * wu_colsum + 128.0 * 128.0 * k_real


def cim_linear(
    x: jnp.ndarray, w: jnp.ndarray, spec: CimSpec = HALO1_SPEC
) -> jnp.ndarray:
    """Float ``x @ w`` through the analog CiM path (fake-quantized int8).

    ``x`` may have any number of leading batch dims; the last dim contracts.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    qx, sx = quantize_sym_i8(x2)
    qw, sw = quantize_sym_i8(w)
    y = cim_matmul(qx, qw, spec)
    return (y * (sx * sw)).reshape(*lead, w.shape[-1])

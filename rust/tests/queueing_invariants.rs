//! Property tests on the queueing simulator's invariants — regression
//! guards for the Device extraction: `replay_trace` must conserve
//! requests across seeds/rates/mappings, every TTFT must cover that
//! request's prefill latency, and e2e must dominate TTFT.

use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::sim::device::CostModel;
use halo::sim::queueing::{poisson_trace, replay_trace};
use halo::util::prop::{forall, OneOf, Triple, UsizeIn};

fn hw() -> HwConfig {
    HwConfig::paper()
}

const MAPPINGS: [MappingKind; 3] =
    [MappingKind::Halo1, MappingKind::Cent, MappingKind::AttAcc1];

const RATES: [u64; 4] = [1, 5, 25, 500];

#[test]
fn replay_conserves_requests_across_seeds_and_rates() {
    let llm = LlmConfig::llama2_7b();
    forall(
        101,
        12,
        Triple(UsizeIn(1, 1000), OneOf(&RATES), UsizeIn(1, 6)),
        |(seed, rate, slots)| {
            let tr = poisson_trace(*seed as u64, 30, *rate as f64, (64, 1024), 24);
            let r = replay_trace(&llm, &hw(), MappingKind::Halo1, *slots, &tr);
            if r.served.len() != tr.len() {
                return false;
            }
            // every arrival appears exactly once in the served set
            let mut got: Vec<f64> = r.served.iter().map(|s| s.arrival).collect();
            let mut want: Vec<f64> = tr.iter().map(|q| q.arrival).collect();
            got.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            got == want
        },
    );
}

#[test]
fn ttft_covers_prefill_and_e2e_covers_ttft() {
    let llm = LlmConfig::llama2_7b();
    forall(
        102,
        8,
        Triple(UsizeIn(1, 1000), OneOf(&RATES), OneOf(&MAPPINGS)),
        |(seed, rate, mapping)| {
            let tr = poisson_trace(*seed as u64 + 7, 25, *rate as f64, (64, 2048), 16);
            let r = replay_trace(&llm, &hw(), *mapping, 4, &tr);
            let mut cost = CostModel::new(&llm, &hw(), *mapping);
            r.served.iter().all(|s| {
                // arrivals are unique, so they key the original request
                let req = tr.iter().find(|q| q.arrival == s.arrival).expect("served unknown arrival");
                let p = cost.prefill(req.l_in);
                s.ttft >= p - 1e-12 && s.e2e >= s.ttft - 1e-12
            })
        },
    );
}

#[test]
fn decode_steps_cover_longest_output() {
    let llm = LlmConfig::llama2_7b();
    forall(103, 10, Triple(UsizeIn(1, 500), OneOf(&RATES), UsizeIn(2, 64)), |(seed, rate, l_out)| {
        let tr = poisson_trace(*seed as u64, 20, *rate as f64, (64, 512), *l_out);
        let r = replay_trace(&llm, &hw(), MappingKind::Halo1, 4, &tr);
        // each decode step emits at most one token per sequence, and the
        // first token comes from prefill
        r.decode_steps >= (*l_out as u64 - 1).max(1)
            && r.makespan >= tr.last().unwrap().arrival
    });
}

//! # HALO — Memory-Centric Heterogeneous Accelerator for Low-Batch LLM Inference
//!
//! Full reproduction of *HALO: Memory-Centric Heterogeneous Accelerator with
//! 2.5D Integration for Low-Batch LLM Inference* (Negi & Roy, cs.AR 2025).
//!
//! The crate has two planes (see `DESIGN.md`):
//!
//! * **Analytical plane** — the paper's contribution: latency/energy models
//!   of the CiD (compute-in-DRAM) and analog CiM substrates ([`arch`]), the
//!   LLM operator-graph workload model ([`model`]), the phase-aware mapping
//!   engine ([`mapping`]), the simulation engine ([`sim`]) and the harness
//!   that regenerates every figure in the paper's evaluation ([`report`]).
//!
//! * **Functional plane** — an AOT-compiled tiny LLaMA-style model whose
//!   GEMMs run through Pallas kernels that model the CiM/CiD numerics
//!   (bit-sliced, bit-streamed, ADC-quantized). The Rust side loads the
//!   lowered HLO through PJRT ([`runtime`]) and serves real token-generation
//!   requests with a phase-aware dispatcher ([`coordinator`]); Python is
//!   never on the request path.
//!
//! * **Cluster plane** — fleet-scale serving built on the analytical
//!   plane ([`cluster`]): N independent device state machines
//!   ([`sim::device`]) behind pluggable routers, including a
//!   phase-disaggregated policy that takes the paper's prefill-on-CiM /
//!   decode-on-CiD mapping to cluster scale, with KV-cache transfers
//!   charged over a configurable interconnect. Each device carries a
//!   pluggable scheduler ([`sim::device::SchedConfig`]): chunked prefill
//!   (`--chunk`) interleaves prompt chunks with the running decode batch,
//!   admission policies (`--admission` fifo/spf/priority) reorder the
//!   ready queue, and a resident-KV byte budget (`--kv-cap`) enforces
//!   decode-side capacity with vLLM-style eviction-and-recompute; the
//!   kvaware router skips full decode devices. Named workload mixes
//!   (chat, summarization, generation, interactive) drive saturation,
//!   scaling-efficiency, tail-latency, chunk-size, and capacity-pressure
//!   studies (`halo cluster`, `halo report --fig cluster`). Traces carry
//!   optional tenant tags with per-tenant TTFT/throughput breakdowns.
//!
//! * **DSE plane** — design-space exploration and SLO auto-tuning
//!   ([`dse`]): a deterministic, seeded search engine over mappings,
//!   scheduler knobs, fleet composition, and hardware knobs (CiM tile
//!   mesh, interposer bandwidth). Pluggable strategies (grid, random,
//!   hill-climb) drive memoized fleet replays; results come back as a
//!   Pareto frontier over configurable objectives (TTFT p50/p99, decode
//!   throughput, evictions, SLO attainment, fleet cost), and an SLO mode
//!   returns the cheapest configuration meeting a TTFT target
//!   (`halo dse`, `halo report --fig dse`). The §V-B Fully-CiD /
//!   Fully-CiM / HALO comparison falls out as a degenerate 3-point
//!   search.
//!
//! * **Power plane** — per-event energy accounting and thermal/TDP/DVFS
//!   feedback ([`power`]). Latency and energy come out of *one* joint
//!   oracle ([`sim::cost::CostModel`]): each distinct (prefill-length /
//!   decode-batch / chunk) point walks `simulate_graph` exactly once and
//!   yields a [`sim::cost::PhaseCost`] whose latency advances the device
//!   clock and whose [`sim::cost::EnergyBreakdown`] (CiD DRAM/MAC, CiM
//!   DAC/ADC/write, systolic, buffers) is charged for the same busy
//!   event — the planes agree bit-for-bit by construction, and power
//!   tracking adds zero walks. On top, [`power`] keeps what a walk
//!   cannot see: the static refresh/leakage floor over wall-clock time,
//!   a per-package RC thermal model whose TDP cap throttles service
//!   (with a 2.5D coupling term that doubles HBM refresh when the CiM
//!   die runs hot), per-phase DVFS ([`power::DvfsConfig`]: a
//!   voltage-frequency ladder scaling latency by `1/f` and dynamic
//!   energy by `V^2`, selectable per phase statically or stepped by the
//!   thermal governor under a TDP cap), and windowed power traces.
//!   Threaded through fleet stats (per-device energy/utilization,
//!   KV-transfer energy) and the DSE objectives (`energy-per-token`,
//!   `edp`, `peak-power`, with TDP and DVFS as search axes). Surfaces:
//!   `halo power`, `halo report --fig power`,
//!   `halo cluster --power/--tdp/--dvfs`.
//!
//! * **Observability plane** — request-lifecycle tracing, streaming
//!   metrics, and the simulator's own perf trajectory ([`obs`]).
//!   Opt-in span recorders ([`obs::Recorder`]) ride on every device and
//!   copy the same `f64`s that advance the clock — an instrumented
//!   replay is bit-identical to an untracked one, and recorded span
//!   totals reconcile exactly with each device's busy accounting.
//!   `halo trace` exports the timelines as Chrome-trace JSON (one track
//!   per device plus a KV-transfer interconnect track; opens in
//!   Perfetto). A fixed-memory log-bucketed histogram
//!   ([`obs::LogHistogram`]) and counter/gauge registry feed versioned
//!   `--json` snapshots on `halo cluster` and `halo dse`; replay
//!   percentiles come off cached sorted views instead of a
//!   clone-and-sort per call. [`obs::SelfProfile`] accounts the
//!   simulator's own wall time (never mixed into simulated results),
//!   and `halo bench` runs pinned workloads into a `halo.bench.v1`
//!   artifact CI tracks commit over commit with a warn-only regression
//!   gate.
//!
//! Quickstart:
//! ```no_run
//! use halo::config::HwConfig;
//! use halo::mapping::MappingKind;
//! use halo::model::LlmConfig;
//! use halo::sim::{simulate_e2e, Scenario};
//!
//! let hw = HwConfig::paper();
//! let llm = LlmConfig::llama2_7b();
//! let sc = Scenario { l_in: 2048, l_out: 128, batch: 1 };
//! let res = simulate_e2e(&llm, &hw, MappingKind::Halo1, &sc);
//! println!("TTFT {:.3} ms, TPOT {:.3} ms", res.ttft() * 1e3, res.tpot() * 1e3);
//! ```

pub mod arch;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod mapping;
pub mod model;
pub mod obs;
pub mod power;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

//! COZ-style what-if engine: virtual speedup over extracted critical
//! paths.
//!
//! Given the per-request critical paths from [`crate::obs::critpath`],
//! a [`WhatIf`] scales every segment bound by a chosen resource and
//! re-folds each path — answering "what would interposer bandwidth ×2
//! buy on the p99?" deterministically, without a re-simulation. The
//! estimate is first-order (it rescales recorded time; it does not
//! re-run admission or batching decisions), so `halo critpath`
//! cross-checks one scaled point against a real replay — the estimate
//! must agree with the true replay in sign and land within a pinned
//! relative bound (enforced in `rust/tests/critpath_plane.rs`).
//!
//! TTFT is estimated by walking each path's segments until the
//! cumulative unscaled time reaches the recorded TTFT, scaling the
//! straddling segment fractionally — the first token moves with the
//! resources on the prefill side of the path only.

use super::critpath::{CritPath, Resource, N_RESOURCES};
use crate::util::percentile;

/// One counterfactual: per-resource time scale factors. A factor of
/// 0.5 on [`Resource::Interconnect`] models "interconnect bandwidth
/// ×2" (transfer time halves); 0.0 on [`Resource::Thermal`] models
/// "no TDP cap" (stalls vanish).
#[derive(Debug, Clone)]
pub struct WhatIf {
    pub name: &'static str,
    pub desc: &'static str,
    pub scale: [f64; N_RESOURCES],
}

impl WhatIf {
    pub fn new(name: &'static str, desc: &'static str) -> Self {
        WhatIf { name, desc, scale: [1.0; N_RESOURCES] }
    }

    pub fn scaled(mut self, resource: Resource, factor: f64) -> Self {
        self.scale[resource.index()] = factor;
        self
    }
}

/// The standard counterfactual set `halo critpath` evaluates.
pub fn standard_whatifs() -> Vec<WhatIf> {
    vec![
        WhatIf::new("interconnect_bw_x2", "interposer/interconnect bandwidth x2")
            .scaled(Resource::Interconnect, 0.5),
        WhatIf::new("cim_mesh_x2", "CiM tile mesh x2 (prefill compute x2)")
            .scaled(Resource::CimCompute, 0.5),
        WhatIf::new("kv_budget_1p5x", "KV byte budget +50% (recompute/blocked time x2/3)")
            .scaled(Resource::KvCapacity, 2.0 / 3.0),
        WhatIf::new("no_tdp_cap", "no TDP cap (thermal stalls vanish)")
            .scaled(Resource::Thermal, 0.0),
    ]
}

/// Estimated latency distribution shift under one [`WhatIf`].
#[derive(Debug, Clone, Copy)]
pub struct WhatIfResult {
    pub name: &'static str,
    pub desc: &'static str,
    pub base_ttft_p99_s: f64,
    pub base_e2e_p99_s: f64,
    pub est_ttft_p99_s: f64,
    pub est_e2e_p99_s: f64,
    /// `est - base`; negative means the counterfactual helps.
    pub delta_ttft_p99_s: f64,
    pub delta_e2e_p99_s: f64,
    pub base_e2e_mean_s: f64,
    pub est_e2e_mean_s: f64,
    pub delta_e2e_mean_s: f64,
}

/// One path's scaled `(ttft, e2e)` under the what-if's factors.
pub fn scaled_latencies(path: &CritPath, w: &WhatIf) -> (f64, f64) {
    let mut cum = 0.0f64;
    let mut e2e = 0.0f64;
    let mut ttft = 0.0f64;
    for s in &path.segments {
        let k = w.scale[s.resource.index()];
        e2e += s.dur * k;
        if cum < path.ttft && s.dur > 0.0 {
            // the part of this segment on the prefill side of the
            // first token, scaled — fractional when it straddles
            let take = s.dur.min(path.ttft - cum);
            ttft += take * k;
        }
        cum += s.dur;
    }
    (ttft.max(0.0), e2e.max(0.0))
}

/// Evaluate one counterfactual over the whole path population.
pub fn evaluate(paths: &[CritPath], w: &WhatIf) -> WhatIfResult {
    let zero = WhatIfResult {
        name: w.name,
        desc: w.desc,
        base_ttft_p99_s: 0.0,
        base_e2e_p99_s: 0.0,
        est_ttft_p99_s: 0.0,
        est_e2e_p99_s: 0.0,
        delta_ttft_p99_s: 0.0,
        delta_e2e_p99_s: 0.0,
        base_e2e_mean_s: 0.0,
        est_e2e_mean_s: 0.0,
        delta_e2e_mean_s: 0.0,
    };
    if paths.is_empty() {
        return zero;
    }
    let base_ttft: Vec<f64> = paths.iter().map(|p| p.ttft).collect();
    let base_e2e: Vec<f64> = paths.iter().map(|p| p.e2e).collect();
    let mut est_ttft = Vec::with_capacity(paths.len());
    let mut est_e2e = Vec::with_capacity(paths.len());
    for p in paths {
        let (t, e) = scaled_latencies(p, w);
        est_ttft.push(t);
        est_e2e.push(e);
    }
    let n = paths.len() as f64;
    let bt = percentile(&base_ttft, 99.0);
    let be = percentile(&base_e2e, 99.0);
    let et = percentile(&est_ttft, 99.0);
    let ee = percentile(&est_e2e, 99.0);
    let bm = base_e2e.iter().sum::<f64>() / n;
    let em = est_e2e.iter().sum::<f64>() / n;
    WhatIfResult {
        base_ttft_p99_s: bt,
        base_e2e_p99_s: be,
        est_ttft_p99_s: et,
        est_e2e_p99_s: ee,
        delta_ttft_p99_s: et - bt,
        delta_e2e_p99_s: ee - be,
        base_e2e_mean_s: bm,
        est_e2e_mean_s: em,
        delta_e2e_mean_s: em - bm,
        ..zero
    }
}

/// Evaluate every counterfactual in `ws`.
pub fn evaluate_all(paths: &[CritPath], ws: &[WhatIf]) -> Vec<WhatIfResult> {
    ws.iter().map(|w| evaluate(paths, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::critpath::Segment;

    fn path(segs: &[(&'static str, Resource, f64)], ttft: f64) -> CritPath {
        let mut start = 0.0;
        let segments: Vec<Segment> = segs
            .iter()
            .map(|&(label, resource, dur)| {
                let s = Segment {
                    label,
                    resource,
                    phase: if start < ttft { "prefill" } else { "decode" },
                    start,
                    dur,
                };
                start += dur;
                s
            })
            .collect();
        let e2e = segments.iter().fold(0.0, |a, s| a + s.dur);
        CritPath { arrival: 0.0, ttft, e2e, segments, coverage: 1.0 }
    }

    #[test]
    fn identity_whatif_changes_nothing() {
        let p = path(
            &[
                ("queue_wait", Resource::Scheduler, 0.2),
                ("prefill", Resource::CimCompute, 0.5),
                ("decode_step", Resource::CidBandwidth, 0.3),
            ],
            0.7,
        );
        let r = evaluate(&[p], &WhatIf::new("noop", "identity"));
        assert!((r.delta_e2e_p99_s).abs() < 1e-12);
        assert!((r.delta_ttft_p99_s).abs() < 1e-12);
    }

    #[test]
    fn scaling_decode_only_moves_e2e_not_ttft() {
        let p = path(
            &[
                ("prefill", Resource::CimCompute, 0.5),
                ("decode_step", Resource::CidBandwidth, 1.0),
            ],
            0.5,
        );
        let w = WhatIf::new("decode_x2", "").scaled(Resource::CidBandwidth, 0.5);
        let r = evaluate(&[p], &w);
        assert!((r.delta_ttft_p99_s).abs() < 1e-12, "ttft is prefill-side only");
        assert!((r.est_e2e_p99_s - 1.0).abs() < 1e-12, "0.5 + 0.5*1.0");
    }

    #[test]
    fn ttft_straddling_segment_scales_fractionally() {
        // one prefill segment of 1.0 with ttft 0.6 inside it: scaling
        // prefill x0.5 halves the straddled fraction too
        let p = path(&[("prefill", Resource::CimCompute, 1.0)], 0.6);
        let w = WhatIf::new("p", "").scaled(Resource::CimCompute, 0.5);
        let (t, e) = scaled_latencies(&p, &w);
        assert!((t - 0.3).abs() < 1e-12);
        assert!((e - 0.5).abs() < 1e-12);
    }

    #[test]
    fn thermal_zeroing_removes_exactly_the_stall() {
        let p = path(
            &[
                ("prefill", Resource::CimCompute, 0.4),
                ("throttle_stall", Resource::Thermal, 0.2),
                ("decode_step", Resource::CidBandwidth, 0.4),
            ],
            0.6,
        );
        let r = evaluate(&[p], &standard_whatifs()[3]);
        assert!((r.delta_e2e_p99_s + 0.2).abs() < 1e-12);
    }

    #[test]
    fn empty_population_is_safe() {
        for w in standard_whatifs() {
            let r = evaluate(&[], &w);
            assert_eq!(r.base_e2e_p99_s, 0.0);
            assert_eq!(r.delta_e2e_p99_s, 0.0);
        }
    }

    #[test]
    fn standard_set_covers_the_advertised_axes() {
        let ws = standard_whatifs();
        assert_eq!(ws.len(), 4);
        assert_eq!(ws[0].scale[Resource::Interconnect.index()], 0.5);
        assert_eq!(ws[1].scale[Resource::CimCompute.index()], 0.5);
        assert!((ws[2].scale[Resource::KvCapacity.index()] - 2.0 / 3.0).abs() < 1e-15);
        assert_eq!(ws[3].scale[Resource::Thermal.index()], 0.0);
        // every other factor stays identity
        assert_eq!(ws[0].scale[Resource::Scheduler.index()], 1.0);
    }
}

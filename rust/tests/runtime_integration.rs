//! Runtime integration tests: manifest + weights + PJRT round-trips over
//! the real artifacts. Skipped (pass vacuously) when `make artifacts` has
//! not run — CI for the analytical plane must not require jax.

use std::path::{Path, PathBuf};

use halo::runtime::{Dtype, HostTensor, Manifest, Runtime, Weights};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

#[test]
fn manifest_and_weights_agree_with_model_shapes() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let w = Weights::load(&m).unwrap();
    assert_eq!(w.tensors.len(), m.params.len());
    // first param is the embedding (vocab, d_model)
    let vocab = m.config_usize("vocab").unwrap();
    let d = m.config_usize("d_model").unwrap();
    assert_eq!(m.params[0].shape, vec![vocab, d]);
    assert_eq!(m.params[0].name, "embed");
    // parameter blob is densely packed
    let total: usize = m.params.iter().map(|p| p.nelems * 4).sum();
    assert_eq!(std::fs::metadata(dir.join("weights.bin")).unwrap().len() as usize, total);
}

#[test]
fn manifest_entries_have_consistent_signatures() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for (name, e) in &m.entries {
        assert!(dir.join(&e.hlo_file).exists(), "{name} hlo missing");
        assert!(e.n_params <= e.inputs.len());
        // every testvec file exists and matches its signature's byte size
        for (f, spec) in e.testvec_inputs.iter().zip(&e.inputs[e.n_params..]) {
            let sz = std::fs::metadata(dir.join("testvec").join(f)).unwrap().len() as usize;
            assert_eq!(sz, spec.nbytes(), "{name}/{f}");
        }
        for (f, spec) in e.testvec_outputs.iter().zip(&e.outputs) {
            let sz = std::fs::metadata(dir.join("testvec").join(f)).unwrap().len() as usize;
            assert_eq!(sz, spec.nbytes(), "{name}/{f}");
        }
    }
}

#[test]
fn cid_kernel_roundtrip_is_exact() {
    // int8 GEMV through HLO text -> PJRT equals the python-side vector
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let spec = rt.manifest.entry("cid_gemv_4x256x512").unwrap().clone();
    let exe = rt.compile("cid_gemv_4x256x512").unwrap();
    let inputs: Vec<HostTensor> = spec
        .testvec_inputs
        .iter()
        .zip(&spec.inputs[spec.n_params..])
        .map(|(f, s)| rt.manifest.load_testvec(f, s).unwrap())
        .collect();
    let outs = exe.run(&inputs).unwrap();
    let want = rt
        .manifest
        .load_testvec(&spec.testvec_outputs[0], &spec.outputs[0])
        .unwrap();
    assert_eq!(outs[0], want, "digital path must be bit-exact");
}

#[test]
fn cid_kernel_matches_host_reference() {
    // independent check: recompute the int8 GEMM on the host in Rust
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let spec = rt.manifest.entry("cid_gemv_4x256x512").unwrap().clone();
    let x = rt.manifest.load_testvec(&spec.testvec_inputs[0], &spec.inputs[0]).unwrap();
    let w = rt.manifest.load_testvec(&spec.testvec_inputs[1], &spec.inputs[1]).unwrap();
    let want = rt.manifest.load_testvec(&spec.testvec_outputs[0], &spec.outputs[0]).unwrap();
    let (m, k) = (x.spec.shape[0], x.spec.shape[1]);
    let n = w.spec.shape[1];
    let xs = x.as_i8().unwrap();
    let ws = w.as_i8().unwrap();
    let mut host = vec![0i32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xv = xs[i * k + kk] as i32;
            for j in 0..n {
                host[i * n + j] += xv * ws[kk * n + j] as i32;
            }
        }
    }
    assert_eq!(host, want.as_i32().unwrap());
}

#[test]
fn prefill_ideal_deterministic_across_runs() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let name = "prefill_ideal_b1_s16";
    let spec = rt.manifest.entry(name).unwrap().clone();
    let exe = rt.compile(name).unwrap();
    let inputs: Vec<HostTensor> = spec
        .testvec_inputs
        .iter()
        .zip(&spec.inputs[spec.n_params..])
        .map(|(f, s)| rt.manifest.load_testvec(f, s).unwrap())
        .collect();
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y);
    }
    assert_eq!(a[0].spec.dtype, Dtype::F32);
}

#[test]
fn decode_entry_shape_contract() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let e = m.entry("decode_b4").unwrap();
    let nl = m.config_usize("n_layers").unwrap();
    let s = m.config_usize("max_seq").unwrap();
    let kvh = m.config_usize("n_kv_heads").unwrap();
    let hd = m.config_usize("head_dim").unwrap();
    let np = e.n_params;
    assert_eq!(e.inputs[np].shape, vec![4]); // tokens
    assert_eq!(e.inputs[np + 1].shape, vec![4]); // pos
    assert_eq!(e.inputs[np + 2].shape, vec![nl, 4, s, kvh, hd]); // K
    assert_eq!(e.outputs[1].shape, vec![nl, 4, s, kvh, hd]); // K'
}

//! Operator-graph builder: (model, phase, context, batch) -> costed ops.
//!
//! The graphs mirror Fig. 2 of the paper: a decoder block is LayerNorm ->
//! QKV -> RoPE -> attention (score, softmax, value) -> projection ->
//! residual -> LayerNorm -> SwiGLU FFN -> residual, followed by a final
//! norm + LM head. Per-layer/per-head replication is collapsed into the
//! op's `count` (costs are identical across uniform layers).

use super::ops::{Op, OpClass, OpKind, Operand};
use super::{LlmConfig, Phase};

/// A phase's worth of operations plus scenario metadata.
#[derive(Debug, Clone)]
pub struct OpGraph {
    pub phase: Phase,
    pub batch: usize,
    /// Prefill: prompt length. Decode: context length at this step.
    pub seq: usize,
    pub ops: Vec<Op>,
}

impl OpGraph {
    pub fn total_flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops()).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    pub fn matmul_ops(&self) -> impl Iterator<Item = &Op> {
        self.ops.iter().filter(|o| o.is_matmul())
    }

    pub fn non_gemm_ops(&self) -> impl Iterator<Item = &Op> {
        self.ops.iter().filter(|o| !o.is_matmul())
    }

    /// Weight bytes streamed if every static stationary operand is read
    /// once (the minimum possible weight traffic).
    pub fn static_weight_bytes(&self, dtype_bytes: usize) -> u64 {
        self.ops
            .iter()
            .filter(|o| o.operand == Operand::StaticWeight)
            .map(|o| o.stationary_bytes(dtype_bytes))
            .sum()
    }
}

/// Build the prefill graph: process `l_in` prompt tokens for `batch`
/// sequences (GEMM-dominated, Fig. 2a).
pub fn build_prefill_graph(m: &LlmConfig, l_in: usize, batch: usize) -> OpGraph {
    assert!(l_in > 0 && batch > 0);
    let nl = m.n_layers;
    let bl = batch * l_in;
    let mut ops = Vec::new();

    ops.push(
        Op::non_gemm(OpKind::Embedding, (bl * m.d_model) as u64, 1)
            .with_stream_bytes((bl * m.d_model * m.dtype_bytes) as u64),
    );

    // attention half
    ops.push(
        Op::non_gemm(OpKind::RmsNorm, (bl * m.d_model * 5) as u64, nl).with_scalar(bl as u64),
    );
    ops.push(Op::matmul(
        OpKind::QkvProj,
        OpClass::Gemm,
        Operand::StaticWeight,
        bl,
        m.d_model,
        m.q_dim() + 2 * m.kv_dim(),
        nl,
    ));
    ops.push(Op::non_gemm(OpKind::Rope, (bl * (m.q_dim() + m.kv_dim()) * 3) as u64, nl));
    // KV cache write-out (bank-level DRAM writes)
    ops.push(
        Op::non_gemm(OpKind::KvAppend, 0, nl)
            .with_stream_bytes((bl * 2 * m.kv_dim() * m.kv_bytes) as u64),
    );
    // attention scores / values: one op per KV head (GQA: the group's
    // `g` query heads share the KV stream, so they batch into the moving
    // operand instead of replicating the stationary one). Causal masking
    // halves the useful work; hardware still executes block-aligned
    // tiles, modeled as a 0.55 occupancy factor on L.
    let l_eff = (l_in as f64 * 0.55).ceil() as usize;
    let g = m.n_heads / m.n_kv_heads;
    ops.push(Op::matmul(
        OpKind::AttnScore,
        OpClass::Attention,
        Operand::Dynamic,
        l_in * g,
        m.head_dim,
        l_eff,
        batch * m.n_kv_heads * nl,
    ));
    ops.push(
        Op::non_gemm(OpKind::Softmax, (batch * m.n_heads * l_in * l_eff * 3) as u64, nl)
            .with_exp((batch * m.n_heads * l_in * l_eff) as u64),
    );
    ops.push(Op::matmul(
        OpKind::AttnValue,
        OpClass::Attention,
        Operand::Dynamic,
        l_in * g,
        l_eff,
        m.head_dim,
        batch * m.n_kv_heads * nl,
    ));
    ops.push(Op::matmul(
        OpKind::OutProj,
        OpClass::Gemm,
        Operand::StaticWeight,
        bl,
        m.q_dim(),
        m.d_model,
        nl,
    ));
    ops.push(Op::non_gemm(OpKind::Residual, (bl * m.d_model) as u64, 2 * nl));

    // FFN half (SwiGLU)
    ops.push(
        Op::non_gemm(OpKind::RmsNorm, (bl * m.d_model * 5) as u64, nl).with_scalar(bl as u64),
    );
    ops.push(Op::matmul(
        OpKind::FfnGate,
        OpClass::Gemm,
        Operand::StaticWeight,
        bl,
        m.d_model,
        m.d_ff,
        nl,
    ));
    ops.push(Op::matmul(
        OpKind::FfnUp,
        OpClass::Gemm,
        Operand::StaticWeight,
        bl,
        m.d_model,
        m.d_ff,
        nl,
    ));
    ops.push(
        Op::non_gemm(OpKind::Activation, (bl * m.d_ff * 4) as u64, nl)
            .with_exp((bl * m.d_ff) as u64),
    );
    ops.push(Op::matmul(
        OpKind::FfnDown,
        OpClass::Gemm,
        Operand::StaticWeight,
        bl,
        m.d_ff,
        m.d_model,
        nl,
    ));

    // final norm + LM head for the *last* position only (TTFT definition:
    // time to the first generated token)
    ops.push(
        Op::non_gemm(OpKind::RmsNorm, (batch * m.d_model * 5) as u64, 1)
            .with_scalar(batch as u64),
    );
    ops.push(Op::matmul(
        OpKind::LmHead,
        OpClass::Gemm,
        Operand::StaticWeight,
        batch,
        m.d_model,
        m.vocab,
        1,
    ));

    OpGraph { phase: Phase::Prefill, batch, seq: l_in, ops }
}

/// Build one decode step at context length `l_ctx` (GEMV-dominated,
/// Fig. 2b). Each of the `batch` sequences has its own KV cache.
pub fn build_decode_graph(m: &LlmConfig, l_ctx: usize, batch: usize) -> OpGraph {
    assert!(l_ctx > 0 && batch > 0);
    let nl = m.n_layers;
    let b = batch;
    let mut ops = Vec::new();

    ops.push(
        Op::non_gemm(OpKind::Embedding, (b * m.d_model) as u64, 1)
            .with_stream_bytes((b * m.d_model * m.dtype_bytes) as u64),
    );
    ops.push(Op::non_gemm(OpKind::RmsNorm, (b * m.d_model * 5) as u64, nl).with_scalar(b as u64));
    // weight GEMVs: one row per sequence; batched sequences share the
    // weight stream (the CiD model decides how much reuse the 4 KB input
    // buffer actually allows)
    ops.push(Op::matmul(
        OpKind::QkvProj,
        OpClass::Gemv,
        Operand::StaticWeight,
        b,
        m.d_model,
        m.q_dim() + 2 * m.kv_dim(),
        nl,
    ));
    ops.push(Op::non_gemm(OpKind::Rope, (b * (m.q_dim() + m.kv_dim()) * 3) as u64, nl));
    ops.push(
        Op::non_gemm(OpKind::KvAppend, 0, nl)
            .with_stream_bytes((b * 2 * m.kv_dim() * m.kv_bytes) as u64),
    );
    // attention against the per-sequence KV cache: a dynamic stationary
    // operand of l_ctx rows, shared by each GQA group's `g` query heads
    let g = m.n_heads / m.n_kv_heads;
    ops.push(Op::matmul(
        OpKind::AttnScore,
        OpClass::Attention,
        Operand::Dynamic,
        g,
        m.head_dim,
        l_ctx,
        b * m.n_kv_heads * nl,
    ));
    ops.push(
        Op::non_gemm(OpKind::Softmax, (b * m.n_heads * l_ctx * 3) as u64, nl)
            .with_exp((b * m.n_heads * l_ctx) as u64),
    );
    ops.push(Op::matmul(
        OpKind::AttnValue,
        OpClass::Attention,
        Operand::Dynamic,
        g,
        l_ctx,
        m.head_dim,
        b * m.n_kv_heads * nl,
    ));
    ops.push(Op::matmul(
        OpKind::OutProj,
        OpClass::Gemv,
        Operand::StaticWeight,
        b,
        m.q_dim(),
        m.d_model,
        nl,
    ));
    ops.push(Op::non_gemm(OpKind::Residual, (b * m.d_model) as u64, 2 * nl));
    ops.push(Op::non_gemm(OpKind::RmsNorm, (b * m.d_model * 5) as u64, nl).with_scalar(b as u64));
    ops.push(Op::matmul(
        OpKind::FfnGate,
        OpClass::Gemv,
        Operand::StaticWeight,
        b,
        m.d_model,
        m.d_ff,
        nl,
    ));
    ops.push(Op::matmul(
        OpKind::FfnUp,
        OpClass::Gemv,
        Operand::StaticWeight,
        b,
        m.d_model,
        m.d_ff,
        nl,
    ));
    ops.push(
        Op::non_gemm(OpKind::Activation, (b * m.d_ff * 4) as u64, nl)
            .with_exp((b * m.d_ff) as u64),
    );
    ops.push(Op::matmul(
        OpKind::FfnDown,
        OpClass::Gemv,
        Operand::StaticWeight,
        b,
        m.d_ff,
        m.d_model,
        nl,
    ));
    ops.push(
        Op::non_gemm(OpKind::RmsNorm, (b * m.d_model * 5) as u64, 1).with_scalar(b as u64),
    );
    ops.push(Op::matmul(
        OpKind::LmHead,
        OpClass::Gemv,
        Operand::StaticWeight,
        b,
        m.d_model,
        m.vocab,
        1,
    ));

    OpGraph { phase: Phase::Decode, batch, seq: l_ctx, ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_flops_match_first_principles() {
        let m = LlmConfig::llama2_7b();
        let g = build_prefill_graph(&m, 512, 1);
        // ~2 * n_params * L (weight matmuls dominate at modest L)
        let expect = 2.0 * m.n_params() as f64 * 512.0;
        let got = g.total_flops() as f64;
        assert!(got > 0.8 * expect && got < 1.4 * expect, "got {got:e} expect {expect:e}");
    }

    #[test]
    fn decode_flops_match_first_principles() {
        let m = LlmConfig::llama2_7b();
        let g = build_decode_graph(&m, 2048, 1);
        let expect = 2.0 * m.n_params() as f64;
        let got = g.total_flops() as f64;
        assert!(got > 0.8 * expect && got < 1.4 * expect, "got {got:e} expect {expect:e}");
    }

    #[test]
    fn prefill_is_gemm_decode_is_gemv() {
        let m = LlmConfig::llama2_7b();
        let p = build_prefill_graph(&m, 512, 1);
        let d = build_decode_graph(&m, 512, 1);
        assert!(p.matmul_ops().all(|o| o.class != OpClass::Gemv));
        assert!(d
            .matmul_ops()
            .filter(|o| o.operand == Operand::StaticWeight)
            .all(|o| o.class == OpClass::Gemv));
    }

    #[test]
    fn attention_is_dynamic_operand() {
        let m = LlmConfig::qwen3_8b();
        for g in [build_prefill_graph(&m, 256, 1), build_decode_graph(&m, 256, 1)] {
            for o in g.matmul_ops() {
                let is_attn = matches!(o.kind, OpKind::AttnScore | OpKind::AttnValue);
                assert_eq!(is_attn, (o.operand == Operand::Dynamic), "{:?}", o.kind);
            }
        }
    }

    #[test]
    fn decode_attention_scales_with_context() {
        let m = LlmConfig::llama2_7b();
        let short = build_decode_graph(&m, 128, 1);
        let long = build_decode_graph(&m, 4096, 1);
        let attn = |g: &OpGraph| -> u64 {
            g.ops.iter().filter(|o| o.kind == OpKind::AttnScore).map(|o| o.macs()).sum()
        };
        assert_eq!(attn(&long), 32 * attn(&short));
    }

    #[test]
    fn batch_scales_weight_gemv_m_not_count() {
        let m = LlmConfig::llama2_7b();
        let b1 = build_decode_graph(&m, 512, 1);
        let b8 = build_decode_graph(&m, 512, 8);
        let ffn1 = b1.ops.iter().find(|o| o.kind == OpKind::FfnUp).unwrap();
        let ffn8 = b8.ops.iter().find(|o| o.kind == OpKind::FfnUp).unwrap();
        assert_eq!(ffn1.m, 1);
        assert_eq!(ffn8.m, 8);
        assert_eq!(ffn1.count, ffn8.count);
        // attention replicates per sequence instead (separate KV caches)
        let at1 = b1.ops.iter().find(|o| o.kind == OpKind::AttnScore).unwrap();
        let at8 = b8.ops.iter().find(|o| o.kind == OpKind::AttnScore).unwrap();
        assert_eq!(at8.count, 8 * at1.count);
    }

    #[test]
    fn static_weight_bytes_close_to_model_size() {
        let m = LlmConfig::llama2_7b();
        let g = build_decode_graph(&m, 128, 1);
        let wb = g.static_weight_bytes(m.dtype_bytes) as f64;
        // everything except the input embedding table is streamed
        let expect = m.weight_bytes() as f64 - (m.vocab * m.d_model) as f64;
        assert!((wb / expect - 1.0).abs() < 0.02, "wb {wb:e} expect {expect:e}");
    }

    #[test]
    fn gqa_shrinks_kv_ops() {
        let q = LlmConfig::qwen3_8b();
        let g = build_decode_graph(&q, 1024, 1);
        let qkv = g.ops.iter().find(|o| o.kind == OpKind::QkvProj).unwrap();
        assert_eq!(qkv.n, q.q_dim() + 2 * q.kv_dim());
        assert!(qkv.n < 3 * q.q_dim());
    }

    #[test]
    fn nonzero_nongemm_everywhere() {
        let m = LlmConfig::llama2_7b();
        for g in [build_prefill_graph(&m, 64, 2), build_decode_graph(&m, 64, 2)] {
            assert!(g.non_gemm_ops().count() >= 6);
            assert!(g.non_gemm_ops().all(|o| o.flops() > 0 || o.stream_bytes > 0));
        }
    }
}

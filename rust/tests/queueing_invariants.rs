//! Property tests on the queueing simulator's invariants — regression
//! guards for the Device extraction and the scheduler work on top of it:
//! `replay_trace` must conserve requests across seeds/rates/mappings,
//! every TTFT must cover that request's prefill latency, e2e must
//! dominate TTFT, per-device busy time must never exceed the fleet
//! makespan under any routing policy or scheduler, and the memoized
//! `CostModel` must agree with direct graph simulation.

use halo::cluster::{Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::{build_decode_graph, LlmConfig};
use halo::sim::device::{AdmissionPolicy, CostModel, SchedConfig};
use halo::sim::queueing::{poisson_trace, replay_trace, replay_trace_with};
use halo::sim::{simulate_graph, EngineSet};
use halo::util::prop::{forall, OneOf, Pair, Triple, UsizeIn};

fn hw() -> HwConfig {
    HwConfig::paper()
}

const MAPPINGS: [MappingKind; 3] =
    [MappingKind::Halo1, MappingKind::Cent, MappingKind::AttAcc1];

const RATES: [u64; 4] = [1, 5, 25, 500];

#[test]
fn replay_conserves_requests_across_seeds_and_rates() {
    let llm = LlmConfig::llama2_7b();
    forall(
        101,
        12,
        Triple(UsizeIn(1, 1000), OneOf(&RATES), UsizeIn(1, 6)),
        |(seed, rate, slots)| {
            let tr = poisson_trace(*seed as u64, 30, *rate as f64, (64, 1024), 24);
            let r = replay_trace(&llm, &hw(), MappingKind::Halo1, *slots, &tr);
            if r.served.len() != tr.len() {
                return false;
            }
            // every arrival appears exactly once in the served set
            let mut got: Vec<f64> = r.served.iter().map(|s| s.arrival).collect();
            let mut want: Vec<f64> = tr.iter().map(|q| q.arrival).collect();
            got.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            got == want
        },
    );
}

#[test]
fn ttft_covers_prefill_and_e2e_covers_ttft() {
    let llm = LlmConfig::llama2_7b();
    forall(
        102,
        8,
        Triple(UsizeIn(1, 1000), OneOf(&RATES), OneOf(&MAPPINGS)),
        |(seed, rate, mapping)| {
            let tr = poisson_trace(*seed as u64 + 7, 25, *rate as f64, (64, 2048), 16);
            let r = replay_trace(&llm, &hw(), *mapping, 4, &tr);
            let mut cost = CostModel::new(&llm, &hw(), *mapping);
            r.served.iter().all(|s| {
                // arrivals are unique, so they key the original request
                let req =
                    tr.iter().find(|q| q.arrival == s.arrival).expect("served unknown arrival");
                let p = cost.prefill(req.l_in).latency;
                s.ttft >= p - 1e-12 && s.e2e >= s.ttft - 1e-12
            })
        },
    );
}

#[test]
fn decode_steps_cover_longest_output() {
    let llm = LlmConfig::llama2_7b();
    forall(103, 10, Triple(UsizeIn(1, 500), OneOf(&RATES), UsizeIn(2, 64)), |(seed, rate, l_out)| {
        let tr = poisson_trace(*seed as u64, 20, *rate as f64, (64, 512), *l_out);
        let r = replay_trace(&llm, &hw(), MappingKind::Halo1, 4, &tr);
        // each decode step emits at most one token per sequence, and the
        // first token comes from prefill
        r.decode_steps >= (*l_out as u64 - 1).max(1)
            && r.makespan >= tr.last().unwrap().arrival
    });
}

// ------------------------------------------------------------- CostModel

#[test]
fn decode_interpolation_matches_direct_simulation_at_unsampled_points() {
    // the cost model samples (512, 1024) per batch size and interpolates;
    // decode cost is affine in context, so the interpolated value must
    // match a direct graph simulation at points it never sampled
    let llm = LlmConfig::llama2_7b();
    for mapping in MAPPINGS {
        let mut cm = CostModel::new(&llm, &hw(), mapping);
        let engines = EngineSet::new(&hw(), mapping);
        for (batch, ctx) in [(1usize, 777usize), (3, 768), (5, 600), (2, 900)] {
            let graph = build_decode_graph(&llm, ctx, batch);
            let direct = simulate_graph(&graph, &engines, mapping).latency;
            let interp = cm.decode_step(batch, ctx).latency;
            assert!(
                (interp - direct).abs() < 1e-6 * direct,
                "{} batch {batch} ctx {ctx}: interp {interp} vs direct {direct}",
                mapping.name()
            );
        }
    }
}

#[test]
fn prefill_memoization_is_stable_across_repeat_calls() {
    let llm = LlmConfig::llama2_7b();
    let mut cm = CostModel::new(&llm, &hw(), MappingKind::Halo1);
    for l_in in [64usize, 777, 2048, 8192] {
        let first = cm.prefill(l_in);
        assert!(first.latency > 0.0 && first.energy.dynamic() > 0.0);
        // bitwise-identical on every repeat call (memoized, no recompute
        // drift)
        for _ in 0..3 {
            assert_eq!(cm.prefill(l_in), first, "prefill({l_in}) drifted");
        }
    }
    let d = cm.decode_step(4, 640);
    assert_eq!(cm.decode_step(4, 640), d);
    let c = cm.prefill_chunk(1024, 256);
    assert_eq!(cm.prefill_chunk(1024, 256), c);
}

#[test]
fn default_sched_replay_is_bit_identical_to_legacy_entry_point() {
    let llm = LlmConfig::llama2_7b();
    let tr = poisson_trace(77, 40, 8.0, (64, 2048), 32);
    let legacy = replay_trace(&llm, &hw(), MappingKind::Halo1, 4, &tr);
    let explicit =
        replay_trace_with(&llm, &hw(), MappingKind::Halo1, 4, SchedConfig::default(), &tr);
    assert_eq!(legacy.makespan, explicit.makespan);
    assert_eq!(legacy.decode_steps, explicit.decode_steps);
    assert_eq!(explicit.evictions, 0);
    for (a, b) in legacy.served.iter().zip(&explicit.served) {
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.ttft, b.ttft);
        assert_eq!(a.e2e, b.e2e);
    }
}

// ------------------------------------------- fleet accounting invariants

const CHUNKS: [usize; 3] = [0, 256, 1024];

#[test]
fn per_device_busy_never_exceeds_makespan_under_any_policy() {
    let llm = LlmConfig::llama2_7b();
    let hw = hw();
    forall(
        104,
        10,
        Triple(UsizeIn(1, 1000), OneOf(&Policy::ALL), OneOf(&CHUNKS)),
        |(seed, policy, chunk)| {
            let trace = Mix::Interactive.trace(*seed as u64, 24, 12.0);
            let sched = SchedConfig {
                chunk: (*chunk > 0).then_some(*chunk),
                ..SchedConfig::default()
            };
            let (mut fleet, mut router) =
                policy.build_with(&llm, &hw, 4, 4, 0.5, Interconnect::board(), sched);
            let r = fleet.replay(&trace, router.as_mut());
            r.served.len() == trace.len()
                && r.per_device.iter().all(|d| {
                    d.busy <= r.makespan + 1e-9
                        && d.busy <= d.last_active + 1e-9
                        && d.last_active <= r.makespan + 1e-9
                })
        },
    );
}

#[test]
fn busy_bounded_under_admission_policies_and_kv_pressure() {
    let llm = LlmConfig::llama2_7b();
    let hw = hw();
    const ADMISSIONS: [AdmissionPolicy; 3] =
        [AdmissionPolicy::Fifo, AdmissionPolicy::ShortestFirst, AdmissionPolicy::Interactive];
    let cap = 4_000_000_000u64; // 4 GB: tight for the interactive mix
    forall(105, 6, Pair(UsizeIn(1, 1000), OneOf(&ADMISSIONS)), |(seed, admission)| {
        let trace = Mix::Interactive.trace(*seed as u64 + 13, 20, 15.0);
        let sched = SchedConfig {
            chunk: Some(512),
            admission: *admission,
            kv_capacity: Some(cap),
        };
        let (mut fleet, mut router) =
            Policy::KvAware.build_with(&llm, &hw, 4, 4, 0.5, Interconnect::board(), sched);
        let r = fleet.replay(&trace, router.as_mut());
        r.served.len() == trace.len()
            && r.per_device.iter().all(|d| {
                d.busy <= r.makespan + 1e-9 && d.kv_peak <= cap
            })
    });
}

//! END-TO-END DRIVER (DESIGN.md E12): serve a real (tiny) LLaMA-style
//! model through the full three-layer stack and report measured
//! latency/throughput next to the analytical HALO projections.
//!
//! The request path is pure Rust + PJRT: prompts are prefillled through
//! the executable whose GEMMs were lowered from the analog-CiM Pallas
//! kernel, then decoded in a slot-based continuous batch through the
//! exact-int8 CiD kernel path — the functional twin of the paper's
//! phase-aware mapping. Python ran once, at `make artifacts`.
//!
//!     make artifacts && cargo run --release --example serve_functional

use std::path::Path;
use std::time::Instant;

use halo::config::HwConfig;
use halo::coordinator::{InferenceEngine, Request, Server};
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::sim::{simulate_e2e, Scenario};
use halo::util::{fmt_seconds, mean, percentile, Rng};

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }

    const SLOTS: usize = 4;
    const N_REQUESTS: usize = 12;
    const MAX_NEW: usize = 16;

    println!("== HALO functional serving (three-layer stack, no python) ==\n");
    let t0 = Instant::now();
    let engine = InferenceEngine::load(artifacts, SLOTS)?;
    println!(
        "engine up in {}: platform={}, {} slots, prefill ladder up to {} tokens",
        fmt_seconds(t0.elapsed().as_secs_f64()),
        engine.rt.platform(),
        engine.slots(),
        engine.max_prompt()
    );
    let vocab = engine.vocab;
    let mut server = Server::new(engine);

    // synthetic workload: mixed prompt lengths, fixed generation budget
    let mut rng = Rng::new(7);
    for id in 0..N_REQUESTS {
        let plen = rng.range(4, 60) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
        server.submit(Request::new(id as u64, prompt, MAX_NEW));
    }

    let (mut responses, stats) = server.run_to_completion()?;
    responses.sort_by_key(|r| r.id);

    let ttfts: Vec<f64> = responses.iter().map(|r| r.ttft.as_secs_f64()).collect();
    let tpots: Vec<f64> = responses.iter().map(|r| r.tpot.as_secs_f64()).collect();
    println!("\nper-request measurements (functional plane, CPU PJRT):");
    for r in &responses {
        println!(
            "  req {:>2}: {:>2} tokens  ttft {:>10}  tpot {:>10}  first tokens {:?}",
            r.id,
            r.tokens.len(),
            fmt_seconds(r.ttft.as_secs_f64()),
            fmt_seconds(r.tpot.as_secs_f64()),
            &r.tokens[..r.tokens.len().min(4)]
        );
    }
    println!("\naggregate:");
    println!(
        "  {} requests, {} tokens, wall {} -> {:.1} tok/s",
        stats.requests,
        stats.generated_tokens,
        fmt_seconds(stats.wall.as_secs_f64()),
        stats.tokens_per_second()
    );
    println!(
        "  TTFT mean {} p95 {}   TPOT mean {} p95 {}",
        fmt_seconds(mean(&ttfts)),
        fmt_seconds(percentile(&ttfts, 95.0)),
        fmt_seconds(mean(&tpots)),
        fmt_seconds(percentile(&tpots, 95.0)),
    );
    println!(
        "  coordinator overhead: {:.1}% of wall (the rest is PJRT execute)",
        (1.0 - stats.execute_fraction()) * 100.0
    );

    // analytical projection for the same tiny model on the HALO hardware
    let hw = HwConfig::paper();
    let tiny = LlmConfig::tiny();
    let sc = Scenario { l_in: 32, l_out: MAX_NEW, batch: SLOTS };
    println!("\nanalytical plane: the same workload on HALO silicon (projected):");
    for mk in [MappingKind::Halo1, MappingKind::Cent, MappingKind::AttAcc1] {
        let r = simulate_e2e(&tiny, &hw, mk, &sc);
        println!(
            "  {:<8} TTFT {:>10}  TPOT {:>10}  e2e {:>10}",
            mk.name(),
            fmt_seconds(r.ttft()),
            fmt_seconds(r.tpot()),
            fmt_seconds(r.e2e_latency())
        );
    }
    println!(
        "\n(the functional numbers validate the dataflow; the analytical numbers\n\
         are the paper's silicon projection — see EXPERIMENTS.md §E12)"
    );
    Ok(())
}

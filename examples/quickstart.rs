//! Quickstart: simulate HALO's phase-aware mapping on LLaMA-2 7B and
//! compare it against the paper's baselines on one scenario.
//!
//!     cargo run --release --example quickstart

use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::sim::{simulate_e2e, Scenario};
use halo::util::{fmt_joules, fmt_seconds};

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();
    let sc = Scenario { l_in: 2048, l_out: 512, batch: 1 };

    println!(
        "HALO quickstart — {} ({:.2}B params), L_in={}, L_out={}, batch={}\n",
        llm.name,
        llm.n_params() as f64 / 1e9,
        sc.l_in,
        sc.l_out,
        sc.batch
    );
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>12}",
        "mapping", "TTFT", "TPOT", "e2e time", "e2e energy"
    );
    let mut rows: Vec<(MappingKind, f64)> = Vec::new();
    for mk in [
        MappingKind::Halo1,
        MappingKind::Halo2,
        MappingKind::Cent,
        MappingKind::AttAcc1,
        MappingKind::AttAcc2,
        MappingKind::HaloSa,
    ] {
        let r = simulate_e2e(&llm, &hw, mk, &sc);
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12}",
            mk.name(),
            fmt_seconds(r.ttft()),
            fmt_seconds(r.tpot()),
            fmt_seconds(r.e2e_latency()),
            fmt_joules(r.e2e_energy())
        );
        rows.push((mk, r.e2e_latency()));
    }
    let halo = rows[0].1;
    println!("\nspeedups of HALO1 at this scenario:");
    for (mk, t) in &rows[2..] {
        println!("  vs {:<8} {:.2}x", mk.name(), t / halo);
    }
}

//! `cargo bench` target: one end-to-end benchmark per paper table/figure.
//!
//! Each benchmark runs the complete generation pipeline for that figure
//! (workload construction -> mapping -> analytical simulation -> table),
//! so the numbers double as a performance budget for the simulator itself
//! (EXPERIMENTS.md §Perf targets the full Fig. 7 grid in well under a
//! second).

use halo::config::HwConfig;
use halo::report;
use halo::util::bench::{bb, BenchSuite};

fn main() {
    let hw = HwConfig::paper();
    let mut s = BenchSuite::new("paper_figures");

    s.bench("fig1_roofline", || {
        bb(report::fig1_roofline(&hw));
    });
    s.bench("fig4_breakdown", || {
        bb(report::fig4_breakdown(&hw));
    });
    s.bench("fig5_6_cid_vs_cim_sweep", || {
        bb(report::fig56_cid_vs_cim(&hw));
    });
    s.bench("fig7_e2e_time_grid", || {
        bb(report::fig78_e2e(&hw, false));
    });
    s.bench("fig8_e2e_energy_grid", || {
        bb(report::fig78_e2e(&hw, true));
    });
    s.bench("fig9_batch_sweep", || {
        bb(report::fig9_batch_sweep(&hw));
    });
    s.bench("fig10_cim_vs_sa", || {
        bb(report::fig10_cim_vs_sa(&hw));
    });
    s.bench("headline_summary_all_claims", || {
        bb(report::headline_summary(&hw));
    });
    s.bench("all_figures_full_reproduction", || {
        bb(report::all_figures(&hw));
    });
    s.finish();
}

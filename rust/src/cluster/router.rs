//! Pluggable request routing across a fleet.
//!
//! A router decides, at arrival time, which device runs a request's
//! prefill and which runs its decode. Unified policies (round-robin,
//! least-loaded) keep both phases on one device; the phase-disaggregated
//! policy splits them across the prefill and decode pools, incurring a
//! KV-cache transfer over the fleet interconnect.

use super::fleet::Fleet;
use super::interconnect::Interconnect;
use crate::config::HwConfig;
use crate::model::LlmConfig;
use crate::sim::queueing::TraceRequest;

/// A routing decision: prefill device and decode device (equal indices
/// mean the whole request stays on one device — no KV transfer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub prefill: usize,
    pub decode: usize,
}

/// Request-routing policy over a fleet.
pub trait Router {
    fn name(&self) -> &'static str;
    /// Route one arriving request given the current fleet state.
    fn route(&mut self, fleet: &Fleet, req: &TraceRequest) -> Route;
}

/// Blind round-robin over the prefill pool; decode stays local.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "roundrobin"
    }
    fn route(&mut self, fleet: &Fleet, _req: &TraceRequest) -> Route {
        let pool = &fleet.prefill_pool;
        let dev = pool[self.next % pool.len()];
        self.next = self.next.wrapping_add(1);
        Route { prefill: dev, decode: dev }
    }
}

/// Join-the-shortest-queue over the prefill pool (queue + active slots);
/// decode stays local.
#[derive(Debug, Default)]
pub struct LeastLoaded;

fn argmin_load(fleet: &Fleet, pool: &[usize]) -> usize {
    *pool
        .iter()
        .min_by_key(|&&d| fleet.devices[d].load())
        .expect("empty pool")
}

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "leastloaded"
    }
    fn route(&mut self, fleet: &Fleet, _req: &TraceRequest) -> Route {
        let dev = argmin_load(fleet, &fleet.prefill_pool);
        Route { prefill: dev, decode: dev }
    }
}

/// Cluster-level analogue of HALO's phase-aware mapping: prefill on the
/// least-loaded device of the (Fully-CiM) prefill pool, decode on the
/// least-loaded device of the (Fully-CiD) decode pool.
#[derive(Debug, Default)]
pub struct PhaseDisaggregated;

impl Router for PhaseDisaggregated {
    fn name(&self) -> &'static str {
        "disaggregated"
    }
    fn route(&mut self, fleet: &Fleet, _req: &TraceRequest) -> Route {
        // decode placement must count assignments still in prefill or KV
        // transfer, or bursts herd onto one decode device
        let decode = *fleet
            .decode_pool
            .iter()
            .min_by_key(|&&d| fleet.decode_load(d))
            .expect("empty decode pool");
        Route { prefill: argmin_load(fleet, &fleet.prefill_pool), decode }
    }
}

/// Named (fleet topology, router) policies exposed on the CLI and in the
/// report tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Monolithic HALO1 devices, blind round-robin routing.
    RoundRobin,
    /// Monolithic HALO1 devices, least-loaded routing (the strongest
    /// non-disaggregated baseline).
    LeastLoaded,
    /// Fully-CiM prefill pool feeding a Fully-CiD decode pool.
    PhaseDisaggregated,
}

impl Policy {
    pub fn all() -> [Policy; 3] {
        [Policy::RoundRobin, Policy::LeastLoaded, Policy::PhaseDisaggregated]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "roundrobin",
            Policy::LeastLoaded => "leastloaded",
            Policy::PhaseDisaggregated => "disaggregated",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        let norm: String =
            s.to_ascii_lowercase().chars().filter(|c| *c != '-' && *c != '_').collect();
        match norm.as_str() {
            "roundrobin" | "rr" => Some(Policy::RoundRobin),
            // `monolithic` = every device runs the HALO1 phase-aware
            // mapping end-to-end; least-loaded is its routing
            "leastloaded" | "ll" | "monolithic" | "mono" => Some(Policy::LeastLoaded),
            "disaggregated" | "disagg" | "phasedisaggregated" | "pd" => {
                Some(Policy::PhaseDisaggregated)
            }
            _ => None,
        }
    }

    /// Construct the (fleet, router) pair this policy describes.
    /// `prefill_frac` only applies to the disaggregated topology.
    pub fn build(
        &self,
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        prefill_frac: f64,
        link: Interconnect,
    ) -> (Fleet, Box<dyn Router>) {
        match self {
            Policy::RoundRobin => {
                (Fleet::unified(llm, hw, devices, slots, link), Box::new(RoundRobin::default()))
            }
            Policy::LeastLoaded => {
                (Fleet::unified(llm, hw, devices, slots, link), Box::new(LeastLoaded))
            }
            Policy::PhaseDisaggregated => (
                Fleet::disaggregated(llm, hw, devices, slots, prefill_frac, link),
                Box::new(PhaseDisaggregated),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> Fleet {
        Fleet::unified(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            n,
            4,
            Interconnect::board(),
        )
    }

    fn req() -> TraceRequest {
        TraceRequest { arrival: 0.0, l_in: 128, l_out: 16 }
    }

    #[test]
    fn round_robin_cycles() {
        let f = fleet(3);
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..6).map(|_| rr.route(&f, &req()).prefill).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_avoids_busy_device() {
        let mut f = fleet(2);
        f.devices[0].push(crate::sim::device::DeviceJob::full(&req()));
        let mut ll = LeastLoaded;
        let r = ll.route(&f, &req());
        assert_eq!(r.prefill, 1);
        assert_eq!(r.decode, 1);
    }

    #[test]
    fn disaggregated_splits_pools() {
        let f = Fleet::disaggregated(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            4,
            4,
            0.5,
            Interconnect::board(),
        );
        let mut pd = PhaseDisaggregated;
        let r = pd.route(&f, &req());
        assert!(f.prefill_pool.contains(&r.prefill));
        assert!(f.decode_pool.contains(&r.decode));
        assert_ne!(r.prefill, r.decode);
    }

    #[test]
    fn policy_by_name() {
        assert_eq!(Policy::by_name("disaggregated"), Some(Policy::PhaseDisaggregated));
        assert_eq!(Policy::by_name("monolithic"), Some(Policy::LeastLoaded));
        assert_eq!(Policy::by_name("round-robin"), Some(Policy::RoundRobin));
        assert!(Policy::by_name("random").is_none());
        for p in Policy::all() {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
    }
}

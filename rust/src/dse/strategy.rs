//! Pluggable search strategies over a [`SearchSpace`].
//!
//! A strategy only decides *which* points to visit; the engine owns
//! evaluation, memoization, and scoring. The `eval` callback returns a
//! scalar guidance score (lower is better — the first objective, or the
//! SLO-penalized cost in auto-tune mode) and `f64::INFINITY` for invalid
//! points, so strategies need no validity logic of their own. All
//! strategies are deterministic given their seed.
//!
//! Strategies speak two equivalent protocols. [`Strategy::search`] is
//! the sequential one: one point per `eval` call. [`Strategy::search_batched`]
//! hands the engine whole batches of mutually independent points and
//! receives all their scores at once, so the engine may replay a batch
//! concurrently — the grid yields fixed-size index chunks, random
//! search yields its entire seeded sample set, and hill-climb yields
//! each step's neighbor ring. Both protocols visit the same points in
//! the same order (pinned by test), so everything downstream of the
//! engine is bit-identical whichever one drives it.

use super::space::{Index, SearchSpace, AXES};
use crate::util::Rng;

/// Flat-index chunk size of the batched grid. A constant (never the
/// worker-thread count), so the visit order — and with it every
/// downstream result — is independent of parallelism.
pub const GRID_BATCH: usize = 64;

/// A search strategy: drive `eval` over points of `space`.
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64);

    /// Batched protocol: call `run_batch` with successive batches of
    /// points whose evaluations are mutually independent; it returns
    /// one guidance score per point, in batch order. Must visit the
    /// same points in the same order as [`search`](Self::search). The
    /// default adapter degenerates to single-point batches, so any
    /// strategy that only implements `search` still works under the
    /// parallel engine (it just exposes no parallelism).
    fn search_batched(
        &mut self,
        space: &SearchSpace,
        run_batch: &mut dyn FnMut(&[Index]) -> Vec<f64>,
    ) {
        self.search(space, &mut |idx| run_batch(std::slice::from_ref(idx))[0]);
    }
}

/// Exhaustive grid enumeration (the degenerate §V-B "search" and every
/// small space). Visits points in flat mixed-radix order.
#[derive(Debug, Default)]
pub struct Exhaustive;

impl Strategy for Exhaustive {
    fn name(&self) -> &'static str {
        "grid"
    }
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
        for i in 0..space.len() {
            eval(&space.flat(i));
        }
    }
    fn search_batched(
        &mut self,
        space: &SearchSpace,
        run_batch: &mut dyn FnMut(&[Index]) -> Vec<f64>,
    ) {
        // grid points are all independent; chunk the flat order so one
        // slow batch never serializes the whole sweep
        let mut start = 0;
        while start < space.len() {
            let end = (start + GRID_BATCH).min(space.len());
            let batch: Vec<Index> = (start..end).map(|i| space.flat(i)).collect();
            run_batch(&batch);
            start = end;
        }
    }
}

/// Seeded uniform random sampling (with replacement; the engine's memo
/// makes repeats free). The workhorse for big spaces.
#[derive(Debug)]
pub struct RandomSearch {
    pub samples: usize,
    pub seed: u64,
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
        let mut rng = Rng::new(self.seed);
        for _ in 0..self.samples {
            eval(&space.sample(&mut rng));
        }
    }
    fn search_batched(
        &mut self,
        space: &SearchSpace,
        run_batch: &mut dyn FnMut(&[Index]) -> Vec<f64>,
    ) {
        // no sample depends on another's score: the whole seeded sample
        // set is one batch
        let mut rng = Rng::new(self.seed);
        let batch: Vec<Index> = (0..self.samples).map(|_| space.sample(&mut rng)).collect();
        if !batch.is_empty() {
            run_batch(&batch);
        }
    }
}

/// Seeded steepest-ascent hill climbing with random restarts: from a
/// random point, evaluate every one-step axis neighbor and move to the
/// best strictly-improving one until a local optimum (or the step budget)
/// is reached. Restarts cover the space's basins; the engine's memo makes
/// revisits free, so the frontier still sees every point touched.
#[derive(Debug)]
pub struct HillClimb {
    pub restarts: usize,
    pub steps: usize,
    pub seed: u64,
}

impl Strategy for HillClimb {
    fn name(&self) -> &'static str {
        "hillclimb"
    }
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
        let mut rng = Rng::new(self.seed);
        for _ in 0..self.restarts.max(1) {
            let mut cur = space.sample(&mut rng);
            let mut cur_score = eval(&cur);
            for _ in 0..self.steps {
                let mut best: Option<(Index, f64)> = None;
                for axis in 0..AXES {
                    for dir in [-1i64, 1] {
                        let Some(next) = space.step(&cur, axis, dir) else { continue };
                        let s = eval(&next);
                        if s < cur_score && best.is_none_or(|(_, bs)| s < bs) {
                            best = Some((next, s));
                        }
                    }
                }
                match best {
                    Some((next, s)) => {
                        cur = next;
                        cur_score = s;
                    }
                    None => break,
                }
            }
        }
    }
    fn search_batched(
        &mut self,
        space: &SearchSpace,
        run_batch: &mut dyn FnMut(&[Index]) -> Vec<f64>,
    ) {
        let mut rng = Rng::new(self.seed);
        for _ in 0..self.restarts.max(1) {
            let mut cur = space.sample(&mut rng);
            let mut cur_score = run_batch(std::slice::from_ref(&cur))[0];
            for _ in 0..self.steps {
                // each step's neighbor ring is one batch, in the same
                // axis-major order the sequential walk visits it
                let ring: Vec<Index> = (0..AXES)
                    .flat_map(|axis| {
                        [-1i64, 1].into_iter().filter_map(move |dir| space.step(&cur, axis, dir))
                    })
                    .collect();
                if ring.is_empty() {
                    break;
                }
                let scores = run_batch(&ring);
                let mut best: Option<(Index, f64)> = None;
                for (next, &s) in ring.iter().zip(scores.iter()) {
                    if s < cur_score && best.is_none_or(|(_, bs)| s < bs) {
                        best = Some((*next, s));
                    }
                }
                match best {
                    Some((next, s)) => {
                        cur = next;
                        cur_score = s;
                    }
                    None => break,
                }
            }
        }
    }
}

/// Resolve a strategy by CLI name. `samples` feeds random search;
/// `restarts`/`steps` feed hill climbing.
pub fn by_name(
    name: &str,
    seed: u64,
    samples: usize,
    restarts: usize,
    steps: usize,
) -> Option<Box<dyn Strategy>> {
    match name.to_ascii_lowercase().as_str() {
        "grid" | "exhaustive" => Some(Box::new(Exhaustive)),
        "random" | "rand" => Some(Box::new(RandomSearch { samples, seed })),
        "hillclimb" | "climb" | "hc" => Some(Box::new(HillClimb { restarts, steps, seed })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn visited(strategy: &mut dyn Strategy, space: &SearchSpace) -> Vec<Index> {
        let mut order = Vec::new();
        let mut eval = |idx: &Index| {
            order.push(*idx);
            // synthetic deterministic score: distance from the origin
            idx.iter().map(|&x| x as f64).sum::<f64>()
        };
        strategy.search(space, &mut eval);
        order
    }

    #[test]
    fn grid_visits_every_point_once() {
        let space = SearchSpace::smoke();
        let order = visited(&mut Exhaustive, &space);
        assert_eq!(order.len(), space.len());
        let unique: BTreeSet<Index> = order.iter().copied().collect();
        assert_eq!(unique.len(), space.len());
    }

    #[test]
    fn random_is_seeded_and_in_bounds() {
        let space = SearchSpace::fleet();
        let a = visited(&mut RandomSearch { samples: 25, seed: 9 }, &space);
        let b = visited(&mut RandomSearch { samples: 25, seed: 9 }, &space);
        assert_eq!(a, b, "same seed, same visit order");
        let c = visited(&mut RandomSearch { samples: 25, seed: 10 }, &space);
        assert_ne!(a, c, "different seed, different walk");
        let dims = space.dims();
        assert!(a.iter().all(|idx| idx.iter().zip(dims.iter()).all(|(&x, &d)| x < d)));
    }

    #[test]
    fn hillclimb_descends_the_synthetic_bowl() {
        // with score = sum of coordinates, the climb must end at the
        // origin from any restart
        let space = SearchSpace::fleet();
        let mut best_seen = f64::INFINITY;
        let mut eval = |idx: &Index| {
            let s = idx.iter().map(|&x| x as f64).sum::<f64>();
            if s < best_seen {
                best_seen = s;
            }
            s
        };
        HillClimb { restarts: 2, steps: 50, seed: 5 }.search(&space, &mut eval);
        assert_eq!(best_seen, 0.0, "steepest descent reaches the origin");
    }

    fn visited_batched(strategy: &mut dyn Strategy, space: &SearchSpace) -> Vec<Index> {
        let mut order = Vec::new();
        let mut run = |batch: &[Index]| -> Vec<f64> {
            order.extend_from_slice(batch);
            batch.iter().map(|idx| idx.iter().map(|&x| x as f64).sum::<f64>()).collect()
        };
        strategy.search_batched(space, &mut run);
        order
    }

    #[test]
    fn batched_visit_order_matches_sequential_for_every_strategy() {
        // the engine's memo/evaluated order (and therefore every
        // downstream snapshot) rides on this equivalence
        let space = SearchSpace::fleet();
        assert_eq!(
            visited(&mut Exhaustive, &space),
            visited_batched(&mut Exhaustive, &space),
            "grid"
        );
        assert_eq!(
            visited(&mut RandomSearch { samples: 25, seed: 9 }, &space),
            visited_batched(&mut RandomSearch { samples: 25, seed: 9 }, &space),
            "random"
        );
        assert_eq!(
            visited(&mut HillClimb { restarts: 3, steps: 12, seed: 5 }, &space),
            visited_batched(&mut HillClimb { restarts: 3, steps: 12, seed: 5 }, &space),
            "hillclimb"
        );
    }

    #[test]
    fn grid_batches_are_chunked_and_cover_the_space() {
        let space = SearchSpace::preset("power").expect("power preset");
        assert!(space.len() > GRID_BATCH, "need a space bigger than one chunk");
        let mut batches = 0usize;
        let mut total = 0usize;
        let mut run = |batch: &[Index]| -> Vec<f64> {
            assert!(!batch.is_empty() && batch.len() <= GRID_BATCH);
            batches += 1;
            total += batch.len();
            vec![0.0; batch.len()]
        };
        Exhaustive.search_batched(&space, &mut run);
        assert_eq!(total, space.len());
        assert_eq!(batches, space.len().div_ceil(GRID_BATCH));
    }

    #[test]
    fn default_batched_adapter_yields_single_point_batches() {
        // a strategy that only implements `search` still drives the
        // batched engine, one point at a time
        struct SeqOnly;
        impl Strategy for SeqOnly {
            fn name(&self) -> &'static str {
                "seq-only"
            }
            fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
                for i in 0..space.len().min(5) {
                    eval(&space.flat(i));
                }
            }
        }
        let space = SearchSpace::smoke();
        let mut sizes = Vec::new();
        let mut run = |batch: &[Index]| -> Vec<f64> {
            sizes.push(batch.len());
            vec![0.0; batch.len()]
        };
        SeqOnly.search_batched(&space, &mut run);
        assert_eq!(sizes, vec![1; space.len().min(5)]);
    }

    #[test]
    fn by_name_resolves() {
        for (name, want) in [("grid", "grid"), ("random", "random"), ("hc", "hillclimb")] {
            assert_eq!(by_name(name, 1, 10, 2, 20).unwrap().name(), want);
        }
        assert!(by_name("annealing", 1, 10, 2, 20).is_none());
    }
}

//! Cluster-plane tables: fleet scaling, router-policy comparisons,
//! chunked-prefill TTFT sweeps, and KV-capacity pressure.
//!
//! Offered load is calibrated against the measured single-device
//! (monolithic HALO1) capacity so the tables stay meaningful if the
//! underlying cost model shifts: scaling/policy runs offer `3x` one
//! device's saturated throughput (overloads a 1-device fleet, leaves an
//! 8-device fleet comfortable); the scheduler tables pick their own
//! multiples of the same calibration.

use super::Table;
use crate::cluster::{AdmissionPolicy, Interconnect, Mix, Policy, SchedConfig};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::sim::queueing::replay_trace_with;

use super::f;

/// Decode slots per device used throughout the cluster tables.
const SLOTS: usize = 8;
const N_REQ: usize = 160;

/// Measured saturated throughput (req/s) of one monolithic HALO1 device
/// with `slots` decode slots on `mix`: replay a burst trace (everything
/// arrives almost at once) and read the served rate.
pub fn single_device_capacity(hw: &HwConfig, llm: &LlmConfig, mix: Mix, slots: usize) -> f64 {
    let burst = mix.trace(11, 96, 1.0e6);
    let (mut fleet, mut router) =
        Policy::LeastLoaded.build(llm, hw, 1, slots, 0.5, Interconnect::board());
    fleet.replay(&burst, router.as_mut()).throughput_rps()
}

/// Throughput and tail latency vs fleet size at fixed offered load
/// (3x single-device capacity, interactive mix, least-loaded routing).
pub fn cluster_scaling(hw: &HwConfig) -> Table {
    let t1 = single_device_capacity(hw, &LlmConfig::llama2_7b(), Mix::Interactive, SLOTS);
    cluster_scaling_at(hw, t1)
}

/// [`cluster_scaling`] with the single-device capacity `t1` already
/// measured (callers generating several tables calibrate once).
pub fn cluster_scaling_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let rate = 3.0 * t1;
    let mut t = Table::new(
        "cluster_scaling",
        &format!(
            "Cluster scaling — throughput and tail latency vs fleet size \
             (LLaMA-2 7B, {} mix, offered {:.2} req/s = 3x single-device capacity)",
            mix.name(),
            rate
        ),
        &[
            "devices",
            "policy",
            "offered_rps",
            "served_rps",
            "ttft_p50_s",
            "ttft_p99_s",
            "e2e_p99_s",
            "utilization",
            "speedup_vs_1",
        ],
    );
    let mut base_rps = 0.0f64;
    for devices in [1usize, 2, 4, 8] {
        let trace = mix.trace(31, N_REQ, rate);
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, hw, devices, SLOTS, 0.5, Interconnect::board());
        let r = fleet.replay(&trace, router.as_mut());
        if devices == 1 {
            base_rps = r.throughput_rps();
        }
        t.row(vec![
            devices.to_string(),
            "leastloaded".into(),
            f(rate),
            f(r.throughput_rps()),
            f(r.ttft_p50()),
            f(r.ttft_p99()),
            f(r.e2e_p99()),
            f(r.utilization()),
            f(r.throughput_rps() / base_rps.max(1e-12)),
        ]);
    }
    t
}

/// Router-policy comparison at a fixed 8-device fleet on the interactive
/// mix: monolithic round-robin and least-loaded vs phase-disaggregated
/// over progressively slower interconnects.
pub fn cluster_policy_comparison(hw: &HwConfig) -> Table {
    let t1 = single_device_capacity(hw, &LlmConfig::llama2_7b(), Mix::Interactive, SLOTS);
    cluster_policy_comparison_at(hw, t1)
}

/// [`cluster_policy_comparison`] with the single-device capacity `t1`
/// already measured.
pub fn cluster_policy_comparison_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let devices = 8usize;
    let rate = 3.0 * t1;
    let trace = mix.trace(37, N_REQ, rate);
    let mut t = Table::new(
        "cluster_policy_comparison",
        &format!(
            "Router policies at {devices} devices — {} mix, offered {rate:.2} req/s",
            mix.name()
        ),
        &[
            "policy",
            "link",
            "served_rps",
            "ttft_p50_s",
            "ttft_p99_s",
            "e2e_p50_s",
            "e2e_p99_s",
            "kv_gb",
            "utilization",
        ],
    );
    let cases: [(Policy, Interconnect); 5] = [
        (Policy::RoundRobin, Interconnect::board()),
        (Policy::LeastLoaded, Interconnect::board()),
        (Policy::PhaseDisaggregated, Interconnect::board()),
        (Policy::PhaseDisaggregated, Interconnect::ethernet()),
        (Policy::PhaseDisaggregated, Interconnect::wan()),
    ];
    for (policy, link) in cases {
        let link_name = link.name;
        let (mut fleet, mut router) = policy.build(&llm, hw, devices, SLOTS, 0.5, link);
        let r = fleet.replay(&trace, router.as_mut());
        t.row(vec![
            policy.name().into(),
            link_name.into(),
            f(r.throughput_rps()),
            f(r.ttft_p50()),
            f(r.ttft_p99()),
            f(r.e2e_p50()),
            f(r.e2e_p99()),
            f(r.kv_bytes as f64 / 1e9),
            f(r.utilization()),
        ]);
    }
    t
}

/// TTFT vs prefill chunk size on one device under the interactive mix,
/// plus admission-policy contrast rows (chunk 0 = serialized prefill).
pub fn chunked_prefill_ttft(hw: &HwConfig) -> Table {
    let t1 = single_device_capacity(hw, &LlmConfig::llama2_7b(), Mix::Interactive, SLOTS);
    chunked_prefill_ttft_at(hw, t1)
}

/// [`chunked_prefill_ttft`] with the single-device capacity `t1` already
/// measured.
///
/// Mild overload (1.25x capacity) keeps every request contended, so the
/// p50 isolates scheduling rather than idle-arrival luck: under
/// serialized FIFO a chat prompt waits for the *whole* prefill of every
/// long prompt admitted ahead of it; chunked prefill streams those long
/// prompts through in chunks and completes the chat prompt's prefill
/// between chunks.
pub fn chunked_prefill_ttft_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let rate = 1.25 * t1;
    let trace = mix.trace(41, N_REQ, rate);
    let mut t = Table::new(
        "cluster_chunked_prefill",
        &format!(
            "Chunked prefill and admission policy — single HALO1 device, {} mix, \
             offered {rate:.2} req/s (chunk 0 = serialized prefill)",
            mix.name()
        ),
        &[
            "chunk",
            "admission",
            "ttft_p50_s",
            "ttft_p99_s",
            "e2e_p50_s",
            "e2e_p99_s",
            "served_rps",
        ],
    );
    let cases: [(usize, AdmissionPolicy); 8] = [
        (0, AdmissionPolicy::Fifo),
        (256, AdmissionPolicy::Fifo),
        (512, AdmissionPolicy::Fifo),
        (1024, AdmissionPolicy::Fifo),
        (2048, AdmissionPolicy::Fifo),
        (0, AdmissionPolicy::ShortestFirst),
        (512, AdmissionPolicy::ShortestFirst),
        (0, AdmissionPolicy::Interactive),
    ];
    for (chunk, admission) in cases {
        let sched = SchedConfig {
            chunk: (chunk > 0).then_some(chunk),
            admission,
            kv_capacity: None,
        };
        let r = replay_trace_with(&llm, hw, MappingKind::Halo1, SLOTS, sched, &trace);
        t.row(vec![
            chunk.to_string(),
            admission.name().into(),
            f(r.ttft_p50()),
            f(r.ttft_p99()),
            f(r.e2e_p50()),
            f(r.e2e_p99()),
            f(r.throughput_rps()),
        ]);
    }
    t
}

/// KV-capacity pressure on the decode pool of a 4-device disaggregated
/// fleet under capacity-aware routing: shrinking per-device budgets force
/// eviction-and-recompute (cap 0 = unlimited).
pub fn kv_capacity_pressure(hw: &HwConfig) -> Table {
    let t1 = single_device_capacity(hw, &LlmConfig::llama2_7b(), Mix::Interactive, SLOTS);
    kv_capacity_pressure_at(hw, t1)
}

/// [`kv_capacity_pressure`] with the single-device capacity `t1` already
/// measured. The smallest budget still exceeds any single request's
/// lifetime KV, so the resident-KV invariant (`kv_peak <= cap`) holds on
/// every row.
pub fn kv_capacity_pressure_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let devices = 4usize;
    let rate = 2.0 * t1;
    let trace = mix.trace(43, N_REQ, rate);
    let mut t = Table::new(
        "cluster_kv_pressure",
        &format!(
            "KV-capacity pressure — {devices}-device disaggregated fleet, kvaware routing, \
             {} mix, offered {rate:.2} req/s (cap 0 = unlimited)",
            mix.name()
        ),
        &[
            "kv_cap_gb",
            "evictions",
            "recompute_tokens",
            "served_rps",
            "ttft_p50_s",
            "e2e_p99_s",
            "kv_peak_gb",
        ],
    );
    for cap_gb in [0.0f64, 16.0, 8.0, 4.0] {
        let (mut fleet, mut router) =
            Policy::KvAware.build(&llm, hw, devices, SLOTS, 0.5, Interconnect::board());
        if cap_gb > 0.0 {
            for d in fleet.decode_pool.clone() {
                fleet.set_kv_capacity(d, Some((cap_gb * 1e9) as u64));
            }
        }
        let r = fleet.replay(&trace, router.as_mut());
        let peak = r.per_device.iter().map(|d| d.kv_peak).max().unwrap_or(0);
        t.row(vec![
            format!("{cap_gb}"),
            r.evictions.to_string(),
            r.recompute_tokens.to_string(),
            f(r.throughput_rps()),
            f(r.ttft_p50()),
            f(r.e2e_p99()),
            f(peak as f64 / 1e9),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_table_shape_and_trends() {
        let t = cluster_scaling(&HwConfig::paper());
        assert_eq!(t.rows.len(), 4);
        let rps = t.col_f64("served_rps");
        // adding devices never hurts served throughput under overload
        assert!(rps[3] > rps[0], "{rps:?}");
        let speedup = t.col_f64("speedup_vs_1");
        assert!((speedup[0] - 1.0).abs() < 1e-9);
        let p99 = t.col_f64("ttft_p99_s");
        assert!(p99[3] < p99[0], "tail must shrink with fleet size: {p99:?}");
    }

    #[test]
    fn policy_table_covers_links_and_counts_kv() {
        let t = cluster_policy_comparison(&HwConfig::paper());
        assert_eq!(t.rows.len(), 5);
        let kv = t.col_f64("kv_gb");
        // monolithic rows move no KV; disaggregated rows all move the same
        assert_eq!(kv[0], 0.0);
        assert_eq!(kv[1], 0.0);
        assert!(kv[2] > 0.0);
        assert!((kv[2] - kv[3]).abs() < 1e-9 && (kv[3] - kv[4]).abs() < 1e-9);
    }

    #[test]
    fn chunked_prefill_improves_interactive_ttft_p50() {
        let t = chunked_prefill_ttft(&HwConfig::paper());
        assert_eq!(t.rows.len(), 8);
        let chunk = t.col_f64("chunk");
        let p50 = t.col_f64("ttft_p50_s");
        // row 0 is the serialized-FIFO baseline; rows 1..=4 are the FIFO
        // chunk sweep
        assert_eq!(chunk[0], 0.0);
        assert!(chunk[1..5].iter().all(|&c| c > 0.0));
        let best_chunked = p50[1..5].iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            best_chunked < p50[0],
            "some chunk size must strictly improve TTFT p50 over serialized: \
             best chunked {best_chunked} vs serialized {}",
            p50[0]
        );
    }

    #[test]
    fn kv_pressure_table_respects_budgets() {
        let t = kv_capacity_pressure(&HwConfig::paper());
        assert_eq!(t.rows.len(), 4);
        let caps = t.col_f64("kv_cap_gb");
        let ev = t.col_f64("evictions");
        let peaks = t.col_f64("kv_peak_gb");
        // unlimited budget never evicts, and some KV is actually resident
        assert_eq!(caps[0], 0.0);
        assert_eq!(ev[0], 0.0);
        assert!(peaks[0] > 0.0);
        // capped rows never exceed their budget (the resident-KV invariant;
        // slack covers the %.6e cell formatting)
        for i in 1..t.rows.len() {
            assert!(caps[i] > 0.0);
            assert!(
                peaks[i] <= caps[i] * (1.0 + 1e-5),
                "row {i}: peak {} exceeds cap {}",
                peaks[i],
                caps[i]
            );
        }
    }
}

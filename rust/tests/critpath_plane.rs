//! Critical-path-plane integration pins: every extracted per-request
//! path must fold bit-exactly onto the recorded e2e on a chunked
//! disaggregated replay, the what-if estimator must agree (sign and
//! magnitude) with a real replay at a scaled point, capped recorders
//! must degrade to partial coverage instead of panicking, recorder drop
//! counters must surface in the JSON snapshots, and the OpenMetrics
//! exposition must match its golden file byte for byte.

use halo::cluster::{
    collect_trace, ArrivalKind, Fleet, Interconnect, Mix, Policy, Router, SchedConfig,
    TrafficConfig,
};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::obs::{self, Registry, Resource, SelfProfile};
use halo::sim::queueing::TraceRequest;
use halo::util::json::Json;
use halo::util::percentile;

/// The configuration of interest: phase-disaggregated pools with
/// chunked prefill, so queue wait, prefill chunks, KV handoff and
/// decode all land on the critical path.
fn chunked_fleet(devices: usize, link: Interconnect) -> (Fleet, Box<dyn Router>) {
    Policy::PhaseDisaggregated.build_with(
        &LlmConfig::llama2_7b(),
        &HwConfig::paper(),
        devices,
        8,
        0.5,
        link,
        SchedConfig::chunked(256),
    )
}

fn mmpp_trace(seed: u64, n: usize, rate: f64) -> Vec<TraceRequest> {
    let cfg = TrafficConfig::new(seed, rate, 1.0e9, Mix::Chat)
        .with_kind(ArrivalKind::Mmpp)
        .with_max_requests(n);
    collect_trace(&mut cfg.build())
}

#[test]
fn critical_paths_fold_bit_exactly_on_a_chunked_disaggregated_replay() {
    let trace = mmpp_trace(7, 200, 24.0);
    let (mut fleet, mut router) = chunked_fleet(4, Interconnect::board());
    fleet.enable_obs();
    let r = fleet.replay(&trace, router.as_mut());

    let recorders = fleet.recorders().expect("obs enabled");
    let kv = fleet.kv_spans().expect("obs enabled");
    let paths = obs::extract_paths(&r.served, &recorders, kv);
    assert_eq!(paths.len(), r.requests);
    assert_eq!(obs::reconcile_paths(&paths), 0, "paths must fold bit-exactly onto e2e");
    for p in &paths {
        assert_eq!(p.fold().to_bits(), p.e2e.to_bits(), "left fold must reproduce e2e bits");
        assert!((0.0..=1.0).contains(&p.coverage), "coverage {} out of range", p.coverage);
    }
    // complete instrumentation: the service segments dominate the paths
    let mean_cov = paths.iter().map(|p| p.coverage).sum::<f64>() / paths.len() as f64;
    assert!(mean_cov > 0.5, "uncapped recorders must cover most of the e2e, got {mean_cov}");

    // the configuration exercises every major segment source
    let has = |label: &str| paths.iter().any(|p| p.segments.iter().any(|s| s.label == label));
    assert!(has("queue_wait"), "bursty load must queue");
    assert!(has("prefill_chunk"), "chunked prefill must land on the path");
    assert!(has("kv_handoff"), "disaggregation must hand off KV");
    assert!(has("decode_step"), "decode must land on the path");

    // bottleneck profile: one row per resource, shares sum to 1
    let rows = obs::bottleneck_profile(&paths, 99.0);
    assert_eq!(rows.len(), obs::N_RESOURCES);
    let share: f64 = rows.iter().map(|r| r.share).sum();
    assert!((share - 1.0).abs() < 1e-6, "resource shares sum to {share}");
    let tail: f64 = rows.iter().map(|r| r.tail_share).sum();
    assert!((tail - 1.0).abs() < 1e-6, "tail shares sum to {tail}");

    // per-phase split covers the same seconds as the flat profile
    let phases = obs::phase_profile(&paths);
    let flat: f64 = rows.iter().map(|r| r.total_s).sum();
    let split: f64 = phases.iter().map(|p| p.total_s).sum();
    assert!((flat - split).abs() < 1e-9 * flat.abs().max(1.0), "{flat} vs {split}");
}

#[test]
fn interconnect_whatif_agrees_with_a_real_scaled_replay() {
    // a slow link at low load: KV handoffs are a first-order cost, and
    // queueing second-order effects stay small, so the virtual speedup
    // should land near the real one
    let trace = mmpp_trace(11, 120, 4.0);
    let (mut base_fleet, mut base_router) = chunked_fleet(4, Interconnect::ethernet());
    base_fleet.enable_obs();
    let base = base_fleet.replay(&trace, base_router.as_mut());

    let recorders = base_fleet.recorders().expect("obs enabled");
    let paths =
        obs::extract_paths(&base.served, &recorders, base_fleet.kv_spans().expect("obs enabled"));
    assert_eq!(obs::reconcile_paths(&paths), 0);
    let interconnect_s: f64 =
        paths.iter().map(|p| p.per_resource()[Resource::Interconnect.index()]).sum();
    assert!(interconnect_s > 0.0, "ethernet handoffs must land on the critical path");

    let whatifs = obs::standard_whatifs();
    let bw2 = whatifs.iter().find(|w| w.name == "interconnect_bw_x2").expect("standard axis");
    let est = obs::whatif::evaluate(&paths, bw2);

    // ground truth: the same trace through the same fleet shape with the
    // link bandwidth actually doubled
    let (mut fast_fleet, mut fast_router) =
        chunked_fleet(4, Interconnect::ethernet().with_bandwidth_scale(2.0));
    let fast = fast_fleet.replay(&trace, fast_router.as_mut());

    let e2e_of = |r: &halo::cluster::FleetResult| -> Vec<f64> {
        r.served.iter().map(|s| s.e2e).collect()
    };
    let base_e2e = e2e_of(&base);
    let fast_e2e = e2e_of(&fast);
    let true_mean_delta = fast_e2e.iter().sum::<f64>() / fast_e2e.len() as f64
        - base_e2e.iter().sum::<f64>() / base_e2e.len() as f64;
    let true_p99_delta = percentile(&fast_e2e, 99.0) - percentile(&base_e2e, 99.0);

    // sign agreement: both the estimator and reality say the faster
    // link helps
    assert!(est.delta_e2e_mean_s < 0.0, "estimated mean delta {}", est.delta_e2e_mean_s);
    assert!(est.delta_e2e_p99_s <= 0.0, "estimated p99 delta {}", est.delta_e2e_p99_s);
    assert!(true_mean_delta < 0.0, "real mean delta {true_mean_delta}");
    assert!(true_p99_delta <= 0.0, "real p99 delta {true_p99_delta}");

    // pinned relative bound on the mean movement: the estimator halves
    // the observed handoff segments, reality halves the pipe term and
    // relaxes queueing — they must land within 60% + 2ms of each other
    let err = (est.delta_e2e_mean_s - true_mean_delta).abs();
    let bound = 0.6 * true_mean_delta.abs() + 2e-3;
    assert!(
        err <= bound,
        "what-if drifted from reality: est {} vs true {true_mean_delta} (err {err} > {bound})",
        est.delta_e2e_mean_s
    );
}

#[test]
fn capped_recorders_degrade_to_partial_coverage_without_panicking() {
    let trace = mmpp_trace(13, 150, 24.0);
    let (mut fleet, mut router) = chunked_fleet(4, Interconnect::board());
    // a cap this tiny guarantees drops on every device
    fleet.enable_obs_capped(8);
    let r = fleet.replay(&trace, router.as_mut());

    let dropped = fleet.obs_dropped().expect("obs enabled");
    assert_ne!(dropped, (0, 0, 0), "the cap must actually have been hit for this pin to bind");

    let recorders = fleet.recorders().expect("obs enabled");
    let paths = obs::extract_paths(&r.served, &recorders, fleet.kv_spans().unwrap_or(&[]));
    assert_eq!(paths.len(), r.requests, "every served request still gets a path");
    assert_eq!(obs::reconcile_paths(&paths), 0, "reconciliation survives lossy traces");
    for p in &paths {
        assert!((0.0..=1.0).contains(&p.coverage));
    }
    // lost spans mean lost coverage, honestly reported
    let mean_cov = paths.iter().map(|p| p.coverage).sum::<f64>() / paths.len() as f64;
    assert!(mean_cov < 0.5, "a cap of 8 spans/device must lose most coverage, got {mean_cov}");
    // inference is disabled on lossy traces: gap time reads unattributed,
    // never confidently mislabeled
    let unattributed: f64 = obs::bottleneck_profile(&paths, 99.0)
        .iter()
        .filter(|r| r.resource == Resource::Unattributed)
        .map(|r| r.total_s)
        .sum();
    assert!(unattributed > 0.0, "lossy traces must carry unattributed time");
}

#[test]
fn snapshots_surface_recorder_drop_counters() {
    let trace = mmpp_trace(17, 80, 24.0);
    let (mut fleet, mut router) = chunked_fleet(2, Interconnect::board());
    fleet.enable_obs_capped(4);
    let r = fleet.replay(&trace, router.as_mut());
    let dropped = fleet.obs_dropped().expect("obs enabled");
    assert_ne!(dropped, (0, 0, 0));

    let snap = obs::cluster_snapshot(
        &r,
        fleet.cost_walks(),
        fleet.cost_memo_hits(),
        &SelfProfile::new(),
        Json::Null,
        Some(dropped),
    );
    let parsed = Json::parse(&snap.to_string()).expect("snapshot must be valid json");
    let spans = parsed.path(&["obs_dropped", "spans"]).and_then(Json::as_f64).unwrap();
    let events = parsed.path(&["obs_dropped", "events"]).and_then(Json::as_f64).unwrap();
    let batches = parsed.path(&["obs_dropped", "batches"]).and_then(Json::as_f64).unwrap();
    assert_eq!(
        (spans as u64, events as u64, batches as u64),
        dropped,
        "drop counters must surface verbatim"
    );
    // uninstrumented runs read null, not zero — "no recorder" and
    // "lossless recorder" stay distinguishable
    let plain = obs::cluster_snapshot(&r, 0, 0, &SelfProfile::new(), Json::Null, None);
    assert_eq!(plain.path(&["obs_dropped"]), Some(&Json::Null));
}

#[test]
fn openmetrics_exposition_matches_the_golden_file() {
    // a hand-pinned registry: dyadic samples so `_sum` renders exactly,
    // samples and boundaries in distinct log buckets so the cumulative
    // bucket counts are unambiguous
    let mut reg = Registry::new();
    reg.inc("decode_steps", 7);
    reg.inc("requests_served", 3);
    reg.gauge("utilization", 0.75);
    let h = reg.hist("e2e_s");
    h.record(0.25);
    h.record(2.0);
    h.record(50.0);

    let golden = include_str!("data/openmetrics.golden.prom");
    assert_eq!(
        reg.to_openmetrics(),
        golden,
        "OpenMetrics exposition drifted from its golden file"
    );
}

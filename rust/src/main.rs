//! `halo` CLI: the leader entrypoint.
//!
//! Subcommands:
//!   simulate  — analytical simulation of one scenario under one mapping
//!   report    — regenerate the paper's figures/tables (CSV + markdown)
//!   roofline  — print the Fig. 1 roofline points
//!   cluster   — fleet-scale serving simulation with routing policies
//!   trace     — cluster replay with request-lifecycle spans -> Chrome-trace JSON
//!   monitor   — streamed serve with windowed telemetry, SLO burn rates, attribution
//!   critpath  — causal critical-path extraction with bottleneck + what-if attribution
//!   dse       — design-space exploration / SLO auto-tuning over the simulator
//!   power     — per-event energy attribution and TDP throttling studies
//!   bench     — pinned simulator benchmarks (the perf trajectory CI tracks)
//!   serve     — functional serving demo over the AOT artifacts (PJRT)
//!   validate  — replay the python test vectors through the Rust runtime

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use halo::cluster::{
    collect_trace, per_tenant_stats_served, AdmissionPolicy, ArrivalKind, Fleet, FleetBuilder,
    Interconnect, Mix, Policy, Router, SchedConfig, ServeOptions, SessionConfig, TrafficConfig,
};
use halo::config::HwConfig;
use halo::coordinator::{InferenceEngine, Request, Server};
use halo::dse::{self, DseConfig, Fidelity, Objective, SearchSpace, SloSpec};
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::obs::{self, SelfProfile};
use halo::power::{power_trace, DvfsConfig, ThermalConfig};
use halo::report;
use halo::runtime::Runtime;
use halo::sim::queueing::TraceRequest;
use halo::sim::{simulate_e2e, Scenario};
use halo::util::json::Json;
use halo::util::{fmt_joules, fmt_seconds, Rng};

const USAGE: &str = "\
halo — memory-centric heterogeneous accelerator for low-batch LLM inference

USAGE:
  halo simulate [--model llama2-7b|qwen3-8b] [--mapping HALO1|HALO2|CENT|AttAcc1|AttAcc2|FullCiD|FullCiM|HALO-SA]
                [--lin N] [--lout N] [--batch N]
  halo report   [--all | --fig 1|4|5|6|7|8|9|10|cluster|dse|power|obs|critpath | --headline] [--out DIR]
  halo roofline [--lin N] [--batch N]
  halo cluster  [--devices N] [--policy roundrobin|leastloaded|disaggregated|kvaware] [--mix chat|summarization|generation|interactive]
                [--model llama2-7b|qwen3-8b] [--requests N] [--rate R] [--slots N] [--link board|pcie|eth|wan]
                [--prefill-frac F] [--seed S] [--tenants N]
                [--chunk TOKENS] [--admission fifo|spf|priority] [--kv-cap GB|auto]
                [--arrivals poisson|mmpp|diurnal] [--duration S] [--sessions]
                [--power] [--tdp W|auto] [--dvfs SPEC] [--smoke] [--json] [--timeseries FILE]
                [--critpath FILE] [--metrics-out FILE]
                  --arrivals  stream requests from a seeded arrival-process generator
                              instead of replaying a pre-built trace: poisson (memoryless),
                              mmpp (two-state bursty), diurnal (rate curve over --duration).
                              Served under a bounded retention cap, so memory stays flat
                              however long the stream runs.
                  --duration  generator horizon in seconds (with --arrivals; default 60,
                              smoke 10); fresh arrivals stop at the horizon, in-flight
                              sessions drain
                  --sessions  multi-turn conversations: completed requests re-arrive after
                              a think time with their context grown (with --arrivals)
                  --chunk     prefill chunk size (0 = serialized monolithic prefill, the default)
                  --admission ready-queue order: fifo (default), spf (shortest prompt first),
                              priority (interactive prompts <= 512 tokens first)
                  --kv-cap    per-device resident-KV budget in GB (0 = unlimited, the default);
                              `auto` derives it from HBM capacity minus model weights
                  --tenants   tag requests with N tenants and print per-tenant breakdowns
                  --power     attribute per-event energy (per-device + fleet totals)
                  --tdp       per-package TDP cap in W (implies --power): device service
                              throttles when the RC thermal model runs over cap;
                              `auto` uses the calibrated package TDP
                  --dvfs      per-phase DVFS: `nominal|balanced|eco` pins both phases,
                              `PRE,DEC` pins prefill/decode separately, `governor` steps
                              the ladder under the TDP cap instead of the scalar throttle
                              (requires --tdp; static points work even without --power)
                  --smoke     tiny CI run: 2 devices, 32 requests
                  --json      print one `halo.cluster.v1` snapshot (metrics registry,
                              per-device rows, self-profile) instead of the tables
                  --timeseries also record windowed telemetry (simulated time) during the
                              run and write one `halo.timeseries.v1` snapshot to FILE
                              (window knobs as in `halo monitor`)
                  --critpath  also record request-lifecycle spans during the run and
                              write one `halo.critpath.v1` snapshot to FILE: per-request
                              causal paths, per-resource bottleneck shares, what-ifs
                  --metrics-out
                              write the whole-run metrics registry as OpenMetrics text
                              exposition to FILE (Prometheus/victoria scrapable)
  halo trace    [same flags as cluster] [--out FILE]
                  replay the cluster with request-lifecycle span recording on (queued,
                  prefill chunks, KV handoffs, decode steps, evictions, throttling) and
                  write a Chrome-trace JSON timeline — one track per device plus an
                  interconnect track. Open in https://ui.perfetto.dev or chrome://tracing.
                  --out       output file (default trace.json)
  halo monitor  [same flags as cluster] [--window S] [--max-windows N]
                [--ttft-slo S] [--e2e-slo S] [--slo-objective P]
                [--fast-windows N] [--slow-windows N] [--burn-threshold X]
                [--timeseries FILE] [--attrib DIR] [--critpath FILE] [--metrics-out FILE]
                  serve a generated stream (default: mmpp arrivals) with windowed
                  telemetry over simulated time: a per-window throughput / latency /
                  utilization table, SLO attainment with fast+slow burn-rate alerts,
                  and per-request latency attribution (where the p99 comes from).
                  Attribution components reconcile bit-exactly against the recorded
                  TTFT/e2e; the command exits nonzero on any mismatch, so CI gates on it.
                  --window      window width in simulated seconds (default duration/24,
                                min 0.25); memory stays fixed however long the stream
                                runs — windows coarsen 2x whenever --max-windows
                                (default 256) would overflow
                  --ttft-slo    TTFT target in seconds (default 0.5)
                  --e2e-slo     end-to-end latency target in seconds (default 10)
                  --slo-objective required attainment in (0,1) (default 0.99)
                  --fast-windows, --slow-windows
                                trailing window counts for the fast/slow burn rates
                                (default 3/12, SRE multi-window style)
                  --burn-threshold alert when both burns exceed this (default 4.0)
                  --timeseries  write one `halo.timeseries.v1` snapshot to FILE
                  --attrib      write the attribution + SLO window tables as CSV to DIR
                  --critpath    write one `halo.critpath.v1` snapshot to FILE (paths
                                extracted from the capped stream recorders; lossy runs
                                degrade to partial coverage instead of failing)
                  --metrics-out write the whole-run + windowed metrics registry as
                                OpenMetrics text exposition to FILE
  halo critpath [same flags as cluster] [--paths N] [--csv DIR] [--out FILE]
                  extract every served request's causal critical path from an
                  instrumented replay (default: mmpp arrivals): queue wait, prefill
                  chunks, KV handoffs, decode steps, throttle stalls — each segment
                  classified by the resource that binds it (cim_compute,
                  cid_bandwidth, interconnect, kv_capacity, scheduler, thermal).
                  Prints the slowest per-request paths, the per-resource bottleneck
                  profile (all requests vs the p99 e2e tail, split by phase), and a
                  COZ-style what-if table: estimated TTFT/e2e p99 movement under
                  interconnect bandwidth x2, CiM mesh x2, KV budget +50%, no TDP
                  cap. Path segments reconcile bit-exactly against the recorded
                  e2e; the command exits nonzero on any mismatch, so CI gates on it.
                  --paths     how many slowest path dumps to print (default 3)
                  --csv       write the bottleneck + what-if tables as CSV to DIR
                  --out       write one `halo.critpath.v1` snapshot to FILE
  halo dse      [--space smoke|sched|fleet|hw|mapping|power|full] [--strategy grid|random|hillclimb]
                [--model llama2-7b|qwen3-8b] [--mix chat|summarization|generation|interactive]
                [--requests N] [--seed S] [--slots N] [--link board|pcie|eth|wan]
                [--rate R | --rate-scale X] [--tenants N] [--samples N] [--restarts N] [--steps N]
                [--threads N] [--fidelity full|halving] [--objectives csv]
                [--ttft-slo MS] [--slo-pct P] [--smoke] [--out DIR] [--json]
                  --space      candidate space preset (default sched; see dse::space presets)
                  --strategy   grid enumerates everything; random/hillclimb sample big spaces
                               (--samples, --restarts/--steps; seeded by --seed)
                  --threads    evaluation worker threads (default 1); results are
                               bit-identical at any thread count
                  --fidelity   `halving` screens candidates on short trace prefixes
                               (successive halving, eta=2 from requests/8) and re-scores
                               survivors at full fidelity; reported metrics always come
                               from full replays (default full)
                  --objectives comma list of ttft-p50,ttft-p99,e2e-p50,e2e-p99,throughput,
                               decode-tput,evictions,cost,slo,tenant-ttft,
                               energy-per-token,edp,peak-power
                               (default ttft-p50,ttft-p99,throughput,cost; the `power`
                               space also sweeps TDP caps and per-phase DVFS points)
                  --ttft-slo   auto-tune mode: also report the cheapest config whose TTFT at
                               --slo-pct (default p50) meets this many milliseconds
                  --rate       absolute offered load in req/s; --rate-scale multiplies one
                               device's measured capacity instead (default 1.5x)
                  --smoke      tiny CI grid: alias for --space smoke with 48 requests
                  --json       print one `halo.dse.v1` snapshot (config, every evaluated
                               candidate with metrics, frontier, self-profile)
  halo bench    [--smoke] [--out FILE] [--baseline FILE] [--tolerance PCT] [--strict]
                  pinned simulator benchmarks: fixed seeds and absolute request rates, so
                  the simulated work is identical on every host. Reports wall time,
                  cost-oracle graph walks and peak RSS — the simulator's own perf
                  trajectory, tracked per commit by CI.
                  --out       write the `halo.bench.v1` JSON artifact here
                  --baseline  compare against a previous artifact (median wall time)
                  --tolerance regression threshold in percent (default 25)
                  --strict    exit nonzero on regression (default: warn only)
  halo power    [--model llama2-7b|qwen3-8b] [--mix chat|summarization|generation|interactive]
                [--mappings csv] [--devices N] [--slots N] [--requests N] [--rate R]
                [--tdp W|auto] [--windows N] [--seed S] [--smoke] [--out DIR]
                  --mappings  mappings to compare (default fullcid,fullcim,halo1)
                  --tdp       per-package TDP cap in W; the thermal throttle slows
                              service while over cap (`auto` = calibrated package TDP)
                  --windows   also print an N-window power-over-time trace per mapping
                  --smoke     tiny CI run: 32 requests on one device
  halo serve    [--artifacts DIR] [--requests N] [--max-new N] [--slots N]
  halo validate [--artifacts DIR]
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            let v = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(k.to_string(), v);
        }
        i += 1;
    }
    m
}

fn flag_usize(f: &HashMap<String, String>, k: &str, default: usize) -> usize {
    f.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn flag_f64(f: &HashMap<String, String>, k: &str, default: f64) -> f64 {
    f.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Parse `--tdp W|auto` into a package cap (W); `auto` reads the
/// calibrated package TDP from the hardware config.
fn flag_tdp(f: &HashMap<String, String>, hw: &HwConfig) -> Result<Option<f64>> {
    match f.get("tdp").map(String::as_str) {
        None => Ok(None),
        Some("auto") => Ok(Some(hw.power.tdp_w)),
        Some(v) => {
            let w: f64 = v.parse().map_err(|_| anyhow!("--tdp wants watts or `auto`, got {v}"))?;
            if w <= 0.0 {
                bail!("--tdp must be positive");
            }
            Ok(Some(w))
        }
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);

    match cmd {
        "simulate" => cmd_simulate(&flags),
        "report" => cmd_report(&flags),
        "roofline" => cmd_roofline(&flags),
        "cluster" => cmd_cluster(&flags),
        "trace" => cmd_trace(&flags),
        "monitor" => cmd_monitor(&flags),
        "critpath" => cmd_critpath(&flags),
        "dse" => cmd_dse(&flags),
        "power" => cmd_power(&flags),
        "bench" => cmd_bench(&flags),
        "serve" => cmd_serve(&flags),
        "validate" => cmd_validate(&flags),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_simulate(f: &HashMap<String, String>) -> Result<()> {
    let hw = HwConfig::paper();
    let model = f.get("model").map(String::as_str).unwrap_or("llama2-7b");
    let llm = LlmConfig::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let mapping = f
        .get("mapping")
        .map(|m| MappingKind::by_name(m).ok_or_else(|| anyhow!("unknown mapping {m}")))
        .transpose()?
        .unwrap_or(MappingKind::Halo1);
    let sc = Scenario {
        l_in: flag_usize(f, "lin", 2048),
        l_out: flag_usize(f, "lout", 128),
        batch: flag_usize(f, "batch", 1),
    };
    let r = simulate_e2e(&llm, &hw, mapping, &sc);
    println!("model    : {} ({:.2}B params)", llm.name, llm.n_params() as f64 / 1e9);
    println!("mapping  : {}  (CiM wordlines: {})", mapping.name(), mapping.wordlines());
    println!("scenario : L_in={} L_out={} batch={}", sc.l_in, sc.l_out, sc.batch);
    println!("TTFT     : {}", fmt_seconds(r.ttft()));
    println!("TPOT     : {}", fmt_seconds(r.tpot()));
    println!("e2e time : {}", fmt_seconds(r.e2e_latency()));
    println!("e2e energy: {}", fmt_joules(r.e2e_energy()));
    println!("prefill  : {} / {}", fmt_seconds(r.prefill.latency), fmt_joules(r.prefill.energy));
    println!(
        "decode   : {}/token, {} total",
        fmt_seconds(r.tpot()),
        fmt_seconds(r.decode_latency())
    );
    println!("\nprefill engines:");
    for (eng, c) in &r.prefill.by_engine {
        println!("  {eng:>8}: {} ({})", fmt_seconds(c.latency), fmt_joules(c.energy));
    }
    println!("decode-step engines:");
    for (eng, c) in &r.decode_step.by_engine {
        println!("  {eng:>8}: {} ({})", fmt_seconds(c.latency), fmt_joules(c.energy));
    }
    Ok(())
}

fn cmd_report(f: &HashMap<String, String>) -> Result<()> {
    let hw = HwConfig::paper();
    let out = f.get("out").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("out/figures"));
    let tables = if f.contains_key("headline") {
        vec![report::headline_summary(&hw)]
    } else if let Some(fig) = f.get("fig") {
        match fig.as_str() {
            "1" => vec![report::fig1_roofline(&hw)],
            "4" => vec![report::fig4_breakdown(&hw)],
            "5" | "6" => vec![report::fig56_cid_vs_cim(&hw)],
            "7" => vec![report::fig78_e2e(&hw, false)],
            "8" => vec![report::fig78_e2e(&hw, true)],
            "9" => vec![report::fig9_batch_sweep(&hw)],
            "10" => vec![report::fig10_cim_vs_sa(&hw)],
            "cluster" => {
                let t1 = report::cluster::single_device_capacity(
                    &hw,
                    &LlmConfig::llama2_7b(),
                    Mix::Interactive,
                    8,
                );
                vec![
                    report::cluster::cluster_scaling_at(&hw, t1),
                    report::cluster::cluster_policy_comparison_at(&hw, t1),
                    report::cluster::chunked_prefill_ttft_at(&hw, t1),
                    report::cluster::kv_capacity_pressure_at(&hw, t1),
                ]
            }
            "obs" => vec![
                report::obs::attribution_breakdown(&hw),
                report::obs::slo_burn_windows(&hw),
            ],
            "critpath" => vec![
                report::critpath::bottleneck_table(&hw),
                report::critpath::whatif_table(&hw),
            ],
            "dse" => vec![
                report::dse::vb_extremes_search(&hw),
                report::dse::dse_frontier_for_mix(&hw, Mix::Chat),
                report::dse::dse_frontier_for_mix(&hw, Mix::Summarization),
            ],
            "power" => {
                let t1 = report::cluster::single_device_capacity(
                    &hw,
                    &LlmConfig::llama2_7b(),
                    Mix::Interactive,
                    8,
                );
                vec![
                    report::power::power_extremes_at(&hw, t1),
                    report::power::power_timeline_at(&hw, t1),
                    report::power::tdp_throttling(&hw),
                    report::power::dvfs_ladder(&hw),
                    report::power::dvfs_phase_split(&hw),
                ]
            }
            other => bail!("unknown figure {other}"),
        }
    } else {
        report::all_figures(&hw)
    };
    for t in &tables {
        t.write_csv(&out)?;
        println!("{}", t.to_markdown());
    }
    println!("CSV written to {}", out.display());
    Ok(())
}

fn cmd_roofline(f: &HashMap<String, String>) -> Result<()> {
    let hw = HwConfig::paper();
    let l_in = flag_usize(f, "lin", 512);
    let batch = flag_usize(f, "batch", 16);
    let t = report::fig1_roofline_at(&hw, l_in, batch);
    println!("{}", t.to_markdown());
    Ok(())
}

/// Everything `halo cluster` and `halo trace` need to stage one fleet
/// replay — parsed once so both subcommands accept identical flags.
struct ClusterSetup {
    hw: HwConfig,
    llm: LlmConfig,
    devices: usize,
    policy: Policy,
    mix: Mix,
    link: Interconnect,
    slots: usize,
    n_req: usize,
    seed: u64,
    prefill_frac: f64,
    sched: SchedConfig,
    tenants: usize,
    tdp: Option<f64>,
    track_power: bool,
    dvfs: Option<DvfsConfig>,
    rate: f64,
    /// `--arrivals`: stream from a generator instead of replaying a trace.
    arrivals: Option<ArrivalKind>,
    duration_s: f64,
    sessions: bool,
    /// `--requests` as the user gave it (streamed mode caps the generator
    /// with it only when explicit; the trace default doesn't apply).
    max_requests: Option<usize>,
}

fn parse_cluster_setup(f: &HashMap<String, String>) -> Result<ClusterSetup> {
    let hw = HwConfig::paper();
    let smoke = f.contains_key("smoke");
    let model = f.get("model").map(String::as_str).unwrap_or("llama2-7b");
    let llm = LlmConfig::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let devices = flag_usize(f, "devices", if smoke { 2 } else { 8 });
    let policy = {
        let name = f.get("policy").map(String::as_str).unwrap_or("disaggregated");
        Policy::by_name(name).ok_or_else(|| anyhow!("unknown policy {name}"))?
    };
    let mix = {
        let name = f.get("mix").map(String::as_str).unwrap_or("interactive");
        Mix::by_name(name).ok_or_else(|| anyhow!("unknown mix {name}"))?
    };
    let link = {
        let name = f.get("link").map(String::as_str).unwrap_or("board");
        Interconnect::by_name(name).ok_or_else(|| anyhow!("unknown link {name}"))?
    };
    if devices == 0 {
        bail!("--devices must be at least 1");
    }
    if matches!(policy, Policy::PhaseDisaggregated | Policy::KvAware) && devices < 2 {
        bail!("disaggregated routing needs at least 2 devices");
    }
    let slots = flag_usize(f, "slots", 8);
    if slots == 0 {
        bail!("--slots must be at least 1");
    }
    let n_req = flag_usize(f, "requests", if smoke { 32 } else { 160 });
    let seed = flag_usize(f, "seed", 42) as u64;
    let prefill_frac = flag_f64(f, "prefill-frac", 0.5);
    if !(prefill_frac > 0.0 && prefill_frac < 1.0) {
        bail!("--prefill-frac must be strictly between 0 and 1");
    }
    let chunk = flag_usize(f, "chunk", 0);
    let admission = {
        let name = f.get("admission").map(String::as_str).unwrap_or("fifo");
        AdmissionPolicy::by_name(name).ok_or_else(|| anyhow!("unknown admission policy {name}"))?
    };
    let kv_capacity = match f.get("kv-cap").map(String::as_str) {
        None => None,
        Some("auto") => Some(hw.kv_budget(llm.weight_bytes())),
        Some(v) => {
            let gb: f64 = v.parse().map_err(|_| anyhow!("--kv-cap wants GB or `auto`, got {v}"))?;
            if gb < 0.0 {
                bail!("--kv-cap must be non-negative");
            }
            (gb > 0.0).then_some((gb * 1e9) as u64)
        }
    };
    let sched = SchedConfig { chunk: (chunk > 0).then_some(chunk), admission, kv_capacity };
    let tenants = flag_usize(f, "tenants", 1);
    if tenants == 0 {
        bail!("--tenants must be at least 1");
    }
    let tdp = flag_tdp(f, &hw)?;
    let track_power = f.contains_key("power") || tdp.is_some();
    let dvfs = f
        .get("dvfs")
        .map(|spec| DvfsConfig::parse(&hw.power, spec).map_err(|e| anyhow!(e)))
        .transpose()?;
    if dvfs.as_ref().is_some_and(|d| d.governor) && tdp.is_none() {
        bail!("--dvfs governor steps the ladder against a TDP cap; add --tdp W|auto");
    }
    let arrivals = f
        .get("arrivals")
        .map(|name| {
            ArrivalKind::by_name(name)
                .ok_or_else(|| anyhow!("unknown arrival process {name} (poisson|mmpp|diurnal)"))
        })
        .transpose()?;
    let duration_s = flag_f64(f, "duration", if smoke { 10.0 } else { 60.0 });
    if duration_s <= 0.0 {
        bail!("--duration must be positive seconds");
    }
    let sessions = f.contains_key("sessions");
    if (f.contains_key("duration") || sessions) && arrivals.is_none() {
        bail!("--duration and --sessions stream from a generator; add --arrivals KIND");
    }
    // default offered load: 3x one monolithic device's measured capacity
    let rate = match f.get("rate").and_then(|v| v.parse::<f64>().ok()) {
        Some(r) => r,
        None => 3.0 * report::cluster::single_device_capacity(&hw, &llm, mix, slots),
    };
    Ok(ClusterSetup {
        hw,
        llm,
        devices,
        policy,
        mix,
        link,
        slots,
        n_req,
        seed,
        prefill_frac,
        sched,
        tenants,
        tdp,
        track_power,
        dvfs,
        rate,
        arrivals,
        duration_s,
        sessions,
        max_requests: f.get("requests").and_then(|v| v.parse().ok()),
    })
}

impl ClusterSetup {
    /// Assemble the fleet + router (shared by both the trace-replay and
    /// generator-streamed paths).
    fn build_fleet(&self) -> (Fleet, Box<dyn Router>) {
        let (mut fleet, router) = self.policy.build_with(
            &self.llm,
            &self.hw,
            self.devices,
            self.slots,
            self.prefill_frac,
            self.link.clone(),
            self.sched.clone(),
        );
        if self.track_power {
            fleet.enable_power(&self.hw, self.tdp.map(ThermalConfig::paper));
        }
        if let Some(d) = &self.dvfs {
            fleet.set_dvfs(d.clone());
        }
        (fleet, router)
    }

    /// Generate the trace and assemble the fleet + router.
    fn build(&self) -> (Vec<TraceRequest>, Fleet, Box<dyn Router>) {
        let trace = self.mix.trace_tenants(self.seed, self.n_req, self.rate, self.tenants);
        let (fleet, router) = self.build_fleet();
        (trace, fleet, router)
    }

    /// The `--arrivals` generator config, when streaming was requested.
    fn traffic(&self) -> Option<TrafficConfig> {
        let kind = self.arrivals?;
        let mut cfg = TrafficConfig::new(self.seed, self.rate, self.duration_s, self.mix)
            .with_kind(kind)
            .with_tenants(self.tenants);
        if self.sessions {
            cfg = cfg.with_sessions(SessionConfig::default());
        }
        if let Some(n) = self.max_requests {
            cfg = cfg.with_max_requests(n);
        }
        Some(cfg)
    }

    fn print_header(&self) {
        println!(
            "fleet    : {}x HALO devices ({} policy, {} link, {} slots/device)",
            self.devices,
            self.policy.name(),
            self.link.name,
            self.slots
        );
        println!(
            "scheduler: {} prefill, {} admission, KV budget {}",
            match self.sched.chunk {
                Some(c) => format!("chunked({c})"),
                None => "serialized".into(),
            },
            self.sched.admission.name(),
            match self.sched.kv_capacity {
                Some(b) => format!("{:.1} GB/device", b as f64 / 1e9),
                None => "unlimited".into(),
            }
        );
        match self.arrivals {
            Some(kind) => println!(
                "workload : {} mix, {} arrivals at {:.2} req/s for {:.0} s{} (seed {})",
                self.mix.name(),
                kind.name(),
                self.rate,
                self.duration_s,
                if self.sessions { ", multi-turn sessions" } else { "" },
                self.seed
            ),
            None => println!(
                "workload : {} mix, {} requests at {:.2} req/s (seed {})",
                self.mix.name(),
                self.n_req,
                self.rate,
                self.seed
            ),
        }
        if self.track_power {
            match self.tdp {
                Some(w) => {
                    println!("power    : tracked, TDP cap {w:.0} W/package (thermal throttle live)")
                }
                None => println!("power    : tracked, no TDP cap"),
            }
        }
        if let Some(d) = &self.dvfs {
            println!(
                "dvfs     : {} ({})",
                d.label(),
                if d.governor {
                    "thermal stepped governor replaces the scalar throttle"
                } else {
                    "static per-phase operating points"
                }
            );
        }
    }

    /// The setup echoed into `--json` snapshots so artifacts are
    /// self-contained.
    fn config_json(&self) -> Json {
        obs::jobj(vec![
            ("model", Json::Str(self.llm.name.to_string())),
            ("devices", Json::Num(self.devices as f64)),
            ("policy", Json::Str(self.policy.name().to_string())),
            ("mix", Json::Str(self.mix.name().to_string())),
            ("link", Json::Str(self.link.name.to_string())),
            ("slots", Json::Num(self.slots as f64)),
            ("requests", Json::Num(self.n_req as f64)),
            ("rate_rps", Json::Num(self.rate)),
            ("seed", Json::Num(self.seed as f64)),
            ("tenants", Json::Num(self.tenants as f64)),
            ("power_tracked", Json::Bool(self.track_power)),
            ("tdp_w", self.tdp.map_or(Json::Null, Json::Num)),
            (
                "arrivals",
                self.arrivals.map_or(Json::Null, |k| Json::Str(k.name().to_string())),
            ),
            (
                "duration_s",
                if self.arrivals.is_some() { Json::Num(self.duration_s) } else { Json::Null },
            ),
            ("sessions", Json::Bool(self.sessions)),
        ])
    }
}

fn cmd_cluster(f: &HashMap<String, String>) -> Result<()> {
    let setup = parse_cluster_setup(f)?;
    let json = f.contains_key("json");
    if !json {
        setup.print_header();
    }
    let tenants = setup.tenants;
    let ts_out = f.get("timeseries").map(PathBuf::from);
    let mut series = match &ts_out {
        Some(_) => Some(monitor_series(f, setup.duration_s)?),
        None => None,
    };
    let cp_out = f.get("critpath").map(PathBuf::from);
    let metrics_out = f.get("metrics-out").map(PathBuf::from);
    let mut prof = SelfProfile::new();
    let (mut fleet, r) = match setup.traffic() {
        // streamed: pull arrivals from the generator one at a time under a
        // bounded retention cap — online histograms carry the percentiles,
        // so memory stays flat however many requests the horizon yields
        Some(cfg) => {
            const STREAM_RETAIN: usize = 65_536;
            let mut gen = cfg.build();
            let (mut fleet, mut router) = setup.build_fleet();
            if cp_out.is_some() {
                fleet.enable_obs_capped(STREAM_RETAIN);
            }
            let opts = ServeOptions::streaming(STREAM_RETAIN);
            let r = prof.time("fleet_replay", || match series.as_mut() {
                Some(s) => fleet.serve_monitored(&mut gen, router.as_mut(), opts, s),
                None => fleet.serve(&mut gen, router.as_mut(), opts),
            });
            (fleet, r)
        }
        None => {
            let (trace, mut fleet, mut router) = setup.build();
            if cp_out.is_some() {
                fleet.enable_obs();
            }
            let r = prof.time("fleet_replay", || match series.as_mut() {
                Some(s) => fleet.replay_monitored(&trace, router.as_mut(), s),
                None => fleet.replay(&trace, router.as_mut()),
            });
            (fleet, r)
        }
    };
    prof.add("graph_walks", fleet.cost_walks());
    prof.add("oracle_memo_hits", fleet.cost_memo_hits());
    let obs_dropped = fleet.obs_dropped();
    if !json {
        if let Some((s, e, b)) = obs_dropped.filter(|&d| d != (0, 0, 0)) {
            println!(
                "WARNING    : lossy trace — recorder dropped {s} spans / {e} events / {b} \
                 decode batches; critical-path coverage degrades to partial"
            );
        }
    }
    if let (Some(path), Some(s)) = (&ts_out, &series) {
        let snap = obs::timeseries_snapshot(s, None, setup.config_json(), obs_dropped);
        std::fs::write(path, snap.to_string())?;
        if !json {
            println!("timeseries : {} windows -> {}", s.len(), path.display());
        }
    }
    if let Some(path) = &metrics_out {
        let mut reg = obs::fleet_registry(&r, fleet.cost_walks(), fleet.cost_memo_hits());
        if let Some(s) = &series {
            obs::timeseries_registry(&mut reg, s);
        }
        std::fs::write(path, reg.to_openmetrics())?;
        if !json {
            println!("metrics    : OpenMetrics exposition -> {}", path.display());
        }
    }
    if let Some(path) = &cp_out {
        let recorders = fleet.recorders().expect("--critpath enables span recording");
        let paths =
            obs::extract_paths(&r.served, &recorders, fleet.kv_spans().unwrap_or(&[]));
        let bad = obs::reconcile_paths(&paths);
        if bad != 0 {
            bail!(
                "critical paths failed to reconcile bit-exactly on {bad} of {} requests",
                paths.len()
            );
        }
        let snap =
            critpath_snapshot_from(&paths, f, setup.duration_s, setup.config_json(), obs_dropped)?;
        std::fs::write(path, snap.to_string())?;
        if !json {
            println!(
                "critpath   : {} paths (reconciled bit-exact) -> {}",
                paths.len(),
                path.display()
            );
        }
    }
    if json {
        let snap = obs::cluster_snapshot(
            &r,
            fleet.cost_walks(),
            fleet.cost_memo_hits(),
            &prof,
            setup.config_json(),
            obs_dropped,
        );
        println!("{snap}");
        return Ok(());
    }

    let mut t = report::Table::new(
        "fleet_summary",
        "Fleet summary — per-device share of the replay",
        &[
            "device",
            "mapping",
            "role",
            "prefills",
            "decode_steps",
            "served",
            "busy_s",
            "util",
            "evictions",
            "kv_peak_gb",
            "energy_j",
            "avg_w",
        ],
    );
    for d in &r.per_device {
        t.row(vec![
            d.id.to_string(),
            d.mapping.name().into(),
            d.role.into(),
            d.prefills.to_string(),
            d.decode_steps.to_string(),
            d.served.to_string(),
            format!("{:.3}", d.busy),
            format!("{:.3}", d.utilization(r.makespan)),
            d.evictions.to_string(),
            format!("{:.3}", d.kv_peak as f64 / 1e9),
            format!("{:.2}", d.energy.total()),
            format!("{:.1}", d.avg_power_w(r.makespan)),
        ]);
    }
    println!("\n{}", t.to_markdown());
    if tenants > 1 {
        let mut tt = report::Table::new(
            "tenant_summary",
            "Per-tenant share of the replay",
            &["tenant", "requests", "tokens", "ttft_p50_s", "ttft_p99_s", "e2e_p99_s", "tok_per_s"],
        );
        for s in per_tenant_stats_served(&r.served, r.makespan) {
            tt.row(vec![
                s.tenant.to_string(),
                s.requests.to_string(),
                s.tokens.to_string(),
                format!("{:.6}", s.ttft_p50),
                format!("{:.6}", s.ttft_p99),
                format!("{:.6}", s.e2e_p99),
                format!("{:.2}", s.tok_per_s),
            ]);
        }
        println!("{}", tt.to_markdown());
    }
    println!("served     : {} requests in {}", r.requests, fmt_seconds(r.makespan));
    println!(
        "throughput : {:.2} req/s (mean utilization {:.1}%)",
        r.throughput_rps(),
        r.utilization() * 100.0
    );
    println!("TTFT       : p50 {}  p99 {}", fmt_seconds(r.ttft_p50()), fmt_seconds(r.ttft_p99()));
    println!("e2e        : p50 {}  p99 {}", fmt_seconds(r.e2e_p50()), fmt_seconds(r.e2e_p99()));
    println!(
        "KV traffic : {:.3} GB over {} transfers ({})",
        r.kv_bytes as f64 / 1e9,
        r.transfers,
        link_desc(&fleet.interconnect)
    );
    if r.evictions > 0 {
        println!(
            "KV pressure: {} evictions, {} tokens recomputed",
            r.evictions, r.recompute_tokens
        );
    }
    if r.power_tracked {
        let tokens = r.tokens;
        println!(
            "energy     : {} fleet total ({} / token, {:.3} J on KV transfers)",
            fmt_joules(r.energy_j()),
            fmt_joules(r.energy_per_token(tokens)),
            r.kv_transfer_energy_j
        );
        println!(
            "power      : {:.1} W avg, {:.1} W peak event{}",
            r.avg_power_w(),
            r.peak_power_w,
            if r.throttled_s > 0.0 {
                format!(", {} lost to throttling", fmt_seconds(r.throttled_s))
            } else {
                String::new()
            }
        );
    }
    println!(
        "profile    : replay {} wall, {} graph walks, {} oracle memo hits",
        fmt_seconds(prof.wall_s("fleet_replay")),
        prof.count("graph_walks"),
        prof.count("oracle_memo_hits")
    );
    Ok(())
}

fn cmd_trace(f: &HashMap<String, String>) -> Result<()> {
    let setup = parse_cluster_setup(f)?;
    setup.print_header();
    let (trace, mut fleet, mut router) = match setup.traffic() {
        // span recording retains every request anyway, so streamed
        // arrivals are materialized up front and replayed
        Some(cfg) => {
            let trace = collect_trace(&mut cfg.build());
            let (fleet, router) = setup.build_fleet();
            (trace, fleet, router)
        }
        None => setup.build(),
    };
    let ts_out = f.get("timeseries").map(PathBuf::from);
    let mut series = match &ts_out {
        Some(_) => Some(monitor_series(f, setup.duration_s)?),
        None => None,
    };
    fleet.enable_obs();
    let r = match series.as_mut() {
        Some(s) => fleet.replay_monitored(&trace, router.as_mut(), s),
        None => fleet.replay(&trace, router.as_mut()),
    };
    if let (Some(path), Some(s)) = (&ts_out, &series) {
        let snap = obs::timeseries_snapshot(s, None, setup.config_json(), fleet.obs_dropped());
        std::fs::write(path, snap.to_string())?;
        println!("timeseries : {} windows -> {}", s.len(), path.display());
    }

    // every recorded device timeline must reconcile exactly with the
    // replay's own busy accounting — same f64s folded in the same order
    for d in &r.per_device {
        let rec = fleet.devices[d.id].obs().expect("obs enabled before replay");
        if rec.busy_total().to_bits() != d.busy.to_bits() {
            bail!(
                "span/busy mismatch on dev{}: span total {} vs busy {}",
                d.id,
                rec.busy_total(),
                d.busy
            );
        }
        println!(
            "dev{:<3}     : {} spans + {} events, busy {} (reconciled bit-exact)",
            d.id,
            rec.spans.len(),
            rec.events.len(),
            fmt_seconds(d.busy)
        );
    }
    if let Some(kv) = fleet.kv_spans() {
        println!("interconn. : {} KV-transfer spans", kv.len());
    }

    let doc = fleet.chrome_trace().expect("obs enabled before replay");
    let out = f.get("out").map(String::as_str).unwrap_or("trace.json");
    std::fs::write(out, doc.to_string())?;
    let n_events = doc.path(&["traceEvents"]).and_then(Json::as_arr).map_or(0, <[Json]>::len);
    println!("served     : {} requests in {}", r.requests, fmt_seconds(r.makespan));
    println!(
        "trace      : {n_events} events -> {out} (open in https://ui.perfetto.dev \
         or chrome://tracing)"
    );
    Ok(())
}

/// Parse the `--window` / `--max-windows` knobs into a fresh
/// [`obs::WindowSeries`] (shared by `halo monitor` and the
/// `--timeseries` flags on `cluster` / `trace`).
fn monitor_series(f: &HashMap<String, String>, duration_s: f64) -> Result<obs::WindowSeries> {
    let width = flag_f64(f, "window", (duration_s / 24.0).max(0.25));
    if !(width > 0.0 && width.is_finite()) {
        bail!("--window must be positive seconds");
    }
    let max_windows = flag_usize(f, "max-windows", 256);
    if max_windows < 4 {
        bail!("--max-windows must be at least 4");
    }
    Ok(obs::WindowSeries::new(width, max_windows))
}

/// Parse the SLO target and burn-rate alerting knobs of `halo monitor`.
fn parse_monitor_slo(f: &HashMap<String, String>) -> Result<(obs::SloSpec, obs::BurnRateConfig)> {
    let d = obs::SloSpec::interactive();
    let spec = obs::SloSpec {
        ttft_target_s: flag_f64(f, "ttft-slo", d.ttft_target_s),
        e2e_target_s: flag_f64(f, "e2e-slo", d.e2e_target_s),
        objective: flag_f64(f, "slo-objective", d.objective),
    };
    if !(spec.objective > 0.0 && spec.objective < 1.0) {
        bail!("--slo-objective must be strictly between 0 and 1");
    }
    if !(spec.ttft_target_s > 0.0 && spec.e2e_target_s > 0.0) {
        bail!("--ttft-slo and --e2e-slo must be positive seconds");
    }
    let db = obs::BurnRateConfig::default();
    let burn = obs::BurnRateConfig {
        fast_windows: flag_usize(f, "fast-windows", db.fast_windows),
        slow_windows: flag_usize(f, "slow-windows", db.slow_windows),
        threshold: flag_f64(f, "burn-threshold", db.threshold),
    };
    if burn.fast_windows == 0 || burn.slow_windows < burn.fast_windows {
        bail!("--fast-windows must be >= 1 and --slow-windows >= --fast-windows");
    }
    if burn.threshold.is_nan() || burn.threshold <= 0.0 {
        bail!("--burn-threshold must be positive");
    }
    Ok((spec, burn))
}

/// Per-window telemetry + SLO table of one monitored serve.
fn slo_windows_table(
    series: &obs::WindowSeries,
    slo: &obs::SloReport,
    n_dev: usize,
) -> report::Table {
    let mut t = report::Table::new(
        "obs_slo_windows",
        &format!(
            "Windowed telemetry — {:.2}s windows: load, latency, SLO attainment, burn rate",
            series.width_s()
        ),
        &[
            "start_s",
            "arrivals",
            "completions",
            "throughput_rps",
            "queue",
            "util",
            "ttft_p99_s",
            "e2e_p99_s",
            "ttft_att",
            "e2e_att",
            "ttft_burn_fast",
            "e2e_burn_fast",
        ],
    );
    let w = series.width_s();
    for (win, s) in series.windows().iter().zip(&slo.per_window) {
        t.row(vec![
            format!("{:.1}", s.start_s),
            win.arrivals.to_string(),
            win.completions.to_string(),
            format!("{:.2}", win.throughput_rps(w)),
            win.queue_depth.to_string(),
            format!("{:.3}", win.utilization(w, n_dev)),
            format!("{:.4}", win.ttft_pct(99.0)),
            format!("{:.4}", win.e2e_pct(99.0)),
            format!("{:.4}", s.ttft_attainment),
            format!("{:.4}", s.e2e_attainment),
            format!("{:.2}", s.ttft_burn_fast),
            format!("{:.2}", s.e2e_burn_fast),
        ]);
    }
    t
}

/// The "where does the p99 come from" table of one monitored serve.
fn attribution_table(attrs: &[obs::Attribution]) -> report::Table {
    let mut t = report::Table::new(
        "obs_attribution",
        "Latency attribution — mean component seconds, all requests vs p99 e2e tail",
        &["component", "mean_s_all", "mean_s_tail", "tail_share"],
    );
    for row in obs::tail_breakdown(attrs, 99.0) {
        t.row(vec![
            row.component.to_string(),
            format!("{:.6}", row.mean_s_all),
            format!("{:.6}", row.mean_s_tail),
            format!("{:.4}", row.tail_share),
        ]);
    }
    t
}

fn cmd_monitor(flags: &HashMap<String, String>) -> Result<()> {
    // monitor is a streaming surface: default to mmpp arrivals so a bare
    // `halo monitor` shows bursts, burn spikes and recovery out of the box
    let mut f = flags.clone();
    f.entry("arrivals".to_string()).or_insert_with(|| "mmpp".to_string());
    let setup = parse_cluster_setup(&f)?;
    let (spec, burn) = parse_monitor_slo(&f)?;
    let mut series = monitor_series(&f, setup.duration_s)?;
    setup.print_header();
    println!(
        "slo      : TTFT < {:.3} s, e2e < {:.3} s at {:.1}% (alert: fast {} / slow {} \
         windows over {:.1}x budget)",
        spec.ttft_target_s,
        spec.e2e_target_s,
        spec.objective * 100.0,
        burn.fast_windows,
        burn.slow_windows,
        burn.threshold
    );

    const STREAM_RETAIN: usize = 65_536;
    let cfg = setup.traffic().expect("monitor always streams");
    let mut gen = cfg.build();
    let (mut fleet, mut router) = setup.build_fleet();
    fleet.enable_obs_capped(STREAM_RETAIN);
    let mut prof = SelfProfile::new();
    let opts = ServeOptions::streaming(STREAM_RETAIN);
    let r = prof.time("fleet_replay", || {
        fleet.serve_monitored(&mut gen, router.as_mut(), opts, &mut series)
    });
    prof.add("graph_walks", fleet.cost_walks());
    prof.add("oracle_memo_hits", fleet.cost_memo_hits());

    // the windowed populations must merge bit-exactly onto the whole-run
    // histograms — the tentpole invariant, enforced on every run
    if series.merged_ttft().counts() != r.ttft_hist.counts()
        || series.merged_e2e().counts() != r.e2e_hist.counts()
    {
        bail!("windowed latency populations do not merge onto the whole-run histograms");
    }

    let slo = obs::slo::evaluate(&series, &spec, &burn);
    let wt = slo_windows_table(&series, &slo, setup.devices);
    println!("\n{}", wt.to_markdown());
    println!(
        "slo      : whole-run attainment TTFT {:.4} / e2e {:.4} (objective {:.2})",
        slo.ttft_attainment, slo.e2e_attainment, spec.objective
    );
    if slo.alerts.is_empty() {
        println!("alerts   : none");
    } else {
        for a in &slo.alerts {
            println!(
                "alert    : {} burn at t={:.1}s (window {}): fast {:.2}x / slow {:.2}x budget",
                a.metric, a.t_s, a.window, a.burn_fast, a.burn_slow
            );
        }
    }

    // attribution needs the complete span record: every served request
    // retained and no recorder drop — true whenever the run fits the
    // streaming caps (the CI smoke path always does)
    let recorders = fleet.recorders().expect("obs enabled before serve");
    let spans_complete = r.complete && recorders.iter().all(|rec| rec.dropped() == (0, 0));
    let obs_dropped = fleet.obs_dropped();
    if let Some((s, e, b)) = obs_dropped.filter(|&d| d != (0, 0, 0)) {
        println!(
            "WARNING  : lossy trace — recorder dropped {s} spans / {e} events / {b} decode \
             batches; critical-path coverage degrades to partial (shorten --duration)"
        );
    }
    let at = if spans_complete {
        let attrs = obs::attribute(&r.served, &recorders, fleet.kv_spans().unwrap_or(&[]));
        let bad = obs::reconcile(&attrs);
        if bad != 0 {
            bail!(
                "attribution failed to reconcile bit-exactly on {bad} of {} requests",
                attrs.len()
            );
        }
        let t = attribution_table(&attrs);
        println!("{}", t.to_markdown());
        println!("attrib   : {} requests, components reconcile bit-exactly", attrs.len());
        Some(t)
    } else {
        println!(
            "attrib   : skipped — span retention capped (shorten --duration or cap --requests)"
        );
        None
    };

    println!(
        "served   : {} requests in {} ({} windows of {:.2}s, {} coarsenings, \
         replay {} wall)",
        r.requests,
        fmt_seconds(r.makespan),
        series.len(),
        series.width_s(),
        series.coarsenings(),
        fmt_seconds(prof.wall_s("fleet_replay"))
    );
    println!(
        "profile  : serve {} wall, {} graph walks, {} oracle memo hits",
        fmt_seconds(prof.wall_s("fleet_replay")),
        prof.count("graph_walks"),
        prof.count("oracle_memo_hits")
    );

    if let Some(dir) = f.get("attrib").map(PathBuf::from) {
        wt.write_csv(&dir)?;
        if let Some(t) = &at {
            t.write_csv(&dir)?;
        }
        println!("csv      : tables -> {}", dir.display());
    }
    if let Some(path) = f.get("timeseries").map(PathBuf::from) {
        let snap = obs::timeseries_snapshot(&series, Some(&slo), setup.config_json(), obs_dropped);
        std::fs::write(&path, snap.to_string())?;
        println!("snapshot : halo.timeseries.v1 -> {}", path.display());
    }
    if let Some(path) = f.get("metrics-out").map(PathBuf::from) {
        let mut reg = obs::fleet_registry(&r, fleet.cost_walks(), fleet.cost_memo_hits());
        obs::timeseries_registry(&mut reg, &series);
        std::fs::write(&path, reg.to_openmetrics())?;
        println!("metrics  : OpenMetrics exposition -> {}", path.display());
    }
    if let Some(path) = f.get("critpath").map(PathBuf::from) {
        // the stream recorders are capped, so long runs degrade to partial
        // coverage — the reconciliation invariant holds regardless
        let paths = obs::extract_paths(&r.served, &recorders, fleet.kv_spans().unwrap_or(&[]));
        let bad = obs::reconcile_paths(&paths);
        if bad != 0 {
            bail!(
                "critical paths failed to reconcile bit-exactly on {bad} of {} requests",
                paths.len()
            );
        }
        let mean_cov =
            paths.iter().map(|p| p.coverage).sum::<f64>() / paths.len().max(1) as f64;
        let snap =
            critpath_snapshot_from(&paths, &f, setup.duration_s, setup.config_json(), obs_dropped)?;
        std::fs::write(&path, snap.to_string())?;
        println!(
            "critpath : {} paths (reconciled bit-exact, coverage mean {mean_cov:.3}) -> {}",
            paths.len(),
            path.display()
        );
    }
    Ok(())
}

/// References to the `n` slowest requests by recorded e2e latency.
fn top_paths(paths: &[obs::CritPath], n: usize) -> Vec<&obs::CritPath> {
    let mut by_e2e: Vec<&obs::CritPath> = paths.iter().collect();
    by_e2e.sort_by(|a, b| b.e2e.total_cmp(&a.e2e));
    by_e2e.truncate(n);
    by_e2e
}

/// Assemble one `halo.critpath.v1` snapshot from extracted paths — the
/// `--critpath FILE` flags on `cluster`/`monitor` and `halo critpath
/// --out` all share this shape (window knobs as in `halo monitor`).
fn critpath_snapshot_from(
    paths: &[obs::CritPath],
    f: &HashMap<String, String>,
    duration_s: f64,
    config: Json,
    obs_dropped: Option<(u64, u64, u64)>,
) -> Result<Json> {
    let width = flag_f64(f, "window", (duration_s / 24.0).max(0.25));
    if !(width > 0.0 && width.is_finite()) {
        bail!("--window must be positive seconds");
    }
    let max_windows = flag_usize(f, "max-windows", 256);
    let bottleneck = obs::bottleneck_profile(paths, 99.0);
    let phases = obs::phase_profile(paths);
    let windows = obs::windowed_profile(paths, width, max_windows);
    let whatifs = obs::evaluate_all(paths, &obs::standard_whatifs());
    let top = top_paths(paths, 5);
    Ok(obs::critpath_snapshot(
        paths,
        obs::reconcile_paths(paths),
        &bottleneck,
        &phases,
        &windows,
        &whatifs,
        &top,
        config,
        obs_dropped,
    ))
}

fn cmd_critpath(flags: &HashMap<String, String>) -> Result<()> {
    // critpath is a diagnosis surface like monitor: default to mmpp
    // arrivals so a bare `halo critpath` profiles a bursty stream
    let mut f = flags.clone();
    f.entry("arrivals".to_string()).or_insert_with(|| "mmpp".to_string());
    let setup = parse_cluster_setup(&f)?;
    setup.print_header();

    // path extraction wants every request's complete span record, so
    // streamed arrivals are materialized up front and replayed with
    // uncapped recorders (the capped live-stream surface is `halo
    // monitor --critpath`, which degrades to partial coverage instead)
    let (trace, mut fleet, mut router) = match setup.traffic() {
        Some(cfg) => {
            let trace = collect_trace(&mut cfg.build());
            let (fleet, router) = setup.build_fleet();
            (trace, fleet, router)
        }
        None => setup.build(),
    };
    fleet.enable_obs();
    let mut prof = SelfProfile::new();
    let r = prof.time("fleet_replay", || fleet.replay(&trace, router.as_mut()));
    prof.add("graph_walks", fleet.cost_walks());
    prof.add("oracle_memo_hits", fleet.cost_memo_hits());

    let recorders = fleet.recorders().expect("obs enabled before replay");
    let kv = fleet.kv_spans().unwrap_or(&[]);
    let paths = prof.time("critpath_extract", || obs::extract_paths(&r.served, &recorders, kv));
    let bad = obs::reconcile_paths(&paths);
    if bad != 0 {
        bail!(
            "critical paths failed to reconcile bit-exactly on {bad} of {} requests",
            paths.len()
        );
    }
    let obs_dropped = fleet.obs_dropped();
    if obs_dropped.is_some_and(|d| d != (0, 0, 0)) {
        println!("WARNING  : lossy trace — coverage degrades to partial (see obs_dropped)");
    }
    let mean_cov = paths.iter().map(|p| p.coverage).sum::<f64>() / paths.len().max(1) as f64;
    println!(
        "critpath : {} paths reconcile bit-exactly against recorded e2e (coverage mean {:.3})",
        paths.len(),
        mean_cov
    );

    // the slowest requests, segment by segment
    let n_dump = flag_usize(&f, "paths", 3);
    const MAX_SEGMENTS: usize = 16;
    for p in top_paths(&paths, n_dump) {
        println!(
            "\npath     : arrival {:.3}s  ttft {}  e2e {}  coverage {:.3}",
            p.arrival,
            fmt_seconds(p.ttft),
            fmt_seconds(p.e2e),
            p.coverage
        );
        for s in p.segments.iter().take(MAX_SEGMENTS) {
            println!(
                "  +{:>9.4}s  {:<13} {:<13} {:<8} {}",
                s.start - p.arrival,
                s.label,
                s.resource.name(),
                s.phase,
                fmt_seconds(s.dur)
            );
        }
        if p.segments.len() > MAX_SEGMENTS {
            println!("  ... {} more segments", p.segments.len() - MAX_SEGMENTS);
        }
    }

    // which resource binds the fleet: whole population, p99 tail, per phase
    let rows = obs::bottleneck_profile(&paths, 99.0);
    let phases = obs::phase_profile(&paths);
    let mut bt = report::Table::new(
        "critpath_bottleneck",
        "Critical-path bottleneck profile — seconds and share per binding resource, \
         all requests vs p99 e2e tail",
        &["resource", "total_s", "share", "tail_s", "tail_share", "prefill_share", "decode_share"],
    );
    for row in &rows {
        let phase_share = |phase: &str| {
            phases
                .iter()
                .find(|p| p.phase == phase && p.resource == row.resource)
                .map_or(0.0, |p| p.share)
        };
        bt.row(vec![
            row.resource.name().to_string(),
            format!("{:.6}", row.total_s),
            format!("{:.4}", row.share),
            format!("{:.6}", row.tail_s),
            format!("{:.4}", row.tail_share),
            format!("{:.4}", phase_share("prefill")),
            format!("{:.4}", phase_share("decode")),
        ]);
    }
    println!("\n{}", bt.to_markdown());

    // the COZ-style counterfactuals: what each upgrade would buy
    let whatifs = obs::evaluate_all(&paths, &obs::standard_whatifs());
    let mut wt = report::Table::new(
        "critpath_whatif",
        "What-if virtual speedups — estimated p99 movement under scaled resources",
        &[
            "whatif",
            "base_ttft_p99_s",
            "est_ttft_p99_s",
            "base_e2e_p99_s",
            "est_e2e_p99_s",
            "delta_e2e_p99_s",
        ],
    );
    for w in &whatifs {
        wt.row(vec![
            w.name.to_string(),
            format!("{:.6}", w.base_ttft_p99_s),
            format!("{:.6}", w.est_ttft_p99_s),
            format!("{:.6}", w.base_e2e_p99_s),
            format!("{:.6}", w.est_e2e_p99_s),
            format!("{:.6}", w.delta_e2e_p99_s),
        ]);
    }
    println!("{}", wt.to_markdown());

    println!(
        "profile  : replay {} + extract {} wall, {} graph walks, {} oracle memo hits",
        fmt_seconds(prof.wall_s("fleet_replay")),
        fmt_seconds(prof.wall_s("critpath_extract")),
        prof.count("graph_walks"),
        prof.count("oracle_memo_hits")
    );

    if let Some(dir) = f.get("csv").map(PathBuf::from) {
        bt.write_csv(&dir)?;
        wt.write_csv(&dir)?;
        println!("csv      : tables -> {}", dir.display());
    }
    if let Some(path) = f.get("out").map(PathBuf::from) {
        let snap =
            critpath_snapshot_from(&paths, &f, setup.duration_s, setup.config_json(), obs_dropped)?;
        std::fs::write(&path, snap.to_string())?;
        println!("snapshot : halo.critpath.v1 -> {}", path.display());
    }
    Ok(())
}

fn cmd_bench(f: &HashMap<String, String>) -> Result<()> {
    let smoke = f.contains_key("smoke");
    println!(
        "pinned simulator benchmarks ({} mode; wall time is host-dependent, graph walks \
         are exact)",
        if smoke { "smoke" } else { "full" }
    );
    let points = obs::run_pinned(smoke);
    let mut t = report::Table::new(
        "bench",
        "Simulator perf trajectory — pinned workloads, fixed seeds and rates",
        &["workload", "iters", "wall_mean_s", "wall_p50_s", "graph_walks", "items"],
    );
    for p in &points {
        t.row(vec![
            p.name.to_string(),
            p.iters.to_string(),
            format!("{:.4}", p.wall_s_mean),
            format!("{:.4}", p.wall_s_p50),
            p.graph_walks.to_string(),
            p.items.to_string(),
        ]);
    }
    println!("\n{}", t.to_markdown());
    if let Some(rss) = obs::peak_rss_bytes() {
        println!("peak RSS   : {:.1} MB", rss as f64 / 1e6);
    }
    let doc = obs::bench_json(&points, smoke);
    if let Some(out) = f.get("out") {
        std::fs::write(out, doc.to_string())?;
        println!("bench JSON : {out}");
    }
    if let Some(base_path) = f.get("baseline") {
        let text = std::fs::read_to_string(base_path)?;
        let base =
            Json::parse(&text).map_err(|e| anyhow!("bad baseline {base_path}: {e}"))?;
        let tol = flag_f64(f, "tolerance", 25.0) / 100.0;
        let mut regressed = 0;
        for d in obs::compare(&doc, &base) {
            let verdict = if d.delta_frac > tol {
                regressed += 1;
                "REGRESSED"
            } else if d.delta_frac < -tol {
                "improved"
            } else {
                "ok"
            };
            println!(
                "compare    : {:<22} {:.4}s -> {:.4}s ({:+.1}%) {verdict}",
                d.name,
                d.base_s,
                d.new_s,
                d.delta_frac * 100.0
            );
        }
        if regressed > 0 {
            let pct = tol * 100.0;
            if f.contains_key("strict") {
                bail!("{regressed} workload(s) regressed beyond {pct:.0}%");
            }
            println!(
                "WARNING    : {regressed} workload(s) slower than baseline beyond {pct:.0}% \
                 (wall time is noisy; informational unless --strict)"
            );
        }
    }
    Ok(())
}

fn link_desc(l: &Interconnect) -> String {
    format!("{}: {:.1} GB/s, {:.0} us latency", l.name, l.bw / 1e9, l.latency * 1e6)
}

fn cmd_dse(f: &HashMap<String, String>) -> Result<()> {
    let smoke = f.contains_key("smoke");
    let space_name =
        f.get("space").map(String::as_str).unwrap_or(if smoke { "smoke" } else { "sched" });
    let space = SearchSpace::preset(space_name).ok_or_else(|| {
        anyhow!("unknown space {space_name} (one of {:?})", SearchSpace::preset_names())
    })?;

    let model = f.get("model").map(String::as_str).unwrap_or("llama2-7b");
    let llm = LlmConfig::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let mix = {
        let name = f.get("mix").map(String::as_str).unwrap_or("interactive");
        Mix::by_name(name).ok_or_else(|| anyhow!("unknown mix {name}"))?
    };
    let link = {
        let name = f.get("link").map(String::as_str).unwrap_or("board");
        Interconnect::by_name(name).ok_or_else(|| anyhow!("unknown link {name}"))?
    };

    let mut cfg = DseConfig::new(llm, mix);
    cfg.link = link;
    cfg.requests = flag_usize(f, "requests", if smoke { 48 } else { 96 });
    cfg.seed = flag_usize(f, "seed", 42) as u64;
    cfg.slots = flag_usize(f, "slots", 8);
    cfg.tenants = flag_usize(f, "tenants", 1);
    cfg.rate = f.get("rate").and_then(|v| v.parse().ok());
    cfg.rate_scale = flag_f64(f, "rate-scale", 1.5);
    if cfg.requests == 0 || cfg.slots == 0 || cfg.tenants == 0 {
        bail!("--requests, --slots and --tenants must be at least 1");
    }
    if cfg.rate.is_some_and(|r| r <= 0.0) {
        bail!("--rate must be a positive offered load in req/s");
    }
    if cfg.rate_scale <= 0.0 {
        bail!("--rate-scale must be positive");
    }
    cfg.threads = flag_usize(f, "threads", 1);
    if cfg.threads == 0 {
        bail!("--threads must be at least 1");
    }
    cfg.fidelity = match f.get("fidelity").map(String::as_str) {
        None | Some("full") => Fidelity::Full,
        Some("halving") | Some("sh") => Fidelity::halving(),
        Some(other) => bail!("unknown fidelity {other} (full|halving)"),
    };
    if let Some(objs) = f.get("objectives") {
        let parsed: Option<Vec<Objective>> =
            objs.split(',').map(|s| Objective::by_name(s.trim())).collect();
        cfg.objectives =
            parsed.ok_or_else(|| anyhow!("unknown objective in `{objs}`"))?;
        if cfg.objectives.is_empty() {
            bail!("--objectives must name at least one objective");
        }
    }
    if let Some(ms) = f.get("ttft-slo") {
        let ms: f64 = ms.parse().map_err(|_| anyhow!("--ttft-slo wants milliseconds"))?;
        if ms <= 0.0 {
            bail!("--ttft-slo must be positive");
        }
        let pct = flag_f64(f, "slo-pct", 50.0);
        if !(0.0..=100.0).contains(&pct) {
            bail!("--slo-pct must be a percentile in 0..=100");
        }
        cfg.slo = Some(SloSpec { ttft: ms / 1e3, pct });
    }
    if cfg.objectives.contains(&Objective::SloAttainment) && cfg.slo.is_none() {
        bail!("the `slo` objective needs --ttft-slo (attainment is constant 1.0 without one)");
    }

    let strategy_name = f.get("strategy").map(String::as_str).unwrap_or("grid");
    let samples = flag_usize(f, "samples", 64);
    let restarts = flag_usize(f, "restarts", 4);
    let steps = flag_usize(f, "steps", 32);
    let mut strategy = dse::strategy::by_name(strategy_name, cfg.seed, samples, restarts, steps)
        .ok_or_else(|| anyhow!("unknown strategy {strategy_name} (grid|random|hillclimb)"))?;
    if strategy.name() == "grid" && space.len() > 512 {
        bail!(
            "space `{space_name}` has {} points — too many for grid; use --strategy random \
             or hillclimb",
            space.len()
        );
    }

    let json = f.contains_key("json");
    if !json {
        println!(
            "search   : {} over `{space_name}` ({} points, {} axes), seed {}",
            strategy.name(),
            space.len(),
            halo::dse::AXES,
            cfg.seed
        );
    }
    let res = dse::explore(&space, strategy.as_mut(), &cfg);
    if json {
        let cfg_json = obs::jobj(vec![
            ("space", Json::Str(space_name.to_string())),
            ("strategy", Json::Str(strategy.name().to_string())),
            ("model", Json::Str(model.to_string())),
            ("mix", Json::Str(cfg.mix.name().to_string())),
            ("requests", Json::Num(cfg.requests as f64)),
            ("seed", Json::Num(cfg.seed as f64)),
            ("slots", Json::Num(cfg.slots as f64)),
            ("tenants", Json::Num(cfg.tenants as f64)),
            // threads is deliberately absent: the snapshot is identical
            // at any --threads N, and CI diffs it to prove exactly that
            ("fidelity", Json::Str(cfg.fidelity.name().to_string())),
        ]);
        println!("{}", obs::dse_snapshot(&res, cfg_json));
        return Ok(());
    }
    println!(
        "workload : {} mix, {} requests at {:.2} req/s, {} tenant(s)",
        cfg.mix.name(),
        cfg.requests,
        res.rate,
        cfg.tenants
    );
    println!(
        "evaluated: {} candidates -> {} on the Pareto frontier over {:?}",
        res.evaluated.len(),
        res.frontier.len(),
        res.objectives.iter().map(|o| o.name()).collect::<Vec<_>>()
    );
    let p = &res.profile;
    println!(
        "profile  : {} candidate evals in {} wall ({} graph walks, {} oracle memo hits, \
         {} DSE memo hits, {} invalid)\n",
        p.count("candidate_evals"),
        fmt_seconds(p.wall_s("candidate_evals")),
        p.count("graph_walks"),
        p.count("oracle_memo_hits"),
        p.count("dse_memo_hits"),
        p.count("invalid_candidates")
    );
    if p.count("sh_pool") > 0 {
        println!(
            "halving  : {} pooled -> {} pruned on trace prefixes ({} rung evals), \
             {} survivors re-scored at full fidelity\n",
            p.count("sh_pool"),
            p.count("sh_pruned"),
            p.count("sh_rung_evals"),
            p.count("sh_pool") - p.count("sh_pruned")
        );
    }
    let table = report::dse::frontier_table(
        &res,
        "dse_frontier",
        &format!("DSE Pareto frontier — {} space, {} mix", space_name, cfg.mix.name()),
    );
    println!("{}", table.to_markdown());
    if let Some(slo) = cfg.slo {
        match res.slo_choice {
            Some(i) => {
                let e = &res.evaluated[i];
                println!(
                    "SLO pick : {} — TTFT p{:.0} {} <= {} at relative cost {:.2}",
                    e.candidate.label(),
                    slo.pct,
                    fmt_seconds(e.metrics.slo_ttft),
                    fmt_seconds(slo.ttft),
                    e.metrics.cost
                );
            }
            None => println!(
                "SLO pick : no evaluated config meets TTFT p{:.0} <= {}",
                slo.pct,
                fmt_seconds(slo.ttft)
            ),
        }
    }
    if let Some(out) = f.get("out") {
        let dir = PathBuf::from(out);
        table.write_csv(&dir)?;
        println!("CSV written to {}", dir.display());
    }
    Ok(())
}

fn cmd_power(f: &HashMap<String, String>) -> Result<()> {
    let hw = HwConfig::paper();
    let smoke = f.contains_key("smoke");
    let model = f.get("model").map(String::as_str).unwrap_or("llama2-7b");
    let llm = LlmConfig::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let mix = {
        let name = f.get("mix").map(String::as_str).unwrap_or("interactive");
        Mix::by_name(name).ok_or_else(|| anyhow!("unknown mix {name}"))?
    };
    let mappings: Vec<MappingKind> = match f.get("mappings") {
        None => report::power::extreme_mappings().to_vec(),
        Some(csv) => csv
            .split(',')
            .map(|s| {
                MappingKind::by_name(s.trim())
                    .ok_or_else(|| anyhow!("unknown mapping {}", s.trim()))
            })
            .collect::<Result<_>>()?,
    };
    if mappings.is_empty() {
        bail!("--mappings must name at least one mapping");
    }
    let devices = flag_usize(f, "devices", 1);
    let slots = flag_usize(f, "slots", 8);
    let n_req = flag_usize(f, "requests", if smoke { 32 } else { 96 });
    if devices == 0 || slots == 0 || n_req == 0 {
        bail!("--devices, --slots and --requests must be at least 1");
    }
    let seed = flag_usize(f, "seed", 42) as u64;
    let windows = flag_usize(f, "windows", 0);
    let tdp = flag_tdp(f, &hw)?;
    let rate = match f.get("rate") {
        Some(v) => {
            let r: f64 = v.parse().map_err(|_| anyhow!("--rate wants req/s, got {v}"))?;
            if r <= 0.0 {
                bail!("--rate must be a positive offered load in req/s");
            }
            r
        }
        None => 1.25 * report::cluster::single_device_capacity(&hw, &llm, mix, slots),
    };
    let trace = mix.trace(seed, n_req, rate);
    let tokens: u64 = trace.iter().map(|q| q.l_out as u64).sum();

    println!(
        "workload : {} mix, {n_req} requests at {rate:.2} req/s on {devices} device(s), \
         seed {seed}",
        mix.name()
    );
    match tdp {
        Some(w) => println!("power    : TDP cap {w:.0} W/package (thermal throttle live)"),
        None => println!("power    : uncapped (attribution only)"),
    }

    let mut t = report::Table::new(
        "power_summary",
        &format!("Per-mapping energy/power summary — {} mix", mix.name()),
        &[
            "mapping",
            "energy_per_token_j",
            "e_dram_j",
            "e_compute_j",
            "e_buffer_j",
            "e_write_j",
            "e_static_j",
            "avg_power_w",
            "peak_power_w",
            "throttled_s",
            "ttft_p50_s",
            "served_rps",
        ],
    );
    let mut timelines: Vec<report::Table> = Vec::new();
    for &mk in &mappings {
        let per_dev = vec![mk; devices];
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .heterogeneous(&per_dev)
            .slots(slots)
            .interconnect(Interconnect::board())
            .power(tdp.map(ThermalConfig::paper))
            .build();
        let mut router: Box<dyn Router> = Policy::LeastLoaded.router();
        let r = fleet.replay(&trace, router.as_mut());
        t.row(vec![
            mk.name().into(),
            format!("{:.6e}", r.energy_per_token(tokens)),
            format!("{:.3}", r.energy.e_dram),
            format!("{:.3}", r.energy.e_compute),
            format!("{:.3}", r.energy.e_buffer),
            format!("{:.3}", r.energy.e_write),
            format!("{:.3}", r.energy.e_static),
            format!("{:.1}", r.avg_power_w()),
            format!("{:.1}", r.peak_power_w),
            format!("{:.3}", r.throttled_s),
            format!("{:.6}", r.ttft_p50()),
            format!("{:.3}", r.throughput_rps()),
        ]);
        if windows > 0 {
            let mut tl = report::Table::new(
                &format!("power_timeline_{}", mk.name().to_ascii_lowercase()),
                &format!("Power over time — {}, {windows} windows", mk.name()),
                &["window", "t_start_s", "t_end_s", "avg_w"],
            );
            // fleet-level timeline: one trace per device over the shared
            // makespan (power_trace has single-device busy/idle
            // semantics), summed window by window
            let mut fleet_avg = vec![0.0f64; windows];
            let mut window_s = r.makespan / windows as f64;
            for d in &fleet.devices {
                let Some(pw) = d.power() else { continue };
                let tr =
                    power_trace(&pw.events, pw.static_power(false), r.makespan, windows);
                window_s = tr.window_s;
                for (acc, &avg) in fleet_avg.iter_mut().zip(&tr.avg_w) {
                    *acc += avg;
                }
            }
            for (w, &avg) in fleet_avg.iter().enumerate() {
                tl.row(vec![
                    w.to_string(),
                    format!("{:.4}", w as f64 * window_s),
                    format!("{:.4}", (w + 1) as f64 * window_s),
                    format!("{avg:.1}"),
                ]);
            }
            timelines.push(tl);
        }
    }
    println!("\n{}", t.to_markdown());
    for tl in &timelines {
        println!("{}", tl.to_markdown());
    }
    if let Some(out) = f.get("out") {
        let dir = PathBuf::from(out);
        t.write_csv(&dir)?;
        for tl in &timelines {
            tl.write_csv(&dir)?;
        }
        println!("CSV written to {}", dir.display());
    }
    Ok(())
}

fn cmd_serve(f: &HashMap<String, String>) -> Result<()> {
    let dir = f.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let n_req = flag_usize(f, "requests", 8);
    let max_new = flag_usize(f, "max-new", 24);
    let slots = flag_usize(f, "slots", 4);

    let engine = InferenceEngine::load(Path::new(dir), slots)?;
    println!(
        "loaded artifacts from {dir} (platform {}, {} slots, max prompt {})",
        engine.rt.platform(),
        engine.slots(),
        engine.max_prompt()
    );
    let vocab = engine.vocab;
    let mut server = Server::new(engine);
    let mut rng = Rng::new(42);
    for id in 0..n_req {
        let plen = rng.range(4, 15) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
        server.submit(Request::new(id as u64, prompt, max_new));
    }
    let (responses, stats) = server.run_to_completion()?;
    for r in &responses {
        println!(
            "req {:>3}: {:>3} tokens  ttft {}  tpot {}  [{:?}...]",
            r.id,
            r.tokens.len(),
            fmt_seconds(r.ttft.as_secs_f64()),
            fmt_seconds(r.tpot.as_secs_f64()),
            &r.tokens[..r.tokens.len().min(6)]
        );
    }
    println!(
        "\n{} requests, {} decode steps, {} tokens in {} -> {:.1} tok/s (PJRT fraction {:.1}%)",
        stats.requests,
        stats.decode_steps,
        stats.generated_tokens,
        fmt_seconds(stats.wall.as_secs_f64()),
        stats.tokens_per_second(),
        stats.execute_fraction() * 100.0
    );
    Ok(())
}

fn cmd_validate(f: &HashMap<String, String>) -> Result<()> {
    let dir = f.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let rt = Runtime::load(Path::new(dir))?;
    println!("platform: {}", rt.platform());
    let mut failures = 0;
    let names: Vec<String> = rt.manifest.entries.keys().cloned().collect();
    for name in names {
        let spec = rt.manifest.entry(&name)?.clone();
        if spec.testvec_inputs.is_empty() {
            continue;
        }
        let exe = rt.compile(&name)?;
        let inputs = spec
            .testvec_inputs
            .iter()
            .zip(&spec.inputs[spec.n_params..])
            .map(|(file, s)| rt.manifest.load_testvec(file, s))
            .collect::<Result<Vec<_>>>()?;
        let outs = exe.run(&inputs)?;
        let mut worst_rel: f64 = 0.0;
        for ((got, file), spec_o) in outs.iter().zip(&spec.testvec_outputs).zip(&spec.outputs) {
            let want = rt.manifest.load_testvec(file, spec_o)?;
            let rel = got.max_abs_diff(&want)? / want.max_abs()?.max(1e-9);
            worst_rel = worst_rel.max(rel);
        }
        // Calibrated-ADC prefill entries are chaotic across XLA versions:
        // per-matmul analog ADC noise (~13% relative, see EXPERIMENTS.md
        // §Functional) compounds over layers, so a single flipped code
        // yields a different — equally valid — noise realization. They are
        // reported (finiteness-checked) but not diff-asserted; the
        // ideal-ADC twins and every integer-path entry must match tightly.
        let calibrated = name.starts_with("prefill_b1_");
        let finite = outs
            .iter()
            .all(|t| t.as_f32().map(|v| v.iter().all(|x| x.is_finite())).unwrap_or(true));
        let ok = if calibrated { finite } else { worst_rel < 1e-4 };
        println!(
            "{:>24}: max rel diff = {:.3e}  {}",
            name,
            worst_rel,
            if !ok { "FAIL" } else if calibrated { "OK (noise realization; finite)" } else { "OK" }
        );
        if !ok {
            failures += 1;
        }
    }
    if failures > 0 {
        bail!("{failures} entry points failed validation");
    }
    println!("all entry points validated against python test vectors");
    Ok(())
}

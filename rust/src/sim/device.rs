//! Reusable single-device serving state machine.
//!
//! Extracted from `sim::queueing::replay_trace` so that the single-device
//! replay and the `cluster` fleet simulator share one core: a [`CostModel`]
//! (memoized analytical prefill/decode-step cost curves) plus a [`Device`]
//! (slot-based continuous batching with serialized prefills), steppable in
//! event time one scheduling cycle at a time.
//!
//! A scheduling cycle mirrors the original replay loop exactly: admit every
//! ready job in FIFO order (each prefill occupies the whole device and
//! advances its clock), then run one batched decode step over the active
//! slots. The cluster layer adds two job shapes on top of the monolithic
//! [`DeviceJob::Full`]: [`DeviceJob::PrefillOnly`] (emit a KV handoff
//! instead of decoding) and [`DeviceJob::DecodeOnly`] (continue a sequence
//! whose prefill ran on another device).

use std::collections::{BTreeMap, VecDeque};

use super::queueing::{ServedRequest, TraceRequest};
use super::{simulate_graph, EngineSet};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig};

/// Memoized analytical cost curves for one (model, hardware, mapping)
/// triple: prefill latency per distinct prompt length, and decode-step
/// latency as an affine function of context per batch size (costs are
/// affine in context, so two samples per batch size suffice).
pub struct CostModel {
    llm: LlmConfig,
    mapping: MappingKind,
    engines: EngineSet,
    prefill_cache: BTreeMap<usize, f64>,
    dec_coef: BTreeMap<usize, (f64, f64)>,
}

impl CostModel {
    pub fn new(llm: &LlmConfig, hw: &HwConfig, mapping: MappingKind) -> Self {
        CostModel {
            llm: llm.clone(),
            mapping,
            engines: EngineSet::new(hw, mapping),
            prefill_cache: BTreeMap::new(),
            dec_coef: BTreeMap::new(),
        }
    }

    /// Prefill latency for a prompt of `l_in` tokens (batch 1).
    pub fn prefill(&mut self, l_in: usize) -> f64 {
        let (llm, engines, mapping) = (&self.llm, &self.engines, self.mapping);
        *self.prefill_cache.entry(l_in).or_insert_with(|| {
            simulate_graph(&build_prefill_graph(llm, l_in, 1), engines, mapping).latency
        })
    }

    /// Batched decode-step latency at (batch, context): affine in ctx —
    /// sample two points per batch size and interpolate.
    pub fn decode_step(&mut self, batch: usize, ctx: usize) -> f64 {
        let (llm, engines, mapping) = (&self.llm, &self.engines, self.mapping);
        let (a, b) = *self.dec_coef.entry(batch).or_insert_with(|| {
            let t1 = simulate_graph(&build_decode_graph(llm, 512, batch), engines, mapping).latency;
            let t2 = simulate_graph(&build_decode_graph(llm, 1024, batch), engines, mapping).latency;
            let slope = (t2 - t1) / 512.0;
            (t1 - slope * 512.0, slope)
        });
        a + b * ctx.max(1) as f64
    }
}

/// One unit of work queued on a device. `ready` is the earliest time the
/// device may start it (arrival time, or KV-transfer completion).
#[derive(Debug, Clone)]
pub enum DeviceJob {
    /// Prefill then decode to completion on this device (monolithic path).
    Full { arrival: f64, ready: f64, l_in: usize, l_out: usize },
    /// Prefill only; completion emits a [`PrefillDone`] handoff addressed
    /// to `decode_dev` instead of occupying a decode slot here.
    PrefillOnly { arrival: f64, ready: f64, l_in: usize, l_out: usize, decode_dev: usize },
    /// Decode-only continuation of a prefill that ran elsewhere; the first
    /// token was already produced at `first_token_at`.
    DecodeOnly { arrival: f64, ready: f64, first_token_at: f64, ctx: usize, remaining: usize },
}

impl DeviceJob {
    /// Monolithic job for one trace request.
    pub fn full(r: &TraceRequest) -> Self {
        DeviceJob::Full { arrival: r.arrival, ready: r.arrival, l_in: r.l_in, l_out: r.l_out }
    }

    pub fn ready(&self) -> f64 {
        match self {
            DeviceJob::Full { ready, .. }
            | DeviceJob::PrefillOnly { ready, .. }
            | DeviceJob::DecodeOnly { ready, .. } => *ready,
        }
    }
}

/// Handoff emitted when a [`DeviceJob::PrefillOnly`] completes: the KV
/// cache for `l_in` context tokens must reach `decode_dev`, which then
/// generates the remaining `l_out - 1` tokens.
#[derive(Debug, Clone)]
pub struct PrefillDone {
    pub arrival: f64,
    /// Prefill completion time on this device (== first-token time).
    pub done_at: f64,
    pub l_in: usize,
    pub l_out: usize,
    pub decode_dev: usize,
}

#[derive(Debug, Clone)]
struct ActiveSeq {
    arrival: f64,
    first_token_at: f64,
    ctx: usize,
    remaining: usize,
}

/// A single HALO device: FIFO admission queue, serialized prefills, and
/// `slots`-way batched decode, advanced one scheduling cycle at a time.
pub struct Device {
    pub id: usize,
    pub mapping: MappingKind,
    cost: CostModel,
    queue: VecDeque<DeviceJob>,
    active: Vec<Option<ActiveSeq>>,
    now: f64,
    /// Completed requests that finished decoding on this device.
    pub served: Vec<ServedRequest>,
    pub decode_steps: u64,
    pub prefills: u64,
    /// Time spent prefilling or decode-stepping (for utilization).
    pub busy: f64,
}

impl Device {
    pub fn new(llm: &LlmConfig, hw: &HwConfig, mapping: MappingKind, slots: usize, id: usize) -> Self {
        assert!(slots > 0);
        Device {
            id,
            mapping,
            cost: CostModel::new(llm, hw, mapping),
            queue: VecDeque::new(),
            active: vec![None; slots],
            now: 0.0,
            served: Vec::new(),
            decode_steps: 0,
            prefills: 0,
            busy: 0.0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().flatten().count()
    }

    /// Queued + in-flight work, the load metric for least-loaded routing.
    pub fn load(&self) -> usize {
        self.queue.len() + self.active_count()
    }

    pub fn has_work(&self) -> bool {
        self.active_count() > 0 || !self.queue.is_empty()
    }

    /// Earliest time this device can usefully run a cycle: immediately if
    /// anything is active or ready, else when the first queued job becomes
    /// ready. `None` when fully idle.
    pub fn next_action_time(&self) -> Option<f64> {
        if self.active_count() > 0 {
            return Some(self.now);
        }
        let min_ready = self.queue.iter().map(DeviceJob::ready).fold(f64::INFINITY, f64::min);
        if min_ready.is_finite() {
            Some(self.now.max(min_ready))
        } else {
            None
        }
    }

    /// Move the clock forward to `t` while idle (never backwards).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    pub fn push(&mut self, job: DeviceJob) {
        self.queue.push_back(job);
    }

    /// Run one scheduling cycle: admit ready jobs in FIFO order (prefills
    /// serialize the device and advance its clock), then one batched
    /// decode step over the active slots. Returns any prefill handoffs
    /// completed this cycle.
    pub fn step_cycle(&mut self) -> Vec<PrefillDone> {
        let mut handoffs = Vec::new();
        // idle-advance: nothing active and nothing ready yet -> jump to
        // the first queued job's ready time
        if self.active_count() == 0 && !self.queue.is_empty() {
            let min_ready = self.queue.iter().map(DeviceJob::ready).fold(f64::INFINITY, f64::min);
            self.now = self.now.max(min_ready);
        }
        // admissions against the cycle-start clock (jobs becoming ready
        // mid-admission wait for the next cycle, as in the original loop)
        let t0 = self.now;
        loop {
            let needs_slot = match self.queue.front() {
                Some(j) if j.ready() <= t0 => !matches!(j, DeviceJob::PrefillOnly { .. }),
                _ => break,
            };
            if needs_slot {
                let Some(slot) = self.active.iter().position(Option::is_none) else { break };
                match self.queue.pop_front().unwrap() {
                    DeviceJob::Full { arrival, ready, l_in, l_out } => {
                        let p = self.cost.prefill(l_in);
                        let start = self.now.max(ready);
                        self.now = start + p;
                        self.busy += p;
                        self.prefills += 1;
                        self.active[slot] = Some(ActiveSeq {
                            arrival,
                            first_token_at: self.now,
                            ctx: l_in,
                            remaining: l_out.saturating_sub(1),
                        });
                    }
                    DeviceJob::DecodeOnly { arrival, first_token_at, ctx, remaining, .. } => {
                        self.active[slot] =
                            Some(ActiveSeq { arrival, first_token_at, ctx, remaining });
                    }
                    DeviceJob::PrefillOnly { .. } => unreachable!(),
                }
            } else {
                match self.queue.pop_front().unwrap() {
                    DeviceJob::PrefillOnly { arrival, ready, l_in, l_out, decode_dev } => {
                        let p = self.cost.prefill(l_in);
                        let start = self.now.max(ready);
                        self.now = start + p;
                        self.busy += p;
                        self.prefills += 1;
                        handoffs.push(PrefillDone {
                            arrival,
                            done_at: self.now,
                            l_in,
                            l_out,
                            decode_dev,
                        });
                    }
                    _ => unreachable!(),
                }
            }
        }
        // one batched decode step at the mean active context
        let batch = self.active_count();
        if batch > 0 {
            let mean_ctx = self.active.iter().flatten().map(|s| s.ctx).sum::<usize>() / batch;
            let dt = self.cost.decode_step(batch, mean_ctx);
            self.now += dt;
            self.busy += dt;
            self.decode_steps += 1;
            for slot in self.active.iter_mut() {
                if let Some(s) = slot {
                    s.ctx += 1;
                    if s.remaining == 0 {
                        self.served.push(ServedRequest {
                            arrival: s.arrival,
                            ttft: s.first_token_at - s.arrival,
                            e2e: self.now - s.arrival,
                        });
                        *slot = None;
                    } else {
                        s.remaining -= 1;
                    }
                }
            }
        }
        handoffs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(slots: usize) -> Device {
        Device::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), MappingKind::Halo1, slots, 0)
    }

    #[test]
    fn full_job_runs_prefill_then_decodes_to_completion() {
        let mut d = dev(2);
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 256, l_out: 4 });
        let mut cycles = 0;
        while d.has_work() {
            assert!(d.step_cycle().is_empty());
            cycles += 1;
            assert!(cycles < 100);
        }
        assert_eq!(d.served.len(), 1);
        assert_eq!(d.decode_steps, 4);
        assert_eq!(d.prefills, 1);
        let s = &d.served[0];
        assert!(s.ttft > 0.0 && s.e2e > s.ttft);
    }

    #[test]
    fn prefill_only_emits_handoff_without_using_slots() {
        let mut d = dev(1);
        d.push(DeviceJob::PrefillOnly { arrival: 0.0, ready: 0.0, l_in: 128, l_out: 8, decode_dev: 3 });
        d.push(DeviceJob::PrefillOnly { arrival: 0.0, ready: 0.0, l_in: 128, l_out: 8, decode_dev: 4 });
        let h = d.step_cycle();
        // both prefills drain in one cycle despite a single slot
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].decode_dev, 3);
        assert!(h[0].done_at < h[1].done_at);
        assert!(!d.has_work());
        assert_eq!(d.active_count(), 0);
        assert_eq!(d.decode_steps, 0);
    }

    #[test]
    fn decode_only_preserves_foreign_ttft() {
        let mut d = dev(2);
        d.push(DeviceJob::DecodeOnly { arrival: 1.0, ready: 2.0, first_token_at: 1.5, ctx: 64, remaining: 2 });
        while d.has_work() {
            d.step_cycle();
        }
        assert_eq!(d.served.len(), 1);
        let s = &d.served[0];
        assert!((s.ttft - 0.5).abs() < 1e-12);
        // admission waited for the KV transfer (ready = 2.0)
        assert!(s.e2e > 1.0);
        assert_eq!(d.decode_steps, 3);
    }

    #[test]
    fn idle_device_jumps_to_ready_time() {
        let mut d = dev(1);
        d.push(DeviceJob::Full { arrival: 5.0, ready: 5.0, l_in: 64, l_out: 1 });
        assert_eq!(d.next_action_time(), Some(5.0));
        d.step_cycle();
        assert!(d.now() > 5.0);
    }

    #[test]
    fn cost_model_matches_direct_simulation() {
        let llm = LlmConfig::llama2_7b();
        let hw = HwConfig::paper();
        let mut cm = CostModel::new(&llm, &hw, MappingKind::Halo1);
        let engines = EngineSet::new(&hw, MappingKind::Halo1);
        let direct =
            simulate_graph(&build_prefill_graph(&llm, 777, 1), &engines, MappingKind::Halo1)
                .latency;
        assert_eq!(cm.prefill(777), direct);
        // affine interpolation is exact at the sampled points
        let d512 = simulate_graph(&build_decode_graph(&llm, 512, 3), &engines, MappingKind::Halo1)
            .latency;
        assert!((cm.decode_step(3, 512) - d512).abs() < 1e-15 * d512.max(1.0));
    }
}

//! Analog CiM cost model: the 2.5D co-packaged crossbar chiplet.
//!
//! COMET-style [19] compound-operation pipeline: a GEMM is tiled into
//! 128x128 crossbar loads; rounds of `resident_tiles()` tiles are
//! (1) streamed HBM -> interposer -> GB -> WB, (2) written into the
//! crossbars row-by-row, and (3) bit-serially computed against the input
//! stream. The three stages are double-buffered against each other
//! (ping-pong across each core's two resident tiles), so a round costs
//! `max(fill, write, compute)`:
//!
//! * prefill (large M): `compute = M * t_vector` dominates -> the chip
//!   runs at its 131 TMAC/s peak (the paper's 6x TTFT win over CiD);
//! * decode (M = 1): fill + crossbar writes dominate -> every token pays
//!   the full weight stream at interposer bandwidth plus the write time
//!   (the paper's 39x TPOT loss vs CiD);
//! * dynamic stationary operands (attention KV) get no residency at all —
//!   same stream+write cost on every call (why AttAcc pins attention to
//!   CiD even while running everything else on the accelerator die).
//!
//! Wordline throttling (HALO2) doubles both the phase count (latency) and
//! the ADC conversions (energy), but the pipeline max() hides the extra
//! latency whenever fill/write bound the round — reproducing the paper's
//! "only 10% slower" observation without special-casing.

use super::{MatmulEngine, OpCost};
use crate::config::HwConfig;
use crate::model::Op;

#[derive(Debug, Clone)]
pub struct CimEngine {
    hw: HwConfig,
}

impl CimEngine {
    pub fn new(hw: &HwConfig) -> Self {
        CimEngine { hw: hw.clone() }
    }

    /// Logical 128x128 int8 tiles of the stationary operand (one op
    /// instance).
    pub fn tiles_each(&self, op: &Op) -> usize {
        let d = self.hw.cim.xbar_dim;
        op.k.div_ceil(d) * op.n.div_ceil(d)
    }

    /// Rounds of crossbar residency needed for all instances.
    pub fn rounds(&self, op: &Op) -> usize {
        (self.tiles_each(op) * op.count).div_ceil(self.hw.cim.resident_tiles())
    }
}

impl MatmulEngine for CimEngine {
    fn matmul_cost(&self, op: &Op) -> OpCost {
        let cim = &self.hw.cim;
        let hbm = &self.hw.hbm;
        let ip = &self.hw.interposer;
        let d = cim.xbar_dim;

        let total_tiles = self.tiles_each(op) * op.count;
        let rounds = self.rounds(op) as f64;
        let tile_bytes = (d * d) as f64;
        let weight_bytes = total_tiles as f64 * tile_bytes;
        let macs = op.macs() as f64;
        let in_bytes = (op.input_bytes_each(1) * op.count as u64) as f64;
        let out_bytes = (op.output_bytes_each() * op.count as u64) as f64;

        // --- per-round pipeline stages ------------------------------------
        let tiles_per_round = (total_tiles as f64 / rounds).ceil();
        // (1) weight fill: HBM -> interposer -> GB (GB bw == interposer bw)
        let t_fill = tiles_per_round * tile_bytes / cim.gb_bw;
        // (2) crossbar write: cores write their resident tiles serially,
        //     cores in parallel
        let t_write = cim.tiles_per_core() as f64 * cim.t_tile_write();
        // (3) bit-serial compute: M vectors stream through the round's
        //     resident tiles (pipelined, one vector per t_vector)
        let t_compute = op.m as f64 * cim.t_vector();

        let round_latency = t_fill.max(t_write).max(t_compute);
        let latency = rounds * round_latency + cim.t_vector(); // pipe drain

        // --- energy -------------------------------------------------------
        // weights: bank read + IO + interposer, then crossbar cell writes
        let e_dram = weight_bytes * (hbm.e_bank_read + hbm.e_io_read + ip.e_link)
            + in_bytes * (hbm.e_bank_read + hbm.e_io_read + ip.e_link)
            + out_bytes * ip.e_link;
        let e_write = weight_bytes * cim.e_write;
        // ADC: every column of every slice-xbar digitized per input bit
        // per wordline phase
        let conversions = macs / (d * d) as f64 * cim.conversions_per_vector();
        let e_adc = conversions * cim.e_adc;
        let e_analog = macs * cim.e_mac_analog;
        // buffers: GB+WB traffic for weights, IB re-reads of the input
        // stream per round-group, OB partial accumulation (8 B per
        // 128-deep partial)
        let e_buffer = (weight_bytes + in_bytes * rounds.min(8.0)) * cim.e_buf
            + macs / d as f64 * 8.0 * cim.e_acc
            + (weight_bytes + in_bytes) * cim.e_noc_hop * cim.mean_hops;

        OpCost {
            latency,
            energy: e_dram + e_write + e_adc + e_analog + e_buffer,
            t_compute: rounds * t_compute.min(round_latency) * bound_frac(t_compute, round_latency),
            t_memory: rounds * t_fill * bound_frac(t_fill, round_latency),
            t_write: rounds * t_write * bound_frac(t_write, round_latency),
            e_dram,
            e_compute: e_adc + e_analog,
            e_buffer,
            e_write,
        }
    }

    fn peak_macs(&self) -> f64 {
        self.hw.cim.peak_macs()
    }

    fn stream_bw(&self) -> f64 {
        self.hw.cim.gb_bw
    }
}

/// 1.0 when this component is the round bottleneck, else 0 — used to
/// attribute round time to a single dominating component in breakdowns.
fn bound_frac(component: f64, round: f64) -> f64 {
    if component >= round * (1.0 - 1e-9) {
        1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_prefill_graph, LlmConfig, OpClass, OpKind, Operand};
    use crate::util::prop::{forall, Triple, UsizeIn};

    fn engine() -> CimEngine {
        CimEngine::new(&HwConfig::paper())
    }

    fn engine_wl64() -> CimEngine {
        CimEngine::new(&HwConfig::paper_wl64())
    }

    fn gemm(m: usize, k: usize, n: usize) -> Op {
        Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, m, k, n, 1)
    }

    #[test]
    fn tiles_and_rounds() {
        let e = engine();
        assert_eq!(e.tiles_each(&gemm(1, 4096, 4096)), 32 * 32);
        assert_eq!(e.rounds(&gemm(1, 4096, 4096)), 8); // 1024 / 128
        assert_eq!(e.tiles_each(&gemm(1, 100, 100)), 1);
    }

    #[test]
    fn prefill_runs_near_peak() {
        let e = engine();
        let op = gemm(2048, 4096, 11008);
        let c = e.matmul_cost(&op);
        let eff = op.macs() as f64 / c.latency;
        assert!(eff > 0.85 * e.peak_macs(), "eff {:.3e} peak {:.3e}", eff, e.peak_macs());
        assert!(c.t_compute > c.t_memory && c.t_compute > c.t_write);
    }

    #[test]
    fn decode_is_fill_or_write_bound() {
        let e = engine();
        let c = e.matmul_cost(&gemm(1, 4096, 4096));
        assert!(c.t_compute < c.latency * 0.1, "{c:?}");
        assert!(c.t_write + c.t_memory > c.latency * 0.9);
    }

    #[test]
    fn prefill_vs_decode_asymmetry_is_large() {
        // the §V-B story: per-MAC decode cost orders of magnitude worse
        let e = engine();
        let pre = e.matmul_cost(&gemm(2048, 4096, 4096));
        let dec = e.matmul_cost(&gemm(1, 4096, 4096));
        let per_mac_pre = pre.latency / gemm(2048, 4096, 4096).macs() as f64;
        let per_mac_dec = dec.latency / gemm(1, 4096, 4096).macs() as f64;
        assert!(per_mac_dec / per_mac_pre > 100.0);
    }

    #[test]
    fn halo2_doubles_compute_but_not_fill() {
        let h1 = engine();
        let h2 = engine_wl64();
        let big = gemm(4096, 4096, 4096);
        let c1 = h1.matmul_cost(&big);
        let c2 = h2.matmul_cost(&big);
        // compute-bound op: ~2x slower
        assert!(c2.latency / c1.latency > 1.8);
        // fill/write-bound op: unchanged latency, higher ADC energy
        let small = gemm(1, 4096, 4096);
        let s1 = h1.matmul_cost(&small);
        let s2 = h2.matmul_cost(&small);
        assert!((s2.latency / s1.latency - 1.0).abs() < 0.05);
        // ADC conversions double; the analog-array share does not
        assert!(s2.e_compute > 1.6 * s1.e_compute);
    }

    #[test]
    fn prefill_7b_ttft_band() {
        // full LLaMA-2 7B prefill at L=2048 should land near
        // MACs / 131 TMAC/s ~ 100-130 ms
        let e = engine();
        let m = LlmConfig::llama2_7b();
        let g = build_prefill_graph(&m, 2048, 1);
        let total: f64 = g.matmul_ops().map(|o| e.matmul_cost(o).latency).sum();
        assert!(total > 0.05 && total < 0.3, "ttft {total}");
    }

    #[test]
    fn latency_monotone() {
        let e = engine();
        forall(
            7,
            40,
            Triple(UsizeIn(1, 512), UsizeIn(64, 4096), UsizeIn(64, 4096)),
            |(m, k, n)| {
                let a = e.matmul_cost(&gemm(*m, *k, *n)).latency;
                let b = e.matmul_cost(&gemm(m + 8, *k, *n)).latency;
                let c = e.matmul_cost(&gemm(*m, k + 128, *n)).latency;
                a <= b + 1e-15 && a <= c + 1e-15
            },
        );
    }

    #[test]
    fn energy_components_positive_and_sum() {
        let e = engine();
        let c = e.matmul_cost(&gemm(256, 4096, 4096));
        assert!(c.e_dram > 0.0 && c.e_compute > 0.0 && c.e_write > 0.0 && c.e_buffer > 0.0);
        let sum = c.e_dram + c.e_compute + c.e_buffer + c.e_write;
        assert!((sum / c.energy - 1.0).abs() < 1e-9);
    }

    #[test]
    fn adc_energy_per_mac_in_band() {
        // ~0.18 pJ/MAC for HALO1 (ADC-dominated analog compute)
        let e = engine();
        let op = gemm(2048, 4096, 4096);
        let c = e.matmul_cost(&op);
        let per_mac = c.e_compute / op.macs() as f64;
        assert!(per_mac > 0.1e-12 && per_mac < 0.3e-12, "{per_mac:e}");
    }
}

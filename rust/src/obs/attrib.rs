//! Per-request latency attribution: where did the time go?
//!
//! Decomposes every served request's TTFT and end-to-end latency into
//! pipeline components using the [`Recorder`] span timelines that
//! `halo trace` already records:
//!
//! - **queue_wait** — arrival until the first prefill span starts;
//! - **prefill** — prefill/chunk span time, net of throttle stall;
//! - **throttle_stall** — extra service time the thermal governor
//!   added during this request's attributable spans;
//! - **recompute** — re-prefill of evicted KV (resume path), net of
//!   any stall beyond the prefill spans';
//! - **kv_handoff** — interconnect KV-transfer time (disaggregated
//!   serving), which lands *after* the first token, so e2e only;
//! - **first_token_gap** / **decode** — signed closure terms: chunk
//!   scheduling gaps and handoff wait for TTFT; batched decode-step
//!   time (never attributable to one arrival — decode spans serve the
//!   whole batch) plus inter-cycle waits for e2e.
//!
//! The closure terms are computed so the component folds are **bit
//! exact**: folding the TTFT components (in
//! [`Attribution::ttft_components`] order) from 0.0 reproduces the
//! recorded `ttft` to the last bit, and likewise for e2e — pinned by
//! [`reconcile`] and enforced in CI. That guarantee is what lets the
//! aggregated "where does p99 come from" table claim every second it
//! prints is a second the simulator actually charged.

use std::collections::HashMap;

use super::span::{EventKind, Recorder, Span, SpanKind};
use crate::sim::queueing::ServedRequest;

/// One request's latency decomposition. All components are simulated
/// seconds; see the module docs for what each covers.
#[derive(Debug, Clone, Copy)]
pub struct Attribution {
    pub arrival: f64,
    /// Recorded TTFT — bit-exactly the fold of [`Self::ttft_components`].
    pub ttft: f64,
    /// Recorded e2e — bit-exactly the fold of [`Self::e2e_components`].
    pub e2e: f64,
    pub queue_wait: f64,
    pub prefill: f64,
    pub throttle_stall: f64,
    pub recompute: f64,
    pub kv_handoff: f64,
    pub first_token_gap: f64,
    pub decode: f64,
}

impl Attribution {
    /// TTFT components in canonical fold order.
    pub fn ttft_components(&self) -> [(&'static str, f64); 4] {
        [
            ("queue_wait", self.queue_wait),
            ("prefill", self.prefill),
            ("throttle_stall", self.throttle_stall),
            ("first_token_gap", self.first_token_gap),
        ]
    }

    /// End-to-end components in canonical fold order.
    pub fn e2e_components(&self) -> [(&'static str, f64); 6] {
        [
            ("queue_wait", self.queue_wait),
            ("prefill", self.prefill),
            ("throttle_stall", self.throttle_stall),
            ("recompute", self.recompute),
            ("kv_handoff", self.kv_handoff),
            ("decode", self.decode),
        ]
    }
}

/// The closure term `r` such that folding `parts` then `r` from 0.0
/// reproduces `total` bit-exactly. A plain `total - partial` residual
/// is not enough in f64 (the final add can round); the correction loop
/// walks `r` until the fold lands on `total`'s exact bits. Shared with
/// `obs::critpath`, whose per-request closure segment uses the same
/// discipline.
pub(crate) fn residual(total: f64, parts: &[f64]) -> f64 {
    let partial: f64 = parts.iter().sum();
    let mut r = total - partial;
    for _ in 0..8 {
        let s = partial + r;
        if s.to_bits() == total.to_bits() {
            break;
        }
        r += total - s;
    }
    r
}

/// Attribute every request in `served` against the fleet's recorded
/// span timelines (`recorders`, device order) and the interconnect's
/// KV-transfer spans. Requests are joined to spans by exact arrival
/// time (arrivals are unique within a stream by construction).
pub fn attribute(
    served: &[ServedRequest],
    recorders: &[&Recorder],
    kv_spans: &[Span],
) -> Vec<Attribution> {
    let idx: HashMap<u64, usize> =
        served.iter().enumerate().map(|(i, r)| (r.arrival.to_bits(), i)).collect();
    let n = served.len();
    let mut prefill = vec![0.0f64; n];
    let mut recompute = vec![0.0f64; n];
    let mut stall = vec![0.0f64; n];
    let mut kv = vec![0.0f64; n];
    let mut first = vec![f64::INFINITY; n];
    for rec in recorders {
        for s in &rec.spans {
            let Some(&i) = idx.get(&s.arrival.to_bits()) else { continue };
            match s.kind {
                SpanKind::Prefill | SpanKind::PrefillChunk => {
                    prefill[i] += s.dur;
                    first[i] = first[i].min(s.start);
                }
                SpanKind::Recompute => recompute[i] += s.dur,
                // decode steps serve the whole batch (arrival -1.0);
                // KV transfers arrive via `kv_spans`
                SpanKind::DecodeStep | SpanKind::KvTransfer => {}
            }
        }
        for e in &rec.events {
            if e.kind == EventKind::Throttle {
                if let Some(&i) = idx.get(&e.arrival.to_bits()) {
                    stall[i] += e.stall_s;
                }
            }
        }
    }
    for s in kv_spans {
        if s.kind == SpanKind::KvTransfer {
            if let Some(&i) = idx.get(&s.arrival.to_bits()) {
                kv[i] += s.dur;
            }
        }
    }
    served
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let queue_wait = if first[i].is_finite() { first[i] - r.arrival } else { 0.0 };
            // span durations already include governor stall; report the
            // stall separately and net it out of the span components
            // (prefill first, any excess out of recompute)
            let from_prefill = stall[i].min(prefill[i]);
            let net_prefill = prefill[i] - from_prefill;
            let net_recompute = (recompute[i] - (stall[i] - from_prefill)).max(0.0);
            let mut a = Attribution {
                arrival: r.arrival,
                ttft: r.ttft,
                e2e: r.e2e,
                queue_wait,
                prefill: net_prefill,
                throttle_stall: stall[i],
                recompute: net_recompute,
                kv_handoff: kv[i],
                first_token_gap: 0.0,
                decode: 0.0,
            };
            a.first_token_gap = residual(a.ttft, &[a.queue_wait, a.prefill, a.throttle_stall]);
            a.decode = residual(
                a.e2e,
                &[a.queue_wait, a.prefill, a.throttle_stall, a.recompute, a.kv_handoff],
            );
            a
        })
        .collect()
}

/// Number of attributions whose component folds do *not* reproduce the
/// recorded TTFT/e2e bit-exactly. Must be 0; CI fails otherwise.
pub fn reconcile(attrs: &[Attribution]) -> usize {
    attrs
        .iter()
        .filter(|a| {
            let t = a.ttft_components().iter().fold(0.0, |acc, c| acc + c.1);
            let e = a.e2e_components().iter().fold(0.0, |acc, c| acc + c.1);
            t.to_bits() != a.ttft.to_bits() || e.to_bits() != a.e2e.to_bits()
        })
        .count()
}

/// One row of the "where does the tail come from" table.
#[derive(Debug, Clone, Copy)]
pub struct BreakdownRow {
    pub component: &'static str,
    /// Mean seconds over the whole population.
    pub mean_s_all: f64,
    /// Mean seconds over the tail (requests at or above the `p`th
    /// e2e percentile).
    pub mean_s_tail: f64,
    /// This component's share of the tail's mean e2e.
    pub tail_share: f64,
}

/// Aggregate attributions into a component breakdown of the e2e tail
/// at percentile `p` (e.g. 99.0 → the slowest 1% of requests). Returns
/// component rows in fold order plus a closing `e2e` total row; empty
/// input yields an empty table.
pub fn tail_breakdown(attrs: &[Attribution], p: f64) -> Vec<BreakdownRow> {
    if attrs.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..attrs.len()).collect();
    order.sort_by(|&a, &b| attrs[a].e2e.partial_cmp(&attrs[b].e2e).unwrap());
    let cut = ((p.clamp(0.0, 100.0) / 100.0) * attrs.len() as f64) as usize;
    let tail: Vec<usize> = order[cut.min(attrs.len() - 1)..].to_vec();
    let mean = |pick: &dyn Fn(&Attribution) -> f64, ids: &[usize]| -> f64 {
        ids.iter().map(|&i| pick(&attrs[i])).sum::<f64>() / ids.len() as f64
    };
    let names = attrs[0].e2e_components().map(|c| c.0);
    let tail_e2e = mean(&|a: &Attribution| a.e2e, &tail).max(1e-12);
    let mut rows: Vec<BreakdownRow> = names
        .iter()
        .enumerate()
        .map(|(k, &component)| {
            let pick = move |a: &Attribution| a.e2e_components()[k].1;
            let all = mean(&pick, &order);
            let t = mean(&pick, &tail);
            BreakdownRow { component, mean_s_all: all, mean_s_tail: t, tail_share: t / tail_e2e }
        })
        .collect();
    rows.push(BreakdownRow {
        component: "e2e",
        mean_s_all: mean(&|a: &Attribution| a.e2e, &order),
        mean_s_tail: mean(&|a: &Attribution| a.e2e, &tail),
        tail_share: 1.0,
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn req(arrival: f64, ttft: f64, e2e: f64) -> ServedRequest {
        ServedRequest { arrival, ttft, e2e, tenant: 0, session: 0, tokens: 4 }
    }

    fn span(kind: SpanKind, start: f64, dur: f64, arrival: f64) -> Span {
        Span { kind, start, dur, arrival, batch: 1 }
    }

    #[test]
    fn components_fold_bit_exactly_onto_recorded_latencies() {
        let served = vec![req(0.0, 2.0, 10.0), req(1.0, 0.7, 3.3)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::PrefillChunk, 0.5, 0.5, 0.0));
        rec.spans.push(span(SpanKind::PrefillChunk, 1.2, 0.5, 0.0));
        rec.spans.push(span(SpanKind::Prefill, 1.1, 0.6, 1.0));
        rec.spans.push(span(SpanKind::DecodeStep, 2.0, 0.3, -1.0));
        let kv = vec![span(SpanKind::KvTransfer, 2.0, 0.25, 0.0)];
        let attrs = attribute(&served, &[&rec], &kv);
        assert_eq!(reconcile(&attrs), 0);
        let a = &attrs[0];
        assert_eq!(a.queue_wait, 0.5);
        assert_eq!(a.prefill, 1.0);
        assert_eq!(a.kv_handoff, 0.25);
        assert!(a.first_token_gap > 0.0, "chunk gap shows up in TTFT closure");
        let b = &attrs[1];
        assert!((b.queue_wait - 0.1).abs() < 1e-12);
        assert_eq!(b.prefill, 0.6);
        assert_eq!(b.kv_handoff, 0.0);
    }

    #[test]
    fn residual_correction_is_bit_exact_on_awkward_floats() {
        let mut rng = Rng::new(11);
        for _ in 0..2000 {
            let parts: Vec<f64> = (0..5).map(|_| rng.f64() * 3.0).collect();
            let total = rng.f64() * 20.0 + 1e-9;
            let r = residual(total, &parts);
            let fold = parts.iter().sum::<f64>() + r;
            assert_eq!(fold.to_bits(), total.to_bits());
        }
    }

    #[test]
    fn empty_inputs_are_safe() {
        let attrs = attribute(&[], &[], &[]);
        assert!(attrs.is_empty());
        assert_eq!(reconcile(&attrs), 0);
        assert!(tail_breakdown(&attrs, 99.0).is_empty());
    }

    #[test]
    fn zero_duration_spans_still_fold_bit_exactly() {
        // a request whose every recorded span has zero duration: the
        // closure terms must absorb everything without losing bits
        let served = vec![req(0.0, 0.3, 1.1)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::PrefillChunk, 0.1, 0.0, 0.0));
        rec.spans.push(span(SpanKind::PrefillChunk, 0.2, 0.0, 0.0));
        rec.spans.push(span(SpanKind::Recompute, 0.4, 0.0, 0.0));
        let kv = vec![span(SpanKind::KvTransfer, 0.3, 0.0, 0.0)];
        let attrs = attribute(&served, &[&rec], &kv);
        assert_eq!(reconcile(&attrs), 0);
        let a = &attrs[0];
        assert_eq!(a.prefill, 0.0);
        assert_eq!(a.recompute, 0.0);
        assert_eq!(a.kv_handoff, 0.0);
        assert!((a.queue_wait - 0.1).abs() < 1e-15);
    }

    #[test]
    fn single_token_decode_ttft_equals_e2e() {
        // one output token: the request finishes at its first token, so
        // ttft == e2e and the decode closure must land on exactly 0-ish
        // while both folds stay bit-exact
        let e2e = 0.7 + 1e-13; // awkward float on purpose
        let served = vec![req(2.0, e2e, e2e)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::Prefill, 2.25, 0.4, 2.0));
        let attrs = attribute(&served, &[&rec], &[]);
        assert_eq!(reconcile(&attrs), 0);
        let a = &attrs[0];
        assert_eq!(a.ttft.to_bits(), a.e2e.to_bits());
        // decode closure equals the ttft closure residual re-derived
        // against the same parts (recompute/kv are zero)
        let t = a.ttft_components().iter().fold(0.0, |acc, c| acc + c.1);
        let e = a.e2e_components().iter().fold(0.0, |acc, c| acc + c.1);
        assert_eq!(t.to_bits(), e.to_bits());
    }

    #[test]
    fn all_queue_wait_request_folds_bit_exactly() {
        // no spans joined at all: the entire e2e is queue wait from the
        // attribution's point of view, carried by the closure terms
        let served = vec![req(5.0, 1.9, 4.2)];
        let attrs = attribute(&served, &[&Recorder::new()], &[]);
        assert_eq!(reconcile(&attrs), 0);
        let a = &attrs[0];
        assert_eq!(a.queue_wait, 0.0, "no first span => queue_wait falls to closure");
        assert_eq!(a.prefill, 0.0);
        assert_eq!(a.first_token_gap.to_bits(), a.ttft.to_bits());
        assert_eq!(a.decode.to_bits(), a.e2e.to_bits());
    }

    #[test]
    fn residual_handles_zero_and_identical_totals() {
        assert_eq!(residual(0.0, &[]).to_bits(), 0.0f64.to_bits());
        let r = residual(1.5, &[1.5]);
        assert_eq!((1.5 + r).to_bits(), 1.5f64.to_bits());
        // parts summing past the total drive a negative closure
        let r2 = residual(1.0, &[0.9, 0.4]);
        let fold = 0.9 + 0.4 + r2;
        assert_eq!(fold.to_bits(), 1.0f64.to_bits());
        assert!(r2 < 0.0);
    }

    #[test]
    fn tail_breakdown_shares_sum_to_one() {
        let served: Vec<ServedRequest> =
            (0..100).map(|k| req(k as f64, 0.1, 1.0 + (k % 10) as f64)).collect();
        let attrs = attribute(&served, &[], &[]);
        assert_eq!(reconcile(&attrs), 0);
        let rows = tail_breakdown(&attrs, 90.0);
        assert_eq!(rows.last().unwrap().component, "e2e");
        let share: f64 = rows.iter().filter(|r| r.component != "e2e").map(|r| r.tail_share).sum();
        assert!((share - 1.0).abs() < 1e-9, "component shares cover the tail mean: {share}");
        // the tail mean is the slowest decile's mean
        assert!(rows.last().unwrap().mean_s_tail > rows.last().unwrap().mean_s_all);
    }
}

//! Observability-plane integration tests: the zero-overhead guarantee
//! (span recording changes no simulated bit), Chrome-trace export
//! validity + byte determinism per seed, exact reconciliation of the
//! exported timeline against the replay's busy accounting, lifecycle
//! completeness (every served request gets a Done event), the
//! log-histogram's percentile error bound against the exact
//! sort-based path, and bit-compatibility of the cached FleetResult
//! percentile views with the legacy clone-and-sort helpers.

use halo::cluster::{Fleet, FleetResult, Interconnect, Mix, Policy, SchedConfig};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::obs::LogHistogram;
use halo::sim::queueing::{e2e_percentile, ttft_percentile, TraceRequest};
use halo::util::json::Json;
use halo::util::{percentile, Rng};

fn hw() -> HwConfig {
    HwConfig::paper()
}

fn llm() -> LlmConfig {
    LlmConfig::llama2_7b()
}

fn mixed_trace(seed: u64, n: usize) -> Vec<TraceRequest> {
    Mix::Chat.trace(seed, n, 18.0)
}

/// A disaggregated fleet with chunked prefill — exercises every span
/// kind the recorder knows: prefill chunks, KV handoffs, decode steps.
fn build_fleet(obs: bool) -> (Fleet, Box<dyn halo::cluster::Router>) {
    let (mut fleet, router) = Policy::PhaseDisaggregated.build_with(
        &llm(),
        &hw(),
        4,
        8,
        0.5,
        Interconnect::board(),
        SchedConfig::chunked(256),
    );
    if obs {
        fleet.enable_obs();
    }
    (fleet, router)
}

fn replay(obs: bool, seed: u64, n: usize) -> (Fleet, FleetResult) {
    let (mut fleet, mut router) = build_fleet(obs);
    let trace = mixed_trace(seed, n);
    let r = fleet.replay(&trace, router.as_mut());
    (fleet, r)
}

#[test]
fn obs_recording_is_bit_identical_at_fleet_scale() {
    let (_, base) = replay(false, 42, 80);
    let (_, traced) = replay(true, 42, 80);
    assert_eq!(base.served.len(), traced.served.len());
    assert_eq!(base.makespan.to_bits(), traced.makespan.to_bits());
    assert_eq!(base.decode_steps, traced.decode_steps);
    assert_eq!(base.prefills, traced.prefills);
    assert_eq!(base.kv_bytes, traced.kv_bytes);
    for (a, b) in base.served.iter().zip(&traced.served) {
        assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
        assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
    }
    for (da, db) in base.per_device.iter().zip(&traced.per_device) {
        assert_eq!(da.busy.to_bits(), db.busy.to_bits(), "dev{}", da.id);
    }
}

#[test]
fn chrome_trace_is_deterministic_valid_and_reconciles_busy() {
    let (fleet_a, r) = replay(true, 7, 60);
    let (fleet_b, _) = replay(true, 7, 60);
    let doc_a = fleet_a.chrome_trace().expect("obs enabled").to_string();
    let doc_b = fleet_b.chrome_trace().expect("obs enabled").to_string();
    assert_eq!(doc_a, doc_b, "same seed must serialize byte-identically");

    let parsed = Json::parse(&doc_a).expect("exported trace must be valid JSON");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());

    // per-device: the sum of exported slice durations on a device's tid
    // must equal that device's busy seconds (x 1e6 for microseconds),
    // within serializer round-trip noise
    for d in &r.per_device {
        let span_us: f64 = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
                    && e.get("tid").and_then(Json::as_f64) == Some(d.id as f64)
            })
            .filter_map(|e| e.get("dur").and_then(Json::as_f64))
            .sum();
        let busy_us = d.busy * 1e6;
        assert!(
            (span_us - busy_us).abs() <= 1e-6 * busy_us.max(1.0),
            "dev{}: span total {span_us} us vs busy {busy_us} us",
            d.id
        );
        // and the recorder itself reconciles bit-exactly (no serializer)
        let rec = fleet_a.devices[d.id].obs().unwrap();
        assert_eq!(rec.busy_total().to_bits(), d.busy.to_bits(), "dev{}", d.id);
    }

    // the KV interconnect track exists and carries every transfer
    let kv_slices = events
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("kv_transfer"))
        .count();
    assert_eq!(kv_slices as u64, r.transfers);
}

#[test]
fn every_served_request_gets_done_and_queued_events() {
    let (fleet, r) = replay(true, 13, 50);
    let mut done = 0usize;
    let mut queued = 0usize;
    for d in &fleet.devices {
        let rec = d.obs().unwrap();
        done += rec
            .events
            .iter()
            .filter(|e| e.kind == halo::obs::EventKind::Done)
            .count();
        queued += rec
            .events
            .iter()
            .filter(|e| e.kind == halo::obs::EventKind::Queued)
            .count();
    }
    assert_eq!(done, r.served.len());
    // every request is queued at least once (prefill side) and possibly
    // again on its decode device after the KV handoff
    assert!(queued >= r.served.len());
}

#[test]
fn log_histogram_tracks_exact_percentiles_within_bucket_error() {
    let mut rng = Rng::new(99);
    // log-uniform over ~6 decades — the TTFT/latency regime
    let xs: Vec<f64> = (0..20_000).map(|_| 10f64.powf(rng.f64() * 6.0 - 4.0)).collect();
    let mut h = LogHistogram::new();
    for &x in &xs {
        h.record(x);
    }
    assert_eq!(h.count(), xs.len() as u64);
    for p in [1.0, 10.0, 50.0, 90.0, 99.0, 99.9] {
        let exact = percentile(&xs, p);
        let approx = h.percentile(p);
        let rel = (approx - exact).abs() / exact;
        // bucket relative width is 1/32 per octave; allow 2 buckets of
        // slack for order-statistic rounding at the tails
        assert!(rel < 0.08, "p{p}: exact {exact} vs hist {approx} (rel {rel})");
    }
}

#[test]
fn fleet_result_cached_percentiles_match_legacy_helpers_bitwise() {
    let (_, r) = replay(false, 31, 70);
    assert!(!r.served.is_empty());
    for p in [0.0, 5.0, 17.0, 50.0, 83.0, 99.0, 100.0] {
        assert_eq!(
            r.ttft_pct(p).to_bits(),
            ttft_percentile(&r.served, p).to_bits(),
            "ttft p{p}"
        );
        assert_eq!(
            r.e2e_pct(p).to_bits(),
            e2e_percentile(&r.served, p).to_bits(),
            "e2e p{p}"
        );
    }
}

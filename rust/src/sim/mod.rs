//! Simulation engine: walks an operator graph, costs each op on the
//! engine chosen by the mapping, and aggregates phase and end-to-end
//! latency/energy with per-kind and per-component breakdowns. The
//! [`cost`] module memoizes those walks into joint latency/energy
//! [`cost::PhaseCost`] curves for the event-driven planes.
//!
//! Decode steps are costed at the mid-generation context length
//! (`l_in + l_out/2`); every decode cost component is affine in the
//! context length (attention GEMVs and softmax scale linearly, everything
//! else is constant), so the midpoint equals the exact per-step average.

pub mod cost;
pub mod device;
pub mod queueing;
pub mod roofline;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::arch::cid::CidEngine;
use crate::arch::cim::CimEngine;
use crate::arch::logicdie::LogicDieEngine;
use crate::arch::systolic::SystolicEngine;
use crate::arch::{EngineSel, MatmulEngine, OpCost};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig, OpGraph, OpKind, Phase};

/// One evaluation point: input/output context lengths and batch size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Scenario {
    pub l_in: usize,
    pub l_out: usize,
    pub batch: usize,
}

impl Scenario {
    pub fn label(&self) -> String {
        format!("({}, {})", self.l_in, self.l_out)
    }
}

/// Aggregated result of one phase (prefill, or one decode step).
#[derive(Debug, Clone, Default)]
pub struct PhaseResult {
    pub latency: f64,
    pub energy: f64,
    /// Per-op-kind cost.
    pub by_kind: BTreeMap<&'static str, OpCost>,
    /// Per-engine cost.
    pub by_engine: BTreeMap<&'static str, OpCost>,
    /// Latency components across all ops (compute vs memory vs writes).
    pub total: OpCost,
}

impl PhaseResult {
    fn absorb(&mut self, kind: OpKind, engine: EngineSel, cost: OpCost) {
        self.latency += cost.latency;
        self.energy += cost.energy;
        self.by_kind.entry(kind.name()).or_default().add(&cost);
        self.by_engine.entry(engine.name()).or_default().add(&cost);
        self.total.add(&cost);
    }

    /// Fraction of phase latency attributed to DRAM/interconnect
    /// streaming (Fig. 4's "memory access" share).
    pub fn memory_fraction(&self) -> f64 {
        self.total.t_memory / self.latency.max(1e-30)
    }

    pub fn compute_fraction(&self) -> f64 {
        self.total.t_compute / self.latency.max(1e-30)
    }
}

/// End-to-end result: prefill + `l_out` decode steps.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub mapping: MappingKind,
    pub scenario: Scenario,
    pub prefill: PhaseResult,
    /// Cost of the *average* decode step (mid-generation context).
    pub decode_step: PhaseResult,
}

impl RunResult {
    /// Time-to-first-token.
    pub fn ttft(&self) -> f64 {
        self.prefill.latency
    }

    /// Time-per-output-token (average step).
    pub fn tpot(&self) -> f64 {
        self.decode_step.latency
    }

    pub fn decode_latency(&self) -> f64 {
        self.tpot() * self.scenario.l_out as f64
    }

    pub fn e2e_latency(&self) -> f64 {
        self.ttft() + self.decode_latency()
    }

    pub fn decode_energy(&self) -> f64 {
        self.decode_step.energy * self.scenario.l_out as f64
    }

    pub fn e2e_energy(&self) -> f64 {
        self.prefill.energy + self.decode_energy()
    }
}

/// Engines instantiated for one (hw, mapping) pair. The mapping fixes the
/// CiM wordline count (Table II).
pub struct EngineSet {
    pub cid: CidEngine,
    pub cim: CimEngine,
    pub systolic: SystolicEngine,
    pub logic: LogicDieEngine,
}

impl EngineSet {
    pub fn new(hw: &HwConfig, mapping: MappingKind) -> Self {
        let mut hw = hw.clone();
        hw.cim = hw.cim.clone().with_wordlines(mapping.wordlines());
        EngineSet {
            cid: CidEngine::new(&hw),
            cim: CimEngine::new(&hw),
            systolic: SystolicEngine::new(&hw),
            logic: LogicDieEngine::new(&hw),
        }
    }

    pub fn cost(&self, op: &crate::model::Op, engine: EngineSel) -> OpCost {
        match engine {
            EngineSel::Cid => self.cid.matmul_cost(op),
            EngineSel::Cim => self.cim.matmul_cost(op),
            EngineSel::Systolic => self.systolic.matmul_cost(op),
            EngineSel::LogicDie => self.logic.non_gemm_cost(op),
        }
    }
}

/// Process-wide count of [`simulate_graph`] walks, monotonically
/// increasing. Test instrumentation for the one-walk-per-point guarantee
/// of [`cost::CostModel`]; tests running in parallel share it, so assert
/// on deltas being at least (never exactly) the walks you triggered.
static GRAPH_WALKS: AtomicU64 = AtomicU64::new(0);

/// Read the process-wide [`simulate_graph`] walk counter.
pub fn graph_walks() -> u64 {
    GRAPH_WALKS.load(Ordering::Relaxed)
}

/// Cost a whole graph under a mapping.
pub fn simulate_graph(graph: &OpGraph, engines: &EngineSet, mapping: MappingKind) -> PhaseResult {
    GRAPH_WALKS.fetch_add(1, Ordering::Relaxed);
    let mut res = PhaseResult::default();
    for op in &graph.ops {
        let sel = mapping.assign(op, graph.phase);
        let cost = engines.cost(op, sel);
        res.absorb(op.kind, sel, cost);
    }
    res
}

/// Simulate one phase from scratch (convenience).
pub fn simulate_phase(
    llm: &LlmConfig,
    hw: &HwConfig,
    mapping: MappingKind,
    phase: Phase,
    seq: usize,
    batch: usize,
) -> PhaseResult {
    let engines = EngineSet::new(hw, mapping);
    let graph = match phase {
        Phase::Prefill => build_prefill_graph(llm, seq, batch),
        Phase::Decode => build_decode_graph(llm, seq, batch),
    };
    simulate_graph(&graph, &engines, mapping)
}

/// Full end-to-end simulation of a scenario under a mapping.
pub fn simulate_e2e(
    llm: &LlmConfig,
    hw: &HwConfig,
    mapping: MappingKind,
    sc: &Scenario,
) -> RunResult {
    let engines = EngineSet::new(hw, mapping);
    let prefill = simulate_graph(&build_prefill_graph(llm, sc.l_in, sc.batch), &engines, mapping);
    // average decode step: mid-generation context (affine costs => exact)
    let mid_ctx = sc.l_in + sc.l_out / 2;
    let decode_step =
        simulate_graph(&build_decode_graph(llm, mid_ctx.max(1), sc.batch), &engines, mapping);
    RunResult { mapping, scenario: *sc, prefill, decode_step }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::geomean;

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    fn llama() -> LlmConfig {
        LlmConfig::llama2_7b()
    }

    const L_INS: [usize; 5] = [128, 512, 2048, 4096, 8192];

    #[test]
    fn fig5_band_cim_wins_prefill() {
        // paper §V-B: fully-CiM prefill ~6x faster, ~2.6x less energy
        let m = llama();
        let mut speed = Vec::new();
        let mut energy = Vec::new();
        for l_in in L_INS {
            let cid = simulate_phase(&m, &hw(), MappingKind::FullCid, Phase::Prefill, l_in, 1);
            let cim = simulate_phase(&m, &hw(), MappingKind::FullCim, Phase::Prefill, l_in, 1);
            speed.push(cid.latency / cim.latency);
            energy.push(cid.energy / cim.energy);
        }
        let gs = geomean(&speed);
        let ge = geomean(&energy);
        assert!(gs > 3.0 && gs < 12.0, "TTFT speedup geomean {gs} (paper: 6x)");
        assert!(ge > 1.5 && ge < 5.0, "prefill energy ratio geomean {ge} (paper: 2.6x)");
    }

    #[test]
    fn fig6_band_cid_wins_decode() {
        // paper §V-B: fully-CiD decode ~39x faster, ~3.9x less energy
        let m = llama();
        let mut speed = Vec::new();
        let mut energy = Vec::new();
        for l_in in L_INS {
            let ctx = l_in + 64;
            let cid = simulate_phase(&m, &hw(), MappingKind::FullCid, Phase::Decode, ctx, 1);
            let cim = simulate_phase(&m, &hw(), MappingKind::FullCim, Phase::Decode, ctx, 1);
            speed.push(cim.latency / cid.latency);
            energy.push(cim.energy / cid.energy);
        }
        let gs = geomean(&speed);
        let ge = geomean(&energy);
        assert!(gs > 15.0 && gs < 80.0, "TPOT speedup geomean {gs} (paper: 39x)");
        assert!(ge > 2.0 && ge < 8.0, "decode energy ratio geomean {ge} (paper: 3.9x)");
    }

    #[test]
    fn decode_midpoint_is_exact_average() {
        // decode cost must be affine in context length for the midpoint
        // shortcut to hold
        let m = llama();
        let e = EngineSet::new(&hw(), MappingKind::Halo1);
        let at = |ctx: usize| {
            simulate_graph(&build_decode_graph(&m, ctx, 1), &e, MappingKind::Halo1).latency
        };
        let avg_exact = (at(1000) + at(2000)) / 2.0;
        let mid = at(1500);
        assert!((mid / avg_exact - 1.0).abs() < 1e-6, "{mid} vs {avg_exact}");
    }

    #[test]
    fn halo_beats_cent_with_growing_lin() {
        let m = llama();
        let gap = |l_in: usize| {
            let sc = Scenario { l_in, l_out: 512, batch: 1 };
            let cent = simulate_e2e(&m, &hw(), MappingKind::Cent, &sc);
            let halo = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc);
            cent.e2e_latency() / halo.e2e_latency()
        };
        let g_small = gap(128);
        let g_large = gap(8192);
        assert!(g_small >= 0.99, "HALO never loses to CENT: {g_small}");
        assert!(g_large > 2.0, "large-context gap {g_large}");
        assert!(g_large > g_small);
    }

    #[test]
    fn halo_decode_matches_cent_decode() {
        // both run decode on CiD -> same TPOT
        let m = llama();
        let sc = Scenario { l_in: 2048, l_out: 512, batch: 1 };
        let cent = simulate_e2e(&m, &hw(), MappingKind::Cent, &sc);
        let halo = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc);
        assert!((cent.tpot() / halo.tpot() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn attacc_decode_is_much_slower_at_bs1() {
        // paper: HALO1 34x faster decode than AttAcc1 at batch 1
        let m = llama();
        let sc = Scenario { l_in: 2048, l_out: 512, batch: 1 };
        let halo = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc);
        let att = simulate_e2e(&m, &hw(), MappingKind::AttAcc1, &sc);
        let r = att.tpot() / halo.tpot();
        assert!(r > 10.0 && r < 80.0, "decode ratio {r} (paper: 34x)");
    }

    #[test]
    fn halo2_slowdown_is_modest() {
        // paper §V-C: ~10% geomean slowdown for HALO2
        let m = llama();
        let mut ratios = Vec::new();
        for l_in in L_INS {
            for l_out in [128usize, 512, 2048] {
                let sc = Scenario { l_in, l_out, batch: 1 };
                let h1 = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc);
                let h2 = simulate_e2e(&m, &hw(), MappingKind::Halo2, &sc);
                ratios.push(h2.e2e_latency() / h1.e2e_latency());
            }
        }
        let g = geomean(&ratios);
        assert!(g >= 1.0 && g < 1.45, "HALO2/HALO1 geomean {g} (paper: ~1.1)");
    }

    #[test]
    fn qwen_runs_and_orders_like_llama() {
        let m = LlmConfig::qwen3_8b();
        let sc = Scenario { l_in: 2048, l_out: 512, batch: 1 };
        let cent = simulate_e2e(&m, &hw(), MappingKind::Cent, &sc);
        let halo = simulate_e2e(&m, &hw(), MappingKind::Halo1, &sc);
        let att = simulate_e2e(&m, &hw(), MappingKind::AttAcc1, &sc);
        assert!(halo.e2e_latency() < cent.e2e_latency());
        assert!(halo.e2e_latency() < att.e2e_latency());
    }

    #[test]
    fn fig4_breakdown_shapes() {
        // prefill on CiM: compute-dominated; decode on CiM: memory/write
        // dominated (~90% in the paper)
        let m = llama();
        let pre = simulate_phase(&m, &hw(), MappingKind::FullCim, Phase::Prefill, 2048, 1);
        let dec = simulate_phase(&m, &hw(), MappingKind::FullCim, Phase::Decode, 2048, 1);
        assert!(pre.compute_fraction() > 0.5, "prefill compute frac {}", pre.compute_fraction());
        let dec_mem = (dec.total.t_memory + dec.total.t_write) / dec.latency;
        assert!(dec_mem > 0.8, "decode memory+write frac {dec_mem}");
    }

    #[test]
    fn energy_breakdown_sums() {
        let m = llama();
        let r = simulate_e2e(
            &m,
            &hw(),
            MappingKind::Halo1,
            &Scenario { l_in: 512, l_out: 128, batch: 1 },
        );
        for ph in [&r.prefill, &r.decode_step] {
            let sum: f64 = ph.by_kind.values().map(|c| c.energy).sum();
            assert!((sum / ph.energy - 1.0).abs() < 1e-9);
            let sum_eng: f64 = ph.by_engine.values().map(|c| c.energy).sum();
            assert!((sum_eng / ph.energy - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn batch_speedup_monotone_for_attacc() {
        // AttAcc amortizes its decode weight streaming across the batch
        let m = llama();
        let per_seq_tpot = |b: usize| {
            let sc = Scenario { l_in: 128, l_out: 2048, batch: b };
            simulate_e2e(&m, &hw(), MappingKind::AttAcc1, &sc).tpot() / b as f64
        };
        assert!(per_seq_tpot(16) < per_seq_tpot(1));
    }

    #[test]
    fn fig9_crossover_band() {
        // paper Fig. 9: HALO/CENT win at low batch; AttAcc catches up
        // around batch 64
        let m = llama();
        let e2e = |mk: MappingKind, b: usize| {
            simulate_e2e(&m, &hw(), mk, &Scenario { l_in: 128, l_out: 2048, batch: b })
                .e2e_latency()
        };
        assert!(e2e(MappingKind::Halo1, 1) < e2e(MappingKind::AttAcc1, 1) / 4.0);
        let r64 = e2e(MappingKind::AttAcc1, 64) / e2e(MappingKind::Halo1, 64);
        assert!(r64 < 1.3, "AttAcc competitive at batch 64: ratio {r64}");
    }
}

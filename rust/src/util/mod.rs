//! Small self-contained substrates: PRNG, statistics, JSON, byte I/O.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency tree available, so the usual ecosystem crates (serde, rand,
//! proptest, criterion) are reimplemented here at the scale this project
//! needs.

pub mod bench;
pub mod json;
pub mod prop;

use std::fmt::Write as _;

/// xorshift64* PRNG — deterministic, seedable, no external deps.
///
/// Used by the workload generators, the property-test harness and the
/// serving examples. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate parameter (λ).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Geometric mean of positive values (the paper's summary statistic).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let s: f64 = xs.iter().map(|x| {
        assert!(*x > 0.0, "geomean requires positive values, got {x}");
        x.ln()
    }).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// [`percentile`] over an already-ascending slice — same rank and
/// interpolation math, minus the per-call clone-and-sort. Callers that
/// retain a sorted view (e.g. `FleetResult`) read percentiles through
/// this for bit-identical values at O(1) cost.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    assert!(!v.is_empty());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Pretty-print joules with an adaptive unit.
pub fn fmt_joules(e: f64) -> String {
    if e >= 1.0 {
        format!("{e:.3} J")
    } else if e >= 1e-3 {
        format!("{:.3} mJ", e * 1e3)
    } else if e >= 1e-6 {
        format!("{:.3} uJ", e * 1e6)
    } else {
        format!("{:.1} nJ", e * 1e9)
    }
}

/// Render a simple aligned markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", headers.join(" | "));
    let _ = writeln!(out, "|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        let _ = writeln!(out, "| {} |", r.join(" | "));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic_and_nontrivial() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }

    #[test]
    fn rng_f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..5000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|x| (0.0..1.0).contains(x)));
        let m = mean(&xs);
        assert!((m - 0.5).abs() < 0.03, "mean {m}");
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        assert!(mean(&xs).abs() < 0.05);
        assert!((stddev(&xs) - 1.0).abs() < 0.05);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_sorted_matches_percentile_bitwise() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..257).map(|_| r.f64() * 10.0).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 12.5, 50.0, 83.0, 99.0, 100.0] {
            assert_eq!(percentile_sorted(&sorted, p).to_bits(), percentile(&xs, p).to_bits());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_seconds(2.5), "2.500 s");
        assert_eq!(fmt_seconds(2.5e-3), "2.500 ms");
        assert_eq!(fmt_joules(0.0021), "2.100 mJ");
    }
}

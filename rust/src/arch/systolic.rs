//! Digital systolic-array cost model (HALO-SA, §V-D / Fig. 10).
//!
//! Replaces each core's analog CiM complement with weight-stationary
//! digital systolic arrays at iso-area. Area calibration (DESIGN.md §6):
//! an 8-bit MAC PE at 7 nm is far larger than an 8T cell column + shared
//! SAR ADC slice, so the iso-area budget buys `sa_per_core` arrays of
//! `sa_dim^2` PEs (default 2x 32x32 per core vs 8x 128x128 crossbars).
//!
//! The execution model mirrors the CiM rounds: weights stream
//! HBM -> GB -> WB, get loaded into the array (row per cycle), then M
//! input vectors stream through (one per cycle) and the pipeline drains.
//! Unlike the analog macro there is no bit-serial ADC serialization, but
//! each tile pass pays fill+drain bubbles of 2*sa_dim cycles — at small M
//! (short prompts) utilization collapses, which is where Fig. 10 shows
//! CiM pulling ahead.

use super::{MatmulEngine, OpCost};
use crate::config::HwConfig;
use crate::model::Op;

#[derive(Debug, Clone)]
pub struct SystolicEngine {
    hw: HwConfig,
}

impl SystolicEngine {
    pub fn new(hw: &HwConfig) -> Self {
        SystolicEngine { hw: hw.clone() }
    }

    pub fn total_arrays(&self) -> usize {
        self.hw.cim.cores() * self.hw.systolic.sa_per_core
    }

    pub fn tiles_each(&self, op: &Op) -> usize {
        let d = self.hw.systolic.sa_dim;
        op.k.div_ceil(d) * op.n.div_ceil(d)
    }

    pub fn rounds(&self, op: &Op) -> usize {
        (self.tiles_each(op) * op.count).div_ceil(self.total_arrays())
    }
}

impl MatmulEngine for SystolicEngine {
    fn matmul_cost(&self, op: &Op) -> OpCost {
        let sa = &self.hw.systolic;
        let cim = &self.hw.cim; // shared buffers/interposer path
        let hbm = &self.hw.hbm;
        let ip = &self.hw.interposer;
        let d = sa.sa_dim;

        let total_tiles = self.tiles_each(op) * op.count;
        let rounds = self.rounds(op) as f64;
        let tile_bytes = (d * d) as f64;
        let weight_bytes = total_tiles as f64 * tile_bytes;
        let macs = op.macs() as f64;
        let in_bytes = (op.input_bytes_each(1) * op.count as u64) as f64;
        let out_bytes = (op.output_bytes_each() * op.count as u64) as f64;

        let tiles_per_round = (total_tiles as f64 / rounds).ceil();
        let t_fill = tiles_per_round * tile_bytes / cim.gb_bw;
        // weight load into the PE array: one row per cycle (overlappable
        // with the previous tile's drain in optimized schedules — modeled
        // as part of the per-pass bubble below)
        let t_load = d as f64 / sa.freq;
        // stream M inputs + fill/drain bubbles of 2*d cycles per pass
        let t_compute = (op.m as f64 + 2.0 * d as f64) / sa.freq;

        let round_latency = t_fill.max(t_load + t_compute);
        let latency = rounds * round_latency;

        let e_dram = (weight_bytes + in_bytes) * (hbm.e_bank_read + hbm.e_io_read + ip.e_link)
            + out_bytes * ip.e_link;
        let e_compute = macs * sa.e_mac;
        let e_buffer = (weight_bytes + in_bytes * rounds.min(8.0)) * cim.e_buf
            + macs / d as f64 * 8.0 * cim.e_acc;

        OpCost {
            latency,
            energy: e_dram + e_compute + e_buffer,
            t_compute: if t_load + t_compute >= t_fill { latency } else { 0.0 },
            t_memory: if t_fill > t_load + t_compute { latency } else { 0.0 },
            t_write: 0.0,
            e_dram,
            e_compute,
            e_buffer,
            e_write: 0.0,
        }
    }

    fn peak_macs(&self) -> f64 {
        let sa = &self.hw.systolic;
        self.total_arrays() as f64 * (sa.sa_dim * sa.sa_dim) as f64 * sa.freq
    }

    fn stream_bw(&self) -> f64 {
        self.hw.cim.gb_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::cim::CimEngine;
    use crate::model::{OpClass, OpKind, Operand};

    fn engine() -> SystolicEngine {
        SystolicEngine::new(&HwConfig::paper())
    }

    fn gemm(m: usize, k: usize, n: usize) -> Op {
        Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, m, k, n, 1)
    }

    #[test]
    fn geometry() {
        let e = engine();
        assert_eq!(e.total_arrays(), 128);
        assert_eq!(e.tiles_each(&gemm(1, 4096, 4096)), 128 * 128);
    }

    #[test]
    fn peak_comparable_to_cim_but_lower(){
        // iso-area calibration: SA peak below the analog peak, but close
        // enough that fill/drain bubbles (not raw rate) decide Fig. 10
        let hw = HwConfig::paper();
        let sa = engine();
        let cim = CimEngine::new(&hw);
        let r = cim.peak_macs() / sa.peak_macs();
        assert!(r > 1.2 && r < 2.4, "cim/sa peak {r}");
    }

    #[test]
    fn small_m_utilization_collapses() {
        let e = engine();
        let big = gemm(2048, 4096, 4096);
        let small = gemm(64, 4096, 4096);
        let eff_big = big.macs() as f64 / e.matmul_cost(&big).latency;
        let eff_small = small.macs() as f64 / e.matmul_cost(&small).latency;
        // fill/drain bubbles kill short-prompt utilization
        assert!(eff_small < 0.5 * eff_big, "{eff_small:e} vs {eff_big:e}");
    }

    #[test]
    fn cim_beats_sa_at_scale_modestly() {
        // the Fig. 10 band: HALO-CiM1 ~1.2-1.4x faster geomean
        let hw = HwConfig::paper();
        let sa = engine();
        let cim = CimEngine::new(&hw);
        let op = gemm(512, 4096, 11008);
        let r = sa.matmul_cost(&op).latency / cim.matmul_cost(&op).latency;
        assert!(r > 1.0 && r < 2.5, "sa/cim {r}");
    }

    #[test]
    fn energy_positive() {
        let c = engine().matmul_cost(&gemm(128, 1024, 1024));
        assert!(c.energy > 0.0 && c.e_compute > 0.0 && c.e_dram > 0.0);
    }
}

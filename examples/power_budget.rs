//! The power plane end to end: compare the §V-B architectural extremes
//! on mixed-workload energy per token, sweep a package TDP cap to watch
//! the thermal throttle trade throughput for power, then run an
//! energy-objective DSE search over the `power` space.
//!
//!     cargo run --release --example power_budget

use halo::cluster::{FleetBuilder, Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::dse::{explore, DseConfig, Exhaustive, Objective, SearchSpace};
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::power::{DvfsConfig, ThermalConfig};
use halo::report::dse::frontier_table;
use halo::util::fmt_joules;

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();
    let trace = Mix::Interactive.trace(61, 64, 12.0);
    let tokens: u64 = trace.iter().map(|q| q.l_out as u64).sum();

    println!("== energy per token: Fully-CiD vs Fully-CiM vs HALO1 ==");
    for mapping in [MappingKind::FullCid, MappingKind::FullCim, MappingKind::Halo1] {
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .heterogeneous(&[mapping])
            .slots(8)
            .interconnect(Interconnect::board())
            .power(None)
            .build();
        let mut router = Policy::LeastLoaded.router();
        let r = fleet.replay(&trace, router.as_mut());
        println!(
            "  {:>9}: {}/token  ({:.0} W avg, {:.0} W peak)",
            mapping.name(),
            fmt_joules(r.energy_per_token(tokens)),
            r.avg_power_w(),
            r.peak_power_w
        );
    }

    println!("\n== TDP sweep on one HALO1 device (saturating burst) ==");
    let burst = Mix::Generation.trace(63, 48, 1.0e6);
    for cap in [None, Some(150.0), Some(100.0), Some(60.0)] {
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(1)
            .slots(8)
            .interconnect(Interconnect::board())
            .power(cap.map(ThermalConfig::paper))
            .build();
        let mut router = Policy::LeastLoaded.router();
        let r = fleet.replay(&burst, router.as_mut());
        println!(
            "  tdp {:>5}: {:6.3} req/s  avg {:5.1} W  throttled {:6.2} s",
            cap.map_or("inf".to_string(), |w| format!("{w:.0}W")),
            r.throughput_rps(),
            r.avg_power_w(),
            r.throttled_s
        );
    }

    println!("\n== per-phase DVFS on one HALO1 device (generation burst) ==");
    let gen = Mix::Generation.trace(65, 32, 1.0e6);
    let gen_tokens: u64 = gen.iter().map(|q| q.l_out as u64).sum();
    let eco = hw.power.dvfs_points.len() - 1;
    for (label, pre, dec) in [("nominal", 0, 0), ("eco-decode", 0, eco), ("eco", eco, eco)] {
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(1)
            .slots(8)
            .interconnect(Interconnect::board())
            .power(None)
            .dvfs(DvfsConfig::with_indices(&hw.power, pre, dec))
            .build();
        let mut router = Policy::LeastLoaded.router();
        let r = fleet.replay(&gen, router.as_mut());
        println!(
            "  {label:>10}: {}/token  {:5.1} W avg  {:5.1} W peak  {:6.1} tok/s",
            fmt_joules(r.energy_per_token(gen_tokens)),
            r.avg_power_w(),
            r.peak_power_w,
            gen_tokens as f64 / r.makespan.max(1e-12)
        );
    }

    println!("\n== energy-objective DSE over the `power` space ==");
    let mut cfg = DseConfig::new(llm, Mix::Interactive);
    cfg.requests = 48;
    cfg.seed = 67;
    cfg.objectives =
        vec![Objective::EnergyPerToken, Objective::Throughput, Objective::PeakPower];
    let res = explore(&SearchSpace::power(), &mut Exhaustive, &cfg);
    let table = frontier_table(
        &res,
        "power_frontier",
        &format!("Energy/throughput/peak-power frontier ({:.2} req/s offered)", res.rate),
    );
    println!("{}", table.to_markdown());
}

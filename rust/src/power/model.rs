//! Thin energy view over the joint cost oracle.
//!
//! The energy plane used to keep its own memoized curves here — an
//! `EnergyModel` walking `simulate_graph` in parallel with the latency
//! `CostModel` and held consistent only by a 5% cross-plane agreement
//! test. Both now read off one [`sim::cost::CostModel`](crate::sim::cost)
//! walk per distinct point: [`EnergyModel`] is a view that projects the
//! energy half of each [`PhaseCost`](crate::sim::cost::PhaseCost) and
//! carries the one term a graph walk cannot see — the static floor (HBM
//! refresh + package leakage) integrated over wall-clock time, with the
//! refresh share doubling when the co-packaged stacks run hot (see
//! [`super::thermal`]). Interconnect KV-transfer energy is charged by
//! the fleet per transferred byte, also outside the walk.

use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::sim::cost::CostModel;

pub use crate::sim::cost::EnergyBreakdown;

/// Energy projection of the joint cost curves for one (model, hardware,
/// mapping) triple, plus the package static-power floor. Every joule it
/// reports comes from the same `simulate_graph` walk the latency plane
/// uses — the planes agree by construction.
pub struct EnergyModel {
    cost: CostModel,
    /// Static floor at normal / hot-refresh DRAM temperature, W.
    static_cold_w: f64,
    static_hot_w: f64,
}

impl EnergyModel {
    pub fn new(llm: &LlmConfig, hw: &HwConfig, mapping: MappingKind) -> Self {
        EnergyModel {
            cost: CostModel::new(llm, hw, mapping),
            static_cold_w: hw.power.static_w(hw.hbm.stacks, false),
            static_hot_w: hw.power.static_w(hw.hbm.stacks, true),
        }
    }

    /// Background power floor, W (`hot_refresh` doubles the DRAM refresh
    /// share — the 2.5D coupling penalty when the stacks run hot).
    pub fn static_power(&self, hot_refresh: bool) -> f64 {
        if hot_refresh {
            self.static_hot_w
        } else {
            self.static_cold_w
        }
    }

    /// `simulate_graph` walks performed by the underlying joint oracle.
    pub fn walks(&self) -> u64 {
        self.cost.walks()
    }

    /// Dynamic energy of a monolithic prefill of `l_in` tokens (batch 1).
    /// Identical to the arch plane's prefill-graph energy by construction.
    pub fn prefill(&mut self, l_in: usize) -> EnergyBreakdown {
        self.cost.prefill(l_in).energy
    }

    /// Dynamic energy of prefilling `chunk` new tokens over `offset`
    /// cached ones (see [`CostModel::prefill_chunk`]).
    pub fn prefill_chunk(&mut self, offset: usize, chunk: usize) -> EnergyBreakdown {
        self.cost.prefill_chunk(offset, chunk).energy
    }

    /// Dynamic energy of one batched decode step at (batch, context).
    pub fn decode_step(&mut self, batch: usize, ctx: usize) -> EnergyBreakdown {
        self.cost.decode_step(batch, ctx).energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Phase;
    use crate::sim::simulate_phase;

    fn model(mapping: MappingKind) -> EnergyModel {
        EnergyModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), mapping)
    }

    #[test]
    fn prefill_matches_arch_plane_exactly() {
        let mut em = model(MappingKind::Halo1);
        let direct = simulate_phase(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            MappingKind::Halo1,
            Phase::Prefill,
            777,
            1,
        );
        let e = em.prefill(777);
        assert_eq!(e.dynamic(), direct.energy);
        assert_eq!(e.e_link, 0.0);
        assert_eq!(e.e_static, 0.0);
    }

    #[test]
    fn view_is_bit_identical_to_the_joint_oracle() {
        let mut em = model(MappingKind::Halo1);
        let mut cm = CostModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), MappingKind::Halo1);
        assert_eq!(em.prefill(1024), cm.prefill(1024).energy);
        assert_eq!(em.decode_step(3, 700), cm.decode_step(3, 700).energy);
        assert_eq!(em.prefill_chunk(512, 256), cm.prefill_chunk(512, 256).energy);
        // the view performs exactly the oracle's walks, nothing extra
        assert_eq!(em.walks(), cm.walks());
    }

    #[test]
    fn static_floor_hot_doubles_refresh_only() {
        let em = model(MappingKind::Halo1);
        let hw = HwConfig::paper();
        let refresh = hw.power.refresh_w_per_stack * hw.hbm.stacks as f64;
        assert!((em.static_power(true) - em.static_power(false) - refresh).abs() < 1e-12);
    }
}

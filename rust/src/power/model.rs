//! Per-event energy attribution, calibrated against the analytical plane.
//!
//! [`EnergyModel`] is the energy twin of [`sim::device::CostModel`]: the
//! same memoized analytical curves, but returning an [`EnergyBreakdown`]
//! instead of a latency. Every joule it reports comes from the *same*
//! `simulate_graph` walk the arch plane uses — CiD DRAM activation/IO and
//! in-DRAM MACs, CiM DAC/ADC/array and crossbar weight programming,
//! systolic MAC + SRAM, logic-die vector/exponent work — so the
//! event-driven planes (`sim`, `cluster`, `dse`) and the analytical
//! `arch` plane agree on dynamic energy by construction (the cross-plane
//! property test in `tests/power_plane.rs` pins this).
//!
//! On top of the dynamic components the model carries the two terms the
//! per-op costs cannot see: interposer/interconnect link energy for KV
//! handoffs (charged by the fleet per transferred byte) and the static
//! floor — HBM refresh plus package leakage — integrated over wall-clock
//! time (with the refresh share doubling when the co-packaged stacks run
//! hot, see [`super::thermal`]).

use std::collections::BTreeMap;

use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig};
use crate::sim::{simulate_graph, EngineSet, PhaseResult};

/// Energy of one simulated event (or an accumulated total), decomposed
/// into the components the arch plane's [`crate::arch::OpCost`] tracks
/// plus the two plane-level terms (link transfers, static floor).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// DRAM bank/IO activity: CiD weight streaming, HBM reads feeding the
    /// CiM/SA fill pipelines, logic-die activation streaming.
    pub e_dram: f64,
    /// Compute: in-DRAM MACs, ADC conversions + analog array, systolic
    /// MACs, vector/exponent ops.
    pub e_compute: f64,
    /// On-chip buffers and NoC (bank SRAM, GB/IB/WB/OB, accumulators).
    pub e_buffer: f64,
    /// Weight programming: crossbar cell writes (and SA loads).
    pub e_write: f64,
    /// Interposer / fleet-interconnect bytes (KV handoffs).
    pub e_link: f64,
    /// Static floor integrated over time: HBM refresh + leakage.
    pub e_static: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.e_dram + self.e_compute + self.e_buffer + self.e_write + self.e_link + self.e_static
    }

    /// Dynamic (activity-proportional) share: everything but the static
    /// floor and link transfers — what the arch plane's per-op costs sum.
    pub fn dynamic(&self) -> f64 {
        self.e_dram + self.e_compute + self.e_buffer + self.e_write
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.e_dram += o.e_dram;
        self.e_compute += o.e_compute;
        self.e_buffer += o.e_buffer;
        self.e_write += o.e_write;
        self.e_link += o.e_link;
        self.e_static += o.e_static;
    }

    /// `ca * a + cb * b`, componentwise (affine interpolation helper).
    pub fn combine(a: &EnergyBreakdown, ca: f64, b: &EnergyBreakdown, cb: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            e_dram: ca * a.e_dram + cb * b.e_dram,
            e_compute: ca * a.e_compute + cb * b.e_compute,
            e_buffer: ca * a.e_buffer + cb * b.e_buffer,
            e_write: ca * a.e_write + cb * b.e_write,
            e_link: ca * a.e_link + cb * b.e_link,
            e_static: ca * a.e_static + cb * b.e_static,
        }
    }

    fn from_phase(r: &PhaseResult) -> EnergyBreakdown {
        EnergyBreakdown {
            e_dram: r.total.e_dram,
            e_compute: r.total.e_compute,
            e_buffer: r.total.e_buffer,
            e_write: r.total.e_write,
            e_link: 0.0,
            e_static: 0.0,
        }
    }
}

/// Memoized per-event energy curves for one (model, hardware, mapping)
/// triple — the energy twin of `CostModel`: prefill energy per distinct
/// prompt length, decode-step energy as an affine function of context per
/// batch size, plus the package static-power floor.
pub struct EnergyModel {
    llm: LlmConfig,
    mapping: MappingKind,
    engines: EngineSet,
    /// Static floor at normal / hot-refresh DRAM temperature, W.
    static_cold_w: f64,
    static_hot_w: f64,
    prefill_cache: BTreeMap<usize, EnergyBreakdown>,
    dec_coef: BTreeMap<usize, (EnergyBreakdown, EnergyBreakdown)>,
}

impl EnergyModel {
    pub fn new(llm: &LlmConfig, hw: &HwConfig, mapping: MappingKind) -> Self {
        EnergyModel {
            llm: llm.clone(),
            mapping,
            engines: EngineSet::new(hw, mapping),
            static_cold_w: hw.power.static_w(hw.hbm.stacks, false),
            static_hot_w: hw.power.static_w(hw.hbm.stacks, true),
            prefill_cache: BTreeMap::new(),
            dec_coef: BTreeMap::new(),
        }
    }

    /// Background power floor, W (`hot_refresh` doubles the DRAM refresh
    /// share — the 2.5D coupling penalty when the stacks run hot).
    pub fn static_power(&self, hot_refresh: bool) -> f64 {
        if hot_refresh {
            self.static_hot_w
        } else {
            self.static_cold_w
        }
    }

    /// Dynamic energy of a monolithic prefill of `l_in` tokens (batch 1).
    /// Identical to the arch plane's prefill-graph energy by construction.
    pub fn prefill(&mut self, l_in: usize) -> EnergyBreakdown {
        let (llm, engines, mapping) = (&self.llm, &self.engines, self.mapping);
        *self.prefill_cache.entry(l_in).or_insert_with(|| {
            EnergyBreakdown::from_phase(&simulate_graph(
                &build_prefill_graph(llm, l_in, 1),
                engines,
                mapping,
            ))
        })
    }

    /// Dynamic energy of prefilling `chunk` new tokens over `offset`
    /// cached ones: the larger (by total) of the incremental energy
    /// `prefill(offset+chunk) - prefill(offset)` and the fresh-pass floor
    /// `prefill(chunk)` — mirroring `CostModel::prefill_chunk`, because a
    /// chunk still re-streams the full weight set regardless of how much
    /// prefix is cached.
    pub fn prefill_chunk(&mut self, offset: usize, chunk: usize) -> EnergyBreakdown {
        assert!(chunk > 0, "empty prefill chunk");
        if offset == 0 {
            return self.prefill(chunk);
        }
        let whole = self.prefill(offset + chunk);
        let prefix = self.prefill(offset);
        let inc = EnergyBreakdown::combine(&whole, 1.0, &prefix, -1.0);
        let fresh = self.prefill(chunk);
        if inc.total() >= fresh.total() {
            inc
        } else {
            fresh
        }
    }

    /// Dynamic energy of one batched decode step at (batch, context):
    /// affine in ctx — two samples per batch size, interpolated
    /// componentwise (the same two points `CostModel` samples).
    pub fn decode_step(&mut self, batch: usize, ctx: usize) -> EnergyBreakdown {
        let (llm, engines, mapping) = (&self.llm, &self.engines, self.mapping);
        let (base, slope) = *self.dec_coef.entry(batch).or_insert_with(|| {
            let b1 = EnergyBreakdown::from_phase(&simulate_graph(
                &build_decode_graph(llm, 512, batch),
                engines,
                mapping,
            ));
            let b2 = EnergyBreakdown::from_phase(&simulate_graph(
                &build_decode_graph(llm, 1024, batch),
                engines,
                mapping,
            ));
            let slope = EnergyBreakdown::combine(&b2, 1.0 / 512.0, &b1, -1.0 / 512.0);
            let base = EnergyBreakdown::combine(&b1, 1.0, &slope, -512.0);
            (base, slope)
        });
        EnergyBreakdown::combine(&base, 1.0, &slope, ctx.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Phase;
    use crate::sim::simulate_phase;

    fn model(mapping: MappingKind) -> EnergyModel {
        EnergyModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), mapping)
    }

    #[test]
    fn prefill_matches_arch_plane_exactly() {
        let mut em = model(MappingKind::Halo1);
        let direct = simulate_phase(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            MappingKind::Halo1,
            Phase::Prefill,
            777,
            1,
        );
        let e = em.prefill(777);
        assert_eq!(e.dynamic(), direct.energy);
        assert_eq!(e.e_link, 0.0);
        assert_eq!(e.e_static, 0.0);
    }

    #[test]
    fn decode_interpolation_exact_at_sampled_points() {
        let mut em = model(MappingKind::Halo1);
        let direct = simulate_phase(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            MappingKind::Halo1,
            Phase::Decode,
            512,
            3,
        );
        let e = em.decode_step(3, 512).dynamic();
        assert!((e / direct.energy - 1.0).abs() < 1e-12, "{} vs {}", e, direct.energy);
    }

    #[test]
    fn energy_monotone_in_tokens_context_and_batch() {
        let mut em = model(MappingKind::Halo1);
        assert!(em.prefill(256).dynamic() < em.prefill(512).dynamic());
        assert!(em.prefill(512).dynamic() < em.prefill(2048).dynamic());
        assert!(em.decode_step(1, 512).dynamic() <= em.decode_step(1, 2048).dynamic());
        assert!(em.decode_step(1, 512).dynamic() < em.decode_step(8, 512).dynamic());
    }

    #[test]
    fn chunked_prefill_energy_covers_monolithic() {
        let mut em = model(MappingKind::Halo1);
        let total = 2048usize;
        for chunk in [256usize, 512, 1024] {
            let mut sum = 0.0;
            let mut off = 0;
            while off < total {
                let take = chunk.min(total - off);
                sum += em.prefill_chunk(off, take).dynamic();
                off += take;
            }
            let mono = em.prefill(total).dynamic();
            assert!(sum >= mono * (1.0 - 1e-9), "chunk {chunk}: {sum} < {mono}");
            assert!(sum <= mono * 8.0, "chunk {chunk}: {sum} vs {mono}");
        }
    }

    #[test]
    fn halo_prefill_cheaper_than_cid_decode_cheaper_than_cim() {
        // the §V-B energy asymmetry seen through the event model
        let mut cid = model(MappingKind::FullCid);
        let mut cim = model(MappingKind::FullCim);
        assert!(cim.prefill(2048).dynamic() < cid.prefill(2048).dynamic());
        assert!(cid.decode_step(1, 2048).dynamic() < cim.decode_step(1, 2048).dynamic());
    }

    #[test]
    fn combine_is_componentwise_affine() {
        let a = EnergyBreakdown { e_dram: 1.0, e_compute: 2.0, ..Default::default() };
        let b = EnergyBreakdown { e_dram: 3.0, e_static: 4.0, ..Default::default() };
        let c = EnergyBreakdown::combine(&a, 2.0, &b, 0.5);
        assert_eq!(c.e_dram, 3.5);
        assert_eq!(c.e_compute, 4.0);
        assert_eq!(c.e_static, 2.0);
        assert!((c.total() - (3.5 + 4.0 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn static_floor_hot_doubles_refresh_only() {
        let em = model(MappingKind::Halo1);
        let hw = HwConfig::paper();
        let refresh = hw.power.refresh_w_per_stack * hw.hbm.stacks as f64;
        assert!((em.static_power(true) - em.static_power(false) - refresh).abs() < 1e-12);
    }
}

//! Cluster-scale serving study: from one HALO device to a routed fleet.
//!
//! The paper's phase-aware mapping routes prefill to CiM and decode to
//! CiD *inside* one device; this walkthrough applies the same idea
//! *across* devices. It (1) calibrates offered load against a single
//! device's measured capacity, (2) sweeps fleet size at fixed load to
//! show throughput scaling and tail-latency relief, and (3) compares
//! routing policies — including phase-disaggregated prefill/decode pools
//! — across interconnect speeds, showing the win evaporate as the
//! KV-cache transfer gets slower.
//!
//!     cargo run --release --example cluster_scaling

use halo::cluster::{Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::report;
use halo::util::fmt_seconds;

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();

    // 1. calibrate: one monolithic HALO1 device under a burst of the
    //    interactive mix tells us what "saturated" means
    let t1 = report::cluster::single_device_capacity(&hw, &llm, Mix::Interactive, 8);
    println!("single HALO1 device saturates at {t1:.2} req/s on the interactive mix\n");

    // 2. fleet-size sweep at fixed offered load (3x one device's capacity)
    println!("{}", report::cluster::cluster_scaling_at(&hw, t1).to_markdown());

    // 3. routing policies at 8 devices, fast -> slow interconnect
    println!("{}", report::cluster::cluster_policy_comparison_at(&hw, t1).to_markdown());

    // 4. one concrete pairwise read: p99 TTFT, blind round-robin vs
    //    phase-disaggregated pools on a fast link
    let rate = 3.0 * t1;
    let trace = Mix::Interactive.trace(42, 160, rate);
    let mut results = Vec::new();
    for policy in [Policy::RoundRobin, Policy::PhaseDisaggregated] {
        let (mut fleet, mut router) =
            policy.build(&llm, &hw, 8, 8, 0.5, Interconnect::board());
        let r = fleet.replay(&trace, router.as_mut());
        println!(
            "{:>13}: TTFT p99 {:>10}  e2e p99 {:>10}  ({} KV transfers, {:.2} GB)",
            policy.name(),
            fmt_seconds(r.ttft_p99()),
            fmt_seconds(r.e2e_p99()),
            r.transfers,
            r.kv_bytes as f64 / 1e9,
        );
        results.push(r.ttft_p99());
    }
    println!(
        "\nreading: dedicated Fully-CiM prefill devices keep new requests from\n\
         queueing behind decode work — the fleet-level analogue of the paper's\n\
         phase-aware mapping ({}x lower p99 TTFT here); a slow link shifts the\n\
         cost to decode start instead (see the wan row above).",
        (results[0] / results[1].max(1e-12)).round()
    );
}

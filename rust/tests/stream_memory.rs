//! Flat-memory pin for the streamed serving loop: a million-request
//! generator-fed `Fleet::serve` under a retention cap must not grow peak
//! RSS beyond a constant bound over a 10k-request run — RSS tracks the
//! active state (device queues, histograms, retained sample), never the
//! emitted request count.
//!
//! This lives in its own test binary on purpose: `VmHWM` is
//! process-lifetime-monotone, so the baseline and the big run must not
//! share a process with unrelated tests' allocations.

use halo::cluster::router::LeastLoaded;
use halo::cluster::{
    Fleet, FleetBuilder, FleetResult, Interconnect, LengthSampler, Mix, ServeOptions,
    TrafficConfig,
};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::obs::{peak_rss_bytes, WindowSeries};

/// Tiny fixed-band requests: the workload's footprint is dominated by
/// the serving loop, not by any single giant context.
fn config(seed: u64, rate: f64, n: usize) -> TrafficConfig {
    let mut cfg = TrafficConfig::new(seed, rate, 1.0e12, Mix::Chat).with_max_requests(n);
    cfg.prompt = LengthSampler::body_only(16, 64);
    cfg.output = LengthSampler::body_only(4, 16);
    cfg
}

fn fleet() -> Fleet {
    FleetBuilder::new(&LlmConfig::llama2_7b(), &HwConfig::paper())
        .devices(4)
        .slots(8)
        .interconnect(Interconnect::board())
        .build()
}

fn serve_n(seed: u64, rate: f64, n: usize) -> FleetResult {
    let mut gen = config(seed, rate, n).build();
    fleet().serve(&mut gen, &mut LeastLoaded, ServeOptions::streaming(4096))
}

#[test]
fn million_request_stream_runs_in_flat_memory() {
    if peak_rss_bytes().is_none() {
        eprintln!("no /proc/self/status on this platform — skipping the flat-memory pin");
        return;
    }
    // calibrate: saturate briefly and read off the measured capacity,
    // then offer half of it so device backlogs stay bounded and the
    // measurement reflects the streaming loop alone
    let cal = serve_n(97, 1.0e4, 2_000);
    let capacity = cal.throughput_rps();
    assert!(capacity > 0.0);
    let rate = 0.5 * capacity;

    // baseline run: warms the allocator pools and the cost-oracle memo,
    // and sets the high-water mark a 100x larger run must stay near
    let base = serve_n(98, rate, 10_000);
    assert_eq!(base.requests, 10_000);
    let rss_before = peak_rss_bytes().unwrap();

    let big = serve_n(99, rate, 1_000_000);
    assert_eq!(big.requests, 1_000_000);
    assert!(!big.complete, "a capped run must report itself incomplete");
    assert_eq!(big.served.len(), 4096, "raw records are bounded by the retention cap");
    let rss_after = peak_rss_bytes().unwrap();

    // constant bound, NOT proportional to the 100x request ratio. Full
    // retention of 1M served records alone would cost ~48 MB, so 32 MB
    // of slack catches any O(requests) regression while tolerating
    // allocator noise and transient queue depth.
    let growth = rss_after.saturating_sub(rss_before);
    const BOUND: u64 = 32 * 1024 * 1024;
    assert!(
        growth < BOUND,
        "100x more requests grew peak RSS by {:.1} MB (bound {} MB): streaming is not flat",
        growth as f64 / 1e6,
        BOUND / (1024 * 1024)
    );
    // sanity: the big run really did ~100x the work
    assert!(big.tokens > 50 * base.tokens);
    assert_eq!(big.ttft_hist.count(), 1_000_000);

    // the same 1M stream again, now fully monitored: windowed telemetry
    // plus capped span recording. Monitoring must (a) not perturb a
    // single simulated f64 — same fingerprint as the unmonitored run —
    // (b) merge its windowed populations bit-exactly onto the whole-run
    // histograms, and (c) stay inside the same flat-memory envelope
    // (series and recorders are fixed-size by construction).
    let mut series = WindowSeries::new(60.0, 64);
    let mut gen = config(99, rate, 1_000_000).build();
    let mut monitored_fleet = fleet();
    monitored_fleet.enable_obs_capped(4096);
    let mon = monitored_fleet.serve_monitored(
        &mut gen,
        &mut LeastLoaded,
        ServeOptions::streaming(4096),
        &mut series,
    );
    assert_eq!(mon.requests, 1_000_000);
    assert_eq!(mon.fingerprint(), big.fingerprint(), "monitoring must not perturb the serve");
    assert_eq!(series.merged_ttft().counts(), big.ttft_hist.counts());
    assert_eq!(series.merged_e2e().counts(), big.e2e_hist.counts());
    let rss_monitored = peak_rss_bytes().unwrap();
    let growth_mon = rss_monitored.saturating_sub(rss_after);
    assert!(
        growth_mon < BOUND,
        "monitoring a 1M-request stream grew peak RSS by {:.1} MB (bound {} MB)",
        growth_mon as f64 / 1e6,
        BOUND / (1024 * 1024)
    );
}

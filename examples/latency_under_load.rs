//! Interactive-serving study: latency distributions under Poisson load.
//!
//! The paper motivates HALO with latency-sensitive applications but
//! evaluates isolated requests; this example replays arrival traces
//! against the analytical device model with the coordinator's slot-based
//! batching policy, showing how far each mapping can be pushed before
//! TTFT/e2e percentiles blow up.
//!
//!     cargo run --release --example latency_under_load

use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::sim::queueing::{poisson_trace, replay_trace};
use halo::util::fmt_seconds;

fn main() {
    let hw = HwConfig::paper();
    let m = LlmConfig::llama2_7b();
    const SLOTS: usize = 4;
    const N: usize = 120;

    println!(
        "LLaMA-2 7B, {SLOTS} decode slots, {N} requests, prompts 128-2048 tokens, 64 output tokens\n"
    );
    for mapping in [MappingKind::Halo1, MappingKind::Cent, MappingKind::AttAcc1] {
        println!("== {} ==", mapping.name());
        println!(
            "{:>10} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "load req/s", "TTFT p50", "TTFT p99", "e2e p50", "e2e p99", "served/s"
        );
        for rate in [0.5, 2.0, 8.0, 32.0] {
            let trace = poisson_trace(42, N, rate, (128, 2048), 64);
            let r = replay_trace(&m, &hw, mapping, SLOTS, &trace);
            println!(
                "{:>10.1} {:>12} {:>12} {:>12} {:>12} {:>10.2}",
                rate,
                fmt_seconds(r.ttft_p50()),
                fmt_seconds(r.ttft_p99()),
                fmt_seconds(r.e2e_p50()),
                fmt_seconds(r.e2e_p99()),
                r.throughput_rps()
            );
        }
        println!();
    }
    println!(
        "reading: HALO1 sustains interactive TTFT far deeper into the load curve;\n\
         CENT saturates earlier on prefill (CiD GEMM), AttAcc1 on decode (CiM GEMV)."
    );
}

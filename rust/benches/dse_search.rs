//! Microbenchmarks of the DSE plane: candidate evaluation (one fleet
//! replay + scoring), full smoke-grid searches, and hill-climbing over
//! the fleet space — the paths `halo dse` sits on.

use halo::cluster::Mix;
use halo::dse::{explore, DseConfig, Exhaustive, HillClimb, RandomSearch, SearchSpace};
use halo::model::LlmConfig;
use halo::util::bench::{bb, BenchSuite};

fn main() {
    let mut s = BenchSuite::new("dse_search");
    let base = {
        let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
        cfg.requests = 48;
        cfg.rate = Some(15.0); // fixed load: no calibration inside the loop
        cfg
    };

    // one-candidate space = the cost of a single evaluation
    let point = SearchSpace::paper_point();
    s.bench("evaluate_single_candidate", || {
        bb(explore(&point, &mut Exhaustive, &base));
    });

    let smoke = SearchSpace::smoke();
    s.bench_throughput("grid_smoke_space", smoke.len() as f64, || {
        bb(explore(&smoke, &mut Exhaustive, &base));
    });

    let fleet = SearchSpace::fleet();
    s.bench_throughput("random12_fleet_space", 12.0, || {
        bb(explore(&fleet, &mut RandomSearch { samples: 12, seed: 9 }, &base));
    });

    s.bench("hillclimb_fleet_space", || {
        let mut hc = HillClimb { restarts: 1, steps: 6, seed: 5 };
        bb(explore(&fleet, &mut hc, &base));
    });

    s.finish();
}

"""Pallas kernel: functional model of the HALO CiD bank-level GEMV unit.

The CiD units (Fig. 3b) are digital: 32 parallel 8-bit multipliers per
bank read 32 weight bytes per column access, multiply against a broadcast
input held in the 4 KB double-buffered local SRAM, and reduce through an
in-bank adder tree — i.e. an *exact* int8 x int8 -> int32 dot product.

The kernel therefore computes an exact integer GEMV/GEMM; its BlockSpec
mirrors the bank-level blocking (a 128-row contraction block is four
32-lane column accesses). Numerics match :func:`ref.cid_gemv_ref`
bit-exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import quantize_sym_i8

# One contraction block: 4 column accesses x 32 multiplier lanes.
BLOCK_K = 128


def _cid_block_kernel(x_ref, w_ref, o_ref):
    """Exact int8 MAC block with int32 accumulation (in-bank adder tree)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        x,
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _block_dim(size: int, pref: int) -> int:
    return pref if size >= pref else size


def cid_gemv(
    x_i8: jnp.ndarray,
    w_i8: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """Exact int8 GEMV/GEMM (M, K) x (K, N) -> int32 (M, N)."""
    m, k = x_i8.shape
    k2, n = w_i8.shape
    assert k == k2, (k, k2)

    tm = _block_dim(m, block_m)
    tn = _block_dim(n, block_n)
    tk = _block_dim(k, BLOCK_K)
    m_pad, n_pad, k_pad = (-m) % tm, (-n) % tn, (-k) % tk
    # Zero padding is exact for the digital path.
    if m_pad or k_pad:
        x_i8 = jnp.pad(x_i8, ((0, m_pad), (0, k_pad)))
    if n_pad or k_pad:
        w_i8 = jnp.pad(w_i8, ((0, k_pad), (0, n_pad)))
    mp, np_, kp = m + m_pad, n + n_pad, k + k_pad

    out = pl.pallas_call(
        _cid_block_kernel,
        grid=(mp // tm, np_ // tn, kp // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,  # CPU PJRT
    )(x_i8, w_i8)
    return out[:m, :n]


def cid_linear(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Float ``x @ w`` through the exact digital CiD int8 path."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    qx, sx = quantize_sym_i8(x2)
    qw, sw = quantize_sym_i8(w)
    y = cid_gemv(qx, qw).astype(jnp.float32)
    return (y * (sx * sw)).reshape(*lead, w.shape[-1])

//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, greedily shrinks using the generator's `shrink` before
//! panicking with the minimal counterexample.

use super::Rng;
use std::fmt::Debug;

/// A generator of random values plus a shrinking strategy.
pub trait Gen {
    type Value: Clone + Debug;
    fn gen(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; empty when fully shrunk.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Uniform usize in [lo, hi] with halving shrink toward lo.
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn gen(&self, rng: &mut Rng) -> usize {
        rng.range(self.0 as u64, self.1 as u64 + 1) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            let mid = self.0 + (*v - self.0) / 2;
            if mid != *v && mid != self.0 {
                out.push(mid);
            }
            if *v - 1 != mid && *v - 1 >= self.0 {
                out.push(*v - 1);
            }
        }
        out
    }
}

/// Pick uniformly from a fixed slice (shrinks toward the first element).
pub struct OneOf<T: Clone + Debug + 'static>(pub &'static [T]);

impl<T: Clone + Debug + PartialEq + 'static> Gen for OneOf<T> {
    type Value = T;
    fn gen(&self, rng: &mut Rng) -> T {
        self.0[rng.below(self.0.len() as u64) as usize].clone()
    }
    fn shrink(&self, v: &T) -> Vec<T> {
        if self.0.first() != Some(v) {
            vec![self.0[0].clone()]
        } else {
            Vec::new()
        }
    }
}

/// Pair of generators.
pub struct Pair<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn gen(&self, rng: &mut Rng) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Triple of generators.
pub struct Triple<A: Gen, B: Gen, C: Gen>(pub A, pub B, pub C);

impl<A: Gen, B: Gen, C: Gen> Gen for Triple<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);
    fn gen(&self, rng: &mut Rng) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng), self.2.gen(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone(), v.2.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b, v.2.clone())));
        out.extend(self.2.shrink(&v.2).into_iter().map(|c| (v.0.clone(), v.1.clone(), c)));
        out
    }
}

/// Run `prop` over `cases` random inputs; shrink and panic on failure.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.gen(&mut rng);
        if !prop(&v) {
            // greedy shrink
            let mut cur = v;
            'outer: loop {
                for cand in gen.shrink(&cur) {
                    if !prop(&cand) {
                        cur = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!("property failed on case {case}: minimal counterexample {cur:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 200, Pair(UsizeIn(1, 100), UsizeIn(1, 100)), |(a, b)| a + b >= *a.max(b));
    }

    #[test]
    fn shrinks_to_minimal() {
        let res = std::panic::catch_unwind(|| {
            forall(1, 500, UsizeIn(0, 1000), |v| *v < 50);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink should land on exactly 50 (smallest failing value)
        assert!(msg.contains("counterexample 50"), "{msg}");
    }

    #[test]
    fn one_of_generates_members() {
        let g = OneOf(&[2usize, 4, 8]);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            assert!([2, 4, 8].contains(&g.gen(&mut rng)));
        }
    }
}

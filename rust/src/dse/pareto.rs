//! Pareto dominance and frontier extraction over score vectors.
//!
//! Scores are *minimized* coordinates (maximize-direction objectives are
//! negated by [`super::objective::Objective::score`] before they get
//! here). Equal points do not dominate each other, so exact ties all
//! survive onto the frontier — a property the search tests rely on.

/// `a` dominates `b` iff `a` is no worse on every coordinate and
/// strictly better on at least one.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "score arity");
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Indices of the non-dominated points, in input order. O(n^2), which is
/// fine at search scale (hundreds of evaluated candidates).
pub fn pareto_indices(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points.iter().enumerate().any(|(j, p)| j != i && dominates(p, &points[i]))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Pair, UsizeIn};
    use crate::util::Rng;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]), "trade-offs don't dominate");
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equality is not dominance");
    }

    #[test]
    fn frontier_of_known_set() {
        let pts = vec![
            vec![1.0, 5.0], // frontier
            vec![2.0, 4.0], // frontier
            vec![2.0, 5.0], // dominated by both
            vec![5.0, 1.0], // frontier
            vec![5.0, 1.0], // exact duplicate: also kept
        ];
        assert_eq!(pareto_indices(&pts), vec![0, 1, 3, 4]);
    }

    #[test]
    fn frontier_properties_hold_on_random_sets() {
        // for random point clouds: (a) no frontier point is dominated by
        // any other point, (b) every non-frontier point is dominated by
        // some frontier point (completeness)
        forall(17, 60, Pair(UsizeIn(1, 40), UsizeIn(1, 4)), |&(n, dim)| {
            let mut rng = Rng::new((n * 131 + dim) as u64);
            let pts: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..dim).map(|_| (rng.below(6) as f64) * 0.5).collect())
                .collect();
            let front = pareto_indices(&pts);
            if front.is_empty() {
                return false;
            }
            let on_front = |i: usize| front.contains(&i);
            for i in 0..n {
                let dominated = pts.iter().any(|p| dominates(p, &pts[i]));
                if on_front(i) && dominated {
                    return false;
                }
                if !on_front(i)
                    && !front.iter().any(|&j| dominates(&pts[j], &pts[i]))
                {
                    return false;
                }
            }
            true
        });
    }
}

//! Pinned-workload benchmark harness for the simulator's own speed.
//!
//! `halo bench` runs a fixed set of workloads (fixed seeds, fixed
//! absolute request rates — no capacity calibration, so the simulated
//! work is identical on every host) and reports wall time, graph walks
//! and peak RSS. CI stores the resulting `BENCH_sim.json` per commit: a
//! self-profiled performance trajectory of the simulator, with a
//! warn-only compare against the previous baseline.
//!
//! Wall times are host measurements and naturally noisy; the graph-walk
//! counts are exact and must not drift without an intentional change.

use super::jobj;
use crate::cluster::router::{LeastLoaded, PhaseDisaggregated};
use crate::cluster::{
    ArrivalKind, FleetBuilder, Interconnect, LengthSampler, Mix, ServeOptions, TrafficConfig,
};
use crate::config::HwConfig;
use crate::dse::{explore, DseConfig, Exhaustive, SearchSpace};
use crate::mapping::MappingKind;
use crate::model::LlmConfig;
use crate::sim::cost::CostModel;
use crate::sim::device::SchedConfig;
use crate::util::json::Json;
use std::time::Instant;

/// One benchmarked workload: wall-time stats over its iterations plus
/// the deterministic work counters of a single run.
#[derive(Debug, Clone)]
pub struct BenchPoint {
    pub name: &'static str,
    pub iters: usize,
    pub wall_s_mean: f64,
    pub wall_s_p50: f64,
    /// Cost-oracle graph walks of one iteration (exact, host-independent).
    pub graph_walks: u64,
    /// Workload-defined size (requests replayed, points evaluated, ...).
    pub items: u64,
    /// Evaluation worker threads the workload ran with (1 unless the
    /// workload exercises the parallel DSE path).
    pub threads: usize,
}

/// Wall-time delta of one workload against a stored baseline.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub base_s: f64,
    pub new_s: f64,
    /// `(new - base) / base`; positive = slower than the baseline.
    pub delta_frac: f64,
}

fn run_point(
    name: &'static str,
    iters: usize,
    mut f: impl FnMut() -> (u64, u64),
) -> BenchPoint {
    let mut walls: Vec<f64> = Vec::with_capacity(iters);
    let (mut walks, mut items) = (0, 0);
    for _ in 0..iters {
        let t0 = Instant::now();
        let (w, n) = f();
        walls.push(t0.elapsed().as_secs_f64());
        walks = w;
        items = n;
    }
    walls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = walls.iter().sum::<f64>() / walls.len() as f64;
    let p50 = walls[walls.len() / 2];
    BenchPoint {
        name,
        iters,
        wall_s_mean: mean,
        wall_s_p50: p50,
        graph_walks: walks,
        items,
        threads: 1,
    }
}

/// Run the pinned suite. `smoke` trims request counts and iterations so
/// CI finishes in seconds; the workload *shapes* are identical.
pub fn run_pinned(smoke: bool) -> Vec<BenchPoint> {
    let iters = if smoke { 3 } else { 7 };
    let n_req = if smoke { 96 } else { 384 };
    let llm = LlmConfig::llama2_7b();
    let hw = HwConfig::paper();

    let unified = run_point("fleet_replay_unified", iters, || {
        let trace = Mix::Interactive.trace(42, n_req, 24.0);
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(4)
            .slots(8)
            .interconnect(Interconnect::board())
            .build();
        let r = fleet.replay(&trace, &mut LeastLoaded);
        (fleet.cost_walks(), r.served.len() as u64)
    });

    let disagg = run_point("fleet_replay_disagg", iters, || {
        let trace = Mix::Chat.trace(43, n_req, 16.0);
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(4)
            .slots(8)
            .interconnect(Interconnect::board())
            .sched(SchedConfig::chunked(256))
            .disaggregated(0.5)
            .build();
        let r = fleet.replay(&trace, &mut PhaseDisaggregated);
        (fleet.cost_walks(), r.served.len() as u64)
    });

    let oracle = run_point("cost_oracle_sweep", iters, || {
        let mut cm = CostModel::new(&llm, &hw, MappingKind::Halo1);
        let mut points = 0u64;
        for l_in in (64..=4096).step_by(64) {
            std::hint::black_box(cm.prefill(l_in));
            points += 1;
        }
        for batch in 1..=8 {
            for ctx in (256..=4096).step_by(256) {
                std::hint::black_box(cm.decode_step(batch, ctx));
                points += 1;
            }
        }
        (cm.walks(), points)
    });

    let dse = run_point("dse_grid", iters, || {
        let space = SearchSpace::preset("smoke").unwrap();
        let mut cfg = DseConfig::new(llm.clone(), Mix::Interactive);
        cfg.requests = if smoke { 48 } else { 128 };
        cfg.rate = Some(24.0);
        let res = explore(&space, &mut Exhaustive, &cfg);
        (res.profile.count("graph_walks"), res.evaluated.len() as u64)
    });

    // Parallel DSE over the power-space grid (96 points, 11 axes): the
    // same seeded exhaustive search at one and at four evaluation
    // threads. Results are bit-identical by construction (pinned by
    // test), so the pair of rows measures the parallel speedup itself —
    // the `threads` and `wall_per_item_s` fields in the artifact are
    // the perf trajectory of the worker pool.
    let power_req = if smoke { 24 } else { 96 };
    let mut run_power = |name: &'static str, threads: usize| {
        let mut p = run_point(name, iters, || {
            let space = SearchSpace::preset("power").unwrap();
            let mut cfg = DseConfig::new(llm.clone(), Mix::Interactive);
            cfg.requests = power_req;
            cfg.rate = Some(16.0);
            cfg.threads = threads;
            let res = explore(&space, &mut Exhaustive, &cfg);
            (res.profile.count("graph_walks"), res.evaluated.len() as u64)
        });
        p.threads = threads;
        p
    };
    let power_t1 = run_power("dse_power_grid_t1", 1);
    let power_t4 = run_power("dse_power_grid_t4", 4);

    // Streamed serving at scale: a bursty generator feeds Fleet::serve
    // directly (no materialized trace) under a small retention cap, so
    // this point exercises both the traffic engine and the bounded-memory
    // loop. Wall time and the suite-wide `peak_rss_bytes` in the artifact
    // together pin the million-request path. One iteration: the workload
    // is large enough to be its own averaging window.
    let n_stream = if smoke { 10_000 } else { 1_000_000 };
    let stream = run_point("stream_1m", 1, || {
        let mut cfg = TrafficConfig::new(44, 200.0, 1.0e9, Mix::Interactive)
            .with_kind(ArrivalKind::Mmpp)
            .with_max_requests(n_stream);
        // tiny fixed bands: absolute work per request is host-independent
        // and small enough that a million requests replay in seconds
        cfg.prompt = LengthSampler::band(16, 64);
        cfg.output = LengthSampler::band(4, 16);
        let mut gen = cfg.build();
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(4)
            .slots(8)
            .interconnect(Interconnect::board())
            .build();
        let r = fleet.serve(&mut gen, &mut LeastLoaded, ServeOptions::streaming(4096));
        (fleet.cost_walks(), r.requests as u64)
    });

    vec![unified, disagg, oracle, dse, power_t1, power_t4, stream]
}

/// Peak resident set size of this process, bytes (`VmHWM` from
/// `/proc/self/status`); `None` where procfs is unavailable.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Serialize a suite run as the `halo.bench.v1` artifact.
pub fn bench_json(points: &[BenchPoint], smoke: bool) -> Json {
    let workloads: Vec<Json> = points
        .iter()
        .map(|p| {
            jobj(vec![
                ("name", Json::Str(p.name.to_string())),
                ("iters", Json::Num(p.iters as f64)),
                ("wall_s_mean", Json::Num(p.wall_s_mean)),
                ("wall_s_p50", Json::Num(p.wall_s_p50)),
                ("graph_walks", Json::Num(p.graph_walks as f64)),
                ("items", Json::Num(p.items as f64)),
                ("threads", Json::Num(p.threads as f64)),
                ("wall_per_item_s", Json::Num(p.wall_s_p50 / p.items.max(1) as f64)),
            ])
        })
        .collect();
    jobj(vec![
        ("schema", Json::Str("halo.bench.v1".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "peak_rss_bytes",
            peak_rss_bytes().map_or(Json::Null, |b| Json::Num(b as f64)),
        ),
        ("workloads", Json::Arr(workloads)),
    ])
}

/// Compare a fresh `halo.bench.v1` document against a stored baseline by
/// workload name (median wall time). Workloads missing on either side
/// are skipped — the gate only judges common ground.
pub fn compare(new: &Json, base: &Json) -> Vec<BenchDelta> {
    let rows = |doc: &Json| -> Vec<(String, f64)> {
        doc.path(&["workloads"])
            .and_then(Json::as_arr)
            .map(|ws| {
                ws.iter()
                    .filter_map(|w| {
                        let name = w.get("name")?.as_str()?.to_string();
                        let p50 = w.get("wall_s_p50")?.as_f64()?;
                        Some((name, p50))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let base_rows = rows(base);
    rows(new)
        .into_iter()
        .filter_map(|(name, new_s)| {
            let (_, base_s) = base_rows.iter().find(|(b, _)| *b == name)?;
            let delta_frac = if *base_s > 0.0 { (new_s - base_s) / base_s } else { 0.0 };
            Some(BenchDelta { name, base_s: *base_s, new_s, delta_frac })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_matches_by_name_and_signs_deltas() {
        let mk = |p50: f64| {
            bench_json(
                &[BenchPoint {
                    name: "w",
                    iters: 1,
                    wall_s_mean: p50,
                    wall_s_p50: p50,
                    graph_walks: 5,
                    items: 2,
                    threads: 1,
                }],
                true,
            )
        };
        let deltas = compare(&mk(1.2), &mk(1.0));
        assert_eq!(deltas.len(), 1);
        assert!((deltas[0].delta_frac - 0.2).abs() < 1e-9);
        // disjoint workload sets compare to nothing, not a panic
        let other = bench_json(&[], true);
        assert!(compare(&other, &mk(1.0)).is_empty());
    }

    #[test]
    fn bench_artifact_shape() {
        let j = bench_json(&[], true);
        assert_eq!(j.path(&["schema"]).and_then(Json::as_str), Some("halo.bench.v1"));
        assert_eq!(j.path(&["smoke"]), Some(&Json::Bool(true)));
        assert!(j.path(&["workloads"]).and_then(Json::as_arr).is_some());
    }
}

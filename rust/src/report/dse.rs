//! DSE-plane tables: Pareto frontiers per workload mix and the §V-B
//! Fully-CiD / Fully-CiM / HALO tradeoff reproduced as a degenerate
//! 3-point search.

use super::{f, Table};
use crate::cluster::Mix;
use crate::config::HwConfig;
use crate::dse::{explore, DseConfig, DseResult, Exhaustive, Objective, SearchSpace};
use crate::model::LlmConfig;

/// Render a search result's Pareto frontier as a table: one row per
/// frontier point, candidate knobs first, then the raw (un-negated)
/// value of every configured objective.
pub fn frontier_table(res: &DseResult, name: &str, title: &str) -> Table {
    let mut headers: Vec<String> = ["config", "policy", "devices", "chunk", "admission"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    headers.extend(res.objectives.iter().map(|o| o.name().to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(name, title, &hdr_refs);
    for e in res.frontier_points() {
        let mut row = vec![
            e.candidate.label(),
            e.candidate.policy.name().to_string(),
            e.candidate.devices.to_string(),
            e.candidate.chunk.to_string(),
            e.candidate.admission.name().to_string(),
        ];
        row.extend(res.objectives.iter().map(|o| f(o.value(&e.metrics))));
        t.row(row);
    }
    t
}

/// The §V-B architectural-extremes comparison as a 3-point search:
/// Fully-CiD vs Fully-CiM vs phase-aware HALO1 on one device, ranked by
/// median end-to-end latency. The `rank_by_e2e` column is the paper's
/// verdict; `on_frontier` shows which points survive multi-objective
/// dominance.
pub fn vb_extremes_search(hw: &HwConfig) -> Table {
    let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
    cfg.base_hw = hw.clone();
    cfg.requests = 48;
    cfg.seed = 17;
    cfg.rate_scale = 1.5;
    cfg.objectives =
        vec![Objective::E2eP50, Objective::TtftP50, Objective::Throughput];
    let res = explore(&SearchSpace::mapping_extremes(), &mut Exhaustive, &cfg);
    // rank all three points by median e2e
    let mut order: Vec<usize> = (0..res.evaluated.len()).collect();
    order.sort_by(|&a, &b| {
        res.evaluated[a].metrics.e2e_p50.total_cmp(&res.evaluated[b].metrics.e2e_p50)
    });
    let mut t = Table::new(
        "dse_vb_extremes",
        "DSE §V-B extremes — Fully-CiD vs Fully-CiM vs HALO1 as a 3-point search \
         (LLaMA-2 7B, interactive mix, 1 device)",
        &["mapping", "ttft_p50_s", "e2e_p50_s", "served_rps", "on_frontier", "rank_by_e2e"],
    );
    for (i, e) in res.evaluated.iter().enumerate() {
        let rank = order.iter().position(|&j| j == i).unwrap() + 1;
        t.row(vec![
            e.candidate.composition.name(),
            f(e.metrics.ttft_p50),
            f(e.metrics.e2e_p50),
            f(e.metrics.throughput_rps),
            res.frontier.contains(&i).to_string(),
            rank.to_string(),
        ]);
    }
    t
}

/// Pareto frontier of the smoke space on one workload mix — the compact
/// per-mix tradeoff table (`halo report --fig dse` emits chat and
/// summarization; they disagree about chunking, which is the point).
pub fn dse_frontier_for_mix(hw: &HwConfig, mix: Mix) -> Table {
    let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), mix);
    cfg.base_hw = hw.clone();
    cfg.requests = 64;
    cfg.seed = 23;
    cfg.rate_scale = 1.25;
    let res = explore(&SearchSpace::smoke(), &mut Exhaustive, &cfg);
    frontier_table(
        &res,
        &format!("dse_frontier_{}", mix.name()),
        &format!(
            "DSE Pareto frontier — smoke space, {} mix, offered {:.2} req/s",
            mix.name(),
            res.rate
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vb_table_ranks_halo1_first() {
        let t = vb_extremes_search(&HwConfig::paper());
        assert_eq!(t.rows.len(), 3);
        let names: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
        assert!(names.contains(&"HALO1"));
        assert!(names.contains(&"Fully-CiD"));
        assert!(names.contains(&"Fully-CiM"));
        for r in &t.rows {
            if r[0] == "HALO1" {
                assert_eq!(r[5], "1", "HALO1 must rank first by e2e p50");
                assert_eq!(r[4], "true", "HALO1 must sit on the frontier");
            }
        }
    }

    #[test]
    fn mix_frontier_is_nonempty_with_objective_columns() {
        let t = dse_frontier_for_mix(&HwConfig::paper(), Mix::Chat);
        assert!(!t.rows.is_empty());
        // candidate knobs + >= 3 objectives
        assert!(t.headers.len() >= 5 + 3);
        let p50 = t.col_f64("ttft_p50");
        assert!(p50.iter().all(|&v| v > 0.0));
    }
}

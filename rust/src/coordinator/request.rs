//! Request/response types for the serving coordinator.

use std::time::Duration;

/// A generation request (token ids in, token ids out; tokenization is out
/// of scope for the functional plane).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens > 0);
        Request { id, prompt, max_new_tokens }
    }
}

/// A completed generation with its latency metrics.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token (prefill wall-clock).
    pub ttft: Duration,
    /// Mean time per subsequent output token.
    pub tpot: Duration,
    /// Total wall-clock from admission to completion.
    pub total: Duration,
}

impl Response {
    pub fn tokens_per_second(&self) -> f64 {
        self.tokens.len() as f64 / self.total.as_secs_f64().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_throughput() {
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            ttft: Duration::from_millis(10),
            tpot: Duration::from_millis(5),
            total: Duration::from_millis(200),
        };
        assert!((r.tokens_per_second() - 20.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 4);
    }
}

"""L2: LLaMA-style transformer whose GEMMs run through the L1 HALO kernels.

This is the *functional plane* of the reproduction (DESIGN.md §2): a small
LLaMA-architecture model (RMSNorm, RoPE, GQA, SwiGLU) whose weight matmuls
are routed phase-aware exactly like HALO1 maps them:

  * prefill   -> :func:`kernels.cim_linear`  (analog CiM: bit-sliced,
                 bit-streamed, ADC-quantized Pallas kernel)
  * decode    -> :func:`kernels.cid_linear`  (digital CiD: exact int8
                 Pallas kernel)
  * attention score/value products and all non-GEMM ops stay in f32 —
    they run on the CiD units / logic-die vector units, which are digital.

Everything here is build-time only: ``aot.py`` lowers ``prefill`` and
``decode_step`` to HLO text once; the Rust coordinator replays them through
PJRT with Python out of the loop.

Parameters are a *flat list* of arrays (``param_specs`` fixes the order) so
that the lowered HLO's parameter order is self-evident for the Rust side.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from .kernels import cid_gemv
from .kernels.cid_gemv import cid_linear
from .kernels.cim_matmul import cim_linear
from .kernels.ref import CimSpec, MODEL_SPEC

IDEAL_SPEC = CimSpec(ideal=True)


@dataclasses.dataclass(frozen=True)
class TinyLlamaConfig:
    """A ~6M-parameter LLaMA-architecture model (GQA like Qwen3).

    Small enough that the bit-serial CiM kernel (32 planes per matmul)
    stays tractable on the CPU PJRT backend, large enough to exercise every
    structural feature of the paper's workloads: multi-head attention with
    grouped KV heads, RoPE, SwiGLU FFN, KV caching, prefill/decode split.
    """

    vocab: int = 4096
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 768
    max_seq: int = 256
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # GEMM path per phase: "cim" (analog kernel), "cid" (exact int8 kernel)
    # or "f32" (plain jnp; the no-hardware reference).
    prefill_mode: str = "cim"
    decode_mode: str = "cid"
    cim_spec: CimSpec = MODEL_SPEC

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def param_specs(cfg: TinyLlamaConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the HLO parameter order contract."""
    specs: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.wq", (cfg.d_model, cfg.q_dim)),
            (f"l{l}.wk", (cfg.d_model, cfg.kv_dim)),
            (f"l{l}.wv", (cfg.d_model, cfg.kv_dim)),
            (f"l{l}.wo", (cfg.q_dim, cfg.d_model)),
            (f"l{l}.w_gate", (cfg.d_model, cfg.d_ff)),
            (f"l{l}.w_up", (cfg.d_model, cfg.d_ff)),
            (f"l{l}.w_down", (cfg.d_ff, cfg.d_model)),
            (f"l{l}.g_attn", (cfg.d_model,)),
            (f"l{l}.g_ffn", (cfg.d_model,)),
        ]
    specs += [("g_final", (cfg.d_model,)), ("w_lm", (cfg.d_model, cfg.vocab))]
    return specs


def init_params(cfg: TinyLlamaConfig, seed: int = 0) -> list[jnp.ndarray]:
    """Deterministic scaled-gaussian init (shared with the Rust side via
    the exported ``weights.bin``)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".g_attn", ".g_ffn")) or name == "g_final":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            std = 1.0 / math.sqrt(fan_in)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


class _P:
    """Name-addressed view over the flat parameter list."""

    def __init__(self, cfg: TinyLlamaConfig, params):
        names = [n for n, _ in param_specs(cfg)]
        assert len(names) == len(params), (len(names), len(params))
        self._d = dict(zip(names, params))

    def __getitem__(self, k):
        return self._d[k]


def _linear(x, w, mode: str, spec: CimSpec):
    if mode == "cim":
        return cim_linear(x, w, spec)
    if mode == "cid":
        return cid_linear(x, w)
    assert mode == "f32", mode
    return x @ w


def rms_norm(x, g, eps: float):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_angles(cfg: TinyLlamaConfig, positions):
    """positions (...,) int32 -> cos/sin of shape (..., head_dim/2)."""
    half = cfg.head_dim // 2
    inv_freq = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., n_heads, head_dim); cos/sin broadcastable (..., 1, hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_prefill(q, k, v, cfg: TinyLlamaConfig):
    """q (B,L,H,hd), k/v (B,L,KV,hd) -> (B,L,H*hd); causal."""
    b, l, h, hd = q.shape
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("blhd,bmhd->bhlm", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((l, l), bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhlm,bmhd->blhd", att, v)
    return out.reshape(b, l, h * hd)


def _attention_decode(q, k_cache, v_cache, pos, cfg: TinyLlamaConfig):
    """q (B,H,hd); k/v_cache (B,S,KV,hd); pos (B,) current positions.

    Attends to cache slots 0..pos inclusive (the current token's K/V has
    already been written at index pos).
    """
    b, h, hd = q.shape
    s = k_cache.shape[1]
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(k_cache, rep, axis=2)  # (B,S,H,hd)
    v = jnp.repeat(v_cache, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, k) / math.sqrt(hd)
    valid = jnp.arange(s)[None, :] <= pos[:, None]  # (B,S)
    scores = jnp.where(valid[:, None, :], scores, -jnp.inf)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", att, v)
    return out.reshape(b, h * hd)


def _block_prefill(x, p: _P, l: int, cfg: TinyLlamaConfig, mode: str):
    """One decoder block over (B,L,D); returns (x', k, v)."""
    b, L, d = x.shape
    h = rms_norm(x, p[f"l{l}.g_attn"], cfg.rms_eps)
    q = _linear(h, p[f"l{l}.wq"], mode, cfg.cim_spec).reshape(b, L, cfg.n_heads, cfg.head_dim)
    k = _linear(h, p[f"l{l}.wk"], mode, cfg.cim_spec).reshape(b, L, cfg.n_kv_heads, cfg.head_dim)
    v = _linear(h, p[f"l{l}.wv"], mode, cfg.cim_spec).reshape(b, L, cfg.n_kv_heads, cfg.head_dim)
    cos, sin = rope_angles(cfg, jnp.arange(L))
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    att = _attention_prefill(q, k, v, cfg)
    x = x + _linear(att, p[f"l{l}.wo"], mode, cfg.cim_spec)
    hf = rms_norm(x, p[f"l{l}.g_ffn"], cfg.rms_eps)
    gate = _linear(hf, p[f"l{l}.w_gate"], mode, cfg.cim_spec)
    up = _linear(hf, p[f"l{l}.w_up"], mode, cfg.cim_spec)
    x = x + _linear(jax.nn.silu(gate) * up, p[f"l{l}.w_down"], mode, cfg.cim_spec)
    return x, k, v


def prefill(params, tokens, cfg: TinyLlamaConfig):
    """Process a full prompt. tokens (B, L) int32.

    Returns (logits (B, L, vocab), k_cache, v_cache) with caches of shape
    (n_layers, B, max_seq, n_kv_heads, head_dim), filled at positions
    [0, L) and zero elsewhere.
    """
    p = _P(cfg, params)
    mode = cfg.prefill_mode
    b, L = tokens.shape
    x = p["embed"][tokens]  # (B, L, D)
    k_cache = jnp.zeros(
        (cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim), jnp.float32
    )
    v_cache = jnp.zeros_like(k_cache)
    for l in range(cfg.n_layers):
        x, k, v = _block_prefill(x, p, l, cfg, mode)
        k_cache = k_cache.at[l, :, :L].set(k)
        v_cache = v_cache.at[l, :, :L].set(v)
    x = rms_norm(x, p["g_final"], cfg.rms_eps)
    logits = _linear(x, p["w_lm"], mode, cfg.cim_spec)
    return logits, k_cache, v_cache


def decode_step(params, token, pos, k_cache, v_cache, cfg: TinyLlamaConfig):
    """One autoregressive step for a batch of independent slots.

    token (B,) int32 — current input token per slot;
    pos   (B,) int32 — its position (0-based) per slot; the new K/V are
    written at ``pos`` and attention sees slots [0, pos].

    Returns (logits (B, vocab), k_cache', v_cache').
    """
    p = _P(cfg, params)
    mode = cfg.decode_mode
    b = token.shape[0]
    x = p["embed"][token]  # (B, D)
    cos, sin = rope_angles(cfg, pos)  # (B, hd/2)
    cos, sin = cos[:, None, :], sin[:, None, :]
    for l in range(cfg.n_layers):
        h = rms_norm(x, p[f"l{l}.g_attn"], cfg.rms_eps)
        q = _linear(h, p[f"l{l}.wq"], mode, cfg.cim_spec).reshape(b, cfg.n_heads, cfg.head_dim)
        k = _linear(h, p[f"l{l}.wk"], mode, cfg.cim_spec).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        v = _linear(h, p[f"l{l}.wv"], mode, cfg.cim_spec).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        # scatter the new K/V at each slot's own position
        upd = jax.vmap(lambda c, kv, pp: jax.lax.dynamic_update_slice(c, kv[None], (pp, 0, 0)))
        k_cache = k_cache.at[l].set(upd(k_cache[l], k, pos))
        v_cache = v_cache.at[l].set(upd(v_cache[l], v, pos))
        att = _attention_decode(q, k_cache[l], v_cache[l], pos, cfg)
        x = x + _linear(att, p[f"l{l}.wo"], mode, cfg.cim_spec)
        hf = rms_norm(x, p[f"l{l}.g_ffn"], cfg.rms_eps)
        gate = _linear(hf, p[f"l{l}.w_gate"], mode, cfg.cim_spec)
        up = _linear(hf, p[f"l{l}.w_up"], mode, cfg.cim_spec)
        x = x + _linear(jax.nn.silu(gate) * up, p[f"l{l}.w_down"], mode, cfg.cim_spec)
    x = rms_norm(x, p["g_final"], cfg.rms_eps)
    logits = _linear(x, p["w_lm"], mode, cfg.cim_spec)
    return logits, k_cache, v_cache


def reference_config(cfg: TinyLlamaConfig) -> TinyLlamaConfig:
    """The same model with all GEMMs in plain f32 (no hardware model)."""
    return dataclasses.replace(cfg, prefill_mode="f32", decode_mode="f32")


def generate(params, prompt, cfg: TinyLlamaConfig, n_new: int):
    """Greedy generation helper (python-side reference for the Rust loop).

    prompt (B, L) int32. Returns (B, n_new) int32 generated ids.
    """
    logits, kc, vc = prefill(params, prompt, cfg)
    b, L = prompt.shape
    last = jnp.argmax(logits[:, L - 1, :], axis=-1).astype(jnp.int32)
    outs = [last]
    pos = jnp.full((b,), L, jnp.int32)
    for _ in range(n_new - 1):
        lg, kc, vc = decode_step(params, outs[-1], pos, kc, vc, cfg)
        outs.append(jnp.argmax(lg, axis=-1).astype(jnp.int32))
        pos = pos + 1
    return jnp.stack(outs, axis=1)

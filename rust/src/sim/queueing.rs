//! Discrete-event serving simulation on top of the analytical model.
//!
//! The paper motivates HALO with *latency-sensitive interactive
//! applications* (chatbots, assistants) but evaluates isolated requests.
//! This module closes that gap: it replays a Poisson arrival trace
//! against a device whose prefill/decode step times come from the
//! analytical simulator, with the same slot-based continuous batching
//! policy the functional coordinator implements — yielding TTFT/latency
//! distributions and saturation points per mapping.
//!
//! Model: a single HALO device with `slots` decode slots. Prefills are
//! serialized on the accelerator (prefill occupies the whole device —
//! both CiD and CiM mappings are throughput-limited by the same shared
//! substrate); decode steps process all active slots in one batched step
//! whose duration comes from `simulate_phase` at the batch's mean context.

use super::{simulate_graph, EngineSet, Scenario};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig};
use crate::util::{percentile, Rng};

/// One request in the trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub arrival: f64,
    pub l_in: usize,
    pub l_out: usize,
}

/// Generate a Poisson-arrival trace with log-uniform prompt lengths.
pub fn poisson_trace(
    seed: u64,
    n: usize,
    rate_per_s: f64,
    l_in_range: (usize, usize),
    l_out: usize,
) -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let (lo, hi) = l_in_range;
    (0..n)
        .map(|_| {
            t += rng.exp(rate_per_s);
            let u = rng.f64();
            let l_in = (lo as f64 * ((hi as f64 / lo as f64).powf(u))).round() as usize;
            TraceRequest { arrival: t, l_in: l_in.max(1), l_out }
        })
        .collect()
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub arrival: f64,
    pub ttft: f64,
    pub e2e: f64,
}

/// Aggregate results of a trace replay.
#[derive(Debug, Clone)]
pub struct QueueingResult {
    pub served: Vec<ServedRequest>,
    pub makespan: f64,
    pub decode_steps: u64,
}

impl QueueingResult {
    pub fn ttft_p50(&self) -> f64 {
        percentile(&self.ttfts(), 50.0)
    }
    pub fn ttft_p99(&self) -> f64 {
        percentile(&self.ttfts(), 99.0)
    }
    pub fn e2e_p50(&self) -> f64 {
        percentile(&self.e2es(), 50.0)
    }
    pub fn e2e_p99(&self) -> f64 {
        percentile(&self.e2es(), 99.0)
    }
    pub fn throughput_rps(&self) -> f64 {
        self.served.len() as f64 / self.makespan.max(1e-12)
    }
    fn ttfts(&self) -> Vec<f64> {
        self.served.iter().map(|r| r.ttft).collect()
    }
    fn e2es(&self) -> Vec<f64> {
        self.served.iter().map(|r| r.e2e).collect()
    }
}

#[derive(Debug, Clone)]
struct ActiveSeq {
    arrival: f64,
    first_token_at: f64,
    ctx: usize,
    remaining: usize,
}

/// Replay a trace on one device under a mapping.
///
/// Scheduling policy (mirrors `coordinator::Server`): FIFO admission into
/// free slots; an admission runs the request's prefill exclusively; decode
/// proceeds in batched steps over the active slots. Decode-step latency is
/// interpolated from the analytical model at the current batch size and
/// mean context (costs are affine in context, so the mean is exact).
pub fn replay_trace(
    llm: &LlmConfig,
    hw: &HwConfig,
    mapping: MappingKind,
    slots: usize,
    trace: &[TraceRequest],
) -> QueueingResult {
    assert!(slots > 0);
    let engines = EngineSet::new(hw, mapping);
    // memoized prefill latency per distinct l_in, decode step per batch size
    let mut prefill_cache: std::collections::BTreeMap<usize, f64> = Default::default();
    let mut prefill = |l_in: usize| {
        *prefill_cache.entry(l_in).or_insert_with(|| {
            simulate_graph(&build_prefill_graph(llm, l_in, 1), &engines, mapping).latency
        })
    };
    // decode step latency at (batch, ctx): affine in ctx — sample two
    // points per batch size and interpolate
    let mut dec_coef: std::collections::BTreeMap<usize, (f64, f64)> = Default::default();
    let mut decode_step = |batch: usize, ctx: usize| {
        let (a, b) = *dec_coef.entry(batch).or_insert_with(|| {
            let t1 = simulate_graph(&build_decode_graph(llm, 512, batch), &engines, mapping).latency;
            let t2 =
                simulate_graph(&build_decode_graph(llm, 1024, batch), &engines, mapping).latency;
            let slope = (t2 - t1) / 512.0;
            (t1 - slope * 512.0, slope)
        });
        a + b * ctx.max(1) as f64
    };

    let mut queue: std::collections::VecDeque<&TraceRequest> = Default::default();
    let mut pending = trace.iter().peekable();
    let mut active: Vec<Option<ActiveSeq>> = vec![None; slots];
    let mut served = Vec::new();
    let mut now = 0.0f64;
    let mut steps = 0u64;

    loop {
        // pull arrivals up to `now`
        while let Some(r) = pending.peek() {
            if r.arrival <= now {
                queue.push_back(pending.next().unwrap());
            } else {
                break;
            }
        }
        // admit into free slots (prefill serializes the device)
        while let Some(slot) = active.iter().position(Option::is_none) {
            let Some(req) = queue.pop_front() else { break };
            let p = prefill(req.l_in);
            let start = now.max(req.arrival);
            now = start + p;
            active[slot] = Some(ActiveSeq {
                arrival: req.arrival,
                first_token_at: now,
                ctx: req.l_in,
                remaining: req.l_out.saturating_sub(1),
            });
        }

        let batch = active.iter().flatten().count();
        if batch == 0 {
            match pending.peek() {
                Some(r) => {
                    now = now.max(r.arrival);
                    continue;
                }
                None if queue.is_empty() => break,
                None => continue,
            }
        }

        // one batched decode step at the mean active context
        let mean_ctx =
            active.iter().flatten().map(|s| s.ctx).sum::<usize>() / batch;
        now += decode_step(batch, mean_ctx);
        steps += 1;
        for slot in active.iter_mut() {
            if let Some(s) = slot {
                s.ctx += 1;
                if s.remaining == 0 {
                    served.push(ServedRequest {
                        arrival: s.arrival,
                        ttft: s.first_token_at - s.arrival,
                        e2e: now - s.arrival,
                    });
                    *slot = None;
                } else {
                    s.remaining -= 1;
                }
            }
        }
    }

    QueueingResult { served, makespan: now, decode_steps: steps }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llm() -> LlmConfig {
        LlmConfig::llama2_7b()
    }

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    #[test]
    fn poisson_trace_statistics() {
        let tr = poisson_trace(1, 2000, 10.0, (64, 1024), 128);
        assert_eq!(tr.len(), 2000);
        // arrivals are sorted and the mean inter-arrival ~ 1/rate
        assert!(tr.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let mean_gap = tr.last().unwrap().arrival / 2000.0;
        assert!((mean_gap - 0.1).abs() < 0.02, "{mean_gap}");
        assert!(tr.iter().all(|r| (64..=1024).contains(&r.l_in)));
    }

    #[test]
    fn all_requests_served_once() {
        let tr = poisson_trace(2, 50, 5.0, (64, 512), 32);
        let r = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        assert_eq!(r.served.len(), 50);
        assert!(r.decode_steps >= 31, "{}", r.decode_steps);
        for s in &r.served {
            assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
        }
    }

    #[test]
    fn latency_grows_with_load() {
        let slow = |rate: f64| {
            let tr = poisson_trace(3, 60, rate, (128, 2048), 64);
            replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr).ttft_p99()
        };
        let light = slow(0.5);
        let heavy = slow(50.0);
        assert!(heavy > light, "p99 ttft: light {light}, heavy {heavy}");
    }

    #[test]
    fn halo_sustains_more_load_than_attacc() {
        // at a load where HALO is comfortable, AttAcc's slow decode
        // steps blow up end-to-end latency
        let tr = poisson_trace(4, 40, 2.0, (128, 1024), 64);
        let halo = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        let att = replay_trace(&llm(), &hw(), MappingKind::AttAcc1, 4, &tr);
        assert!(att.e2e_p50() > 3.0 * halo.e2e_p50(), "{} vs {}", att.e2e_p50(), halo.e2e_p50());
        assert!(att.makespan > halo.makespan);
    }

    #[test]
    fn throughput_bounded_by_decode_rate() {
        // closed-form sanity: with saturating load, token throughput
        // can't exceed slots / tpot
        let tr = poisson_trace(5, 80, 1000.0, (128, 128), 64);
        let r = replay_trace(&llm(), &hw(), MappingKind::Halo1, 4, &tr);
        let tokens = 80.0 * 64.0;
        let tok_rate = tokens / r.makespan;
        let engines = EngineSet::new(&hw(), MappingKind::Halo1);
        let tpot4 =
            simulate_graph(&build_decode_graph(&llm(), 256, 4), &engines, MappingKind::Halo1)
                .latency;
        assert!(tok_rate <= 4.0 / tpot4 * 1.05, "{tok_rate} vs {}", 4.0 / tpot4);
    }
}

//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and executes
//! them on the CPU PJRT client. Python never runs here — the HLO text was
//! lowered once at build time.
//!
//! * [`Manifest`] — parses `artifacts/manifest.json` (parameter table,
//!   entry-point signatures, test vectors).
//! * [`Weights`] — memory-maps `weights.bin` into per-parameter literals.
//! * [`Runtime`] — compiles entry HLOs (`HloModuleProto::from_text_file`
//!   -> `XlaComputation` -> `PjRtLoadedExecutable`) and runs them, with
//!   model weights uploaded to device buffers **once** and reused across
//!   steps (the request-path hot loop only moves tokens, positions and the
//!   KV cache).

pub mod tensor;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
pub use tensor::{Dtype, HostTensor, TensorSpec};

/// One entry point's signature from the manifest.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub hlo_file: String,
    /// Leading inputs that are model parameters (fed from weights.bin).
    pub n_params: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Test-vector files (non-param inputs, then outputs).
    pub testvec_inputs: Vec<String>,
    pub testvec_outputs: Vec<String>,
}

/// One model parameter's slice of weights.bin.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nelems: usize,
}

/// Parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub params: Vec<ParamSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
    /// Tiny-model config values (vocab, n_layers, max_seq, ...).
    pub config: BTreeMap<String, f64>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j.get("shape").and_then(|s| s.as_usize_vec()).ok_or_else(|| anyhow!("shape"))?;
    let dtype = match j.get("dtype").and_then(|d| d.as_str()) {
        Some("f32") => Dtype::F32,
        Some("i32") => Dtype::I32,
        Some("i8") => Dtype::I8,
        other => bail!("unsupported dtype {other:?}"),
    };
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut params = Vec::new();
        for p in j.get("params").and_then(|p| p.as_arr()).ok_or_else(|| anyhow!("params"))? {
            params.push(ParamSpec {
                name: p.get("name").and_then(|x| x.as_str()).unwrap_or_default().to_string(),
                shape: p
                    .get("shape")
                    .and_then(|x| x.as_usize_vec())
                    .ok_or_else(|| anyhow!("param shape"))?,
                offset: p
                    .get("offset")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("offset"))?,
                nelems: p
                    .get("nelems")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("nelems"))?,
            });
        }

        let mut entries = BTreeMap::new();
        let obj = j.get("entries").and_then(|e| e.as_obj()).ok_or_else(|| anyhow!("entries"))?;
        for (name, e) in obj {
            let inputs = e
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("inputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("outputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let (ti, to) = match e.get("testvec") {
                Some(tv) => (
                    tv.get("inputs")
                        .and_then(|x| x.as_arr())
                        .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                        .unwrap_or_default(),
                    tv.get("outputs")
                        .and_then(|x| x.as_arr())
                        .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                        .unwrap_or_default(),
                ),
                None => (Vec::new(), Vec::new()),
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    hlo_file: e
                        .get("hlo")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("hlo"))?
                        .to_string(),
                    n_params: e.get("n_params").and_then(|x| x.as_usize()).unwrap_or(0),
                    inputs,
                    outputs,
                    testvec_inputs: ti,
                    testvec_outputs: to,
                },
            );
        }

        let mut config = BTreeMap::new();
        if let Some(c) = j.get("config").and_then(|c| c.as_obj()) {
            for (k, v) in c {
                if let Some(n) = v.as_f64() {
                    config.insert(k.clone(), n);
                }
            }
        }

        Ok(Manifest { dir: dir.to_path_buf(), params, entries, config })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries.get(name).ok_or_else(|| anyhow!("no entry point '{name}' in manifest"))
    }

    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.config.get(key).map(|v| *v as usize).ok_or_else(|| anyhow!("no config key {key}"))
    }

    /// Load a test-vector file into a host tensor.
    pub fn load_testvec(&self, file: &str, spec: &TensorSpec) -> Result<HostTensor> {
        let bytes = std::fs::read(self.dir.join("testvec").join(file))?;
        HostTensor::from_bytes(&bytes, spec.clone())
    }
}

/// Model weights loaded from weights.bin as per-parameter host tensors.
#[derive(Debug, Clone)]
pub struct Weights {
    pub tensors: Vec<HostTensor>,
}

impl Weights {
    pub fn load(manifest: &Manifest) -> Result<Weights> {
        let blob = std::fs::read(manifest.dir.join("weights.bin"))
            .context("reading weights.bin (run `make artifacts`)")?;
        let mut tensors = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let start = p.offset;
            let end = start + p.nelems * 4;
            if end > blob.len() {
                bail!("weights.bin too short for {}", p.name);
            }
            tensors.push(HostTensor::from_bytes(
                &blob[start..end],
                TensorSpec { shape: p.shape.clone(), dtype: Dtype::F32 },
            )?);
        }
        Ok(Weights { tensors })
    }
}

/// A compiled entry point.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident parameter buffers (uploaded once).
    param_bufs: Vec<xla::PjRtBuffer>,
}

impl Executable {
    /// Run with the given non-parameter inputs; parameters are the
    /// device-resident buffers. Returns host tensors per output.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.run_ref(&inputs.iter().collect::<Vec<_>>())
    }

    /// Like [`Self::run`] but borrows the inputs (the decode hot loop
    /// passes the multi-MB KV tensors without cloning them).
    pub fn run_ref(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let want = self.spec.inputs.len() - self.spec.n_params;
        if inputs.len() != want {
            bail!("{}: expected {} inputs, got {}", self.spec.name, want, inputs.len());
        }
        let client = self.exe.client();
        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        let in_bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .zip(&self.spec.inputs[self.spec.n_params..])
            .map(|(t, spec)| {
                if t.spec != *spec {
                    bail!("{}: input spec mismatch {:?} vs {:?}", self.spec.name, t.spec, spec)
                } else {
                    t.to_device(client)
                }
            })
            .collect::<Result<Vec<_>>>()?;
        args.extend(in_bufs.iter());

        let out = self.exe.execute_b(&args)?;
        self.collect_outputs(out)
    }

    /// Run with host inputs plus trailing *device-resident* buffers,
    /// returning raw output buffers (no host copies). The serving engine
    /// uses this to keep the KV cache on device across decode steps.
    ///
    /// Requires the untupled-output PJRT patch (third_party/xla); falls
    /// back is the caller's job if a single tuple buffer comes back.
    pub fn run_buffers(
        &self,
        host_inputs: &[&HostTensor],
        trailing: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let want = self.spec.inputs.len() - self.spec.n_params;
        if host_inputs.len() + trailing.len() != want {
            bail!(
                "{}: expected {} inputs, got {}+{}",
                self.spec.name,
                want,
                host_inputs.len(),
                trailing.len()
            );
        }
        let client = self.exe.client();
        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        let in_bufs: Vec<xla::PjRtBuffer> = host_inputs
            .iter()
            .map(|t| t.to_device(client))
            .collect::<Result<Vec<_>>>()?;
        args.extend(in_bufs.iter());
        args.extend(trailing.iter().copied());
        let out = self.exe.execute_b(&args)?;
        out.into_iter().next().ok_or_else(|| anyhow!("no replica output"))
    }

    /// Download one output buffer to the host, checked against the
    /// entry's i-th output signature.
    pub fn download_output(&self, buf: &xla::PjRtBuffer, i: usize) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        HostTensor::from_literal(&lit, self.spec.outputs[i].clone())
    }

    fn collect_outputs(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
        let bufs = out.into_iter().next().ok_or_else(|| anyhow!("no replica output"))?;
        let n_out = self.spec.outputs.len();
        // the AOT path lowers with return_tuple=True, so the single output
        // buffer is a tuple even for one-output entries; decompose via the
        // literal's shape (PJRT may or may not have untupled).
        let mut literals = Vec::new();
        for b in &bufs {
            let lit = b.to_literal_sync()?;
            if lit.shape()?.is_tuple() {
                literals.extend(lit.to_tuple()?);
            } else {
                literals.push(lit);
            }
        }
        if literals.len() != n_out {
            bail!("{}: {} output literals, expected {n_out}", self.spec.name, literals.len());
        }
        literals
            .iter()
            .zip(&self.spec.outputs)
            .map(|(l, spec)| HostTensor::from_literal(l, spec.clone()))
            .collect()
    }
}

/// The PJRT runtime: client + compiled entry points + resident weights.
pub struct Runtime {
    pub manifest: Manifest,
    pub client: xla::PjRtClient,
    weights: Weights,
}

impl Runtime {
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = Weights::load(&manifest)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { manifest, client, weights })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an entry point and upload its parameter buffers.
    pub fn compile(&self, entry: &str) -> Result<Executable> {
        let spec = self.manifest.entry(entry)?.clone();
        let path = self.manifest.dir.join(&spec.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        if spec.n_params > self.weights.tensors.len() {
            bail!("{entry}: n_params {} > weights {}", spec.n_params, self.weights.tensors.len());
        }
        let param_bufs = self.weights.tensors[..spec.n_params]
            .iter()
            .map(|t| t.to_device(&self.client))
            .collect::<Result<Vec<_>>>()?;
        Ok(Executable { spec, exe, param_bufs })
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure manifest-parsing tests (no artifacts needed); the end-to-end
    // PJRT tests live in rust/tests/runtime_integration.rs and skip when
    // artifacts are absent.

    fn sample_manifest() -> &'static str {
        r#"{
          "config": {"vocab": 4096, "n_layers": 4},
          "seed": 0,
          "params": [
            {"name": "embed", "shape": [8, 4], "offset": 0, "nelems": 32}
          ],
          "entries": {
            "decode_b1": {
              "hlo": "decode_b1.hlo.txt",
              "n_params": 1,
              "inputs": [{"shape": [8,4], "dtype": "f32"}, {"shape": [1], "dtype": "i32"}],
              "outputs": [{"shape": [1, 4096], "dtype": "f32"}],
              "testvec": {"inputs": ["decode_b1.in0.bin"], "outputs": ["decode_b1.out0.bin"]}
            }
          }
        }"#
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("halo_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.params.len(), 1);
        assert_eq!(m.params[0].shape, vec![8, 4]);
        let e = m.entry("decode_b1").unwrap();
        assert_eq!(e.n_params, 1);
        assert_eq!(e.inputs[1].dtype, Dtype::I32);
        assert_eq!(e.outputs[0].shape, vec![1, 4096]);
        assert_eq!(e.testvec_inputs, vec!["decode_b1.in0.bin"]);
        assert_eq!(m.config_usize("vocab").unwrap(), 4096);
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn weights_length_checked() {
        let dir = std::env::temp_dir().join("halo_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        // too short: 10 floats instead of 32
        std::fs::write(dir.join("weights.bin"), vec![0u8; 40]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(Weights::load(&m).is_err());
        std::fs::write(dir.join("weights.bin"), vec![0u8; 128]).unwrap();
        let w = Weights::load(&m).unwrap();
        assert_eq!(w.tensors[0].spec.shape, vec![8, 4]);
    }
}

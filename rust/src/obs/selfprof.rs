//! Simulator self-profiling: wall-clock time and work counters per
//! stage, so hot paths are measurable before they are optimized.
//!
//! Wall times are host measurements (`std::time::Instant`), never part
//! of any simulated quantity — they live in a side struct precisely so
//! determinism guarantees over simulation results are untouched.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Debug, Clone, Default)]
pub struct SelfProfile {
    wall: BTreeMap<&'static str, f64>,
    counts: BTreeMap<&'static str, u64>,
}

impl SelfProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, accumulating its wall time under `key` and bumping the
    /// same-named counter by one invocation.
    pub fn time<R>(&mut self, key: &'static str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        *self.wall.entry(key).or_insert(0.0) += t0.elapsed().as_secs_f64();
        *self.counts.entry(key).or_insert(0) += 1;
        r
    }

    pub fn add(&mut self, key: &'static str, n: u64) {
        *self.counts.entry(key).or_insert(0) += n;
    }

    /// Accumulate an externally measured wall time under `key` without
    /// touching the counter — the shape batched work needs, where one
    /// timed region covers many counted items (bump the counter
    /// separately with [`add`](Self::add)).
    pub fn add_wall(&mut self, key: &'static str, secs: f64) {
        *self.wall.entry(key).or_insert(0.0) += secs;
    }

    /// Fold another profile into this one (both wall times and counts).
    pub fn absorb(&mut self, other: &SelfProfile) {
        for (k, v) in &other.wall {
            *self.wall.entry(k).or_insert(0.0) += v;
        }
        for (k, n) in &other.counts {
            *self.counts.entry(k).or_insert(0) += n;
        }
    }

    pub fn wall_s(&self, key: &str) -> f64 {
        self.wall.get(key).copied().unwrap_or(0.0)
    }

    pub fn count(&self, key: &str) -> u64 {
        self.counts.get(key).copied().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let wall: BTreeMap<String, Json> =
            self.wall.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect();
        let counts: BTreeMap<String, Json> =
            self.counts.iter().map(|(k, v)| (k.to_string(), Json::Num(*v as f64))).collect();
        let mut m = BTreeMap::new();
        m.insert("wall_s".to_string(), Json::Obj(wall));
        m.insert("counts".to_string(), Json::Obj(counts));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates_and_counts() {
        let mut p = SelfProfile::new();
        let x = p.time("work", || 7);
        assert_eq!(x, 7);
        p.time("work", || ());
        p.add("walks", 3);
        assert_eq!(p.count("work"), 2);
        assert_eq!(p.count("walks"), 3);
        assert!(p.wall_s("work") >= 0.0);
        assert_eq!(p.wall_s("missing"), 0.0);
        let j = p.to_json();
        assert!(j.path(&["counts", "walks"]).is_some());
    }

    #[test]
    fn add_wall_accumulates_without_counting() {
        let mut p = SelfProfile::new();
        p.add_wall("batch", 0.25);
        p.add_wall("batch", 0.5);
        assert_eq!(p.wall_s("batch"), 0.75);
        assert_eq!(p.count("batch"), 0, "wall-only accumulation never bumps the counter");
    }

    #[test]
    fn absorb_merges_both_maps() {
        let mut a = SelfProfile::new();
        a.add("walks", 2);
        a.add_wall("work", 1.0);
        let mut b = SelfProfile::new();
        b.add("walks", 3);
        b.add("hits", 1);
        b.add_wall("work", 0.5);
        a.absorb(&b);
        assert_eq!(a.count("walks"), 5);
        assert_eq!(a.count("hits"), 1);
        assert_eq!(a.wall_s("work"), 1.5);
    }
}

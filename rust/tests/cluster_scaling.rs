//! Cluster-plane integration tests: scaling efficiency, router-policy
//! ordering across interconnect speeds, exact equivalence of the
//! refactored single-device core with the fleet simulator, and the
//! KV-capacity / chunked-prefill scheduler paths.

use halo::cluster::{Interconnect, Mix, Policy, SchedConfig};
use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::report;
use halo::sim::queueing::replay_trace;
use halo::model::LlmConfig;

fn hw() -> HwConfig {
    HwConfig::paper()
}

fn llm() -> LlmConfig {
    LlmConfig::llama2_7b()
}

fn run(
    policy: Policy,
    devices: usize,
    link: Interconnect,
    trace: &[halo::sim::queueing::TraceRequest],
) -> halo::cluster::FleetResult {
    let (mut fleet, mut router) = policy.build(&llm(), &hw(), devices, 8, 0.5, link);
    fleet.replay(trace, router.as_mut())
}

#[test]
fn throughput_scales_at_least_3x_from_1_to_8_devices() {
    // saturating load: the whole trace arrives in the first microseconds,
    // so served rate == fleet capacity
    let trace = Mix::Chat.trace(1, 160, 1.0e6);
    let r1 = run(Policy::LeastLoaded, 1, Interconnect::board(), &trace);
    let r8 = run(Policy::LeastLoaded, 8, Interconnect::board(), &trace);
    assert_eq!(r1.served.len(), 160);
    assert_eq!(r8.served.len(), 160);
    let speedup = r8.throughput_rps() / r1.throughput_rps();
    assert!(speedup >= 3.0, "1->8 device speedup only {speedup:.2}x");
    // and it cannot meaningfully exceed the device count
    assert!(speedup <= 8.5, "superlinear speedup {speedup:.2}x");
}

#[test]
fn more_devices_never_reduce_saturated_throughput() {
    let trace = Mix::Chat.trace(2, 120, 1.0e6);
    let mut last = 0.0;
    for devices in [1usize, 2, 4, 8] {
        let r = run(Policy::LeastLoaded, devices, Interconnect::board(), &trace);
        let rps = r.throughput_rps();
        assert!(rps >= last * 0.999, "throughput regressed at {devices} devices: {rps} < {last}");
        last = rps;
    }
}

#[test]
fn disaggregated_beats_round_robin_on_mixed_tail_ttft_with_fast_link() {
    // offered load: 3x one device's capacity on an 8-device fleet — busy
    // but stable for every policy
    let t1 = report::cluster::single_device_capacity(&hw(), &llm(), Mix::Interactive, 8);
    let trace = Mix::Interactive.trace(5, 240, 3.0 * t1);
    let rr = run(Policy::RoundRobin, 8, Interconnect::board(), &trace);
    let pd = run(Policy::PhaseDisaggregated, 8, Interconnect::board(), &trace);
    assert_eq!(rr.served.len(), 240);
    assert_eq!(pd.served.len(), 240);
    // dedicated prefill devices keep new requests from queueing behind
    // decode slots: the tail TTFT must drop
    assert!(
        pd.ttft_p99() < rr.ttft_p99(),
        "disaggregated p99 TTFT {} !< round-robin {}",
        pd.ttft_p99(),
        rr.ttft_p99()
    );
    // the fast link moved every KV cache and still won
    assert_eq!(pd.transfers, 240);
    assert_eq!(rr.transfers, 0);
}

#[test]
fn disaggregation_loses_when_the_link_is_slow() {
    let t1 = report::cluster::single_device_capacity(&hw(), &llm(), Mix::Interactive, 8);
    let trace = Mix::Interactive.trace(6, 240, 3.0 * t1);
    let rr = run(Policy::RoundRobin, 8, Interconnect::board(), &trace);
    let pd_fast = run(Policy::PhaseDisaggregated, 8, Interconnect::board(), &trace);
    let pd_slow = run(Policy::PhaseDisaggregated, 8, Interconnect::wan(), &trace);
    let mean = |r: &halo::cluster::FleetResult| {
        r.served.iter().map(|s| s.e2e).sum::<f64>() / r.served.len() as f64
    };
    // same KV volume, very different cost
    assert_eq!(pd_fast.kv_bytes, pd_slow.kv_bytes);
    assert!(mean(&pd_slow) > mean(&pd_fast) + 0.05, "{} vs {}", mean(&pd_slow), mean(&pd_fast));
    // once transfers dominate, the monolithic baseline wins end-to-end
    assert!(
        mean(&pd_slow) > mean(&rr),
        "slow-link disaggregation should lose on mean e2e: {} vs {}",
        mean(&pd_slow),
        mean(&rr)
    );
}

#[test]
fn single_device_fleet_is_bit_identical_to_replay_trace() {
    // acceptance (c): the Device refactor reproduces the pre-refactor
    // replay exactly, including through the fleet event loop
    let trace = Mix::Interactive.trace(9, 60, 8.0);
    let single = replay_trace(&llm(), &hw(), MappingKind::Halo1, 8, &trace);
    let fleet = run(Policy::RoundRobin, 1, Interconnect::board(), &trace);
    assert_eq!(fleet.served.len(), single.served.len());
    assert_eq!(fleet.decode_steps, single.decode_steps);
    assert_eq!(fleet.makespan, single.makespan);
    for (a, b) in fleet.served.iter().zip(&single.served) {
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.ttft, b.ttft);
        assert_eq!(a.e2e, b.e2e);
    }
}

#[test]
fn every_mix_runs_on_every_policy() {
    for mix in Mix::all() {
        let trace = mix.trace(12, 40, 20.0);
        for policy in Policy::all() {
            let r = run(policy, 4, Interconnect::pcie5(), &trace);
            assert_eq!(r.served.len(), 40, "{} on {}", policy.name(), mix.name());
            assert!(r.makespan > 0.0);
            for s in &r.served {
                assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
            }
        }
    }
}

#[test]
fn build_with_default_sched_matches_build_bit_for_bit() {
    // acceptance: the scheduler threading must not perturb the default
    // (serialized FIFO, unlimited KV) configuration in any way
    let trace = Mix::Interactive.trace(15, 80, 12.0);
    for policy in Policy::all() {
        let (mut fa, mut ra) = policy.build(&llm(), &hw(), 4, 8, 0.5, Interconnect::board());
        let (mut fb, mut rb) = policy.build_with(
            &llm(),
            &hw(),
            4,
            8,
            0.5,
            Interconnect::board(),
            SchedConfig::default(),
        );
        let a = fa.replay(&trace, ra.as_mut());
        let b = fb.replay(&trace, rb.as_mut());
        assert_eq!(a.makespan, b.makespan, "{}", policy.name());
        assert_eq!(a.decode_steps, b.decode_steps);
        assert_eq!(a.evictions, 0);
        assert_eq!(b.evictions, 0);
        for (x, y) in a.served.iter().zip(&b.served) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.ttft, y.ttft);
            assert_eq!(x.e2e, y.e2e);
        }
    }
}

#[test]
fn decode_pool_kv_budget_is_never_exceeded() {
    // acceptance: resident KV bytes never exceed the configured budget.
    // 4 GB/device comfortably exceeds any single interactive request's
    // lifetime KV (~2.2 GB), so the invariant is strict.
    let cap = 4_000_000_000u64;
    let t1 = report::cluster::single_device_capacity(&hw(), &llm(), Mix::Interactive, 8);
    let trace = Mix::Interactive.trace(16, 160, 2.0 * t1);
    let sched = SchedConfig::default().with_kv_capacity(cap);
    let (mut fleet, mut router) =
        Policy::KvAware.build_with(&llm(), &hw(), 4, 8, 0.5, Interconnect::board(), sched);
    let r = fleet.replay(&trace, router.as_mut());
    assert_eq!(r.served.len(), 160, "eviction/recompute must conserve requests");
    for d in &r.per_device {
        assert!(
            d.kv_peak <= cap,
            "device {} resident KV peak {} exceeds budget {cap}",
            d.id,
            d.kv_peak
        );
        if d.role == "prefill" {
            // handoff KV is transient and charged to the decode side
            assert_eq!(d.kv_peak, 0, "prefill device {} holds resident KV", d.id);
            assert_eq!(d.evictions, 0);
        }
    }
    // recompute accounting is consistent: tokens only when evictions
    assert_eq!(r.evictions == 0, r.recompute_tokens == 0);
    for s in &r.served {
        assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
    }
}

#[test]
fn heterogeneous_decode_capacities_route_toward_headroom() {
    // decode pool = {2, 3}: device 2 gets a tight budget, device 3 an
    // unlimited one; capacity-aware routing must shift decode work (and
    // all eviction pressure) toward device 3
    let t1 = report::cluster::single_device_capacity(&hw(), &llm(), Mix::Interactive, 8);
    let trace = Mix::Interactive.trace(17, 120, 2.0 * t1);
    let tight = 3_000_000_000u64;
    let (mut fleet, mut router) =
        Policy::KvAware.build(&llm(), &hw(), 4, 8, 0.5, Interconnect::board());
    fleet.set_kv_capacity(2, Some(tight));
    let r = fleet.replay(&trace, router.as_mut());
    assert_eq!(r.served.len(), 120);
    let d2 = &r.per_device[2];
    let d3 = &r.per_device[3];
    assert!(d2.kv_peak <= tight, "tight device over budget: {}", d2.kv_peak);
    // the unlimited device never needs to evict, and both decode
    assert_eq!(d3.evictions, 0);
    assert!(d2.served > 0 && d3.served > 0, "{} vs {}", d2.served, d3.served);
}

#[test]
fn chunked_prefill_conserves_requests_across_mixes_and_links() {
    for mix in [Mix::Chat, Mix::Summarization, Mix::Interactive] {
        let trace = mix.trace(18, 40, 15.0);
        for chunk in [256usize, 1024] {
            let (mut fleet, mut router) = Policy::PhaseDisaggregated.build_with(
                &llm(),
                &hw(),
                4,
                8,
                0.5,
                Interconnect::pcie5(),
                SchedConfig::chunked(chunk),
            );
            let r = fleet.replay(&trace, router.as_mut());
            assert_eq!(r.served.len(), 40, "chunk {chunk} on {}", mix.name());
            assert_eq!(r.transfers, 40);
            for s in &r.served {
                assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
            }
        }
    }
}

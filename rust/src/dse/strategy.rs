//! Pluggable search strategies over a [`SearchSpace`].
//!
//! A strategy only decides *which* points to visit; the engine owns
//! evaluation, memoization, and scoring. The `eval` callback returns a
//! scalar guidance score (lower is better — the first objective, or the
//! SLO-penalized cost in auto-tune mode) and `f64::INFINITY` for invalid
//! points, so strategies need no validity logic of their own. All
//! strategies are deterministic given their seed.

use super::space::{Index, SearchSpace, AXES};
use crate::util::Rng;

/// A search strategy: drive `eval` over points of `space`.
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64);
}

/// Exhaustive grid enumeration (the degenerate §V-B "search" and every
/// small space). Visits points in flat mixed-radix order.
#[derive(Debug, Default)]
pub struct Exhaustive;

impl Strategy for Exhaustive {
    fn name(&self) -> &'static str {
        "grid"
    }
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
        for i in 0..space.len() {
            eval(&space.flat(i));
        }
    }
}

/// Seeded uniform random sampling (with replacement; the engine's memo
/// makes repeats free). The workhorse for big spaces.
#[derive(Debug)]
pub struct RandomSearch {
    pub samples: usize,
    pub seed: u64,
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
        let mut rng = Rng::new(self.seed);
        for _ in 0..self.samples {
            eval(&space.sample(&mut rng));
        }
    }
}

/// Seeded steepest-ascent hill climbing with random restarts: from a
/// random point, evaluate every one-step axis neighbor and move to the
/// best strictly-improving one until a local optimum (or the step budget)
/// is reached. Restarts cover the space's basins; the engine's memo makes
/// revisits free, so the frontier still sees every point touched.
#[derive(Debug)]
pub struct HillClimb {
    pub restarts: usize,
    pub steps: usize,
    pub seed: u64,
}

impl Strategy for HillClimb {
    fn name(&self) -> &'static str {
        "hillclimb"
    }
    fn search(&mut self, space: &SearchSpace, eval: &mut dyn FnMut(&Index) -> f64) {
        let mut rng = Rng::new(self.seed);
        for _ in 0..self.restarts.max(1) {
            let mut cur = space.sample(&mut rng);
            let mut cur_score = eval(&cur);
            for _ in 0..self.steps {
                let mut best: Option<(Index, f64)> = None;
                for axis in 0..AXES {
                    for dir in [-1i64, 1] {
                        let Some(next) = space.step(&cur, axis, dir) else { continue };
                        let s = eval(&next);
                        if s < cur_score && best.is_none_or(|(_, bs)| s < bs) {
                            best = Some((next, s));
                        }
                    }
                }
                match best {
                    Some((next, s)) => {
                        cur = next;
                        cur_score = s;
                    }
                    None => break,
                }
            }
        }
    }
}

/// Resolve a strategy by CLI name. `samples` feeds random search;
/// `restarts`/`steps` feed hill climbing.
pub fn by_name(
    name: &str,
    seed: u64,
    samples: usize,
    restarts: usize,
    steps: usize,
) -> Option<Box<dyn Strategy>> {
    match name.to_ascii_lowercase().as_str() {
        "grid" | "exhaustive" => Some(Box::new(Exhaustive)),
        "random" | "rand" => Some(Box::new(RandomSearch { samples, seed })),
        "hillclimb" | "climb" | "hc" => Some(Box::new(HillClimb { restarts, steps, seed })),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn visited(strategy: &mut dyn Strategy, space: &SearchSpace) -> Vec<Index> {
        let mut order = Vec::new();
        let mut eval = |idx: &Index| {
            order.push(*idx);
            // synthetic deterministic score: distance from the origin
            idx.iter().map(|&x| x as f64).sum::<f64>()
        };
        strategy.search(space, &mut eval);
        order
    }

    #[test]
    fn grid_visits_every_point_once() {
        let space = SearchSpace::smoke();
        let order = visited(&mut Exhaustive, &space);
        assert_eq!(order.len(), space.len());
        let unique: BTreeSet<Index> = order.iter().copied().collect();
        assert_eq!(unique.len(), space.len());
    }

    #[test]
    fn random_is_seeded_and_in_bounds() {
        let space = SearchSpace::fleet();
        let a = visited(&mut RandomSearch { samples: 25, seed: 9 }, &space);
        let b = visited(&mut RandomSearch { samples: 25, seed: 9 }, &space);
        assert_eq!(a, b, "same seed, same visit order");
        let c = visited(&mut RandomSearch { samples: 25, seed: 10 }, &space);
        assert_ne!(a, c, "different seed, different walk");
        let dims = space.dims();
        assert!(a.iter().all(|idx| idx.iter().zip(dims.iter()).all(|(&x, &d)| x < d)));
    }

    #[test]
    fn hillclimb_descends_the_synthetic_bowl() {
        // with score = sum of coordinates, the climb must end at the
        // origin from any restart
        let space = SearchSpace::fleet();
        let mut best_seen = f64::INFINITY;
        let mut eval = |idx: &Index| {
            let s = idx.iter().map(|&x| x as f64).sum::<f64>();
            if s < best_seen {
                best_seen = s;
            }
            s
        };
        HillClimb { restarts: 2, steps: 50, seed: 5 }.search(&space, &mut eval);
        assert_eq!(best_seen, 0.0, "steepest descent reaches the origin");
    }

    #[test]
    fn by_name_resolves() {
        for (name, want) in [("grid", "grid"), ("random", "random"), ("hc", "hillclimb")] {
            assert_eq!(by_name(name, 1, 10, 2, 20).unwrap().name(), want);
        }
        assert!(by_name("annealing", 1, 10, 2, 20).is_none());
    }
}

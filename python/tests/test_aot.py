"""AOT artifact tests: manifest integrity, HLO text validity, vector replay.

Runs against whatever `make artifacts` produced; skipped if absent.
"""

import json
import pathlib

import numpy as np
import pytest

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="run `make artifacts` first"
)

DT = {"f32": np.float32, "i32": np.int32, "i8": np.int8}


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_weights_bin_matches_table(manifest):
    size = (ART / "weights.bin").stat().st_size
    total = sum(p["nelems"] for p in manifest["params"]) * 4
    assert size == total
    # offsets are contiguous and ordered
    off = 0
    for p in manifest["params"]:
        assert p["offset"] == off
        assert p["nelems"] == int(np.prod(p["shape"]))
        off += p["nelems"] * 4


def test_weights_match_model_init(manifest):
    from compile import model as M

    cfg = M.TinyLlamaConfig()
    params = M.init_params(cfg, manifest["seed"])
    blob = np.fromfile(ART / "weights.bin", dtype=np.float32)
    for spec, arr in zip(manifest["params"], params):
        got = blob[spec["offset"] // 4 : spec["offset"] // 4 + spec["nelems"]]
        np.testing.assert_array_equal(got, np.asarray(arr).ravel())


def test_hlo_files_exist_and_parse(manifest):
    for name, e in manifest["entries"].items():
        text = (ART / e["hlo"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # parameter count in the entry computation matches the signature
        entry_params = text.count("= f32[") + 0  # loose; structural check below
        assert len(e["inputs"]) >= 1 and len(e["outputs"]) >= 1


def test_entry_signatures(manifest):
    cfg = manifest["config"]
    e = manifest["entries"]["prefill_b1_s16"]
    assert e["inputs"][-1]["shape"] == [1, 16]
    assert e["outputs"][0]["shape"] == [1, 16, cfg["vocab"]]
    d = manifest["entries"]["decode_b4"]
    assert d["inputs"][len(manifest["params"])]["shape"] == [4]
    assert d["outputs"][0]["shape"] == [4, cfg["vocab"]]
    kv_shape = [cfg["n_layers"], 4, cfg["max_seq"], cfg["n_kv_heads"], cfg["head_dim"]]
    assert d["outputs"][1]["shape"] == kv_shape


def _load_vec(e, which, i):
    f = ART / "testvec" / e["testvec"][which][i]
    sig = e["inputs"][e["n_params"] + i] if which == "inputs" else e["outputs"][i]
    return np.fromfile(f, dtype=DT[sig["dtype"]]).reshape(sig["shape"])


def test_testvec_replay_decode(manifest):
    """Re-run the jitted decode entry on the stored inputs; the stored
    outputs must reproduce (same lowering as the HLO the Rust side runs)."""
    import jax.numpy as jnp
    from compile import model as M

    cfg = M.TinyLlamaConfig()
    params = M.init_params(cfg, manifest["seed"])
    e = manifest["entries"]["decode_b1"]
    token, pos, kc, vc = (jnp.asarray(_load_vec(e, "inputs", i)) for i in range(4))
    lg, kc2, vc2 = M.decode_step(params, token, pos, kc, vc, cfg)
    np.testing.assert_allclose(np.asarray(lg), _load_vec(e, "outputs", 0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kc2), _load_vec(e, "outputs", 1), atol=1e-4)


def test_testvec_replay_cid_kernel(manifest):
    import jax.numpy as jnp
    from compile.kernels.cid_gemv import cid_gemv

    e = manifest["entries"]["cid_gemv_4x256x512"]
    x = _load_vec(e, "inputs", 0)
    w = _load_vec(e, "inputs", 1)
    got = np.asarray(cid_gemv(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, _load_vec(e, "outputs", 0))

//! Batched KV-cache state and slot bookkeeping.
//!
//! The decode executable is compiled for a fixed batch of `slots`; each
//! slot holds one in-flight sequence's KV cache at a fixed index of the
//! (n_layers, B, max_seq, n_kv_heads, head_dim) tensors. The coordinator
//! copies a finished prefill's (B=1) cache into a free slot and recycles
//! slots as sequences complete (continuous batching, vLLM-style but
//! slot-granular).

use anyhow::{bail, Result};

use crate::runtime::{Dtype, HostTensor, TensorSpec};

/// Per-slot sequence state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    Free,
    /// Active sequence: request id and the position the *next* token will
    /// be written to (== current sequence length).
    Active { request: u64, pos: usize, generated: usize, budget: usize },
}

/// Batched KV tensors plus slot table.
#[derive(Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub slots: usize,
    pub max_seq: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub k: HostTensor,
    pub v: HostTensor,
    table: Vec<Slot>,
}

impl KvCache {
    pub fn new(
        n_layers: usize,
        slots: usize,
        max_seq: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Self {
        let spec = TensorSpec {
            shape: vec![n_layers, slots, max_seq, kv_heads, head_dim],
            dtype: Dtype::F32,
        };
        KvCache {
            n_layers,
            slots,
            max_seq,
            kv_heads,
            head_dim,
            k: HostTensor::zeros(spec.clone()),
            v: HostTensor::zeros(spec),
            table: vec![Slot::Free; slots],
        }
    }

    pub fn slot(&self, i: usize) -> Option<Slot> {
        self.table.get(i).copied()
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.table.iter().position(|s| matches!(s, Slot::Free))
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.slots).filter(|i| matches!(self.table[*i], Slot::Active { .. })).collect()
    }

    pub fn is_idle(&self) -> bool {
        self.active_slots().is_empty()
    }

    /// Claim a slot for a request whose prefill produced `pos` cached
    /// positions; `budget` = max new tokens.
    pub fn claim(&mut self, i: usize, request: u64, pos: usize, budget: usize) -> Result<()> {
        if i >= self.slots {
            bail!("slot index {i} out of range (slots = {})", self.slots);
        }
        if !matches!(self.table[i], Slot::Free) {
            bail!("slot {i} is busy");
        }
        if pos >= self.max_seq {
            bail!("prompt length {pos} >= max_seq {}", self.max_seq);
        }
        self.table[i] = Slot::Active { request, pos, generated: 0, budget };
        Ok(())
    }

    pub fn release(&mut self, i: usize) {
        self.table[i] = Slot::Free;
    }

    /// Advance an active slot by one generated token. Returns true when
    /// the slot is finished (budget exhausted or context full); advancing
    /// a free or out-of-range slot is a coordinator-state error, reported
    /// rather than panicking.
    pub fn advance(&mut self, i: usize) -> Result<bool> {
        match self.table.get_mut(i) {
            Some(Slot::Active { pos, generated, budget, .. }) => {
                *pos += 1;
                *generated += 1;
                Ok(*generated >= *budget || *pos + 1 >= self.max_seq)
            }
            Some(Slot::Free) => bail!("advance on free slot {i}"),
            None => bail!("slot index {i} out of range (slots = {})", self.slots),
        }
    }

    /// Copy a single-sequence prefill cache (n_layers, 1, S, H, D) into
    /// slot `i` of the batched tensors.
    pub fn load_prefill(&mut self, i: usize, k1: &HostTensor, v1: &HostTensor) -> Result<()> {
        let expect = vec![self.n_layers, 1, self.max_seq, self.kv_heads, self.head_dim];
        if k1.spec.shape != expect || v1.spec.shape != expect {
            bail!("prefill KV shape {:?}, expected {:?}", k1.spec.shape, expect);
        }
        let per_seq = self.max_seq * self.kv_heads * self.head_dim;
        let batch_layer = self.slots * per_seq;
        for (dst, src) in [(&mut self.k, k1), (&mut self.v, v1)] {
            let s = src.as_f32()?.to_vec();
            let d = dst.as_f32_mut()?;
            for l in 0..self.n_layers {
                let doff = l * batch_layer + i * per_seq;
                let soff = l * per_seq;
                d[doff..doff + per_seq].copy_from_slice(&s[soff..soff + per_seq]);
            }
        }
        Ok(())
    }

    /// Gather (token, pos) vectors for one decode step. Inactive slots get
    /// token 0 at position 0 (their writes are garbage by construction and
    /// are overwritten by the next prefill claiming the slot). A
    /// wrong-arity token vector is a caller error, reported as a `Result`.
    pub fn step_inputs(&self, next_tokens: &[i32]) -> Result<(Vec<i32>, Vec<i32>)> {
        if next_tokens.len() != self.slots {
            bail!("step_inputs got {} tokens for {} slots", next_tokens.len(), self.slots);
        }
        let mut toks = vec![0i32; self.slots];
        let mut pos = vec![0i32; self.slots];
        for i in 0..self.slots {
            if let Slot::Active { pos: p, .. } = self.table[i] {
                toks[i] = next_tokens[i];
                pos[i] = p as i32;
            }
        }
        Ok((toks, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(2, 4, 16, 2, 8)
    }

    #[test]
    fn claim_release_cycle() {
        let mut c = cache();
        assert_eq!(c.free_slot(), Some(0));
        c.claim(0, 77, 5, 3).unwrap();
        assert!(matches!(c.slot(0), Some(Slot::Active { request: 77, pos: 5, .. })));
        assert_eq!(c.free_slot(), Some(1));
        assert!(c.claim(0, 78, 1, 1).is_err(), "double claim");
        c.release(0);
        assert_eq!(c.free_slot(), Some(0));
    }

    #[test]
    fn advance_finishes_on_budget() {
        let mut c = cache();
        c.claim(1, 9, 4, 2).unwrap();
        assert!(!c.advance(1).unwrap());
        assert!(c.advance(1).unwrap()); // budget 2 reached
    }

    #[test]
    fn advance_finishes_on_context_limit() {
        let mut c = cache();
        c.claim(2, 9, 13, 100).unwrap();
        assert!(!c.advance(2).unwrap()); // pos 14
        assert!(c.advance(2).unwrap()); // pos 15 == max_seq-1 -> full
    }

    #[test]
    fn claim_rejects_overlong_prompt() {
        let mut c = cache();
        assert!(c.claim(0, 1, 16, 4).is_err());
    }

    #[test]
    fn bad_indices_and_arity_error_instead_of_panicking() {
        let mut c = cache();
        assert!(c.claim(99, 1, 2, 2).is_err(), "out-of-range claim");
        assert!(c.advance(99).is_err(), "out-of-range advance");
        assert!(c.advance(0).is_err(), "advance on a free slot");
        assert_eq!(c.slot(99), None);
        assert!(c.step_inputs(&[1, 2]).is_err(), "wrong-arity token vector");
    }

    #[test]
    fn load_prefill_targets_one_slot() {
        let mut c = cache();
        let spec = TensorSpec { shape: vec![2, 1, 16, 2, 8], dtype: Dtype::F32 };
        let mut k1 = HostTensor::zeros(spec.clone());
        k1.as_f32_mut().unwrap().iter_mut().for_each(|x| *x = 7.0);
        let v1 = HostTensor::zeros(spec);
        c.load_prefill(2, &k1, &v1).unwrap();
        let per_seq = 16 * 2 * 8;
        let k = c.k.as_f32().unwrap();
        // slot 2 of layer 0 and 1 is 7.0, slots 0,1,3 untouched
        for l in 0..2 {
            let base = l * 4 * per_seq;
            assert!(k[base + 2 * per_seq..base + 3 * per_seq].iter().all(|x| *x == 7.0));
            assert!(k[base..base + 2 * per_seq].iter().all(|x| *x == 0.0));
            assert!(k[base + 3 * per_seq..base + 4 * per_seq].iter().all(|x| *x == 0.0));
        }
    }

    #[test]
    fn step_inputs_mask_inactive() {
        let mut c = cache();
        c.claim(1, 5, 9, 4).unwrap();
        let (toks, pos) = c.step_inputs(&[11, 22, 33, 44]).unwrap();
        assert_eq!(toks, vec![0, 22, 0, 0]);
        assert_eq!(pos, vec![0, 9, 0, 0]);
    }

    #[test]
    fn active_slots_listing() {
        let mut c = cache();
        assert!(c.is_idle());
        c.claim(0, 1, 2, 2).unwrap();
        c.claim(3, 2, 2, 2).unwrap();
        assert_eq!(c.active_slots(), vec![0, 3]);
    }
}

//! Reusable single-device serving state machine.
//!
//! Extracted from `sim::queueing::replay_trace` so that the single-device
//! replay and the `cluster` fleet simulator share one core: a
//! [`CostModel`] (the joint latency/energy oracle of [`super::cost`] —
//! memoized prefill/decode-step [`PhaseCost`] curves) plus a [`Device`]
//! (slot-based continuous batching), steppable in event time one
//! scheduling cycle at a time. Every busy event advances the clock by the
//! latency half of one `PhaseCost` and — when power tracking is attached —
//! charges the energy half of the *same* cost, so the two planes cannot
//! drift. A per-phase [`DvfsConfig`] scales event latency by `1/f` and
//! dynamic energy by `V^2` (nominal by default, which is the exact
//! identity).
//!
//! Admission scheduling is pluggable via [`SchedConfig`]:
//!
//! * **prefill** — serialized (the default: an admitted prompt occupies
//!   the whole device until its prefill completes, exactly the original
//!   replay loop) or *chunked*: prompts stream through in
//!   configurable-size chunks, one chunk per in-progress prompt per
//!   cycle (at most `slots` prompts in flight), interleaved with the
//!   running decode batch, so short prompts finish their prefill while
//!   long ones are still streaming;
//! * **admission order** — FIFO with head-of-line blocking (default),
//!   shortest-prompt-first, or interactive-priority
//!   (prompts at or below [`INTERACTIVE_CUTOFF`] tokens first);
//! * **KV capacity** — an optional resident-KV byte budget. Admission is
//!   gated on the *committed* footprint (active contexts plus the full
//!   prompt of every in-progress prefill), and decode-step growth that
//!   would overflow the budget evicts the youngest-arrival sequences
//!   back to the queue as [`DeviceJob::Resume`] jobs whose cached tokens
//!   must be recomputed (prefilled again) before decoding continues —
//!   vLLM-style preemption with recompute accounting.
//!
//! A scheduling cycle mirrors the original replay loop: admit ready jobs
//! (serialized prefills advance the clock; chunked prefills run one chunk
//! per prompt), then run one batched decode step over the active slots.
//! The cluster layer adds two job shapes on top of the monolithic
//! [`DeviceJob::Full`]: [`DeviceJob::PrefillOnly`] (emit a KV handoff
//! instead of decoding) and [`DeviceJob::DecodeOnly`] (continue a sequence
//! whose prefill ran on another device).

use std::cell::Cell;
use std::collections::VecDeque;

use super::queueing::{ServedRequest, TraceRequest};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{LlmConfig, Phase};
use crate::obs::{EventKind, Recorder, Span, SpanKind};
use crate::power::{DevicePower, DvfsConfig, ThermalConfig, ThermalModel};

pub use super::cost::{CostModel, PhaseCost};

/// Prompt length at or below which a request counts as interactive for
/// [`AdmissionPolicy::Interactive`] (the chat band of the workload mixes).
pub const INTERACTIVE_CUTOFF: usize = 512;

/// Order in which ready jobs leave the device queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Strict arrival order with head-of-line blocking — the original
    /// replay loop's policy, and the default.
    #[default]
    Fifo,
    /// Among ready jobs, least pending prefill work first (SJF on prompt
    /// length; KV-transfer continuations count as zero work).
    ShortestFirst,
    /// Two-class priority: prompts at or below [`INTERACTIVE_CUTOFF`]
    /// tokens first, FIFO within each class.
    Interactive,
}

impl AdmissionPolicy {
    pub fn all() -> [AdmissionPolicy; 3] {
        [AdmissionPolicy::Fifo, AdmissionPolicy::ShortestFirst, AdmissionPolicy::Interactive]
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::ShortestFirst => "spf",
            AdmissionPolicy::Interactive => "priority",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "spf" | "sjf" | "shortest" | "shortest-first" => {
                Some(AdmissionPolicy::ShortestFirst)
            }
            "priority" | "interactive" => Some(AdmissionPolicy::Interactive),
            _ => None,
        }
    }
}

/// Pluggable device scheduling configuration. The default — serialized
/// prefill, FIFO admission, unlimited KV — reproduces the original
/// replay loop bit-for-bit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SchedConfig {
    /// Prefill chunk size in tokens; `None` serializes each prompt's
    /// prefill as one monolithic pass.
    pub chunk: Option<usize>,
    pub admission: AdmissionPolicy,
    /// Resident-KV byte budget for this device; `None` is unlimited.
    pub kv_capacity: Option<u64>,
}

impl SchedConfig {
    /// The legacy configuration (alias for `default()`), spelled out.
    pub fn serialized() -> Self {
        SchedConfig::default()
    }

    pub fn chunked(chunk: usize) -> Self {
        SchedConfig { chunk: Some(chunk), ..SchedConfig::default() }
    }

    pub fn with_kv_capacity(mut self, cap: u64) -> Self {
        self.kv_capacity = Some(cap);
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }
}

/// Request identity riding along with every job: who submitted it
/// (`tenant`), which conversation it belongs to (`session`), and how
/// many output tokens it generates — folded into the [`ServedRequest`]
/// at completion so streaming consumers aggregate per tenant/session
/// without joining back to a materialized trace. Jobs pushed through
/// the untagged [`Device::push`] get a default tag derived from the job
/// itself (tenant 0, session 0, the job's own output-token count), so
/// existing single-device callers are unaffected.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReqTag {
    pub tenant: usize,
    pub session: u64,
    /// Output tokens the request generates (its `l_out`).
    pub tokens: u64,
}

impl ReqTag {
    /// The identity of one trace request.
    pub fn of(r: &TraceRequest) -> Self {
        ReqTag { tenant: r.tenant, session: r.session, tokens: r.l_out as u64 }
    }
}

/// One unit of work queued on a device. `ready` is the earliest time the
/// device may start it (arrival time, or KV-transfer completion).
#[derive(Debug, Clone)]
pub enum DeviceJob {
    /// Prefill then decode to completion on this device (monolithic path).
    Full { arrival: f64, ready: f64, l_in: usize, l_out: usize },
    /// Prefill only; completion emits a [`PrefillDone`] handoff addressed
    /// to `decode_dev` instead of occupying a decode slot here.
    PrefillOnly { arrival: f64, ready: f64, l_in: usize, l_out: usize, decode_dev: usize },
    /// Decode-only continuation of a prefill that ran elsewhere; the first
    /// token was already produced at `first_token_at`.
    DecodeOnly { arrival: f64, ready: f64, first_token_at: f64, ctx: usize, remaining: usize },
    /// Re-admission of a sequence evicted under KV pressure: its `ctx`
    /// cached tokens must be recomputed (prefilled again) before decoding
    /// resumes. The first token was already emitted at `first_token_at`,
    /// so eviction costs recompute time and end-to-end latency, not TTFT.
    Resume { arrival: f64, ready: f64, first_token_at: f64, ctx: usize, remaining: usize },
}

impl DeviceJob {
    /// Monolithic job for one trace request.
    pub fn full(r: &TraceRequest) -> Self {
        DeviceJob::Full { arrival: r.arrival, ready: r.arrival, l_in: r.l_in, l_out: r.l_out }
    }

    pub fn ready(&self) -> f64 {
        match self {
            DeviceJob::Full { ready, .. }
            | DeviceJob::PrefillOnly { ready, .. }
            | DeviceJob::DecodeOnly { ready, .. }
            | DeviceJob::Resume { ready, .. } => *ready,
        }
    }

    /// Arrival time of the request this job serves (span identity for
    /// the observability plane).
    pub fn arrival(&self) -> f64 {
        match self {
            DeviceJob::Full { arrival, .. }
            | DeviceJob::PrefillOnly { arrival, .. }
            | DeviceJob::DecodeOnly { arrival, .. }
            | DeviceJob::Resume { arrival, .. } => *arrival,
        }
    }

    /// Prefill tokens this job must run before decoding — the admission
    /// key for shortest-first and interactive-priority ordering.
    fn prefill_work(&self) -> usize {
        match self {
            DeviceJob::Full { l_in, .. } | DeviceJob::PrefillOnly { l_in, .. } => *l_in,
            DeviceJob::DecodeOnly { .. } => 0,
            DeviceJob::Resume { ctx, .. } => *ctx,
        }
    }

    /// KV tokens this job commits on the device at admission time.
    /// PrefillOnly KV is transient (it ships to the decode device) and is
    /// not charged against this device's budget.
    fn kv_admit_tokens(&self) -> usize {
        match self {
            DeviceJob::Full { l_in, .. } => *l_in,
            DeviceJob::PrefillOnly { .. } => 0,
            DeviceJob::DecodeOnly { ctx, .. } | DeviceJob::Resume { ctx, .. } => *ctx,
        }
    }

    /// KV tokens this job will occupy once fully decoded — what a
    /// capacity-aware router must count for jobs already delivered to a
    /// device's queue but not yet admitted. A full job's final context is
    /// `l_in + max(l_out, 1)`: even `l_out == 0` runs one decode step.
    fn kv_lifetime_tokens(&self) -> usize {
        match self {
            DeviceJob::Full { l_in, l_out, .. } => l_in + (*l_out).max(1),
            DeviceJob::PrefillOnly { .. } => 0,
            DeviceJob::DecodeOnly { ctx, remaining, .. }
            | DeviceJob::Resume { ctx, remaining, .. } => ctx + remaining + 1,
        }
    }

    /// Output tokens this job stands for — the default [`ReqTag::tokens`]
    /// when a job is pushed without an explicit tag. Continuations count
    /// their already-emitted first token.
    fn output_tokens(&self) -> u64 {
        match self {
            DeviceJob::Full { l_out, .. } | DeviceJob::PrefillOnly { l_out, .. } => *l_out as u64,
            DeviceJob::DecodeOnly { remaining, .. } | DeviceJob::Resume { remaining, .. } => {
                *remaining as u64 + 1
            }
        }
    }
}

/// Handoff emitted when a [`DeviceJob::PrefillOnly`] completes: the KV
/// cache for `l_in` context tokens must reach `decode_dev`, which then
/// generates the remaining `l_out - 1` tokens.
#[derive(Debug, Clone)]
pub struct PrefillDone {
    pub arrival: f64,
    /// Prefill completion time on this device (== first-token time).
    pub done_at: f64,
    pub l_in: usize,
    pub l_out: usize,
    pub decode_dev: usize,
    /// Request identity, forwarded to the decode device.
    pub tag: ReqTag,
}

#[derive(Debug, Clone)]
struct ActiveSeq {
    arrival: f64,
    first_token_at: f64,
    ctx: usize,
    remaining: usize,
    tag: ReqTag,
}

/// A prompt streaming through chunked prefill: `offset` of `l_in` tokens
/// are cached so far.
#[derive(Debug, Clone)]
struct PrefillingJob {
    arrival: f64,
    offset: usize,
    l_in: usize,
    kind: PrefillKind,
    tag: ReqTag,
}

#[derive(Debug, Clone)]
enum PrefillKind {
    /// Decode here after prefill completes; the decode slot is reserved.
    Full { slot: usize, l_out: usize },
    /// Emit a KV handoff to `decode_dev` on completion.
    Handoff { decode_dev: usize, l_out: usize },
    /// KV recompute of an evicted sequence; decode resumes in the
    /// reserved `slot` with TTFT already earned at `first_token_at`.
    Resume { slot: usize, first_token_at: f64, remaining: usize },
}

impl PrefillingJob {
    fn reserved_slot(&self) -> Option<usize> {
        match self.kind {
            PrefillKind::Full { slot, .. } | PrefillKind::Resume { slot, .. } => Some(slot),
            PrefillKind::Handoff { .. } => None,
        }
    }

    /// Tokens committed against the KV budget (handoff KV is transient).
    fn kv_committed_tokens(&self) -> usize {
        match self.kind {
            PrefillKind::Handoff { .. } => 0,
            _ => self.l_in,
        }
    }

    /// Tokens resident so far (handoff KV is transient).
    fn kv_resident_tokens(&self) -> usize {
        match self.kind {
            PrefillKind::Handoff { .. } => 0,
            _ => self.offset,
        }
    }
}

/// A single HALO device: policy-ordered admission queue, serialized or
/// chunked prefills, an optional resident-KV budget with
/// eviction-and-recompute, and `slots`-way batched decode, advanced one
/// scheduling cycle at a time.
pub struct Device {
    pub id: usize,
    pub mapping: MappingKind,
    sched: SchedConfig,
    /// KV-cache bytes per cached token (model-dependent).
    kv_per_token: u64,
    cost: CostModel,
    queue: VecDeque<(DeviceJob, ReqTag)>,
    /// Cached minimum `ready` over the queued jobs (`None` = stale;
    /// rebuilt on the next read). Pushes fold into a fresh cache
    /// in-place — a push can only lower the min — while removals mark
    /// it stale. `Cell` keeps [`next_action_time`](Self::next_action_time)
    /// a `&self` read; debug builds assert every cached read against a
    /// fresh scan.
    q_min_ready: Cell<Option<f64>>,
    /// Prompts mid-chunked-prefill (always empty under serialized prefill).
    prefilling: Vec<PrefillingJob>,
    active: Vec<Option<ActiveSeq>>,
    /// Occupied decode slots, maintained at every slot write so the hot
    /// paths never re-scan `active` (asserted against a fresh scan at
    /// each cycle start in debug builds).
    n_active: usize,
    now: f64,
    /// Completed requests that finished decoding on this device.
    pub served: Vec<ServedRequest>,
    pub decode_steps: u64,
    pub prefills: u64,
    /// Time spent prefilling or decode-stepping (for utilization).
    pub busy: f64,
    /// Clock value when this device last executed work (unlike `now()`,
    /// never inflated by idle jumps).
    pub last_active: f64,
    /// Sequences evicted from the decode pool under KV pressure.
    pub evictions: u64,
    /// Cached tokens whose prefill must be re-run because of evictions.
    pub recompute_tokens: u64,
    /// High-water mark of resident KV bytes, sampled at cycle boundaries.
    pub kv_peak: u64,
    /// Optional per-event energy attribution + thermal/TDP state. `None`
    /// (the default) keeps every latency computation bit-identical to the
    /// untracked device.
    power: Option<DevicePower>,
    /// Per-phase DVFS operating points (nominal by default, the exact
    /// identity). Static points apply with or without power tracking;
    /// the thermal stepped governor additionally needs power tracking
    /// with a TDP cap.
    dvfs: DvfsConfig,
    /// Optional request-lifecycle span recorder ([`crate::obs`]). `None`
    /// (the default) records nothing; when attached it only *copies* the
    /// same `f64`s that advance the clock, so the replay stays
    /// bit-identical either way.
    obs: Option<Recorder>,
}

impl Device {
    pub fn new(
        llm: &LlmConfig,
        hw: &HwConfig,
        mapping: MappingKind,
        slots: usize,
        id: usize,
    ) -> Self {
        Self::with_sched(llm, hw, mapping, slots, id, SchedConfig::default())
    }

    pub fn with_sched(
        llm: &LlmConfig,
        hw: &HwConfig,
        mapping: MappingKind,
        slots: usize,
        id: usize,
        sched: SchedConfig,
    ) -> Self {
        assert!(slots > 0);
        if let Some(c) = sched.chunk {
            assert!(c > 0, "chunk size must be positive");
        }
        Device {
            id,
            mapping,
            sched,
            kv_per_token: llm.kv_bytes_per_token(),
            cost: CostModel::new(llm, hw, mapping),
            queue: VecDeque::new(),
            q_min_ready: Cell::new(Some(f64::INFINITY)),
            prefilling: Vec::new(),
            active: vec![None; slots],
            n_active: 0,
            now: 0.0,
            served: Vec::new(),
            decode_steps: 0,
            prefills: 0,
            busy: 0.0,
            last_active: 0.0,
            evictions: 0,
            recompute_tokens: 0,
            kv_peak: 0,
            power: None,
            dvfs: DvfsConfig::nominal(&hw.power),
            obs: None,
        }
    }

    /// Attach per-event energy attribution (and, with a [`ThermalConfig`],
    /// live TDP throttling) to this device. Call before pushing work.
    /// Without a thermal cap the replay's latency results stay
    /// bit-identical to the untracked device — the energy charged per
    /// event is the energy half of the same [`PhaseCost`] that advances
    /// the clock, so attribution adds no extra `simulate_graph` walks.
    pub fn enable_power(&mut self, hw: &HwConfig, thermal: Option<ThermalConfig>) {
        self.power = Some(DevicePower::new(hw, thermal.map(ThermalModel::new)));
    }

    /// The power/thermal state, if tracking is enabled.
    pub fn power(&self) -> Option<&DevicePower> {
        self.power.as_ref()
    }

    /// Attach a request-lifecycle span recorder ([`crate::obs`]) to this
    /// device. Call before pushing work. Recording is pure observation —
    /// spans copy the same `f64` start/duration values that advance the
    /// clock, so an instrumented replay is bit-identical to an untracked
    /// one and [`Recorder::busy_total`] reconciles exactly with `busy`.
    pub fn enable_obs(&mut self) {
        self.obs = Some(Recorder::new());
    }

    /// [`enable_obs`](Self::enable_obs) with a retention cap: at most
    /// `cap` spans (and `cap` events) are kept, the rest counted in
    /// [`Recorder::dropped`] — busy totals stay exact either way. The
    /// monitored-streaming path, mirroring `ServeOptions::streaming`.
    pub fn enable_obs_capped(&mut self, cap: usize) {
        self.obs = Some(Recorder::with_cap(cap));
    }

    /// The recorded span timeline, if observability is enabled.
    pub fn obs(&self) -> Option<&Recorder> {
        self.obs.as_ref()
    }

    /// Cost-oracle lookups served from memo tables without a walk.
    pub fn cost_memo_hits(&self) -> u64 {
        self.cost.memo_hits()
    }

    /// Record one busy span (no-op when obs is off). Reads the power
    /// plane's cumulative throttle time so thermal/DVFS transitions
    /// surface as instant events on the device track.
    fn record_span(&mut self, kind: SpanKind, start: f64, dur: f64, arrival: f64, batch: usize) {
        if self.obs.is_none() {
            return;
        }
        let (throttled, rung) = match &self.power {
            Some(pw) => (pw.throttled_s, pw.governor_rung()),
            None => (0.0, 0),
        };
        if let Some(rec) = &mut self.obs {
            rec.busy_span(Span { kind, start, dur, arrival, batch }, throttled, rung);
        }
    }

    /// Record one instant event (no-op when obs is off).
    fn record_event(&mut self, kind: EventKind, t: f64, arrival: f64) {
        if let Some(rec) = &mut self.obs {
            rec.event(kind, t, arrival);
        }
    }

    /// Override the per-phase DVFS operating points (nominal by default).
    pub fn set_dvfs(&mut self, dvfs: DvfsConfig) {
        self.dvfs = dvfs;
    }

    pub fn dvfs(&self) -> &DvfsConfig {
        &self.dvfs
    }

    /// `simulate_graph` walks this device's cost oracle has performed.
    pub fn cost_walks(&self) -> u64 {
        self.cost.walks()
    }

    /// Charge one busy event of the given phase starting at `start` and
    /// return the duration the clock must advance by: the nominal
    /// latency scaled by the phase's DVFS point, then — with power
    /// tracking on — stretched by the thermal scalar throttle or the
    /// stepped governor, with the event's energy attributed from the
    /// same joint cost.
    fn charge(&mut self, start: f64, nominal: PhaseCost, phase: Phase) -> f64 {
        match &mut self.power {
            None => nominal.latency * self.dvfs.point(phase).time_scale(),
            Some(pw) => pw.busy_event(start, nominal, &self.dvfs, phase),
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn sched(&self) -> &SchedConfig {
        &self.sched
    }

    /// Override the resident-KV budget (heterogeneous fleets).
    pub fn set_kv_capacity(&mut self, cap: Option<u64>) {
        self.sched.kv_capacity = cap;
    }

    pub fn kv_capacity(&self) -> Option<u64> {
        self.sched.kv_capacity
    }

    pub fn active_count(&self) -> usize {
        self.n_active
    }

    /// Minimum `ready` across queued jobs (`INFINITY` when empty),
    /// served from the dirty-min cache; a stale cache is rebuilt with
    /// one scan.
    fn queue_min_ready(&self) -> f64 {
        match self.q_min_ready.get() {
            Some(m) => {
                debug_assert_eq!(
                    m.to_bits(),
                    self.scan_queue_min().to_bits(),
                    "stale queue min-ready cache"
                );
                m
            }
            None => {
                let m = self.scan_queue_min();
                self.q_min_ready.set(Some(m));
                m
            }
        }
    }

    fn scan_queue_min(&self) -> f64 {
        self.queue.iter().map(|(j, _)| j.ready()).fold(f64::INFINITY, f64::min)
    }

    /// Queue insert that keeps a fresh min-ready cache fresh (a push can
    /// only lower the min).
    fn enqueue(&mut self, job: DeviceJob, tag: ReqTag) {
        if let Some(m) = self.q_min_ready.get() {
            self.q_min_ready.set(Some(m.min(job.ready())));
        }
        self.queue.push_back((job, tag));
    }

    /// KV bytes resident right now: active decode contexts plus the
    /// cached prefix of every in-progress chunked prefill.
    pub fn kv_resident_bytes(&self) -> u64 {
        let tokens = self.active.iter().flatten().map(|s| s.ctx).sum::<usize>()
            + self.prefilling.iter().map(PrefillingJob::kv_resident_tokens).sum::<usize>();
        tokens as u64 * self.kv_per_token
    }

    /// KV bytes committed: like [`kv_resident_bytes`](Self::kv_resident_bytes)
    /// but charging each in-progress prefill its *full* prompt, so that
    /// admission decisions account for growth already promised.
    pub fn kv_committed_bytes(&self) -> u64 {
        let tokens = self.active.iter().flatten().map(|s| s.ctx).sum::<usize>()
            + self.prefilling.iter().map(PrefillingJob::kv_committed_tokens).sum::<usize>();
        tokens as u64 * self.kv_per_token
    }

    /// Lifetime KV bytes promised to jobs delivered to this device's
    /// queue but not yet admitted. Invisible to `kv_committed_bytes`
    /// (admission hasn't reserved them), but a router must count them or
    /// it keeps placing work on a device whose budget is already spoken
    /// for by its own backlog.
    pub fn kv_queued_bytes(&self) -> u64 {
        let tokens: usize = self.queue.iter().map(|(j, _)| j.kv_lifetime_tokens()).sum();
        tokens as u64 * self.kv_per_token
    }

    /// Lifetime KV bytes of the prefill-handoff work parked on this
    /// device (queued [`DeviceJob::PrefillOnly`] jobs plus in-progress
    /// handoff prefills): KV this device will soon push *into the decode
    /// pool*. Not charged against this device's own budget (handoff KV is
    /// transient here), but a capacity-aware router reads it to steer new
    /// prefills away from devices about to flood a pressured decode pool.
    pub fn handoff_backlog_bytes(&self) -> u64 {
        let queued: usize = self
            .queue
            .iter()
            .map(|(j, _)| match j {
                DeviceJob::PrefillOnly { l_in, l_out, .. } => l_in + (*l_out).max(1),
                _ => 0,
            })
            .sum();
        let streaming: usize = self
            .prefilling
            .iter()
            .map(|p| match p.kind {
                PrefillKind::Handoff { l_out, .. } => p.l_in + l_out.max(1),
                _ => 0,
            })
            .sum();
        (queued + streaming) as u64 * self.kv_per_token
    }

    /// Uncommitted, unpromised KV budget (`u64::MAX` when unlimited) —
    /// what a capacity-aware router reads before placing decode work
    /// here: capacity minus committed residency minus the queued
    /// backlog's lifetime KV.
    pub fn kv_headroom(&self) -> u64 {
        match self.sched.kv_capacity {
            None => u64::MAX,
            Some(cap) => {
                cap.saturating_sub(self.kv_committed_bytes())
                    .saturating_sub(self.kv_queued_bytes())
            }
        }
    }

    /// Jobs delivered to this device but not yet admitted.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Instantaneous telemetry for the windowed monitor: queue/active/KV
    /// state plus the cumulative busy/throttle/energy meters. Pure reads
    /// of existing accumulators — sampling never perturbs the replay.
    pub fn telemetry(&self) -> crate::obs::DeviceGauges {
        let (throttled_s, energy_j) = match &self.power {
            Some(pw) => (pw.throttled_s, pw.energy.total()),
            None => (0.0, 0.0),
        };
        crate::obs::DeviceGauges {
            queue_depth: self.queue.len() as u64,
            active: (self.active_count() + self.prefilling.len()) as u64,
            kv_resident_bytes: self.kv_resident_bytes(),
            busy_s: self.busy,
            throttled_s,
            energy_j,
        }
    }

    /// Queued + in-flight work, the load metric for least-loaded routing.
    pub fn load(&self) -> usize {
        self.queue.len() + self.prefilling.len() + self.active_count()
    }

    pub fn has_work(&self) -> bool {
        self.active_count() > 0 || !self.prefilling.is_empty() || !self.queue.is_empty()
    }

    /// Earliest time this device can usefully run a cycle: immediately if
    /// anything is active or ready, else when the first queued job becomes
    /// ready. `None` when fully idle.
    pub fn next_action_time(&self) -> Option<f64> {
        if self.n_active > 0 || !self.prefilling.is_empty() {
            return Some(self.now);
        }
        let min_ready = self.queue_min_ready();
        if min_ready.is_finite() {
            Some(self.now.max(min_ready))
        } else {
            None
        }
    }

    /// Move the clock forward to `t` while idle (never backwards).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    pub fn push(&mut self, job: DeviceJob) {
        let tag = ReqTag { tenant: 0, session: 0, tokens: job.output_tokens() };
        self.push_tagged(job, tag);
    }

    /// [`push`](Self::push) with an explicit request identity — the
    /// fleet's path, so tenant/session/token counts survive onto the
    /// [`ServedRequest`] wherever the request finishes.
    pub fn push_tagged(&mut self, job: DeviceJob, tag: ReqTag) {
        self.record_event(EventKind::Queued, job.ready(), job.arrival());
        self.enqueue(job, tag);
    }

    /// Index of the next job to admit under the configured policy, or
    /// `None` when nothing is ready. FIFO preserves the original loop's
    /// head-of-line blocking exactly; the other policies scan all ready
    /// jobs.
    fn next_admission(&self, t0: f64) -> Option<usize> {
        match self.sched.admission {
            AdmissionPolicy::Fifo => match self.queue.front() {
                Some((j, _)) if j.ready() <= t0 => Some(0),
                _ => None,
            },
            AdmissionPolicy::ShortestFirst => self
                .queue
                .iter()
                .enumerate()
                .filter(|(_, (j, _))| j.ready() <= t0)
                .min_by_key(|&(i, (j, _))| (j.prefill_work(), i))
                .map(|(i, _)| i),
            AdmissionPolicy::Interactive => self
                .queue
                .iter()
                .enumerate()
                .filter(|(_, (j, _))| j.ready() <= t0)
                .min_by_key(|&(i, (j, _))| (j.prefill_work() > INTERACTIVE_CUTOFF, i))
                .map(|(i, _)| i),
        }
    }

    /// A decode slot that is neither occupied nor reserved by an
    /// in-progress chunked prefill.
    fn free_slot(&self) -> Option<usize> {
        (0..self.active.len()).find(|&i| {
            self.active[i].is_none()
                && !self.prefilling.iter().any(|p| p.reserved_slot() == Some(i))
        })
    }

    /// Would admitting `tokens` KV tokens overflow the budget? Always
    /// admits when the device is otherwise empty (progress guarantee for
    /// requests larger than the budget).
    fn kv_admission_blocked(&self, tokens: usize) -> bool {
        let Some(cap) = self.sched.kv_capacity else { return false };
        if self.n_active == 0 && self.prefilling.is_empty() {
            return false;
        }
        self.kv_committed_bytes() + tokens as u64 * self.kv_per_token > cap
    }

    /// Run one scheduling cycle: admit ready jobs under the admission
    /// policy (serialized prefills advance the clock; chunked prefills
    /// run one chunk per in-progress prompt), evict under KV pressure,
    /// then run one batched decode step over the active slots. Returns
    /// any prefill handoffs completed this cycle.
    pub fn step_cycle(&mut self) -> Vec<PrefillDone> {
        debug_assert_eq!(
            self.n_active,
            self.active.iter().flatten().count(),
            "active-slot counter out of sync"
        );
        let mut handoffs = Vec::new();
        // idle-advance: nothing running and nothing ready yet -> jump to
        // the first queued job's ready time
        if self.n_active == 0 && self.prefilling.is_empty() && !self.queue.is_empty() {
            let min_ready = self.queue_min_ready();
            self.now = self.now.max(min_ready);
        }
        // admissions against the cycle-start clock (jobs becoming ready
        // mid-admission wait for the next cycle, as in the original loop)
        let t0 = self.now;
        match self.sched.chunk {
            None => self.admit_serialized(t0, &mut handoffs),
            Some(chunk) => {
                self.admit_chunked(t0);
                self.run_prefill_chunks(chunk, &mut handoffs);
            }
        }
        self.evict_for_decode();
        self.run_decode_step();
        self.kv_peak = self.kv_peak.max(self.kv_resident_bytes());
        handoffs
    }

    /// Serialized admission: each admitted prefill occupies the whole
    /// device and advances its clock (the original replay-loop path).
    fn admit_serialized(&mut self, t0: f64, handoffs: &mut Vec<PrefillDone>) {
        loop {
            let Some(idx) = self.next_admission(t0) else { break };
            let needs_slot = !matches!(self.queue[idx].0, DeviceJob::PrefillOnly { .. });
            if needs_slot {
                let Some(slot) = self.free_slot() else { break };
                if self.kv_admission_blocked(self.queue[idx].0.kv_admit_tokens()) {
                    let blocked = self.queue[idx].0.arrival();
                    self.record_event(EventKind::AdmitBlocked, self.now, blocked);
                    break;
                }
                let (job, tag) = self.queue.remove(idx).unwrap();
                self.q_min_ready.set(None);
                match job {
                    DeviceJob::Full { arrival, ready, l_in, l_out } => {
                        let c = self.cost.prefill(l_in);
                        let start = self.now.max(ready);
                        let p = self.charge(start, c, Phase::Prefill);
                        self.now = start + p;
                        self.busy += p;
                        self.last_active = self.now;
                        self.prefills += 1;
                        self.record_span(SpanKind::Prefill, start, p, arrival, 1);
                        self.active[slot] = Some(ActiveSeq {
                            arrival,
                            first_token_at: self.now,
                            ctx: l_in,
                            remaining: l_out.saturating_sub(1),
                            tag,
                        });
                        self.n_active += 1;
                    }
                    DeviceJob::DecodeOnly { arrival, first_token_at, ctx, remaining, .. } => {
                        self.active[slot] =
                            Some(ActiveSeq { arrival, first_token_at, ctx, remaining, tag });
                        self.n_active += 1;
                    }
                    DeviceJob::Resume { arrival, ready, first_token_at, ctx, remaining } => {
                        // recompute the evicted KV prefix, then resume
                        // decoding; TTFT was already earned
                        let c = self.cost.prefill(ctx);
                        let start = self.now.max(ready);
                        let p = self.charge(start, c, Phase::Prefill);
                        self.now = start + p;
                        self.busy += p;
                        self.last_active = self.now;
                        self.record_span(SpanKind::Recompute, start, p, arrival, 1);
                        self.active[slot] =
                            Some(ActiveSeq { arrival, first_token_at, ctx, remaining, tag });
                        self.n_active += 1;
                    }
                    DeviceJob::PrefillOnly { .. } => unreachable!(),
                }
            } else {
                let (job, tag) = self.queue.remove(idx).unwrap();
                self.q_min_ready.set(None);
                match job {
                    DeviceJob::PrefillOnly { arrival, ready, l_in, l_out, decode_dev } => {
                        let c = self.cost.prefill(l_in);
                        let start = self.now.max(ready);
                        let p = self.charge(start, c, Phase::Prefill);
                        self.now = start + p;
                        self.busy += p;
                        self.last_active = self.now;
                        self.prefills += 1;
                        self.record_span(SpanKind::Prefill, start, p, arrival, 1);
                        handoffs.push(PrefillDone {
                            arrival,
                            done_at: self.now,
                            l_in,
                            l_out,
                            decode_dev,
                            tag,
                        });
                    }
                    _ => unreachable!(),
                }
            }
        }
    }

    /// Chunked admission: ready jobs join the prefilling set (reserving a
    /// decode slot when they will decode here) without running yet.
    ///
    /// Concurrent in-progress prefills are capped at the slot count —
    /// Full/Resume jobs are bounded by slot reservation anyway, and the
    /// cap extends the same bound to slot-free handoff prefills. Without
    /// it a backlogged prefill-pool device would stream *every* queued
    /// prompt one chunk per cycle, stretching each prompt's completion by
    /// the whole backlog (Sarathi-style chunked prefill bounds the
    /// in-flight set for the same reason).
    fn admit_chunked(&mut self, t0: f64) {
        loop {
            if self.prefilling.len() >= self.active.len() {
                break;
            }
            let Some(idx) = self.next_admission(t0) else { break };
            if self.kv_admission_blocked(self.queue[idx].0.kv_admit_tokens()) {
                let blocked = self.queue[idx].0.arrival();
                self.record_event(EventKind::AdmitBlocked, self.now, blocked);
                break;
            }
            let needs_slot = !matches!(self.queue[idx].0, DeviceJob::PrefillOnly { .. });
            let slot = if needs_slot {
                match self.free_slot() {
                    Some(s) => s,
                    None => break,
                }
            } else {
                usize::MAX // unused
            };
            let (job, tag) = self.queue.remove(idx).unwrap();
            self.q_min_ready.set(None);
            match job {
                DeviceJob::Full { arrival, l_in, l_out, .. } => {
                    self.prefilling.push(PrefillingJob {
                        arrival,
                        offset: 0,
                        l_in,
                        kind: PrefillKind::Full { slot, l_out },
                        tag,
                    });
                }
                DeviceJob::PrefillOnly { arrival, l_in, l_out, decode_dev, .. } => {
                    self.prefilling.push(PrefillingJob {
                        arrival,
                        offset: 0,
                        l_in,
                        kind: PrefillKind::Handoff { decode_dev, l_out },
                        tag,
                    });
                }
                DeviceJob::DecodeOnly { arrival, first_token_at, ctx, remaining, .. } => {
                    self.active[slot] =
                        Some(ActiveSeq { arrival, first_token_at, ctx, remaining, tag });
                    self.n_active += 1;
                }
                DeviceJob::Resume { arrival, first_token_at, ctx, remaining, .. } => {
                    self.prefilling.push(PrefillingJob {
                        arrival,
                        offset: 0,
                        l_in: ctx,
                        kind: PrefillKind::Resume { slot, first_token_at, remaining },
                        tag,
                    });
                }
            }
        }
    }

    /// Run one chunk for every in-progress prefill, oldest-admitted
    /// first: short prompts complete (and start decoding, or ship their
    /// KV handoff) while long ones are still streaming through.
    fn run_prefill_chunks(&mut self, chunk: usize, handoffs: &mut Vec<PrefillDone>) {
        let mut i = 0;
        while i < self.prefilling.len() {
            let offset = self.prefilling[i].offset;
            let take = chunk.min(self.prefilling[i].l_in - offset);
            let c = self.cost.prefill_chunk(offset, take);
            let start = self.now;
            let dt = self.charge(start, c, Phase::Prefill);
            self.now += dt;
            self.busy += dt;
            self.last_active = self.now;
            let arrival = self.prefilling[i].arrival;
            let kind = match self.prefilling[i].kind {
                PrefillKind::Resume { .. } => SpanKind::Recompute,
                _ => SpanKind::PrefillChunk,
            };
            self.record_span(kind, start, dt, arrival, 1);
            self.prefilling[i].offset += take;
            if self.prefilling[i].offset == self.prefilling[i].l_in {
                let job = self.prefilling.remove(i);
                match job.kind {
                    PrefillKind::Full { slot, l_out } => {
                        self.prefills += 1;
                        self.active[slot] = Some(ActiveSeq {
                            arrival: job.arrival,
                            first_token_at: self.now,
                            ctx: job.l_in,
                            remaining: l_out.saturating_sub(1),
                            tag: job.tag,
                        });
                        self.n_active += 1;
                    }
                    PrefillKind::Handoff { decode_dev, l_out } => {
                        self.prefills += 1;
                        handoffs.push(PrefillDone {
                            arrival: job.arrival,
                            done_at: self.now,
                            l_in: job.l_in,
                            l_out,
                            decode_dev,
                            tag: job.tag,
                        });
                    }
                    PrefillKind::Resume { slot, first_token_at, remaining } => {
                        self.active[slot] = Some(ActiveSeq {
                            arrival: job.arrival,
                            first_token_at,
                            ctx: job.l_in,
                            remaining,
                            tag: job.tag,
                        });
                        self.n_active += 1;
                    }
                }
            } else {
                i += 1;
            }
        }
    }

    /// Under a KV budget, make room for this cycle's decode growth (one
    /// token per active sequence) before stepping: evict youngest-arrival
    /// sequences back to the queue for recompute until the post-step
    /// committed footprint fits. The last remaining sequence is never
    /// evicted while it is the only in-flight work (progress guarantee);
    /// when a chunked prefill is also streaming, even a lone decode
    /// sequence may be evicted — otherwise its growth alongside the
    /// prefill's would creep past the budget with no recourse.
    fn evict_for_decode(&mut self) {
        let Some(cap) = self.sched.kv_capacity else { return };
        loop {
            let batch = self.active_count() as u64;
            if batch == 0
                || (batch == 1 && self.prefilling.is_empty())
                || self.kv_committed_bytes() + batch * self.kv_per_token <= cap
            {
                break;
            }
            let slot = self
                .active
                .iter()
                .enumerate()
                .filter_map(|(i, s)| s.as_ref().map(|s| (i, s.arrival)))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            let s = self.active[slot].take().unwrap();
            self.n_active -= 1;
            self.evictions += 1;
            self.recompute_tokens += s.ctx as u64;
            self.record_event(EventKind::Evicted, self.now, s.arrival);
            let resume = DeviceJob::Resume {
                arrival: s.arrival,
                ready: self.now,
                first_token_at: s.first_token_at,
                ctx: s.ctx,
                remaining: s.remaining,
            };
            self.enqueue(resume, s.tag);
        }
    }

    /// One batched decode step at the mean active context.
    fn run_decode_step(&mut self) {
        let batch = self.active_count();
        if batch == 0 {
            return;
        }
        let mean_ctx = self.active.iter().flatten().map(|s| s.ctx).sum::<usize>() / batch;
        let c = self.cost.decode_step(batch, mean_ctx);
        let start = self.now;
        let dt = self.charge(start, c, Phase::Decode);
        self.now += dt;
        self.busy += dt;
        self.last_active = self.now;
        self.decode_steps += 1;
        // a decode step serves the whole batch: no single arrival
        self.record_span(SpanKind::DecodeStep, start, dt, -1.0, batch);
        // decode-batch membership side-channel: which arrivals shared
        // this step (pure observation — copies already-charged values)
        if self.obs.is_some() {
            let members: Vec<f64> = self.active.iter().flatten().map(|s| s.arrival).collect();
            if let Some(rec) = &mut self.obs {
                rec.decode_batch(start, dt, members);
            }
        }
        let observe = self.obs.is_some();
        let mut finished: Vec<f64> = Vec::new();
        for slot in self.active.iter_mut() {
            if let Some(s) = slot {
                s.ctx += 1;
                if s.remaining == 0 {
                    if observe {
                        finished.push(s.arrival);
                    }
                    self.served.push(ServedRequest {
                        arrival: s.arrival,
                        ttft: s.first_token_at - s.arrival,
                        e2e: self.now - s.arrival,
                        tenant: s.tag.tenant,
                        session: s.tag.session,
                        tokens: s.tag.tokens,
                    });
                    *slot = None;
                    self.n_active -= 1;
                } else {
                    s.remaining -= 1;
                }
            }
        }
        let done_at = self.now;
        for arrival in finished {
            self.record_event(EventKind::Done, done_at, arrival);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(slots: usize) -> Device {
        Device::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), MappingKind::Halo1, slots, 0)
    }

    fn dev_with(slots: usize, sched: SchedConfig) -> Device {
        Device::with_sched(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            MappingKind::Halo1,
            slots,
            0,
            sched,
        )
    }

    fn drain(d: &mut Device) -> u64 {
        let mut cycles = 0;
        while d.has_work() {
            d.step_cycle();
            cycles += 1;
            assert!(cycles < 100_000, "device did not drain");
        }
        cycles
    }

    fn cost_model() -> CostModel {
        CostModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), MappingKind::Halo1)
    }

    #[test]
    fn full_job_runs_prefill_then_decodes_to_completion() {
        let mut d = dev(2);
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 256, l_out: 4 });
        let mut cycles = 0;
        while d.has_work() {
            assert!(d.step_cycle().is_empty());
            cycles += 1;
            assert!(cycles < 100);
        }
        assert_eq!(d.served.len(), 1);
        assert_eq!(d.decode_steps, 4);
        assert_eq!(d.prefills, 1);
        let s = &d.served[0];
        assert!(s.ttft > 0.0 && s.e2e > s.ttft);
    }

    #[test]
    fn prefill_only_emits_handoff_without_using_slots() {
        let mut d = dev(1);
        d.push(DeviceJob::PrefillOnly {
            arrival: 0.0,
            ready: 0.0,
            l_in: 128,
            l_out: 8,
            decode_dev: 3,
        });
        d.push(DeviceJob::PrefillOnly {
            arrival: 0.0,
            ready: 0.0,
            l_in: 128,
            l_out: 8,
            decode_dev: 4,
        });
        let h = d.step_cycle();
        // both prefills drain in one cycle despite a single slot
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].decode_dev, 3);
        assert!(h[0].done_at < h[1].done_at);
        assert!(!d.has_work());
        assert_eq!(d.active_count(), 0);
        assert_eq!(d.decode_steps, 0);
    }

    #[test]
    fn decode_only_preserves_foreign_ttft() {
        let mut d = dev(2);
        d.push(DeviceJob::DecodeOnly {
            arrival: 1.0,
            ready: 2.0,
            first_token_at: 1.5,
            ctx: 64,
            remaining: 2,
        });
        while d.has_work() {
            d.step_cycle();
        }
        assert_eq!(d.served.len(), 1);
        let s = &d.served[0];
        assert!((s.ttft - 0.5).abs() < 1e-12);
        // admission waited for the KV transfer (ready = 2.0)
        assert!(s.e2e > 1.0);
        assert_eq!(d.decode_steps, 3);
    }

    #[test]
    fn idle_device_jumps_to_ready_time() {
        let mut d = dev(1);
        d.push(DeviceJob::Full { arrival: 5.0, ready: 5.0, l_in: 64, l_out: 1 });
        assert_eq!(d.next_action_time(), Some(5.0));
        d.step_cycle();
        assert!(d.now() > 5.0);
    }

    #[test]
    fn default_sched_is_serialized_fifo_unlimited() {
        let d = dev(2);
        assert_eq!(*d.sched(), SchedConfig::default());
        assert_eq!(d.sched().chunk, None);
        assert_eq!(d.sched().admission, AdmissionPolicy::Fifo);
        assert_eq!(d.sched().kv_capacity, None);
        assert_eq!(SchedConfig::serialized(), SchedConfig::default());
    }

    #[test]
    fn chunked_short_prompt_overtakes_long_prefill() {
        // A long prompt is admitted first; under chunked prefill a short
        // prompt admitted one cycle later still earns its first token
        // earlier, because each cycle runs one chunk of every in-progress
        // prefill.
        let mut d = dev_with(2, SchedConfig::chunked(64));
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 1024, l_out: 4 });
        d.push(DeviceJob::Full { arrival: 1e-9, ready: 1e-9, l_in: 64, l_out: 4 });
        drain(&mut d);
        assert_eq!(d.served.len(), 2);
        let long = d.served.iter().find(|s| s.arrival == 0.0).unwrap();
        let short = d.served.iter().find(|s| s.arrival > 0.0).unwrap();
        let long_first = long.arrival + long.ttft;
        let short_first = short.arrival + short.ttft;
        assert!(
            short_first < long_first,
            "short prompt should finish prefill first: {short_first} vs {long_first}"
        );
        assert_eq!(d.prefills, 2);
        // chunking never undercuts the monolithic prefill cost
        let mut cm = cost_model();
        assert!(d.busy >= cm.prefill(1024).latency + cm.prefill(64).latency);
    }

    #[test]
    fn serialized_fifo_runs_long_prefill_first() {
        // the contrast case for chunked_short_prompt_overtakes_long_prefill
        let mut d = dev(2);
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 1024, l_out: 4 });
        d.push(DeviceJob::Full { arrival: 1e-9, ready: 1e-9, l_in: 64, l_out: 4 });
        drain(&mut d);
        let long = d.served.iter().find(|s| s.arrival == 0.0).unwrap();
        let short = d.served.iter().find(|s| s.arrival > 0.0).unwrap();
        assert!(long.arrival + long.ttft < short.arrival + short.ttft);
    }

    #[test]
    fn shortest_first_admits_short_prompt_ahead_of_long() {
        let sched = SchedConfig::default().with_admission(AdmissionPolicy::ShortestFirst);
        let mut d = dev_with(1, sched);
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 2048, l_out: 1 });
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 64, l_out: 1 });
        drain(&mut d);
        assert_eq!(d.served.len(), 2);
        // the short prompt (pushed second) completes first under SPF
        assert_eq!(d.served[0].arrival, 0.0);
        let mut cm = cost_model();
        assert!((d.served[0].ttft - cm.prefill(64).latency).abs() < 1e-12, "{}", d.served[0].ttft);
    }

    #[test]
    fn interactive_priority_orders_by_class_then_fifo() {
        let sched = SchedConfig::default().with_admission(AdmissionPolicy::Interactive);
        let mut d = dev_with(1, sched);
        // pushed order: 5000, 1000, 100 — admission order must be
        // 100 (interactive class), then 5000 (FIFO among the rest), 1000
        for l_in in [5000usize, 1000, 100] {
            d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in, l_out: 1 });
        }
        drain(&mut d);
        assert_eq!(d.served.len(), 3);
        let mut cm = cost_model();
        let p100 = cm.prefill(100).latency;
        assert!((d.served[0].ttft - p100).abs() < 1e-12, "interactive prompt first");
        // second served is the 5000-token prompt (FIFO within the
        // non-interactive class): its prefill started after 100's
        let p5000 = cm.prefill(5000).latency;
        assert!((d.served[1].ttft - (p100 + d.cost_decode_probe() + p5000)).abs() < 1e-9);
    }

    #[test]
    fn kv_pressure_evicts_recomputes_and_conserves() {
        let llm = LlmConfig::llama2_7b();
        let kvpt = llm.kv_bytes_per_token();
        let cap = 1000 * kvpt;
        let sched = SchedConfig::default().with_kv_capacity(cap);
        let mut d = dev_with(4, sched);
        for _ in 0..4 {
            d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 200, l_out: 300 });
        }
        let mut cycles = 0u64;
        while d.has_work() {
            d.step_cycle();
            cycles += 1;
            assert!(cycles < 100_000, "kv-capped device did not drain");
            assert!(
                d.kv_resident_bytes() <= cap,
                "resident {} exceeds cap {cap} at cycle {cycles}",
                d.kv_resident_bytes()
            );
        }
        // all four admit (4 x 200 = 800 committed tokens <= 1000), then
        // decode growth of 4 tokens/step must overflow the budget
        assert!(d.evictions > 0, "expected evictions under a 1000-token budget");
        assert!(d.recompute_tokens >= 200);
        assert_eq!(d.served.len(), 4);
        assert!(d.kv_peak <= cap);
        // TTFT unaffected by eviction: every first token precedes recompute
        for s in &d.served {
            assert!(s.ttft > 0.0 && s.e2e >= s.ttft);
        }
    }

    #[test]
    fn oversized_request_still_served_when_alone() {
        let llm = LlmConfig::llama2_7b();
        let kvpt = llm.kv_bytes_per_token();
        // budget smaller than the request's own prompt
        let sched = SchedConfig::default().with_kv_capacity(100 * kvpt);
        let mut d = dev_with(2, sched);
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 400, l_out: 4 });
        drain(&mut d);
        assert_eq!(d.served.len(), 1, "progress guarantee for oversized requests");
        assert_eq!(d.evictions, 0);
    }

    #[test]
    fn queued_jobs_reduce_router_visible_headroom() {
        let llm = LlmConfig::llama2_7b();
        let kvpt = llm.kv_bytes_per_token();
        let sched = SchedConfig::default().with_kv_capacity(1000 * kvpt);
        let mut d = dev_with(2, sched);
        assert_eq!(d.kv_headroom(), 1000 * kvpt);
        // delivered but not yet admitted (ready in the future): its
        // lifetime KV (300 + 99 + 1 tokens) must already dent the
        // headroom a capacity-aware router sees
        d.push(DeviceJob::DecodeOnly {
            arrival: 0.0,
            ready: 5.0,
            first_token_at: 0.5,
            ctx: 300,
            remaining: 99,
        });
        assert_eq!(d.kv_committed_bytes(), 0);
        assert_eq!(d.kv_headroom(), 600 * kvpt);
    }

    #[test]
    fn handoff_backlog_counts_outbound_kv_only() {
        let llm = LlmConfig::llama2_7b();
        let kvpt = llm.kv_bytes_per_token();
        let mut d = dev(2);
        assert_eq!(d.handoff_backlog_bytes(), 0);
        // outbound handoff work counts its lifetime KV (l_in + l_out)
        d.push(DeviceJob::PrefillOnly {
            arrival: 0.0,
            ready: 0.0,
            l_in: 300,
            l_out: 20,
            decode_dev: 1,
        });
        assert_eq!(d.handoff_backlog_bytes(), 320 * kvpt);
        // local work does not: it never crosses into the decode pool
        d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 500, l_out: 8 });
        assert_eq!(d.handoff_backlog_bytes(), 320 * kvpt);
    }

    #[test]
    fn chunked_handoff_prefills_bounded_by_slots() {
        let mut d = dev_with(2, SchedConfig::chunked(256));
        for i in 0..6usize {
            d.push(DeviceJob::PrefillOnly {
                arrival: 0.0,
                ready: 0.0,
                l_in: 1024,
                l_out: 8,
                decode_dev: i,
            });
        }
        // first cycle: only `slots` prompts enter the prefilling set and
        // none of their 4-chunk prefills completes yet
        let h = d.step_cycle();
        assert!(h.is_empty());
        assert_eq!(d.load(), 6, "2 prefilling + 4 still queued");
        let mut handoffs = 0;
        let mut cycles = 0;
        while d.has_work() {
            handoffs += d.step_cycle().len();
            cycles += 1;
            assert!(cycles < 1000);
        }
        assert_eq!(handoffs, 6);
    }

    #[test]
    fn busy_and_last_active_bounded_by_clock() {
        let mut d = dev_with(4, SchedConfig::chunked(128));
        for i in 0..6 {
            d.push(DeviceJob::Full {
                arrival: i as f64 * 0.01,
                ready: i as f64 * 0.01,
                l_in: 256 + 128 * i,
                l_out: 8,
            });
        }
        drain(&mut d);
        assert!(d.busy <= d.now() + 1e-12);
        assert!(d.last_active <= d.now() + 1e-12);
        assert!(d.busy <= d.last_active + 1e-12);
        assert!(d.last_active > 0.0);
    }

    #[test]
    fn power_tracking_without_tdp_is_bit_identical() {
        let jobs = |d: &mut Device| {
            for i in 0..5 {
                d.push(DeviceJob::Full {
                    arrival: i as f64 * 0.02,
                    ready: i as f64 * 0.02,
                    l_in: 128 + 64 * i,
                    l_out: 6,
                });
            }
        };
        let mut plain = dev(2);
        jobs(&mut plain);
        drain(&mut plain);
        let mut tracked = dev(2);
        tracked.enable_power(&HwConfig::paper(), None);
        jobs(&mut tracked);
        drain(&mut tracked);
        assert_eq!(plain.now().to_bits(), tracked.now().to_bits());
        assert_eq!(plain.busy.to_bits(), tracked.busy.to_bits());
        for (a, b) in plain.served.iter().zip(&tracked.served) {
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
            assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
        }
        // and the tracked replay actually attributed energy per event
        // without a single extra graph walk
        let pw = tracked.power().unwrap();
        assert!(pw.energy.total() > 0.0);
        assert_eq!(pw.events.len() as u64, tracked.prefills + tracked.decode_steps);
        assert_eq!(pw.throttled_s, 0.0);
        assert_eq!(plain.cost_walks(), tracked.cost_walks());
    }

    #[test]
    fn obs_recording_is_bit_identical_and_reconciles_busy() {
        let jobs = |d: &mut Device| {
            for i in 0..5 {
                d.push(DeviceJob::Full {
                    arrival: i as f64 * 0.02,
                    ready: i as f64 * 0.02,
                    l_in: 128 + 64 * i,
                    l_out: 6,
                });
            }
        };
        let mut plain = dev(2);
        jobs(&mut plain);
        drain(&mut plain);
        let mut observed = dev(2);
        observed.enable_obs();
        jobs(&mut observed);
        drain(&mut observed);
        // observation never perturbs the simulation
        assert_eq!(plain.now().to_bits(), observed.now().to_bits());
        assert_eq!(plain.busy.to_bits(), observed.busy.to_bits());
        assert_eq!(plain.cost_walks(), observed.cost_walks());
        for (a, b) in plain.served.iter().zip(&observed.served) {
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
            assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
        }
        // every busy event left a span, and their durations fold back to
        // the device's busy accumulator bit-for-bit
        let rec = observed.obs().unwrap();
        assert_eq!(rec.spans.len() as u64, observed.prefills + observed.decode_steps);
        assert_eq!(rec.busy_total().to_bits(), observed.busy.to_bits());
        // lifecycle events: one Queued per pushed job, one Done per serve
        let queued = rec.events.iter().filter(|e| e.kind == EventKind::Queued).count();
        let done = rec.events.iter().filter(|e| e.kind == EventKind::Done).count();
        assert_eq!(queued, 5);
        assert_eq!(done, observed.served.len());
    }

    #[test]
    fn decode_batch_membership_mirrors_decode_spans() {
        let mut d = dev(4);
        d.enable_obs();
        for i in 0..4 {
            d.push(DeviceJob::Full {
                arrival: i as f64 * 0.001,
                ready: i as f64 * 0.001,
                l_in: 128,
                l_out: 8,
            });
        }
        drain(&mut d);
        let rec = d.obs().unwrap();
        // one membership record per decode step, uncapped
        assert_eq!(rec.batches.len() as u64, d.decode_steps);
        let decode_spans: Vec<_> =
            rec.spans.iter().filter(|s| s.kind == SpanKind::DecodeStep).collect();
        assert_eq!(decode_spans.len(), rec.batches.len());
        for (s, b) in decode_spans.iter().zip(&rec.batches) {
            assert_eq!(s.start.to_bits(), b.start.to_bits());
            assert_eq!(s.dur.to_bits(), b.dur.to_bits());
            assert_eq!(s.batch, b.arrivals.len(), "span batch size equals member count");
        }
        // every served arrival appears in at least one batch record
        for r in &d.served {
            assert!(
                rec.batches.iter().any(|b| b.arrivals.contains(&r.arrival)),
                "arrival {} missing from batch membership",
                r.arrival
            );
        }
    }

    #[test]
    fn kv_blocked_admission_emits_admit_blocked_events() {
        let llm = LlmConfig::llama2_7b();
        let kvpt = llm.kv_bytes_per_token();
        let sched = SchedConfig::default().with_kv_capacity(1000 * kvpt);
        let mut d = dev_with(4, sched);
        d.enable_obs();
        for _ in 0..4 {
            d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 200, l_out: 300 });
        }
        drain(&mut d);
        let rec = d.obs().unwrap();
        let blocked =
            rec.events.iter().filter(|e| e.kind == EventKind::AdmitBlocked).count();
        assert!(blocked > 0, "KV-capped backlog must record admission-gate events");
        // the gate names the request it refused
        assert!(rec
            .events
            .iter()
            .filter(|e| e.kind == EventKind::AdmitBlocked)
            .all(|e| e.arrival >= 0.0));
    }

    #[test]
    fn tdp_cap_stretches_service_time() {
        let run = |thermal: Option<ThermalConfig>| {
            let mut d = dev(4);
            d.enable_power(&HwConfig::paper(), thermal);
            for _ in 0..4 {
                d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 512, l_out: 256 });
            }
            drain(&mut d);
            d
        };
        let free = run(None);
        // short replay: shrink the thermal time constant so the package
        // reaches its throttling band within the test's busy time
        let mut cfg = ThermalConfig::paper(40.0);
        cfg.tau_s = 0.05;
        let capped = run(Some(cfg));
        assert!(
            capped.now() > free.now() * 1.2,
            "40 W cap must visibly stretch the replay: {} vs {}",
            capped.now(),
            free.now()
        );
        let pw = capped.power().unwrap();
        assert!(pw.throttled_s > 0.0);
        let th = pw.thermal.as_ref().unwrap();
        assert!(th.max_temp_c > th.cfg.ambient_c);
    }

    #[test]
    fn static_dvfs_scales_latency_identically_tracked_or_not() {
        let hw = HwConfig::paper();
        let eco = hw.power.dvfs_points.len() - 1;
        // burst arrivals: the admission order (hence the busy-event set)
        // is speed-independent, so busy time must scale exactly by 1/f
        let jobs = |d: &mut Device| {
            for _ in 0..4 {
                d.push(DeviceJob::Full { arrival: 0.0, ready: 0.0, l_in: 256, l_out: 6 });
            }
        };
        let run = |dvfs_idx: usize, power: bool| {
            let mut d = dev(2);
            if power {
                d.enable_power(&hw, None);
            }
            d.set_dvfs(DvfsConfig::with_indices(&hw.power, dvfs_idx, dvfs_idx));
            jobs(&mut d);
            drain(&mut d);
            d
        };
        // the static point is a performance knob: it applies with or
        // without power tracking, bit-identically
        let plain_eco = run(eco, false);
        let tracked_eco = run(eco, true);
        assert_eq!(plain_eco.now().to_bits(), tracked_eco.now().to_bits());
        assert_eq!(plain_eco.busy.to_bits(), tracked_eco.busy.to_bits());
        // and a lower point slows the device by exactly its 1/f stretch
        let nominal = run(0, false);
        let f = hw.power.dvfs_points[eco].f_scale;
        assert!(f < 1.0);
        let ratio = plain_eco.busy / nominal.busy;
        assert!((ratio - 1.0 / f).abs() < 1e-9, "busy stretch {ratio} vs 1/f {}", 1.0 / f);
        // no throttling is booked for a *configured* slowdown
        assert_eq!(tracked_eco.power().unwrap().throttled_s, 0.0);
    }

    impl Device {
        /// Test helper: decode-step latency probe at batch 1, context 100
        /// — the step that completes the interactive request and frees
        /// its slot for the next admission.
        fn cost_decode_probe(&mut self) -> f64 {
            self.cost.decode_step(1, 100).latency
        }
    }
}

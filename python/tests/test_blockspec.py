"""BlockSpec tiling sweeps: the kernels must be invariant to the grid
decomposition (block sizes change the HBM<->VMEM schedule, never the
numbers)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cid_gemv import cid_gemv
from compile.kernels.cim_matmul import cim_matmul_codes

RNG = np.random.default_rng(2024)

blocks = st.sampled_from([16, 32, 64, 128])


@settings(max_examples=10, deadline=None)
@given(bm=blocks, bn=blocks)
def test_cid_gemv_block_invariance(bm, bn):
    x = RNG.integers(-128, 128, (48, 200), dtype=np.int8)
    w = RNG.integers(-128, 128, (200, 96), dtype=np.int8)
    got = np.asarray(cid_gemv(jnp.asarray(x), jnp.asarray(w), block_m=bm, block_n=bn))
    np.testing.assert_array_equal(got.astype(np.int64), x.astype(np.int64) @ w.astype(np.int64))


@settings(max_examples=8, deadline=None)
@given(bm=blocks, bn=blocks)
def test_cim_codes_block_invariance(bm, bn):
    """ADC codes are computed per 128-row crossbar block regardless of the
    M/N tiling, so any block decomposition gives identical codes."""
    x = RNG.integers(-128, 128, (40, 256), dtype=np.int8)
    w = RNG.integers(-128, 128, (256, 72), dtype=np.int8)
    base = np.asarray(
        cim_matmul_codes(jnp.asarray(x), jnp.asarray(w), ref.HALO1_SPEC, block_m=128, block_n=128)
    )
    got = np.asarray(
        cim_matmul_codes(jnp.asarray(x), jnp.asarray(w), ref.HALO1_SPEC, block_m=bm, block_n=bn)
    )
    np.testing.assert_array_equal(got, base)


def test_single_row_and_column_edges():
    """Degenerate GEMV shapes (M=1, N=1) through both kernels."""
    x = RNG.integers(-128, 128, (1, 128), dtype=np.int8)
    w = RNG.integers(-128, 128, (128, 1), dtype=np.int8)
    exact = x.astype(np.int64) @ w.astype(np.int64)
    got_cid = np.asarray(cid_gemv(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got_cid.astype(np.int64), exact)
    got_cim = np.asarray(
        ref.cim_matmul_ref(jnp.asarray(x), jnp.asarray(w), ref.CimSpec(ideal=True))
    )
    np.testing.assert_array_equal(got_cim.astype(np.int64), exact)

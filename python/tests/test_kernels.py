"""Kernel-vs-oracle tests: the CORE correctness signal of the L1 layer.

The Pallas kernels must match the pure-jnp refs bit-exactly in the integer
modes and to float-association tolerance in calibrated mode; hypothesis
sweeps shapes and specs.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cim_matmul import cim_matmul, cim_matmul_codes, cim_linear
from compile.kernels.cid_gemv import cid_gemv, cid_linear

RNG = np.random.default_rng(1234)


def rand_i8(m, k):
    return RNG.integers(-128, 128, (m, k), dtype=np.int8)


def exact_i64(x, w):
    return x.astype(np.int64) @ w.astype(np.int64)


dims = st.integers(min_value=1, max_value=64)
kdims = st.sampled_from([1, 3, 64, 100, 128, 200, 256, 300])
wl = st.sampled_from([128, 64, 32])


# ---------------------------------------------------------------------- CiD


@settings(max_examples=25, deadline=None)
@given(m=dims, k=kdims, n=dims)
def test_cid_gemv_exact(m, k, n):
    """CiD kernel is an exact int8 x int8 -> int32 GEMM for any shape."""
    x, w = rand_i8(m, k), rand_i8(k, n)
    got = np.asarray(cid_gemv(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got.astype(np.int64), exact_i64(x, w))


def test_cid_gemv_matches_ref():
    x, w = rand_i8(17, 300), rand_i8(300, 65)
    got = np.asarray(cid_gemv(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.cid_gemv_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


def test_cid_gemv_extremes():
    """Saturated operands accumulate correctly (int32 headroom)."""
    x = np.full((2, 256), -128, np.int8)
    w = np.full((256, 2), 127, np.int8)
    got = np.asarray(cid_gemv(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got.astype(np.int64), exact_i64(x, w))


# ---------------------------------------------------------------------- CiM


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 16), nblk=st.integers(1, 2), n=st.integers(1, 32), w_lines=wl)
def test_cim_codes_kernel_matches_ref(m, nblk, n, w_lines):
    """Full-mode ADC codes from the Pallas kernel == oracle, bit-exact."""
    k = 128 * nblk
    x, w = rand_i8(m, k), rand_i8(k, n)
    spec = ref.CimSpec(wordlines=w_lines)
    got = np.asarray(cim_matmul_codes(jnp.asarray(x), jnp.asarray(w), spec))
    want = np.asarray(ref.cim_matmul_codes_ref(jnp.asarray(x), jnp.asarray(w), spec))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 16), k=kdims, n=st.integers(1, 32))
def test_cim_ideal_is_exact(m, k, n):
    """With an ideal ADC the whole bit-slice pipeline is exact, including
    the unsigned-domain offset corrections and -128 padding."""
    x, w = rand_i8(m, k), rand_i8(k, n)
    got = np.asarray(cim_matmul(jnp.asarray(x), jnp.asarray(w), ref.CimSpec(ideal=True)))
    np.testing.assert_array_equal(got.astype(np.int64), exact_i64(x, w))


@settings(max_examples=8, deadline=None)
@given(w_lines=st.sampled_from([128, 64]), mode=st.sampled_from(["full", "calibrated"]))
def test_cim_kernel_matches_ref_all_modes(w_lines, mode):
    x, w = rand_i8(5, 256), rand_i8(256, 9)
    spec = ref.CimSpec(wordlines=w_lines, adc_mode=mode)
    got = np.asarray(cim_matmul(jnp.asarray(x), jnp.asarray(w), spec))
    want = np.asarray(ref.cim_matmul_ref(jnp.asarray(x), jnp.asarray(w), spec))
    # full mode: identical integer codes -> identical floats. calibrated:
    # same math, different reduction order -> one-code tolerance.
    tol = 0 if mode == "full" else 2.0
    assert np.max(np.abs(got - want)) <= tol


def test_cim_full_mode_noise_is_bounded():
    """ADC quantization noise in MAC units is bounded by the shift-add
    amplification of half a code step per (bit, slice, phase)."""
    x, w = rand_i8(8, 128), rand_i8(128, 16)
    spec = ref.HALO1_SPEC
    got = np.asarray(cim_matmul(jnp.asarray(x), jnp.asarray(w), spec))
    exact = exact_i64(x, w)
    # worst case: delta/2 per conversion, amplified by sum(2^(b+2s)) = 21675
    bound = (spec.adc_delta / 2) * 21675 + 1
    assert np.max(np.abs(got - exact)) <= bound


def test_wordline_throttling_reduces_error():
    """Paper Table II / §V-C: fewer active wordlines -> finer ADC grid ->
    lower quantization error (the HALO2 accuracy argument)."""
    x, w = rand_i8(32, 512), rand_i8(512, 32)
    exact = exact_i64(x, w)
    errs = {}
    for w_lines in (128, 64, 32):
        spec = ref.CimSpec(wordlines=w_lines)
        y = np.asarray(cim_matmul(jnp.asarray(x), jnp.asarray(w), spec))
        errs[w_lines] = np.abs(y - exact).mean()
    assert errs[64] < errs[128]
    assert errs[32] < errs[64]


def test_calibrated_beats_full_range():
    """The adaptive-SNR calibrated ADC [1] outperforms worst-case sizing."""
    xf = RNG.normal(size=(16, 256)).astype(np.float32)
    wf = RNG.normal(size=(256, 32)).astype(np.float32)
    yt = xf @ wf
    err = {}
    for mode in ("full", "calibrated"):
        y = np.asarray(cim_linear(jnp.asarray(xf), jnp.asarray(wf), ref.CimSpec(adc_mode=mode)))
        err[mode] = np.abs(y - yt).mean()
    assert err["calibrated"] < 0.5 * err["full"]


@settings(max_examples=6, deadline=None)
@given(k=st.sampled_from([100, 128, 200]))
def test_cim_padding_adds_no_noise(k):
    """-128 (unsigned zero) padding must not change the result at all:
    compare a K-multiple-of-128 matmul against the same data embedded in a
    padded call."""
    x, w = rand_i8(4, k), rand_i8(k, 8)
    spec = ref.HALO1_SPEC
    y = np.asarray(cim_matmul(jnp.asarray(x), jnp.asarray(w), spec))
    # manually pre-pad to the next multiple and compare
    kp = (-k) % 128
    xp = np.pad(x, ((0, 0), (0, kp)), constant_values=-128)
    wp = np.pad(w, ((0, kp), (0, 0)), constant_values=-128)
    yp = np.asarray(cim_matmul(jnp.asarray(xp), jnp.asarray(wp), spec))
    # the padded call's exact constant 128*128*kp is part of its true
    # product; remove it to compare
    np.testing.assert_allclose(yp - 128.0 * 128.0 * kp, y, atol=1e-3)


# ------------------------------------------------------------ quantization


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_sym_roundtrip(seed):
    r = np.random.default_rng(seed)
    a = r.normal(size=(32,)).astype(np.float32) * r.uniform(0.01, 100)
    q, s = ref.quantize_sym_i8(jnp.asarray(a))
    back = np.asarray(q, np.float32) * float(s)
    assert np.abs(back - a).max() <= float(s) * 0.5 + 1e-6
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127


def test_quantize_zero_tensor():
    q, s = ref.quantize_sym_i8(jnp.zeros((8,)))
    assert np.all(np.asarray(q) == 0) and float(s) > 0


# ----------------------------------------------------------------- linears


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 8), k=st.sampled_from([32, 100, 256]), n=st.integers(1, 16))
def test_cid_linear_close_to_f32(m, k, n):
    xf = RNG.normal(size=(m, k)).astype(np.float32)
    wf = RNG.normal(size=(k, n)).astype(np.float32)
    y = np.asarray(cid_linear(jnp.asarray(xf), jnp.asarray(wf)))
    yt = xf @ wf
    # int8 fake-quant error only
    denom = np.abs(yt).mean() + 1e-6
    assert np.abs(y - yt).mean() / denom < 0.05


def test_cim_linear_batch_dims():
    """Leading batch dims are flattened and restored."""
    xf = RNG.normal(size=(2, 3, 64)).astype(np.float32)
    wf = RNG.normal(size=(64, 16)).astype(np.float32)
    y = np.asarray(cim_linear(jnp.asarray(xf), jnp.asarray(wf), ref.CimSpec(ideal=True)))
    assert y.shape == (2, 3, 16)
    y2 = np.asarray(cim_linear(jnp.asarray(xf.reshape(6, 64)), jnp.asarray(wf), ref.CimSpec(ideal=True)))
    np.testing.assert_allclose(y.reshape(6, 16), y2, rtol=1e-6)


# ------------------------------------------------------------------- spec


def test_spec_properties():
    s = ref.HALO1_SPEC
    assert s.num_slices == 4 and s.slice_max == 3 and s.adc_levels == 127
    assert s.phases_per_block == 1
    s2 = ref.HALO2_SPEC
    assert s2.phases_per_block == 2
    assert s2.adc_delta == pytest.approx(s.adc_delta / 2)


def test_adc_quantize_grid_and_clip():
    s = ref.HALO1_SPEC
    # on-grid values are preserved
    p = jnp.asarray([0.0, s.adc_delta * 10, s.adc_delta * 127])
    q = np.asarray(ref.adc_quantize(p, s))
    np.testing.assert_array_equal(q, [0, 10, 127])
    # above-range saturates
    q2 = np.asarray(ref.adc_quantize(jnp.asarray([1e6]), s))
    assert q2[0] == 127

//! Named scenario mixes layered on the Poisson trace machinery.
//!
//! Each mix is a distribution over (prompt length, output length) pairs —
//! log-uniform within a band, mirroring `poisson_trace` — chosen to stress
//! a different side of the prefill/decode dichotomy:
//!
//! * **chat**: short-in / short-out — balanced, latency-sensitive;
//! * **summarization**: long-in / short-out — prefill-dominated;
//! * **generation**: short-in / long-out — decode-dominated;
//! * **interactive**: a 50/25/25 blend of the three.

use crate::sim::queueing::{
    log_uniform, trace_with, trace_with_tenants, ServedRequest, TraceRequest,
};
use crate::util::{percentile, Rng};

/// Named workload mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mix {
    Chat,
    Summarization,
    Generation,
    Interactive,
}

impl Mix {
    pub fn all() -> [Mix; 4] {
        [Mix::Chat, Mix::Summarization, Mix::Generation, Mix::Interactive]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mix::Chat => "chat",
            Mix::Summarization => "summarization",
            Mix::Generation => "generation",
            Mix::Interactive => "interactive",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "chat" => Some(Mix::Chat),
            "summarization" | "summarize" | "sum" => Some(Mix::Summarization),
            "generation" | "gen" => Some(Mix::Generation),
            "interactive" | "mixed" | "blend" => Some(Mix::Interactive),
            _ => None,
        }
    }

    /// (l_in, l_out) bands: short-in/short-out, long-in/short-out,
    /// short-in/long-out.
    fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        match self {
            Mix::Chat => (log_uniform(rng, 64, 512), log_uniform(rng, 64, 256)),
            Mix::Summarization => (log_uniform(rng, 2048, 8192), log_uniform(rng, 32, 128)),
            Mix::Generation => (log_uniform(rng, 64, 256), log_uniform(rng, 512, 2048)),
            Mix::Interactive => {
                let u = rng.f64();
                if u < 0.5 {
                    Mix::Chat.sample(rng)
                } else if u < 0.75 {
                    Mix::Summarization.sample(rng)
                } else {
                    Mix::Generation.sample(rng)
                }
            }
        }
    }

    /// Poisson-arrival trace of `n` requests from this mix.
    pub fn trace(&self, seed: u64, n: usize, rate_per_s: f64) -> Vec<TraceRequest> {
        trace_with(seed, n, rate_per_s, |rng| self.sample(rng))
    }

    /// [`Mix::trace`] with each request tagged by a uniformly drawn
    /// tenant in `[0, tenants)`; `tenants <= 1` is bit-identical to
    /// [`Mix::trace`].
    pub fn trace_tenants(
        &self,
        seed: u64,
        n: usize,
        rate_per_s: f64,
        tenants: usize,
    ) -> Vec<TraceRequest> {
        trace_with_tenants(seed, n, rate_per_s, tenants, |rng| self.sample(rng))
    }
}

/// Per-tenant share of a replay (requests, TTFT/e2e percentiles, decode
/// token throughput over the fleet makespan).
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub tenant: usize,
    pub requests: usize,
    /// Output tokens generated for this tenant.
    pub tokens: u64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    pub tok_per_s: f64,
}

/// Aggregate per-tenant stats straight off the served records — no
/// trace retention required, since tenant identity and token counts now
/// travel on [`ServedRequest`] itself. This is the streaming-safe path:
/// a bounded-retention [`serve`](crate::cluster::Fleet::serve) keeps
/// only a sample of served records, so pass the full population (an
/// exact replay, or the retained window knowingly). Rows come back
/// sorted by tenant.
pub fn per_tenant_stats_served(served: &[ServedRequest], makespan: f64) -> Vec<TenantStats> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<usize, (Vec<f64>, Vec<f64>, u64)> = BTreeMap::new();
    for s in served {
        let g = groups.entry(s.tenant).or_default();
        g.0.push(s.ttft);
        g.1.push(s.e2e);
        g.2 += s.tokens;
    }
    groups
        .into_iter()
        .map(|(tenant, (ttfts, e2es, tokens))| TenantStats {
            tenant,
            requests: ttfts.len(),
            tokens,
            ttft_p50: percentile(&ttfts, 50.0),
            ttft_p99: percentile(&ttfts, 99.0),
            e2e_p50: percentile(&e2es, 50.0),
            e2e_p99: percentile(&e2es, 99.0),
            tok_per_s: tokens as f64 / makespan.max(1e-12),
        })
        .collect()
}

/// Join served records back to their trace requests (arrivals are
/// strictly increasing, hence unique) and aggregate per tenant. Tenants
/// absent from the trace produce no row; rows come back sorted by tenant.
/// Legacy compatibility path — prefer [`per_tenant_stats_served`], which
/// needs no materialized trace.
pub fn per_tenant_stats(
    trace: &[TraceRequest],
    served: &[ServedRequest],
    makespan: f64,
) -> Vec<TenantStats> {
    use std::collections::{BTreeMap, HashMap};
    let by_arrival: HashMap<u64, &TraceRequest> =
        trace.iter().map(|r| (r.arrival.to_bits(), r)).collect();
    let mut groups: BTreeMap<usize, (Vec<f64>, Vec<f64>, u64)> = BTreeMap::new();
    for s in served {
        let Some(req) = by_arrival.get(&s.arrival.to_bits()) else { continue };
        let g = groups.entry(req.tenant).or_default();
        g.0.push(s.ttft);
        g.1.push(s.e2e);
        g.2 += req.l_out as u64;
    }
    groups
        .into_iter()
        .map(|(tenant, (ttfts, e2es, tokens))| TenantStats {
            tenant,
            requests: ttfts.len(),
            tokens,
            ttft_p50: percentile(&ttfts, 50.0),
            ttft_p99: percentile(&ttfts, 99.0),
            e2e_p50: percentile(&e2es, 50.0),
            e2e_p99: percentile(&e2es, 99.0),
            tok_per_s: tokens as f64 / makespan.max(1e-12),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_respect_bands() {
        let tr = Mix::Chat.trace(1, 500, 10.0);
        assert_eq!(tr.len(), 500);
        assert!(tr.iter().all(|r| (64..=512).contains(&r.l_in) && (64..=256).contains(&r.l_out)));
        let tr = Mix::Summarization.trace(2, 500, 10.0);
        assert!(tr.iter().all(|r| r.l_in >= 2048 && r.l_out <= 128));
        let tr = Mix::Generation.trace(3, 500, 10.0);
        assert!(tr.iter().all(|r| r.l_in <= 256 && r.l_out >= 512));
    }

    #[test]
    fn interactive_blends_all_three() {
        let tr = Mix::Interactive.trace(7, 2000, 10.0);
        let sum = tr.iter().filter(|r| r.l_in >= 2048).count();
        let gen = tr.iter().filter(|r| r.l_out >= 512).count();
        let chat = tr.iter().filter(|r| r.l_in <= 512 && r.l_out <= 256).count();
        // 50/25/25 split with slack
        assert!((800..=1200).contains(&chat), "{chat}");
        assert!((300..=700).contains(&sum), "{sum}");
        assert!((300..=700).contains(&gen), "{gen}");
        // arrivals strictly increase (Poisson machinery intact)
        assert!(tr.windows(2).all(|w| w[0].arrival < w[1].arrival));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Mix::Interactive.trace(9, 100, 5.0);
        let b = Mix::Interactive.trace(9, 100, 5.0);
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival && x.l_in == y.l_in && x.l_out == y.l_out
        }));
        let c = Mix::Interactive.trace(10, 100, 5.0);
        assert!(a.iter().zip(&c).any(|(x, y)| x.l_in != y.l_in || x.arrival != y.arrival));
    }

    #[test]
    fn by_name_roundtrip() {
        for m in Mix::all() {
            assert_eq!(Mix::by_name(m.name()), Some(m));
        }
        assert!(Mix::by_name("batch").is_none());
    }

    #[test]
    fn tenant_stats_join_and_conserve() {
        use crate::cluster::{Interconnect, Policy};
        use crate::config::HwConfig;
        use crate::model::LlmConfig;
        let llm = LlmConfig::llama2_7b();
        let trace = Mix::Chat.trace_tenants(5, 80, 50.0, 3);
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, &HwConfig::paper(), 2, 8, 0.5, Interconnect::board());
        let r = fleet.replay(&trace, router.as_mut());
        let stats = per_tenant_stats(&trace, &r.served, r.makespan);
        // every request lands in exactly one tenant bucket
        assert_eq!(stats.iter().map(|t| t.requests).sum::<usize>(), 80);
        let want_tokens: u64 = trace.iter().map(|q| q.l_out as u64).sum();
        assert_eq!(stats.iter().map(|t| t.tokens).sum::<u64>(), want_tokens);
        // tenants come back sorted, with sane latency orderings
        assert!(stats.windows(2).all(|w| w[0].tenant < w[1].tenant));
        for t in &stats {
            assert!(t.requests > 0);
            assert!(t.ttft_p50 > 0.0 && t.ttft_p99 >= t.ttft_p50);
            assert!(t.e2e_p99 >= t.e2e_p50 && t.e2e_p50 >= t.ttft_p50);
            assert!(t.tok_per_s > 0.0);
        }
    }

    #[test]
    fn served_based_tenant_stats_agree_with_legacy_join() {
        use crate::cluster::{Interconnect, Policy};
        use crate::config::HwConfig;
        use crate::model::LlmConfig;
        let llm = LlmConfig::llama2_7b();
        let trace = Mix::Interactive.trace_tenants(6, 90, 40.0, 4);
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, &HwConfig::paper(), 2, 8, 0.5, Interconnect::board());
        let r = fleet.replay(&trace, router.as_mut());
        let legacy = per_tenant_stats(&trace, &r.served, r.makespan);
        let streaming = per_tenant_stats_served(&r.served, r.makespan);
        // identity now travels on ServedRequest, so the trace-free path
        // reproduces the legacy join bit for bit
        assert_eq!(legacy.len(), streaming.len());
        for (a, b) in legacy.iter().zip(&streaming) {
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.requests, b.requests);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.ttft_p50.to_bits(), b.ttft_p50.to_bits());
            assert_eq!(a.ttft_p99.to_bits(), b.ttft_p99.to_bits());
            assert_eq!(a.e2e_p50.to_bits(), b.e2e_p50.to_bits());
            assert_eq!(a.e2e_p99.to_bits(), b.e2e_p99.to_bits());
            assert_eq!(a.tok_per_s.to_bits(), b.tok_per_s.to_bits());
        }
    }
}

//! LLM workload model: model configurations and the operator-graph builder
//! that turns (model, phase, context lengths, batch) into the exact set of
//! GEMM / GEMV / non-GEMM operations the paper's simulator costs.

pub mod graph;
pub mod ops;

pub use graph::{build_decode_graph, build_prefill_graph, OpGraph};
pub use ops::{Op, OpClass, OpKind, Operand};

/// Transformer model configuration (decoder-only, LLaMA-style).
#[derive(Debug, Clone, PartialEq)]
pub struct LlmConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (grouped-query attention; == n_heads for MHA).
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// FFN inner dimension (SwiGLU: three projections).
    pub d_ff: usize,
    pub vocab: usize,
    /// Weight/activation precision in bytes (int8 on HALO).
    pub dtype_bytes: usize,
    /// KV-cache element size in bytes.
    pub kv_bytes: usize,
}

impl LlmConfig {
    /// LLaMA-2 7B [27]: 32 layers, d=4096, 32 heads (MHA), FFN 11008.
    pub fn llama2_7b() -> Self {
        LlmConfig {
            name: "llama2-7b",
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            d_ff: 11008,
            vocab: 32000,
            dtype_bytes: 1,
            kv_bytes: 1,
        }
    }

    /// Qwen3 8B [34]: 36 layers, d=4096, 32 Q heads / 8 KV heads (GQA),
    /// FFN 12288, large vocabulary.
    pub fn qwen3_8b() -> Self {
        LlmConfig {
            name: "qwen3-8b",
            n_layers: 36,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 12288,
            vocab: 151936,
            dtype_bytes: 1,
            kv_bytes: 1,
        }
    }

    /// The functional-plane tiny model (mirrors python TinyLlamaConfig).
    pub fn tiny() -> Self {
        LlmConfig {
            name: "tiny-llama",
            n_layers: 4,
            d_model: 256,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 64,
            d_ff: 768,
            vocab: 4096,
            dtype_bytes: 1,
            kv_bytes: 1,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" | "llama" => Some(Self::llama2_7b()),
            "qwen3-8b" | "qwen" => Some(Self::qwen3_8b()),
            "tiny" | "tiny-llama" => Some(Self::tiny()),
            _ => None,
        }
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Total weight parameters (attention + FFN + embedding/LM head).
    pub fn n_params(&self) -> u64 {
        let attn = self.d_model * (self.q_dim() + 2 * self.kv_dim()) + self.q_dim() * self.d_model;
        let ffn = 3 * self.d_model * self.d_ff;
        let per_layer = (attn + ffn) as u64;
        per_layer * self.n_layers as u64 + 2 * (self.vocab * self.d_model) as u64
    }

    /// Weight footprint in bytes at the configured precision.
    pub fn weight_bytes(&self) -> u64 {
        self.n_params() * self.dtype_bytes as u64
    }

    /// KV-cache bytes per token per sequence (K and V, all layers).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.kv_dim() * self.kv_bytes) as u64
    }
}

/// Inference phase (the paper's central dichotomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Prefill,
    Decode,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_param_count() {
        let m = LlmConfig::llama2_7b();
        // ~6.7e9 parameters (embedding + 32 layers)
        let p = m.n_params() as f64;
        assert!(p > 6.4e9 && p < 7.1e9, "{p:e}");
        assert_eq!(m.q_dim(), 4096);
        assert_eq!(m.kv_dim(), 4096);
    }

    #[test]
    fn qwen3_8b_param_count_and_gqa() {
        let m = LlmConfig::qwen3_8b();
        let p = m.n_params() as f64;
        assert!(p > 7.5e9 && p < 9.5e9, "{p:e}");
        assert_eq!(m.kv_dim(), 1024); // 8 KV heads x 128
        assert!(m.kv_bytes_per_token() < LlmConfig::llama2_7b().kv_bytes_per_token());
    }

    #[test]
    fn kv_bytes_per_token() {
        let m = LlmConfig::llama2_7b();
        // 2 * 32 layers * 4096 * 1 B = 256 KiB/token at int8
        assert_eq!(m.kv_bytes_per_token(), 2 * 32 * 4096);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(LlmConfig::by_name("llama2-7b").unwrap().name, "llama2-7b");
        assert_eq!(LlmConfig::by_name("qwen").unwrap().name, "qwen3-8b");
        assert!(LlmConfig::by_name("gpt-5").is_none());
    }

    #[test]
    fn model_fits_hbm() {
        let hw = crate::config::HwConfig::paper();
        for m in [LlmConfig::llama2_7b(), LlmConfig::qwen3_8b()] {
            assert!(m.weight_bytes() < hw.hbm.total_capacity());
        }
    }
}

//! Cluster-scale serving: a fleet of HALO devices behind a router.
//!
//! The paper's core insight — route prefill to the compute-dense CiM die
//! and decode to the bandwidth-dense CiD substrate — generalizes from
//! intra-device mapping to inter-device scheduling: a *prefill pool* of
//! Fully-CiM-mapped devices can feed a *decode pool* of Fully-CiD-mapped
//! devices over an interconnect that carries the KV cache, the
//! cluster-level analogue of HALO's Table II phase-aware mapping (and of
//! disaggregated LLM serving à la DistServe/Splitwise).
//!
//! Pieces:
//! * [`interconnect`] — inter-device link model charging a KV-cache
//!   transfer (`bytes = 2 x layers x ctx x kv_heads x head_dim`) whenever
//!   prefill and decode run on different devices;
//! * [`workload`] — named scenario mixes (chat, summarization,
//!   generation, interactive) on the Poisson trace machinery, optional
//!   per-request tenant tags ([`Mix::trace_tenants`]) and per-tenant
//!   replay breakdowns ([`per_tenant_stats`]);
//! * [`router`] — pluggable request routing: round-robin, least-loaded,
//!   phase-disaggregated (prefill pool -> decode pool), and KV-capacity-
//!   aware placement: decode skips full devices, and under decode-pool
//!   pressure prefill placement steers to the device with the smallest
//!   outbound handoff backlog;
//! * [`traffic`] — the streaming workload engine: seeded arrival
//!   processes (Poisson, bursty MMPP, diurnal rate curves), heavy-tailed
//!   prompt/output length samplers, and multi-turn sessions that
//!   re-arrive after a think time with grown context, all behind the
//!   pull-based [`WorkloadSource`] trait so traffic never has to be
//!   materialized;
//! * [`fleet`] — N independent [`sim::device::Device`](crate::sim::device)
//!   state machines advanced in global event order, each carrying its own
//!   [`SchedConfig`] (chunked prefill, admission policy, resident-KV
//!   budget with eviction-and-recompute), optionally a heterogeneous
//!   per-device KV capacity or an explicit per-device mapping
//!   composition (see [`FleetBuilder`]).
//!
//! Entry points: [`FleetBuilder`] (or [`Policy::build`] /
//! [`Policy::build_with`] for a (fleet, router) pair) to construct a
//! fleet, then [`Fleet::serve`] to pull a [`WorkloadSource`] through it
//! in bounded memory ([`ServeOptions`] caps raw-record retention;
//! counters and streaming histogram percentiles stay exact-count), or
//! [`Fleet::replay`] — a thin, bit-identical wrapper for materialized
//! traces. The [`crate::dse`] plane searches over all of these knobs at
//! once.
//!
//! Energy: [`Fleet::enable_power`] attaches the [`crate::power`] plane —
//! per-event energy accounting on every device (read off the same joint
//! [`sim::cost::PhaseCost`](crate::sim::cost) that advances each device
//! clock, so tracking adds no graph walks), optional per-package TDP
//! throttling — and KV transfers across the [`Interconnect`] are charged
//! joules per byte alongside their latency; both surface in the
//! per-device and fleet-level replay stats. [`Fleet::set_dvfs`] pins
//! per-phase DVFS operating points fleet-wide (or arms the thermal
//! stepped governor).
//!
//! Observability: [`Fleet::enable_obs`] attaches a request-lifecycle
//! span recorder ([`crate::obs`]) to every device plus an interconnect
//! track for KV handoffs — pure observation, bit-identical replays —
//! exported as a Chrome-trace timeline via [`Fleet::chrome_trace`]
//! (`halo trace`). Replay percentiles ([`FleetResult::ttft_pct`] /
//! [`FleetResult::e2e_pct`]) read cached sorted views built once at
//! collection instead of cloning and sorting per call.
//! [`Fleet::serve_monitored`] / [`Fleet::replay_monitored`] additionally
//! drive a fixed-memory [`crate::obs::WindowSeries`] from the same event
//! loop — windowed arrivals/completions/latency/utilization over
//! *simulated* time for `halo monitor`, again without perturbing a
//! single simulated f64 (monitored and unmonitored serves fingerprint
//! identically; pinned by test).

pub mod fleet;
pub mod interconnect;
pub mod router;
pub mod traffic;
pub mod workload;

pub use crate::sim::device::{AdmissionPolicy, SchedConfig};
pub use fleet::{Fleet, FleetBuilder, FleetResult, ServeOptions};
pub use interconnect::{kv_transfer_bytes, Interconnect};
pub use router::{KvAware, LeastLoaded, PhaseDisaggregated, Policy, Route, Router, RoundRobin};
pub use traffic::{
    collect_trace, ArrivalKind, ArrivalProcess, LengthSampler, SessionConfig, SliceSource,
    TrafficConfig, TrafficGen, WorkloadSource,
};
pub use workload::{per_tenant_stats, per_tenant_stats_served, Mix, TenantStats};

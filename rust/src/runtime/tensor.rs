//! Host-side tensors and conversion to/from PJRT literals/buffers.

use anyhow::{anyhow, bail, Result};

/// Element types used by the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    I8,
}

impl Dtype {
    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I8 => 1,
        }
    }
}

/// Shape + dtype signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn nelems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.nelems() * self.dtype.size()
    }
}

/// A host tensor backed by typed storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub spec: TensorSpec,
    pub data: Storage,
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor {
            spec: TensorSpec { shape: shape.to_vec(), dtype: Dtype::F32 },
            data: Storage::F32(data),
        }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor {
            spec: TensorSpec { shape: shape.to_vec(), dtype: Dtype::I32 },
            data: Storage::I32(data),
        }
    }

    pub fn i8(data: Vec<i8>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor {
            spec: TensorSpec { shape: shape.to_vec(), dtype: Dtype::I8 },
            data: Storage::I8(data),
        }
    }

    pub fn zeros(spec: TensorSpec) -> Self {
        let n = spec.nelems();
        let data = match spec.dtype {
            Dtype::F32 => Storage::F32(vec![0.0; n]),
            Dtype::I32 => Storage::I32(vec![0; n]),
            Dtype::I8 => Storage::I8(vec![0; n]),
        };
        HostTensor { spec, data }
    }

    /// Parse little-endian raw bytes (the .bin testvec/weights format).
    pub fn from_bytes(bytes: &[u8], spec: TensorSpec) -> Result<Self> {
        if bytes.len() != spec.nbytes() {
            bail!("byte length {} != spec {} ({:?})", bytes.len(), spec.nbytes(), spec);
        }
        let data = match spec.dtype {
            Dtype::F32 => Storage::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            Dtype::I32 => Storage::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            Dtype::I8 => Storage::I8(bytes.iter().map(|b| *b as i8).collect()),
        };
        Ok(HostTensor { spec, data })
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Storage::F32(v) => Ok(v),
            _ => Err(anyhow!("not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Storage::I32(v) => Ok(v),
            _ => Err(anyhow!("not i32")),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Storage::I8(v) => Ok(v),
            _ => Err(anyhow!("not i8")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Storage::F32(v) => Ok(v),
            _ => Err(anyhow!("not f32")),
        }
    }

    /// Upload to a device buffer.
    pub fn to_device(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let b = match &self.data {
            Storage::F32(v) => client.buffer_from_host_buffer(v, &self.spec.shape, None)?,
            Storage::I32(v) => client.buffer_from_host_buffer(v, &self.spec.shape, None)?,
            Storage::I8(v) => client.buffer_from_host_buffer(v, &self.spec.shape, None)?,
        };
        Ok(b)
    }

    /// Download from a literal, checking the element count.
    pub fn from_literal(lit: &xla::Literal, spec: TensorSpec) -> Result<Self> {
        if lit.element_count() != spec.nelems() {
            bail!("literal has {} elements, spec {:?}", lit.element_count(), spec);
        }
        let data = match spec.dtype {
            Dtype::F32 => Storage::F32(lit.to_vec::<f32>()?),
            Dtype::I32 => Storage::I32(lit.to_vec::<i32>()?),
            Dtype::I8 => Storage::I8(lit.to_vec::<i8>()?),
        };
        Ok(HostTensor { spec, data })
    }

    /// Max |a - b| against another tensor (for test-vector checks).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f64> {
        match (&self.data, &other.data) {
            (Storage::F32(a), Storage::F32(b)) if a.len() == b.len() => {
                Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max))
            }
            (Storage::I32(a), Storage::I32(b)) if a.len() == b.len() => {
                Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max))
            }
            (Storage::I8(a), Storage::I8(b)) if a.len() == b.len() => Ok(a
                .iter()
                .zip(b)
                .map(|(x, y)| (*x as i32 - *y as i32).abs() as f64)
                .fold(0.0, f64::max)),
            _ => Err(anyhow!("tensor mismatch: {:?} vs {:?}", self.spec, other.spec)),
        }
    }

    /// Max |value| over the tensor.
    pub fn max_abs(&self) -> Result<f64> {
        Ok(match &self.data {
            Storage::F32(v) => v.iter().map(|x| x.abs() as f64).fold(0.0, f64::max),
            Storage::I32(v) => v.iter().map(|x| x.abs() as f64).fold(0.0, f64::max),
            Storage::I8(v) => v.iter().map(|x| (*x as i32).abs() as f64).fold(0.0, f64::max),
        })
    }

    /// Row argmax for a (rows, cols) f32 tensor (greedy sampling).
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        let v = self.as_f32()?;
        if self.spec.shape.len() != 2 {
            bail!("argmax_rows needs 2-D, got {:?}", self.spec.shape);
        }
        let (rows, cols) = (self.spec.shape[0], self.spec.shape[1]);
        Ok((0..rows)
            .map(|r| {
                let row = &v[r * cols..(r + 1) * cols];
                row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_sizes() {
        let s = TensorSpec { shape: vec![2, 3, 4], dtype: Dtype::F32 };
        assert_eq!(s.nelems(), 24);
        assert_eq!(s.nbytes(), 96);
        assert_eq!(TensorSpec { shape: vec![5], dtype: Dtype::I8 }.nbytes(), 5);
    }

    #[test]
    fn from_bytes_roundtrip_f32() {
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let t = HostTensor::from_bytes(&bytes, TensorSpec { shape: vec![3], dtype: Dtype::F32 })
            .unwrap();
        assert_eq!(t.as_f32().unwrap(), &vals);
    }

    #[test]
    fn from_bytes_checks_length() {
        assert!(HostTensor::from_bytes(&[0u8; 5], TensorSpec { shape: vec![3], dtype: Dtype::F32 })
            .is_err());
    }

    #[test]
    fn i8_bytes_are_signed() {
        let t =
            HostTensor::from_bytes(&[0xff, 0x7f], TensorSpec { shape: vec![2], dtype: Dtype::I8 })
                .unwrap();
        assert_eq!(t.as_i8().unwrap(), &[-1i8, 127]);
    }

    #[test]
    fn argmax_rows_works() {
        let t = HostTensor::f32(vec![0.0, 3.0, 1.0, 9.0, -1.0, 2.0], &[2, 3]);
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
    }

    #[test]
    fn max_abs_diff() {
        let a = HostTensor::f32(vec![1.0, 2.0], &[2]);
        let b = HostTensor::f32(vec![1.5, 2.0], &[2]);
        assert!((a.max_abs_diff(&b).unwrap() - 0.5).abs() < 1e-12);
        let c = HostTensor::i32(vec![1, 2], &[2]);
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn zeros_shapes() {
        let t = HostTensor::zeros(TensorSpec { shape: vec![4, 2], dtype: Dtype::I32 });
        assert_eq!(t.as_i32().unwrap(), &[0; 8]);
    }
}

//! Versioned JSON snapshots for the `--json` CLI surfaces.
//!
//! Each snapshot carries a `schema` tag (`halo.cluster.v1`,
//! `halo.dse.v1`) so downstream tooling can dispatch on shape instead of
//! sniffing fields. Simulated quantities come from the [`Registry`] /
//! replay results; host wall times ride along under `profile` and are
//! explicitly measurement metadata, not simulation output.

use super::registry::fleet_registry;
use super::slo::SloReport;
use super::timeseries::WindowSeries;
use super::{jobj, SelfProfile};
use crate::cluster::fleet::{DeviceSummary, FleetResult};
use crate::dse::{DseResult, Metrics};
use crate::util::json::Json;

/// One replayed cluster as a machine-readable snapshot. `config` is the
/// caller-described setup (fleet shape, workload, seed) echoed back so
/// the artifact is self-contained.
pub fn cluster_snapshot(
    r: &FleetResult,
    walks: u64,
    memo_hits: u64,
    profile: &SelfProfile,
    config: Json,
) -> Json {
    let per_device: Vec<Json> =
        r.per_device.iter().map(|d| device_json(d, r.makespan)).collect();
    jobj(vec![
        ("schema", Json::Str("halo.cluster.v1".to_string())),
        ("config", config),
        ("metrics", fleet_registry(r, walks, memo_hits).to_json()),
        ("per_device", Json::Arr(per_device)),
        ("profile", profile.to_json()),
    ])
}

fn device_json(d: &DeviceSummary, makespan: f64) -> Json {
    jobj(vec![
        ("id", Json::Num(d.id as f64)),
        ("mapping", Json::Str(d.mapping.name().to_string())),
        ("role", Json::Str(d.role.to_string())),
        ("prefills", Json::Num(d.prefills as f64)),
        ("decode_steps", Json::Num(d.decode_steps as f64)),
        ("served", Json::Num(d.served as f64)),
        ("busy_s", Json::Num(d.busy)),
        ("utilization", Json::Num(d.utilization(makespan))),
        ("evictions", Json::Num(d.evictions as f64)),
        ("recompute_tokens", Json::Num(d.recompute_tokens as f64)),
        ("kv_peak_bytes", Json::Num(d.kv_peak as f64)),
        ("energy_j", Json::Num(d.energy.total())),
        ("peak_power_w", Json::Num(d.peak_power_w)),
        ("throttled_s", Json::Num(d.throttled_s)),
    ])
}

/// One finished exploration as a machine-readable snapshot.
pub fn dse_snapshot(res: &DseResult, config: Json) -> Json {
    let objectives: Vec<Json> =
        res.objectives.iter().map(|o| Json::Str(o.name().to_string())).collect();
    let slo = match res.slo {
        None => Json::Null,
        Some(s) => jobj(vec![("ttft_s", Json::Num(s.ttft)), ("pct", Json::Num(s.pct))]),
    };
    let evaluated: Vec<Json> = res
        .evaluated
        .iter()
        .map(|e| {
            jobj(vec![
                ("label", Json::Str(e.candidate.label())),
                ("scores", Json::Arr(e.scores.iter().map(|s| Json::Num(*s)).collect())),
                ("metrics", metrics_json(&e.metrics)),
            ])
        })
        .collect();
    let frontier: Vec<Json> = res.frontier.iter().map(|&i| Json::Num(i as f64)).collect();
    jobj(vec![
        ("schema", Json::Str("halo.dse.v1".to_string())),
        ("config", config),
        ("rate_rps", Json::Num(res.rate)),
        ("objectives", Json::Arr(objectives)),
        ("slo", slo),
        ("evaluated", Json::Arr(evaluated)),
        ("frontier", Json::Arr(frontier)),
        (
            "slo_choice",
            res.slo_choice.map_or(Json::Null, |i| Json::Num(i as f64)),
        ),
        ("profile", res.profile.to_json()),
    ])
}

/// One monitored serve's windowed telemetry as a machine-readable
/// `halo.timeseries.v1` snapshot: the config echo, the window series,
/// the merged whole-run latency populations (bit-identical to the
/// `FleetResult` histograms — pinned by test), and the SLO burn-rate
/// report when one was evaluated.
pub fn timeseries_snapshot(series: &WindowSeries, slo: Option<&SloReport>, config: Json) -> Json {
    jobj(vec![
        ("schema", Json::Str("halo.timeseries.v1".to_string())),
        ("config", config),
        ("series", series.to_json()),
        ("ttft_total", series.merged_ttft().to_json()),
        ("e2e_total", series.merged_e2e().to_json()),
        ("slo", slo.map_or(Json::Null, SloReport::to_json)),
    ])
}

/// A [`Metrics`] record as a flat JSON object (keys match the
/// [`crate::dse::Objective`] vocabulary where one exists).
pub fn metrics_json(m: &Metrics) -> Json {
    jobj(vec![
        ("ttft_p50_s", Json::Num(m.ttft_p50)),
        ("ttft_p99_s", Json::Num(m.ttft_p99)),
        ("e2e_p50_s", Json::Num(m.e2e_p50)),
        ("e2e_p99_s", Json::Num(m.e2e_p99)),
        ("throughput_rps", Json::Num(m.throughput_rps)),
        ("decode_tok_per_s", Json::Num(m.decode_tok_per_s)),
        ("utilization", Json::Num(m.utilization)),
        ("evictions", Json::Num(m.evictions)),
        ("recompute_tokens", Json::Num(m.recompute_tokens)),
        ("kv_transfer_gb", Json::Num(m.kv_transfer_gb)),
        ("worst_tenant_ttft_p99_s", Json::Num(m.worst_tenant_ttft_p99)),
        ("slo_ttft_s", Json::Num(m.slo_ttft)),
        ("slo_attainment", Json::Num(m.slo_attainment)),
        ("cost", Json::Num(m.cost)),
        ("energy_per_token_j", Json::Num(m.energy_per_token_j)),
        ("total_energy_j", Json::Num(m.total_energy_j)),
        ("peak_power_w", Json::Num(m.peak_power_w)),
        ("edp", Json::Num(m.edp)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::LeastLoaded;
    use crate::cluster::{FleetBuilder, Interconnect};
    use crate::config::HwConfig;
    use crate::model::LlmConfig;
    use crate::sim::queueing::poisson_trace;

    #[test]
    fn cluster_snapshot_is_tagged_and_self_contained() {
        let llm = LlmConfig::llama2_7b();
        let hw = HwConfig::paper();
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(2)
            .slots(4)
            .interconnect(Interconnect::pcie5())
            .build();
        let trace = poisson_trace(7, 20, 10.0, (64, 512), 16);
        let r = fleet.replay(&trace, &mut LeastLoaded);
        let prof = SelfProfile::new();
        let cfg = jobj(vec![("devices", Json::Num(2.0))]);
        let j = cluster_snapshot(&r, fleet.cost_walks(), fleet.cost_memo_hits(), &prof, cfg);
        assert_eq!(j.path(&["schema"]).and_then(Json::as_str), Some("halo.cluster.v1"));
        assert_eq!(j.path(&["config", "devices"]).and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.path(&["per_device"]).and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        let served = j.path(&["metrics", "counters", "requests_served"]).and_then(Json::as_f64);
        assert_eq!(served, Some(r.requests as f64));
        // snapshots must round-trip through the serializer
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}

//! Power-plane integration tests: cross-plane energy agreement (the
//! event-driven replay must accumulate the same joules the analytical
//! `arch` plane computes), monotonicity of energy in workload size, the
//! zero-overhead guarantee (power tracking off or uncapped changes no
//! latency bit), interconnect KV-transfer energy accounting, and the
//! live TDP throttling feedback (tighter caps cost real throughput).

use halo::cluster::{Fleet, Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::power::ThermalConfig;
use halo::sim::queueing::TraceRequest;
use halo::sim::{simulate_e2e, Scenario};

fn hw() -> HwConfig {
    HwConfig::paper()
}

fn llm() -> LlmConfig {
    LlmConfig::llama2_7b()
}

/// One power-tracked HALO1 device serving `trace`.
fn powered_replay(
    trace: &[TraceRequest],
    thermal: Option<ThermalConfig>,
) -> halo::cluster::FleetResult {
    let mut fleet = Fleet::unified(&llm(), &hw(), 1, 8, Interconnect::board());
    fleet.enable_power(&hw(), thermal);
    let mut router = Policy::LeastLoaded.router();
    fleet.replay(trace, router.as_mut())
}

fn single_request(l_in: usize, l_out: usize) -> Vec<TraceRequest> {
    vec![TraceRequest { arrival: 0.0, l_in, l_out, tenant: 0 }]
}

#[test]
fn single_request_energy_matches_the_analytical_plane() {
    // acceptance: a one-request replay's accumulated dynamic energy must
    // agree with arch's e2e energy. The replay runs l_out - 1 discrete
    // decode steps at exact contexts while the analytical plane charges
    // l_out steps at the mid-generation context (affine costs), so the
    // two differ by about one step in l_out — well inside 5%.
    for (l_in, l_out) in [(512usize, 64usize), (2048, 128), (1024, 32)] {
        let r = powered_replay(&single_request(l_in, l_out), None);
        assert!(r.power_tracked);
        let replay_dynamic = r.energy.dynamic();
        let arch = simulate_e2e(
            &llm(),
            &hw(),
            MappingKind::Halo1,
            &Scenario { l_in, l_out, batch: 1 },
        )
        .e2e_energy();
        let rel = (replay_dynamic - arch).abs() / arch;
        assert!(
            rel < 0.05,
            "({l_in},{l_out}): replay {replay_dynamic} vs arch {arch} (rel {rel:.4})"
        );
        // static energy is accounted on top of (never inside) dynamic
        assert!(r.energy.e_static > 0.0);
        assert!(r.energy_j() > replay_dynamic);
    }
}

#[test]
fn replay_energy_is_monotone_in_tokens_and_sequence_length() {
    let dynamic = |l_in: usize, l_out: usize| {
        powered_replay(&single_request(l_in, l_out), None).energy.dynamic()
    };
    // non-decreasing in generated tokens
    let e16 = dynamic(512, 16);
    let e64 = dynamic(512, 64);
    let e256 = dynamic(512, 256);
    assert!(e16 < e64 && e64 < e256, "{e16} {e64} {e256}");
    // non-decreasing in prompt length
    let p256 = dynamic(256, 32);
    let p1024 = dynamic(1024, 32);
    let p4096 = dynamic(4096, 32);
    assert!(p256 < p1024 && p1024 < p4096, "{p256} {p1024} {p4096}");
}

#[test]
fn power_tracking_off_or_uncapped_is_bit_identical() {
    // acceptance: with tracking disabled the replay is the legacy one;
    // with tracking on but no TDP cap, latency results are still
    // bit-identical — attribution is an observer, not a participant
    let trace = Mix::Interactive.trace(31, 60, 10.0);
    let run = |power: Option<Option<ThermalConfig>>| {
        let mut fleet = Fleet::unified(&llm(), &hw(), 2, 8, Interconnect::board());
        if let Some(thermal) = power {
            fleet.enable_power(&hw(), thermal);
        }
        let mut router = Policy::LeastLoaded.router();
        fleet.replay(&trace, router.as_mut())
    };
    let plain = run(None);
    let tracked = run(Some(None));
    assert_eq!(plain.makespan.to_bits(), tracked.makespan.to_bits());
    assert_eq!(plain.decode_steps, tracked.decode_steps);
    assert_eq!(plain.served.len(), tracked.served.len());
    for (a, b) in plain.served.iter().zip(&tracked.served) {
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
        assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
    }
    // the observer still observed
    assert!(!plain.power_tracked && tracked.power_tracked);
    assert_eq!(plain.energy_j(), 0.0);
    assert!(tracked.energy_j() > 0.0);
    assert_eq!(tracked.throttled_s, 0.0);
}

#[test]
fn throughput_degrades_monotonically_as_tdp_tightens() {
    // acceptance: throttling feedback is live. Saturating burst on one
    // device: served rate == capacity, so any throttling shows directly.
    let trace = Mix::Generation.trace(33, 48, 1.0e6);
    let caps: [Option<f64>; 4] = [None, Some(150.0), Some(100.0), Some(60.0)];
    let mut rps = Vec::new();
    let mut throttled = Vec::new();
    for cap in caps {
        let r = powered_replay(&trace, cap.map(ThermalConfig::paper));
        assert_eq!(r.served.len(), 48);
        rps.push(r.throughput_rps());
        throttled.push(r.throttled_s);
    }
    for w in rps.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-9), "tighter cap raised throughput: {rps:?}");
    }
    assert!(rps[3] < rps[0] * 0.95, "the tightest cap must cost real throughput: {rps:?}");
    assert_eq!(throttled[0], 0.0);
    assert!(throttled[3] > throttled[1], "{throttled:?}");
}

#[test]
fn kv_transfers_cost_joules_proportional_to_bytes() {
    let trace = Mix::Chat.trace(35, 40, 50.0);
    let run = |link: Interconnect| {
        let (mut fleet, mut router) =
            Policy::PhaseDisaggregated.build(&llm(), &hw(), 4, 8, 0.5, link);
        fleet.replay(&trace, router.as_mut())
    };
    let board = run(Interconnect::board());
    let eth = run(Interconnect::ethernet());
    assert_eq!(board.transfers, 40);
    assert_eq!(board.kv_bytes, eth.kv_bytes, "same trace, same KV volume");
    let want_board = Interconnect::board().transfer_energy(board.kv_bytes);
    assert!((board.kv_transfer_energy_j - want_board).abs() < 1e-9 * want_board);
    // a higher-energy link class costs proportionally more joules
    let ratio = eth.kv_transfer_energy_j / board.kv_transfer_energy_j;
    let want_ratio = Interconnect::ethernet().e_per_byte / Interconnect::board().e_per_byte;
    assert!((ratio - want_ratio).abs() < 1e-9, "{ratio} vs {want_ratio}");
}

#[test]
fn per_device_energy_and_utilization_surface_in_fleet_stats() {
    let trace = Mix::Interactive.trace(37, 60, 30.0);
    let mut fleet = Fleet::unified(&llm(), &hw(), 3, 8, Interconnect::board());
    fleet.enable_power(&hw(), None);
    let mut router = Policy::LeastLoaded.router();
    let r = fleet.replay(&trace, router.as_mut());
    let device_sum: f64 = r.per_device.iter().map(|d| d.energy.total()).sum();
    assert!((r.energy_j() - device_sum).abs() < 1e-9 * device_sum);
    for d in &r.per_device {
        let util = d.utilization(r.makespan);
        assert!((0.0..=1.0 + 1e-9).contains(&util), "device {} util {util}", d.id);
        // every serving device draws at least the static floor on average
        let floor = hw().power.static_w(hw().hbm.stacks, false);
        assert!(d.avg_power_w(r.makespan) >= floor * 0.99, "device {}", d.id);
        assert!(d.peak_power_w >= floor || d.served == 0);
    }
}

//! Design-space exploration and SLO auto-tuning over the whole simulator.
//!
//! The paper's methodology is a search: sweep the architectural extremes
//! (Fully-CiD, Fully-CiM, phase-aware; §V-B), score each point, and pick
//! the winner. This plane turns that from a hand-run argument into an
//! engine — "evaluate one point" becomes "find the best point":
//!
//! * [`space`] — the searchable cross product: router policy, fleet
//!   composition (uniform or heterogeneous HALO1/HALO2/SA), device count,
//!   pool split, scheduler knobs (chunk / admission / KV budget),
//!   hardware knobs (CiM tile mesh, interposer bandwidth), and the power
//!   knobs (per-package TDP cap, per-phase DVFS operating points);
//! * [`strategy`] — pluggable, seeded, deterministic search drivers:
//!   exhaustive grid, random sampling, steepest hill-climb with restarts;
//! * [`objective`] — multi-objective scoring (TTFT p50/p99, decode
//!   throughput, evictions, SLO attainment, fleet cost, and the power
//!   plane's energy-per-token / EDP / peak-power);
//! * [`pareto`] — dominance and frontier extraction.
//!
//! [`explore`] wires them together: it calibrates one offered load,
//! generates one trace, memoizes every candidate's replay (revisits are
//! free, so hill-climbs can wander), and returns every evaluated point,
//! the Pareto frontier, and — when a TTFT SLO is given — the *cheapest*
//! configuration that meets it. Everything is deterministic per seed:
//! two runs with the same arguments are bit-identical.
//!
//! Evaluation is batched and optionally parallel: strategies hand the
//! engine whole batches of independent points (see
//! [`Strategy::search_batched`]) which fan out over a
//! `std::thread::scope` worker pool ([`DseConfig::threads`]) and merge
//! back in batch order, so results are bit-identical at any thread
//! count. [`Fidelity::SuccessiveHalving`] layers multi-fidelity on top:
//! the strategy runs on short trace prefixes, the top `1/eta` survive
//! each rung, and survivors are always re-scored at full fidelity —
//! reported metrics, the frontier, and the SLO choice only ever come
//! from full replays.

pub mod objective;
pub mod pareto;
pub mod space;
pub mod strategy;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

pub use objective::{fleet_cost, Direction, Metrics, Objective};
pub use pareto::{dominates, pareto_indices};
pub use space::{Candidate, Composition, Index, SearchSpace, AXES};
pub use strategy::{Exhaustive, HillClimb, RandomSearch, Strategy};

use crate::cluster::{Interconnect, Mix};
use crate::config::HwConfig;
use crate::model::LlmConfig;
use crate::obs::SelfProfile;
use crate::report::cluster::single_device_capacity;
use crate::sim::queueing::TraceRequest;

/// A TTFT service-level objective: the TTFT at `pct` (a percentile in
/// 0..=100) must not exceed `ttft` seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub ttft: f64,
    pub pct: f64,
}

impl SloSpec {
    /// Median-TTFT SLO (the default percentile).
    pub fn median(ttft: f64) -> Self {
        SloSpec { ttft, pct: 50.0 }
    }
}

/// Everything one exploration run needs besides the space and strategy.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub llm: LlmConfig,
    pub mix: Mix,
    /// Requests per evaluated trace.
    pub requests: usize,
    /// Seeds both the trace and any stochastic strategy.
    pub seed: u64,
    /// Decode slots per device.
    pub slots: usize,
    pub link: Interconnect,
    /// Absolute offered load in req/s; `None` calibrates it as
    /// `rate_scale x` one paper-default device's saturated throughput.
    pub rate: Option<f64>,
    pub rate_scale: f64,
    /// Tenants in the trace (1 = untagged single-tenant).
    pub tenants: usize,
    pub slo: Option<SloSpec>,
    /// Scored dimensions; the first one doubles as the scalar guidance
    /// for hill-climbing when no SLO is set.
    pub objectives: Vec<Objective>,
    pub base_hw: HwConfig,
    /// Worker threads for candidate evaluation (1 = in-line). Purely a
    /// wall-clock knob: results are bit-identical at any value.
    pub threads: usize,
    /// How much of the trace each candidate replays before scoring.
    pub fidelity: Fidelity,
}

impl DseConfig {
    pub fn new(llm: LlmConfig, mix: Mix) -> Self {
        DseConfig {
            llm,
            mix,
            requests: 96,
            seed: 42,
            slots: 8,
            link: Interconnect::board(),
            rate: None,
            rate_scale: 1.5,
            tenants: 1,
            slo: None,
            objectives: Objective::default_set(),
            base_hw: HwConfig::paper(),
            threads: 1,
            fidelity: Fidelity::Full,
        }
    }
}

/// Evaluation fidelity of one exploration run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Every visited candidate replays the full trace (the default).
    Full,
    /// Successive halving: the strategy runs entirely on the shortest
    /// trace prefix (`requests / start_div`), then the visited pool is
    /// re-scored on geometrically longer prefixes, keeping the top
    /// `1/eta` per rung; survivors are always re-scored on the full
    /// trace. `evaluated`, the frontier, and the SLO choice therefore
    /// come only from full-fidelity replays; pruned points are counted
    /// in the self-profile (`sh_pruned` out of `sh_pool`), never
    /// silently dropped from coverage claims.
    SuccessiveHalving { eta: usize, start_div: usize },
}

impl Fidelity {
    /// The default halving schedule: score on requests/8, promote the
    /// top half, re-score on requests/4, promote again, then replay the
    /// survivors in full — about 4x fewer full-fidelity replays than an
    /// exhaustive pass over the same pool.
    pub fn halving() -> Self {
        Fidelity::SuccessiveHalving { eta: 2, start_div: 8 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Full => "full",
            Fidelity::SuccessiveHalving { .. } => "halving",
        }
    }
}

/// One evaluated point of the space.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub index: Index,
    pub candidate: Candidate,
    pub metrics: Metrics,
    /// Minimized coordinates, one per configured objective.
    pub scores: Vec<f64>,
}

/// The outcome of one exploration run.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub objectives: Vec<Objective>,
    pub slo: Option<SloSpec>,
    /// The offered load every candidate was replayed under, req/s.
    pub rate: f64,
    /// Every distinct evaluated candidate, in first-visit order.
    pub evaluated: Vec<Evaluated>,
    /// Indices into `evaluated` of the Pareto-optimal points, sorted by
    /// the first objective.
    pub frontier: Vec<usize>,
    /// Index of the cheapest candidate meeting the SLO, if one was set
    /// and met.
    pub slo_choice: Option<usize>,
    /// Self-profiling of the exploration itself: wall time and counts
    /// per stage (candidate evals, memo hits, graph walks). Host
    /// measurement metadata — excluded from the determinism guarantee,
    /// which covers everything else in this struct.
    pub profile: SelfProfile,
}

impl DseResult {
    pub fn frontier_points(&self) -> Vec<&Evaluated> {
        self.frontier.iter().map(|&i| &self.evaluated[i]).collect()
    }

    /// Index of the evaluated candidate best on `obj` (by minimized
    /// score; ties resolve to the earliest-visited). When `obj` is one
    /// of the configured objectives, the ranking reads the cached
    /// `Evaluated.scores` column — no re-scoring, and guaranteed
    /// consistency with the frontier's coordinates; other objectives
    /// fall back to scoring the stored metrics.
    pub fn best_by(&self, obj: Objective) -> Option<usize> {
        let col = self.objectives.iter().position(|&o| o == obj);
        let score = |i: usize| match col {
            Some(c) => self.evaluated[i].scores[c],
            None => obj.score(&self.evaluated[i].metrics),
        };
        (0..self.evaluated.len()).min_by(|&a, &b| score(a).total_cmp(&score(b)))
    }

    fn meets_slo(&self, i: usize) -> bool {
        match self.slo {
            None => false,
            Some(slo) => self.evaluated[i].metrics.slo_ttft <= slo.ttft,
        }
    }
}

/// Scalar guidance for strategies: the SLO-penalized cost in auto-tune
/// mode (any config missing the SLO scores worse than every config
/// meeting it), else the first objective.
fn scalarize(cfg: &DseConfig, m: &Metrics) -> f64 {
    match cfg.slo {
        Some(slo) => {
            if m.slo_ttft <= slo.ttft {
                m.cost
            } else {
                1e12 + (m.slo_ttft - slo.ttft)
            }
        }
        None => cfg.objectives[0].score(m),
    }
}

/// Replay one candidate; returns its metrics plus the replay's graph
/// walks and cost-oracle memo hits for the exploration's self-profile.
fn evaluate_candidate(
    cand: &Candidate,
    cfg: &DseConfig,
    trace: &[TraceRequest],
) -> (Metrics, u64, u64) {
    let hw = cand.hw(&cfg.base_hw);
    let (mut fleet, mut router) = cand.build_fleet(&cfg.llm, &hw, cfg.slots, cfg.link.clone());
    let r = fleet.replay(trace, router.as_mut());
    let m = Metrics::collect(cand, &r, cfg.slo.map(|s| (s.ttft, s.pct)));
    (m, fleet.cost_walks(), fleet.cost_memo_hits())
}

/// Replay every pending candidate — in-line for one worker, fanned over
/// a `std::thread::scope` pool otherwise. Workers steal positions from
/// an atomic cursor and return `(position, result)` pairs; the merge
/// reorders them by position, so the output is position-aligned with
/// `pending` regardless of which worker ran what. Wall time accumulates
/// under `wall_key` and the same-named counter counts the replays.
fn evaluate_batch(
    pending: &[(Index, Candidate)],
    cfg: &DseConfig,
    trace: &[TraceRequest],
    prof: &mut SelfProfile,
    wall_key: &'static str,
) -> Vec<(Metrics, u64, u64)> {
    if pending.is_empty() {
        return Vec::new();
    }
    let n = pending.len();
    let workers = cfg.threads.max(1).min(n);
    let t0 = Instant::now();
    let results: Vec<(Metrics, u64, u64)> = if workers == 1 {
        pending.iter().map(|(_, cand)| evaluate_candidate(cand, cfg, trace)).collect()
    } else {
        let mut slots: Vec<Option<(Metrics, u64, u64)>> = vec![None; n];
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let cursor = &cursor;
                    s.spawn(move || {
                        let mut done = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            done.push((i, evaluate_candidate(&pending[i].1, cfg, trace)));
                        }
                        done
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("DSE worker panicked") {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|r| r.expect("unevaluated batch slot")).collect()
    };
    prof.add_wall(wall_key, t0.elapsed().as_secs_f64());
    prof.add(wall_key, n as u64);
    results
}

/// Resolution of one batch point against a memo: already scored, or
/// position `usize` in the batch's pending (to-replay) list.
enum Slot {
    Done(f64),
    Pending(usize),
}

/// The memoizing batch evaluator behind [`explore`]: resolves each batch
/// against the canonical-index memo (later in-batch duplicates of a
/// pending key count as memo hits, exactly as they would sequentially),
/// replays the distinct new candidates via [`evaluate_batch`], and
/// merges results in batch order — so `evaluated`, the memo, and every
/// profile counter are bit-identical at any thread count.
struct Evaluator<'a> {
    space: &'a SearchSpace,
    cfg: &'a DseConfig,
    trace: &'a [TraceRequest],
    evaluated: Vec<Evaluated>,
    /// Keyed on the canonical index (axes a topology ignores are
    /// pinned), so physically identical points replay once and appear
    /// as one frontier row; invalid points pin to +inf.
    memo: BTreeMap<Index, f64>,
    prof: SelfProfile,
}

impl Evaluator<'_> {
    fn run_batch(&mut self, batch: &[Index]) -> Vec<f64> {
        let mut slots: Vec<Slot> = Vec::with_capacity(batch.len());
        let mut pending: Vec<(Index, Candidate)> = Vec::new();
        let mut pending_at: BTreeMap<Index, usize> = BTreeMap::new();
        for idx in batch {
            let key = self.space.canonical(idx);
            if let Some(&s) = self.memo.get(&key) {
                self.prof.add("dse_memo_hits", 1);
                slots.push(Slot::Done(s));
            } else if let Some(&p) = pending_at.get(&key) {
                self.prof.add("dse_memo_hits", 1);
                slots.push(Slot::Pending(p));
            } else {
                let cand = self.space.decode(&key);
                if cand.valid() {
                    pending_at.insert(key, pending.len());
                    slots.push(Slot::Pending(pending.len()));
                    pending.push((key, cand));
                } else {
                    self.prof.add("invalid_candidates", 1);
                    self.memo.insert(key, f64::INFINITY);
                    slots.push(Slot::Done(f64::INFINITY));
                }
            }
        }
        let results =
            evaluate_batch(&pending, self.cfg, self.trace, &mut self.prof, "candidate_evals");
        let mut scalars = Vec::with_capacity(pending.len());
        for ((key, cand), (metrics, walks, oracle_hits)) in pending.into_iter().zip(results) {
            self.prof.add("graph_walks", walks);
            self.prof.add("oracle_memo_hits", oracle_hits);
            let scalar = scalarize(self.cfg, &metrics);
            let scores = self.cfg.objectives.iter().map(|o| o.score(&metrics)).collect();
            self.evaluated.push(Evaluated { index: key, candidate: cand, metrics, scores });
            self.memo.insert(key, scalar);
            scalars.push(scalar);
        }
        slots
            .iter()
            .map(|s| match s {
                Slot::Done(v) => *v,
                Slot::Pending(p) => scalars[*p],
            })
            .collect()
    }
}

/// One pooled point of a successive-halving run, carrying its
/// latest-rung score.
struct ShPoint {
    key: Index,
    cand: Candidate,
    scalar: f64,
    slo_ttft: f64,
}

/// Multi-fidelity mode: run the whole strategy on the shortest trace
/// prefix (cheap replays both guide the walk and seed the pool), prune
/// the pool on geometrically longer prefixes keeping the top `1/eta`
/// per rung, and finally push the survivors through the full-fidelity
/// engine — the only place `ev.evaluated` grows. Deterministic at any
/// thread count: batches merge in order and the promotion sort is total
/// with a pool-order tie-break.
fn successive_halving(
    ev: &mut Evaluator<'_>,
    strategy: &mut dyn Strategy,
    eta: usize,
    start_div: usize,
) {
    let eta = eta.max(2);
    // prefix divisors, largest first; stop above `eta` so the last rung
    // is still a strict prefix and full fidelity stays a separate pass
    let mut divs: Vec<usize> = Vec::new();
    let mut d = start_div;
    while d > eta {
        divs.push(d);
        d /= eta;
    }
    if divs.is_empty() {
        // degenerate schedule (start_div <= eta): plain full fidelity
        strategy.search_batched(ev.space, &mut |b| ev.run_batch(b));
        return;
    }

    // rung 0: the strategy's entire walk happens here, scored on the
    // shortest prefix against a rung-local memo
    let space = ev.space;
    let cfg = ev.cfg;
    let trace = ev.trace;
    let n0 = (trace.len() / divs[0]).max(1).min(trace.len().max(1));
    let prefix0 = &trace[..n0.min(trace.len())];
    let mut pool: Vec<ShPoint> = Vec::new();
    let mut rung_memo: BTreeMap<Index, f64> = BTreeMap::new();
    {
        let mut run = |batch: &[Index]| -> Vec<f64> {
            let mut slots: Vec<Slot> = Vec::with_capacity(batch.len());
            let mut pending: Vec<(Index, Candidate)> = Vec::new();
            let mut pending_at: BTreeMap<Index, usize> = BTreeMap::new();
            for idx in batch {
                let key = space.canonical(idx);
                if let Some(&s) = rung_memo.get(&key) {
                    ev.prof.add("dse_memo_hits", 1);
                    slots.push(Slot::Done(s));
                } else if let Some(&p) = pending_at.get(&key) {
                    ev.prof.add("dse_memo_hits", 1);
                    slots.push(Slot::Pending(p));
                } else {
                    let cand = space.decode(&key);
                    if cand.valid() {
                        pending_at.insert(key, pending.len());
                        slots.push(Slot::Pending(pending.len()));
                        pending.push((key, cand));
                    } else {
                        ev.prof.add("invalid_candidates", 1);
                        rung_memo.insert(key, f64::INFINITY);
                        slots.push(Slot::Done(f64::INFINITY));
                    }
                }
            }
            let results = evaluate_batch(&pending, cfg, prefix0, &mut ev.prof, "sh_rung_evals");
            let mut scalars = Vec::with_capacity(pending.len());
            for ((key, cand), (m, walks, hits)) in pending.into_iter().zip(results) {
                ev.prof.add("graph_walks", walks);
                ev.prof.add("oracle_memo_hits", hits);
                let scalar = scalarize(cfg, &m);
                rung_memo.insert(key, scalar);
                pool.push(ShPoint { key, cand, scalar, slo_ttft: m.slo_ttft });
                scalars.push(scalar);
            }
            slots
                .iter()
                .map(|s| match s {
                    Slot::Done(v) => *v,
                    Slot::Pending(p) => scalars[*p],
                })
                .collect()
        };
        strategy.search_batched(space, &mut run);
    }
    ev.prof.add("sh_pool", pool.len() as u64);

    // later rungs re-score the survivors on longer prefixes; each rung
    // (including rung 0, whose scores the strategy drive produced)
    // promotes the top 1/eta
    let mut alive: Vec<usize> = (0..pool.len()).collect();
    for (r, &div) in divs.iter().enumerate() {
        if r > 0 {
            let n_r = (trace.len() / div).max(1).min(trace.len().max(1));
            let prefix = &trace[..n_r.min(trace.len())];
            let batch: Vec<(Index, Candidate)> =
                alive.iter().map(|&p| (pool[p].key, pool[p].cand.clone())).collect();
            let results = evaluate_batch(&batch, cfg, prefix, &mut ev.prof, "sh_rung_evals");
            for (&p, (m, walks, hits)) in alive.iter().zip(results) {
                ev.prof.add("graph_walks", walks);
                ev.prof.add("oracle_memo_hits", hits);
                pool[p].scalar = scalarize(cfg, &m);
                pool[p].slo_ttft = m.slo_ttft;
            }
        }
        let keep = alive.len().div_ceil(eta).max(1);
        if keep >= alive.len() {
            continue;
        }
        // rank by (scalar, slo_ttft, pool position) — the same ordering
        // the final SLO choice uses, so ties never prune the would-be
        // winner arbitrarily
        let mut ranked = alive.clone();
        ranked.sort_by(|&a, &b| {
            pool[a]
                .scalar
                .total_cmp(&pool[b].scalar)
                .then(pool[a].slo_ttft.total_cmp(&pool[b].slo_ttft))
                .then(a.cmp(&b))
        });
        ranked.truncate(keep);
        ranked.sort_unstable(); // back to pool order for the next rung
        ev.prof.add("sh_pruned", (alive.len() - keep) as u64);
        alive = ranked;
    }

    // survivors go through the full-fidelity engine: this is the only
    // place `evaluated` grows, so metrics/frontier/SLO are full replays
    let survivors: Vec<Index> = alive.iter().map(|&p| pool[p].key).collect();
    let _ = ev.run_batch(&survivors);
}

/// Run one exploration: calibrate the offered load, drive `strategy`
/// over `space` with memoized, batched (and, at `cfg.threads > 1`,
/// parallel) candidate evaluation, then extract the Pareto frontier and
/// the SLO choice. Deterministic per (space, strategy, cfg) — including
/// bit-identical floating-point results at any thread count; only the
/// profile's wall times vary across hosts.
pub fn explore(
    space: &SearchSpace,
    strategy: &mut dyn Strategy,
    cfg: &DseConfig,
) -> DseResult {
    assert!(!cfg.objectives.is_empty(), "need at least one objective");
    assert!(cfg.requests > 0 && cfg.slots > 0 && cfg.tenants > 0);
    let mut prof = SelfProfile::new();
    let rate = prof.time("calibrate_rate", || {
        cfg.rate.unwrap_or_else(|| {
            cfg.rate_scale * single_device_capacity(&cfg.base_hw, &cfg.llm, cfg.mix, cfg.slots)
        })
    });
    let trace =
        prof.time("trace_gen", || cfg.mix.trace_tenants(cfg.seed, cfg.requests, rate, cfg.tenants));

    let mut ev = Evaluator {
        space,
        cfg,
        trace: &trace,
        evaluated: Vec::new(),
        memo: BTreeMap::new(),
        prof,
    };
    match cfg.fidelity {
        Fidelity::Full => strategy.search_batched(space, &mut |b| ev.run_batch(b)),
        Fidelity::SuccessiveHalving { eta, start_div } => {
            successive_halving(&mut ev, strategy, eta, start_div)
        }
    }
    let Evaluator { evaluated, prof, .. } = ev;

    let score_vecs: Vec<Vec<f64>> = evaluated.iter().map(|e| e.scores.clone()).collect();
    let mut frontier = pareto_indices(&score_vecs);
    frontier.sort_by(|&a, &b| {
        evaluated[a].scores[0]
            .total_cmp(&evaluated[b].scores[0])
            .then(a.cmp(&b))
    });

    let mut result = DseResult {
        objectives: cfg.objectives.clone(),
        slo: cfg.slo,
        rate,
        evaluated,
        frontier,
        slo_choice: None,
        profile: prof,
    };
    if cfg.slo.is_some() {
        let mut best: Option<usize> = None;
        for i in 0..result.evaluated.len() {
            if !result.meets_slo(i) {
                continue;
            }
            best = match best {
                None => Some(i),
                Some(b) => {
                    let (mi, mb) = (&result.evaluated[i].metrics, &result.evaluated[b].metrics);
                    let better = mi.cost < mb.cost
                        || (mi.cost == mb.cost && mi.slo_ttft < mb.slo_ttft);
                    Some(if better { i } else { b })
                }
            };
        }
        result.slo_choice = best;
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Policy;

    fn tiny_cfg() -> DseConfig {
        let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
        cfg.requests = 40;
        cfg.seed = 7;
        cfg
    }

    fn tiny_space() -> SearchSpace {
        SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded])
            .with_devices(vec![1])
            .with_chunks(vec![0, 512])
    }

    #[test]
    fn explore_scores_every_candidate_and_extracts_a_frontier() {
        let cfg = tiny_cfg();
        let res = explore(&tiny_space(), &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 2);
        assert!(!res.frontier.is_empty());
        for e in &res.evaluated {
            assert_eq!(e.scores.len(), cfg.objectives.len());
            assert!(e.metrics.throughput_rps > 0.0);
            assert!(e.metrics.ttft_p99 >= e.metrics.ttft_p50);
            assert_eq!(e.metrics.cost, 1.0, "single paper device costs 1.0");
        }
        // no frontier point dominated by any evaluated point
        for &i in &res.frontier {
            assert!(!res
                .evaluated
                .iter()
                .any(|e| dominates(&e.scores, &res.evaluated[i].scores)));
        }
    }

    #[test]
    fn invalid_candidates_are_skipped_not_evaluated() {
        let space = SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded, Policy::KvAware])
            .with_devices(vec![1]);
        let res = explore(&space, &mut Exhaustive, &tiny_cfg());
        // kvaware on one device is structurally invalid -> only the
        // unified point is evaluated
        assert_eq!(res.evaluated.len(), 1);
        assert_eq!(res.evaluated[0].candidate.policy, Policy::LeastLoaded);
    }

    #[test]
    fn energy_objectives_are_populated_and_rank_halo_first() {
        let mut cfg = tiny_cfg();
        cfg.objectives = vec![Objective::EnergyPerToken, Objective::Throughput];
        let res = explore(&SearchSpace::mapping_extremes(), &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 3);
        for e in &res.evaluated {
            assert!(e.metrics.energy_per_token_j > 0.0, "{}", e.candidate.label());
            assert!(e.metrics.total_energy_j > 0.0);
            assert!(e.metrics.peak_power_w > 0.0);
            assert!(e.metrics.edp > 0.0);
        }
        // phase-aware HALO1 picks the cheaper engine per phase, so it
        // must also be the cheapest-energy point of the three extremes
        let best = res.best_by(Objective::EnergyPerToken).unwrap();
        assert_eq!(res.evaluated[best].candidate.composition.name(), "HALO1");
    }

    #[test]
    fn tdp_cap_degrades_throughput_in_the_search() {
        let mut cfg = tiny_cfg();
        cfg.objectives = vec![Objective::Throughput, Objective::PeakPower];
        let space = SearchSpace::paper_point()
            .with_devices(vec![1])
            .with_tdp_caps_w(vec![0.0, 40.0]);
        let res = explore(&space, &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 2);
        let free = res.evaluated.iter().find(|e| e.candidate.tdp_w == 0.0).unwrap();
        let capped = res.evaluated.iter().find(|e| e.candidate.tdp_w > 0.0).unwrap();
        assert!(
            capped.metrics.throughput_rps < free.metrics.throughput_rps,
            "a 40 W cap must cost throughput: {} vs {}",
            capped.metrics.throughput_rps,
            free.metrics.throughput_rps
        );
    }

    #[test]
    fn empty_trace_yields_finite_zero_metrics() {
        // regression: energy_per_token / decode_tok_per_s on an empty
        // trace used to flow inf/NaN (or panic in the percentile helper)
        // into total_cmp rankings and report tables
        let trace = Mix::Interactive.trace(1, 0, 5.0);
        assert!(trace.is_empty());
        let space = SearchSpace::paper_point().with_devices(vec![1]);
        let cand = space.decode(&space.first_index());
        let hw = HwConfig::paper();
        let (mut fleet, mut router) = cand.build_fleet(
            &LlmConfig::llama2_7b(),
            &hw,
            4,
            Interconnect::board(),
        );
        let r = fleet.replay(&trace, router.as_mut());
        assert!(r.served.is_empty());
        let m = Metrics::collect(&cand, &r, None);
        for v in [
            m.ttft_p50,
            m.ttft_p99,
            m.e2e_p50,
            m.e2e_p99,
            m.throughput_rps,
            m.decode_tok_per_s,
            m.energy_per_token_j,
            m.total_energy_j,
            m.peak_power_w,
            m.edp,
            m.worst_tenant_ttft_p99,
            m.slo_attainment,
        ] {
            assert!(v.is_finite(), "{m:?}");
        }
        assert_eq!(m.energy_per_token_j, 0.0);
        assert_eq!(m.decode_tok_per_s, 0.0);
        assert_eq!(m.edp, 0.0);
        // and every objective still produces a rankable (non-NaN) score
        for o in Objective::all() {
            assert!(!o.score(&m).is_nan(), "{}", o.name());
        }
    }

    #[test]
    fn dvfs_axis_trades_peak_power_onto_the_edp_frontier() {
        // acceptance: a decode-heavy mix searched over the DVFS ladder
        // keeps a non-nominal point on the EDP frontier — low-frequency
        // decode cuts both energy per token and peak power there
        let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Generation);
        cfg.requests = 32;
        cfg.seed = 11;
        cfg.objectives =
            vec![Objective::Edp, Objective::EnergyPerToken, Objective::PeakPower];
        let space = SearchSpace::paper_point()
            .with_devices(vec![1])
            .with_dvfs(vec![(0, 0), (1, 1), (0, 2), (2, 2)]);
        let res = explore(&space, &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 4);
        let by_dvfs = |d: (usize, usize)| {
            &res.evaluated.iter().find(|e| e.candidate.dvfs == d).unwrap().metrics
        };
        // peak power falls strictly down the ladder
        let (nom, bal, eco) = (by_dvfs((0, 0)), by_dvfs((1, 1)), by_dvfs((2, 2)));
        assert!(bal.peak_power_w < nom.peak_power_w, "{} vs {}", bal.peak_power_w, nom.peak_power_w);
        assert!(eco.peak_power_w < bal.peak_power_w);
        // decode-heavy: eco decode spends fewer joules per token than
        // nominal (streaming power dwarfs the static-time penalty)
        let split = by_dvfs((0, 2));
        assert!(
            split.energy_per_token_j < nom.energy_per_token_j,
            "{} vs {}",
            split.energy_per_token_j,
            nom.energy_per_token_j
        );
        // ...so the frontier retains at least one non-nominal point
        let frontier_dvfs: Vec<(usize, usize)> =
            res.frontier_points().iter().map(|e| e.candidate.dvfs).collect();
        assert!(
            frontier_dvfs.iter().any(|&d| d != (0, 0)),
            "EDP frontier lost every non-nominal DVFS point: {frontier_dvfs:?}"
        );
    }

    #[test]
    fn explicit_rate_bypasses_calibration() {
        let mut cfg = tiny_cfg();
        cfg.rate = Some(3.5);
        let res = explore(&tiny_space(), &mut Exhaustive, &cfg);
        assert_eq!(res.rate, 3.5);
    }

    #[test]
    fn parallel_explore_is_bit_identical_to_sequential() {
        let space = SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded])
            .with_devices(vec![1])
            .with_chunks(vec![0, 512])
            .with_tdp_caps_w(vec![0.0, 60.0]);
        let mut cfg = tiny_cfg();
        cfg.rate = Some(10.0);
        let seq = explore(&space, &mut Exhaustive, &cfg);
        cfg.threads = 4;
        let par = explore(&space, &mut Exhaustive, &cfg);
        assert_eq!(seq.evaluated.len(), par.evaluated.len());
        for (a, b) in seq.evaluated.iter().zip(par.evaluated.iter()) {
            assert_eq!(a.index, b.index, "visit order");
            let (sa, sb): (Vec<u64>, Vec<u64>) = (
                a.scores.iter().map(|v| v.to_bits()).collect(),
                b.scores.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(sa, sb, "scores of {}", a.candidate.label());
            assert_eq!(a.metrics.ttft_p50.to_bits(), b.metrics.ttft_p50.to_bits());
        }
        assert_eq!(seq.frontier, par.frontier);
        assert_eq!(seq.slo_choice, par.slo_choice);
        // counters (not wall times) are part of the determinism contract
        for key in ["candidate_evals", "dse_memo_hits", "invalid_candidates", "graph_walks"] {
            assert_eq!(seq.profile.count(key), par.profile.count(key), "{key}");
        }
    }

    #[test]
    fn best_by_reads_the_cached_scores_column_for_configured_objectives() {
        let mut cfg = tiny_cfg();
        cfg.objectives = vec![Objective::TtftP50, Objective::Cost];
        let mut res = explore(&tiny_space(), &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 2);
        // doctor one point's metrics so cached scores and a re-score
        // disagree: a configured objective must follow the cache (the
        // frontier's coordinates), an unconfigured one the metrics
        let cached_best = res.best_by(Objective::TtftP50).unwrap();
        let other = 1 - cached_best;
        res.evaluated[other].metrics.ttft_p50 = -1.0;
        assert_eq!(
            res.best_by(Objective::TtftP50),
            Some(cached_best),
            "configured objective must rank by the cached scores column"
        );
        res.evaluated[other].metrics.e2e_p50 = -1.0;
        assert_eq!(
            res.best_by(Objective::E2eP50),
            Some(other),
            "unconfigured objective falls back to scoring the metrics"
        );
    }

    #[test]
    fn successive_halving_reports_only_full_fidelity_survivors() {
        let space = SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded])
            .with_devices(vec![1])
            .with_chunks(vec![0, 256, 512, 1024]);
        let mut cfg = tiny_cfg();
        cfg.requests = 64;
        cfg.rate = Some(8.0);
        cfg.fidelity = Fidelity::halving();
        let res = explore(&space, &mut Exhaustive, &cfg);
        let pool = res.profile.count("sh_pool");
        let pruned = res.profile.count("sh_pruned");
        assert_eq!(pool, 4, "every valid candidate joins the rung-0 pool");
        // coverage conservation: pool = survivors + pruned, nothing
        // silently dropped
        assert_eq!(res.evaluated.len() as u64 + pruned, pool);
        assert!(pruned > 0, "halving must prune on a 4-point pool");
        assert_eq!(
            res.profile.count("candidate_evals"),
            res.evaluated.len() as u64,
            "full replays count only the survivors"
        );
        assert!(res.profile.count("sh_rung_evals") > 0);
        assert!(!res.frontier.is_empty());
    }

    #[test]
    fn degenerate_halving_schedule_falls_back_to_full_fidelity() {
        let mut cfg = tiny_cfg();
        cfg.fidelity = Fidelity::SuccessiveHalving { eta: 2, start_div: 2 };
        let sh = explore(&tiny_space(), &mut Exhaustive, &cfg);
        cfg.fidelity = Fidelity::Full;
        let full = explore(&tiny_space(), &mut Exhaustive, &cfg);
        assert_eq!(sh.evaluated.len(), full.evaluated.len());
        assert_eq!(sh.profile.count("sh_pool"), 0);
        for (a, b) in sh.evaluated.iter().zip(full.evaluated.iter()) {
            let (sa, sb): (Vec<u64>, Vec<u64>) = (
                a.scores.iter().map(|v| v.to_bits()).collect(),
                b.scores.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(sa, sb);
        }
    }
}

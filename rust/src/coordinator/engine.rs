//! InferenceEngine: phase-aware execution of the AOT artifacts.
//!
//! Prefill requests run the `prefill_b1_s{L}` executable whose GEMMs were
//! lowered through the analog-CiM Pallas kernel; decode steps run the
//! `decode_b{B}` executable (exact-int8 CiD kernel path) over the batched
//! KV cache. This is the functional twin of the paper's phase-aware
//! mapping (Table II, HALO1).

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::kv_cache::KvCache;
use crate::runtime::{Executable, HostTensor, Runtime};

/// Result of a prefill: the first generated token and the prompt length.
#[derive(Debug, Clone)]
pub struct PrefillOutcome {
    pub first_token: i32,
    pub prompt_len: usize,
    pub wall: std::time::Duration,
}

pub struct InferenceEngine {
    pub rt: Runtime,
    /// (padded length, executable), ascending by length.
    prefills: Vec<(usize, Executable)>,
    decode: Executable,
    pub kv: KvCache,
    /// Device-resident KV buffers, valid when no host-side slot mutation
    /// happened since the last decode step. Decode steps chain K'/V'
    /// buffers directly, so the multi-MB caches never cross the host
    /// boundary inside a generation burst (EXPERIMENTS.md §Perf).
    kv_dev: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    pub vocab: usize,
    /// Wall-clock spent inside PJRT execute (perf accounting).
    pub execute_time: std::time::Duration,
    pub steps: u64,
}

impl InferenceEngine {
    /// Load artifacts and compile the prefill ladder + the batched decode
    /// entry. `slots` must match a `decode_b{slots}` artifact.
    ///
    /// Prefill prefers the ideal-ADC entries (deterministic across XLA
    /// versions); pass `noisy_prefill=true` via [`Self::load_with_mode`]
    /// to serve through the calibrated analog-noise path instead.
    pub fn load(artifacts: &Path, slots: usize) -> Result<Self> {
        Self::load_with_mode(artifacts, slots, false)
    }

    pub fn load_with_mode(artifacts: &Path, slots: usize, noisy_prefill: bool) -> Result<Self> {
        let rt = Runtime::load(artifacts)?;
        let n_layers = rt.manifest.config_usize("n_layers")?;
        let max_seq = rt.manifest.config_usize("max_seq")?;
        let kv_heads = rt.manifest.config_usize("n_kv_heads")?;
        let head_dim = rt.manifest.config_usize("head_dim")?;
        let vocab = rt.manifest.config_usize("vocab")?;

        let prefix = if noisy_prefill { "prefill_b1_s" } else { "prefill_ideal_b1_s" };
        let mut prefills = Vec::new();
        for (name, _) in rt.manifest.entries.iter() {
            if let Some(len) = name.strip_prefix(prefix).and_then(|s| s.parse().ok()) {
                prefills.push((len, rt.compile(name)?));
            }
        }
        if prefills.is_empty() && !noisy_prefill {
            // older artifact sets may only carry the calibrated entries
            for (name, _) in rt.manifest.entries.iter() {
                if let Some(len) = name.strip_prefix("prefill_b1_s").and_then(|s| s.parse().ok()) {
                    prefills.push((len, rt.compile(name)?));
                }
            }
        }
        prefills.sort_by_key(|(l, _)| *l);
        if prefills.is_empty() {
            bail!("no prefill entries in manifest");
        }
        let decode = rt.compile(&format!("decode_b{slots}"))?;
        let kv = KvCache::new(n_layers, slots, max_seq, kv_heads, head_dim);
        Ok(InferenceEngine {
            rt,
            prefills,
            decode,
            kv,
            kv_dev: None,
            vocab,
            execute_time: Default::default(),
            steps: 0,
        })
    }

    pub fn slots(&self) -> usize {
        self.kv.slots
    }

    pub fn max_prompt(&self) -> usize {
        self.prefills.last().map(|(l, _)| *l).unwrap_or(0)
    }

    /// Pull the device-resident KV state back to the host (needed before
    /// any host-side slot mutation, i.e. prefill installs).
    fn sync_kv_to_host(&mut self) -> Result<()> {
        if let Some((kb, vb)) = self.kv_dev.take() {
            self.kv.k = self.decode.download_output(&kb, 1)?;
            self.kv.v = self.decode.download_output(&vb, 2)?;
        }
        Ok(())
    }

    /// Run prefill for a prompt and install its KV into `slot`.
    pub fn prefill_into_slot(
        &mut self,
        slot: usize,
        request: u64,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<PrefillOutcome> {
        self.sync_kv_to_host()?;
        let plen = prompt.len();
        let (padded, exe) = self
            .prefills
            .iter()
            .find(|(l, _)| *l >= plen)
            .ok_or_else(|| {
                anyhow!("prompt of {plen} exceeds longest prefill ({})", self.max_prompt())
            })?;

        // right-pad: padded positions are causally after the prompt, so
        // their K/V never get attended (decode positions start at plen)
        let mut toks = prompt.to_vec();
        toks.resize(*padded, 0);
        let t0 = Instant::now();
        let outs = exe.run(&[HostTensor::i32(toks, &[1, *padded])])?;
        let wall = t0.elapsed();
        self.execute_time += wall;

        let [logits, k1, v1]: &[HostTensor; 3] = outs
            .as_slice()
            .try_into()
            .map_err(|_| anyhow!("prefill returned {} outputs", outs.len()))?;
        // logits (1, padded, vocab): greedy over the last *real* position
        let lv = logits.as_f32()?;
        let row = &lv[(plen - 1) * self.vocab..plen * self.vocab];
        let first = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .ok_or_else(|| anyhow!("prefill produced an empty logits row"))?;

        // the prefill itself produced the first generated token, so the
        // decode budget is one less than the request's max_new
        self.kv.claim(slot, request, plen, max_new.saturating_sub(1).max(1))?;
        self.kv.load_prefill(slot, k1, v1)?;
        Ok(PrefillOutcome { first_token: first, prompt_len: plen, wall })
    }

    /// One batched decode step: feed each active slot's current token,
    /// update the KV cache, return per-slot greedy next tokens.
    ///
    /// The KV caches stay device-resident between steps: only the token
    /// and position vectors go up and only the logits come down.
    pub fn decode_step(&mut self, current_tokens: &[i32]) -> Result<Vec<i32>> {
        let b = self.slots();
        let (toks, pos) = self.kv.step_inputs(current_tokens)?;
        let t0 = Instant::now();
        let tok_t = HostTensor::i32(toks, &[b]);
        let pos_t = HostTensor::i32(pos, &[b]);

        // upload the KV state only when a host mutation invalidated it
        let (kb, vb) = match self.kv_dev.take() {
            Some(bufs) => bufs,
            None => (self.kv.k.to_device(&self.rt.client)?, self.kv.v.to_device(&self.rt.client)?),
        };
        let mut bufs = self.decode.run_buffers(&[&tok_t, &pos_t], &[&kb, &vb])?;
        if bufs.len() != 3 {
            bail!("decode: expected 3 untupled outputs, got {} (unpatched xla?)", bufs.len());
        }
        let vb_new = bufs.pop().unwrap();
        let kb_new = bufs.pop().unwrap();
        let logits = self.decode.download_output(&bufs[0], 0)?;
        self.kv_dev = Some((kb_new, vb_new));
        self.execute_time += t0.elapsed();
        self.steps += 1;

        let next = logits.argmax_rows()?.into_iter().map(|i| i as i32).collect();
        Ok(next)
    }
}

// engine integration tests (need artifacts + PJRT) live in
// rust/tests/serving_integration.rs

//! Request-lifecycle spans and Chrome-trace export.
//!
//! A [`Recorder`] rides along on a device (`Device::enable_obs`) and
//! copies the *same* `f64` start/duration values that advance the
//! simulated clock — recording observes, it never computes, so an
//! instrumented replay is bit-identical to an untracked one and
//! [`Recorder::busy_total`] reconciles exactly with the device's `busy`
//! accumulator (same values folded in the same order).
//!
//! [`chrome_trace`] serializes the recorded timelines into the Chrome
//! trace-event JSON format (one track per device plus an interconnect
//! track for KV handoffs), which loads directly in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`.

use super::jobj;
use crate::util::json::Json;

/// What a busy span on a device track was doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Whole-prompt prefill (serialized admission).
    Prefill,
    /// One chunk of a chunked prefill.
    PrefillChunk,
    /// KV recompute after an eviction (resume path).
    Recompute,
    /// One decode step over the resident batch.
    DecodeStep,
    /// KV-cache handoff over the interconnect (fleet track).
    KvTransfer,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Prefill => "prefill",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::Recompute => "recompute",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::KvTransfer => "kv_transfer",
        }
    }

    /// Trace category — Perfetto colors slices per category, which is
    /// what makes the prefill/decode phase structure visible at a glance.
    pub fn cat(&self) -> &'static str {
        match self {
            SpanKind::Prefill | SpanKind::PrefillChunk => "prefill",
            SpanKind::Recompute => "recompute",
            SpanKind::DecodeStep => "decode",
            SpanKind::KvTransfer => "kv",
        }
    }
}

/// One busy interval on a track, in simulated seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub start: f64,
    pub dur: f64,
    /// Arrival time of the request this span serves; `-1.0` for batched
    /// spans (decode steps) that serve several requests at once.
    pub arrival: f64,
    /// Requests served by this span (decode batch size; 1 otherwise).
    pub batch: usize,
}

/// Point events on a track (instants, not intervals).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request delivered to the device queue.
    Queued,
    /// Resident sequence evicted under KV-capacity pressure.
    Evicted,
    /// Final token emitted; request leaves the device.
    Done,
    /// Thermal governor throttled service during the preceding span.
    Throttle,
    /// Admission gate refused the request this cycle: the KV byte
    /// budget could not fit its working set. The critical-path plane
    /// reclassifies the request's queue wait as KV-capacity-bound.
    AdmitBlocked,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Queued => "queued",
            EventKind::Evicted => "evicted",
            EventKind::Done => "done",
            EventKind::Throttle => "throttle",
            EventKind::AdmitBlocked => "admit_blocked",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    pub t: f64,
    /// Arrival of the affected request (`-1.0` when not per-request).
    pub arrival: f64,
    /// Kind-specific detail: the DVFS governor rung for `Throttle`.
    pub detail: usize,
    /// Stall seconds this `Throttle` event added over the previous one
    /// (0.0 for other kinds) — the attribution plane's input.
    pub stall_s: f64,
}

/// Decode-batch membership: which arrivals shared one decode step.
///
/// Decode-step [`Span`]s carry `arrival: -1.0` because one span serves
/// the whole resident batch — per-request decode time is unrecoverable
/// from spans alone. This side-channel records the member arrivals per
/// step so the critical-path plane can rebuild each request's decode
/// intervals (and its batching/coupling edges to co-batched requests)
/// without touching the span vector that existing tests pin.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRecord {
    pub start: f64,
    pub dur: f64,
    /// Arrival times of every sequence resident during this step.
    pub arrivals: Vec<f64>,
}

/// Per-device span/event log. Appended to by the device's busy-time
/// bookkeeping; drained by [`chrome_trace`].
///
/// Retention is capped (mirroring `ServeOptions::streaming`): past
/// `retain_cap` recorded spans (and, independently, events) new entries
/// are counted in [`dropped`](Self::dropped) instead of stored, so
/// enabling obs on a million-request stream cannot grow memory
/// unboundedly. [`busy_total`](Self::busy_total) stays exact under the
/// cap: the running sum accumulates *before* the retention gate, in
/// call order, so it reconciles bit-for-bit with the device's `busy`
/// accumulator whether or not spans were dropped.
#[derive(Debug, Clone)]
pub struct Recorder {
    pub spans: Vec<Span>,
    pub events: Vec<Event>,
    /// Decode-batch membership records (capped like spans/events).
    pub batches: Vec<BatchRecord>,
    last_throttled_s: f64,
    /// Span durations folded in call order — `busy_total` under capping.
    busy_sum: f64,
    retain_cap: usize,
    dropped_spans: u64,
    dropped_events: u64,
    dropped_batches: u64,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    /// An uncapped recorder (retains everything) — the `halo trace`
    /// path, where the full timeline is the product.
    pub fn new() -> Self {
        Self::with_cap(usize::MAX)
    }

    /// A recorder retaining at most `cap` spans and `cap` events.
    pub fn with_cap(cap: usize) -> Self {
        Recorder {
            spans: Vec::new(),
            events: Vec::new(),
            batches: Vec::new(),
            last_throttled_s: 0.0,
            busy_sum: 0.0,
            retain_cap: cap,
            dropped_spans: 0,
            dropped_events: 0,
            dropped_batches: 0,
        }
    }

    /// Record one busy span. `throttled_s` is the device's cumulative
    /// throttle time *after* the span: when it grew, the span was
    /// stretched by the thermal governor and a `Throttle` instant (with
    /// the governor rung and the stall delta) is emitted at the span's
    /// end.
    pub fn busy_span(&mut self, span: Span, throttled_s: f64, rung: usize) {
        self.busy_sum += span.dur;
        if throttled_s > self.last_throttled_s {
            self.push_event(Event {
                kind: EventKind::Throttle,
                t: span.start + span.dur,
                arrival: span.arrival,
                detail: rung,
                stall_s: throttled_s - self.last_throttled_s,
            });
            self.last_throttled_s = throttled_s;
        }
        if self.spans.len() < self.retain_cap {
            self.spans.push(span);
        } else {
            self.dropped_spans += 1;
        }
    }

    pub fn event(&mut self, kind: EventKind, t: f64, arrival: f64) {
        self.push_event(Event { kind, t, arrival, detail: 0, stall_s: 0.0 });
    }

    fn push_event(&mut self, e: Event) {
        if self.events.len() < self.retain_cap {
            self.events.push(e);
        } else {
            self.dropped_events += 1;
        }
    }

    /// Record one decode step's batch membership. Capped independently
    /// at the same `retain_cap` as spans/events; the member list copies
    /// values that already advanced the simulated clock, so recording
    /// stays pure observation.
    pub fn decode_batch(&mut self, start: f64, dur: f64, arrivals: Vec<f64>) {
        if self.batches.len() < self.retain_cap {
            self.batches.push(BatchRecord { start, dur, arrivals });
        } else {
            self.dropped_batches += 1;
        }
    }

    /// `(spans, events)` discarded past the retention cap.
    pub fn dropped(&self) -> (u64, u64) {
        (self.dropped_spans, self.dropped_events)
    }

    /// Decode-batch membership records discarded past the retention cap.
    pub fn dropped_batches(&self) -> u64 {
        self.dropped_batches
    }

    /// Sum of span durations, folded in recorded order from 0.0 — the
    /// exact operation the device performs on its `busy` accumulator, so
    /// the two agree bit-for-bit (even when retention dropped spans: the
    /// sum is accumulated before the gate).
    pub fn busy_total(&self) -> f64 {
        self.busy_sum
    }
}

/// One named timeline in the exported trace.
pub struct Track<'a> {
    pub tid: usize,
    pub label: String,
    pub rec: &'a Recorder,
}

fn span_event(tid: usize, s: &Span) -> Json {
    let mut pairs = vec![
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(s.start * 1e6)),
        ("dur", Json::Num(s.dur * 1e6)),
        ("name", Json::Str(s.kind.name().to_string())),
        ("cat", Json::Str(s.kind.cat().to_string())),
    ];
    let mut args = Vec::new();
    if s.arrival >= 0.0 {
        args.push(("arrival_s", Json::Num(s.arrival)));
    }
    if s.batch > 1 {
        args.push(("batch", Json::Num(s.batch as f64)));
    }
    if !args.is_empty() {
        pairs.push(("args", jobj(args)));
    }
    jobj(pairs)
}

fn instant_event(tid: usize, e: &Event) -> Json {
    let mut args = Vec::new();
    if e.arrival >= 0.0 {
        args.push(("arrival_s", Json::Num(e.arrival)));
    }
    if e.kind == EventKind::Throttle {
        args.push(("governor_rung", Json::Num(e.detail as f64)));
        args.push(("stall_s", Json::Num(e.stall_s)));
    }
    let mut pairs = vec![
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("t".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(e.t * 1e6)),
        ("name", Json::Str(e.kind.name().to_string())),
    ];
    if !args.is_empty() {
        pairs.push(("args", jobj(args)));
    }
    jobj(pairs)
}

fn thread_name(tid: usize, label: &str) -> Json {
    jobj(vec![
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("name", Json::Str("thread_name".to_string())),
        ("args", jobj(vec![("name", Json::Str(label.to_string()))])),
    ])
}

/// Serialize recorded timelines as a Chrome-trace JSON document.
///
/// Timestamps and durations are microseconds (the format's unit), i.e.
/// simulated seconds × 1e6. Events are emitted in deterministic order
/// (tracks in the given order; spans then instants in recorded order),
/// so the same replay always produces byte-identical output.
pub fn chrome_trace(tracks: &[Track<'_>], kv_spans: &[Span], kv_label: &str) -> Json {
    let mut events = Vec::new();
    for t in tracks {
        events.push(thread_name(t.tid, &t.label));
        for s in &t.rec.spans {
            events.push(span_event(t.tid, s));
        }
        for e in &t.rec.events {
            events.push(instant_event(t.tid, e));
        }
    }
    if !kv_spans.is_empty() {
        let kv_tid = tracks.iter().map(|t| t.tid + 1).max().unwrap_or(0);
        events.push(thread_name(kv_tid, kv_label));
        for s in kv_spans {
            events.push(span_event(kv_tid, s));
        }
    }
    jobj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, start: f64, dur: f64) -> Span {
        Span { kind, start, dur, arrival: 0.0, batch: 1 }
    }

    #[test]
    fn busy_total_folds_in_order() {
        let mut r = Recorder::new();
        let durs = [0.1, 0.07, 1e-9, 0.3];
        let mut busy = 0.0;
        for (i, &d) in durs.iter().enumerate() {
            r.busy_span(span(SpanKind::Prefill, i as f64, d), 0.0, 0);
            busy += d;
        }
        assert_eq!(r.busy_total().to_bits(), busy.to_bits());
    }

    #[test]
    fn throttle_instant_emitted_once_per_increase() {
        let mut r = Recorder::new();
        r.busy_span(span(SpanKind::DecodeStep, 0.0, 0.1), 0.0, 0);
        r.busy_span(span(SpanKind::DecodeStep, 0.1, 0.2), 0.05, 2);
        r.busy_span(span(SpanKind::DecodeStep, 0.3, 0.1), 0.05, 2);
        let th: Vec<_> = r.events.iter().filter(|e| e.kind == EventKind::Throttle).collect();
        assert_eq!(th.len(), 1);
        assert_eq!(th[0].detail, 2);
        assert!((th[0].t - 0.3).abs() < 1e-12);
        // the instant carries the stall delta it reported
        assert_eq!(th[0].stall_s.to_bits(), 0.05f64.to_bits());
    }

    #[test]
    fn retention_cap_bounds_memory_but_busy_total_stays_exact() {
        let mut capped = Recorder::with_cap(8);
        let mut full = Recorder::new();
        let mut busy = 0.0;
        for i in 0..100 {
            let s = span(SpanKind::DecodeStep, i as f64, 0.013 * (i + 1) as f64);
            capped.busy_span(s, 0.0, 0);
            full.busy_span(s, 0.0, 0);
            capped.event(EventKind::Done, s.start + s.dur, 0.0);
            busy += s.dur;
        }
        assert_eq!(capped.spans.len(), 8, "span retention is capped");
        assert_eq!(capped.events.len(), 8, "event retention is capped");
        assert_eq!(capped.dropped(), (92, 92));
        assert_eq!(full.dropped(), (0, 0));
        // the retained prefix is the earliest spans, untouched
        assert_eq!(capped.spans[..], full.spans[..8]);
        // busy reconciliation is exact despite the drops
        assert_eq!(capped.busy_total().to_bits(), busy.to_bits());
        assert_eq!(capped.busy_total().to_bits(), full.busy_total().to_bits());
    }

    #[test]
    fn batch_records_are_capped_independently_of_spans() {
        let mut r = Recorder::with_cap(3);
        for i in 0..10 {
            r.decode_batch(i as f64, 0.01, vec![0.0, 1.0]);
        }
        assert_eq!(r.batches.len(), 3);
        assert_eq!(r.dropped_batches(), 7);
        // span/event drop counters are untouched by batch drops
        assert_eq!(r.dropped(), (0, 0));
        assert_eq!(r.batches[0].arrivals, vec![0.0, 1.0]);
    }

    #[test]
    fn chrome_trace_is_valid_and_deterministic() {
        let mut r = Recorder::new();
        r.busy_span(span(SpanKind::Prefill, 0.0, 0.5), 0.0, 0);
        r.event(EventKind::Done, 0.5, 0.0);
        let tracks = vec![Track { tid: 0, label: "dev0".to_string(), rec: &r }];
        let kv =
            [Span { kind: SpanKind::KvTransfer, start: 0.5, dur: 0.01, arrival: 0.0, batch: 1 }];
        let doc = chrome_trace(&tracks, &kv, "interconnect");
        let s1 = doc.to_string();
        let s2 = chrome_trace(&tracks, &kv, "interconnect").to_string();
        assert_eq!(s1, s2);
        let parsed = Json::parse(&s1).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name + 1 span + 1 instant + 1 kv span
        assert_eq!(evs.len(), 5);
        assert!(evs.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("X")));
        // kv track lands on its own tid, one past the max device tid
        let kv_ev =
            evs.iter().find(|e| e.get("name").and_then(Json::as_str) == Some("kv_transfer"));
        assert_eq!(kv_ev.unwrap().get("tid").and_then(Json::as_f64), Some(1.0));
    }
}

//! Monitoring-plane integration pins: windowed telemetry driven from
//! inside the serve loop must not perturb the simulation (bit-exact
//! fingerprints), its per-window populations must merge bucket-for-
//! bucket onto the whole-run histograms, latency attribution must fold
//! bit-exactly onto the recorded TTFT/e2e, and empty windows on sparse
//! streams must read as zeros, never NaN.

use halo::cluster::{
    collect_trace, ArrivalKind, Fleet, Interconnect, Mix, Policy, Router, SchedConfig,
    ServeOptions, TrafficConfig,
};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::obs::{self, BurnRateConfig, WindowSeries};
use halo::sim::queueing::TraceRequest;

/// The monitored configuration of interest: phase-disaggregated pools
/// with chunked prefill, so queue wait, prefill chunks, KV handoff and
/// decode all contribute to latency.
fn chunked_fleet(devices: usize) -> (Fleet, Box<dyn Router>) {
    Policy::PhaseDisaggregated.build_with(
        &LlmConfig::llama2_7b(),
        &HwConfig::paper(),
        devices,
        8,
        0.5,
        Interconnect::board(),
        SchedConfig::chunked(256),
    )
}

fn mmpp_trace(seed: u64, n: usize, rate: f64) -> Vec<TraceRequest> {
    let cfg = TrafficConfig::new(seed, rate, 1.0e9, Mix::Chat)
        .with_kind(ArrivalKind::Mmpp)
        .with_max_requests(n);
    collect_trace(&mut cfg.build())
}

#[test]
fn monitored_replay_is_bit_identical_and_merges_bucket_for_bucket() {
    let trace = mmpp_trace(4242, 300, 24.0);
    let (mut plain_fleet, mut plain_router) = chunked_fleet(4);
    let plain = plain_fleet.replay(&trace, plain_router.as_mut());

    let (mut mon_fleet, mut mon_router) = chunked_fleet(4);
    let mut series = WindowSeries::new(2.0, 64);
    let mon = mon_fleet.replay_monitored(&trace, mon_router.as_mut(), &mut series);

    // observation must not perturb a single simulated f64
    assert_eq!(plain.fingerprint(), mon.fingerprint(), "monitoring changed the replay");

    // the windowed populations merge bit-exactly onto the global ones
    let mt = series.merged_ttft();
    let me = series.merged_e2e();
    assert_eq!(mt.counts(), mon.ttft_hist.counts(), "ttft buckets diverge");
    assert_eq!(me.counts(), mon.e2e_hist.counts(), "e2e buckets diverge");
    assert_eq!(mt.min().to_bits(), mon.ttft_hist.min().to_bits());
    assert_eq!(mt.max().to_bits(), mon.ttft_hist.max().to_bits());
    for p in [1.0, 25.0, 50.0, 90.0, 99.0] {
        assert_eq!(mt.percentile(p).to_bits(), mon.ttft_hist.percentile(p).to_bits());
        assert_eq!(me.percentile(p).to_bits(), mon.e2e_hist.percentile(p).to_bits());
    }

    // and the window counters conserve the run's totals
    let arrivals: u64 = series.windows().iter().map(|w| w.arrivals).sum();
    let completions: u64 = series.windows().iter().map(|w| w.completions).sum();
    let tokens: u64 = series.windows().iter().map(|w| w.tokens).sum();
    assert_eq!(arrivals as usize, trace.len());
    assert_eq!(completions as usize, mon.requests);
    assert_eq!(tokens, mon.tokens);
}

#[test]
fn attribution_folds_bit_exactly_on_a_chunked_disaggregated_replay() {
    let trace = mmpp_trace(7, 200, 24.0);
    let (mut fleet, mut router) = chunked_fleet(4);
    fleet.enable_obs();
    let r = fleet.replay(&trace, router.as_mut());

    let recorders = fleet.recorders().expect("obs enabled");
    let attrs = obs::attribute(&r.served, &recorders, fleet.kv_spans().expect("obs enabled"));
    assert_eq!(attrs.len(), r.requests);
    assert_eq!(obs::reconcile(&attrs), 0, "components must fold bit-exactly onto ttft/e2e");

    // the configuration exercises every component source
    assert!(attrs.iter().any(|a| a.queue_wait > 0.0), "bursty load must queue");
    assert!(attrs.iter().any(|a| a.prefill > 0.0), "prefill chunks must attribute");
    assert!(attrs.iter().any(|a| a.kv_handoff > 0.0), "disaggregation must hand off KV");
    assert!(attrs.iter().any(|a| a.decode > 0.0), "decode must attribute");

    // the tail table is well-formed: component shares sum to 1, the
    // closing e2e row carries share 1.0
    let rows = obs::tail_breakdown(&attrs, 99.0);
    assert_eq!(rows.last().unwrap().component, "e2e");
    let share: f64 = rows[..rows.len() - 1].iter().map(|r| r.tail_share).sum();
    assert!((share - 1.0).abs() < 1e-6, "tail shares sum to {share}");
}

#[test]
fn low_rate_diurnal_stream_keeps_empty_windows_zero_not_nan() {
    let cfg = TrafficConfig::new(5, 0.2, 120.0, Mix::Chat).with_kind(ArrivalKind::Diurnal);
    let mut gen = cfg.build();
    let (mut fleet, mut router) = chunked_fleet(2);
    let mut series = WindowSeries::new(5.0, 64);
    let r = fleet.serve_monitored(&mut gen, router.as_mut(), ServeOptions::exact(), &mut series);

    assert!(r.requests > 0, "the stream must serve something");
    let empties = series.windows().iter().filter(|w| w.completions == 0).count();
    assert!(empties > 0, "a low-rate diurnal stream must leave idle windows");

    let spec = obs::SloSpec::interactive();
    let report = obs::slo::evaluate(&series, &spec, &BurnRateConfig::default());
    assert_eq!(report.per_window.len(), series.len());
    let width = series.width_s();
    for (w, s) in series.windows().iter().zip(&report.per_window) {
        for v in [
            w.ttft_pct(99.0),
            w.e2e_pct(50.0),
            w.throughput_rps(width),
            w.utilization(width, 2),
            s.ttft_attainment,
            s.e2e_attainment,
            s.ttft_burn_fast,
            s.e2e_burn_slow,
        ] {
            assert!(v.is_finite(), "telemetry must stay finite on every window, got {v}");
        }
        if w.completions == 0 {
            assert_eq!(w.ttft_pct(99.0), 0.0);
            assert_eq!(s.ttft_attainment, 0.0);
            assert_eq!(s.e2e_attainment, 0.0);
        }
    }
    // idle troughs burn no error budget, so a quiet stream never alerts
    // on its empty windows
    for a in &report.alerts {
        let bad_window = &series.windows()[a.window];
        assert!(bad_window.completions > 0, "an empty window can never raise an alert");
    }
    let total: u64 = series.windows().iter().map(|w| w.completions).sum();
    assert_eq!(total as usize, r.requests);
}

#[test]
fn long_streams_coarsen_in_place_and_stay_retention_independent() {
    let cfg = TrafficConfig::new(9, 40.0, 400.0, Mix::Chat).with_max_requests(1_500);
    let mut gen = cfg.build();
    let (mut fleet, mut router) = chunked_fleet(2);
    let mut series = WindowSeries::new(0.5, 16);
    // a tight retention cap: raw records are sampled, histograms exact
    let opts = ServeOptions::streaming(256);
    let r = fleet.serve_monitored(&mut gen, router.as_mut(), opts, &mut series);

    assert!(!r.complete, "the cap must have been hit for this pin to mean anything");
    assert!(series.coarsenings() > 0, "a long stream must coarsen its windows");
    assert!(series.len() <= 16, "the window budget is a hard bound");
    let completions: u64 = series.windows().iter().map(|w| w.completions).sum();
    assert_eq!(completions as usize, r.requests);
    // merging stays bit-exact even when raw-record retention was capped
    assert_eq!(series.merged_ttft().counts(), r.ttft_hist.counts());
    assert_eq!(series.merged_e2e().counts(), r.e2e_hist.counts());
}

//! Hardware configuration: Table I of the paper plus every timing/energy
//! constant of the analytical models, with per-value provenance.
//!
//! Values marked `CALIBRATED` are not given by the paper or its references
//! and were chosen so the reproduced *ratios* land in the paper's bands
//! (see DESIGN.md §6 and EXPERIMENTS.md); everything else carries a
//! citation comment.

/// HBM3 stack geometry and DRAM timing/energy.
#[derive(Debug, Clone, PartialEq)]
pub struct HbmConfig {
    /// Number of HBM3 stacks (Table I: 80 GB over 5 stacks).
    pub stacks: usize,
    /// Capacity per stack, bytes (16 GB -> 80 GB total).
    pub stack_capacity: u64,
    /// Channels per stack (HBM3: 16 independent 64-bit channels).
    pub channels_per_stack: usize,
    /// Bank groups per channel (HBM3 JEDEC: 4).
    pub bankgroups_per_channel: usize,
    /// Banks per bank group (4 -> 16 banks/channel).
    pub banks_per_bankgroup: usize,
    /// Per-channel IO bandwidth, B/s (6.4 Gb/s/pin x 64 pins = 51.2 GB/s).
    pub channel_bw: f64,
    /// Column-to-column delay, s (tCCD; the in-bank streaming cadence).
    pub t_ccd: f64,
    /// Row activate latency, s (tRCD).
    pub t_rcd: f64,
    /// Row buffer (page) size per bank, bytes.
    pub row_bytes: usize,
    /// Bank-level read energy, J/byte (1.1 pJ/bit near-bank sensing [13][22]).
    pub e_bank_read: f64,
    /// Off-stack read energy incl. IO/PHY, J/byte (3.5 pJ/bit, HBM3 [22]).
    pub e_io_read: f64,
}

impl HbmConfig {
    pub fn paper() -> Self {
        HbmConfig {
            stacks: 5,
            stack_capacity: 16 << 30,
            channels_per_stack: 16,
            bankgroups_per_channel: 4,
            banks_per_bankgroup: 4,
            channel_bw: 51.2e9,
            t_ccd: 2.0e-9,
            t_rcd: 13.75e-9,
            row_bytes: 1024,
            e_bank_read: 8.8e-12, // 1.1 pJ/bit
            e_io_read: 28.0e-12,  // 3.5 pJ/bit
        }
    }

    pub fn total_banks(&self) -> usize {
        self.stacks * self.channels_per_stack * self.bankgroups_per_channel
            * self.banks_per_bankgroup
    }

    pub fn total_capacity(&self) -> u64 {
        self.stacks as u64 * self.stack_capacity
    }

    /// Aggregate off-stack IO bandwidth, B/s.
    pub fn io_bw(&self) -> f64 {
        self.stacks as f64 * self.channels_per_stack as f64 * self.channel_bw
    }

    /// Aggregate bank-level internal bandwidth, B/s (what CiD taps).
    pub fn internal_bw(&self, bytes_per_access: usize) -> f64 {
        self.total_banks() as f64 * bytes_per_access as f64 / self.t_ccd
    }

    /// Streaming overhead factor for row activation: reading a full row
    /// of `row_bytes` takes `row_bytes/access` tCCDs plus one tRCD.
    pub fn row_overhead(&self, bytes_per_access: usize) -> f64 {
        let accesses = self.row_bytes as f64 / bytes_per_access as f64;
        1.0 + self.t_rcd / (accesses * self.t_ccd)
    }
}

/// CiD: bank-level compute units (Fig. 3b).
#[derive(Debug, Clone, PartialEq)]
pub struct CidConfig {
    /// 8-bit multipliers per bank (paper §IV-A: 32).
    pub mults_per_bank: usize,
    /// Weight bytes consumed per column access (= mults, int8).
    pub bytes_per_access: usize,
    /// Local double-buffered input SRAM per bank cluster, bytes (4 KB).
    pub input_buffer: usize,
    /// Banks sharing one input buffer (paper §IV-A: the buffered input is
    /// "broadcasted to multiple bank groups and banks" — one buffer serves
    /// a broadcast cluster, halving the per-bank resident input rows).
    pub buffer_share: usize,
    /// int8 MAC energy incl. adder-tree share, J. Genus 65 nm synthesis
    /// scaled per [26] gives ~0.25 pJ in 7 nm CMOS; implemented in the
    /// 1z-nm DRAM process (paper §V-A: 10x density gap, slower/leakier
    /// logic transistors) we apply a 1.6x process penalty -> 0.4 pJ.
    pub e_mac: f64,
    /// Local SRAM access energy, J/byte.
    pub e_sram: f64,
}

impl CidConfig {
    pub fn paper() -> Self {
        CidConfig {
            mults_per_bank: 32,
            bytes_per_access: 32,
            input_buffer: 4096,
            buffer_share: 2,
            e_mac: 0.4e-12,
            e_sram: 0.5e-12,
        }
    }
}

/// Analog CiM accelerator (Fig. 3a/3c, Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct CimConfig {
    /// Tile mesh (Table I: 4x4).
    pub tile_mesh: (usize, usize),
    /// Core mesh per tile (Table I: 2x2).
    pub core_mesh: (usize, usize),
    /// Crossbars per core (Table I: one CiM unit = 8 crossbars).
    pub xbars_per_core: usize,
    /// Crossbar rows/cols (128x128).
    pub xbar_dim: usize,
    /// Weight bits per cell (2 b/cell -> an 8-bit weight spans 4 xbars).
    pub cell_bits: usize,
    /// Operand precision (8-bit).
    pub weight_bits: usize,
    pub input_bits: usize,
    /// ADCs per crossbar (Table I: 48x 7-bit SAR).
    pub adcs_per_xbar: usize,
    pub adc_bits: usize,
    /// Wordlines activated per phase (128 = HALO1/AttAcc1, 64 = HALO2/2).
    pub wordlines: usize,
    /// Global buffer size/bandwidth (Table I: 4 MB, 2 TB/s).
    pub gb_bytes: usize,
    pub gb_bw: f64,
    /// Child buffer sizes (Table I: IB 32 KB, WB 64 KB, OB 128 KB) and
    /// their aggregate bandwidth (4 TB/s).
    pub ib_bytes: usize,
    pub wb_bytes: usize,
    pub ob_bytes: usize,
    pub child_bw: f64,
    /// Time for one input-bit wordline phase (DAC settle + 48 interleaved
    /// SAR conversions covering 128 columns). CALIBRATED: 1.5 ns, which
    /// puts chip peak at 175 TMAC/s = 8.5x the CiD peak; combined with the
    /// write-bound small-L_in regime this lands the paper's ~6x geomean
    /// prefill speedup band.
    pub t_bit_phase: f64,
    /// Crossbar row write time (weight streaming / KV updates).
    /// CALIBRATED: 20 ns/row -> fully-CiM decode lands at the paper's
    /// ~39x TPOT penalty and the Fig. 9 crossover near batch 64.
    pub t_write_row: f64,
    /// 7-bit SAR conversion energy, J ([7]: 3.8 mW @ 1 GS/s in 65 nm
    /// = 3.8 pJ/conv, scaled to 7 nm per [26] -> ~0.5 pJ/conv).
    pub e_adc: f64,
    /// Analog MAC energy (array + DAC/driver share), J.
    pub e_mac_analog: f64,
    /// Cell write energy, J per byte of weight written (4 cells/byte).
    pub e_write: f64,
    /// On-chip buffer access energy, J/byte (GB/IB/WB/OB average).
    pub e_buf: f64,
    /// Partial-sum accumulator access energy, J/byte (core-local
    /// register-file accumulators next to the shift-and-add).
    pub e_acc: f64,
    /// NoC energy per byte per hop and mean hop count.
    pub e_noc_hop: f64,
    pub mean_hops: f64,
}

impl CimConfig {
    pub fn paper() -> Self {
        CimConfig {
            tile_mesh: (4, 4),
            core_mesh: (2, 2),
            xbars_per_core: 8,
            xbar_dim: 128,
            cell_bits: 2,
            weight_bits: 8,
            input_bits: 8,
            adcs_per_xbar: 48,
            adc_bits: 7,
            wordlines: 128,
            gb_bytes: 4 << 20,
            gb_bw: 2.0e12,
            ib_bytes: 32 << 10,
            wb_bytes: 64 << 10,
            ob_bytes: 128 << 10,
            child_bw: 4.0e12,
            t_bit_phase: 1.5e-9,
            t_write_row: 20.0e-9,
            e_adc: 0.5e-12,
            e_mac_analog: 0.05e-12,
            e_write: 4.0e-12,
            e_buf: 1.0e-12,
            e_acc: 0.1e-12,
            e_noc_hop: 0.2e-12,
            mean_hops: 2.0,
        }
    }

    /// HALO2 variant: 64 of 128 wordlines active (Table II).
    pub fn with_wordlines(mut self, wl: usize) -> Self {
        assert!(self.xbar_dim % wl == 0, "wordlines must divide xbar_dim");
        self.wordlines = wl;
        self
    }

    /// Scale the tile mesh (a `dse` knob: more CiM tiles buy prefill
    /// throughput at a proportional area/cost premium). Buffer sizes and
    /// bandwidths are left untouched — the mesh is the first-order lever.
    pub fn with_tile_mesh(mut self, mesh: (usize, usize)) -> Self {
        assert!(mesh.0 > 0 && mesh.1 > 0, "tile mesh must be non-empty");
        self.tile_mesh = mesh;
        self
    }

    pub fn cores(&self) -> usize {
        self.tile_mesh.0 * self.tile_mesh.1 * self.core_mesh.0 * self.core_mesh.1
    }

    pub fn total_xbars(&self) -> usize {
        self.cores() * self.xbars_per_core
    }

    /// Crossbars per logical int8 weight tile (bit slicing).
    pub fn xbars_per_tile(&self) -> usize {
        self.weight_bits / self.cell_bits
    }

    /// Resident 128x128 int8 weight tiles per core.
    pub fn tiles_per_core(&self) -> usize {
        self.xbars_per_core / self.xbars_per_tile()
    }

    /// Resident int8 weight tiles chip-wide.
    pub fn resident_tiles(&self) -> usize {
        self.cores() * self.tiles_per_core()
    }

    /// Resident weight bytes chip-wide.
    pub fn resident_bytes(&self) -> usize {
        self.resident_tiles() * self.xbar_dim * self.xbar_dim
    }

    /// Wordline phases per input bit (128/wl: 1 for HALO1, 2 for HALO2).
    pub fn phases(&self) -> usize {
        self.xbar_dim / self.wordlines
    }

    /// Time to stream one input vector through a resident tile
    /// (bit-serial: input_bits x phases x t_bit_phase).
    pub fn t_vector(&self) -> f64 {
        self.input_bits as f64 * self.phases() as f64 * self.t_bit_phase
    }

    /// Peak MAC/s (all resident tiles streaming).
    pub fn peak_macs(&self) -> f64 {
        self.resident_tiles() as f64 * (self.xbar_dim * self.xbar_dim) as f64 / self.t_vector()
    }

    /// ADC conversions per input vector per resident tile.
    pub fn conversions_per_vector(&self) -> f64 {
        // every column of every slice-crossbar is digitized once per input
        // bit per wordline phase
        (self.input_bits * self.xbars_per_tile() * self.xbar_dim * self.phases()) as f64
    }

    /// Time to write one full weight tile into a core's crossbars
    /// (rows written sequentially; slice crossbars in parallel).
    pub fn t_tile_write(&self) -> f64 {
        self.xbar_dim as f64 * self.t_write_row
    }
}

/// Digital systolic-array alternative (Fig. 10 / NeuPIM-style HALO-SA).
#[derive(Debug, Clone, PartialEq)]
pub struct SystolicConfig {
    /// Arrays per core (paper §V-D: two per core at iso-area).
    pub sa_per_core: usize,
    /// Array dimension. Paper uses 128x128; we size 32x32 at 7 nm so that
    /// 2 SAs/core is genuinely iso-area with 8 crossbars + 384 SAR ADCs
    /// (~0.1 mm^2 each; an 8-bit MAC PE is far larger than an 8T cell
    /// column slice + shared ADC). CALIBRATED via HiSim-class area
    /// reasoning — the paper's exact HiSim tables are unavailable.
    pub sa_dim: usize,
    /// Clock, Hz. CALIBRATED: 0.7 GHz — 2.5D interposer thermal envelope
    /// (HiSim-class derate over the nominal 1 GHz).
    pub freq: f64,
    /// 8-bit MAC energy (digital, 7 nm), J.
    pub e_mac: f64,
}

impl SystolicConfig {
    pub fn paper() -> Self {
        SystolicConfig { sa_per_core: 2, sa_dim: 32, freq: 0.7e9, e_mac: 0.3e-12 }
    }
}

/// Logic-die non-GEMM units (Fig. 3d).
#[derive(Debug, Clone, PartialEq)]
pub struct LogicDieConfig {
    /// Vector unit width (Table I: 512 lanes) and clock.
    pub vector_width: usize,
    pub freq: f64,
    /// Exponent-unit throughput, exp/s: a 512-lane exponent array at
    /// 0.5 GHz (dedicated units for softmax, paper §IV-A).
    pub exp_per_s: f64,
    /// Scalar (RISC-V BOOM) op rate for div/sqrt etc.
    pub scalar_ops_per_s: f64,
    /// Vector op energy, J/op; exponent op energy, J/op.
    pub e_vec_op: f64,
    pub e_exp_op: f64,
    /// Bandwidth of the logic-die datapath to/from DRAM banks, B/s.
    pub die_bw: f64,
}

impl LogicDieConfig {
    pub fn paper() -> Self {
        LogicDieConfig {
            vector_width: 512,
            freq: 1.0e9,
            exp_per_s: 256.0e9,
            scalar_ops_per_s: 4.0e9,
            e_vec_op: 0.5e-12,
            e_exp_op: 2.0e-12,
            die_bw: 4.096e12, // stack IO aggregate
        }
    }
}

/// One voltage-frequency operating point of the package DVFS ladder.
///
/// Scales are relative to the nominal Table-I clocks: every timed phase
/// (CiD `t_ccd` streaming cadence, CiM bit-phases and row writes,
/// logic-die clocks) stretches as `1/f_scale`, and dynamic CV^2
/// switching energy scales as `v_scale^2`. The static floor does not
/// scale — refresh is temperature-driven, and the leakage delta over
/// these shallow voltage steps is inside the calibration noise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DvfsPoint {
    pub name: &'static str,
    /// Clock frequency relative to nominal (1.0 = Table I).
    pub f_scale: f64,
    /// Supply voltage relative to nominal.
    pub v_scale: f64,
}

impl DvfsPoint {
    pub fn nominal() -> Self {
        DvfsPoint { name: "nominal", f_scale: 1.0, v_scale: 1.0 }
    }

    /// Latency multiplier of a timed phase at this point (`1/f`).
    pub fn time_scale(&self) -> f64 {
        1.0 / self.f_scale
    }

    /// Dynamic-energy multiplier at this point (`V^2`).
    pub fn energy_scale(&self) -> f64 {
        self.v_scale * self.v_scale
    }

    /// Mean-power multiplier of a fixed unit of work (`f * V^2`): the
    /// energy shrinks by `V^2` while the time stretches by `1/f`.
    pub fn power_scale(&self) -> f64 {
        self.f_scale * self.energy_scale()
    }

    pub fn is_nominal(&self) -> bool {
        self.f_scale == 1.0 && self.v_scale == 1.0
    }
}

/// Package-level power constants for the `power` plane: background
/// (static) power integrated over wall-clock time, the default thermal
/// design power of one HALO package, and the DVFS operating-point
/// ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerConfig {
    /// HBM refresh background power per stack, W. CALIBRATED: ~1.2 W for
    /// a 16 GB HBM3 stack at normal temperature; DRAM refresh rate (and
    /// hence this power) doubles above the hot threshold (JEDEC 2x
    /// refresh above ~85C), which the thermal model applies when the CiM
    /// die heats the co-packaged stacks.
    pub refresh_w_per_stack: f64,
    /// Package static leakage (CiM + logic dies + PHYs), W. CALIBRATED.
    pub leakage_w: f64,
    /// Default package TDP, W (`halo power --tdp auto`). CALIBRATED:
    /// sized just above the fully-CiD decode streaming power (~150 W
    /// dynamic + static floor) so the paper-point config runs unthrottled
    /// at nominal load but a tighter cap bites immediately.
    pub tdp_w: f64,
    /// Voltage-frequency operating points, fastest first; index 0 must be
    /// nominal. CALIBRATED: the voltage steps are shallow (the 2.5D
    /// package is IR-drop limited), so stepping down trades real latency
    /// for modest CV^2 savings — memory-bound decode, whose streaming
    /// power dwarfs the static floor, profits on energy per token, while
    /// compute-bound prefill pays the stretched static-time penalty.
    pub dvfs_points: Vec<DvfsPoint>,
}

impl PowerConfig {
    pub fn paper() -> Self {
        PowerConfig {
            refresh_w_per_stack: 1.2,
            leakage_w: 10.0,
            tdp_w: 180.0,
            dvfs_points: vec![
                DvfsPoint::nominal(),
                DvfsPoint { name: "balanced", f_scale: 0.8, v_scale: 0.97 },
                DvfsPoint { name: "eco", f_scale: 0.6, v_scale: 0.93 },
            ],
        }
    }

    /// Background (static) power floor of one package, W: refresh across
    /// all `stacks` plus leakage. `hot_refresh` doubles the refresh share.
    pub fn static_w(&self, stacks: usize, hot_refresh: bool) -> f64 {
        let refresh = self.refresh_w_per_stack * stacks as f64;
        self.leakage_w + if hot_refresh { 2.0 * refresh } else { refresh }
    }

    /// Ladder position of a named operating point (case-insensitive).
    pub fn dvfs_index(&self, name: &str) -> Option<usize> {
        self.dvfs_points.iter().position(|p| p.name.eq_ignore_ascii_case(name))
    }
}

/// 2.5D interposer link between HBM stacks and the CiM chiplet.
#[derive(Debug, Clone, PartialEq)]
pub struct InterposerConfig {
    /// Link bandwidth, B/s (sized to the CiM GB: 2 TB/s, Table I).
    pub bw: f64,
    /// Transfer energy, J/byte (0.6 pJ/bit ubump+wire, 2.5D [31]).
    pub e_link: f64,
}

impl InterposerConfig {
    pub fn paper() -> Self {
        InterposerConfig { bw: 2.0e12, e_link: 4.8e-12 }
    }

    /// Bandwidth-scaled variant (a `dse` knob: wider/narrower 2.5D link).
    /// Energy per byte is geometry-bound and does not scale with width.
    pub fn scaled(mut self, factor: f64) -> Self {
        assert!(factor > 0.0, "bandwidth scale must be positive");
        self.bw *= factor;
        self
    }
}

/// Complete HALO hardware description (Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    pub hbm: HbmConfig,
    pub cid: CidConfig,
    pub cim: CimConfig,
    pub systolic: SystolicConfig,
    pub logic: LogicDieConfig,
    pub interposer: InterposerConfig,
    pub power: PowerConfig,
}

impl HwConfig {
    /// The paper's Table I configuration (HALO1: 128 wordlines).
    pub fn paper() -> Self {
        HwConfig {
            hbm: HbmConfig::paper(),
            cid: CidConfig::paper(),
            cim: CimConfig::paper(),
            systolic: SystolicConfig::paper(),
            logic: LogicDieConfig::paper(),
            interposer: InterposerConfig::paper(),
            power: PowerConfig::paper(),
        }
    }

    /// HALO2: 64 of 128 wordlines active.
    pub fn paper_wl64() -> Self {
        let mut hw = Self::paper();
        hw.cim = hw.cim.with_wordlines(64);
        hw
    }

    /// CiD peak MAC/s (all banks).
    pub fn cid_peak_macs(&self) -> f64 {
        self.hbm.total_banks() as f64 * self.cid.mults_per_bank as f64 / self.hbm.t_ccd
    }

    /// Per-device KV-cache byte budget: HBM capacity left after the
    /// resident model weights. The serving simulator's decode pools use
    /// this as the default capacity limit when one is requested.
    pub fn kv_budget(&self, weight_bytes: u64) -> u64 {
        self.hbm.total_capacity().saturating_sub(weight_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let hw = HwConfig::paper();
        // Table I rows
        assert_eq!(hw.hbm.stacks, 5);
        assert_eq!(hw.hbm.total_capacity(), 80 << 30);
        assert_eq!(hw.cim.tile_mesh, (4, 4));
        assert_eq!(hw.cim.core_mesh, (2, 2));
        assert_eq!(hw.cim.gb_bytes, 4 << 20);
        assert_eq!(hw.cim.gb_bw, 2.0e12);
        assert_eq!(hw.cim.ib_bytes, 32 << 10);
        assert_eq!(hw.cim.wb_bytes, 64 << 10);
        assert_eq!(hw.cim.ob_bytes, 128 << 10);
        assert_eq!(hw.cim.xbars_per_core, 8);
        assert_eq!(hw.cim.xbar_dim, 128);
        assert_eq!(hw.cim.adcs_per_xbar, 48);
        assert_eq!(hw.cim.adc_bits, 7);
        assert_eq!(hw.logic.vector_width, 512);
    }

    #[test]
    fn derived_geometry() {
        let hw = HwConfig::paper();
        assert_eq!(hw.hbm.total_banks(), 1280);
        assert_eq!(hw.cim.cores(), 64);
        assert_eq!(hw.cim.total_xbars(), 512);
        assert_eq!(hw.cim.xbars_per_tile(), 4);
        assert_eq!(hw.cim.tiles_per_core(), 2);
        assert_eq!(hw.cim.resident_tiles(), 128);
        assert_eq!(hw.cim.resident_bytes(), 2 << 20);
    }

    #[test]
    fn peak_rates_are_in_the_designed_band() {
        let hw = HwConfig::paper();
        let cid = hw.cid_peak_macs();
        let cim = hw.cim.peak_macs();
        // CiD: 1280 banks x 32 mults / 2 ns = 20.48 TMAC/s
        assert!((cid / 20.48e12 - 1.0).abs() < 1e-9, "cid {cid:e}");
        // CiM HALO1: 128 tiles x 16384 / 12 ns = 174.8 TMAC/s
        assert!((cim / 174.76e12 - 1.0).abs() < 1e-3, "cim {cim:e}");
        let ratio = cim / cid;
        assert!(ratio > 6.0 && ratio < 11.0, "cim/cid {ratio}");
    }

    #[test]
    fn halo2_halves_rows_doubles_phases() {
        let h1 = HwConfig::paper();
        let h2 = HwConfig::paper_wl64();
        assert_eq!(h2.cim.phases(), 2);
        assert!((h2.cim.t_vector() / h1.cim.t_vector() - 2.0).abs() < 1e-12);
        assert!(
            (h2.cim.conversions_per_vector() / h1.cim.conversions_per_vector() - 2.0).abs()
                < 1e-12
        );
    }

    #[test]
    fn internal_bw_exceeds_io_bw() {
        let hbm = HbmConfig::paper();
        // the whole premise of CiD: bank-level bandwidth >> off-stack IO
        assert!(hbm.internal_bw(32) > 4.0 * hbm.io_bw());
    }

    #[test]
    fn row_overhead_reasonable() {
        let hbm = HbmConfig::paper();
        let ov = hbm.row_overhead(32);
        assert!(ov > 1.1 && ov < 1.3, "{ov}");
    }

    #[test]
    #[should_panic]
    fn wordlines_must_divide() {
        CimConfig::paper().with_wordlines(100);
    }

    #[test]
    fn tile_mesh_scaling_scales_peak() {
        let base = CimConfig::paper();
        let wide = CimConfig::paper().with_tile_mesh((8, 4));
        assert_eq!(wide.resident_tiles(), 2 * base.resident_tiles());
        assert!((wide.peak_macs() / base.peak_macs() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn interposer_scaling_touches_bw_only() {
        let base = InterposerConfig::paper();
        let fat = InterposerConfig::paper().scaled(2.0);
        assert_eq!(fat.bw, 2.0 * base.bw);
        assert_eq!(fat.e_link, base.e_link);
    }

    #[test]
    #[should_panic]
    fn interposer_scale_must_be_positive() {
        InterposerConfig::paper().scaled(0.0);
    }

    #[test]
    fn static_power_floor_and_hot_refresh() {
        let hw = HwConfig::paper();
        let cold = hw.power.static_w(hw.hbm.stacks, false);
        let hot = hw.power.static_w(hw.hbm.stacks, true);
        // leakage + 5 stacks of refresh; hot doubles only the refresh share
        assert!((cold - (10.0 + 5.0 * 1.2)).abs() < 1e-12, "{cold}");
        assert!((hot - cold - 5.0 * 1.2).abs() < 1e-12, "{hot}");
        // the static floor is well under the default TDP
        assert!(cold < hw.power.tdp_w / 5.0);
    }

    #[test]
    fn dvfs_ladder_is_ordered_and_monotone() {
        let p = PowerConfig::paper();
        assert!(p.dvfs_points.len() >= 3, "need at least 3 operating points");
        assert!(p.dvfs_points[0].is_nominal(), "index 0 must be nominal");
        for w in p.dvfs_points.windows(2) {
            // fastest first: frequency and voltage fall down the ladder
            assert!(w[1].f_scale < w[0].f_scale);
            assert!(w[1].v_scale <= w[0].v_scale);
            // lower points strictly stretch time and strictly cut the
            // mean power of a fixed unit of work
            assert!(w[1].time_scale() > w[0].time_scale());
            assert!(w[1].power_scale() < w[0].power_scale());
            // dynamic energy per op never grows going down
            assert!(w[1].energy_scale() <= w[0].energy_scale());
        }
        assert_eq!(p.dvfs_index("ECO"), Some(p.dvfs_points.len() - 1));
        assert_eq!(p.dvfs_index("nominal"), Some(0));
        assert_eq!(p.dvfs_index("warp"), None);
    }

    #[test]
    fn kv_budget_leaves_room_after_weights() {
        let hw = HwConfig::paper();
        // a 7B int8 model leaves most of the 80 GB for KV
        let budget = hw.kv_budget(7 << 30);
        assert_eq!(budget, (80u64 << 30) - (7 << 30));
        // degenerate: weights larger than HBM clamp to zero
        assert_eq!(hw.kv_budget(u64::MAX), 0);
    }
}

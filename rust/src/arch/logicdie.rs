//! Logic-die non-GEMM units (Fig. 3d): 512-wide vector unit, dedicated
//! exponent units for softmax, and a RISC-V scalar core for div/sqrt.
//!
//! Non-GEMM ops are a small fraction of the FLOPs (paper §IV-A) but sit on
//! the critical path between GEMM stages; the model charges vector-lane
//! time, exponent-unit time, scalar time, and the activation streaming
//! through the logic-die datapath, taking the max (the units pipeline
//! against the stream).

use super::OpCost;
use crate::config::HwConfig;
use crate::model::Op;

#[derive(Debug, Clone)]
pub struct LogicDieEngine {
    hw: HwConfig,
}

impl LogicDieEngine {
    pub fn new(hw: &HwConfig) -> Self {
        LogicDieEngine { hw: hw.clone() }
    }

    pub fn non_gemm_cost(&self, op: &Op) -> OpCost {
        let lg = &self.hw.logic;
        let count = op.count as f64;
        let elems = op.elems as f64 * count;
        let exps = op.exp_elems as f64 * count;
        let scalars = op.scalar_elems as f64 * count;
        let bytes = op.stream_bytes as f64 * count;

        let t_vec = elems / (lg.vector_width as f64 * lg.freq);
        let t_exp = exps / lg.exp_per_s;
        let t_scalar = scalars / lg.scalar_ops_per_s;
        let t_stream = bytes / lg.die_bw;
        let latency = t_vec.max(t_exp).max(t_scalar).max(t_stream);

        let e_compute = elems * lg.e_vec_op + exps * lg.e_exp_op + scalars * 10.0 * lg.e_vec_op;
        let e_dram = bytes * self.hw.hbm.e_bank_read;

        OpCost {
            latency,
            energy: e_compute + e_dram,
            t_compute: t_vec.max(t_exp).max(t_scalar),
            t_memory: t_stream,
            t_write: 0.0,
            e_dram,
            e_compute,
            e_buffer: 0.0,
            e_write: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig, Op, OpKind};

    fn engine() -> LogicDieEngine {
        LogicDieEngine::new(&HwConfig::paper())
    }

    #[test]
    fn softmax_is_exp_bound() {
        let e = engine();
        // exp-heavy softmax: equal exp and vector elems; exp is the
        // slower unit (256 G/s vs 512 lanes at 1 GHz)
        let op = Op::non_gemm(OpKind::Softmax, 1_000_000, 1).with_exp(1_000_000);
        let c = e.non_gemm_cost(&op);
        let lg = &HwConfig::paper().logic;
        assert!((c.latency - 1.0e6 / lg.exp_per_s).abs() < 1e-12);
    }

    #[test]
    fn nongemm_is_small_fraction_of_decode() {
        // paper §IV-A: non-GEMM ops don't need bank-level parallelism
        let e = engine();
        let m = LlmConfig::llama2_7b();
        let g = build_decode_graph(&m, 2048, 1);
        let t: f64 = g.non_gemm_ops().map(|o| e.non_gemm_cost(o).latency).sum();
        // well under the ~0.4 ms CiD weight stream
        assert!(t < 0.2e-3, "non-GEMM {t}");
    }

    #[test]
    fn prefill_nongemm_positive_energy() {
        let e = engine();
        let m = LlmConfig::qwen3_8b();
        let g = build_prefill_graph(&m, 1024, 1);
        for op in g.non_gemm_ops() {
            let c = e.non_gemm_cost(op);
            assert!(c.latency > 0.0 || op.elems == 0, "{:?}", op.kind);
            assert!(c.energy > 0.0);
        }
    }

    #[test]
    fn scalar_ops_can_dominate() {
        let e = engine();
        let op = Op::non_gemm(OpKind::RmsNorm, 10, 1).with_scalar(1_000_000);
        let c = e.non_gemm_cost(&op);
        let lg = &HwConfig::paper().logic;
        assert!((c.latency - 1.0e6 / lg.scalar_ops_per_s).abs() < 1e-12);
    }
}

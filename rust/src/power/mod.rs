//! Power plane: per-event energy attribution, TDP/thermal throttling, and
//! windowed power traces for the event-driven simulator.
//!
//! The analytical `arch` plane has always computed per-op joules; this
//! plane threads that energy through everything built on top of it:
//!
//! * [`model`] — [`EnergyModel`], the energy twin of the device
//!   `CostModel`: memoized per-event energies (prefill, chunked prefill,
//!   batched decode step) whose dynamic components come from the same
//!   `simulate_graph` walk the arch plane uses, plus the static floor
//!   (HBM refresh + leakage) integrated over wall-clock time;
//! * [`thermal`] — a per-package RC thermal model with a TDP cap whose
//!   throttle factor *feeds back into service time*, and a 2.5D coupling
//!   term that pushes CiM-die heat into the HBM stacks, doubling refresh
//!   power in the JEDEC hot band;
//! * [`trace`] — windowed average/peak power timelines from the per-event
//!   logs.
//!
//! A [`DevicePower`] instance attaches to one `sim::device::Device`
//! (`Device::enable_power`) and is advanced by the device on every busy
//! event; with tracking disabled the device's latency math is untouched
//! (bit-identical replays — pinned by `tests/power_plane.rs`). The
//! cluster plane aggregates per-device energy into fleet stats, and the
//! `dse` plane scores `energy-per-token` / `edp` / `peak-power`
//! objectives over a TDP axis. Surfaces: `halo power`,
//! `halo report --fig power`.

pub mod model;
pub mod thermal;
pub mod trace;

pub use model::{EnergyBreakdown, EnergyModel};
pub use thermal::{ThermalConfig, ThermalModel};
pub use trace::{power_trace, PowerEvent, PowerTrace};

/// Per-device power state: the energy model, optional thermal/TDP state,
/// the accumulated energy breakdown, and the per-event log.
pub struct DevicePower {
    pub model: EnergyModel,
    pub thermal: Option<ThermalModel>,
    /// Accumulated energy of every busy event (dynamic + busy-time
    /// static). Idle-time static is added at collection, where the
    /// observer knows the replay makespan.
    pub energy: EnergyBreakdown,
    /// Busy-event log for windowed power traces.
    pub events: Vec<PowerEvent>,
    /// Highest mean event power seen, W.
    pub peak_w: f64,
    /// Extra service time added by thermal throttling, s.
    pub throttled_s: f64,
}

impl DevicePower {
    pub fn new(model: EnergyModel, thermal: Option<ThermalModel>) -> Self {
        DevicePower {
            model,
            thermal,
            energy: EnergyBreakdown::default(),
            events: Vec::new(),
            peak_w: 0.0,
            throttled_s: 0.0,
        }
    }

    /// Account one busy event starting at `start` with unthrottled
    /// duration `raw_dt` and dynamic energy `dynamic`. Applies the
    /// thermal throttle (stretching the event), charges busy-time static
    /// power (doubled refresh when the HBM stacks are hot), heats the
    /// package, and returns the actual duration the device clock must
    /// advance by. Without a thermal model the duration is returned
    /// untouched.
    pub fn busy_event(&mut self, start: f64, raw_dt: f64, dynamic: EnergyBreakdown) -> f64 {
        let idle_w = self.model.static_power(false);
        let (dt, hot) = match &mut self.thermal {
            None => (raw_dt, false),
            Some(th) => {
                th.advance_idle(start, idle_w);
                (raw_dt / th.throttle_factor(), th.hbm_hot())
            }
        };
        let mut e = dynamic;
        e.e_static += self.model.static_power(hot) * dt;
        let total = e.total();
        let watts = total / dt.max(1e-30);
        if let Some(th) = &mut self.thermal {
            th.heat(dt, watts);
        }
        self.energy.add(&e);
        self.peak_w = self.peak_w.max(watts);
        self.throttled_s += dt - raw_dt;
        self.events.push(PowerEvent { start, end: start + dt, joules: total });
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::mapping::MappingKind;
    use crate::model::LlmConfig;

    fn meter(thermal: Option<ThermalConfig>) -> DevicePower {
        let em = EnergyModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), MappingKind::Halo1);
        DevicePower::new(em, thermal.map(ThermalModel::new))
    }

    #[test]
    fn untracked_thermal_keeps_duration_exact() {
        let mut pw = meter(None);
        let e = pw.model.prefill(256);
        let raw = 0.0123456789f64;
        let dt = pw.busy_event(1.0, raw, e);
        assert_eq!(dt.to_bits(), raw.to_bits(), "no thermal model, no stretching");
        assert_eq!(pw.throttled_s, 0.0);
        assert_eq!(pw.events.len(), 1);
        // event energy = dynamic + static floor over the event
        let want = e.dynamic() + pw.model.static_power(false) * raw;
        assert!((pw.events[0].joules - want).abs() < 1e-12 * want);
        assert!(pw.peak_w > 0.0);
    }

    #[test]
    fn hot_package_stretches_events_and_logs_throttle_time() {
        // pre-heat far above a tiny TDP ceiling, then run an event
        let mut pw = meter(Some(ThermalConfig::paper(20.0)));
        pw.thermal.as_mut().unwrap().heat(100.0, 200.0);
        let e = pw.model.decode_step(4, 1024);
        let raw = 1e-3;
        let dt = pw.busy_event(100.0, raw, e);
        assert!(dt > raw * 2.0, "expected a strong throttle, got {}x", dt / raw);
        assert!((pw.throttled_s - (dt - raw)).abs() < 1e-15);
        let ev = pw.events[0];
        // end - start loses a few ulps of `start`'s magnitude
        assert!((ev.duration() - dt).abs() < 1e-12);
    }

    #[test]
    fn accumulated_energy_matches_event_log() {
        let mut pw = meter(None);
        let mut t = 0.0;
        for l in [128usize, 256, 512] {
            let e = pw.model.prefill(l);
            let dt = pw.busy_event(t, 0.01, e);
            t += dt;
        }
        let logged: f64 = pw.events.iter().map(|e| e.joules).sum();
        assert!((pw.energy.total() - logged).abs() < 1e-9 * logged);
    }
}

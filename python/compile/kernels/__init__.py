"""L1 kernels: functional models of HALO's two compute substrates.

* :mod:`.cim_matmul` — analog CiM crossbar GEMM (Pallas, bit-sliced /
  bit-streamed / ADC-quantized).
* :mod:`.cid_gemv`   — digital CiD bank-level GEMV (Pallas, exact int8).
* :mod:`.ref`        — pure-jnp oracles for both, plus the quantization
  helpers shared by the L2 model.
"""

from .ref import CimSpec, HALO1_SPEC, HALO2_SPEC, XBAR_ROWS  # noqa: F401
from .cim_matmul import cim_linear, cim_matmul, cim_matmul_codes  # noqa: F401
from .cid_gemv import cid_gemv, cid_linear  # noqa: F401

//! Fig. 9 scenario explorer: how the HALO/CENT vs AttAcc trade-off moves
//! with batch size, and where the crossover lands (paper: around 64).
//!
//!     cargo run --release --example batch_sweep

use halo::config::HwConfig;
use halo::mapping::MappingKind;
use halo::model::LlmConfig;
use halo::sim::{simulate_e2e, Scenario};
use halo::util::fmt_seconds;

fn main() {
    let hw = HwConfig::paper();
    let m = LlmConfig::llama2_7b();
    println!("LLaMA-2 7B, L_in=128, L_out=2048 (the paper's Fig. 9 setup)\n");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>12}",
        "batch", "HALO1 e2e", "CENT e2e", "AttAcc1 e2e", "AttAcc1/HALO1"
    );
    let mut crossover = None;
    for b in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let sc = Scenario { l_in: 128, l_out: 2048, batch: b };
        let halo = simulate_e2e(&m, &hw, MappingKind::Halo1, &sc).e2e_latency();
        let cent = simulate_e2e(&m, &hw, MappingKind::Cent, &sc).e2e_latency();
        let att = simulate_e2e(&m, &hw, MappingKind::AttAcc1, &sc).e2e_latency();
        println!(
            "{:>6} {:>14} {:>14} {:>14} {:>11.2}x",
            b,
            fmt_seconds(halo),
            fmt_seconds(cent),
            fmt_seconds(att),
            att / halo
        );
        if att < halo && crossover.is_none() {
            crossover = Some(b);
        }
    }
    match crossover {
        Some(b) => println!(
            "\nAttAcc1 overtakes the phase-aware mapping at batch {b} \
             (paper observes the flip at 64): batching amortizes its decode \
             weight streaming, while per-sequence KV attention keeps scaling."
        ),
        None => println!("\nno crossover in the swept range"),
    }
}

//! Microbenchmarks of the power plane: the overhead of per-event energy
//! attribution on a fleet replay (the zero-feedback observer path), the
//! thermally throttled replay (attribution + RC updates + stretched
//! events), and windowed power-trace extraction.

use halo::cluster::{FleetBuilder, Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::power::{power_trace, DvfsConfig, ThermalConfig};
use halo::util::bench::{bb, BenchSuite};

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();
    let mut s = BenchSuite::new("power_replay");
    let trace = Mix::Interactive.trace(71, 120, 40.0);

    // baseline: the untracked replay the observer must not perturb
    s.bench_throughput("fleet4_replay_untracked", trace.len() as f64, || {
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, &hw, 4, 8, 0.5, Interconnect::board());
        bb(fleet.replay(&trace, router.as_mut()));
    });

    s.bench_throughput("fleet4_replay_power_tracked", trace.len() as f64, || {
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, &hw, 4, 8, 0.5, Interconnect::board());
        fleet.enable_power(&hw, None);
        bb(fleet.replay(&trace, router.as_mut()));
    });

    s.bench_throughput("fleet4_replay_tdp_throttled", trace.len() as f64, || {
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, &hw, 4, 8, 0.5, Interconnect::board());
        fleet.enable_power(&hw, Some(ThermalConfig::paper(100.0)));
        bb(fleet.replay(&trace, router.as_mut()));
    });

    s.bench_throughput("fleet4_replay_dvfs_governor", trace.len() as f64, || {
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, &hw, 4, 8, 0.5, Interconnect::board());
        fleet.enable_power(&hw, Some(ThermalConfig::paper(100.0)));
        fleet.set_dvfs(DvfsConfig::governed(&hw.power));
        bb(fleet.replay(&trace, router.as_mut()));
    });

    // trace extraction over a realistic event log
    let mut fleet = FleetBuilder::new(&llm, &hw)
        .devices(1)
        .slots(8)
        .interconnect(Interconnect::board())
        .power(None)
        .build();
    let mut router = Policy::LeastLoaded.router();
    let r = fleet.replay(&trace, router.as_mut());
    let pw = fleet.devices[0].power().expect("tracked");
    let floor = pw.static_power(false);
    s.bench("power_trace_64_windows", || {
        bb(power_trace(&pw.events, floor, r.makespan, 64));
    });

    s.finish();
}

//! Microbenchmarks of the DSE plane: candidate evaluation (one fleet
//! replay + scoring), full smoke-grid searches, hill-climbing over the
//! fleet space, and the parallel / multi-fidelity variants of the grid
//! — the paths `halo dse` sits on.

use halo::cluster::Mix;
use halo::dse::{explore, DseConfig, Exhaustive, Fidelity, HillClimb, RandomSearch, SearchSpace};
use halo::model::LlmConfig;
use halo::util::bench::{bb, BenchSuite};

fn main() {
    let mut s = BenchSuite::new("dse_search");
    let base = {
        let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
        cfg.requests = 48;
        cfg.rate = Some(15.0); // fixed load: no calibration inside the loop
        cfg
    };

    // one-candidate space = the cost of a single evaluation
    let point = SearchSpace::paper_point();
    s.bench("evaluate_single_candidate", || {
        bb(explore(&point, &mut Exhaustive, &base));
    });

    let smoke = SearchSpace::smoke();
    s.bench_throughput("grid_smoke_space", smoke.len() as f64, || {
        bb(explore(&smoke, &mut Exhaustive, &base));
    });

    let fleet = SearchSpace::fleet();
    s.bench_throughput("random12_fleet_space", 12.0, || {
        bb(explore(&fleet, &mut RandomSearch { samples: 12, seed: 9 }, &base));
    });

    s.bench("hillclimb_fleet_space", || {
        let mut hc = HillClimb { restarts: 1, steps: 6, seed: 5 };
        bb(explore(&fleet, &mut hc, &base));
    });

    // the same power-space grid at 1 and 4 evaluation threads: the pair
    // measures the worker pool's speedup on a bit-identical search
    let power = SearchSpace::preset("power").unwrap();
    let par = {
        let mut cfg = base.clone();
        cfg.requests = 24;
        cfg
    };
    s.bench_throughput("grid_power_space_t1", power.len() as f64, || {
        bb(explore(&power, &mut Exhaustive, &par));
    });
    let par4 = {
        let mut cfg = par.clone();
        cfg.threads = 4;
        cfg
    };
    s.bench_throughput("grid_power_space_t4", power.len() as f64, || {
        bb(explore(&power, &mut Exhaustive, &par4));
    });

    // successive halving over the same grid: most replays are short
    // prefixes, only survivors pay the full trace
    let halved = {
        let mut cfg = par.clone();
        cfg.fidelity = Fidelity::halving();
        cfg
    };
    s.bench_throughput("grid_power_space_halving", power.len() as f64, || {
        bb(explore(&power, &mut Exhaustive, &halved));
    });

    s.finish();
}

//! Critical-path-plane tables: which resource binds the fleet (whole
//! population vs p99 tail, and per phase), and what the standard
//! hardware counterfactuals would buy — the `halo report --fig
//! critpath` artifact, run on the same MMPP chat stream as the
//! observability tables so the two figures read side by side.

use super::Table;
use crate::cluster::{
    collect_trace, ArrivalKind, Interconnect, Mix, Policy, SchedConfig, TrafficConfig,
};
use crate::config::HwConfig;
use crate::model::LlmConfig;
use crate::obs::{self, bottleneck_profile, extract_paths, phase_profile, reconcile_paths};

use super::f;

/// Decode slots per device (matches the cluster/obs-plane tables).
const SLOTS: usize = 8;

fn critpath_trace(rate: f64) -> Vec<crate::sim::queueing::TraceRequest> {
    let cfg = TrafficConfig::new(4242, rate, 40.0, Mix::Chat)
        .with_kind(ArrivalKind::Mmpp)
        .with_max_requests(400);
    collect_trace(&mut cfg.build())
}

/// Run the shared instrumented replay and extract every path.
fn extracted(hw: &HwConfig, rate: f64) -> Vec<obs::CritPath> {
    let llm = LlmConfig::llama2_7b();
    let trace = critpath_trace(rate);
    let (mut fleet, mut router) = Policy::PhaseDisaggregated.build_with(
        &llm,
        hw,
        4,
        SLOTS,
        0.5,
        Interconnect::board(),
        SchedConfig::chunked(256),
    );
    fleet.enable_obs();
    let r = fleet.replay(&trace, router.as_mut());
    let recorders = fleet.recorders().expect("obs enabled");
    let kv = fleet.kv_spans().expect("obs enabled");
    let paths = extract_paths(&r.served, &recorders, kv);
    debug_assert_eq!(reconcile_paths(&paths), 0, "paths must fold bit-exactly");
    paths
}

/// Per-resource critical-path shares, whole population vs the p99 e2e
/// tail, with the per-phase split alongside — "what resource binds the
/// tail" as one table.
pub fn bottleneck_table(hw: &HwConfig) -> Table {
    let rate = 24.0;
    let paths = extracted(hw, rate);
    let rows = bottleneck_profile(&paths, 99.0);
    let phases = phase_profile(&paths);
    let mut t = Table::new(
        "critpath_bottleneck",
        &format!(
            "Critical-path bottleneck profile — seconds and share per binding resource, \
             all requests vs p99 e2e tail, with per-phase shares \
             (LLaMA-2 7B, chat MMPP {rate:.1} req/s, 4-dev disaggregated, chunked prefill)"
        ),
        &["resource", "total_s", "share", "tail_s", "tail_share", "prefill_share", "decode_share"],
    );
    for row in rows {
        let phase_share = |phase: &str| {
            phases
                .iter()
                .find(|p| p.phase == phase && p.resource == row.resource)
                .map_or(0.0, |p| p.share)
        };
        t.row(vec![
            row.resource.name().to_string(),
            f(row.total_s),
            f(row.share),
            f(row.tail_s),
            f(row.tail_share),
            f(phase_share("prefill")),
            f(phase_share("decode")),
        ]);
    }
    t
}

/// The standard what-if table: estimated p99 movement under each
/// counterfactual, from re-folding the extracted paths with scaled
/// resources — no re-simulation.
pub fn whatif_table(hw: &HwConfig) -> Table {
    let rate = 24.0;
    let paths = extracted(hw, rate);
    let results = obs::evaluate_all(&paths, &obs::standard_whatifs());
    let mut t = Table::new(
        "critpath_whatif",
        &format!(
            "What-if virtual speedups — estimated TTFT/e2e p99 under scaled resources \
             (LLaMA-2 7B, chat MMPP {rate:.1} req/s, 4-dev disaggregated, chunked prefill)"
        ),
        &[
            "whatif",
            "base_ttft_p99_s",
            "est_ttft_p99_s",
            "delta_ttft_p99_s",
            "base_e2e_p99_s",
            "est_e2e_p99_s",
            "delta_e2e_p99_s",
        ],
    );
    for r in results {
        t.row(vec![
            r.name.to_string(),
            f(r.base_ttft_p99_s),
            f(r.est_ttft_p99_s),
            f(r.delta_ttft_p99_s),
            f(r.base_e2e_p99_s),
            f(r.est_e2e_p99_s),
            f(r.delta_e2e_p99_s),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bottleneck_table_covers_every_resource_and_shares_sum() {
        let t = bottleneck_table(&HwConfig::paper());
        assert_eq!(t.rows.len(), obs::N_RESOURCES);
        let share: f64 = t.col_f64("share").iter().sum();
        assert!((share - 1.0).abs() < 1e-6, "resource shares sum to 1, got {share}");
        let tail: f64 = t.col_f64("tail_share").iter().sum();
        assert!((tail - 1.0).abs() < 1e-6);
    }

    #[test]
    fn whatif_table_rows_are_finite_and_never_hurt() {
        let t = whatif_table(&HwConfig::paper());
        assert_eq!(t.rows.len(), 4);
        for h in ["base_e2e_p99_s", "est_e2e_p99_s", "delta_e2e_p99_s"] {
            for v in t.col_f64(h) {
                assert!(v.is_finite());
            }
        }
        // a pure speedup counterfactual can only move the estimate down
        for d in t.col_f64("delta_e2e_p99_s") {
            assert!(d <= 1e-9, "speedup what-ifs must not raise the estimated p99: {d}");
        }
    }
}

//! L3 coordinator: the serving stack around the PJRT runtime.
//!
//! Mirrors the paper's phase-aware execution at the *system* level: a new
//! request runs the **prefill** executable (whose GEMMs were lowered
//! through the analog-CiM Pallas kernel) once, then joins the slot-based
//! continuous **decode** batch (exact-int8 CiD kernel path). Python is not
//! involved; the token loop is pure Rust + PJRT.
//!
//! * [`request`]  — request/response types and per-request metrics.
//! * [`kv_cache`] — batched KV-cache state and slot bookkeeping.
//! * [`engine`]   — `InferenceEngine`: prefill + batched decode steps.
//! * [`batcher`]  — admission queue and continuous-batching policy.
//! * [`server`]   — thread-based request loop with latency metrics.

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod request;
pub mod server;

pub use batcher::Batcher;
pub use engine::InferenceEngine;
pub use kv_cache::KvCache;
pub use request::{Request, Response};
pub use server::Server;

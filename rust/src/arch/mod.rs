//! Architecture cost models: per-operation latency/energy on each compute
//! substrate (CiD banks, analog CiM, digital systolic arrays, logic-die
//! vector units).
//!
//! Each engine implements [`MatmulEngine::matmul_cost`] returning an
//! [`OpCost`] with a component breakdown; the sim engine picks the engine
//! per op according to the active mapping (Table II) and aggregates.

pub mod cid;
pub mod cim;
pub mod logicdie;
pub mod systolic;

use crate::model::Op;

/// Which substrate executes an operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineSel {
    /// Bank-level compute-in-DRAM units.
    Cid,
    /// Analog compute-in-memory chiplet.
    Cim,
    /// Digital systolic-array chiplet (HALO-SA ablation).
    Systolic,
    /// Logic-die vector/exponent/scalar units.
    LogicDie,
}

impl EngineSel {
    pub fn name(&self) -> &'static str {
        match self {
            EngineSel::Cid => "cid",
            EngineSel::Cim => "cim",
            EngineSel::Systolic => "systolic",
            EngineSel::LogicDie => "logic",
        }
    }
}

/// Latency/energy of one operation, with the latency decomposed into the
/// pipeline components that bound it (components overlap; `latency` is the
/// pipelined total, not their sum).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCost {
    /// Pipelined wall-clock latency, s.
    pub latency: f64,
    /// Total energy, J.
    pub energy: f64,
    /// Time the compute units are the bottleneck (serial sum of
    /// compute-bound rounds), s.
    pub t_compute: f64,
    /// Time DRAM/interconnect streaming is the bottleneck, s.
    pub t_memory: f64,
    /// Time crossbar (or SA) weight writes are the bottleneck, s.
    pub t_write: f64,
    /// Energy sub-components, J.
    pub e_dram: f64,
    pub e_compute: f64,
    pub e_buffer: f64,
    pub e_write: f64,
}

impl OpCost {
    pub fn add(&mut self, o: &OpCost) {
        self.latency += o.latency;
        self.energy += o.energy;
        self.t_compute += o.t_compute;
        self.t_memory += o.t_memory;
        self.t_write += o.t_write;
        self.e_dram += o.e_dram;
        self.e_compute += o.e_compute;
        self.e_buffer += o.e_buffer;
        self.e_write += o.e_write;
    }

    pub fn scaled(&self, f: f64) -> OpCost {
        OpCost {
            latency: self.latency * f,
            energy: self.energy * f,
            t_compute: self.t_compute * f,
            t_memory: self.t_memory * f,
            t_write: self.t_write * f,
            e_dram: self.e_dram * f,
            e_compute: self.e_compute * f,
            e_buffer: self.e_buffer * f,
            e_write: self.e_write * f,
        }
    }
}

/// A substrate that can execute matrix products.
pub trait MatmulEngine {
    /// Cost of executing `op` (all `count` instances).
    fn matmul_cost(&self, op: &Op) -> OpCost;
    /// Peak MAC/s (roofline ceiling).
    fn peak_macs(&self) -> f64;
    /// Effective stationary-operand streaming bandwidth, B/s (roofline
    /// slope for the memory-bound region).
    fn stream_bw(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcost_add_and_scale() {
        let a = OpCost { latency: 1.0, energy: 2.0, t_compute: 0.5, ..Default::default() };
        let mut b = a;
        b.add(&a);
        assert_eq!(b.latency, 2.0);
        assert_eq!(b.energy, 4.0);
        let c = a.scaled(3.0);
        assert_eq!(c.t_compute, 1.5);
    }
}

//! Windowed time-series telemetry over *simulated* time.
//!
//! Whole-run aggregates hide nonstationary behavior: an MMPP burst that
//! doubles p99 TTFT for two simulated minutes is invisible in a run-level
//! histogram. [`WindowSeries`] slices a streamed serve into fixed-width
//! windows of simulated seconds and aggregates, per window: arrivals,
//! completions, token throughput, queue depth, per-device utilization,
//! throttle/energy deltas, KV pressure, and full TTFT/e2e
//! [`LogHistogram`]s.
//!
//! Two invariants drive the design:
//!
//! - **Pure observation.** The series is fed from inside
//!   [`crate::cluster::fleet::Fleet::serve`] but only *copies* the same
//!   `f64`s that advance the simulated clock — a monitored serve is
//!   bit-identical to an unmonitored one (pinned by
//!   `rust/tests/monitor_plane.rs`).
//! - **Fixed memory.** The series owns at most `max_windows` windows.
//!   When simulated time outgrows the budget the series *coarsens*:
//!   window width doubles and adjacent pairs merge ([`LogHistogram`]
//!   merges are exact on counts), so a million-request stream keeps the
//!   flat-RSS guarantee of `rust/tests/stream_memory.rs` while still
//!   ending with a full-run series at the finest width that fits.
//!
//! Cumulative device gauges (busy seconds, throttle seconds, energy)
//! are sampled at window close and *differenced* against the previous
//! close, so per-window deltas telescope exactly to the run totals.
//! When a roll closes several windows at once (an idle gap), the whole
//! gap's delta lands on the first window closed — later ones close
//! empty, which is the truthful reading of an idle trough.

use super::hist::LogHistogram;
use super::jobj;
use crate::util::json::Json;

/// Instantaneous telemetry for one device: queue/KV state plus the
/// device's *cumulative* busy/throttle/energy meters. Produced by
/// `Device::telemetry`; consumed via [`GaugeSample::from_devices`].
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceGauges {
    /// Jobs delivered but not yet admitted.
    pub queue_depth: u64,
    /// Sequences resident in decode slots plus in-progress prefills.
    pub active: u64,
    /// Resident KV bytes right now.
    pub kv_resident_bytes: u64,
    /// Cumulative busy seconds since construction.
    pub busy_s: f64,
    /// Cumulative thermal-throttle stall seconds (0 when power is off).
    pub throttled_s: f64,
    /// Cumulative attributed energy in joules (0 when power is off).
    pub energy_j: f64,
}

/// A fleet-wide gauge snapshot at one simulated instant: device gauges
/// summed, with per-device cumulative busy retained for utilization.
#[derive(Debug, Clone, Default)]
pub struct GaugeSample {
    pub queue_depth: u64,
    pub active: u64,
    pub kv_resident_bytes: u64,
    pub busy_s: f64,
    pub throttled_s: f64,
    pub energy_j: f64,
    pub per_dev_busy_s: Vec<f64>,
}

impl GaugeSample {
    pub fn from_devices<I: IntoIterator<Item = DeviceGauges>>(devices: I) -> Self {
        let mut s = GaugeSample::default();
        for d in devices {
            s.queue_depth += d.queue_depth;
            s.active += d.active;
            s.kv_resident_bytes += d.kv_resident_bytes;
            s.busy_s += d.busy_s;
            s.throttled_s += d.throttled_s;
            s.energy_j += d.energy_j;
            s.per_dev_busy_s.push(d.busy_s);
        }
        s
    }
}

/// One window of the series. Counters and histograms accumulate as
/// events land; gauge fields are set when the window closes (snapshot
/// gauges hold the close-instant value, delta gauges hold the in-window
/// difference of the cumulative meters).
#[derive(Debug, Clone, Default)]
pub struct Window {
    pub arrivals: u64,
    pub completions: u64,
    /// Output tokens of requests *completed* in this window.
    pub tokens: u64,
    /// TTFTs of completions in this window.
    pub ttft: LogHistogram,
    /// End-to-end latencies of completions in this window.
    pub e2e: LogHistogram,
    /// Fleet queue depth at window close.
    pub queue_depth: u64,
    /// Active sequences at window close.
    pub active: u64,
    /// Resident KV bytes at window close.
    pub kv_resident_bytes: u64,
    /// Busy seconds accrued fleet-wide inside this window.
    pub busy_s: f64,
    /// Throttle stall seconds accrued inside this window.
    pub throttled_s: f64,
    /// Energy joules accrued inside this window.
    pub energy_j: f64,
    /// Per-device busy seconds accrued inside this window.
    pub per_dev_busy_s: Vec<f64>,
    /// Whether this window has received its close-time gauge snapshot.
    closed: bool,
}

impl Window {
    /// Merge `other` (the *later* of an adjacent pair) into `self` for a
    /// coarsening step: counters add, histograms merge, gauge deltas
    /// add; the close-time snapshot is the later window's when it has
    /// one. The merged window is closed only if `other` was — a merge
    /// with the still-open current window stays open and takes its
    /// snapshot at the next close.
    fn absorb(&mut self, other: Window) {
        self.arrivals += other.arrivals;
        self.completions += other.completions;
        self.tokens += other.tokens;
        self.ttft.merge(&other.ttft);
        self.e2e.merge(&other.e2e);
        self.busy_s += other.busy_s;
        self.throttled_s += other.throttled_s;
        self.energy_j += other.energy_j;
        if self.per_dev_busy_s.len() < other.per_dev_busy_s.len() {
            self.per_dev_busy_s.resize(other.per_dev_busy_s.len(), 0.0);
        }
        for (i, b) in other.per_dev_busy_s.iter().enumerate() {
            self.per_dev_busy_s[i] += b;
        }
        if other.closed {
            self.queue_depth = other.queue_depth;
            self.active = other.active;
            self.kv_resident_bytes = other.kv_resident_bytes;
        }
        self.closed = other.closed;
    }

    /// Completions per simulated second (0.0 for an empty window).
    pub fn throughput_rps(&self, width_s: f64) -> f64 {
        if self.completions == 0 || width_s <= 0.0 {
            return 0.0;
        }
        self.completions as f64 / width_s
    }

    /// TTFT percentile of this window's completions (0.0 when empty —
    /// idle diurnal troughs produce genuinely empty windows).
    pub fn ttft_pct(&self, p: f64) -> f64 {
        self.ttft.percentile(p)
    }

    /// End-to-end percentile of this window's completions (0.0 when empty).
    pub fn e2e_pct(&self, p: f64) -> f64 {
        self.e2e.percentile(p)
    }

    /// Mean fleet utilization over the window: busy seconds divided by
    /// `n_dev` device-seconds of wall width (0.0 when degenerate).
    pub fn utilization(&self, width_s: f64, n_dev: usize) -> f64 {
        if n_dev == 0 || width_s <= 0.0 {
            return 0.0;
        }
        self.busy_s / (width_s * n_dev as f64)
    }
}

/// Fixed-memory windowed telemetry over simulated time. See the module
/// docs for the coarsening and gauge-delta semantics.
#[derive(Debug, Clone)]
pub struct WindowSeries {
    width: f64,
    max_windows: usize,
    windows: Vec<Window>,
    /// Index of the oldest still-open window.
    cur: usize,
    /// Cumulative gauge meters at the last window close.
    prev: GaugeSample,
    coarsenings: u32,
    finalized: bool,
}

impl WindowSeries {
    /// A series of `max_windows` windows starting `width_s` wide.
    ///
    /// Panics if `width_s` is not a positive finite number or
    /// `max_windows < 4` (coarsening needs room to halve into).
    pub fn new(width_s: f64, max_windows: usize) -> Self {
        assert!(width_s.is_finite() && width_s > 0.0, "window width must be positive");
        assert!(max_windows >= 4, "need at least 4 windows");
        WindowSeries {
            width: width_s,
            max_windows,
            windows: vec![Window::default()],
            cur: 0,
            prev: GaugeSample::default(),
            coarsenings: 0,
            finalized: false,
        }
    }

    /// Window index for simulated time `t` at the current width.
    /// Saturates (never panics) for huge `t`; `t <= 0` maps to 0.
    fn index_of(&self, t: f64) -> usize {
        if t.is_nan() || t <= 0.0 {
            return 0;
        }
        // `as` saturates at usize::MAX for out-of-range floats
        (t / self.width) as usize
    }

    /// Whether advancing to event time `t` crosses a window boundary —
    /// the caller should take a gauge sample and [`roll`](Self::roll).
    pub fn needs_roll(&self, t: f64) -> bool {
        !self.finalized && self.index_of(t) > self.cur
    }

    /// Advance the series to event time `t`, closing every window that
    /// ends at or before it with gauges from `sample`. Coarsens first if
    /// `t` falls outside the window budget.
    pub fn roll(&mut self, t: f64, sample: &GaugeSample) {
        if self.finalized {
            return;
        }
        let mut target = self.index_of(t);
        while target >= self.max_windows {
            self.coarsen();
            target = self.index_of(t);
        }
        while self.cur < target {
            self.close_current(sample);
            self.cur += 1;
            if self.windows.len() <= self.cur {
                self.windows.push(Window::default());
            }
        }
    }

    /// Double the window width: merge adjacent pairs, halve the cursor.
    fn coarsen(&mut self) {
        let old = std::mem::take(&mut self.windows);
        let mut merged: Vec<Window> = Vec::with_capacity(old.len() / 2 + 1);
        let mut it = old.into_iter();
        loop {
            let Some(mut a) = it.next() else { break };
            if let Some(b) = it.next() {
                a.absorb(b);
            }
            merged.push(a);
        }
        self.windows = merged;
        self.width *= 2.0;
        self.cur /= 2;
        self.coarsenings += 1;
    }

    /// Close the window at `cur`: snapshot gauges, difference the
    /// cumulative meters against the previous close.
    fn close_current(&mut self, sample: &GaugeSample) {
        let w = &mut self.windows[self.cur];
        w.queue_depth = sample.queue_depth;
        w.active = sample.active;
        w.kv_resident_bytes = sample.kv_resident_bytes;
        w.busy_s += sample.busy_s - self.prev.busy_s;
        w.throttled_s += sample.throttled_s - self.prev.throttled_s;
        w.energy_j += sample.energy_j - self.prev.energy_j;
        if w.per_dev_busy_s.len() < sample.per_dev_busy_s.len() {
            w.per_dev_busy_s.resize(sample.per_dev_busy_s.len(), 0.0);
        }
        for (i, b) in sample.per_dev_busy_s.iter().enumerate() {
            let p = self.prev.per_dev_busy_s.get(i).copied().unwrap_or(0.0);
            w.per_dev_busy_s[i] += b - p;
        }
        w.closed = true;
        self.prev = sample.clone();
    }

    /// Ensure a window exists for time `t` and return it (coarsening and
    /// extending as needed). Completions may land *behind* the cursor
    /// (a request finishes mid-cycle while the clock sits at the cycle
    /// end) or ahead of it (the cycle overshoots the boundary); both are
    /// bucketed at their true simulated time.
    fn window_at(&mut self, t: f64) -> &mut Window {
        let mut i = self.index_of(t);
        while i >= self.max_windows {
            self.coarsen();
            i = self.index_of(t);
        }
        while self.windows.len() <= i {
            self.windows.push(Window::default());
        }
        &mut self.windows[i]
    }

    /// Record one request arrival at simulated time `t`.
    pub fn observe_arrival(&mut self, t: f64) {
        self.window_at(t).arrivals += 1;
    }

    /// Record one request completion: `t_done` is the completion's
    /// simulated time (arrival + e2e), `tokens` its output tokens.
    pub fn observe_completion(&mut self, t_done: f64, ttft: f64, e2e: f64, tokens: u64) {
        let w = self.window_at(t_done);
        w.completions += 1;
        w.tokens += tokens;
        w.ttft.record(ttft);
        w.e2e.record(e2e);
    }

    /// Close out the series at the end of a serve: roll to `makespan`,
    /// close the last window, and freeze. Idempotent.
    pub fn finalize(&mut self, makespan: f64, sample: &GaugeSample) {
        if self.finalized {
            return;
        }
        if makespan.is_finite() {
            self.roll(makespan, sample);
        }
        self.close_current(sample);
        self.finalized = true;
    }

    /// The windows, oldest first. Window `i` covers
    /// `[start_of(i), start_of(i) + width_s())`.
    pub fn windows(&self) -> &[Window] {
        &self.windows
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Current window width in simulated seconds (doubles per coarsening).
    pub fn width_s(&self) -> f64 {
        self.width
    }

    /// Start time of window `i` in simulated seconds.
    pub fn start_of(&self, i: usize) -> f64 {
        i as f64 * self.width
    }

    /// How many times the series doubled its width to stay in budget.
    pub fn coarsenings(&self) -> u32 {
        self.coarsenings
    }

    /// All per-window TTFT histograms merged — bucket-for-bucket equal
    /// to the global streaming population (pinned by test).
    pub fn merged_ttft(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for w in &self.windows {
            h.merge(&w.ttft);
        }
        h
    }

    /// All per-window e2e histograms merged (see [`merged_ttft`](Self::merged_ttft)).
    pub fn merged_e2e(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for w in &self.windows {
            h.merge(&w.e2e);
        }
        h
    }

    /// The series as JSON (the `series` body of the
    /// `halo.timeseries.v1` snapshot).
    pub fn to_json(&self) -> Json {
        let n_dev = self.windows.iter().map(|w| w.per_dev_busy_s.len()).max().unwrap_or(0);
        let windows: Vec<Json> = self
            .windows
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let util_per_dev: Vec<Json> = (0..n_dev)
                    .map(|d| {
                        let b = w.per_dev_busy_s.get(d).copied().unwrap_or(0.0);
                        Json::Num(if self.width > 0.0 { b / self.width } else { 0.0 })
                    })
                    .collect();
                jobj(vec![
                    ("start_s", Json::Num(self.start_of(i))),
                    ("arrivals", Json::Num(w.arrivals as f64)),
                    ("completions", Json::Num(w.completions as f64)),
                    ("tokens", Json::Num(w.tokens as f64)),
                    ("throughput_rps", Json::Num(w.throughput_rps(self.width))),
                    ("queue_depth", Json::Num(w.queue_depth as f64)),
                    ("active", Json::Num(w.active as f64)),
                    ("kv_resident_bytes", Json::Num(w.kv_resident_bytes as f64)),
                    ("busy_s", Json::Num(w.busy_s)),
                    ("throttled_s", Json::Num(w.throttled_s)),
                    ("energy_j", Json::Num(w.energy_j)),
                    ("utilization", Json::Num(w.utilization(self.width, n_dev))),
                    ("util_per_device", Json::Arr(util_per_dev)),
                    ("ttft", w.ttft.to_json()),
                    ("e2e", w.e2e.to_json()),
                ])
            })
            .collect();
        jobj(vec![
            ("window_s", Json::Num(self.width)),
            ("coarsenings", Json::Num(self.coarsenings as f64)),
            ("windows", Json::Arr(windows)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(busy: f64, per_dev: &[f64]) -> GaugeSample {
        GaugeSample {
            queue_depth: 1,
            active: 2,
            kv_resident_bytes: 3,
            busy_s: busy,
            throttled_s: busy * 0.1,
            energy_j: busy * 5.0,
            per_dev_busy_s: per_dev.to_vec(),
        }
    }

    #[test]
    fn coarsening_preserves_totals_within_budget() {
        let mut s = WindowSeries::new(1.0, 4);
        let mut total = 0u64;
        for k in 0..64u64 {
            let t = k as f64 + 0.5;
            if s.needs_roll(t) {
                let g = sample(k as f64, &[k as f64]);
                s.roll(t, &g);
            }
            s.observe_arrival(t);
            s.observe_completion(t, 0.01 * (k + 1) as f64, 0.1 * (k + 1) as f64, 7);
            total += 1;
        }
        s.finalize(64.0, &sample(63.0, &[63.0]));
        assert!(s.len() <= 4, "stayed within the window budget");
        assert!(s.coarsenings() >= 4, "64 s into 4 windows of 1 s needs >= 4 doublings");
        let arrivals: u64 = s.windows().iter().map(|w| w.arrivals).sum();
        let completions: u64 = s.windows().iter().map(|w| w.completions).sum();
        let tokens: u64 = s.windows().iter().map(|w| w.tokens).sum();
        assert_eq!(arrivals, total);
        assert_eq!(completions, total);
        assert_eq!(tokens, total * 7);
        assert_eq!(s.merged_ttft().count(), total);
        assert_eq!(s.merged_e2e().count(), total);
    }

    #[test]
    fn gauge_deltas_telescope_to_run_totals() {
        let mut s = WindowSeries::new(1.0, 8);
        for k in 1..=6u64 {
            let t = k as f64 + 0.25;
            let g = sample(k as f64 * 2.0, &[k as f64, k as f64]);
            if s.needs_roll(t) {
                s.roll(t, &g);
            }
        }
        let fin = sample(12.0, &[6.0, 6.0]);
        s.finalize(6.25, &fin);
        let busy: f64 = s.windows().iter().map(|w| w.busy_s).sum();
        assert!((busy - 12.0).abs() < 1e-9, "deltas sum to the final cumulative meter");
        let dev0: f64 =
            s.windows().iter().map(|w| w.per_dev_busy_s.first().copied().unwrap_or(0.0)).sum();
        assert!((dev0 - 6.0).abs() < 1e-9);
    }

    #[test]
    fn empty_windows_are_zero_safe() {
        let mut s = WindowSeries::new(1.0, 8);
        s.finalize(5.0, &GaugeSample::default());
        for (i, w) in s.windows().iter().enumerate() {
            assert_eq!(w.throughput_rps(s.width_s()), 0.0, "window {i}");
            assert_eq!(w.ttft_pct(99.0), 0.0);
            assert_eq!(w.e2e_pct(50.0), 0.0);
            assert_eq!(w.utilization(s.width_s(), 4), 0.0);
        }
        assert_eq!(Window::default().utilization(0.0, 0), 0.0);
        // the snapshot must serialize without NaN
        let text = s.to_json().to_string();
        assert!(!text.contains("NaN") && !text.contains("null"), "{text}");
    }

    #[test]
    fn out_of_order_completions_land_in_their_true_window() {
        let mut s = WindowSeries::new(1.0, 8);
        s.roll(3.5, &GaugeSample::default());
        // completion behind the cursor: finished at t=1.2 while the
        // clock sits at 3.5
        s.observe_completion(1.2, 0.1, 0.2, 1);
        // completion ahead of the cursor: cycle overshoots to 4.8
        s.observe_completion(4.8, 0.1, 0.3, 1);
        s.finalize(5.0, &GaugeSample::default());
        assert_eq!(s.windows()[1].completions, 1);
        assert_eq!(s.windows()[4].completions, 1);
        assert_eq!(s.merged_e2e().count(), 2);
    }
}

//! Cluster-plane tables: fleet scaling and router-policy comparisons.
//!
//! Offered load is calibrated against the measured single-device
//! (monolithic HALO1) capacity so the tables stay meaningful if the
//! underlying cost model shifts: every run offers `3x` one device's
//! saturated throughput, which overloads a 1-device fleet and leaves an
//! 8-device fleet comfortable.

use super::Table;
use crate::cluster::{Interconnect, Mix, Policy};
use crate::config::HwConfig;
use crate::model::LlmConfig;

use super::f;

/// Decode slots per device used throughout the cluster tables.
const SLOTS: usize = 8;
const N_REQ: usize = 160;

/// Measured saturated throughput (req/s) of one monolithic HALO1 device
/// with `slots` decode slots on `mix`: replay a burst trace (everything
/// arrives almost at once) and read the served rate.
pub fn single_device_capacity(hw: &HwConfig, llm: &LlmConfig, mix: Mix, slots: usize) -> f64 {
    let burst = mix.trace(11, 96, 1.0e6);
    let (mut fleet, mut router) =
        Policy::LeastLoaded.build(llm, hw, 1, slots, 0.5, Interconnect::board());
    fleet.replay(&burst, router.as_mut()).throughput_rps()
}

/// Throughput and tail latency vs fleet size at fixed offered load
/// (3x single-device capacity, interactive mix, least-loaded routing).
pub fn cluster_scaling(hw: &HwConfig) -> Table {
    let t1 = single_device_capacity(hw, &LlmConfig::llama2_7b(), Mix::Interactive, SLOTS);
    cluster_scaling_at(hw, t1)
}

/// [`cluster_scaling`] with the single-device capacity `t1` already
/// measured (callers generating several tables calibrate once).
pub fn cluster_scaling_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let rate = 3.0 * t1;
    let mut t = Table::new(
        "cluster_scaling",
        &format!(
            "Cluster scaling — throughput and tail latency vs fleet size \
             (LLaMA-2 7B, {} mix, offered {:.2} req/s = 3x single-device capacity)",
            mix.name(),
            rate
        ),
        &["devices", "policy", "offered_rps", "served_rps", "ttft_p50_s", "ttft_p99_s", "e2e_p99_s", "utilization", "speedup_vs_1"],
    );
    let mut base_rps = 0.0f64;
    for devices in [1usize, 2, 4, 8] {
        let trace = mix.trace(31, N_REQ, rate);
        let (mut fleet, mut router) =
            Policy::LeastLoaded.build(&llm, hw, devices, SLOTS, 0.5, Interconnect::board());
        let r = fleet.replay(&trace, router.as_mut());
        if devices == 1 {
            base_rps = r.throughput_rps();
        }
        t.row(vec![
            devices.to_string(),
            "leastloaded".into(),
            f(rate),
            f(r.throughput_rps()),
            f(r.ttft_p50()),
            f(r.ttft_p99()),
            f(r.e2e_p99()),
            f(r.utilization()),
            f(r.throughput_rps() / base_rps.max(1e-12)),
        ]);
    }
    t
}

/// Router-policy comparison at a fixed 8-device fleet on the interactive
/// mix: monolithic round-robin and least-loaded vs phase-disaggregated
/// over progressively slower interconnects.
pub fn cluster_policy_comparison(hw: &HwConfig) -> Table {
    let t1 = single_device_capacity(hw, &LlmConfig::llama2_7b(), Mix::Interactive, SLOTS);
    cluster_policy_comparison_at(hw, t1)
}

/// [`cluster_policy_comparison`] with the single-device capacity `t1`
/// already measured.
pub fn cluster_policy_comparison_at(hw: &HwConfig, t1: f64) -> Table {
    let llm = LlmConfig::llama2_7b();
    let mix = Mix::Interactive;
    let devices = 8usize;
    let rate = 3.0 * t1;
    let trace = mix.trace(37, N_REQ, rate);
    let mut t = Table::new(
        "cluster_policy_comparison",
        &format!(
            "Router policies at {devices} devices — {} mix, offered {rate:.2} req/s",
            mix.name()
        ),
        &["policy", "link", "served_rps", "ttft_p50_s", "ttft_p99_s", "e2e_p50_s", "e2e_p99_s", "kv_gb", "utilization"],
    );
    let cases: [(Policy, Interconnect); 5] = [
        (Policy::RoundRobin, Interconnect::board()),
        (Policy::LeastLoaded, Interconnect::board()),
        (Policy::PhaseDisaggregated, Interconnect::board()),
        (Policy::PhaseDisaggregated, Interconnect::ethernet()),
        (Policy::PhaseDisaggregated, Interconnect::wan()),
    ];
    for (policy, link) in cases {
        let link_name = link.name;
        let (mut fleet, mut router) = policy.build(&llm, hw, devices, SLOTS, 0.5, link);
        let r = fleet.replay(&trace, router.as_mut());
        t.row(vec![
            policy.name().into(),
            link_name.into(),
            f(r.throughput_rps()),
            f(r.ttft_p50()),
            f(r.ttft_p99()),
            f(r.e2e_p50()),
            f(r.e2e_p99()),
            f(r.kv_bytes as f64 / 1e9),
            f(r.utilization()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_table_shape_and_trends() {
        let t = cluster_scaling(&HwConfig::paper());
        assert_eq!(t.rows.len(), 4);
        let rps = t.col_f64("served_rps");
        // adding devices never hurts served throughput under overload
        assert!(rps[3] > rps[0], "{rps:?}");
        let speedup = t.col_f64("speedup_vs_1");
        assert!((speedup[0] - 1.0).abs() < 1e-9);
        let p99 = t.col_f64("ttft_p99_s");
        assert!(p99[3] < p99[0], "tail must shrink with fleet size: {p99:?}");
    }

    #[test]
    fn policy_table_covers_links_and_counts_kv() {
        let t = cluster_policy_comparison(&HwConfig::paper());
        assert_eq!(t.rows.len(), 5);
        let kv = t.col_f64("kv_gb");
        // monolithic rows move no KV; disaggregated rows all move the same
        assert_eq!(kv[0], 0.0);
        assert_eq!(kv[1], 0.0);
        assert!(kv[2] > 0.0);
        assert!((kv[2] - kv[3]).abs() < 1e-9 && (kv[3] - kv[4]).abs() < 1e-9);
    }
}

//! Operation descriptors: the unit of work the analytical simulator costs.
//!
//! Every LLM sub-operation is either a matrix product (GEMM/GEMV, with a
//! *stationary* operand that may be a static weight or a dynamic tensor
//! like the KV cache) or a non-GEMM vector/scalar op (LayerNorm, softmax,
//! RoPE, activations, ...). The distinction between static and dynamic
//! stationary operands matters enormously on CiM (dynamic operands force
//! crossbar rewrites — why AttAcc keeps attention on CiD).

/// Whether the stationary (weight-side) operand of a matmul is a static
/// model weight or a dynamically produced tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Operand {
    /// Static model weights: persistently resident in DRAM; streamed into
    /// CiM crossbars with reuse across calls within one pass.
    StaticWeight,
    /// Dynamic tensor (KV cache, attention probabilities): produced at
    /// runtime; on CiM it must be written into crossbars on every use.
    Dynamic,
}

/// Broad operation classes used by the mapping rules and Fig. 4 breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Matrix-matrix multiply against static weights (M > 1).
    Gemm,
    /// Matrix-vector multiply against static weights (M == 1 per seq).
    Gemv,
    /// Attention score/value products (dynamic stationary operand).
    Attention,
    /// Element-wise / reduction ops on the logic die.
    NonGemm,
}

/// The specific operation kind (for breakdowns and reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    QkvProj,
    OutProj,
    FfnGate,
    FfnUp,
    FfnDown,
    LmHead,
    AttnScore,
    AttnValue,
    RmsNorm,
    Softmax,
    Rope,
    Residual,
    Activation,
    Embedding,
    KvAppend,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::QkvProj => "qkv_proj",
            OpKind::OutProj => "out_proj",
            OpKind::FfnGate => "ffn_gate",
            OpKind::FfnUp => "ffn_up",
            OpKind::FfnDown => "ffn_down",
            OpKind::LmHead => "lm_head",
            OpKind::AttnScore => "attn_score",
            OpKind::AttnValue => "attn_value",
            OpKind::RmsNorm => "rms_norm",
            OpKind::Softmax => "softmax",
            OpKind::Rope => "rope",
            OpKind::Residual => "residual",
            OpKind::Activation => "activation",
            OpKind::Embedding => "embedding",
            OpKind::KvAppend => "kv_append",
        }
    }
}

/// One costed operation.
///
/// Matmul ops represent `X (M x K) @ W (K x N)`, repeated `count` times
/// (e.g. per attention head, per layer) — `count` multiplies both work and
/// traffic. Non-GEMM ops use `elems` (vector lanes touched) and
/// `exp_elems`/`scalar_elems` for the dedicated units.
#[derive(Debug, Clone, PartialEq)]
pub struct Op {
    pub kind: OpKind,
    pub class: OpClass,
    pub operand: Operand,
    /// Matmul dims (0 for non-GEMM).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Replication factor (heads x layers collapsed where uniform).
    pub count: usize,
    /// Non-GEMM element counts.
    pub elems: u64,
    pub exp_elems: u64,
    pub scalar_elems: u64,
    /// Bytes moved for non-GEMM ops (activation streaming).
    pub stream_bytes: u64,
}

impl Op {
    pub fn matmul(
        kind: OpKind,
        class: OpClass,
        operand: Operand,
        m: usize,
        k: usize,
        n: usize,
        count: usize,
    ) -> Self {
        debug_assert!(m > 0 && k > 0 && n > 0 && count > 0);
        Op {
            kind,
            class,
            operand,
            m,
            k,
            n,
            count,
            elems: 0,
            exp_elems: 0,
            scalar_elems: 0,
            stream_bytes: 0,
        }
    }

    pub fn non_gemm(kind: OpKind, elems: u64, count: usize) -> Self {
        Op {
            kind,
            class: OpClass::NonGemm,
            operand: Operand::Dynamic,
            m: 0,
            k: 0,
            n: 0,
            count,
            elems,
            exp_elems: 0,
            scalar_elems: 0,
            stream_bytes: elems * 2, // touch in + out at ~1 B each (int8/fp8 mix)
        }
    }

    pub fn with_exp(mut self, exp_elems: u64) -> Self {
        self.exp_elems = exp_elems;
        self
    }

    pub fn with_scalar(mut self, scalar_elems: u64) -> Self {
        self.scalar_elems = scalar_elems;
        self
    }

    pub fn with_stream_bytes(mut self, bytes: u64) -> Self {
        self.stream_bytes = bytes;
        self
    }

    pub fn is_matmul(&self) -> bool {
        self.class != OpClass::NonGemm
    }

    /// Multiply-accumulates for one instance.
    pub fn macs_each(&self) -> u64 {
        (self.m as u64) * (self.k as u64) * (self.n as u64)
    }

    /// Total MACs including replication.
    pub fn macs(&self) -> u64 {
        self.macs_each() * self.count as u64
    }

    /// FLOPs (2 per MAC) or vector-op count for non-GEMM.
    pub fn flops(&self) -> u64 {
        if self.is_matmul() {
            2 * self.macs()
        } else {
            self.elems * self.count as u64
        }
    }

    /// Stationary-operand bytes for one instance (the K x N tensor).
    pub fn stationary_bytes_each(&self, dtype_bytes: usize) -> u64 {
        (self.k as u64) * (self.n as u64) * dtype_bytes as u64
    }

    /// Total stationary bytes including replication.
    pub fn stationary_bytes(&self, dtype_bytes: usize) -> u64 {
        self.stationary_bytes_each(dtype_bytes) * self.count as u64
    }

    /// Moving-operand (input) bytes per instance.
    pub fn input_bytes_each(&self, dtype_bytes: usize) -> u64 {
        (self.m as u64) * (self.k as u64) * dtype_bytes as u64
    }

    /// Output bytes per instance (accumulators materialize at 4 B before
    /// requantization).
    pub fn output_bytes_each(&self) -> u64 {
        (self.m as u64) * (self.n as u64)
    }

    /// Total bytes touched (roofline denominator).
    pub fn total_bytes(&self, dtype_bytes: usize) -> u64 {
        if self.is_matmul() {
            (self.stationary_bytes_each(dtype_bytes)
                + self.input_bytes_each(dtype_bytes)
                + self.output_bytes_each())
                * self.count as u64
        } else {
            self.stream_bytes * self.count as u64
        }
    }

    /// Arithmetic intensity, FLOP / byte.
    pub fn arithmetic_intensity(&self, dtype_bytes: usize) -> f64 {
        self.flops() as f64 / self.total_bytes(dtype_bytes).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm() -> Op {
        Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, 512, 4096, 11008, 32)
    }

    #[test]
    fn macs_and_flops() {
        let op = gemm();
        assert_eq!(op.macs_each(), 512 * 4096 * 11008);
        assert_eq!(op.macs(), op.macs_each() * 32);
        assert_eq!(op.flops(), 2 * op.macs());
    }

    #[test]
    fn byte_accounting() {
        let op = gemm();
        assert_eq!(op.stationary_bytes_each(1), 4096 * 11008);
        assert_eq!(op.input_bytes_each(1), 512 * 4096);
        assert_eq!(op.output_bytes_each(), 512 * 11008);
    }

    #[test]
    fn intensity_grows_with_m() {
        let mk = |m| {
            Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, m, 4096, 4096, 1)
        };
        let a1 = mk(1).arithmetic_intensity(1);
        let a512 = mk(512).arithmetic_intensity(1);
        assert!(a1 < 2.5, "GEMV AI ~1-2: {a1}");
        assert!(a512 > 100.0, "prefill GEMM AI: {a512}");
    }

    #[test]
    fn non_gemm_defaults() {
        let op = Op::non_gemm(OpKind::RmsNorm, 4096 * 5, 32).with_scalar(32);
        assert!(!op.is_matmul());
        assert_eq!(op.flops(), 4096 * 5 * 32);
        assert_eq!(op.scalar_elems, 32);
        assert!(op.total_bytes(1) > 0);
    }
}

//! Device-scheduling study: chunked prefill, priority admission, and
//! KV-capacity pressure.
//!
//! HALO's serving win comes from keeping decode resident on the CiD
//! substrate — but a serialized monolithic prefill still blocks the whole
//! device for the length of the longest prompt, and a real decode pool
//! has finite HBM for KV. This walkthrough shows the three scheduler
//! mechanisms on top of the single-device state machine:
//!
//! 1. a chunk-size sweep on the interactive mix (TTFT p50/p99 vs
//!    serialized prefill), plus admission-policy contrast rows;
//! 2. a KV-capacity pressure sweep on a disaggregated fleet with
//!    capacity-aware routing (evictions, recompute, peak residency);
//! 3. one concrete heterogeneous fleet: a decode pool mixing a tight and
//!    an unlimited device.
//!
//!     cargo run --release --example chunked_prefill

use halo::cluster::{Interconnect, Mix, Policy};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::report;
use halo::util::fmt_seconds;

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();

    // calibrate offered load once against a single monolithic device
    let t1 = report::cluster::single_device_capacity(&hw, &llm, Mix::Interactive, 8);
    println!("single HALO1 device saturates at {t1:.2} req/s on the interactive mix\n");

    // 1. chunk-size and admission-policy sweep
    println!("{}", report::cluster::chunked_prefill_ttft_at(&hw, t1).to_markdown());

    // 2. KV-capacity pressure under capacity-aware routing
    println!("{}", report::cluster::kv_capacity_pressure_at(&hw, t1).to_markdown());

    // 3. heterogeneous decode pool: device 2 tight, device 3 unlimited
    let trace = Mix::Interactive.trace(42, 120, 2.0 * t1);
    let (mut fleet, mut router) =
        Policy::KvAware.build(&llm, &hw, 4, 8, 0.5, Interconnect::board());
    fleet.set_kv_capacity(2, Some(3_000_000_000));
    let r = fleet.replay(&trace, router.as_mut());
    println!("heterogeneous decode pool (device 2 capped at 3 GB, device 3 unlimited):");
    for d in &r.per_device {
        println!(
            "  device {} [{:>7}]: served {:>3}  evictions {:>3}  recompute {:>6} tok  kv peak {:.2} GB",
            d.id,
            d.role,
            d.served,
            d.evictions,
            d.recompute_tokens,
            d.kv_peak as f64 / 1e9,
        );
    }
    println!(
        "fleet      : TTFT p50 {}  e2e p99 {}  ({} evictions, {} tokens recomputed)\n",
        fmt_seconds(r.ttft_p50()),
        fmt_seconds(r.e2e_p99()),
        r.evictions,
        r.recompute_tokens,
    );

    println!(
        "reading: chunked prefill lets short interactive prompts finish their\n\
         prefill between the chunks of long summarization prompts instead of\n\
         waiting behind them — TTFT relief without giving up the decode batch;\n\
         a per-device KV budget turns decode placement into a packing problem,\n\
         and capacity-aware routing plus evict-and-recompute keeps every\n\
         device inside its HBM while conserving all requests."
    );
}

//! Serving loop: continuous batching over the inference engine.
//!
//! [`Server::run_to_completion`] is the synchronous driver used by the
//! examples, benches and tests: it admits queued requests into free slots
//! (running their prefill), steps the batched decode until all sequences
//! finish, and reports per-request TTFT/TPOT plus aggregate throughput.
//! [`Server::spawn`] wraps the same loop in a worker thread behind mpsc
//! channels for interactive use.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::Batcher;
use super::engine::InferenceEngine;
use super::kv_cache::Slot;
use super::request::{Request, Response};

#[derive(Debug, Clone, Default)]
struct InFlight {
    request: u64,
    tokens: Vec<i32>,
    admitted_at: Option<Instant>,
    ttft: Duration,
    decode_started: Option<Instant>,
}

/// Aggregate serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub decode_steps: u64,
    pub wall: Duration,
    pub execute_time: Duration,
    pub generated_tokens: usize,
}

impl ServerStats {
    pub fn tokens_per_second(&self) -> f64 {
        self.generated_tokens as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Fraction of wall time spent inside PJRT execute (coordinator
    /// overhead = 1 - this).
    pub fn execute_fraction(&self) -> f64 {
        self.execute_time.as_secs_f64() / self.wall.as_secs_f64().max(1e-12)
    }
}

pub struct Server {
    engine: InferenceEngine,
    batcher: Batcher,
}

impl Server {
    pub fn new(engine: InferenceEngine) -> Self {
        Server { engine, batcher: Batcher::new() }
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.submit(r);
    }

    /// Drive the continuous-batching loop until queue and slots drain.
    pub fn run_to_completion(&mut self) -> Result<(Vec<Response>, ServerStats)> {
        let slots = self.engine.slots();
        let mut inflight: Vec<InFlight> = vec![InFlight::default(); slots];
        let mut current: Vec<i32> = vec![0; slots];
        let mut done = Vec::new();
        let mut rejects = Vec::new();
        let t0 = Instant::now();
        let exec0 = self.engine.execute_time;
        let mut steps = 0u64;

        loop {
            // admission: fill every free slot (prefill phase)
            while let Some((slot, req)) = self.batcher.admit(
                self.engine.kv.free_slot(),
                self.engine.max_prompt(),
                &mut rejects,
            ) {
                let admitted_at = Instant::now();
                let out =
                    self.engine.prefill_into_slot(slot, req.id, &req.prompt, req.max_new_tokens)?;
                inflight[slot] = InFlight {
                    request: req.id,
                    tokens: vec![out.first_token],
                    admitted_at: Some(admitted_at),
                    ttft: out.wall,
                    decode_started: Some(Instant::now()),
                };
                current[slot] = out.first_token;
                // a request may be satisfied by the prefill alone
                if req.max_new_tokens == 1 {
                    // pos still advances conceptually; release immediately
                    self.finish(slot, &mut inflight, &mut done);
                }
            }

            if self.engine.kv.is_idle() {
                break;
            }

            // one batched decode step (CiD path)
            let next = self.engine.decode_step(&current)?;
            steps += 1;
            let mut finished = Vec::new();
            for s in self.engine.kv.active_slots() {
                inflight[s].tokens.push(next[s]);
                current[s] = next[s];
                if self.engine.kv.advance(s)? {
                    finished.push(s);
                }
            }
            for s in finished {
                self.finish(s, &mut inflight, &mut done);
            }
        }

        let wall = t0.elapsed();
        let stats = ServerStats {
            requests: done.len(),
            decode_steps: steps,
            wall,
            execute_time: self.engine.execute_time - exec0,
            generated_tokens: done.iter().map(|r: &Response| r.tokens.len()).sum(),
        };
        Ok((done, stats))
    }

    fn finish(&mut self, slot: usize, inflight: &mut [InFlight], done: &mut Vec<Response>) {
        debug_assert!(matches!(
            self.engine.kv.slot(slot),
            Some(Slot::Active { .. }) | Some(Slot::Free)
        ));
        let fl = std::mem::take(&mut inflight[slot]);
        let total = fl.admitted_at.map(|t| t.elapsed()).unwrap_or_default();
        let n_decode = fl.tokens.len().saturating_sub(1).max(1);
        let decode_wall = fl.decode_started.map(|t| t.elapsed()).unwrap_or_default();
        done.push(Response {
            id: fl.request,
            tokens: fl.tokens,
            ttft: fl.ttft,
            tpot: decode_wall / n_decode as u32,
            total,
        });
        self.engine.kv.release(slot);
        self.batcher.complete();
    }

    /// Spawn a server on a worker thread (PJRT handles are not `Send`, so
    /// the engine is constructed inside the worker from the artifacts
    /// path). Returns a submit channel and a response receiver; closing
    /// the submit channel drains and stops the worker.
    pub fn spawn(
        artifacts: std::path::PathBuf,
        slots: usize,
    ) -> (mpsc::Sender<Request>, mpsc::Receiver<Response>, thread::JoinHandle<Result<ServerStats>>)
    {
        let (tx_req, rx_req) = mpsc::channel::<Request>();
        let (tx_resp, rx_resp) = mpsc::channel::<Response>();
        let handle = thread::spawn(move || -> Result<ServerStats> {
            let mut this = Server::new(InferenceEngine::load(&artifacts, slots)?);
            let mut total = ServerStats::default();
            // batch-at-a-time: collect whatever is queued, run it, repeat
            loop {
                match rx_req.recv() {
                    Ok(first) => {
                        this.submit(first);
                        while let Ok(more) = rx_req.try_recv() {
                            this.submit(more);
                        }
                        let (responses, stats) = this.run_to_completion()?;
                        total.requests += stats.requests;
                        total.decode_steps += stats.decode_steps;
                        total.wall += stats.wall;
                        total.execute_time += stats.execute_time;
                        total.generated_tokens += stats.generated_tokens;
                        for r in responses {
                            let _ = tx_resp.send(r);
                        }
                    }
                    Err(_) => break, // channel closed: shut down
                }
            }
            Ok(total)
        });
        (tx_req, rx_resp, handle)
    }
}

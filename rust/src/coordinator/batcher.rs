//! Admission queue and continuous-batching policy.
//!
//! Pure logic (no PJRT): decides which queued request is admitted into
//! which free slot each scheduling round. Tested exhaustively here and
//! driven by the [`super::server`] loop.

use std::collections::VecDeque;

use super::request::Request;

/// FIFO admission with slot-granular continuous batching.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<Request>,
    admitted: u64,
    completed: u64,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop the next request if a slot is free and the request fits the
    /// engine's limits; oversized requests are rejected to `rejects`.
    pub fn admit(
        &mut self,
        free_slot: Option<usize>,
        max_prompt: usize,
        rejects: &mut Vec<Request>,
    ) -> Option<(usize, Request)> {
        let slot = free_slot?;
        while let Some(r) = self.queue.pop_front() {
            if r.prompt.len() > max_prompt {
                rejects.push(r);
                continue;
            }
            self.admitted += 1;
            return Some((slot, r));
        }
        None
    }

    pub fn complete(&mut self) {
        self.completed += 1;
    }

    pub fn stats(&self) -> (u64, u64, usize) {
        (self.admitted, self.completed, self.queue.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![1; len], 4)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new();
        b.submit(req(1, 4));
        b.submit(req(2, 4));
        let mut rej = Vec::new();
        let (s, r) = b.admit(Some(0), 64, &mut rej).unwrap();
        assert_eq!((s, r.id), (0, 1));
        let (_, r2) = b.admit(Some(1), 64, &mut rej).unwrap();
        assert_eq!(r2.id, 2);
        assert!(rej.is_empty());
    }

    #[test]
    fn no_slot_no_admission() {
        let mut b = Batcher::new();
        b.submit(req(1, 4));
        let mut rej = Vec::new();
        assert!(b.admit(None, 64, &mut rej).is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn oversized_requests_rejected_not_stuck() {
        let mut b = Batcher::new();
        b.submit(req(1, 100)); // too long
        b.submit(req(2, 8));
        let mut rej = Vec::new();
        let (_, r) = b.admit(Some(0), 64, &mut rej).unwrap();
        assert_eq!(r.id, 2);
        assert_eq!(rej.len(), 1);
        assert_eq!(rej[0].id, 1);
    }

    #[test]
    fn stats_track_lifecycle() {
        let mut b = Batcher::new();
        b.submit(req(1, 4));
        let mut rej = Vec::new();
        b.admit(Some(0), 64, &mut rej);
        b.complete();
        assert_eq!(b.stats(), (1, 1, 0));
    }
}

//! Fixed-memory log-bucketed latency histogram (HDR-style).
//!
//! The legacy percentile path (`ttft_percentile`) clones and sorts the
//! full served vector on every call — O(n log n) per lookup and O(n)
//! memory per retained population. [`LogHistogram`] bounds both: values
//! land in one of [`N_BUCKETS`] log-spaced buckets (32 sub-buckets per
//! power of two, so the bucket-midpoint representative is within ~1.6%
//! relative error), recording is O(1), percentile lookup is a linear
//! walk over a fixed array, and two histograms merge by adding counts —
//! the property that lets per-device populations roll up into a fleet
//! view without keeping raw samples. This is the bounded-memory metrics
//! layer the ROADMAP's streaming event loop requires.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per octave.
const SUB_BITS: u32 = 5;
const SUBS: usize = 1 << SUB_BITS;
/// Smallest tracked exponent: values below 2^-30 (~1 ns at seconds
/// scale) collapse into the first bucket.
const MIN_EXP: i32 = -30;
/// Largest tracked exponent: values at or above 2^31 (~68 years)
/// collapse into the last bucket.
const MAX_EXP: i32 = 31;
const OCTAVES: usize = (MAX_EXP - MIN_EXP) as usize;
/// Bucket 0 holds zero / negative / NaN; the rest are log-spaced.
pub const N_BUCKETS: usize = OCTAVES * SUBS + 1;

/// Mergeable fixed-memory histogram over non-negative `f64` samples.
///
/// Alongside the bucket counts it tracks exact `n`, `sum`, `min` and
/// `max`, so means and the extreme percentiles (p0/p100) are exact and
/// only interior percentiles pay the ~1.6% bucket-quantization error.
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; N_BUCKETS],
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a sample, derived from the IEEE-754 exponent and
    /// the top [`SUB_BITS`] mantissa bits — no `ln()` on the hot path.
    fn index(x: f64) -> usize {
        if x.is_nan() || x <= 0.0 {
            return 0;
        }
        let bits = x.to_bits();
        let exp = ((bits >> 52) & 0x7ff) as i32 - 1023;
        if exp < MIN_EXP {
            return 1; // subnormals and tiny values share the first octave's floor
        }
        if exp >= MAX_EXP {
            return N_BUCKETS - 1;
        }
        let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        1 + (exp - MIN_EXP) as usize * SUBS + sub
    }

    /// Midpoint representative of bucket `i` (`i >= 1`).
    fn bucket_value(i: usize) -> f64 {
        let j = i - 1;
        let exp = MIN_EXP + (j / SUBS) as i32;
        let sub = (j % SUBS) as f64;
        (exp as f64).exp2() * (1.0 + (sub + 0.5) / SUBS as f64)
    }

    pub fn record(&mut self, x: f64) {
        self.counts[Self::index(x)] += 1;
        self.n += 1;
        if x.is_finite() {
            self.sum += x;
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
    }

    /// Add another histogram's population into this one.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// The raw bucket counts (length [`N_BUCKETS`]) — for bit-exact
    /// merge pins and external aggregation.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Number of recorded samples at or below `x`, to bucket
    /// resolution: every bucket up to and including `x`'s own is
    /// counted (bucket 0's zero/negative/NaN samples always are). The
    /// SLO attainment primitive.
    pub fn count_at_or_below(&self, x: f64) -> u64 {
        self.counts[..=Self::index(x)].iter().sum()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 || !self.min.is_finite() {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 || !self.max.is_finite() {
            0.0
        } else {
            self.max
        }
    }

    /// Exact sum of all finite recorded samples — the OpenMetrics
    /// histogram `_sum` series.
    pub fn sum(&self) -> f64 {
        if self.sum.is_finite() {
            self.sum
        } else {
            0.0
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate p-th percentile (0..=100): walks the cumulative
    /// counts to the nearest order statistic and returns that bucket's
    /// midpoint, clamped to the exact observed `[min, max]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min();
        }
        if p >= 100.0 {
            return self.max();
        }
        let target = ((p / 100.0) * (self.n - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > target {
                let v = if i == 0 { 0.0 } else { Self::bucket_value(i) };
                return v.clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// Summary object (count/min/max/mean/p50/p90/p99) for snapshots.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.n as f64));
        m.insert("min".to_string(), Json::Num(self.min()));
        m.insert("max".to_string(), Json::Num(self.max()));
        m.insert("mean".to_string(), Json::Num(self.mean()));
        m.insert("p50".to_string(), Json::Num(self.percentile(50.0)));
        m.insert("p90".to_string(), Json::Num(self.percentile(90.0)));
        m.insert("p99".to_string(), Json::Num(self.percentile(99.0)));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{percentile, Rng};

    #[test]
    fn empty_and_zero_are_safe() {
        let mut h = LogHistogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.mean(), 0.0);
        h.record(0.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(50.0), 0.0);
    }

    #[test]
    fn single_value_is_exact() {
        let mut h = LogHistogram::new();
        h.record(0.125);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 0.125);
        }
    }

    #[test]
    fn bucket_relative_error_is_bounded() {
        // midpoint representative of the containing bucket is within
        // half a bucket width: 1/(2*SUBS) relative
        for &x in &[1e-6, 3.7e-3, 0.042, 1.0, 17.3, 900.0] {
            let i = LogHistogram::index(x);
            let rep = LogHistogram::bucket_value(i);
            assert!((rep - x).abs() / x < 1.0 / SUBS as f64, "x={x} rep={rep}");
        }
    }

    #[test]
    fn percentiles_track_exact_within_bucket_error() {
        let mut rng = Rng::new(9);
        let mut h = LogHistogram::new();
        let mut xs = Vec::new();
        for _ in 0..20000 {
            // log-uniform over ~6 decades, like latency populations
            let x = 10f64.powf(rng.f64() * 6.0 - 4.0);
            h.record(x);
            xs.push(x);
        }
        for p in [1.0, 10.0, 50.0, 90.0, 99.0, 99.9] {
            let exact = percentile(&xs, p);
            let approx = h.percentile(p);
            assert!(
                (approx - exact).abs() / exact < 0.05,
                "p{p}: exact {exact} approx {approx}"
            );
        }
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut rng = Rng::new(4);
        let (mut a, mut b, mut all) =
            (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for i in 0..5000 {
            let x = rng.f64() * 3.0 + 1e-3;
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            all.record(x);
        }
        a.merge(&b);
        // f64 sums are order-dependent, so compare sum approximately
        assert_eq!(a.counts, all.counts);
        assert_eq!(a.n, all.n);
        assert_eq!(a.min, all.min);
        assert_eq!(a.max, all.max);
        assert!((a.sum - all.sum).abs() < 1e-9 * all.sum.abs());
        for p in [10.0, 50.0, 95.0] {
            assert_eq!(a.percentile(p), all.percentile(p));
        }
    }

    #[test]
    fn merge_of_many_partitions_is_bucket_for_bucket_exact() {
        // the windowed-telemetry contract: per-window histograms merged
        // in any grouping equal the combined population, bucket for
        // bucket, with identical quantiles at every probe point
        let mut rng = Rng::new(21);
        let mut parts: Vec<LogHistogram> = (0..16).map(|_| LogHistogram::new()).collect();
        let mut all = LogHistogram::new();
        for i in 0..12000 {
            let x = 10f64.powf(rng.f64() * 5.0 - 3.0);
            parts[i % 16].record(x);
            all.record(x);
        }
        let mut merged = LogHistogram::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.counts, all.counts);
        assert_eq!(merged.n, all.n);
        assert_eq!(merged.min, all.min);
        assert_eq!(merged.max, all.max);
        for p in [0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(merged.percentile(p), all.percentile(p), "p{p}");
        }
    }

    #[test]
    fn merge_with_empty_is_identity_both_ways() {
        let mut a = LogHistogram::new();
        for x in [0.01, 0.5, 2.0, 40.0] {
            a.record(x);
        }
        let before = a.clone();
        a.merge(&LogHistogram::new());
        assert_eq!(a, before, "merging an empty histogram changes nothing");
        let mut empty = LogHistogram::new();
        empty.merge(&before);
        assert_eq!(empty, before, "merging into an empty histogram copies the population");
        // empty-into-empty stays empty and zero-safe
        let mut e2 = LogHistogram::new();
        e2.merge(&LogHistogram::new());
        assert_eq!(e2.count(), 0);
        assert_eq!(e2.percentile(50.0), 0.0);
    }

    #[test]
    fn merge_of_single_bucket_histograms_is_exact() {
        // both populations in one bucket: the merged histogram is that
        // bucket with the summed count, and every percentile is exact
        let (mut a, mut b) = (LogHistogram::new(), LogHistogram::new());
        for _ in 0..3 {
            a.record(0.25);
        }
        for _ in 0..5 {
            b.record(0.25);
        }
        a.merge(&b);
        assert_eq!(a.count(), 8);
        assert_eq!(a.counts.iter().sum::<u64>(), 8);
        assert_eq!(a.counts.iter().filter(|&&c| c > 0).count(), 1);
        for p in [0.0, 10.0, 50.0, 99.0, 100.0] {
            assert_eq!(a.percentile(p), 0.25);
        }
    }

    #[test]
    fn count_at_or_below_splits_the_population() {
        let mut h = LogHistogram::new();
        for _ in 0..7 {
            h.record(0.1);
        }
        for _ in 0..3 {
            h.record(4.0);
        }
        h.record(0.0); // bucket 0 counts as "at or below"
        assert_eq!(h.count_at_or_below(1.0), 8);
        assert_eq!(h.count_at_or_below(1e9), 11);
        assert_eq!(h.count_at_or_below(1e-12), 1);
        assert_eq!(LogHistogram::new().count_at_or_below(1.0), 0);
    }

    #[test]
    fn extremes_and_garbage_collapse_into_edge_buckets() {
        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(-3.0);
        h.record(1e-200);
        h.record(1e200);
        assert_eq!(h.count(), 4);
        assert_eq!(LogHistogram::index(f64::NAN), 0);
        assert_eq!(LogHistogram::index(-3.0), 0);
        assert_eq!(LogHistogram::index(1e-200), 1);
        assert_eq!(LogHistogram::index(1e200), N_BUCKETS - 1);
    }
}

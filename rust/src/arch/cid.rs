//! CiD cost model: bank-level GEMV/GEMM units inside the HBM stacks.
//!
//! Fig. 3b of the paper: each bank has 32 int8 multipliers fed 32 weight
//! bytes per column access (tCCD cadence) against an input held in a 4 KB
//! double-buffered local SRAM, reduced by an in-bank adder tree.
//!
//! The model captures the two regimes the paper's analysis rests on:
//!
//! * **GEMV (decode)** — every weight byte is read once per use; latency is
//!   the bank-parallel weight stream (row-activation overhead included).
//!   This taps the full internal bandwidth (20.5 TB/s) instead of the
//!   4.1 TB/s IO pins — the whole point of CiD.
//! * **GEMM (prefill)** — input reuse is capped by the 4 KB buffer: with a
//!   32 B column chunk resident per access, at most 128 input rows can be
//!   applied per weight read, so large-M GEMMs re-stream weights
//!   ceil(M/128) times and throughput saturates at the multiplier peak
//!   (41 TOPS) — far below the CiM chiplet. This is the §V-B "fully CiD"
//!   prefill penalty.

use super::{MatmulEngine, OpCost};
use crate::config::HwConfig;
use crate::model::Op;

#[derive(Debug, Clone)]
pub struct CidEngine {
    hw: HwConfig,
}

impl CidEngine {
    pub fn new(hw: &HwConfig) -> Self {
        CidEngine { hw: hw.clone() }
    }

    /// Max input rows that share one weight stream (buffer-limited reuse):
    /// the buffer double-buffers `input_buffer` bytes and each resident
    /// row needs one `bytes_per_access` chunk of the contraction dim.
    pub fn input_reuse(&self, m: usize) -> usize {
        let cap =
            self.hw.cid.input_buffer / self.hw.cid.bytes_per_access / self.hw.cid.buffer_share;
        m.min(cap.max(1))
    }

    /// How many times the stationary operand is streamed from the banks.
    pub fn weight_passes(&self, m: usize) -> usize {
        m.div_ceil(self.input_reuse(m))
    }
}

impl MatmulEngine for CidEngine {
    fn matmul_cost(&self, op: &Op) -> OpCost {
        let hbm = &self.hw.hbm;
        let cid = &self.hw.cid;
        let banks = hbm.total_banks() as f64;
        let dtype = 1; // int8 weights/activations on the CiD path

        let passes = self.weight_passes(op.m) as f64;
        let w_bytes = op.stationary_bytes(dtype) as f64;
        let in_bytes = op.input_bytes_each(dtype) as f64 * op.count as f64;
        let out_bytes = op.output_bytes_each() as f64 * op.count as f64;
        let macs = op.macs() as f64;

        // pipeline components (double-buffered: they overlap)
        let stream_bw = banks * cid.bytes_per_access as f64 / hbm.t_ccd;
        let t_memory = w_bytes * passes / stream_bw * hbm.row_overhead(cid.bytes_per_access);
        let t_compute = macs / (banks * cid.mults_per_bank as f64) * hbm.t_ccd;
        // input broadcast over the channel buses (usually negligible)
        let t_input = in_bytes * passes / hbm.io_bw();

        let latency = t_memory.max(t_compute).max(t_input);

        let e_dram = w_bytes * passes * hbm.e_bank_read + out_bytes * 4.0 * hbm.e_bank_read;
        let e_compute = macs * cid.e_mac;
        let e_buffer = in_bytes * passes * cid.e_sram;

        OpCost {
            latency,
            energy: e_dram + e_compute + e_buffer,
            t_compute,
            t_memory: t_memory.max(t_input),
            t_write: 0.0,
            e_dram,
            e_compute,
            e_buffer,
            e_write: 0.0,
        }
    }

    fn peak_macs(&self) -> f64 {
        self.hw.cid_peak_macs()
    }

    fn stream_bw(&self) -> f64 {
        self.hw.hbm.internal_bw(self.hw.cid.bytes_per_access)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LlmConfig, OpClass, OpKind, Operand};
    use crate::util::prop::{forall, Pair, UsizeIn};

    fn engine() -> CidEngine {
        CidEngine::new(&HwConfig::paper())
    }

    fn gemv(k: usize, n: usize) -> Op {
        Op::matmul(OpKind::FfnUp, OpClass::Gemv, Operand::StaticWeight, 1, k, n, 1)
    }

    #[test]
    fn gemv_is_stream_bound() {
        let e = engine();
        let c = e.matmul_cost(&gemv(4096, 4096));
        assert!(c.t_memory > c.t_compute, "{c:?}");
        assert_eq!(c.latency, c.t_memory);
        // 16 MiB at ~20.5 TB/s with ~1.2x row overhead: around a microsecond
        assert!(c.latency > 0.5e-6 && c.latency < 3e-6, "{}", c.latency);
    }

    #[test]
    fn decode_7b_tpot_scale() {
        // a full 7B decode step streams ~6.5 GB of weights: ~0.4 ms on CiD
        let e = engine();
        let m = LlmConfig::llama2_7b();
        let g = crate::model::build_decode_graph(&m, 512, 1);
        let total: f64 = g.matmul_ops().map(|o| e.matmul_cost(o).latency).sum();
        assert!(total > 0.15e-3 && total < 1.2e-3, "tpot {total}");
    }

    #[test]
    fn reuse_is_buffer_capped() {
        let e = engine();
        assert_eq!(e.input_reuse(1), 1);
        assert_eq!(e.input_reuse(64), 64);
        // 4096 B / 32 B chunks, shared across a 2-bank broadcast cluster
        assert_eq!(e.input_reuse(2048), 64);
        assert_eq!(e.weight_passes(2048), 32);
    }

    #[test]
    fn large_gemm_is_compute_bound() {
        let e = engine();
        let op =
            Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, 2048, 4096, 11008, 1);
        let c = e.matmul_cost(&op);
        assert!(c.t_compute > c.t_memory, "{c:?}");
        // effective rate == multiplier peak
        let eff = op.macs() as f64 / c.latency;
        assert!((eff / e.peak_macs() - 1.0).abs() < 0.05);
    }

    #[test]
    fn latency_monotone_in_every_dim() {
        let e = engine();
        forall(42, 60, Pair(UsizeIn(1, 4096), UsizeIn(1, 8192)), |(k, n)| {
            let a = e.matmul_cost(&gemv(*k, *n));
            let b = e.matmul_cost(&gemv(k + 64, *n));
            let c = e.matmul_cost(&gemv(*k, n + 64));
            a.latency <= b.latency + 1e-15 && a.latency <= c.latency + 1e-15
        });
    }

    #[test]
    fn energy_positive_and_scales_with_passes() {
        let e = engine();
        let m1 =
            Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, 128, 4096, 4096, 1);
        let m2 =
            Op::matmul(OpKind::FfnUp, OpClass::Gemm, Operand::StaticWeight, 256, 4096, 4096, 1);
        let c1 = e.matmul_cost(&m1);
        let c2 = e.matmul_cost(&m2);
        assert!(c1.energy > 0.0);
        // 256 rows -> 4 weight passes vs 2 -> ~2x DRAM energy
        assert!(c2.e_dram > 1.8 * c1.e_dram && c2.e_dram < 2.2 * c1.e_dram);
    }

    #[test]
    fn count_replication_is_linear() {
        let e = engine();
        let one =
            Op::matmul(OpKind::AttnScore, OpClass::Attention, Operand::Dynamic, 1, 128, 512, 1);
        let many =
            Op::matmul(OpKind::AttnScore, OpClass::Attention, Operand::Dynamic, 1, 128, 512, 32);
        let c1 = e.matmul_cost(&one);
        let c32 = e.matmul_cost(&many);
        assert!((c32.latency / c1.latency - 32.0).abs() < 1e-6);
        assert!((c32.energy / c1.energy - 32.0).abs() < 1e-6);
    }
}
